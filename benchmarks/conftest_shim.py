"""Small shared fixtures for benchmarks (no pytest dependency)."""
from __future__ import annotations

from benchmarks.evolving import make_benchmark_graph
from repro.core.bounds import compute_bounds
from repro.core.qrs import build_qrs
from repro.core.semiring import SEMIRINGS


def make_small_qrs():
    eg = make_benchmark_graph(num_vertices=2048, num_edges=16384,
                              num_snapshots=8, batch_size=200)
    sr = SEMIRINGS["sssp"]
    b = compute_bounds(eg, sr, 0)
    return build_qrs(eg, b.uvv, b.val_cap, sr), eg

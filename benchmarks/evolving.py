"""Shared harness for the paper-validation benchmarks (CPU, real timings)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.baselines import BASELINES
from repro.core.bounds import compute_bounds
from repro.core.semiring import SEMIRINGS
from repro.graph.generators import (
    generate_evolving_stream,
    generate_rmat,
    generate_uniform_weights,
)
from repro.graph.structures import build_evolving_graph


def make_benchmark_graph(
    *, num_vertices=8192, num_edges=65536, num_snapshots=16, batch_size=600,
    seed=7, readd_prob=0.25,
):
    src, dst = generate_rmat(num_vertices, num_edges, seed=seed)
    w = generate_uniform_weights(len(src), seed=seed + 1, grid=16)
    (bs, bd, bw), deltas = generate_evolving_stream(
        src, dst, w, num_vertices, num_snapshots=num_snapshots,
        batch_size=batch_size, readd_prob=readd_prob, seed=seed + 2,
    )
    return build_evolving_graph(bs, bd, bw, deltas, num_vertices)


def time_method(eg, query: str, method: str, source=0, *, repeats=1):
    """Median wall-clock seconds (post-warmup: first call includes compile)."""
    sr = SEMIRINGS[query]
    fn = BASELINES[method]
    fn(eg, sr, source)  # warmup/compile
    times = []
    res = stats = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        res, stats = fn(eg, sr, source)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), res, stats


def uvv_stats(eg, query: str, source=0):
    """(true UVV fraction, detected fraction, detected/true recall)."""
    sr = SEMIRINGS[query]
    full, _ = BASELINES["full"](eg, sr, source)
    true_uvv = np.all(full == full[0:1, :], axis=0)
    detected = np.asarray(compute_bounds(eg, sr, source).uvv)
    recall = detected.sum() / max(1, true_uvv.sum())
    return float(true_uvv.mean()), float(detected.mean()), float(recall)

"""Benchmark suite — one entry per paper table/figure. CSV: name,us_per_call,derived.

  table4   — KS / CG / QRS / CQRS wall-clock + speedups (paper Table 4)
  fig9     — QRS edge/vertex reduction fractions        (paper Figure 9)
  fig10    — true vs detected UVV fractions             (paper Figure 10)
  fig12a   — sensitivity to number of snapshots         (paper Figure 12a)
  fig12b   — sensitivity to update-batch size           (paper Figure 12b)
  kernels  — vrelax / embedding_bag / ell_agg / flash-attn op timings
  multiq   — batched (Q×S×V) multi-source CQRS vs a Q-loop of single-source
  evolving-stream — sliding-window StreamingQuery.advance() vs from-scratch
             re-evaluation of each slid window (asserts the per-slide speedup);
             with --sharded, the dst-range-sharded SPMD advance instead: one
             CSV row per slide, asserted bit-for-bit against the single-host
             engine (a schedule-lowering smoke, not a CPU speed contest — run
             under XLA_FLAGS=--xla_force_host_platform_device_count=8);
             with --qbatch Q, batched serving (one StreamingQueryBatch
             advance for Q watchers) vs the sequential Q-loop — per-slide
             CSV rows carry both columns, bit-for-bit asserted, batched ≥2x
             at Q=8 (combine with --sharded for the SPMD Q-fold, exactness
             only);
             with --latency, slide-to-result latency of the pipelined
             serving path (advance_window_async + incremental presence)
             vs the synchronous baseline (blocking advance_window + legacy
             presence rebuild) — p50/p99 per mode, bit-for-bit asserted,
             plus a presence-maintenance microbench (O(capacity) rebuild
             vs O(touched) scatter);
             with --warmstart, cold vs warm time-to-first-served-slide for
             a restarted replica (AOT kernel-grid manifest replay against a
             persistent executable cache + streaming checkpoint resume) —
             bit-for-bit asserted, warm ≥3x cold (≥1.5x with --fast);
             with --chaos, fault-injected serving: seeded multi-fault
             schedules replayed bit-for-bit vs a fault-free reference,
             rollback/recovery latency percentiles, and the disarmed
             injection-hook overhead asserted ≤3% of the per-slide p50
  roofline — summary of dry-run-derived roofline terms (if present)

--json PATH writes the run as a structured BENCH payload (CSV rows +
latency records + schema-v2 metrics block, see repro.utils.benchjson) next
to the --out CSV; --metrics-jsonl PATH (with --latency) additionally writes
one resolved registry snapshot per served slide as JSON lines.

Run: PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]
     [--sharded] [--qbatch Q] [--latency] [--warmstart] [--out CSV]
     [--json PATH] [--metrics-jsonl PATH]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.evolving import make_benchmark_graph, time_method, uvv_stats  # noqa: E402

ROWS = []
LATENCY_RECORDS = []  # structured per-mode records for the --json payload
METRICS_JSONL = None  # --metrics-jsonl PATH: per-slide registry snapshots
METRICS_BLOCK = None  # schema-v2 "metrics" block for the --json payload


def emit(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


# ---------------------------------------------------------------- table 4
def bench_table4(fast: bool):
    scale = dict(num_vertices=4096, num_edges=32768, num_snapshots=8, batch_size=400) \
        if fast else dict(num_vertices=8192, num_edges=65536, num_snapshots=16, batch_size=600)
    eg = make_benchmark_graph(**scale)
    for query in (["sssp"] if fast else ["bfs", "sssp", "sswp"]):
        t_ks, ref, _ = time_method(eg, query, "kickstarter")
        emit(f"table4/{query}/kickstarter", t_ks * 1e6, "baseline")
        for method in ("commongraph", "qrs", "cqrs", "cqrs_folded"):
            t, res, stats = time_method(eg, query, method)
            assert np.allclose(res, ref), f"{method} mismatch vs kickstarter"
            emit(f"table4/{query}/{method}", t * 1e6,
                 f"speedup_vs_ks={t_ks / t:.2f}x")


# ---------------------------------------------------------------- fig 9/10
def bench_fig9_10(fast: bool):
    eg = make_benchmark_graph(
        num_vertices=4096, num_edges=32768,
        num_snapshots=8 if fast else 16, batch_size=400,
    )
    from repro.core.baselines import run_qrs
    from repro.core.semiring import SEMIRINGS

    for query in (["sssp"] if fast else ["bfs", "sssp", "sswp", "ssnp", "viterbi"]):
        t0 = time.perf_counter()
        _, stats = run_qrs(eg, SEMIRINGS[query], 0)
        dt = time.perf_counter() - t0
        emit(f"fig9/{query}/frac_edges_kept", dt * 1e6,
             f"frac={stats['frac_edges_kept']:.4f}")
        emit(f"fig9/{query}/frac_vertices_incremental", dt * 1e6,
             f"frac={1.0 - stats['frac_uvv']:.4f}")
        t0 = time.perf_counter()
        true_f, det_f, recall = uvv_stats(eg, query)
        dt = time.perf_counter() - t0
        emit(f"fig10/{query}/uvv", dt * 1e6,
             f"true={true_f:.4f};detected={det_f:.4f};recall={recall:.4f}")


# ---------------------------------------------------------------- fig 12
def bench_fig12(fast: bool):
    snaps = [8, 16] if fast else [8, 16, 32]
    for s in snaps:
        eg = make_benchmark_graph(num_vertices=4096, num_edges=32768,
                                  num_snapshots=s, batch_size=400)
        t_ks, _, _ = time_method(eg, "sssp", "kickstarter")
        t_c, _, _ = time_method(eg, "sssp", "cqrs")
        emit(f"fig12a/snapshots={s}/cqrs", t_c * 1e6,
             f"speedup_vs_ks={t_ks / t_c:.2f}x")
    batches = [200, 800] if fast else [200, 400, 800, 1600]
    for b in batches:
        eg = make_benchmark_graph(num_vertices=4096, num_edges=32768,
                                  num_snapshots=8, batch_size=b)
        t_ks, _, _ = time_method(eg, "sssp", "kickstarter")
        t_c, _, stats = time_method(eg, "sssp", "cqrs")
        emit(f"fig12b/batch={b}/cqrs", t_c * 1e6,
             f"speedup_vs_ks={t_ks / t_c:.2f}x;uvv={stats['frac_uvv']:.3f}")


# ---------------------------------------------------------------- kernels
def bench_kernels(fast: bool):
    import jax
    import jax.numpy as jnp

    def timeit(fn, *args, n=3):
        fn(*args)  # compile
        jax.block_until_ready(fn(*args))
        t0 = time.perf_counter()
        for _ in range(n):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / n * 1e6

    rng = np.random.default_rng(0)
    # vrelax XLA-reference superstep (kernel path is interpret-mode on CPU)
    from repro.core.concurrent import concurrent_fixpoint
    from repro.core.semiring import SEMIRINGS
    from benchmarks.conftest_shim import make_small_qrs

    qrs, eg = make_small_qrs()
    sr = SEMIRINGS["sssp"]
    us = timeit(
        lambda: concurrent_fixpoint(
            qrs.bootstrap, qrs.src, qrs.dst, qrs.weight, qrs.presence,
            qrs.valid, sr, eg.num_vertices, eg.num_snapshots,
        )[0].block_until_ready()
    )
    emit("kernels/cqrs_fixpoint_xla", us, f"S={eg.num_snapshots}")

    from repro.kernels.embedding_bag.ops import embedding_bag
    table = jnp.asarray(rng.normal(size=(10000, 128)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 10000, (256, 32)).astype(np.int32))
    us = timeit(lambda: embedding_bag(table, idx, use_kernel=False))
    emit("kernels/embedding_bag_xla", us, "B=256,L=32,D=128")

    from repro.kernels.ell_agg.ops import ell_multi_aggregate
    feats = jnp.asarray(rng.normal(size=(512, 32, 128)).astype(np.float32))
    valid = jnp.asarray(rng.random((512, 32)) > 0.3)
    us = timeit(lambda: ell_multi_aggregate(feats, valid, use_kernel=False))
    emit("kernels/ell_agg_xla", us, "R=512,D=32,F=128")

    from repro.kernels.flash_attention.ops import flash_attention
    q = jnp.asarray(rng.normal(size=(1, 4, 512, 64)).astype(np.float32))
    us = timeit(lambda: flash_attention(q, q, q, use_kernel=False))
    emit("kernels/attention_xla", us, "T=512,H=4,d=64")


# ---------------------------------------------------------------- multiq
def bench_multiq(fast: bool):
    """Batched multi-source CQRS vs the Q-loop of single-source CQRS.

    Correctness is asserted per query against the kickstarter baseline;
    ``speedup_vs_loop`` is the headline number (batching amortizes bounds
    launches, the shared-QRS compaction, and the concurrent fixpoint).
    """
    from repro.core.baselines import run_cqrs, run_cqrs_batch, run_kickstarter
    from repro.core.semiring import SEMIRINGS

    q = 8
    scale = dict(num_vertices=4096, num_edges=32768, num_snapshots=8, batch_size=400) \
        if fast else dict(num_vertices=8192, num_edges=65536, num_snapshots=16, batch_size=600)
    eg = make_benchmark_graph(**scale)
    rng = np.random.default_rng(13)
    sources = sorted(int(s) for s in rng.choice(eg.num_vertices, size=q, replace=False))

    for query in (["sssp"] if fast else ["bfs", "sssp", "sswp"]):
        sr = SEMIRINGS[query]
        # per-query kickstarter ground truth
        refs = [run_kickstarter(eg, sr, s)[0] for s in sources]

        run_cqrs(eg, sr, sources[0])  # warmup/compile the single-source path
        t0 = time.perf_counter()
        loop_res = [run_cqrs(eg, sr, s)[0] for s in sources]
        t_loop = time.perf_counter() - t0
        for s, res, ref in zip(sources, loop_res, refs):
            assert np.allclose(res, ref), f"loop cqrs mismatch vs kickstarter (src={s})"
        emit(f"multiq/{query}/q{q}_loop", t_loop * 1e6,
             f"queries_per_s={q / t_loop:.1f}")

        run_cqrs_batch(eg, sr, sources)  # warmup/compile the batched path
        t0 = time.perf_counter()
        batch_res, stats = run_cqrs_batch(eg, sr, sources)
        t_batch = time.perf_counter() - t0
        for i, (s, ref) in enumerate(zip(sources, refs)):
            assert np.allclose(batch_res[i], ref), \
                f"batched cqrs mismatch vs kickstarter (src={s})"
        emit(f"multiq/{query}/q{q}_batched", t_batch * 1e6,
             f"speedup_vs_loop={t_loop / t_batch:.2f}x;"
             f"queries_per_s={q / t_batch:.1f};"
             f"qrs_edges={stats['qrs_edges']}")


# ------------------------------------------------------- evolving-stream
def bench_evolving_stream(fast: bool):
    """Per-slide streaming advance vs from-scratch window re-evaluation.

    The streaming path folds each slide into warm bounds/QRS state and
    evaluates only the appended snapshot; the from-scratch path runs the full
    bounds → UVV → QRS → concurrent CQRS pipeline on the slid window's
    materialized graph (graph construction itself is *excluded* from its
    timing, which is conservative in the streaming path's favor).  Results
    are asserted bit-for-bit equal every slide, and the median per-slide
    speedup is asserted ≥ 1.5× in full mode (the window-64 acceptance
    criterion; 1.7–2.7× measured with the acyclic-parent-forest trim).
    Fast/CI mode uses a smaller window and a looser 1.2× floor so a noisy
    shared runner cannot fail the job without a real regression (~2.8×
    measured at window 16).
    """
    from repro.core.api import EvolvingQuery, StreamingQuery
    from repro.graph.generators import (
        generate_evolving_stream, generate_rmat, generate_uniform_weights,
    )
    from repro.graph.stream import SnapshotLog, WindowView

    if fast:
        v, e, s, batch, slides = 2048, 16384, 16, 200, 5
    else:
        v, e, s, batch, slides = 4096, 32768, 64, 400, 6
    src, dst = generate_rmat(v, e, seed=7)
    w = generate_uniform_weights(len(src), seed=8, grid=16)
    base, deltas = generate_evolving_stream(
        src, dst, w, v, num_snapshots=s + slides + 1, batch_size=batch, seed=9,
    )
    # pre-size the universe so neither path recompiles mid-run
    capacity = e + (s + slides + 1) * batch

    for query in (["sssp"] if fast else ["sssp", "sswp"]):
        log = SnapshotLog(v, capacity=capacity)
        log.append_snapshot(*base)
        for d in deltas[: s - 1]:
            log.append_snapshot(*d)
        view = WindowView(log, size=s)
        sq = StreamingQuery(view, query, 0)
        sq.results  # prime (cold solve + compile)
        sq.advance(deltas[s - 1])  # warm the advance path
        EvolvingQuery(view.materialize(), query, 0).evaluate("cqrs")  # warmup

        stream_ts, fresh_ts = [], []
        for d in deltas[s : s + slides]:
            t0 = time.perf_counter()
            res = sq.advance(d)
            stream_ts.append(time.perf_counter() - t0)
            mat = view.materialize()
            t0 = time.perf_counter()
            ref = EvolvingQuery(mat, query, 0).evaluate("cqrs")
            fresh_ts.append(time.perf_counter() - t0)
            assert np.array_equal(res, ref), \
                f"streaming != fresh on slid window ({query})"

        t_stream = float(np.median(stream_ts))
        t_fresh = float(np.median(fresh_ts))
        speedup = t_fresh / t_stream
        emit(f"evolving-stream/{query}/S{s}_slide_fresh", t_fresh * 1e6,
             "full bounds+QRS+CQRS per window")
        emit(f"evolving-stream/{query}/S{s}_slide_stream", t_stream * 1e6,
             f"speedup_vs_fresh={speedup:.2f}x;window={s};"
             f"supersteps={sq.stats['supersteps']};"
             f"qrs_edges={sq.stats['qrs_edges']}")
        floor = 1.2 if fast else 1.5
        assert speedup >= floor, (
            f"streaming slide speedup {speedup:.2f}x < {floor}x at window {s}"
        )


def bench_evolving_stream_qbatch(fast: bool, q: int, sharded: bool = False):
    """Batched streaming serving (Q watchers, one launch) vs the Q-loop.

    Both paths consume the same stream: the sequential column advances Q
    warm ``StreamingQuery`` instances one by one (the pre-batching serving
    loop), the batched column advances ONE ``StreamingQueryBatch`` — one
    vmapped bounds refresh, one shared-QRS patch, one Q-lane evaluation of
    the appended snapshot.  Results are asserted **bit-for-bit** equal per
    slide, one CSV row per slide carries both columns, and the batched
    median must be ≥2× the sequential at Q=8 in full mode (2.58× measured
    at window 64 on a 2-core runner; the same contract the one-shot
    ``multiq`` mode pins).  Fast/CI mode uses a smaller window where the
    per-slide work is less launch-bound and the same looser 1.2× floor as
    ``bench_evolving_stream`` (1.4–1.7× measured at window 16) — a noisy
    shared runner cannot fail the job without a real regression.  With ``sharded``
    the same comparison runs through the dst-range SPMD engine on a host
    mesh — exactness only, no speedup assertion (a laptop-scale graph split
    8 ways is not a speed contest; the win is the Q-folded collective
    schedule).
    """
    from repro.core.api import StreamingQuery, StreamingQueryBatch
    from repro.graph.generators import (
        generate_evolving_stream, generate_rmat, generate_uniform_weights,
    )
    from repro.graph.stream import SnapshotLog, WindowView

    if fast:
        v, e, s, batch, slides = 2048, 16384, 16, 200, 5
    else:
        v, e, s, batch, slides = 4096, 32768, 64, 400, 6
    if sharded:
        import jax

        from repro.graph.shardlog import ShardedSnapshotLog, ShardedWindowView

        n_shards = max(d for d in (1, 2, 4, 8) if d <= len(jax.devices()))
        if fast:
            v, e, s, batch, slides = 512, 4096, 8, 100, 4
    src, dst = generate_rmat(v, e, seed=7)
    w = generate_uniform_weights(len(src), seed=8, grid=16)
    base, deltas = generate_evolving_stream(
        src, dst, w, v, num_snapshots=s + slides + 2, batch_size=batch, seed=9,
    )
    capacity = e + (s + slides + 2) * batch
    rng = np.random.default_rng(13)
    sources = sorted(int(x) for x in rng.choice(v, size=q, replace=False))

    for query in (["sssp"] if fast else ["sssp", "sswp"]):
        if sharded:
            log = ShardedSnapshotLog(v, n_shards,
                                     capacity=capacity // n_shards + batch)
        else:
            log = SnapshotLog(v, capacity=capacity)
        log.append_snapshot(*base)
        for d in deltas[: s - 1]:
            log.append_snapshot(*d)
        mk_view = ShardedWindowView if sharded else WindowView
        batch_view = mk_view(log, size=s)
        loop_view = mk_view(log, size=s)
        sqb = StreamingQueryBatch(batch_view, query, sources)
        seqs = [StreamingQuery(loop_view, query, x) for x in sources]
        res_b = sqb.results
        for i, sq in enumerate(seqs):
            assert np.array_equal(res_b[i], sq.results), "prime mismatch"
        sqb.advance(deltas[s - 1])  # warm both advance paths
        for sq in seqs:
            sq.advance()

        batch_ts, loop_ts = [], []
        for k, d in enumerate(deltas[s : s + slides]):
            t0 = time.perf_counter()
            got = sqb.advance(d)
            batch_ts.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            refs = [sq.advance() for sq in seqs]
            loop_ts.append(time.perf_counter() - t0)
            for i, ref in enumerate(refs):
                assert np.array_equal(got[i], ref), \
                    f"batched != sequential on slide {k} lane {i} ({query})"
            tag = "-sharded" if sharded else ""
            emit(f"evolving-stream-qbatch{tag}/{query}/slide{k}",
                 batch_ts[-1] * 1e6,
                 f"q={q};window={s};loop_us={loop_ts[-1]*1e6:.1f};"
                 f"speedup_vs_loop={loop_ts[-1]/batch_ts[-1]:.2f}x;"
                 f"bit_for_bit=1")
        t_batch = float(np.median(batch_ts))
        t_loop = float(np.median(loop_ts))
        speedup = t_loop / t_batch
        tag = "-sharded" if sharded else ""
        emit(f"evolving-stream-qbatch{tag}/{query}/S{s}_median",
             t_batch * 1e6,
             f"q={q};loop_us={t_loop*1e6:.1f};speedup_vs_loop={speedup:.2f}x;"
             f"qrs_edges={sqb.stats['qrs_edges']}")
        if not sharded and q >= 8:
            floor = 1.2 if fast else 2.0
            assert speedup >= floor, (
                f"batched streaming serving {speedup:.2f}x < {floor}x at "
                f"Q={q} ({query}, window {s})"
            )


def bench_evolving_stream_sharded(fast: bool):
    """Per-slide sharded SPMD advance, asserted bit-for-bit vs single-host.

    Emits one row per (query, slide) — the CI artifact the host-mesh job
    uploads — with both engines' per-slide latency in the derived column,
    running the naive dst-range and the degree-histogram **balanced**
    assignments side by side: each row carries both modes' per-slide time,
    per-shard occupancy spread (max/mean), and per-slide shard_map kernel
    launches, and the balanced mode's spread is asserted ≤ 2× on the skewed
    RMAT fixture (the naive ranges run far above that).  The sharded path's
    win is the *collective schedule* it lowers (shard-local scatters, one
    per-vertex all-gather per superstep); on a forced host mesh the 8-way
    partitioning of a laptop-scale graph is expected to be slower than the
    single device, so no speedup is asserted here — only exactness.
    """
    import jax

    from repro.core.api import StreamingQuery
    from repro.graph.generators import (
        generate_evolving_stream, generate_rmat, generate_uniform_weights,
    )
    from repro.graph.shardlog import (
        ShardedSnapshotLog, ShardedWindowView, degree_histogram,
    )
    from repro.graph.stream import SnapshotLog, WindowView

    # largest power-of-two shard count the host can mesh (always divides v)
    n_shards = max(d for d in (1, 2, 4, 8) if d <= len(jax.devices()))
    if fast:
        v, e, s, batch, slides = 512, 4096, 8, 100, 4
    else:
        v, e, s, batch, slides = 2048, 16384, 16, 200, 6
    src, dst = generate_rmat(v, e, seed=7)
    w = generate_uniform_weights(len(src), seed=8, grid=16)
    base, deltas = generate_evolving_stream(
        src, dst, w, v, num_snapshots=s + slides + 2, batch_size=batch, seed=9,
    )
    capacity = e + (s + slides + 2) * batch
    hist = degree_histogram(base, deltas, v)

    for query in (["sssp"] if fast else ["sssp", "sswp"]):
        log = SnapshotLog(v, capacity=capacity)
        shard_cap = capacity // n_shards + batch
        slogs = {
            "naive": ShardedSnapshotLog(v, n_shards, capacity=shard_cap),
            "balanced": ShardedSnapshotLog(
                v, n_shards, capacity=shard_cap, assignment="balanced",
                degree_hist=hist,
            ),
        }
        log.append_snapshot(*base)
        for sl in slogs.values():
            sl.append_snapshot(*base)
        for d in deltas[: s - 1]:
            log.append_snapshot(*d)
            for sl in slogs.values():
                sl.append_snapshot(*d)
        view = WindowView(log, size=s)
        sq = StreamingQuery(view, query, 0)
        ssqs = {
            mode: StreamingQuery(ShardedWindowView(sl, size=s), query, 0)
            for mode, sl in slogs.items()
        }
        for ssq in ssqs.values():
            np.testing.assert_array_equal(sq.results, ssq.results)
        sq.advance(deltas[s - 1])  # warm every advance path
        for sl in slogs.values():
            sl.append_snapshot(*deltas[s - 1])
        for ssq in ssqs.values():
            ssq.advance()

        shard_ts = {mode: [] for mode in ssqs}
        launches0 = {m: q.stats["kernel_launches"] for m, q in ssqs.items()}
        for k, d in enumerate(deltas[s : s + slides]):
            t0 = time.perf_counter()
            ref = sq.advance(d)
            t_host = time.perf_counter() - t0
            row_t, row_l = {}, {}
            for mode, ssq in ssqs.items():
                slogs[mode].append_snapshot(*d)
                t0 = time.perf_counter()
                got = ssq.advance()
                row_t[mode] = time.perf_counter() - t0
                assert np.array_equal(got, ref), \
                    f"sharded[{mode}] != single-host on slide {k} ({query})"
                shard_ts[mode].append(row_t[mode])
                row_l[mode] = ssq.stats["kernel_launches"] - launches0[mode]
                launches0[mode] = ssq.stats["kernel_launches"]
            emit(f"evolving-stream-sharded/{query}/slide{k}",
                 row_t["naive"] * 1e6,
                 f"shards={n_shards};window={s};"
                 f"single_host_us={t_host*1e6:.1f};"
                 f"balanced_us={row_t['balanced']*1e6:.1f};"
                 f"occupancy_spread_naive={slogs['naive'].occupancy_spread():.2f};"
                 f"occupancy_spread_balanced={slogs['balanced'].occupancy_spread():.2f};"
                 f"launches_naive={row_l['naive']};"
                 f"launches_balanced={row_l['balanced']};"
                 f"bit_for_bit=1")
        spread = {m: sl.occupancy_spread() for m, sl in slogs.items()}
        emit(f"evolving-stream-sharded/{query}/S{s}_median",
             float(np.median(shard_ts["naive"])) * 1e6,
             f"shards={n_shards};slides={slides};"
             f"balanced_median_us={float(np.median(shard_ts['balanced']))*1e6:.1f};"
             f"occupancy_spread_naive={spread['naive']:.2f};"
             f"occupancy_spread_balanced={spread['balanced']:.2f};"
             f"supersteps={ssqs['naive'].stats['supersteps']};"
             f"qrs_edges={ssqs['naive'].stats['qrs_edges']}")
        assert spread["balanced"] <= 2.0, (
            f"balanced occupancy spread {spread['balanced']:.2f} > 2x "
            f"(naive {spread['naive']:.2f}) on the RMAT fixture"
        )


def bench_evolving_stream_latency(fast: bool):
    """Slide-to-result latency: pipelined serving vs the synchronous stall.

    Both modes serve the same Q=8 ``cqrs_ell`` watcher group through the
    dst-range-sharded SPMD engine on a host mesh, fed identical streams on
    separate logs.  The **synchronous** baseline is the pre-pipelining
    serving loop: a blocking ``advance_window`` per slide with the legacy
    O(capacity) presence-plane rebuild.  The **pipelined** mode runs a
    steady-state serving loop with one window in flight
    (``advance_window_async``): slide k+1's ingest — sweep, append, slide
    routing, ELL packing, the O(touched) incremental presence scatter, and
    kernel dispatch — overlaps the consumer's materialization of window k,
    and per-slide latency is the loop's result-to-result interval.  Results
    are asserted **bit-for-bit** equal across modes on every slide; p50/p99
    land in the CSV rows and (with ``--json``) in structured latency
    records alongside presence touched-slot counts and the shard occupancy
    spread.

    The pipeline's overlap needs a second core (the worker ingests while
    the consumer fetches), so the ≥1.3× p50 floor is asserted only in full
    mode on multi-core hosts — on a single core the two paths serialize
    identically, and fast/CI rows stay report-only exactly like the other
    stream benches' noisy-runner policy.  The presence **microbench** rows
    pin the maintenance win itself independent of core count: a full
    O(capacity) rebuild + upload per flip batch vs the incremental
    O(touched) scatter on the same layout, bit-for-bit equal planes,
    incremental ≥2× in full mode.
    """
    import jax

    from repro.distributed import stream_shard
    from repro.graph.generators import (
        generate_evolving_stream, generate_rmat, generate_uniform_weights,
    )
    from repro.graph.shardlog import ShardedSnapshotLog, ShardedWindowView
    from repro.kernels.vrelax.ops import EllPresenceCache
    from repro.serving.scheduler import QueryBatcher

    q = 8
    query = "sssp"
    n_shards = max(d for d in (1, 2, 4, 8) if d <= len(jax.devices()))
    if fast:
        v, e, s, batch, slides = 512, 4096, 8, 100, 4
    else:
        v, e, s, batch, slides = 4096, 32768, 64, 400, 6
    src, dst = generate_rmat(v, e, seed=7)
    w = generate_uniform_weights(len(src), seed=8, grid=16)
    base, deltas = generate_evolving_stream(
        src, dst, w, v, num_snapshots=s + slides + 2, batch_size=batch, seed=9,
    )
    capacity = e + (s + slides + 2) * batch
    rng = np.random.default_rng(13)
    sources = sorted(int(x) for x in rng.choice(v, size=q, replace=False))

    from repro.obs.export import snapshot as obs_snapshot
    from repro.obs.metrics import MetricsRegistry, use_registry

    def serve_once(pipelined: bool, incremental: bool, per_slide=None):
        """One full serving run under whichever registry is active.

        ``per_slide``: optional list — a resolved registry snapshot is
        appended after each materialized result, *outside* the timed
        interval (snapshot resolution is the lazy-gauge sync point and must
        not land in the latency measurement).
        """
        was = stream_shard._ShardedEllCache.incremental
        stream_shard._ShardedEllCache.incremental = incremental
        try:
            slog = ShardedSnapshotLog(
                v, n_shards, capacity=capacity // n_shards + batch
            )
            slog.append_snapshot(*base)
            for d in deltas[: s - 1]:
                slog.append_snapshot(*d)
            view = ShardedWindowView(slog, size=s)
            qb = QueryBatcher(method="cqrs_ell", pipelined=pipelined)
            for x in sources:
                qb.watch(view, query, x, method="cqrs_ell")
            qb.advance_window(view, deltas[s - 1])  # warm the advance path
            ts: list = []
            outs: list = []
            if pipelined:
                # steady state, one window in flight: interval between
                # consecutive materialized results = slide-to-result
                pending = None
                mark = time.perf_counter()
                for d in deltas[s : s + slides]:
                    nxt = qb.advance_window_async(view, d)
                    if pending is not None:
                        outs.append(pending.result())
                        ts.append(time.perf_counter() - mark)
                        if per_slide is not None:
                            per_slide.append(
                                {"slide": len(outs) - 1, **obs_snapshot()}
                            )
                        mark = time.perf_counter()
                    pending = nxt
                outs.append(pending.result())
                ts.append(time.perf_counter() - mark)
                if per_slide is not None:
                    per_slide.append(
                        {"slide": len(outs) - 1, **obs_snapshot()}
                    )
            else:
                for d in deltas[s : s + slides]:
                    t0 = time.perf_counter()
                    outs.append(qb.advance_window(view, d))
                    ts.append(time.perf_counter() - t0)
            touched: list = []
            rebuilds = 0
            for b in qb._batches.values():
                cache = getattr(b, "_ell_cache", None)
                if cache is not None:
                    st = cache.presence_stats()
                    touched += st["touched"]
                    rebuilds += st["rebuilds"]
            probe = next(iter(qb._batches.values()), None)
            spread = float(slog.occupancy_spread())
            qb.close()
        finally:
            stream_shard._ShardedEllCache.incremental = was
        return outs, ts, touched, rebuilds, spread, probe

    modes = [  # (name, pipelined, incremental presence)
        ("synchronous", False, False),
        ("pipelined", True, True),
    ]
    outs_by_mode: dict = {}
    p50 = {}
    probe = None
    reg = MetricsRegistry()  # scoped: the pipelined pass is the telemetry source
    per_slide_rows: list = []
    for mode, pipelined, incremental in modes:
        if pipelined:
            with use_registry(reg):
                outs, ts, touched, rebuilds, spread, probe = serve_once(
                    pipelined, incremental,
                    per_slide=per_slide_rows if METRICS_JSONL else None,
                )
        else:
            outs, ts, touched, rebuilds, spread, _ = serve_once(
                pipelined, incremental
            )
        ms = np.asarray(ts) * 1e3
        p50[mode] = float(np.percentile(ms, 50))
        p99 = float(np.percentile(ms, 99))
        outs_by_mode[mode] = outs
        LATENCY_RECORDS.append({
            "mode": mode, "query": query, "window": int(s), "q": int(q),
            "per_slide_ms": [float(x) for x in ms],
            "p50_ms": p50[mode], "p99_ms": p99,
            "touched_slots": [int(x) for x in touched],
            "occupancy_spread": spread,
        })
        emit(f"evolving-stream-latency/{query}/{mode}", p50[mode] * 1e3,
             f"p50_ms={p50[mode]:.1f};p99_ms={p99:.1f};q={q};window={s};"
             f"shards={n_shards};presence_rebuilds={rebuilds};"
             f"presence_touched={sum(touched)};"
             f"occupancy_spread={spread:.2f}")

    for k in range(slides):  # bit-for-bit across serving modes, every slide
        a, b = outs_by_mode["synchronous"][k], outs_by_mode["pipelined"][k]
        assert set(a) == set(b), f"watcher sets differ on slide {k}"
        for key in a:
            assert np.array_equal(a[key], b[key]), \
                f"pipelined != synchronous on slide {k} lane {key}"
    speedup = p50["synchronous"] / p50["pipelined"]
    emit(f"evolving-stream-latency/{query}/p50_speedup",
         p50["pipelined"] * 1e3,
         f"speedup_vs_synchronous={speedup:.2f}x;q={q};window={s};"
         f"bit_for_bit=1")
    if not fast and (os.cpu_count() or 1) >= 2:
        assert speedup >= 1.3, (
            f"pipelined p50 speedup {speedup:.2f}x < 1.3x at window {s} "
            f"(Q={q}, cqrs_ell, {n_shards}-shard host mesh)"
        )

    # -- metrics overhead: the ≤3% serving-tax contract --------------------
    # Two measurements.  (a) The asserted bound times one slide's worth of
    # instrumentation directly — all six phase spans plus record_slide on
    # the live pipelined replica — which is microseconds of pure-Python
    # accounting against a milliseconds p50, so the 3% ceiling holds even on
    # noisy shared runners.  (b) A wall-clock A/B (the same pipelined loop
    # with every instrument disabled) is report-only, bit-for-bit asserted,
    # per the stream benches' noisy-runner policy.
    from repro.obs.stability import record_slide
    from repro.obs.trace import PHASES, span

    off = MetricsRegistry(enabled=False)
    with use_registry(off):
        outs_off, ts_off, _, _, _, _ = serve_once(True, True)
    for k in range(slides):
        a, b = outs_by_mode["pipelined"][k], outs_off[k]
        for key in a:
            assert np.array_equal(a[key], b[key]), \
                f"metrics-off != metrics-on on slide {k} lane {key}"
    p50_off = float(np.percentile(np.asarray(ts_off) * 1e3, 50))

    reps = 50
    with use_registry(MetricsRegistry()):
        record_slide(probe)  # warm the instrument-creation paths
        t0 = time.perf_counter()
        for _ in range(reps):
            for ph in PHASES:
                with span(ph):
                    pass
            record_slide(probe)
        instr_us = (time.perf_counter() - t0) / reps * 1e6
    overhead_frac = instr_us / (p50["pipelined"] * 1e3)
    emit("evolving-stream-latency/metrics/overhead", instr_us,
         f"frac_of_p50={overhead_frac:.4f};p50_on_ms={p50['pipelined']:.1f};"
         f"p50_off_ms={p50_off:.1f};bit_for_bit=1")
    assert overhead_frac <= 0.03, (
        f"per-slide instrumentation {instr_us:.0f}us is "
        f"{overhead_frac * 100:.1f}% of the {p50['pipelined']:.1f}ms "
        f"pipelined p50 (contract: <=3%)"
    )

    global METRICS_BLOCK
    snap = obs_snapshot(reg)
    METRICS_BLOCK = {
        "counters": snap["counters"],
        "gauges": snap["gauges"],
        "overhead": {
            "instrumentation_us_per_slide": instr_us,
            "frac_of_p50": overhead_frac,
            "p50_ms_metrics_on": p50["pipelined"],
            "p50_ms_metrics_off": p50_off,
        },
    }
    if per_slide_rows:
        METRICS_BLOCK["per_slide"] = per_slide_rows
    if METRICS_JSONL:
        with open(METRICS_JSONL, "w") as fh:
            for row in per_slide_rows:
                fh.write(json.dumps({"ts": time.time(), **row}) + "\n")
        emit("evolving-stream-latency/metrics/jsonl", 0.0,
             f"path={METRICS_JSONL};slides={len(per_slide_rows)}")

    # -- presence-maintenance microbench (core-count independent) ----------
    # The O(capacity)→O(touched) win needs the rebuild to cost more than one
    # scatter *dispatch* (~1.5 ms on CPU): measured crossover is ≈64k slots
    # (3.3× at 256k, 7.5× at 1M).  Full mode pins the claim at 256k slots;
    # fast mode reports the stream fixture's own capacity (report-only — at
    # toy capacities the rebuild is cheaper than dispatching the scatter,
    # which is exactly why the cache is keyed to capacity-inflated serving).
    lanes = 8
    n_slots = (capacity if fast else max(capacity, 1 << 18)) // lanes * lanes
    eid = np.arange(n_slots).reshape(-1, lanes)
    prng = np.random.default_rng(5)
    mask0 = prng.random(n_slots) < 0.5
    flips = [prng.choice(n_slots, size=batch, replace=False)
             for _ in range(slides)]
    caches = {"legacy": EllPresenceCache(), "incremental": EllPresenceCache()}
    caches["legacy"].incremental = False
    t_us, planes = {}, {}
    for mode, cache in caches.items():
        mask = mask0.copy()
        jax.block_until_ready(cache.update("k", mask, eid, num_queries=q))
        ts = []
        for f in flips:
            mask[f] = ~mask[f]
            t0 = time.perf_counter()
            plane = cache.update("k", mask, eid, num_queries=q)
            jax.block_until_ready(plane)
            ts.append(time.perf_counter() - t0)
        t_us[mode] = float(np.median(ts)) * 1e6
        planes[mode] = np.asarray(plane)
    assert np.array_equal(planes["legacy"], planes["incremental"]), \
        "incremental presence plane != full rebuild"
    assert caches["incremental"].touched == [len(f) for f in flips], \
        "touched-slot counts must pin the flip sizes, not the capacity"
    ratio = t_us["legacy"] / t_us["incremental"]
    emit(f"evolving-stream-latency/presence/rebuild", t_us["legacy"],
         f"slots={n_slots};q={q};flips_per_update={batch}")
    emit(f"evolving-stream-latency/presence/incremental",
         t_us["incremental"],
         f"speedup_vs_rebuild={ratio:.2f}x;slots={n_slots};q={q};"
         f"touched_per_update={batch};bit_for_bit=1")
    if not fast:
        assert ratio >= 2.0, (
            f"incremental presence {ratio:.2f}x < 2x vs O(capacity) rebuild "
            f"({n_slots} slots, {batch} flips/update)"
        )


# ---------------------------------------------------------------- roofline
def bench_warmstart(fast: bool):
    """Cold vs warm time-to-first-served-slide for a restarted replica.

    **Cold** is a fresh process serving its first slide: construct the
    replica, cold-solve the window (with every jit/XLA compile inline on the
    serving path — ``jax.clear_caches()`` first, no persistent cache),
    advance once.  **Warm** is the restarted process: the AOT kernel-grid
    manifest is replayed against the persistent executable cache at process
    start (``warm_from_manifest`` — every compile a disk hit; it runs *off*
    the serving path, before traffic, and is reported separately in the
    derived column), then the timed serving path is checkpoint load + resume
    (zero solves: the checkpointed fixpoints are injected) + advancing the
    same slide.  Both paths serve the identical delta and are asserted
    bit-for-bit; the speedup floor is 3× in full mode, 1.5× in fast/CI mode
    (noisy-runner policy).  Rows:
    ``warmstart/<query>/{cold,warm}_first_slide`` with the speedup and the
    warm breakdown in the derived column.
    """
    import shutil
    import tempfile

    import jax

    from repro.checkpoint import CheckpointManager, resume_streaming, streaming_state
    from repro.core.api import StreamingQueryBatch
    from repro.graph.generators import (
        generate_evolving_stream, generate_rmat, generate_uniform_weights,
    )
    from repro.graph.stream import SnapshotLog, WindowView
    from repro.serving.warmstart import (
        enable_persistent_cache, grid_for, warm_from_manifest, warmup,
    )

    if fast:
        v, e, s, batch = 2048, 16384, 8, 200
    else:
        v, e, s, batch = 4096, 32768, 16, 400
    query, sources = "sssp", [0, 7, 13, 21]
    src, dst = generate_rmat(v, e, seed=7)
    w = generate_uniform_weights(len(src), seed=8, grid=16)
    base, deltas = generate_evolving_stream(
        src, dst, w, v, num_snapshots=s + 4, batch_size=batch, seed=9,
    )
    capacity = e + (s + 4) * batch

    def build():
        log = SnapshotLog(v, capacity=capacity)
        log.append_snapshot(*base)
        for d in deltas[: s - 1]:
            log.append_snapshot(*d)
        return StreamingQueryBatch(
            WindowView(log, size=s), query, sources, method="cqrs"
        )

    first_slide = deltas[s - 1]
    work = tempfile.mkdtemp(prefix="warmstart-bench-")
    try:
        # -- setup (untimed): probe the grid, checkpoint the warm state
        sq = build()
        sq.results
        specs = [grid_for(sq)]
        mgr = CheckpointManager(os.path.join(work, "ckpt"))
        tree, extra = streaming_state(sq)
        mgr.save(0, tree, extra=extra)

        # -- cold: fresh process, no caches anywhere
        jax.clear_caches()
        t0 = time.perf_counter()
        cold_sq = build()
        cold_sq.results
        cold_res = np.asarray(cold_sq.advance(first_slide)).copy()
        t_cold = time.perf_counter() - t0

        # -- populate the persistent executable cache + grid manifest
        # (clear first so the warmup compiles actually run and land on disk)
        cache_dir = os.path.join(work, "xla-cache")
        cache_ok = enable_persistent_cache(cache_dir)
        jax.clear_caches()
        warmup(specs, cache_dir=cache_dir)

        # -- warm: restarted process — manifest replay at process start
        # (off the serving path), then the timed resume + first advance
        jax.clear_caches()
        t0 = time.perf_counter()
        warm_from_manifest(cache_dir)
        t_manifest = time.perf_counter() - t0
        t0 = time.perf_counter()
        arrays, manifest = mgr.load()
        warm_sq = resume_streaming(arrays, manifest["extra"])
        warm_res = np.asarray(warm_sq.advance(first_slide)).copy()
        t_warm = time.perf_counter() - t0

        assert np.array_equal(cold_res, warm_res), \
            "warm-started replica diverged from the cold one"
        speedup = t_cold / t_warm
        emit(f"warmstart/{query}/cold_first_slide", t_cold * 1e6,
             f"construct+prime+advance;window={s};Q={len(sources)}")
        emit(f"warmstart/{query}/warm_first_slide", t_warm * 1e6,
             f"speedup_vs_cold={speedup:.2f}x;"
             f"manifest_replay_s={t_manifest:.3f};"
             f"persistent_cache={'on' if cache_ok else 'off'}")
        floor = 1.5 if fast else 3.0
        if cache_ok:
            assert speedup >= floor, (
                f"warm start {speedup:.2f}x < {floor}x cold "
                f"(cold {t_cold:.2f}s vs warm {t_warm:.2f}s)"
            )
    finally:
        shutil.rmtree(work, ignore_errors=True)


def bench_reshard(fast: bool):
    """Online resharding under hub drift: fixed vs live-migrated layout.

    **Spread track** (host-level, 4 shards, no mesh): a 200-slide adds-only
    stream whose hub region sweeps the vertex space is ingested twice — once
    on a layout balanced for the opening histogram and frozen (``fixed``),
    once under a ``ReshardPolicy`` that rebalances on the live histogram
    when the occupancy spread drifts past 1.5 (``online``).  Rows record the
    per-slide ingest+policy cost and the occupancy-spread trajectory; the
    bench asserts the online layout holds the tail spread ≤ 2.0x max/mean
    where the fixed one degrades past it.

    **Migration track** (SPMD, in-process 1-shard shard_map with a hash
    assignment — a nontrivial position permutation): a live ``cqrs`` query
    is resharded mid-stream; the row's value is the migration pause
    (``reshard()`` wall time) with moved-bytes and the resulting spread in
    the derived column, and every post-migration slide is asserted
    bit-for-bit against a never-resharded run with zero fixpoint re-solves.
    """
    from repro.core.api import StreamingQuery
    from repro.graph.generators import (
        generate_evolving_stream, generate_rmat, generate_uniform_weights,
    )
    from repro.graph.shardlog import (
        ShardedSnapshotLog, ShardedWindowView, degree_histogram,
    )
    from repro.serving.scheduler import ReshardPolicy, plan_reshard

    v = 256
    slides = 60 if fast else 200
    per_slide, width = 32, v // 8
    rng = np.random.default_rng(17)
    base = (rng.integers(0, v, size=per_slide),
            rng.integers(0, width, size=per_slide),
            np.ones(per_slide, np.float32))
    drift = []
    for t in range(1, slides):
        center = (t * v) // slides
        drift.append((
            rng.integers(0, v, size=per_slide),
            (center + rng.integers(0, width, size=per_slide)) % v,
            (1.0 + rng.integers(0, 8, size=per_slide) / 8.0).astype(np.float32),
            (), (),
        ))

    hist0 = degree_histogram(base, [], v)
    logs = {
        "fixed": ShardedSnapshotLog(v, 4, capacity=128, assignment="balanced",
                                    degree_hist=hist0),
        "online": ShardedSnapshotLog(v, 4, capacity=128, assignment="balanced",
                                     degree_hist=hist0),
    }
    pol = ReshardPolicy(spread_threshold=1.5, min_slides=4,
                        on_capacity_growth=False)
    spreads: dict[str, list] = {"fixed": [], "online": []}
    migrations, t_paused = 0, 0.0
    since = 0
    times = {"fixed": 0.0, "online": 0.0}
    for name, log in logs.items():
        log.append_snapshot(*base)
    for d in drift:
        for name, log in logs.items():
            t0 = time.perf_counter()
            log.append_snapshot(*d)
            if name == "online":
                since += 1
                got = plan_reshard(log, pol, slides_since=since)
                if got is not None:
                    tm = time.perf_counter()
                    log.reshard(got)
                    t_paused += time.perf_counter() - tm
                    migrations += 1
                    since = 0
            times[name] += time.perf_counter() - t0
            spreads[name].append(log.occupancy_spread())
    tail = max(1, slides // 8)
    for name in ("fixed", "online"):
        tr = spreads[name]
        emit(
            f"reshard/hubdrift/{name}",
            times[name] / len(drift) * 1e6,
            f"spread_final={tr[-1]:.2f};spread_max={max(tr):.2f};"
            f"spread_tail_max={max(tr[-tail:]):.2f};slides={slides}"
            + (f";migrations={migrations};"
               f"migration_pause_s={t_paused:.4f}" if name == "online" else ""),
        )
    assert max(spreads["online"][-tail:]) <= 2.0, (
        f"online layout did not hold the spread: {spreads['online'][-tail:]}"
    )
    assert spreads["fixed"][-1] > 2.0, (
        "hub drift failed to degrade the fixed layout — stream too tame "
        f"(fixed final spread {spreads['fixed'][-1]:.2f})"
    )
    assert spreads["online"][-1] < spreads["fixed"][-1]
    assert migrations >= 1

    # -- migration track: live SPMD query, pause + bit-for-bit -------------
    vq, eq, s = (512, 4096, 8) if fast else (1024, 8192, 8)
    src, dst = generate_rmat(vq, eq, seed=21)
    w = generate_uniform_weights(len(src), seed=22, grid=16)
    qbase, qdeltas = generate_evolving_stream(
        src, dst, w, vq, num_snapshots=s + 6, batch_size=128, seed=23,
    )

    def replica():
        slog = ShardedSnapshotLog(vq, 1, capacity=eq * 2, assignment="hash")
        slog.append_snapshot(*qbase)
        for d in qdeltas[: s - 1]:
            slog.append_snapshot(*d)
        return StreamingQuery(
            ShardedWindowView(slog, size=s), "sssp", 0
        ), qdeltas[s - 1:]

    ref_sq, pending = replica()
    ref = [np.asarray(ref_sq.results).copy()]
    for d in pending:
        ref_sq.advance(d)
        ref.append(np.asarray(ref_sq.results).copy())
    sq, _ = replica()
    sq.results
    sq.advance(pending[0])
    pre_ss = sq._bounds.supersteps
    report = sq.reshard()  # hash -> balanced: a real position permutation
    assert sq._bounds.supersteps == pre_ss, "migration re-solved a fixpoint"
    np.testing.assert_array_equal(np.asarray(sq.results), ref[1])
    for j, d in enumerate(pending[1:], start=1):
        sq.advance(d)
        np.testing.assert_array_equal(
            np.asarray(sq.results), ref[j + 1],
            err_msg=f"post-migration slide {j}",
        )
    emit(
        "reshard/migration/pause",
        report["seconds"] * 1e6,
        f"moved_positions={report['moved_positions']};"
        f"bytes_moved={report['bytes_moved']};epoch={report['epoch']};"
        f"spread={report['occupancy_spread']:.2f};V={vq};window={s};"
        "resolves=0;bit_for_bit=pass",
    )


def bench_chaos(fast: bool):
    """Chaos-hardened serving: recovery latency + disarmed-hook inertness.

    **Schedule track.**  Seeded multi-fault schedules (``FaultPlan.seeded``)
    replayed through :class:`~repro.ft.chaos.ChaosHarness`: each row is one
    schedule's wall time with its fired/quarantined/degraded accounting, and
    every schedule is asserted to converge **bit-for-bit** with the
    fault-free reference after drain.  One extra schedule bit-flips a
    committed checkpoint payload and asserts the newest-verifiable fallback
    restores bit-for-bit.

    **Recovery track.**  A warm ``QueryBatcher`` on a zero-backoff clock is
    faulted on alternating slides, one advance phase per round: the rows are
    p50/p99 of the *rollback* (the failed, transactionally-rolled-back
    advance serving last-good rows), the *recovery* (the catch-up retry),
    and the *clean advance* baseline — all on the same stream, every slide's
    rows asserted equal to the fault-free reference.

    **Inert track.**  With no plan armed every injection hook is one
    host-side ``is None`` test; the row times the disarmed
    ``fault_point``/``corrupt_point`` pair directly and prices a generous
    16-hooks-per-slide budget against the clean advance p50 (a conservative
    stand-in for the pipelined p50 — the sync path is the shorter
    denominator).  Asserted ≤3% — the criterion that armed-off chaos
    support costs serving nothing.
    """
    import shutil
    import tempfile

    from repro.ft.chaos import ChaosHarness
    from repro.ft.faultinject import (
        ADVANCE_SITES, FaultPlan, FaultSpec, active_injector,
        corrupt_point, fault_point, inject,
    )
    from repro.serving.scheduler import QueryBatcher

    if fast:
        stream = dict(num_snapshots=8)
        seeds = range(3)
    else:
        stream = dict(num_vertices=96, num_edges=384, num_snapshots=12,
                      batch_size=30)
        seeds = range(6)

    # -- schedule track: seeded schedules, bit-for-bit after drain ----------
    h = ChaosHarness(**stream)
    for seed in seeds:
        t0 = time.perf_counter()
        rep = h.run(seed=seed, n_faults=2)
        dt = time.perf_counter() - t0
        assert rep["converged"], f"seed {seed} diverged: {rep['mismatches']}"
        emit(f"chaos/schedule/seed{seed}", dt * 1e6,
             f"faults={rep['faults_fired']};quarantined={rep['quarantined']};"
             f"degraded_slides={rep['degraded_slides']};"
             f"drain_rounds={rep['drain_rounds']};"
             f"max_behind={rep['max_behind']};bit_for_bit=1")

    work = tempfile.mkdtemp(prefix="chaos-bench-")
    try:
        hc = ChaosHarness(**stream, ckpt_every=2, ckpt_dir=work)
        t0 = time.perf_counter()
        rep = hc.run(FaultPlan(specs=(
            FaultSpec(site="ckpt_payload", slide=1, mode="bitflip"),
            FaultSpec(site="advance_eval", slide=2),
        )))
        dt = time.perf_counter() - t0
        assert rep["converged"], rep["mismatches"]
        assert rep.get("ckpt_restore_ok"), "corrupt-step fallback failed"
        emit("chaos/schedule/ckpt_bitflip", dt * 1e6,
             f"faults={rep['faults_fired']};"
             f"degraded_slides={rep['degraded_slides']};"
             f"ckpt_restore_ok=1;bit_for_bit=1")
    finally:
        shutil.rmtree(work, ignore_errors=True)

    # -- recovery track: rollback / catch-up wall times ---------------------
    ref_rows = h._reference["rows"]
    now = [0.0]
    _, view = h._fresh_view()
    qb = QueryBatcher(clock=lambda: now[0], retry_budget=8,
                      backoff_base=0.0, backoff_cap=0.0)
    for q_, s_ in h.watchers:
        qb.watch(view, q_, s_)
    clean_ts, rollback_ts, recover_ts = [], [], []
    for k, d in enumerate(h.serve_deltas):
        if k % 2 == 0:
            site = ADVANCE_SITES[(k // 2) % len(ADVANCE_SITES)]
            with inject(FaultPlan(specs=(FaultSpec(site=site),))) as inj:
                t0 = time.perf_counter()
                out = qb.advance_window(view, d)
                rollback_ts.append(time.perf_counter() - t0)
            assert inj.faults_fired == 1, f"{site} never fired"
            assert out.degraded and max(out.slides_behind.values()) == 1
            t0 = time.perf_counter()
            out = qb.advance_window(view, None)
            recover_ts.append(time.perf_counter() - t0)
            assert not out.degraded, f"retry did not recover slide {k}"
        else:
            t0 = time.perf_counter()
            out = qb.advance_window(view, d)
            clean_ts.append(time.perf_counter() - t0)
            assert not out.degraded
        for key, val in ref_rows[k].items():
            assert np.array_equal(out[key], val), \
                f"chaos recovery != reference on slide {k} lane {key}"
    for name, ts in (("clean_advance", clean_ts), ("rollback", rollback_ts),
                     ("recovery", recover_ts)):
        ms = np.asarray(ts) * 1e3
        emit(f"chaos/recovery/{name}", float(np.median(ts)) * 1e6,
             f"p50_ms={float(np.percentile(ms, 50)):.2f};"
             f"p99_ms={float(np.percentile(ms, 99)):.2f};n={len(ts)};"
             f"bit_for_bit=1")

    # -- inert track: disarmed hooks priced against the serving p50 ---------
    assert active_injector() is None
    reps = 20_000
    t0 = time.perf_counter()
    for _ in range(reps):
        fault_point("advance_eval")
        corrupt_point("ingest", None, num_vertices=0)
    hook_us = (time.perf_counter() - t0) / reps / 2 * 1e6
    per_slide_us = hook_us * 16  # ingest + shards + 4 phases + ckpt + stall
    p50_clean_us = float(np.percentile(np.asarray(clean_ts), 50)) * 1e6
    frac = per_slide_us / p50_clean_us
    emit("chaos/inert/hook_overhead", hook_us,
         f"per_slide_us={per_slide_us:.3f};frac_of_p50={frac:.6f};"
         f"p50_clean_ms={p50_clean_us / 1e3:.2f};hooks_per_slide=16")
    assert frac <= 0.03, (
        f"disarmed injection hooks cost {frac * 100:.2f}% of the per-slide "
        f"p50 (contract: <=3%)"
    )


def bench_roofline_summary(fast: bool):
    pat = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun", "*.json")
    files = sorted(glob.glob(pat))
    if not files:
        emit("roofline/none", 0.0, "run launch.dryrun first")
        return
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        r = rec["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r.get("roofline_fraction")
        emit(
            f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
            bound * 1e6,
            f"dominant={r['dominant']};frac={frac if frac is None else round(frac, 4)}",
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--sharded", action="store_true",
                    help="run evolving-stream through the dst-range-sharded "
                         "SPMD engine (per-slide rows, bit-for-bit asserted)")
    ap.add_argument("--qbatch", type=int, default=None, metavar="Q",
                    help="run evolving-stream as Q batched watchers vs the "
                         "sequential Q-loop (bit-for-bit asserted; batched "
                         "must be ≥2x at Q=8 on the single-host path)")
    ap.add_argument("--latency", action="store_true",
                    help="run evolving-stream in latency mode: pipelined "
                         "serving vs the synchronous baseline, p50/p99 "
                         "slide-to-result per mode, bit-for-bit asserted")
    ap.add_argument("--warmstart", action="store_true",
                    help="run evolving-stream in warm-start mode: cold vs "
                         "warm (AOT manifest replay + checkpoint resume) "
                         "time-to-first-served-slide, bit-for-bit asserted, "
                         "warm >=3x cold (>=1.5x with --fast)")
    ap.add_argument("--reshard", action="store_true",
                    help="run evolving-stream in resharding mode: fixed vs "
                         "online layout occupancy spread over a hub-drift "
                         "stream (online tail spread <=2x asserted) plus a "
                         "live-migration pause row, bit-for-bit asserted")
    ap.add_argument("--chaos", action="store_true",
                    help="run evolving-stream in chaos mode: seeded fault "
                         "schedules bit-for-bit vs a fault-free reference, "
                         "rollback/recovery latency p50/p99, disarmed-hook "
                         "overhead asserted <=3% of the per-slide p50")
    ap.add_argument("--out", default=None, help="also write the CSV to this path")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write a structured BENCH payload (CSV rows + "
                         "latency records, repro.utils.benchjson schema)")
    ap.add_argument("--metrics-jsonl", default=None, metavar="PATH",
                    help="with --latency: write one JSON line per served "
                         "slide (resolved registry snapshot) to PATH")
    args = ap.parse_args()
    global METRICS_JSONL
    METRICS_JSONL = args.metrics_jsonl
    if args.chaos:
        stream_bench = bench_chaos
    elif args.reshard:
        stream_bench = bench_reshard
    elif args.warmstart:
        stream_bench = bench_warmstart
    elif args.latency:
        stream_bench = bench_evolving_stream_latency
    elif args.qbatch is not None:
        stream_bench = lambda fast: bench_evolving_stream_qbatch(  # noqa: E731
            fast, args.qbatch, sharded=args.sharded
        )
    elif args.sharded:
        stream_bench = bench_evolving_stream_sharded
    else:
        stream_bench = bench_evolving_stream
    benches = {
        "table4": bench_table4,
        "fig9_10": bench_fig9_10,
        "fig12": bench_fig12,
        "kernels": bench_kernels,
        "multiq": bench_multiq,
        "evolving-stream": stream_bench,
        "roofline": bench_roofline_summary,
    }
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if args.only and args.only != name:
            continue
        fn(args.fast)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write("name,us_per_call,derived\n")
            for name, us, derived in ROWS:
                fh.write(f"{name},{us:.1f},{derived}\n")
    if args.json:
        import jax

        from repro.utils.benchjson import make_payload, validate_bench_json

        payload = make_payload(
            ROWS,
            mode="fast" if args.fast else "full",
            meta={"argv": sys.argv[1:], "devices": len(jax.devices())},
            latency=LATENCY_RECORDS or None,
            metrics=METRICS_BLOCK,
        )
        validate_bench_json(payload)
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2)


if __name__ == "__main__":
    main()

"""GNN training demo: PNA node classification with the neighbor sampler.

    PYTHONPATH=src python examples/train_gnn.py --steps 50
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.graphs import sampled_block_batch
from repro.graph.generators import generate_rmat
from repro.graph.sampler import NeighborSampler
from repro.graph.structures import CSR
from repro.models.gnn.common import GNNConfig
from repro.models.gnn.pna import pna_defs
from repro.models.params import init_params
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.training.steps import build_gnn_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    args = ap.parse_args()

    # synthetic graph with community-correlated labels/features
    n, e, d_feat, n_cls = 2000, 16000, 32, 5
    rng = np.random.default_rng(0)
    src, dst = generate_rmat(n, e, seed=0)
    labels = rng.integers(0, n_cls, n)
    feats = rng.normal(size=(n, d_feat)).astype(np.float32)
    feats[:, :n_cls] += 2.0 * np.eye(n_cls)[labels]  # learnable signal

    csr = CSR.from_edges(src, dst, np.ones(len(src), np.float32), n)
    sampler = NeighborSampler(csr, fanouts=(10, 5))
    features = jnp.asarray(feats)
    labels_j = jnp.asarray(labels.astype(np.int32))

    cfg = GNNConfig(name="pna-demo", arch="pna", num_layers=2, d_hidden=48,
                    d_feat=d_feat, num_classes=n_cls)
    params = init_params(pna_defs(cfg), jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    base_step = build_gnn_train_step(
        cfg, AdamWConfig(peak_lr=3e-3, warmup_steps=5, total_steps=args.steps)
    )

    @jax.jit
    def step(params, opt_state, seeds, key):
        blocks = sampler.sample(key, seeds)
        batch = sampled_block_batch(blocks, features, labels_j)
        batch["label_mask"] = (
            jnp.arange(batch["node_feat"].shape[0]) < batch.pop("num_seeds")
        ).astype(jnp.float32)
        batch.pop("node_ids")
        return base_step(params, opt_state, batch)

    losses = []
    key = jax.random.PRNGKey(1)
    for i in range(args.steps):
        key, k1, k2 = jax.random.split(key, 3)
        seeds = jax.random.randint(k1, (256,), 0, n, dtype=jnp.int32)
        params, opt_state, m = step(params, opt_state, seeds, k2)
        losses.append(float(m["loss"]))
        if i % 10 == 0:
            print(f"step {i:4d} loss {losses[-1]:.4f}")
    print(f"loss {losses[0]:.3f} → {losses[-1]:.3f}")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()

"""LM serving demo: prefill + batched decode with the request scheduler.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import jax.numpy as jnp

from repro.models.layers import TransformerConfig
from repro.models.params import init_params
from repro.models.transformer import cache_defs, decode_step, transformer_defs
from repro.serving.scheduler import Request, RequestScheduler

CFG = TransformerConfig(
    name="serve-demo", num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
    head_dim=64, d_ff=1024, vocab_size=1024, remat=False,
)
BATCH = 4
MAX_LEN = 128


def main():
    defs = transformer_defs(CFG)
    params = init_params(defs, jax.random.PRNGKey(0))
    cache = init_params(cache_defs(CFG, BATCH, MAX_LEN), jax.random.PRNGKey(1))

    # NOTE: the scheduler drives token-at-a-time decode over per-slot
    # positions; each slot writes its own cache row at its own index.
    state = {"cache": cache}

    @jax.jit
    def decode_at(params, cache, tokens, positions):
        # per-slot positions: run decode per unique index via vmap-style
        # masking — demo uses lockstep positions per wave for simplicity
        logits, new_cache = decode_step(CFG, params, tokens, cache, positions[0])
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache

    def decode_token(tokens, positions, mask):
        nxt, state["cache"] = decode_at(params, state["cache"], tokens, positions)
        return nxt

    sched = RequestScheduler(batch_size=BATCH, eos_id=0, max_len=MAX_LEN)
    for uid in range(8):
        prompt = [1 + (uid * 7 + k) % (CFG.vocab_size - 1) for k in range(5)]
        sched.submit(Request(uid=uid, prompt=prompt, max_new_tokens=8))

    done = sched.run(decode_token, max_steps=200)
    for r in sorted(done, key=lambda r: r.uid):
        print(f"request {r.uid}: prompt={r.prompt} → generated={r.generated}")
    print(f"served {len(done)} requests")


if __name__ == "__main__":
    main()

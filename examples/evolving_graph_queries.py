"""End-to-end evolving-graph scenario: the paper's Table-4 style comparison.

Runs all five evaluation strategies (full / kickstarter / commongraph /
qrs / cqrs) over all five monotone queries on one evolving RMAT graph and
prints the timing + reduction table.

    PYTHONPATH=src python examples/evolving_graph_queries.py [--snapshots 16]
"""
import argparse
import time

import numpy as np

from repro.core.baselines import BASELINES
from repro.core.semiring import SEMIRINGS
from repro.graph.generators import (
    generate_evolving_stream, generate_rmat, generate_uniform_weights,
)
from repro.graph.structures import build_evolving_graph


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=4096)
    ap.add_argument("--edges", type=int, default=32768)
    ap.add_argument("--snapshots", type=int, default=16)
    ap.add_argument("--batch", type=int, default=400)
    args = ap.parse_args()

    src, dst = generate_rmat(args.vertices, args.edges, seed=0)
    w = generate_uniform_weights(len(src), seed=1, grid=16)
    base, deltas = generate_evolving_stream(
        src, dst, w, args.vertices, num_snapshots=args.snapshots,
        batch_size=args.batch, seed=2,
    )
    eg = build_evolving_graph(*base, deltas, args.vertices)

    print(f"{'query':8s} {'method':12s} {'ms':>10s} {'speedup':>8s}  notes")
    for qname, sr in SEMIRINGS.items():
        # Ground truth is run_full (independent from-scratch solves) — NOT the
        # first timed method.  Comparing every method against the previous
        # one's output once mis-attributed a kickstarter trim unsoundness
        # (equal-value plateaus under ssnp's extend=max) as "commongraph
        # disagrees"; commongraph's direct-hop bootstrap was provably fine
        # (G∩ ⊆ every snapshot keeps R∩ conservative for every semiring).
        ref, _ = BASELINES["full"](eg, sr, 0)
        baseline = None
        for method in ("kickstarter", "commongraph", "qrs", "cqrs"):
            fn = BASELINES[method]
            fn(eg, sr, 0)  # warmup
            t0 = time.perf_counter()
            res, stats = fn(eg, sr, 0)
            dt = time.perf_counter() - t0
            assert np.allclose(res, ref), f"{method} disagrees with full ({qname})"
            if baseline is None:
                baseline = dt
            note = ""
            if "frac_uvv" in stats:
                note = (f"uvv={stats['frac_uvv']:.1%} "
                        f"edges_kept={stats['frac_edges_kept']:.1%}")
            print(f"{qname:8s} {method:12s} {dt * 1e3:10.1f} "
                  f"{baseline / dt:7.2f}x  {note}")


if __name__ == "__main__":
    main()

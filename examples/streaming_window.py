"""Sliding-window streaming walkthrough: warm advance() vs from-scratch.

A serving system sees snapshots arrive continuously.  ``StreamingQuery``
keeps warm state — intersection/union bound fixpoints with witness parents,
a slot-patched QRS, and the window's result rows — and each ``advance()``
folds one slide in incrementally instead of recomputing bounds → UVV → QRS →
all-snapshot evaluation from scratch.  The script streams deltas through a
window, prints per-slide timings and the cross-window vertex-value stability
(the paper's 53–99 % observation, which is exactly why sliding beats
recomputing), and asserts bit-for-bit equality with a fresh evaluation on
the final window.

    PYTHONPATH=src python examples/streaming_window.py [--smoke]
"""
import argparse
import time

import numpy as np

from repro.core.api import EvolvingQuery, StreamingQuery
from repro.graph.generators import (
    generate_evolving_stream, generate_rmat, generate_uniform_weights,
)
from repro.graph.stream import SnapshotLog, WindowView
from repro.serving.scheduler import QueryBatcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=4096)
    ap.add_argument("--edges", type=int, default=32768)
    ap.add_argument("--window", type=int, default=32)
    ap.add_argument("--slides", type=int, default=6)
    ap.add_argument("--batch", type=int, default=400)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, not minutes)")
    args = ap.parse_args()
    if args.smoke:
        args.vertices, args.edges, args.window = 512, 2048, 6
        args.slides, args.batch = 3, 64

    src, dst = generate_rmat(args.vertices, args.edges, seed=0)
    w = generate_uniform_weights(len(src), seed=1, grid=16)
    base, deltas = generate_evolving_stream(
        src, dst, w, args.vertices,
        num_snapshots=args.window + args.slides, batch_size=args.batch, seed=2,
    )

    log = SnapshotLog(args.vertices,
                      capacity=args.edges + len(deltas) * args.batch)
    log.append_snapshot(*base)
    for d in deltas[: args.window - 1]:
        log.append_snapshot(*d)
    view = WindowView(log, size=args.window)
    print(f"stream: V={args.vertices} E≈{args.edges} window={args.window} "
          f"({args.slides} slides of {args.batch} updates)\n")

    # A QueryBatcher keeps warm per-(window, query) state; watch() primes.
    qb = QueryBatcher()
    t0 = time.perf_counter()
    sq = qb.watch(view, "sssp", 0)
    print(f"prime (cold solve of {args.window} snapshots): "
          f"{(time.perf_counter() - t0) * 1e3:8.1f} ms   "
          f"UVV={sq.stats['frac_uvv']:.1%} QRS={sq.stats['qrs_edges']} edges")

    # more standing watchers on the same window: same-(view, query, method)
    # watchers share ONE warm StreamingQueryBatch — (Q, V) bounds, one
    # shared patched QRS — so every slide below is one batched advance for
    # the whole group, not Q sequential per-watcher advances
    sources = sorted({0} | {int(s) for s in
                            np.linspace(7, args.vertices - 1, 7, dtype=int)})
    for s in sources[1:]:
        qb.watch(view, "sssp", s)  # primes only the new lane
    print(f"watching Q={len(sources)} sources "
          f"(one batched group: {sq.batch.num_queries} lanes)\n")

    for i, d in enumerate(deltas[args.window - 1:]):
        t0 = time.perf_counter()
        out = qb.advance_window(view, d)
        ms = (time.perf_counter() - t0) * 1e3
        res = out[("sssp", 0)]
        # the paper's stability observation: the appended snapshot's values
        # vs its predecessor's (this is why sliding beats recomputing)
        stable = float(np.mean(res[-1] == res[-2]))
        print(f"slide {i}: {ms:8.1f} ms   supersteps={sq.stats['supersteps']:3d} "
              f"QRS {sq.stats.get('qrs_entered', 0):+d}/-{sq.stats.get('qrs_left', 0)} edges   "
              f"stable vertex values vs prev window: {stable:.1%}")

    t0 = time.perf_counter()
    ref = EvolvingQuery(view.materialize(), "sssp", 0).evaluate("cqrs")
    ms = (time.perf_counter() - t0) * 1e3
    assert np.array_equal(sq.results, ref), "streaming != fresh (bug!)"
    last = sources[-1]
    ref_last = EvolvingQuery(view.materialize(), "sssp", last).evaluate("cqrs")
    assert np.array_equal(out[("sssp", last)], ref_last), "lane != fresh (bug!)"
    print(f"\nfrom-scratch check on final window: {ms:8.1f} ms — "
          "bit-for-bit identical to the streamed state "
          f"(spot-checked lanes 0 and {last}) ✓")


if __name__ == "__main__":
    main()

"""End-to-end LM training driver: data pipeline → jit train step →
checkpoint/restart supervisor → metrics.

Default: a ~10M-param model for a quick CPU demo; ``--model 100m`` selects
the ~100M config (same code path, longer wall-clock).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import TokenPipeline
from repro.ft.recovery import TrainSupervisor
from repro.ft.straggler import StragglerDetector
from repro.models.layers import TransformerConfig
from repro.models.params import init_params
from repro.models.transformer import transformer_defs
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.training.steps import build_lm_train_step

CONFIGS = {
    "10m": TransformerConfig(
        name="demo-10m", num_layers=4, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=8192, remat=False,
    ),
    "100m": TransformerConfig(
        name="demo-100m", num_layers=12, d_model=768, num_heads=12,
        num_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=32768, remat=True,
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=sorted(CONFIGS), default="10m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = CONFIGS[args.model]
    defs = transformer_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")

    opt_cfg = AdamWConfig(peak_lr=3e-3, warmup_steps=20, total_steps=args.steps)
    opt_state = adamw_init(params)
    step_fn = jax.jit(build_lm_train_step(cfg, opt_cfg))

    pipe = TokenPipeline(batch=args.batch, seq=args.seq, vocab=cfg.vocab_size)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    sup = TrainSupervisor(mgr, ckpt_every=args.ckpt_every)
    straggler = StragglerDetector(num_workers=1)

    losses = []

    def one_step(state, step):
        params, opt_state, pipe_state = state
        pipe.restore(pipe_state)
        batch = pipe.next()
        t0 = time.perf_counter()
        params, opt_state, m = step_fn(params, opt_state, batch)
        dt = time.perf_counter() - t0
        straggler.record_step([dt])
        losses.append(float(m["loss"]))
        if step % 20 == 0:
            print(f"step {step:5d} loss {m['loss']:.4f} "
                  f"lr {float(m['lr']):.2e} {dt*1e3:.0f}ms")
        return (params, opt_state, pipe.state())

    state = (params, opt_state, pipe.state())
    state, stats = sup.run(state, one_step, args.steps)
    print(f"done: {stats}. loss {losses[0]:.3f} → {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training did not reduce loss"


if __name__ == "__main__":
    main()

"""Batched multi-source query walkthrough: SSSP + BFS from 32 sources.

Real serving workloads issue many vertex-specific queries over the same
snapshot window.  The Q×S×V CQRS path answers a whole batch with ONE vmapped
bounds launch, ONE shared-QRS compaction, and ONE concurrent fixpoint —
amortizing every piece of graph-resident work — and its results are
bit-for-bit identical to looping single-source queries.

    PYTHONPATH=src python examples/multi_query.py [--sources 32]
"""
import argparse
import time

import numpy as np

from repro.core.api import EvolvingQuery, MultiQuery
from repro.graph.generators import (
    generate_evolving_stream, generate_rmat, generate_uniform_weights,
)
from repro.graph.structures import build_evolving_graph


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=4096)
    ap.add_argument("--edges", type=int, default=32768)
    ap.add_argument("--snapshots", type=int, default=8)
    ap.add_argument("--batch", type=int, default=400)
    ap.add_argument("--sources", type=int, default=32)
    args = ap.parse_args()

    src, dst = generate_rmat(args.vertices, args.edges, seed=0)
    w = generate_uniform_weights(len(src), seed=1, grid=16)
    base, deltas = generate_evolving_stream(
        src, dst, w, args.vertices, num_snapshots=args.snapshots,
        batch_size=args.batch, seed=2,
    )
    eg = build_evolving_graph(*base, deltas, args.vertices)

    rng = np.random.default_rng(3)
    sources = sorted(int(s) for s in
                     rng.choice(args.vertices, size=args.sources, replace=False))
    print(f"graph: V={args.vertices} E={args.edges} S={args.snapshots}; "
          f"Q={len(sources)} sources\n")

    for query in ("sssp", "bfs"):
        # -- batched: one Q×S×V launch -----------------------------------
        mq = MultiQuery(eg, query, sources)
        mq.evaluate()  # warmup/compile
        t0 = time.perf_counter()
        batched = mq.evaluate(method="cqrs")
        t_batch = time.perf_counter() - t0
        st = mq.stats

        # -- reference: loop of single-source queries ---------------------
        EvolvingQuery(eg, query, sources[0]).evaluate("cqrs")  # warmup
        t0 = time.perf_counter()
        looped = np.stack(
            [EvolvingQuery(eg, query, s).evaluate("cqrs") for s in sources]
        )
        t_loop = time.perf_counter() - t0

        assert np.array_equal(batched, looped), "batched != looped (bug!)"
        uvv_frac = st["frac_uvv_per_query"]
        print(f"{query}:")
        print(f"  batched   {t_batch * 1e3:8.1f} ms "
              f"({len(sources) / t_batch:7.1f} queries/s)")
        print(f"  Q-loop    {t_loop * 1e3:8.1f} ms "
              f"({len(sources) / t_loop:7.1f} queries/s)")
        print(f"  speedup   {t_loop / t_batch:8.2f}x  (bit-for-bit identical)")
        print(f"  shared QRS: {st['qrs_edges']} / {st['universe_edges']} edges "
              f"kept ({st['frac_edges_kept']:.1%}); "
              f"UVV% per query: min={min(uvv_frac):.1%} "
              f"mean={np.mean(uvv_frac):.1%} max={max(uvv_frac):.1%}; "
              f"shared-UVV={st['frac_uvv_shared']:.1%}")
        print()


if __name__ == "__main__":
    main()

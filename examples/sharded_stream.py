"""Sharded streaming walkthrough: SPMD window serving on a host mesh.

Partitions the streaming edge universe by dst range across 8 (forced host)
devices, serves a sliding-window query through the shard_map engine, and
checks every slide bit-for-bit against the single-host ``StreamingQuery``:

    PYTHONPATH=src python examples/sharded_stream.py [--smoke]

What to look at in the output:

* per-shard universe occupancy, naive vs rebalanced — appends route each
  edge to the shard owning its destination, so naive dst ranges inherit the
  RMAT degree skew (~3x max/mean, ~18x max/min on this fixture); the
  degree-histogram range rebalance (`assignment="balanced"`) evens the
  per-shard edge mass out to ~1.1x max/mean while keeping every shard-local guarantee (and the serving
  engine bit-for-bit);
* per-slide supersteps and kernel launches — each advance folds the slide
  diff into warm per-shard bounds and evaluates only the appended snapshot,
  with ONE all-gather of the per-vertex values per superstep as the only
  cross-shard traffic (the invariant
  `tests/_stream_shard_checks.py::check_collectives` pins against the
  compiled HLO, including the per-shard Pallas ELL kernels).
"""
import argparse
import os
import time

# must be set before jax initializes: fake an 8-device mesh on one CPU host
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--vertices", type=int, default=None)
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--slides", type=int, default=None)
    args = ap.parse_args()

    import jax

    from repro.core.api import StreamingQuery
    from repro.graph.generators import (
        generate_evolving_stream, generate_rmat, generate_uniform_weights,
    )
    from repro.graph.shardlog import (
        ShardedSnapshotLog, ShardedWindowView, degree_histogram,
    )
    from repro.graph.stream import SnapshotLog, WindowView

    # largest power-of-two shard count the host can mesh (always divides v)
    n_shards = max(d for d in (1, 2, 4, 8) if d <= len(jax.devices()))
    v = args.vertices or (256 if args.smoke else 1024)
    e = v * 8
    window = args.window or (4 if args.smoke else 8)
    slides = args.slides or (3 if args.smoke else 6)
    batch = max(20, e // 80)

    src, dst = generate_rmat(v, e, seed=0)
    w = generate_uniform_weights(len(src), seed=1, grid=16)
    base, deltas = generate_evolving_stream(
        src, dst, w, v, num_snapshots=window + slides, batch_size=batch, seed=2,
    )

    # naive dst ranges inherit the RMAT degree skew; the degree-histogram
    # rebalance moves the range boundaries so per-shard edge mass evens out
    hist = degree_histogram(base, deltas, v)
    log = SnapshotLog(v, capacity=2 * e)
    naive = ShardedSnapshotLog(v, n_shards, capacity=2 * e // n_shards)
    slog = ShardedSnapshotLog(v, n_shards, capacity=2 * e // n_shards,
                              assignment="balanced", degree_hist=hist)
    log.append_snapshot(*base)
    naive.append_snapshot(*base)
    slog.append_snapshot(*base)
    for d in deltas[: window - 1]:
        log.append_snapshot(*d)
        naive.append_snapshot(*d)
        slog.append_snapshot(*d)

    print(f"universe: {slog.num_edges} edges over {n_shards} dst shards")
    print(f"per-shard occupancy, naive ranges:  "
          f"{[sh.num_edges for sh in naive.shards]}  "
          f"(max/mean {naive.occupancy_spread():.1f}x)")
    print(f"per-shard occupancy, rebalanced:    "
          f"{[sh.num_edges for sh in slog.shards]}  "
          f"(max/mean {slog.occupancy_spread():.1f}x)")

    view = WindowView(log, size=window)
    sview = ShardedWindowView(slog, size=window)
    ref_q = StreamingQuery(view, "sssp", 0)
    t0 = time.perf_counter()
    sq = StreamingQuery(sview, "sssp", 0)  # dispatches to the sharded engine
    results = sq.results  # prime: full sharded bounds + window solve
    print(f"\nengine: {type(sq).__name__} (method={sq.method}), "
          f"prime {time.perf_counter() - t0:.2f}s, "
          f"uvv={sq.stats['frac_uvv']:.1%}, qrs_edges={sq.stats['qrs_edges']}")
    np.testing.assert_array_equal(results, ref_q.results)

    print(f"\n{'slide':>5s} {'ms':>8s} {'supersteps':>10s} "
          f"{'launches':>8s} {'qrs_edges':>9s}  check")
    launches = sq.stats["kernel_launches"]
    for k, d in enumerate(deltas[window - 1:]):
        t0 = time.perf_counter()
        got = sq.advance(d)
        dt = time.perf_counter() - t0
        ref = ref_q.advance(d)
        ok = np.array_equal(got, ref)
        print(f"{k:5d} {dt * 1e3:8.1f} {sq.stats['supersteps']:10d} "
              f"{sq.stats['kernel_launches'] - launches:8d} "
              f"{sq.stats['qrs_edges']:9d}  "
              f"{'bit-for-bit == single-host' if ok else 'MISMATCH'}")
        launches = sq.stats["kernel_launches"]
        assert ok, f"sharded advance diverged at slide {k}"

    # shared views are pruned by whoever coordinates their consumers
    # (QueryBatcher.advance_window in serving); doing it here retires the
    # pre-window id arrays of every shard log to bounded delta storage
    sview.prune_history(sq.diff_pos)
    print(f"\nserved {sq.stats['slides']} slides; window "
          f"[{sview.start}, {sview.stop}); per-shard log history retired "
          f"up to {[sh.retired_upto for sh in slog.shards]}")


if __name__ == "__main__":
    main()

"""Quickstart: evaluate an evolving-graph SSSP query with UVV/QRS/CQRS.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import EvolvingQuery
from repro.graph.generators import (
    generate_evolving_stream, generate_rmat, generate_uniform_weights,
)
from repro.graph.structures import build_evolving_graph


def main():
    # 1. build an evolving graph: base snapshot + per-snapshot update batches
    V, E, S = 2048, 16384, 16
    src, dst = generate_rmat(V, E, seed=0)
    w = generate_uniform_weights(len(src), seed=1, grid=16)
    base, deltas = generate_evolving_stream(
        src, dst, w, V, num_snapshots=S, batch_size=256, seed=2,
    )
    graph = build_evolving_graph(*base, deltas, V)
    print(f"evolving graph: V={V} E_universe={graph.num_edges_padded} S={S}")

    # 2. the paper's pipeline: bounds → UVV → QRS → concurrent evaluation
    query = EvolvingQuery(graph, "sssp", source=0)
    bounds = query.bounds
    uvv_frac = float(np.asarray(bounds.uvv).mean())
    print(f"UVV detected for {uvv_frac:.1%} of vertices (Theorem 2)")

    qrs = query.qrs
    print(f"QRS keeps {qrs.stats_dict['frac_edges_kept']:.1%} of edges")

    results = query.evaluate(method="cqrs")  # (S, V) values, all snapshots
    print(f"results: {results.shape}, evaluated in {query.stats['seconds']:.3f}s")

    # 3. cross-check against the naive per-snapshot baseline
    ref = query.evaluate(method="full")
    assert np.allclose(results, ref)
    print("CQRS == full recompute on every snapshot ✓")


if __name__ == "__main__":
    main()

"""Gradient compression for DP all-reduce: int8 quantization + error feedback.

At 2-pod scale the gradient all-reduce over the (slow) pod axis is the
dominant collective; int8 with per-tensor scale cuts those bytes 4× vs bf16
(8× vs fp32).  Error feedback (Karimireddy et al. '19) keeps SGD/Adam
convergence: the quantization residual is added back into the next step's
gradient, so the bias telescopes instead of accumulating.

``compressed_psum`` is built for use inside ``shard_map`` over the axis being
reduced; quantize → psum(int32) → dequantize.  The pure quantizer round-trip
is also used standalone (tests + checkpoint compression).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    """Per-tensor symmetric int8. Returns (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str):
    """int8-quantized all-reduce (use inside shard_map over ``axis_name``).

    The int8 payloads are summed in int32 (no overflow for ≤ 2^23 shards);
    scales are max-reduced so every shard dequantizes identically.
    """
    q, scale = quantize_int8(x)
    scale = jax.lax.pmax(scale, axis_name)
    # requantize against the shared scale so the integer sum is exact
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale), -127, 127
    ).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(jnp.float32) * scale


def ef_compress_tree(grads, residuals):
    """Error-feedback compression of a gradient pytree.

    Returns (quantized-dequantized grads, new residuals).  Apply BEFORE the
    cross-pod reduce; residual = (g + r) − Q(g + r) is replayed next step.
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, scale = quantize_int8(corrected)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), corrected - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_r = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_g, new_r


def init_residuals(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )

"""Logical-axis sharding rules (t5x/MaxText style).

Model code annotates tensors with *logical* axis names; the rules map those
to physical mesh axes.  One rule table serves every architecture, so moving a
model between meshes (single-pod (data, model) vs multi-pod (pod, data,
model)) is a rule edit, not a model edit.

Conventions:
  batch        — global example/token batch            → data (+pod)
  seq          — sequence length in training           → unsharded
  cache_seq    — KV-cache length in decode             → model (flash-decode
                 style partial-softmax sharding for the 32k/500k caches)
  heads/kv     — attention heads                        → model (Megatron TP)
  mlp          — FFN hidden                             → model
  vocab        — embedding/output vocab                 → model
  expert       — MoE expert id                          → model (EP)
  embed        — d_model                                → unsharded (activations)
  snapshots    — evolving-graph snapshot axis           → data
  vertices     — evolving-graph/GNN vertex space        → model
  edges        — evolving-graph/GNN edge space          → model
  table_rows   — recsys embedding-table rows            → model
  stage        — pipeline stage                         → pod (when PP on)
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (logical axis, mesh axis | tuple | None) — first match wins; None = replicate.
# `embed` → data implements FSDP/ZeRO: params + fp32 moments fully sharded
# over data×model (XLA all-gathers weights at use sites); dims that don't
# divide the axis size fall back to replication (see logical_to_spec).
LOGICAL_RULES: list[tuple[str, Optional[str]]] = [
    ("pod_batch", "pod"),
    ("batch", ("pod", "data")),
    ("seq", None),
    ("cache_seq", "model"),
    ("cache_seq_mp", ("pod", "data", "model")),  # 500k decode cache
    ("heads", "model"),
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("vocab", "model"),
    ("expert", "model"),
    ("embed", "data"),
    ("snapshots", ("pod", "data")),
    # full-batch graph/recsys workloads have no batch axis — the vertex/edge/
    # table space takes the whole mesh (pod×data×model)
    ("vertices", ("pod", "data", "model")),
    ("edges", ("pod", "data", "model")),
    ("table_rows", ("pod", "data", "model")),
    ("stage", "pod"),
]


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[list] = None,
    shape: Optional[Sequence[int]] = None,
) -> P:
    """Translate per-dim logical names into a PartitionSpec for ``mesh``.

    * mesh axes absent from ``mesh`` (e.g. ``pod`` single-pod) → replication;
    * a mesh axis is used at most once per spec (first dim wins);
    * with ``shape`` given, axes that do not divide the dim are skipped and
      stay available for later dims (e.g. 60 experts on a 16-wide ``model``
      axis fall back so d_ff can claim it instead).
    """
    rules = LOGICAL_RULES if rules is None else rules
    table = dict(rules)
    used: set = set()
    spec = []
    for i, name in enumerate(logical_axes):
        axis = table.get(name) if name else None
        dim = None if shape is None else int(shape[i])
        candidates = axis if isinstance(axis, tuple) else (axis,) if axis else ()
        picked = []
        residue = dim
        for a in candidates:
            if a not in mesh.axis_names or a in used:
                continue
            size = mesh.shape[a]
            if residue is not None and residue % size:
                continue
            picked.append(a)
            used.add(a)
            if residue is not None:
                residue //= size
        if not picked:
            spec.append(None)
        elif len(picked) == 1:
            spec.append(picked[0])
        else:
            spec.append(tuple(picked))
    return P(*spec)


def sharding_for(
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[list] = None,
    shape: Optional[Sequence[int]] = None,
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical_axes, mesh, rules, shape))


def shard_logical(x, logical_axes, mesh: Mesh, rules: Optional[list] = None):
    """``with_sharding_constraint`` by logical names (no-op off-mesh)."""
    try:
        return jax.lax.with_sharding_constraint(
            x, sharding_for(logical_axes, mesh, rules, x.shape)
        )
    except ValueError:
        return x


# --------------------------------------------------------------------------
# ambient mesh for in-model activation constraints.
#
# Model code calls ``constrain(x, logical_axes)``; with no active mesh it is
# a no-op (single-host smoke tests), under a launcher-set mesh it pins
# activation shardings at block boundaries.  Without these pins GSPMD can
# resolve the FSDP(d_model→data) vs DP(batch→data) contraction conflict by
# REPLICATING activations and all-reducing them at full size (measured:
# a 9.9 GB/chip logits all-reduce on qwen2-moe train — §Perf B-iterations).
# --------------------------------------------------------------------------
import contextlib
import threading

_ACTIVE = threading.local()


@contextlib.contextmanager
def active_mesh(mesh: Mesh):
    prev = getattr(_ACTIVE, "mesh", None)
    _ACTIVE.mesh = mesh
    try:
        yield
    finally:
        _ACTIVE.mesh = prev


def constrain(x, logical_axes):
    mesh = getattr(_ACTIVE, "mesh", None)
    if mesh is None:
        return x
    return shard_logical(x, logical_axes, mesh)

from repro.distributed.partitioning import (
    LOGICAL_RULES,
    logical_to_spec,
    shard_logical,
    sharding_for,
)

__all__ = [
    "LOGICAL_RULES",
    "logical_to_spec",
    "shard_logical",
    "sharding_for",
]

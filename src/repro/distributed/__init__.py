from repro.distributed.partitioning import (
    LOGICAL_RULES,
    logical_to_spec,
    shard_logical,
    sharding_for,
)
from repro.distributed.stream_shard import (
    ShardedQRSMask,
    ShardedStreamingBounds,
    ShardedStreamingQuery,
    host_mesh,
)

__all__ = [
    "LOGICAL_RULES",
    "logical_to_spec",
    "shard_logical",
    "sharding_for",
    "ShardedQRSMask",
    "ShardedStreamingBounds",
    "ShardedStreamingQuery",
    "host_mesh",
]

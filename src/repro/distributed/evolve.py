"""Pod-scale concurrent evolving-graph evaluation (shard_map SPMD).

Layout (DESIGN.md §5):
  * value matrix (S, V): snapshots over (pod, data), vertices over model;
  * edge universe sharded by dst-range over model → the segment-reduce
    scatter is shard-local; only the source-value gather communicates;
  * per superstep: ONE all-gather of the (S_local, V) value matrix over
    `model` — the collective the §Roofline table tracks for this workload;
  * convergence: psum'd change flag inside the while_loop.

The math is identical to repro.core.concurrent (tests assert equality on an
8-device host mesh); this module exists so the 256/512-chip dry-run lowers
the exact collective schedule the real deployment would run.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.semiring import Semiring


def shard_evolving_arrays(qrs_like, mesh: Mesh, *, model_axis: str = "model"):
    """Host-side prep: split dst-sorted edges into per-shard dst ranges.

    Returns dict of arrays padded so every model shard owns the same number
    of edges, with dst rebased to shard-local ids.  (dst-sorted input ⇒ each
    shard's edges are a contiguous slice.)
    """
    n_shards = int(mesh.shape[model_axis])
    src = np.asarray(qrs_like.src)
    dst = np.asarray(qrs_like.dst)
    weight = np.asarray(qrs_like.weight)
    presence = np.asarray(qrs_like.presence)
    valid = np.asarray(qrs_like.valid)
    v = qrs_like.num_vertices
    if v % n_shards:
        raise ValueError(f"num_vertices {v} must divide model shards {n_shards}")
    v_local = v // n_shards

    shard_of = dst // v_local
    counts = np.bincount(shard_of[valid], minlength=n_shards)
    e_local = int(max(1, counts.max()))
    e_local = ((e_local + 127) // 128) * 128

    o_src = np.zeros((n_shards, e_local), np.int32)
    o_dstl = np.zeros((n_shards, e_local), np.int32)
    o_w = np.zeros((n_shards, e_local), np.float32)
    o_pres = np.zeros((n_shards, e_local, presence.shape[1]), np.uint32)
    o_valid = np.zeros((n_shards, e_local), bool)
    for s in range(n_shards):
        idx = np.flatnonzero(valid & (shard_of == s))
        k = len(idx)
        o_src[s, :k] = src[idx]
        o_dstl[s, :k] = dst[idx] - s * v_local
        o_w[s, :k] = weight[idx]
        o_pres[s, :k] = presence[idx]
        o_valid[s, :k] = True
    return {
        "src": jnp.asarray(o_src.reshape(-1)),
        "dst_local": jnp.asarray(o_dstl.reshape(-1)),
        "weight": jnp.asarray(o_w.reshape(-1)),
        "presence": jnp.asarray(o_pres.reshape(n_shards * e_local, -1)),
        "valid": jnp.asarray(o_valid.reshape(-1)),
        "v_local": v_local,
        "e_local": e_local,
    }


def distributed_concurrent_fixpoint(
    bootstrap: jax.Array,  # (V,) replicated
    sharded: dict,  # from shard_evolving_arrays
    sr: Semiring,
    num_vertices: int,
    num_snapshots: int,
    mesh: Mesh,
    *,
    max_iters: Optional[int] = None,
    fixed_iters: Optional[int] = None,
    snap_axes: tuple = ("data",),
    model_axis: str = "model",
):
    """Concurrent CQRS relaxation on the production mesh. → ((S, V), iters).

    ``fixed_iters``: run exactly K supersteps via ``lax.scan`` instead of the
    converge-tested while_loop — the dry-run uses this so cost_analysis counts
    a known superstep count (while-bodies are counted once).
    """
    from jax.experimental.shard_map import shard_map

    snap_axes = tuple(a for a in snap_axes if a in mesh.axis_names)
    s_shards = int(np.prod([mesh.shape[a] for a in snap_axes])) if snap_axes else 1
    if num_snapshots % s_shards:
        raise ValueError(f"S={num_snapshots} must divide snapshot shards {s_shards}")
    s_local = num_snapshots // s_shards
    identity = jnp.float32(sr.identity)
    limit = num_vertices + 1 if max_iters is None else max_iters

    def per_shard(boot, src, dst_local, weight, presence, valid):
        v_local = boot.shape[0]
        # global snapshot ids owned by this shard
        if snap_axes:
            sizes = [mesh.shape[a] for a in snap_axes]
            idx = 0
            for a, sz in zip(snap_axes, sizes):
                idx = idx * sz + jax.lax.axis_index(a)
        else:
            idx = 0
        s0 = idx * s_local
        snaps = s0 + jnp.arange(s_local)
        word_idx = (snaps // 32).astype(jnp.int32)
        bit_idx = (snaps % 32).astype(jnp.uint32)
        words = presence.T[word_idx]  # (S_l, E_l)
        present = ((words >> bit_idx[:, None]) & jnp.uint32(1)).astype(bool)
        present = present & valid[None, :]

        values0 = jnp.broadcast_to(boot[None, :], (s_local, v_local))

        def relax(values_l):
            vals_full = jax.lax.all_gather(
                values_l, model_axis, axis=1, tiled=True
            )  # (S_l, V)
            cand = sr.extend(vals_full[:, src], weight[None, :])
            cand = jnp.where(present, cand, identity)
            seg = functools.partial(
                sr.segment_reduce, segment_ids=dst_local, num_segments=v_local,
                indices_are_sorted=True,
            )
            upd = jax.vmap(seg)(cand)
            return sr.improve(values_l, upd)

        if fixed_iters is not None:
            def scan_body(values_l, _):
                return relax(values_l), None

            values_l, _ = jax.lax.scan(scan_body, values0, None, length=fixed_iters)
            return values_l, jnp.int32(fixed_iters)

        def cond(state):
            _, changed, it = state
            return changed & (it < limit)

        def body(state):
            values_l, _, it = state
            new = relax(values_l)
            local_change = jnp.any(new != values_l)
            axes = snap_axes + (model_axis,)
            changed = jax.lax.psum(local_change.astype(jnp.int32), axes) > 0
            return new, changed, it + 1

        values_l, _, iters = jax.lax.while_loop(
            cond, body, (values0, jnp.bool_(True), jnp.int32(0))
        )
        return values_l, iters

    snap_spec = snap_axes if len(snap_axes) != 1 else snap_axes[0]
    edge_spec = P(model_axis)
    values_spec = P(snap_spec, model_axis)
    fn = shard_map(
        per_shard,
        mesh=mesh,
        in_specs=(
            P(model_axis),  # bootstrap split by vertex range
            edge_spec, edge_spec, edge_spec, P(model_axis, None), edge_spec,
        ),
        out_specs=(values_spec, P()),
        check_rep=False,
    )
    return fn(
        bootstrap, sharded["src"], sharded["dst_local"], sharded["weight"],
        sharded["presence"], sharded["valid"],
    )

"""Pipeline parallelism over the ``pod`` axis (GPipe schedule, shard_map).

The layer stack (already scanned on a leading L axis) is split across pipeline
stages: stage s owns layers [s·L/S, (s+1)·L/S).  Microbatches stream through
stages with ``collective_permute`` carrying activations; the classic GPipe
timeline runs T = M + S − 1 ticks, each tick processing one microbatch on
each busy stage, so bubbles are the usual (S−1)/(M+S−1) fraction.

This is the selectable ``--pp`` strategy for multi-pod runs (default multi-pod
strategy is pod-as-data-parallel); it exists to prove the activation-permute
sharding composes with the in-pod (data, model) layout, and is exercised by
the dry-run as an alternative config.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def gpipe_apply(
    stage_fn: Callable,  # (stage_params, x) -> y   (one stage's layers)
    stage_params,  # leading dim = num_stages, sharded over `pod`
    x_microbatches: jax.Array,  # (M, mb, ...) microbatched inputs
    mesh: Mesh,
    *,
    axis: str = "pod",
):
    """Run the GPipe schedule. Returns (M, mb, ...) final-stage outputs.

    Inside shard_map each pod sees its own stage's params. Tick t: stage s
    processes microbatch (t - s); activations advance one stage per tick via
    collective_permute. Outputs are collected on the last stage and
    broadcast back (psum over one-hot) so every pod returns the same value.
    """
    from jax.experimental.shard_map import shard_map

    num_stages = mesh.shape[axis]
    m = x_microbatches.shape[0]
    ticks = m + num_stages - 1
    perm = [(i, i + 1) for i in range(num_stages - 1)]

    def per_pod(params_local, xs):
        # params_local: (1, ...) this pod's stage params; xs: full (M, mb, ...)
        stage = jax.lax.axis_index(axis)
        p = jax.tree_util.tree_map(lambda a: a[0], params_local)
        mb_shape = xs.shape[1:]

        def tick(carry, t):
            incoming, outputs = carry
            # stage 0 injects microbatch t (if valid); others use the permuted
            mb_idx = jnp.clip(t, 0, m - 1)
            first_in = xs[mb_idx]
            x_in = jnp.where(stage == 0, first_in, incoming)
            y = stage_fn(p, x_in)
            # collect on the final stage: microbatch (t - (S-1))
            out_idx = t - (num_stages - 1)
            is_final = stage == num_stages - 1
            valid = (out_idx >= 0) & (out_idx <= m - 1)
            outputs = jax.lax.cond(
                valid & is_final,
                lambda o: o.at[jnp.clip(out_idx, 0, m - 1)].set(y),
                lambda o: o,
                outputs,
            )
            nxt = jax.lax.ppermute(y, axis, perm)
            return (nxt, outputs), None

        init = (
            jnp.zeros(mb_shape, xs.dtype),
            jnp.zeros((m,) + mb_shape, xs.dtype),
        )
        (_, outputs), _ = jax.lax.scan(tick, init, jnp.arange(ticks))
        # broadcast final-stage outputs to every pod
        one_hot = (jax.lax.axis_index(axis) == num_stages - 1).astype(outputs.dtype)
        return jax.lax.psum(outputs * one_hot, axis)

    in_specs = (
        jax.tree_util.tree_map(lambda _: P(axis), stage_params),
        P(),
    )
    return shard_map(
        per_pod, mesh=mesh, in_specs=in_specs, out_specs=P(), check_rep=False
    )(stage_params, x_microbatches)

"""SPMD sliding-window serving: sharded streaming bounds + query (shard_map).

Device-side counterpart of :mod:`repro.graph.shardlog`.  The host structures
partition the edge universe by destination; this module runs the streaming
maintenance passes (:class:`~repro.core.bounds.StreamingBounds`'s monotone
re-relaxations, KickStarter-style parent trims, and the per-snapshot
incremental evaluation) as ``shard_map`` programs over a 1-D ``model`` mesh
with each shard owning the vertices its log's
:class:`~repro.graph.shardlog.ShardAssignment` names (equal dst ranges by
default — the :func:`repro.distributed.evolve` layout — or the balanced /
hash-of-dst rebalances) and all edges sinking there.  Per-vertex state
lives in the assignment's flat position space, so the kernels are
assignment-agnostic; ``method="cqrs_ell"`` additionally runs the Pallas
vrelax kernel per shard INSIDE ``shard_map`` over per-shard row-split ELL
tiles (:func:`_ell_kernels`) instead of a replicated stacked-universe
launch.

Communication contract (the §Roofline invariant, asserted by
``tests/_stream_shard_checks.py`` against the lowered HLO):

* the segment-reduce **scatter is shard-local by construction** (every edge's
  dst lives on its own shard), and so are the witness-count updates, QRS keep
  rules, and parent selections that feed it;
* per superstep exactly **one all-gather of the per-vertex state** (values /
  BFS levels / invalid flags — all "source-value" gathers in the paper's
  sense) crosses shards, plus the scalar convergence ``psum`` every
  while-body also carries in :func:`distributed_concurrent_fixpoint`.

The maintained fixpoints are **bit-for-bit** identical to the single-host
:class:`~repro.core.api.StreamingQuery`: min/max segment reductions are
order-exact, ``extend`` is elementwise, and both engines run the same
superstep sequence — so partitioning changes which device computes a float,
never the float.  A host-mesh fallback
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) makes the whole
subsystem testable in CI.

Serving Q-fold: :class:`ShardedStreamingQueryBatch` carries a leading query
axis through the same machinery — ``(Q, V)`` state split on the VERTEX axis
(:func:`_kernels_q`), so one ``shard_map`` launch maintains/evaluates all Q
watchers with the collective schedule unchanged (the all-gather tile is Q
rows tall, but it is still exactly one all-gather per superstep).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.api import StreamingQuery, StreamingQueryBatch
from repro.core.bounds import BoundsResult, StreamingBounds, detect_uvv
from repro.core.engine import PARENT_FRAGILE
from repro.core.qrs import PatchableQRS
from repro.core.semiring import Semiring
from repro.graph.shardlog import ShardedSnapshotLog, ShardedWindowView
from repro.obs.trace import span
from repro.utils.padding import pad_to

MODEL_AXIS = "model"


def host_mesh(n_shards: int, axis_name: str = MODEL_AXIS) -> Mesh:
    """1-D mesh over the first ``n_shards`` local devices.

    On a development host, export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before* jax
    initializes to fake an 8-device mesh on CPU (the CI pattern).
    """
    devices = jax.devices()
    if len(devices) < n_shards:
        raise RuntimeError(
            f"need {n_shards} devices for {n_shards} shards but only "
            f"{len(devices)} are visible; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards} before jax "
            f"initializes (or shard the log to fewer shards)"
        )
    return Mesh(np.asarray(devices[:n_shards]), (axis_name,))


@functools.lru_cache(maxsize=None)
def _kernels(mesh: Mesh, sr: Semiring, num_vertices: int, e_cap: int,
             model_axis: str):
    """shard_map maintenance kernels, compiled once per (mesh, semiring,
    vertex count, per-shard capacity class).

    All edge arrays are flat ``(n_shards * e_cap,)`` stacks
    (:meth:`ShardedSnapshotLog.stacked_arrays`); per-vertex state is ``(V,)``
    split by vertex range.  Inside the shard body every index is local:
    ``dst_local`` scatters into the shard's own ``v_local`` segment, and
    parent edge ids index the shard's own ``e_cap`` slice.

    MIRROR WARNING: :func:`_kernels_q` carries the same three bodies with a
    leading query axis (different shapes/specs keep this scalar HLO pinned
    unchanged) — any fix to the maintenance algebra here MUST be applied
    there too, and vice versa.
    """
    from jax.experimental.shard_map import shard_map

    ax = model_axis
    n_shards = int(mesh.shape[ax])
    if num_vertices % n_shards:
        raise ValueError(
            f"num_vertices {num_vertices} must be divisible by the "
            f"{n_shards} mesh shards"
        )
    v_local = num_vertices // n_shards
    identity = jnp.float32(sr.identity)
    limit = num_vertices + 1
    unreached = jnp.int32(num_vertices + 1)

    def local_vertex_ids():
        return (jnp.arange(v_local, dtype=jnp.int32)
                + jax.lax.axis_index(ax) * v_local)

    def fixpoint_body(values_l, src, dst_local, weight, active):
        # Monotone relaxation from values_l (conservative ⇒ exact; identical
        # supersteps to repro.core.engine._fixpoint, so identical floats).
        def relax(vals_l):
            vals_full = jax.lax.all_gather(vals_l, ax, axis=0, tiled=True)
            cand = sr.extend(vals_full[src], weight)  # source-value gather
            cand = jnp.where(active, cand, identity)
            upd = sr.segment_reduce(  # scatter: shard-local by construction
                cand, dst_local, v_local, indices_are_sorted=False
            )
            return sr.improve(vals_l, upd)

        def cond(state):
            _, changed, it = state
            return changed & (it < limit)

        def body(state):
            vals, _, it = state
            new = relax(vals)
            changed = jax.lax.psum(
                jnp.any(new != vals).astype(jnp.int32), ax
            ) > 0
            return new, changed, it + 1

        vals, _, iters = jax.lax.while_loop(
            cond, body, (values_l, jnp.bool_(True), jnp.int32(0))
        )
        return vals, iters

    def parents_body(values_l, src, dst_local, weight, active, source):
        # Shard-local port of repro.core.engine.compute_parents: BFS levels
        # over the achieving subgraph (gathered per superstep), parents drawn
        # from level-(L-1)→L edges only, so chains strictly descend — the
        # same acyclicity argument, with parent ids in shard-local edge space.
        vals_full = jax.lax.all_gather(values_l, ax, axis=0, tiled=True)
        cand = sr.extend(vals_full[src], weight)
        achieving = (active & (cand == values_l[dst_local])
                     & (values_l[dst_local] != identity))
        local_ids = local_vertex_ids()
        level0 = jnp.where(local_ids == source, 0, unreached).astype(jnp.int32)

        def cond(state):
            return state[1]

        def body(state):
            level, _ = state
            lvl_full = jax.lax.all_gather(level, ax, axis=0, tiled=True)
            cand_lvl = jnp.where(
                achieving & (lvl_full[src] < unreached),
                lvl_full[src] + 1, unreached,
            )
            upd = jax.ops.segment_min(
                cand_lvl, dst_local, v_local, indices_are_sorted=False
            )
            new = jnp.minimum(level, upd)
            changed = jax.lax.psum(
                jnp.any(new != level).astype(jnp.int32), ax
            ) > 0
            return new, changed

        level, _ = jax.lax.while_loop(cond, body, (level0, jnp.bool_(True)))
        lvl_full = jax.lax.all_gather(level, ax, axis=0, tiled=True)
        on_forest = achieving & (lvl_full[src] + 1 == level[dst_local])
        eid = jnp.where(on_forest, jnp.arange(e_cap, dtype=jnp.int32), e_cap)
        parent = jax.ops.segment_min(
            eid, dst_local, v_local, indices_are_sorted=False
        )
        parent = jnp.where(parent >= e_cap, -1, parent)
        fragile = (values_l != identity) & (level == unreached)
        parent = jnp.where(fragile, jnp.int32(PARENT_FRAGILE), parent)
        return jnp.where(local_ids == source, -1, parent)

    def invalidate_body(values_l, parent_l, deleted, src, source):
        # Shard-local port of repro.core.engine.invalidate_from_deletions:
        # a vertex's parent edge sinks at it, hence lives on its own shard;
        # only the transitive invalid flags are gathered.
        has_parent = parent_l >= 0
        pidx = jnp.maximum(parent_l, 0)
        invalid0 = (has_parent & deleted[pidx]) | (parent_l == PARENT_FRAGILE)
        parent_src = src[pidx]  # global vertex ids

        def cond(state):
            return state[1]

        def body(state):
            invalid, _ = state
            inv_full = jax.lax.all_gather(invalid, ax, axis=0, tiled=True)
            nxt = invalid | (has_parent & inv_full[parent_src])
            changed = jax.lax.psum(
                jnp.any(nxt != invalid).astype(jnp.int32), ax
            ) > 0
            return nxt, changed

        invalid, _ = jax.lax.while_loop(
            cond, body, (invalid0, jnp.bool_(True))
        )
        new_values = jnp.where(invalid, identity, values_l)
        new_values = jnp.where(
            local_vertex_ids() == source, jnp.float32(sr.source), new_values
        )
        return new_values, invalid

    e = P(ax)  # flat per-shard stacks / vertex-range splits
    r = P()  # replicated scalars
    fixpoint = jax.jit(shard_map(
        fixpoint_body, mesh=mesh,
        in_specs=(e, e, e, e, e), out_specs=(e, r), check_rep=False,
    ))
    parents = jax.jit(shard_map(
        parents_body, mesh=mesh,
        in_specs=(e, e, e, e, e, r), out_specs=e, check_rep=False,
    ))
    invalidate = jax.jit(shard_map(
        invalidate_body, mesh=mesh,
        in_specs=(e, e, e, e, r), out_specs=(e, e), check_rep=False,
    ))
    return {"fixpoint": fixpoint, "parents": parents, "invalidate": invalidate}


@functools.lru_cache(maxsize=None)
def _kernels_q(mesh: Mesh, sr: Semiring, num_vertices: int, e_cap: int,
               model_axis: str, num_queries: int):
    """Q-batched shard_map maintenance kernels (the serving Q-fold).

    Same bodies as :func:`_kernels` with a leading query axis on every
    per-vertex array — state is ``(Q, V)`` split on the VERTEX axis, so the
    per-superstep collective schedule is unchanged: exactly ONE all-gather
    (now of the ``(Q, v_local)`` tile, one op regardless of Q) plus the
    scalar convergence ``psum``.  The joint ``while_loop`` runs until the
    slowest query converges; the extra supersteps for already-converged
    lanes are idempotent monotone relaxations, so per-lane results are
    bit-for-bit identical to Q scalar-kernel runs.

    MIRROR WARNING: these are the :func:`_kernels` bodies with a leading
    query axis — any fix to the maintenance algebra in either function MUST
    be applied to both (the bit-for-bit batch≡loop tests sample only some
    semirings/seeds and may not catch a one-sided edit).
    """
    from jax.experimental.shard_map import shard_map

    ax = model_axis
    n_shards = int(mesh.shape[ax])
    if num_vertices % n_shards:
        raise ValueError(
            f"num_vertices {num_vertices} must be divisible by the "
            f"{n_shards} mesh shards"
        )
    del num_queries  # shapes are taken from the operands; key only
    v_local = num_vertices // n_shards
    identity = jnp.float32(sr.identity)
    limit = num_vertices + 1
    unreached = jnp.int32(num_vertices + 1)

    def local_vertex_ids():
        return (jnp.arange(v_local, dtype=jnp.int32)
                + jax.lax.axis_index(ax) * v_local)

    def seg_min_q(data, dst_local):
        return jax.vmap(
            lambda c: jax.ops.segment_min(
                c, dst_local, v_local, indices_are_sorted=False
            )
        )(data)

    def fixpoint_body(values_l, src, dst_local, weight, active):
        # values_l (Q, v_local); one all-gather per superstep, Q-wide.
        # Per-lane convergence accounting rides the SAME collective: the
        # scalar convergence psum becomes one (Q,) psum of per-lane change
        # flags (still exactly one all-reduce in the lowered HLO), and each
        # lane records its freeze step — defined exactly as the vmapped
        # single-host ledger does: the count of supersteps up to AND
        # including the lane's own confirming (no-change) pass, so a lane
        # last changing at superstep m reports m+1 and an instantly-
        # converged lane reports 1.  Counts are therefore comparable across
        # the single-host and sharded deployments.
        q = values_l.shape[0]

        def relax(vals_l):
            vals_full = jax.lax.all_gather(vals_l, ax, axis=1, tiled=True)
            cand = sr.extend(vals_full[:, src], weight[None, :])  # (Q, E)
            cand = jnp.where(active[None, :], cand, identity)
            upd = jax.vmap(
                lambda c: sr.segment_reduce(
                    c, dst_local, v_local, indices_are_sorted=False
                )
            )(cand)
            return sr.improve(vals_l, upd)

        def cond(state):
            _, changed, it, _ = state
            return changed & (it < limit)

        def body(state):
            vals, _, it, lane_it = state
            new = relax(vals)
            lane_changed = jax.lax.psum(
                jnp.any(new != vals, axis=1).astype(jnp.int32), ax
            ) > 0  # (Q,) — the one all-reduce, now a vector
            lane_it = jnp.where(lane_changed, it + 2, lane_it)
            return new, jnp.any(lane_changed), it + 1, lane_it

        vals, _, iters, lane_iters = jax.lax.while_loop(
            cond, body,
            (values_l, jnp.bool_(True), jnp.int32(0),
             jnp.ones(q, jnp.int32)),
        )
        return vals, iters, lane_iters

    def parents_body(values_l, src, dst_local, weight, active, sources):
        # per-lane BFS levels over each lane's achieving subgraph
        vals_full = jax.lax.all_gather(values_l, ax, axis=1, tiled=True)
        cand = sr.extend(vals_full[:, src], weight[None, :])
        achieving = (active[None, :] & (cand == values_l[:, dst_local])
                     & (values_l[:, dst_local] != identity))
        local_ids = local_vertex_ids()
        is_source = local_ids[None, :] == sources[:, None]
        level0 = jnp.where(is_source, 0, unreached).astype(jnp.int32)

        def cond(state):
            return state[1]

        def body(state):
            level, _ = state
            lvl_full = jax.lax.all_gather(level, ax, axis=1, tiled=True)
            cand_lvl = jnp.where(
                achieving & (lvl_full[:, src] < unreached),
                lvl_full[:, src] + 1, unreached,
            )
            upd = seg_min_q(cand_lvl, dst_local)
            new = jnp.minimum(level, upd)
            changed = jax.lax.psum(
                jnp.any(new != level).astype(jnp.int32), ax
            ) > 0
            return new, changed

        level, _ = jax.lax.while_loop(cond, body, (level0, jnp.bool_(True)))
        lvl_full = jax.lax.all_gather(level, ax, axis=1, tiled=True)
        on_forest = achieving & (lvl_full[:, src] + 1 == level[:, dst_local])
        eid = jnp.where(
            on_forest, jnp.arange(e_cap, dtype=jnp.int32)[None, :], e_cap
        )
        parent = seg_min_q(eid, dst_local)
        parent = jnp.where(parent >= e_cap, -1, parent)
        fragile = (values_l != identity) & (level == unreached)
        parent = jnp.where(fragile, jnp.int32(PARENT_FRAGILE), parent)
        return jnp.where(is_source, -1, parent)

    def invalidate_body(values_l, parent_l, deleted, src, sources):
        # deleted is shared across lanes (slide transitions are structural);
        # parents are per-lane, so the invalid frontier is too
        has_parent = parent_l >= 0
        pidx = jnp.maximum(parent_l, 0)  # (Q, v_local) shard-local edge ids
        invalid0 = (has_parent & deleted[pidx]) | (parent_l == PARENT_FRAGILE)
        parent_src = src[pidx]  # (Q, v_local) global vertex ids

        def cond(state):
            return state[1]

        def body(state):
            invalid, _ = state
            inv_full = jax.lax.all_gather(invalid, ax, axis=1, tiled=True)
            nxt = invalid | (
                has_parent & jnp.take_along_axis(inv_full, parent_src, axis=1)
            )
            changed = jax.lax.psum(
                jnp.any(nxt != invalid).astype(jnp.int32), ax
            ) > 0
            return nxt, changed

        invalid, _ = jax.lax.while_loop(
            cond, body, (invalid0, jnp.bool_(True))
        )
        new_values = jnp.where(invalid, identity, values_l)
        new_values = jnp.where(
            local_vertex_ids()[None, :] == sources[:, None],
            jnp.float32(sr.source), new_values,
        )
        return new_values, invalid

    vq = P(None, ax)  # (Q, V) state split on the vertex axis
    e = P(ax)  # flat per-shard stacks
    r = P()  # replicated: (Q,) sources
    fixpoint = jax.jit(shard_map(
        fixpoint_body, mesh=mesh,
        in_specs=(vq, e, e, e, e), out_specs=(vq, r, r), check_rep=False,
    ))
    parents = jax.jit(shard_map(
        parents_body, mesh=mesh,
        in_specs=(vq, e, e, e, e, r), out_specs=vq, check_rep=False,
    ))
    invalidate = jax.jit(shard_map(
        invalidate_body, mesh=mesh,
        in_specs=(vq, vq, e, e, r), out_specs=(vq, vq), check_rep=False,
    ))
    return {"fixpoint": fixpoint, "parents": parents, "invalidate": invalidate}


@functools.lru_cache(maxsize=None)
def _ell_kernels(mesh: Mesh, sr: Semiring, state_len: int, model_axis: str,
                 interpret: bool):
    """Per-shard Pallas vrelax fixpoint under shard_map (the SPMD ELL path).

    Each shard holds its OWN row-split ELL packing — rows split within the
    shard's dst range, local-dst row→vertex ids, global-src *positions* on
    the slot plane (:class:`_ShardedEllCache`) — so the Pallas kernel's
    gather/relax/reduce runs on shard-local tiles instead of the old
    replicated stacked-universe launch, and per-slide kernel work scales
    with the mesh.  The collective schedule is IDENTICAL to the flat
    :func:`_kernels` fixpoint: per superstep exactly one all-gather of the
    per-vertex state (the source-value gather feeding ``vals_full[src]``)
    plus the convergence psum — pinned against the lowered HLO by
    ``tests/_stream_shard_checks.py::check_collectives``.

    ``fixpoint`` relaxes scalar ``(state_len,)`` state; ``fixpoint_q`` the
    serving Q-fold — ``(Q, state_len)`` state split on the VERTEX axis with
    Q folded into the kernel's snapshot axis (presence words pre-tiled by
    :func:`repro.kernels.vrelax.ops.tile_presence_words`), one collective
    per superstep regardless of Q, plus per-lane freeze-step accounting on
    the same (Q,) psum.  Bit-for-bit: min/max slot reductions are exact for
    f32, so row splitting and shard placement never change a float.
    """
    from jax.experimental.shard_map import shard_map

    from repro.kernels.vrelax.kernel import S_BLOCK, vrelax_partial_pallas
    from repro.utils.padding import round_up

    ax = model_axis
    n_shards = int(mesh.shape[ax])
    if state_len % n_shards:
        raise ValueError(
            f"state_len {state_len} must be divisible by the "
            f"{n_shards} mesh shards"
        )
    v_cap = state_len // n_shards
    limit = state_len + 1

    def seg(partial, row2v):
        # combine split rows → shard-local vertices (tiny XLA segment reduce)
        return sr.segment_reduce(
            partial, row2v, v_cap, indices_are_sorted=True
        )

    def fixpoint_body(values_l, src_pos, weight, words, row2v):
        # values_l (v_cap,); src_pos/weight (R, D); words (R, D, W); the
        # pallas launch computes all S_BLOCK sublanes but only bit 0 is set
        # in the words, so rows 1.. reduce to identity and are dropped.
        def relax(vals_l):
            vals_full = jax.lax.all_gather(vals_l, ax, axis=0, tiled=True)
            g = vals_full[src_pos][None]  # (1, R, D) — source-value gather
            g = jnp.pad(g, ((0, S_BLOCK - 1), (0, 0), (0, 0)))
            partial = vrelax_partial_pallas(
                g, weight, words, semiring=sr.name, interpret=interpret
            )  # (S_BLOCK, R)
            return sr.improve(vals_l, seg(partial[0], row2v))

        def cond(state):
            _, changed, it = state
            return changed & (it < limit)

        def body(state):
            vals, _, it = state
            new = relax(vals)
            changed = jax.lax.psum(
                jnp.any(new != vals).astype(jnp.int32), ax
            ) > 0
            return new, changed, it + 1

        vals, _, iters = jax.lax.while_loop(
            cond, body, (values_l, jnp.bool_(True), jnp.int32(0))
        )
        return vals, iters

    def fixpoint_q_body(values_l, src_pos, weight, words, row2v):
        # values_l (Q, v_cap); Q folded into the kernel snapshot axis (words
        # carry bit q for lane q — tile_presence_words), padded to S_BLOCK.
        q = values_l.shape[0]
        s_pad = round_up(q, S_BLOCK)

        def relax(vals_l):
            vals_full = jax.lax.all_gather(vals_l, ax, axis=1, tiled=True)
            g = vals_full[:, src_pos]  # (Q, R, D) — ONE gather, Q rows tall
            g = jnp.pad(g, ((0, s_pad - q), (0, 0), (0, 0)))
            partial = vrelax_partial_pallas(
                g, weight, words, semiring=sr.name, interpret=interpret
            )  # (s_pad, R)
            upd = jax.vmap(lambda p: seg(p, row2v))(partial[:q])
            return sr.improve(vals_l, upd)

        def cond(state):
            _, changed, it, _ = state
            return changed & (it < limit)

        def body(state):
            vals, _, it, lane_it = state
            new = relax(vals)
            lane_changed = jax.lax.psum(
                jnp.any(new != vals, axis=1).astype(jnp.int32), ax
            ) > 0  # (Q,) — still the one all-reduce
            # freeze step incl. the lane's confirming pass (see _kernels_q)
            lane_it = jnp.where(lane_changed, it + 2, lane_it)
            return new, jnp.any(lane_changed), it + 1, lane_it

        vals, _, iters, lane_iters = jax.lax.while_loop(
            cond, body,
            (values_l, jnp.bool_(True), jnp.int32(0),
             jnp.ones(q, jnp.int32)),
        )
        return vals, iters, lane_iters

    e = P(ax)  # per-shard ELL planes stacked on the leading row axis
    r = P()
    v = P(ax)
    vq = P(None, ax)
    fixpoint = jax.jit(shard_map(
        fixpoint_body, mesh=mesh,
        in_specs=(v, e, e, e, e), out_specs=(v, r), check_rep=False,
    ))
    fixpoint_q = jax.jit(shard_map(
        fixpoint_q_body, mesh=mesh,
        in_specs=(vq, e, e, e, e), out_specs=(vq, r, r), check_rep=False,
    ))
    return {"fixpoint": fixpoint, "fixpoint_q": fixpoint_q}


class ShardedStreamingBounds:
    """Sharded drop-in for :class:`~repro.core.bounds.StreamingBounds`.

    Same maintenance algebra — monotone re-relax where G∩/G∪ grew,
    witness-parent trims where they shrank, safe-weight worsening treated as
    deletion and improvement as re-relax — but every pass runs shard-locally
    under ``shard_map`` with one per-superstep all-gather of the per-vertex
    state.  ``apply_slide`` consumes a
    :class:`~repro.graph.shardlog.ShardSlideDiff` (per-shard ids) and
    per-shard mask lists; ``val_cap``/``val_cup`` remain global ``(V,)``
    arrays (device-sharded by vertex range), bit-for-bit equal to the
    single-host maintenance.  Safe weights are the per-shard views'
    window-local extrema (exact, narrowing when a widening snapshot
    retires).

    ``source`` may be a sequence of Q vertices (batched mode, mirroring
    :class:`~repro.core.bounds.StreamingBounds`): state becomes ``(Q, V)``
    split on the VERTEX axis and every pass is one Q-batched ``shard_map``
    launch (:func:`_kernels_q`) with still exactly one all-gather per
    superstep.

    Internally every per-vertex array lives in the log's assignment
    **position space** (:class:`~repro.graph.shardlog.ShardAssignment`:
    vertex ``v`` at ``owner·v_cap + local``, padding positions idle at the
    semiring identity) so rebalanced-range and hash-of-dst shard
    assignments run the same kernels; for the default range mode the map is
    the identity.  ``uvv``/``result`` translate back to global vertex order
    at the API boundary (:meth:`to_global`).
    """

    def __init__(self, view: ShardedWindowView, sr: Semiring, source,
                 mesh: Optional[Mesh] = None, *, model_axis: str = MODEL_AXIS):
        self.view = view
        self.sr = sr
        self.assign = view.log.assignment
        self.mesh = mesh if mesh is not None else host_mesh(
            view.log.n_shards, model_axis
        )
        if int(self.mesh.shape[model_axis]) != view.log.n_shards:
            raise ValueError(
                f"mesh axis {model_axis!r} has "
                f"{int(self.mesh.shape[model_axis])} devices but the log has "
                f"{view.log.n_shards} shards"
            )
        self.model_axis = model_axis
        pos = self.assign.positions
        if np.ndim(source) == 0:
            self.sources = None  # scalar mode: (state_len,) position space
            self.source = jnp.int32(int(pos[int(source)]))
        else:
            srcs = [int(s) for s in np.asarray(source).ravel()]
            if not srcs:
                raise ValueError("ShardedStreamingBounds needs ≥1 source")
            self.sources = [int(pos[s]) for s in srcs]  # positions
            self.source = jnp.asarray(self.sources, jnp.int32)
        self.supersteps = 0
        self.launches = 0  # shard_map kernel launches (bench accounting)
        self.trims = 0      # invalidation launches (same ledger as the
        self.rerelaxes = 0  # single-host StreamingBounds — obs/stability)
        self.lane_supersteps = (
            None if self.sources is None
            else np.zeros(len(self.sources), np.int64)
        )
        self._dev_key = None
        self._dev: dict = {}
        self._full_init()

    @property
    def batched(self) -> bool:
        return self.sources is not None

    def to_global(self, vals) -> np.ndarray:
        """Gather position-space per-vertex state back to global ids."""
        return np.asarray(vals)[..., self.assign.positions]

    def to_global_lazy(self, vals) -> jax.Array:
        """:meth:`to_global` as a device-side gather — no host fetch.

        The pipelined serving path keeps eval results on device until a
        consumer reads them; the position→global permutation runs as a tiny
        jnp gather so dispatch stays asynchronous.
        """
        if getattr(self, "_pos_dev", None) is None:
            self._pos_dev = jnp.asarray(self.assign.positions)
        return vals[..., self._pos_dev]

    # -- device-side stacked arrays -------------------------------------------
    def _kernels(self):
        if self.batched:
            return _kernels_q(
                self.mesh, self.sr, self.view.log.state_len,
                self.view.log.capacity, self.model_axis, len(self.sources),
            )
        return _kernels(self.mesh, self.sr, self.view.log.state_len,
                        self.view.log.capacity, self.model_axis)

    def _fixpoint(self, k, values, dev, w, active, tally: bool = True,
                  fetch: bool = True):
        """One fixpoint launch → ``(vals, steps)``.

        ``tally`` folds the batched kernel's per-lane freeze steps into
        :attr:`lane_supersteps` (maintenance passes only — snapshot
        evaluations pass ``tally=False`` so the per-lane ledger means the
        same thing as the single-host vmapped one).  ``fetch=False`` leaves
        the step count on device (pipelined eval: no host sync).
        """
        self.launches += 1
        if self.batched:
            vals, it, lane_it = k["fixpoint"](
                values, dev["src"], dev["dst_local"], w, active
            )
            if tally:
                self._tally(np.asarray(lane_it))
        else:
            vals, it = k["fixpoint"](
                values, dev["src"], dev["dst_local"], w, active
            )
        return vals, int(it) if fetch else it

    def _device(self) -> dict:
        """Stacked edge arrays + safe weights, re-uploaded only when stale.

        Weights are the per-shard views' window-local extrema, keyed on the
        view's ``weight_epoch`` on top of the log's structural state.
        """
        log = self.view.log
        arrs = log.stacked_arrays()
        key = (log.state_key(), arrs["e_cap"], self.view.weight_epoch)
        if self._dev_key != key:
            sr = self.sr
            wmin, wmax = self.view.stacked_weight_extrema()
            self._dev = {
                # gather side: source POSITIONS into the assignment layout
                "src": jnp.asarray(arrs["src_pos"]),
                "dst_local": jnp.asarray(arrs["dst_local"]),
                "w_cap": jnp.asarray(sr.intersection_weight(wmin, wmax)),
                "w_cup": jnp.asarray(sr.union_weight(wmin, wmax)),
            }
            self._dev_key = key
        return self._dev

    def _stack(self, per_shard_masks) -> jax.Array:
        return jnp.asarray(self.view.log.stack_masks(per_shard_masks))

    # -- full solve (cold start) ----------------------------------------------
    def _full_init(self):
        sr, n = self.sr, self.view.log.state_len
        dev, k = self._device(), self._kernels()
        inter = self._stack(self.view.intersection_masks())
        union = self._stack(self.view.union_masks())
        if getattr(self, "_warm_vals", None) is not None:
            # checkpoint restore (see from_state): the saved arrays ARE the
            # fixpoints of this window — monotone fixpoints are unique — so
            # only the parent forests (trim metadata) are recomputed in the
            # replayed edge-id space; no solve runs
            self.val_cap, self.val_cup = self._warm_vals
            self._warm_vals = None
            self.parent_cap = k["parents"](
                self.val_cap, dev["src"], dev["dst_local"], dev["w_cap"],
                inter, self.source,
            )
            self.parent_cup = k["parents"](
                self.val_cup, dev["src"], dev["dst_local"], dev["w_cup"],
                union, self.source,
            )
            self.launches += 2
            return
        if self.batched:
            boot = np.full((len(self.sources), n), sr.identity, np.float32)
            boot[np.arange(len(self.sources)), self.sources] = np.float32(
                sr.source
            )
        else:
            boot = np.full(n, sr.identity, np.float32)
            boot[int(self.source)] = np.float32(sr.source)
        self.val_cap, it_cap = self._fixpoint(
            k, jnp.asarray(boot), dev, dev["w_cap"], inter
        )
        self.val_cup, it_cup = self._fixpoint(
            k, self.val_cap, dev, dev["w_cup"], union
        )
        self.parent_cap = k["parents"](
            self.val_cap, dev["src"], dev["dst_local"], dev["w_cap"], inter,
            self.source,
        )
        self.parent_cup = k["parents"](
            self.val_cup, dev["src"], dev["dst_local"], dev["w_cup"], union,
            self.source,
        )
        self.launches += 2
        self.supersteps += it_cap + it_cup

    # batched-mode lane membership + tallies: the state layout (sources/
    # source + val/parent/lane arrays + supersteps) deliberately matches
    # StreamingBounds, so the bookkeeping is shared rather than re-encoded
    from_state = classmethod(StreamingBounds.from_state.__func__)

    def reshard(self, view: ShardedWindowView, plan, *,
                mesh: Optional[Mesh] = None) -> "ShardedStreamingBounds":
        """Migrated copy of this maintainer on ``view``'s new layout.

        ``plan`` is the :func:`~repro.graph.shardlog.migration_plan` from
        this maintainer's (pre-migration) assignment to the one ``view``
        now carries.  The warm ``val_cap``/``val_cup`` fixpoints are
        permuted through global vertex space onto the new position layout
        and re-injected via :meth:`from_state` — monotone fixpoints are
        unique, so **zero solves** run; only the parent forests (trim
        metadata) are recomputed on the new layout (2 launches).  Counters
        carry over so the obs ledger spans the migration.
        """
        old = self.assign
        inv = np.full(old.state_len, -1, np.int64)
        inv[old.positions] = np.arange(old.num_vertices)
        if self.batched:
            src = [int(inv[p]) for p in self.sources]
        else:
            src = int(inv[int(self.source)])
        ident = np.float32(self.sr.identity)
        new = type(self).from_state(
            view, self.sr, src,
            plan.permute(np.asarray(self.val_cap), ident),
            plan.permute(np.asarray(self.val_cup), ident),
            supersteps=self.supersteps,
            lane_supersteps=self.lane_supersteps,
            mesh=mesh if mesh is not None else self.mesh,
            model_axis=self.model_axis,
        )
        new.launches += self.launches
        new.trims = self.trims
        new.rerelaxes = self.rerelaxes
        return new

    append_lane = StreamingBounds.append_lane
    drop_lane = StreamingBounds.drop_lane
    set_lane = StreamingBounds.set_lane
    pad_lanes = StreamingBounds.pad_lanes
    drop_lane_padded = StreamingBounds.drop_lane_padded
    _permute_lanes = StreamingBounds._permute_lanes
    _tally = StreamingBounds._tally

    # -- one slide ------------------------------------------------------------
    def apply_slide(self, diff, inter_masks=None, union_masks=None) -> int:
        """Fold one :class:`ShardSlideDiff` in; returns supersteps spent.

        Masks default to the view's current per-shard masks (correct only
        for the latest slide); multi-slide catch-up passes each intermediate
        window's masks from :meth:`ShardedWindowView.rolling_masks`, exactly
        as on the single-host path.
        """
        sr = self.sr
        log = self.view.log
        if inter_masks is None:
            inter_masks = self.view.intersection_masks()
        if union_masks is None:
            union_masks = self.view.union_masks()
        dev, k = self._device(), self._kernels()
        per = diff.shards
        steps = 0

        # window-extrema transitions: a WORSE safe weight behaves like a
        # deletion of the old-weight edge, a BETTER one is a plain monotone
        # re-relax (per-shard, via the SlideDiff single-source-of-truth
        # mapping — same moves as the single-host StreamingBounds)
        cap_trans = [d.cap_weight_transitions(sr.minimize) for d in per]
        cup_trans = [d.cup_weight_transitions(sr.minimize) for d in per]
        cap_weight_worse = [t[0] for t in cap_trans]
        cap_weight_better = [t[1] for t in cap_trans]
        cup_weight_worse = [t[0] for t in cup_trans]
        cup_weight_better = [t[1] for t in cup_trans]

        cap_drop_ids = [
            np.concatenate([d.inter_lost, w]) for d, w in zip(per, cap_weight_worse)
        ]
        n_cap_drop = sum(len(a) for a in cap_drop_ids)
        cap_changed = bool(
            n_cap_drop
            or any(len(d.inter_gained) for d in per)
            or any(len(a) for a in cap_weight_better)
        )
        if cap_changed:
            inter = self._stack(inter_masks)
            if n_cap_drop:
                dropped = jnp.asarray(log.stack_ids(cap_drop_ids))
                self.val_cap, _ = k["invalidate"](
                    self.val_cap, self.parent_cap, dropped, dev["src"],
                    self.source,
                )
                self.launches += 1
                self.trims += 1
            self.val_cap, it = self._fixpoint(
                k, self.val_cap, dev, dev["w_cap"], inter
            )
            self.parent_cap = k["parents"](
                self.val_cap, dev["src"], dev["dst_local"], dev["w_cap"],
                inter, self.source,
            )
            self.launches += 1
            self.rerelaxes += 1
            steps += it

        cup_drop_ids = [
            np.concatenate([d.union_lost, w]) for d, w in zip(per, cup_weight_worse)
        ]
        n_cup_drop = sum(len(a) for a in cup_drop_ids)
        cup_changed = bool(
            n_cup_drop
            or any(len(d.union_gained) for d in per)
            or any(len(a) for a in cup_weight_better)
        )
        if cup_changed:
            union = self._stack(union_masks)
            if n_cup_drop:
                dropped = jnp.asarray(log.stack_ids(cup_drop_ids))
                self.val_cup, _ = k["invalidate"](
                    self.val_cup, self.parent_cup, dropped, dev["src"],
                    self.source,
                )
                self.launches += 1
                self.trims += 1
            self.val_cup, it = self._fixpoint(
                k, self.val_cup, dev, dev["w_cup"], union
            )
            self.parent_cup = k["parents"](
                self.val_cup, dev["src"], dev["dst_local"], dev["w_cup"],
                union, self.source,
            )
            self.launches += 1
            self.rerelaxes += 1
            steps += it

        self.supersteps += steps
        return steps

    # -- results (global vertex order at the API boundary) --------------------
    @property
    def uvv(self) -> np.ndarray:
        # host-side on purpose: every consumer (QRS keep rule, stats) reads
        # it as numpy right away, so re-uploading the gathered array would
        # just add two device round trips per advance
        return self.to_global(detect_uvv(self.val_cap, self.val_cup))

    @property
    def result(self) -> BoundsResult:
        val_cap = jnp.asarray(self.to_global(self.val_cap))
        val_cup = jnp.asarray(self.to_global(self.val_cup))
        if self.sr.minimize:
            lower, upper = val_cup, val_cap
        else:
            lower, upper = val_cap, val_cup
        return BoundsResult(
            val_cap=val_cap, val_cup=val_cup,
            lower=lower, upper=upper, uvv=detect_uvv(val_cap, val_cup),
            iters_cap=jnp.int32(self.supersteps), iters_cup=jnp.int32(0),
        )


class ShardedQRSMask:
    """Per-shard Algorithm-1 keep masks (the sharded stand-in for
    :class:`~repro.core.qrs.PatchableQRS`).

    The keep rule — *in G∪ and sink not UVV* — is evaluated per shard over
    the shard's own edges (``uvv[dst]`` reads only shard-owned destinations),
    and per-snapshot evaluation relaxes the full shard-local edge stack under
    ``keep ∧ present`` masks instead of compacting slots: masked-out edges
    contribute ``identity``, so the relaxed edge *set* — and therefore every
    float — matches the single-host compacted QRS exactly, while keeping the
    stacked shapes slide-stable (no per-slide recompaction, no cross-shard
    traffic).
    """

    def __init__(self, view: ShardedWindowView, uvv, sr: Semiring):
        self.view = view
        self.sr = sr
        # (Q, V) masks fold to the shared keep rule (see PatchableQRS)
        self.uvv = PatchableQRS._fold(uvv).copy()
        self._keep = self._compute_keep(view.union_masks(), self.uvv)

    def _compute_keep(self, union_masks, uvv) -> list[np.ndarray]:
        keeps = []
        for s, sh in enumerate(self.view.log.shards):
            keep = np.asarray(union_masks[s]).copy()
            n = sh.num_edges
            if n:
                keep[:n] &= ~uvv[sh.dst[:n]]
            keeps.append(keep)
        return keeps

    @property
    def num_edges(self) -> int:
        return int(sum(k.sum() for k in self._keep))

    def apply_slide(self, diff, uvv_new, union_mask=None) -> dict:
        """Recompute per-shard keep masks for one slide; returns patch stats."""
        uvv_new = PatchableQRS._fold(uvv_new)
        unions = (union_mask if union_mask is not None
                  else self.view.union_masks())
        new_keep = self._compute_keep(unions, uvv_new)
        entered = left = 0
        for old, new in zip(self._keep, new_keep):
            m = min(len(old), len(new))  # capacity may have grown mid-queue
            entered += int((new[:m] & ~old[:m]).sum()) + int(new[m:].sum())
            left += int((old[:m] & ~new[:m]).sum())
        self._keep = new_keep
        self.uvv = uvv_new.copy()
        return {
            "qrs_edges": self.num_edges,
            "qrs_entered": int(entered),
            "qrs_left": int(left),
            "qrs_touched": int(entered + left),
        }

    def refresh(self, uvv_new) -> dict:
        """Re-evaluate the keep masks for a new UVV mask (same window).

        The masks are recomputed in full on every slide anyway, so a query-
        set change (serving batch gained/lost a lane) is just another
        recompute against the view's current union masks.
        """
        uvv_new = PatchableQRS._fold(uvv_new)
        self._keep = self._compute_keep(self.view.union_masks(), uvv_new)
        self.uvv = uvv_new.copy()
        return {
            "qrs_edges": self.num_edges,
            "qrs_entered": 0,
            "qrs_left": 0,
            "qrs_touched": 0,
        }

    def snapshot_masks(self, t: int) -> list[np.ndarray]:
        """Per-shard ``keep ∧ present-in-snapshot-t`` evaluation masks."""
        out = []
        for keep, v in zip(self._keep, self.view.views):
            present = v.snapshot_mask(t)
            out.append(pad_to(keep, len(present), False) & present)
        return out


class _ShardedEllCache:
    """Per-shard row-split ELL packings at a uniform sticky row capacity.

    The pre-SPMD path packed the *stacked union* of all shard universes into
    one host-side ELL and launched the Pallas kernel fully replicated —
    every device did all-shards work every superstep, throwing away the
    paper's small-subgraph scaling at the kernel layer.  This cache keeps
    one :class:`~repro.graph.ell.StableEllPacker` PER SHARD over the shard's
    own slot plane: rows split within the shard's dst range (``row2vertex``
    in shard-local ids ``[0, v_cap)``), source *positions* on the slot plane
    (the gather side spans shards), invalid slots masked by all-zero
    presence words exactly like the single-host packer.  All shards pack at
    one uniform amortized-doubling row capacity so the stacked
    ``(n_shards · R, D)`` planes split cleanly under ``shard_map`` and the
    kernel compiles once per capacity class.  Re-packed only when
    ``(state_key, weight_epoch)`` moves.

    Presence words live in a persistent device-resident plane
    (:class:`~repro.kernels.vrelax.ops.EllPresenceCache`): each
    :meth:`presence` call scatters only the slots whose ``keep ∧ present``
    mask flipped since the previous call — O(touched) per slide instead of
    the O(capacity) rebuild + re-upload — and the plane is invalidated
    whenever :meth:`pack` re-packs (the slot→row positions moved).  Setting
    the class attribute ``incremental = False`` restores the legacy
    rebuild-every-slide path (the latency bench's synchronous baseline).
    """

    incremental = True  # False: legacy O(cap) presence rebuild per call

    def __init__(self, view: ShardedWindowView, sr: Semiring):
        from repro.graph.ell import StableEllPacker

        self.view = view
        self.sr = sr
        self._packers = [
            StableEllPacker(view.log.assignment.v_cap)
            for _ in range(view.log.n_shards)
        ]
        self._row_cap = 0  # uniform sticky per-shard row capacity
        self._packs: Optional[list] = None  # host EllPacks (edge_id scatter)
        self._dev: dict = {}
        self._key = None
        self._eid_flat: Optional[np.ndarray] = None  # stacked global edge ids
        self._presence: dict = {}  # num_queries → EllPresenceCache

    def pack(self):
        """→ ``(per-shard host EllPacks, stacked device planes)``."""
        log = self.view.log
        key = (log.state_key(), self.view.weight_epoch)
        if self._key != key:
            arrs = log.stacked_arrays()
            cap, n = arrs["e_cap"], log.n_shards
            wmin, wmax = self.view.stacked_weight_extrema()
            w = np.asarray(self.sr.intersection_weight(wmin, wmax))
            srcp = arrs["src_pos"].reshape(n, cap)
            dstl = arrs["dst_local"].reshape(n, cap)
            w = w.reshape(n, cap)
            # uniform row capacity: every packer sees the NEEDIEST shard's
            # natural row count as its floor, so the packers' own amortized-
            # doubling growth runs in lockstep (identical inputs + identical
            # history ⇒ identical sticky capacities, guarded by the assert)
            need = max(
                p._natural_rows(dstl[s]) for s, p in enumerate(self._packers)
            )
            packs = [
                p.pack(srcp[s], dstl[s], w[s], min_rows=need)
                for s, p in enumerate(self._packers)
            ]
            assert len({p.num_rows for p in packs}) == 1, \
                "per-shard ELL packs disagree on row capacity"
            self._row_cap = packs[0].num_rows
            self._packs = packs
            self._dev = {
                "src": jnp.concatenate([p.src for p in packs]),
                "weight": jnp.concatenate([p.weight for p in packs]),
                "row2vertex": jnp.concatenate([p.row2vertex for p in packs]),
            }
            # slot ids offset into the flat (n_shards · cap) mask space, so
            # one stacked inverse map serves the incremental presence plane
            eids = []
            for s, p in enumerate(packs):
                e = np.asarray(p.edge_id, np.int64)
                eids.append(np.where(e >= 0, e + s * cap, -1))
            self._eid_flat = np.concatenate(eids, axis=0)
            self._key = key
        return self._packs, self._dev

    def presence(self, masks, num_queries: Optional[int] = None) -> jax.Array:
        """Scatter per-shard ``keep ∧ present`` masks into stacked ELL words.

        With ``num_queries`` the words are pre-tiled for the Q-folded kernel
        snapshot axis (bit ``q`` set for lane ``q`` wherever bit 0 was).
        Incremental: only slots whose mask bit flipped since the previous
        call are scattered into the persistent device plane (see the class
        docstring for the invalidation rule).
        """
        from repro.kernels.vrelax.ops import EllPresenceCache

        cap = self.view.log.capacity
        self.pack()
        flat = np.concatenate(
            [pad_to(np.asarray(m), cap, False) for m in masks]
        )
        cache = self._presence.get(num_queries)
        if cache is None:
            cache = self._presence[num_queries] = EllPresenceCache()
        cache.incremental = self.incremental
        return cache.update(
            self._key, flat, self._eid_flat, num_queries=num_queries
        )

    def presence_stats(self) -> dict:
        """Aggregate incremental-presence counters across Q-fold planes."""
        return {
            "rebuilds": sum(c.rebuilds for c in self._presence.values()),
            "touched": [t for c in self._presence.values() for t in c.touched],
        }


class _ShardedEllMixin:
    """Shared per-shard ``cqrs_ell`` machinery for the sharded query classes."""

    def _ell(self) -> _ShardedEllCache:
        if getattr(self, "_ell_cache", None) is None:
            self._ell_cache = self._make_ell_cache()
        return self._ell_cache

    def _make_ell_cache(self, row_cap: int = 0) -> _ShardedEllCache:
        """Fresh per-shard ELL cache, optionally re-seeded at a sticky row
        capacity (checkpoint restore re-enters the saved compile class
        instead of re-walking the amortized-doubling ladder)."""
        cache = _ShardedEllCache(self.view, self.semiring)
        if row_cap:
            cache._row_cap = int(row_cap)
            for p in cache._packers:
                p.num_rows = int(row_cap)
        return cache

    def _ell_kernels(self):
        from repro.kernels.common import default_interpret

        return _ell_kernels(
            self.mesh, self.semiring, self.view.log.state_len,
            self.model_axis, default_interpret(),
        )

    def _reset_eval_caches(self) -> None:
        """Rollback hook: rebuild the per-shard ELL cache at its sticky row
        class (the exact move :meth:`reshard` performs on every migration,
        proven bit-for-bit), on top of the base presence-plane reset."""
        super()._reset_eval_caches()
        if getattr(self, "_ell_cache", None) is not None:
            self._ell_cache = self._make_ell_cache(
                row_cap=self._ell_cache._row_cap
            )

    # -- live migration (layout epochs) ---------------------------------------
    def reshard(self, assignment=None, *, degree_hist=None,
                mesh: Optional[Mesh] = None) -> dict:
        """Migrate this query to a new shard layout mid-stream — no restart.

        Re-routes the host log onto ``assignment`` (default: a degree-
        balanced :meth:`~repro.graph.shardlog.ShardAssignment.rebalance` of
        the live universe), permutes the warm ``val_cap``/``val_cup``
        fixpoints through global vertex space onto the new position layout
        (zero solves — see :meth:`ShardedStreamingBounds.reshard`), rebuilds
        the QRS keep masks and the per-shard ELL packers *at their saved
        sticky capacity classes* on the new layout, and re-derives the mesh
        when ``n_shards`` changed.  Subsequent slides are bit-for-bit equal
        to a never-resharded run.

        Requires a caught-up query (``advance()`` to the log tip first);
        sibling queries sharing the view each call this once — the first
        call migrates the log, the rest only migrate their own warm state.

        Returns a migration report: new ``epoch``/``n_shards``, positions
        and bytes moved, wall seconds, post-migration occupancy spread.
        """
        import time

        from repro.graph.shardlog import migration_plan
        from repro.obs.metrics import get_registry

        view = self.view
        log = view.log
        if view.stop != log.num_snapshots or self._diff_pos != view.history_end:
            raise RuntimeError(
                "reshard() needs a caught-up query: advance() to the log "
                "tip before migrating"
            )
        t0 = time.perf_counter()
        with span("reshard"):
            bounds = self._bounds
            old = bounds.assign
            cap_pos = np.asarray(bounds.val_cap)
            cup_pos = np.asarray(bounds.val_cup)
            installed = view.reshard(assignment, degree_hist=degree_hist)
            plan = migration_plan(old, installed)
            if mesh is not None:
                self.mesh = mesh
            elif installed.n_shards != old.n_shards:
                self.mesh = host_mesh(installed.n_shards, self.model_axis)
            self._bounds = bounds.reshard(view, plan, mesh=self.mesh)
            self._qrs = self._make_qrs()
            if self._ell_cache is not None:
                # fresh packers on the new layout, re-seeded at the sticky
                # row class so the kernel compile cache stays warm; the
                # rebuilt pack key (assignment epoch ∈ state_key) is what
                # invalidates the persistent presence planes
                self._ell_cache = self._make_ell_cache(
                    row_cap=self._ell_cache._row_cap
                )
            self._diff_pos = view.history_end
        seconds = time.perf_counter() - t0
        moved_bytes = plan.bytes_moved(cap_pos, cup_pos)
        reg = get_registry()
        reg.counter(
            "reshard_total", "completed live shard-layout migrations"
        ).inc()
        reg.counter(
            "reshard_bytes_moved_total", "warm-state bytes relocated"
        ).inc(moved_bytes)
        reg.histogram(
            "reshard_seconds", "live migration wall time"
        ).observe(seconds)
        return {
            "epoch": installed.epoch,
            "n_shards": installed.n_shards,
            "moved_positions": plan.moved,
            "bytes_moved": moved_bytes,
            "seconds": seconds,
            "occupancy_spread": log.occupancy_spread(),
        }


class ShardedStreamingQuery(_ShardedEllMixin, StreamingQuery):
    """:class:`~repro.core.api.StreamingQuery` over a dst-range-sharded log.

    Constructed automatically when ``StreamingQuery(...)`` receives a
    :class:`~repro.graph.shardlog.ShardedSnapshotLog` or
    :class:`~repro.graph.shardlog.ShardedWindowView`; the ``advance()``
    control flow (multi-slide catch-up, weight-dirty row rebuilds, history
    pruning) is inherited unchanged — only the bounds maintenance, the QRS
    keep rule, and the per-snapshot evaluation are swapped for their
    shard_map counterparts.  Results are bit-for-bit identical to the
    single-host query on the same stream.

    ``mesh`` defaults to a 1-D host mesh over ``n_shards`` local devices
    (:func:`host_mesh`).  ``method="cqrs"`` evaluates the appended snapshot
    through the SPMD fixpoint kernel; ``method="cqrs_ell"`` runs the Pallas
    vrelax kernel INSIDE ``shard_map`` over per-shard sticky-shape ELL
    packings (:class:`_ShardedEllCache`) — each device relaxes only its own
    shard's rows, with the same one-all-gather-per-superstep schedule as
    the flat kernels; row-split min/max reductions are order-exact, so the
    floats match the single-host path bit-for-bit.
    """

    def __init__(self, stream, query, source: int, *,
                 window: Optional[int] = None, method: str = "cqrs",
                 mesh: Optional[Mesh] = None, model_axis: str = MODEL_AXIS):
        owns_view = isinstance(stream, ShardedSnapshotLog)
        if owns_view:
            stream = ShardedWindowView(stream, size=window)
        elif not isinstance(stream, ShardedWindowView):
            raise TypeError(
                f"ShardedStreamingQuery needs a ShardedSnapshotLog or "
                f"ShardedWindowView, got {type(stream).__name__}"
            )
        elif window is not None and window != stream.size:
            raise ValueError(
                f"window={window} conflicts with the shared view's size "
                f"{stream.size}"
            )
        self.mesh = mesh if mesh is not None else host_mesh(
            stream.log.n_shards, model_axis
        )
        self.model_axis = model_axis
        self._ell_cache = None
        super().__init__(stream, query, source, method=method)
        self._owns_view = owns_view

    # -- sharded substitutions ------------------------------------------------
    def _make_bounds(self):
        return ShardedStreamingBounds(
            self.view, self.semiring, self.source, self.mesh,
            model_axis=self.model_axis,
        )

    def _make_qrs(self):
        return ShardedQRSMask(
            self.view, np.asarray(self._bounds.uvv), self.semiring
        )

    def _eval_snapshot(self, t: int, bounds=None):
        """Exact values for log snapshot ``t``: warm-start from R∩ over the
        shard-local ``keep ∧ present`` masks (one launch).

        ``bounds`` overrides the warm bounds supplying the R∩ bootstrap and
        the device/kernel caches — the batched subclass passes one new
        lane's scalar bounds here to prime just that lane.
        """
        bounds = self._bounds if bounds is None else bounds
        if self.method == "cqrs":
            with span("ell_pack"):  # shard-local device-array refresh
                dev, k = bounds._device(), bounds._kernels()
                mask = bounds._stack(self._qrs.snapshot_masks(t))
            with span("fixpoint"):
                vals, it = bounds._fixpoint(
                    k, bounds.val_cap, dev, dev["w_cap"], mask, tally=False,
                    fetch=not self._defer_fetch,
                )
            if self._defer_fetch:
                return bounds.to_global_lazy(vals), it
            return bounds.to_global(vals), it
        # cqrs_ell — per-shard Pallas vrelax under shard_map: shard-local
        # ELL tiles, one all-gather of the per-vertex state per superstep
        with span("ell_pack"):
            _, dev = self._ell().pack()
            words = self._ell().presence(self._qrs.snapshot_masks(t))
        with span("fixpoint"):
            k = self._ell_kernels()
            vals, it = k["fixpoint"](
                bounds.val_cap, dev["src"], dev["weight"], words,
                dev["row2vertex"],
            )
        bounds.launches += 1
        if self._defer_fetch:
            return bounds.to_global_lazy(vals), it
        return bounds.to_global(vals), int(it)

    def _set_stats(self, **kw):
        super()._set_stats(**kw)
        self.stats["kernel_launches"] = self._bounds.launches


class ShardedStreamingQueryBatch(_ShardedEllMixin, StreamingQueryBatch):
    """Q-batched sharded streaming query — the serving Q-fold under SPMD.

    Constructed automatically when ``StreamingQueryBatch(...)`` receives a
    sharded stream.  Warm state is ``(Q, V)`` split on the VERTEX axis:
    every maintenance pass runs as one Q-batched ``shard_map`` launch
    (:func:`_kernels_q`) with still exactly ONE all-gather of the per-vertex
    state per superstep, and the appended snapshot is evaluated for all Q
    queries in one launch (``cqrs``: the batched SPMD fixpoint kernel;
    ``cqrs_ell``: the Pallas vrelax kernel with Q folded into its snapshot
    axis).  Results are bit-for-bit identical to Q sequential
    :class:`ShardedStreamingQuery` instances — and to the single-host loop.
    """

    def __init__(self, stream, query, sources, *,
                 window: Optional[int] = None, method: str = "cqrs",
                 mesh: Optional[Mesh] = None, model_axis: str = MODEL_AXIS):
        owns_view = isinstance(stream, ShardedSnapshotLog)
        if owns_view:
            stream = ShardedWindowView(stream, size=window)
            window = None
        elif not isinstance(stream, ShardedWindowView):
            raise TypeError(
                f"ShardedStreamingQueryBatch needs a ShardedSnapshotLog or "
                f"ShardedWindowView, got {type(stream).__name__}"
            )
        self.mesh = mesh if mesh is not None else host_mesh(
            stream.log.n_shards, model_axis
        )
        self.model_axis = model_axis
        self._ell_cache = None
        super().__init__(stream, query, sources, window=window, method=method)
        self._owns_view = owns_view

    # -- sharded substitutions ------------------------------------------------
    def _make_bounds(self):
        return ShardedStreamingBounds(
            self.view, self.semiring, self._lane_sources(), self.mesh,
            model_axis=self.model_axis,
        )

    def _lane_bounds(self, source: int):
        return ShardedStreamingBounds(
            self.view, self.semiring, source, self.mesh,
            model_axis=self.model_axis,
        )

    def _make_qrs(self):
        return ShardedQRSMask(
            self.view, np.asarray(self._bounds.uvv), self.semiring
        )

    def _eval_snapshot(self, t: int):
        """Exact ``(Q, V)`` values for log snapshot ``t`` in ONE launch."""
        bounds = self._bounds
        if self.method == "cqrs":
            with span("ell_pack"):  # shard-local device-array refresh
                dev, k = bounds._device(), bounds._kernels()
                mask = bounds._stack(self._qrs.snapshot_masks(t))
            with span("fixpoint"):
                vals, it = bounds._fixpoint(
                    k, bounds.val_cap, dev, dev["w_cap"], mask, tally=False,
                    fetch=not self._defer_fetch,
                )
            if self._defer_fetch:
                return bounds.to_global_lazy(vals), it
            return bounds.to_global(vals), it
        # cqrs_ell: Q folded into the per-shard kernel's snapshot axis —
        # still one shard_map launch, one all-gather per superstep
        with span("ell_pack"):
            _, dev = self._ell().pack()
            q = int(bounds.val_cap.shape[0])
            words = self._ell().presence(
                self._qrs.snapshot_masks(t), num_queries=q
            )
        with span("fixpoint"):
            k = self._ell_kernels()
            vals, it, _ = k["fixpoint_q"](
                bounds.val_cap, dev["src"], dev["weight"], words,
                dev["row2vertex"],
            )
        bounds.launches += 1
        if self._defer_fetch:
            return bounds.to_global_lazy(vals), it
        return bounds.to_global(vals), int(it)

    def _eval_lane_snapshot(self, t: int, lane):
        """Scalar shard_map eval of snapshot ``t`` for ONE new lane."""
        return ShardedStreamingQuery._eval_snapshot(self, t, bounds=lane)

    def _set_stats(self, **kw):
        super()._set_stats(**kw)
        self.stats["kernel_launches"] = self._bounds.launches

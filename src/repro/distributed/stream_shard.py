"""SPMD sliding-window serving: sharded streaming bounds + query (shard_map).

Device-side counterpart of :mod:`repro.graph.shardlog`.  The host structures
partition the edge universe by dst range; this module runs the streaming
maintenance passes (:class:`~repro.core.bounds.StreamingBounds`'s monotone
re-relaxations, KickStarter-style parent trims, and the per-snapshot
incremental evaluation) as ``shard_map`` programs over a 1-D ``model`` mesh
with shard ``s`` owning vertices ``[s * v_local, (s+1) * v_local)`` and all
edges sinking there — the :func:`repro.distributed.evolve` layout.

Communication contract (the §Roofline invariant, asserted by
``tests/_stream_shard_checks.py`` against the lowered HLO):

* the segment-reduce **scatter is shard-local by construction** (every edge's
  dst lives on its own shard), and so are the witness-count updates, QRS keep
  rules, and parent selections that feed it;
* per superstep exactly **one all-gather of the per-vertex state** (values /
  BFS levels / invalid flags — all "source-value" gathers in the paper's
  sense) crosses shards, plus the scalar convergence ``psum`` every
  while-body also carries in :func:`distributed_concurrent_fixpoint`.

The maintained fixpoints are **bit-for-bit** identical to the single-host
:class:`~repro.core.api.StreamingQuery`: min/max segment reductions are
order-exact, ``extend`` is elementwise, and both engines run the same
superstep sequence — so partitioning changes which device computes a float,
never the float.  A host-mesh fallback
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) makes the whole
subsystem testable in CI.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.api import StreamingQuery
from repro.core.bounds import BoundsResult, detect_uvv
from repro.core.engine import PARENT_FRAGILE
from repro.core.semiring import Semiring
from repro.graph.shardlog import ShardedSnapshotLog, ShardedWindowView
from repro.utils.padding import pad_to

MODEL_AXIS = "model"


def host_mesh(n_shards: int, axis_name: str = MODEL_AXIS) -> Mesh:
    """1-D mesh over the first ``n_shards`` local devices.

    On a development host, export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before* jax
    initializes to fake an 8-device mesh on CPU (the CI pattern).
    """
    devices = jax.devices()
    if len(devices) < n_shards:
        raise RuntimeError(
            f"need {n_shards} devices for {n_shards} shards but only "
            f"{len(devices)} are visible; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards} before jax "
            f"initializes (or shard the log to fewer shards)"
        )
    return Mesh(np.asarray(devices[:n_shards]), (axis_name,))


@functools.lru_cache(maxsize=None)
def _kernels(mesh: Mesh, sr: Semiring, num_vertices: int, e_cap: int,
             model_axis: str):
    """shard_map maintenance kernels, compiled once per (mesh, semiring,
    vertex count, per-shard capacity class).

    All edge arrays are flat ``(n_shards * e_cap,)`` stacks
    (:meth:`ShardedSnapshotLog.stacked_arrays`); per-vertex state is ``(V,)``
    split by vertex range.  Inside the shard body every index is local:
    ``dst_local`` scatters into the shard's own ``v_local`` segment, and
    parent edge ids index the shard's own ``e_cap`` slice.
    """
    from jax.experimental.shard_map import shard_map

    ax = model_axis
    n_shards = int(mesh.shape[ax])
    if num_vertices % n_shards:
        raise ValueError(
            f"num_vertices {num_vertices} must be divisible by the "
            f"{n_shards} mesh shards"
        )
    v_local = num_vertices // n_shards
    identity = jnp.float32(sr.identity)
    limit = num_vertices + 1
    unreached = jnp.int32(num_vertices + 1)

    def local_vertex_ids():
        return (jnp.arange(v_local, dtype=jnp.int32)
                + jax.lax.axis_index(ax) * v_local)

    def fixpoint_body(values_l, src, dst_local, weight, active):
        # Monotone relaxation from values_l (conservative ⇒ exact; identical
        # supersteps to repro.core.engine._fixpoint, so identical floats).
        def relax(vals_l):
            vals_full = jax.lax.all_gather(vals_l, ax, axis=0, tiled=True)
            cand = sr.extend(vals_full[src], weight)  # source-value gather
            cand = jnp.where(active, cand, identity)
            upd = sr.segment_reduce(  # scatter: shard-local by construction
                cand, dst_local, v_local, indices_are_sorted=False
            )
            return sr.improve(vals_l, upd)

        def cond(state):
            _, changed, it = state
            return changed & (it < limit)

        def body(state):
            vals, _, it = state
            new = relax(vals)
            changed = jax.lax.psum(
                jnp.any(new != vals).astype(jnp.int32), ax
            ) > 0
            return new, changed, it + 1

        vals, _, iters = jax.lax.while_loop(
            cond, body, (values_l, jnp.bool_(True), jnp.int32(0))
        )
        return vals, iters

    def parents_body(values_l, src, dst_local, weight, active, source):
        # Shard-local port of repro.core.engine.compute_parents: BFS levels
        # over the achieving subgraph (gathered per superstep), parents drawn
        # from level-(L-1)→L edges only, so chains strictly descend — the
        # same acyclicity argument, with parent ids in shard-local edge space.
        vals_full = jax.lax.all_gather(values_l, ax, axis=0, tiled=True)
        cand = sr.extend(vals_full[src], weight)
        achieving = (active & (cand == values_l[dst_local])
                     & (values_l[dst_local] != identity))
        local_ids = local_vertex_ids()
        level0 = jnp.where(local_ids == source, 0, unreached).astype(jnp.int32)

        def cond(state):
            return state[1]

        def body(state):
            level, _ = state
            lvl_full = jax.lax.all_gather(level, ax, axis=0, tiled=True)
            cand_lvl = jnp.where(
                achieving & (lvl_full[src] < unreached),
                lvl_full[src] + 1, unreached,
            )
            upd = jax.ops.segment_min(
                cand_lvl, dst_local, v_local, indices_are_sorted=False
            )
            new = jnp.minimum(level, upd)
            changed = jax.lax.psum(
                jnp.any(new != level).astype(jnp.int32), ax
            ) > 0
            return new, changed

        level, _ = jax.lax.while_loop(cond, body, (level0, jnp.bool_(True)))
        lvl_full = jax.lax.all_gather(level, ax, axis=0, tiled=True)
        on_forest = achieving & (lvl_full[src] + 1 == level[dst_local])
        eid = jnp.where(on_forest, jnp.arange(e_cap, dtype=jnp.int32), e_cap)
        parent = jax.ops.segment_min(
            eid, dst_local, v_local, indices_are_sorted=False
        )
        parent = jnp.where(parent >= e_cap, -1, parent)
        fragile = (values_l != identity) & (level == unreached)
        parent = jnp.where(fragile, jnp.int32(PARENT_FRAGILE), parent)
        return jnp.where(local_ids == source, -1, parent)

    def invalidate_body(values_l, parent_l, deleted, src, source):
        # Shard-local port of repro.core.engine.invalidate_from_deletions:
        # a vertex's parent edge sinks at it, hence lives on its own shard;
        # only the transitive invalid flags are gathered.
        has_parent = parent_l >= 0
        pidx = jnp.maximum(parent_l, 0)
        invalid0 = (has_parent & deleted[pidx]) | (parent_l == PARENT_FRAGILE)
        parent_src = src[pidx]  # global vertex ids

        def cond(state):
            return state[1]

        def body(state):
            invalid, _ = state
            inv_full = jax.lax.all_gather(invalid, ax, axis=0, tiled=True)
            nxt = invalid | (has_parent & inv_full[parent_src])
            changed = jax.lax.psum(
                jnp.any(nxt != invalid).astype(jnp.int32), ax
            ) > 0
            return nxt, changed

        invalid, _ = jax.lax.while_loop(
            cond, body, (invalid0, jnp.bool_(True))
        )
        new_values = jnp.where(invalid, identity, values_l)
        new_values = jnp.where(
            local_vertex_ids() == source, jnp.float32(sr.source), new_values
        )
        return new_values, invalid

    e = P(ax)  # flat per-shard stacks / vertex-range splits
    r = P()  # replicated scalars
    fixpoint = jax.jit(shard_map(
        fixpoint_body, mesh=mesh,
        in_specs=(e, e, e, e, e), out_specs=(e, r), check_rep=False,
    ))
    parents = jax.jit(shard_map(
        parents_body, mesh=mesh,
        in_specs=(e, e, e, e, e, r), out_specs=e, check_rep=False,
    ))
    invalidate = jax.jit(shard_map(
        invalidate_body, mesh=mesh,
        in_specs=(e, e, e, e, r), out_specs=(e, e), check_rep=False,
    ))
    return {"fixpoint": fixpoint, "parents": parents, "invalidate": invalidate}


class ShardedStreamingBounds:
    """Sharded drop-in for :class:`~repro.core.bounds.StreamingBounds`.

    Same maintenance algebra — monotone re-relax where G∩/G∪ grew,
    witness-parent trims where they shrank, G∩ weight widening treated as
    deletion — but every pass runs shard-locally under ``shard_map`` with one
    per-superstep all-gather of the per-vertex state.  ``apply_slide``
    consumes a :class:`~repro.graph.shardlog.ShardSlideDiff` (per-shard ids)
    and per-shard mask lists; ``val_cap``/``val_cup`` remain global ``(V,)``
    arrays (device-sharded by vertex range), bit-for-bit equal to the
    single-host maintenance.
    """

    def __init__(self, view: ShardedWindowView, sr: Semiring, source: int,
                 mesh: Optional[Mesh] = None, *, model_axis: str = MODEL_AXIS):
        self.view = view
        self.sr = sr
        self.mesh = mesh if mesh is not None else host_mesh(
            view.log.n_shards, model_axis
        )
        if int(self.mesh.shape[model_axis]) != view.log.n_shards:
            raise ValueError(
                f"mesh axis {model_axis!r} has "
                f"{int(self.mesh.shape[model_axis])} devices but the log has "
                f"{view.log.n_shards} shards"
            )
        self.model_axis = model_axis
        self.source = jnp.int32(int(source))
        self.supersteps = 0
        self._dev_key = None
        self._dev: dict = {}
        self._full_init()

    # -- device-side stacked arrays -------------------------------------------
    def _kernels(self):
        return _kernels(self.mesh, self.sr, self.view.log.num_vertices,
                        self.view.log.capacity, self.model_axis)

    def _device(self) -> dict:
        """Stacked edge arrays + safe weights, re-uploaded only when stale."""
        log = self.view.log
        arrs = log.stacked_arrays()
        key = (log.state_key(), arrs["e_cap"])
        if self._dev_key != key:
            sr = self.sr
            self._dev = {
                "src": jnp.asarray(arrs["src"]),
                "dst_local": jnp.asarray(arrs["dst_local"]),
                "w_cap": jnp.asarray(sr.intersection_weight(
                    arrs["weight_min"], arrs["weight_max"])),
                "w_cup": jnp.asarray(sr.union_weight(
                    arrs["weight_min"], arrs["weight_max"])),
            }
            self._dev_key = key
        return self._dev

    def _stack(self, per_shard_masks) -> jax.Array:
        return jnp.asarray(self.view.log.stack_masks(per_shard_masks))

    # -- full solve (cold start) ----------------------------------------------
    def _full_init(self):
        sr, v = self.sr, self.view.log.num_vertices
        dev, k = self._device(), self._kernels()
        inter = self._stack(self.view.intersection_masks())
        union = self._stack(self.view.union_masks())
        boot = np.full(v, sr.identity, np.float32)
        boot[int(self.source)] = np.float32(sr.source)
        self.val_cap, it_cap = k["fixpoint"](
            jnp.asarray(boot), dev["src"], dev["dst_local"], dev["w_cap"], inter
        )
        self.val_cup, it_cup = k["fixpoint"](
            self.val_cap, dev["src"], dev["dst_local"], dev["w_cup"], union
        )
        self.parent_cap = k["parents"](
            self.val_cap, dev["src"], dev["dst_local"], dev["w_cap"], inter,
            self.source,
        )
        self.parent_cup = k["parents"](
            self.val_cup, dev["src"], dev["dst_local"], dev["w_cup"], union,
            self.source,
        )
        self.supersteps += int(it_cap) + int(it_cup)

    # -- one slide ------------------------------------------------------------
    def apply_slide(self, diff, inter_masks=None, union_masks=None) -> int:
        """Fold one :class:`ShardSlideDiff` in; returns supersteps spent.

        Masks default to the view's current per-shard masks (correct only
        for the latest slide); multi-slide catch-up passes each intermediate
        window's masks from :meth:`ShardedWindowView.rolling_masks`, exactly
        as on the single-host path.
        """
        sr = self.sr
        log = self.view.log
        if inter_masks is None:
            inter_masks = self.view.intersection_masks()
        if union_masks is None:
            union_masks = self.view.union_masks()
        dev, k = self._device(), self._kernels()
        per = diff.shards
        steps = 0

        cap_weight_worse = [
            d.wmax_grown if sr.minimize else d.wmin_shrunk for d in per
        ]
        cup_weight_better = [
            d.wmin_shrunk if sr.minimize else d.wmax_grown for d in per
        ]

        cap_drop_ids = [
            np.concatenate([d.inter_lost, w]) for d, w in zip(per, cap_weight_worse)
        ]
        n_cap_drop = sum(len(a) for a in cap_drop_ids)
        cap_changed = bool(
            n_cap_drop
            or any(len(d.inter_gained) for d in per)
            or any(len(a) for a in cap_weight_worse)
        )
        if cap_changed:
            inter = self._stack(inter_masks)
            if n_cap_drop:
                dropped = jnp.asarray(log.stack_ids(cap_drop_ids))
                self.val_cap, _ = k["invalidate"](
                    self.val_cap, self.parent_cap, dropped, dev["src"],
                    self.source,
                )
            self.val_cap, it = k["fixpoint"](
                self.val_cap, dev["src"], dev["dst_local"], dev["w_cap"], inter
            )
            self.parent_cap = k["parents"](
                self.val_cap, dev["src"], dev["dst_local"], dev["w_cap"],
                inter, self.source,
            )
            steps += int(it)

        cup_drop_ids = [d.union_lost for d in per]
        n_cup_drop = sum(len(a) for a in cup_drop_ids)
        cup_changed = bool(
            n_cup_drop
            or any(len(d.union_gained) for d in per)
            or any(len(a) for a in cup_weight_better)
        )
        if cup_changed:
            union = self._stack(union_masks)
            if n_cup_drop:
                dropped = jnp.asarray(log.stack_ids(cup_drop_ids))
                self.val_cup, _ = k["invalidate"](
                    self.val_cup, self.parent_cup, dropped, dev["src"],
                    self.source,
                )
            self.val_cup, it = k["fixpoint"](
                self.val_cup, dev["src"], dev["dst_local"], dev["w_cup"], union
            )
            self.parent_cup = k["parents"](
                self.val_cup, dev["src"], dev["dst_local"], dev["w_cup"],
                union, self.source,
            )
            steps += int(it)

        self.supersteps += steps
        return steps

    # -- results --------------------------------------------------------------
    @property
    def uvv(self) -> jax.Array:
        return detect_uvv(self.val_cap, self.val_cup)

    @property
    def result(self) -> BoundsResult:
        if self.sr.minimize:
            lower, upper = self.val_cup, self.val_cap
        else:
            lower, upper = self.val_cap, self.val_cup
        return BoundsResult(
            val_cap=self.val_cap, val_cup=self.val_cup,
            lower=lower, upper=upper, uvv=self.uvv,
            iters_cap=jnp.int32(self.supersteps), iters_cup=jnp.int32(0),
        )


class ShardedQRSMask:
    """Per-shard Algorithm-1 keep masks (the sharded stand-in for
    :class:`~repro.core.qrs.PatchableQRS`).

    The keep rule — *in G∪ and sink not UVV* — is evaluated per shard over
    the shard's own edges (``uvv[dst]`` reads only shard-owned destinations),
    and per-snapshot evaluation relaxes the full shard-local edge stack under
    ``keep ∧ present`` masks instead of compacting slots: masked-out edges
    contribute ``identity``, so the relaxed edge *set* — and therefore every
    float — matches the single-host compacted QRS exactly, while keeping the
    stacked shapes slide-stable (no per-slide recompaction, no cross-shard
    traffic).
    """

    def __init__(self, view: ShardedWindowView, uvv, sr: Semiring):
        self.view = view
        self.sr = sr
        self.uvv = np.asarray(uvv).copy()
        self._keep = self._compute_keep(view.union_masks(), self.uvv)

    def _compute_keep(self, union_masks, uvv) -> list[np.ndarray]:
        keeps = []
        for s, sh in enumerate(self.view.log.shards):
            keep = np.asarray(union_masks[s]).copy()
            n = sh.num_edges
            if n:
                keep[:n] &= ~uvv[sh.dst[:n]]
            keeps.append(keep)
        return keeps

    @property
    def num_edges(self) -> int:
        return int(sum(k.sum() for k in self._keep))

    def apply_slide(self, diff, uvv_new, union_mask=None) -> dict:
        """Recompute per-shard keep masks for one slide; returns patch stats."""
        uvv_new = np.asarray(uvv_new)
        unions = (union_mask if union_mask is not None
                  else self.view.union_masks())
        new_keep = self._compute_keep(unions, uvv_new)
        entered = left = 0
        for old, new in zip(self._keep, new_keep):
            m = min(len(old), len(new))  # capacity may have grown mid-queue
            entered += int((new[:m] & ~old[:m]).sum()) + int(new[m:].sum())
            left += int((old[:m] & ~new[:m]).sum())
        self._keep = new_keep
        self.uvv = uvv_new.copy()
        return {
            "qrs_edges": self.num_edges,
            "qrs_entered": int(entered),
            "qrs_left": int(left),
            "qrs_touched": int(entered + left),
        }

    def snapshot_masks(self, t: int) -> list[np.ndarray]:
        """Per-shard ``keep ∧ present-in-snapshot-t`` evaluation masks."""
        out = []
        for keep, v in zip(self._keep, self.view.views):
            present = v.snapshot_mask(t)
            out.append(pad_to(keep, len(present), False) & present)
        return out


class ShardedStreamingQuery(StreamingQuery):
    """:class:`~repro.core.api.StreamingQuery` over a dst-range-sharded log.

    Constructed automatically when ``StreamingQuery(...)`` receives a
    :class:`~repro.graph.shardlog.ShardedSnapshotLog` or
    :class:`~repro.graph.shardlog.ShardedWindowView`; the ``advance()``
    control flow (multi-slide catch-up, weight-dirty row rebuilds, history
    pruning) is inherited unchanged — only the bounds maintenance, the QRS
    keep rule, and the per-snapshot evaluation are swapped for their
    shard_map counterparts.  Results are bit-for-bit identical to the
    single-host query on the same stream.

    ``mesh`` defaults to a 1-D host mesh over ``n_shards`` local devices
    (:func:`host_mesh`); only the flat-XLA ``method="cqrs"`` engine is
    supported on the sharded path.
    """

    def __init__(self, stream, query, source: int, *,
                 window: Optional[int] = None, method: str = "cqrs",
                 mesh: Optional[Mesh] = None, model_axis: str = MODEL_AXIS):
        owns_view = isinstance(stream, ShardedSnapshotLog)
        if owns_view:
            stream = ShardedWindowView(stream, size=window)
        elif not isinstance(stream, ShardedWindowView):
            raise TypeError(
                f"ShardedStreamingQuery needs a ShardedSnapshotLog or "
                f"ShardedWindowView, got {type(stream).__name__}"
            )
        elif window is not None and window != stream.size:
            raise ValueError(
                f"window={window} conflicts with the shared view's size "
                f"{stream.size}"
            )
        if method != "cqrs":
            raise ValueError(
                f"sharded streaming supports method='cqrs' only, got {method!r}"
            )
        self.mesh = mesh if mesh is not None else host_mesh(
            stream.log.n_shards, model_axis
        )
        self.model_axis = model_axis
        super().__init__(stream, query, source, method=method)
        self._owns_view = owns_view

    # -- sharded substitutions ------------------------------------------------
    def _make_bounds(self):
        return ShardedStreamingBounds(
            self.view, self.semiring, self.source, self.mesh,
            model_axis=self.model_axis,
        )

    def _make_qrs(self):
        return ShardedQRSMask(
            self.view, np.asarray(self._bounds.uvv), self.semiring
        )

    def _eval_snapshot(self, t: int):
        """Exact values for log snapshot ``t``: warm-start from R∩ over the
        shard-local ``keep ∧ present`` masks (one shard_map launch)."""
        bounds = self._bounds
        dev, k = bounds._device(), bounds._kernels()
        mask = bounds._stack(self._qrs.snapshot_masks(t))
        vals, it = k["fixpoint"](
            bounds.val_cap, dev["src"], dev["dst_local"], dev["w_cap"], mask
        )
        return np.asarray(vals), int(it)

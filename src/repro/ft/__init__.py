from repro.ft.straggler import StragglerDetector
from repro.ft.heartbeat import HeartbeatMonitor
from repro.ft.recovery import ServeSupervisor, TrainSupervisor

__all__ = [
    "StragglerDetector",
    "HeartbeatMonitor",
    "ServeSupervisor",
    "TrainSupervisor",
]

"""Fault tolerance: stragglers, heartbeats, supervisors, fault injection.

Submodules are loaded lazily (PEP 562): :mod:`repro.ft.faultinject` has no
``repro`` dependencies and is imported by the delta log / query layers, so
eagerly pulling in :mod:`repro.ft.recovery` here (→ checkpoint → graph)
would close an import cycle.
"""
import importlib

_LAZY = {
    "StragglerDetector": "repro.ft.straggler",
    "HeartbeatMonitor": "repro.ft.heartbeat",
    "ServeSupervisor": "repro.ft.recovery",
    "TrainSupervisor": "repro.ft.recovery",
    "FaultSpec": "repro.ft.faultinject",
    "FaultPlan": "repro.ft.faultinject",
    "FaultInjector": "repro.ft.faultinject",
    "InjectedFault": "repro.ft.faultinject",
    "DeadLetterLog": "repro.ft.faultinject",
    "inject": "repro.ft.faultinject",
    "ChaosHarness": "repro.ft.chaos",
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.ft' has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)

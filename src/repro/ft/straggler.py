"""Straggler detection & mitigation (deadline-based, MTTR-aware).

At pod scale the slowest worker sets the step time.  The detector keeps a
robust running estimate (median + MAD) of per-worker step durations and
flags workers exceeding ``median × deadline_factor``.  Mitigation policy is
pluggable; the built-ins are the two standard ones:

* ``skip``       — drop the straggler's microbatch this step (gradient is
                   renormalized by the surviving fraction);
* ``redistribute`` — reassign the straggler's shard to the fastest worker
                   (work-stealing; doubles that worker's microbatch).

On a real deployment the timings come from the collective runtime; here the
interface accepts them directly, which is also what the chaos tests drive.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Optional


@dataclasses.dataclass
class StragglerDetector:
    num_workers: int
    deadline_factor: float = 2.5
    window: int = 32
    min_history: int = 4

    def __post_init__(self):
        self.history: list[list[float]] = [[] for _ in range(self.num_workers)]

    def record_step(self, durations: list[float]):
        if len(durations) != self.num_workers:
            raise ValueError("one duration per worker required")
        for w, d in enumerate(durations):
            h = self.history[w]
            h.append(float(d))
            if len(h) > self.window:
                del h[0]

    def _median_all(self) -> Optional[float]:
        allv = [d for h in self.history for d in h]
        if len(allv) < self.min_history * self.num_workers:
            return None
        return statistics.median(allv)

    def deadline(self) -> Optional[float]:
        med = self._median_all()
        return None if med is None else med * self.deadline_factor

    def stragglers(self, durations: list[float]) -> list[int]:
        """Workers whose CURRENT step exceeds the deadline."""
        dl = self.deadline()
        if dl is None:
            return []
        return [w for w, d in enumerate(durations) if d > dl]

    def plan(self, durations: list[float], policy: str = "redistribute") -> dict:
        """Mitigation plan for this step. Returns worker → action mapping."""
        slow = self.stragglers(durations)
        if not slow:
            return {}
        if policy == "skip":
            return {w: {"action": "skip"} for w in slow}
        if policy == "redistribute":
            fast = sorted(
                (w for w in range(self.num_workers) if w not in slow),
                key=lambda w: durations[w],
            )
            plan = {}
            for i, w in enumerate(slow):
                target = fast[i % len(fast)] if fast else w
                plan[w] = {"action": "redistribute", "to": target}
            return plan
        raise ValueError(f"unknown policy {policy!r}")

"""Deterministic fault injection for the ingest→serve→checkpoint stack.

The module is intentionally dependency-free (no ``repro.*`` imports) so any
layer — delta log, query advance, checkpoint manager, pipelined executor —
can thread an injection point through its hot path without import cycles.

Design mirrors ``obs/trace.py``: a module-global active injector that every
``*_point`` helper checks first.  When no injector is armed the helpers are
a single ``is None`` test on the host, so the serving path pays nothing and
no traced/JIT'd computation ever sees the fault layer (zero new
collectives by construction).

Fault sites are plain strings; each site keeps a per-``(site, shard)``
occurrence counter, and a :class:`FaultSpec` selects the *n-th occurrence*
of a site (``slide``), optionally restricted to one shard.  This makes a
plan deterministic under replay: the same seeded schedule fires at the
same phase of the same slide every run.
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "InjectedFault",
    "FaultSpec",
    "FaultPlan",
    "FaultInjector",
    "DeadLetterLog",
    "inject",
    "active_injector",
    "fault_point",
    "corrupt_point",
    "stall_point",
    "fault_file_point",
]


class InjectedFault(RuntimeError):
    """Raised by :func:`fault_point` when a planned fault fires."""


# Injection sites threaded through the stack.  Grouped here so seeded plans
# can draw from the full space; the strings are the single source of truth.
INGEST_SITES = ("ingest", "ingest_shard")
ADVANCE_SITES = (
    "advance_delta_route",
    "advance_bounds_refresh",
    "advance_qrs_patch",
    "advance_eval",
)
CHECKPOINT_SITES = ("ckpt_torn", "ckpt_payload")
EXECUTOR_SITES = ("executor_stall",)
ALL_SITES = INGEST_SITES + ADVANCE_SITES + CHECKPOINT_SITES + EXECUTOR_SITES


@dataclass(frozen=True)
class FaultSpec:
    """One planned fault.

    ``slide``
        Which occurrence of ``site`` (per shard) fires, counted from 0 by
        the injector.  ``-1`` means every occurrence.
    ``shard``
        Restrict to one shard index; ``-1`` matches any shard (including
        unsharded sites, which report shard ``-1``).
    ``mode``
        Site-specific detail: an ingest corruption kind (``"range"`` /
        ``"malformed"`` / ``"duplicate"``), a file corruption kind
        (``"bitflip"`` / ``"truncate"``), free-form otherwise.
    ``payload``
        Numeric knob (stall seconds for ``executor_stall``).
    ``times``
        How many matching occurrences fire; ``-1`` = persistent.
    """

    site: str
    slide: int = 0
    shard: int = -1
    mode: str = ""
    payload: float = 0.0
    times: int = 1


@dataclass
class FaultPlan:
    """A deterministic schedule of :class:`FaultSpec`s."""

    specs: tuple = ()
    seed: int = 0

    def __post_init__(self):
        self.specs = tuple(self.specs)

    @classmethod
    def seeded(
        cls,
        seed: int,
        *,
        n_faults: int = 2,
        n_slides: int = 6,
        n_shards: int = 0,
        sites=None,
    ) -> "FaultPlan":
        """Draw a random multi-fault schedule from ``seed``.

        Sites are drawn from ``sites`` (default: every ingest + advance
        site), occurrence indices from ``[0, n_slides)``, shards from
        ``[0, n_shards)`` when sharded.  Ingest faults get a random
        corruption mode.  Deterministic: same seed → same plan.
        """
        rng = np.random.default_rng(seed)
        pool = tuple(sites) if sites is not None else INGEST_SITES[:1] + ADVANCE_SITES
        specs = []
        for _ in range(int(n_faults)):
            site = pool[int(rng.integers(len(pool)))]
            slide = int(rng.integers(n_slides)) if n_slides > 0 else 0
            # only per-shard sites report a shard index; everything else
            # reports -1 and a pinned shard would never match
            shard = (
                int(rng.integers(n_shards))
                if n_shards > 0 and site == "ingest_shard" else -1
            )
            mode = ""
            if site in INGEST_SITES:
                mode = ("range", "malformed", "duplicate")[int(rng.integers(3))]
            elif site == "ckpt_payload":
                mode = ("bitflip", "truncate")[int(rng.integers(2))]
            specs.append(FaultSpec(site=site, slide=slide, shard=shard, mode=mode))
        return cls(specs=tuple(specs), seed=int(seed))


class FaultInjector:
    """Evaluates a :class:`FaultPlan` against live occurrence counters."""

    def __init__(self, plan: FaultPlan, events=None):
        self.plan = plan
        self.events = events
        self._lock = threading.Lock()
        # (site, shard) → next occurrence index
        self._counts: dict = {}
        # id(spec) → times fired so far
        self._fired: dict = {}
        self.fired_log: list = []

    # ------------------------------------------------------------- core
    def _match(self, site: str, shard: int):
        """Advance the (site, shard) counter; return the firing spec or None."""
        with self._lock:
            key = (site, shard)
            occ = self._counts.get(key, 0)
            self._counts[key] = occ + 1
            for spec in self.plan.specs:
                if spec.site != site:
                    continue
                if spec.shard != -1 and spec.shard != shard:
                    continue
                if spec.slide != -1 and spec.slide != occ:
                    continue
                fired = self._fired.get(id(spec), 0)
                if spec.times != -1 and fired >= spec.times:
                    continue
                self._fired[id(spec)] = fired + 1
                rec = {
                    "site": site,
                    "shard": shard,
                    "occurrence": occ,
                    "mode": spec.mode,
                }
                self.fired_log.append(rec)
                if self.events is not None:
                    self.events.emit("fault_injected", **rec)
                return spec
        return None

    @property
    def faults_fired(self) -> int:
        return len(self.fired_log)


# ------------------------------------------------------------------ global
_ACTIVE: FaultInjector | None = None
_ACTIVE_LOCK = threading.Lock()


def active_injector() -> FaultInjector | None:
    return _ACTIVE


@contextmanager
def inject(plan: FaultPlan, events=None):
    """Arm ``plan`` for the dynamic extent of the block.

    Yields the :class:`FaultInjector` so callers can inspect
    ``faults_fired`` / ``fired_log`` afterwards.  Nested arming raises —
    overlapping chaos schedules would make occurrence counting ambiguous.
    """
    global _ACTIVE
    inj = FaultInjector(plan, events=events)
    with _ACTIVE_LOCK:
        if _ACTIVE is not None:
            raise RuntimeError("a FaultPlan is already armed")
        _ACTIVE = inj
    try:
        yield inj
    finally:
        with _ACTIVE_LOCK:
            _ACTIVE = None


# ------------------------------------------------------------------ points
def fault_point(site: str, shard: int = -1) -> None:
    """Raise :class:`InjectedFault` if the armed plan targets this site."""
    inj = _ACTIVE
    if inj is None:
        return
    spec = inj._match(site, shard)
    if spec is not None:
        raise InjectedFault(f"injected fault at {site} (shard {shard})")


def corrupt_point(site: str, value, *, num_vertices: int = 0, shard: int = -1):
    """Return ``value`` or a corrupted copy if the armed plan fires here.

    Used on delta batches before validation: the corruption modes are all
    guaranteed-rejected by ``_validate_delta``, so a fired corruption turns
    into a clean validation error the quarantine path can absorb.
    """
    inj = _ACTIVE
    if inj is None:
        return value
    spec = inj._match(site, shard)
    if spec is None:
        return value
    return _corrupt_delta(value, spec.mode or "malformed", num_vertices)


def stall_point(site: str, shard: int = -1) -> float:
    """Sleep ``spec.payload`` seconds if the armed plan fires here."""
    inj = _ACTIVE
    if inj is None:
        return 0.0
    spec = inj._match(site, shard)
    if spec is None:
        return 0.0
    delay = float(spec.payload) if spec.payload else 0.05
    time.sleep(delay)
    return delay


def fault_file_point(site: str, path: str, shard: int = -1) -> bool:
    """Corrupt the file at ``path`` in place if the armed plan fires here.

    Modes: ``"bitflip"`` flips one bit mid-file; ``"truncate"`` halves it.
    Returns True when a corruption was applied.
    """
    inj = _ACTIVE
    if inj is None:
        return False
    spec = inj._match(site, shard)
    if spec is None:
        return False
    size = os.path.getsize(path)
    if size == 0:
        return False
    if (spec.mode or "bitflip") == "truncate":
        with open(path, "r+b") as f:
            f.truncate(max(1, size // 2))
    else:
        off = size // 2
        with open(path, "r+b") as f:
            f.seek(off)
            b = f.read(1)
            f.seek(off)
            f.write(bytes([b[0] ^ 0x40]))
    return True


# ------------------------------------------------------------------ corrupt
def _corrupt_delta(delta, mode: str, num_vertices: int):
    """Produce a delta batch that ``_validate_delta`` must reject.

    ``delta`` is the ``(add_src, add_dst, add_w, del_src, del_dst)`` tuple
    (weights possibly absent).  The input arrays are never mutated.
    """
    parts = [np.asarray(p).copy() for p in delta]
    while len(parts) < 5:
        parts.append(np.zeros(0, dtype=parts[0].dtype if parts else np.int64))
    a_src, a_dst, a_w, d_src, d_dst = parts[:5]

    if mode == "duplicate" and len(d_src) == 0:
        mode = "malformed"  # no deletion to duplicate → fall back

    if mode == "range":
        if len(a_src):
            a_dst = a_dst.copy()
            a_dst[0] = num_vertices + 7
        else:
            a_src = np.array([0], dtype=np.int64)
            a_dst = np.array([num_vertices + 7], dtype=np.int64)
            a_w = np.array([1.0], dtype=np.float64)
    elif mode == "duplicate":
        d_src = np.concatenate([d_src, d_src[:1]])
        d_dst = np.concatenate([d_dst, d_dst[:1]])
    else:  # malformed: length mismatch between add columns
        a_src = np.concatenate([a_src, np.array([0], dtype=a_src.dtype)])

    return (a_src, a_dst, a_w, d_src, d_dst)


# ------------------------------------------------------------------ DLQ
@dataclass
class DeadLetter:
    delta: object
    error: str
    context: dict = field(default_factory=dict)
    ts: float = 0.0


class DeadLetterLog:
    """Bounded quarantine log for rejected delta batches."""

    def __init__(self, maxlen: int = 256):
        self._entries: deque = deque(maxlen=maxlen)
        self._lock = threading.Lock()
        self.total = 0

    def record(self, delta, error, context: dict | None = None) -> DeadLetter:
        entry = DeadLetter(
            delta=delta,
            error=f"{type(error).__name__}: {error}",
            context=dict(context or {}),
            ts=time.time(),
        )
        with self._lock:
            self._entries.append(entry)
            self.total += 1
        return entry

    @property
    def entries(self) -> list:
        with self._lock:
            return list(self._entries)

    def drain(self) -> list:
        with self._lock:
            out = list(self._entries)
            self._entries.clear()
            return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

"""Chaos harness: seeded fault schedules vs a fault-free reference.

:class:`ChaosHarness` drives the same generated delta stream through two
:class:`~repro.serving.scheduler.QueryBatcher` runs — one clean, one under
an armed :class:`~repro.ft.faultinject.FaultPlan` — and compares every
served slide bit-for-bit.  The invariants it certifies are exactly the
failure-model contract:

* a poisoned delta is quarantined (dead-letter log) and its *clean
  redelivery* converges to the reference — no partial mutation survived;
* a mid-phase advance fault rolls the group back transactionally, the
  slide is served degraded from last-good rows, and the backed-off retry
  re-folds the same diffs to the identical fixpoint (monotone fixpoints
  are unique, min/max folds are order-exact);
* torn cross-shard appends self-heal, torn checkpoint writes never become
  visible, and a bit-flipped committed checkpoint is skipped for the
  newest verifiable step.

The batcher runs on a **fake clock** owned by the harness, so capped
exponential backoff is drained by advancing time, not sleeping.  An
``on_slide`` hook runs after each served slide (fault-during-reshard
schedules live there).  Modes: sync, pipelined, sharded (any shard count —
``StreamingQueryBatch`` dispatches on the view type).
"""
from __future__ import annotations

from contextlib import nullcontext
from typing import Callable, Optional, Sequence

import numpy as np

from repro.ft.faultinject import FaultPlan, InjectedFault, inject


class ChaosHarness:
    """Replay one delta stream clean and faulted; assert convergence.

    Parameters mirror the test-suite stream fixture (RMAT edges, uniform
    weight grid, evolving add/del batches).  ``watchers`` is a sequence of
    ``(query, source)`` pairs registered on the shared window; ``n_shards``
    > 0 builds a :class:`~repro.graph.shardlog.ShardedSnapshotLog`.
    ``ckpt_dir`` (with ``ckpt_every``) saves the batcher's warm state
    periodically during the *faulted* run — checkpoint-site faults fire
    there — and verifies the newest loadable step restores bit-for-bit.
    """

    def __init__(
        self,
        *,
        num_vertices: int = 48,
        num_edges: int = 192,
        window: int = 3,
        num_snapshots: int = 10,
        batch_size: int = 20,
        stream_seed: int = 0,
        watchers: Sequence[tuple] = (("sssp", 0), ("sssp", 7)),
        method: str = "cqrs",
        pipelined: bool = False,
        n_shards: int = 0,
        retry_budget: int = 16,
        backoff_base: float = 0.25,
        backoff_cap: float = 1.0,
        max_drain: int = 32,
        max_redeliver: int = 3,
        ckpt_every: int = 0,
        ckpt_dir: Optional[str] = None,
        on_slide: Optional[Callable] = None,
    ):
        from repro.graph.generators import (
            generate_evolving_stream,
            generate_rmat,
            generate_uniform_weights,
        )

        self.num_vertices = int(num_vertices)
        self.window = int(window)
        self.watchers = [(str(q), int(s)) for q, s in watchers]
        self.method = method
        self.pipelined = bool(pipelined)
        self.n_shards = int(n_shards)
        self.retry_budget = int(retry_budget)
        self.backoff_base = float(backoff_base)
        self.backoff_cap = float(backoff_cap)
        self.max_drain = int(max_drain)
        self.max_redeliver = int(max_redeliver)
        self.ckpt_every = int(ckpt_every)
        self.ckpt_dir = ckpt_dir
        self.on_slide = on_slide

        src, dst = generate_rmat(self.num_vertices, num_edges, seed=stream_seed)
        w = generate_uniform_weights(len(src), seed=stream_seed + 1, grid=16)
        self.base, deltas = generate_evolving_stream(
            src, dst, w, self.num_vertices,
            num_snapshots=num_snapshots, batch_size=batch_size,
            readd_prob=0.4, seed=stream_seed + 2,
        )
        # prime the window to full, serve the rest
        self.prime_deltas = deltas[: self.window - 1]
        self.serve_deltas = deltas[self.window - 1:]
        self._reference: Optional[dict] = None

    # ------------------------------------------------------------- plumbing
    def _fresh_view(self):
        if self.n_shards:
            from repro.graph.shardlog import ShardedSnapshotLog, ShardedWindowView

            log = ShardedSnapshotLog(self.num_vertices, self.n_shards)
            view_cls = ShardedWindowView
        else:
            from repro.graph.stream import SnapshotLog, WindowView

            log = SnapshotLog(self.num_vertices, capacity=512)
            view_cls = WindowView
        log.append_snapshot(*self.base)
        for d in self.prime_deltas:
            log.append_snapshot(*d)
        return log, view_cls(log, size=self.window)

    @staticmethod
    def _freeze(out: dict) -> dict:
        return {k: np.asarray(v).copy() for k, v in out.items()}

    # ------------------------------------------------------------- one run
    def _run(self, plan: Optional[FaultPlan]) -> dict:
        from repro.obs.export import EventLog
        from repro.serving.scheduler import QueryBatcher

        now = [0.0]
        ev = EventLog()
        _, view = self._fresh_view()
        qb = QueryBatcher(
            method=self.method,
            pipelined=self.pipelined,
            clock=lambda: now[0],
            retry_budget=self.retry_budget,
            backoff_base=self.backoff_base,
            backoff_cap=self.backoff_cap,
            events=ev,
        )
        for q, s in self.watchers:
            qb.watch(view, q, s)

        mgr = None
        saved: dict[int, dict] = {}
        if plan is not None and self.ckpt_dir and self.ckpt_every:
            from repro.checkpoint.manager import CheckpointManager

            mgr = CheckpointManager(self.ckpt_dir, keep=0)

        rows: list[dict] = []
        stats = {
            "faults_fired": 0,
            "fired": [],
            "quarantined": 0,
            "redelivered": 0,
            "degraded_slides": 0,
            "drain_rounds": 0,
            "max_behind": 0,
            "retries": 0,
            "torn_ckpts": 0,
        }
        ctx = inject(plan, events=ev) if plan is not None else nullcontext()
        with ctx as inj:
            for i, delta in enumerate(self.serve_deltas):
                dl0 = qb.dead_letters.total
                out = qb.advance_window(view, delta)
                # a poisoned copy was rejected before any mutation: the
                # clean original is simply redelivered (at-least-once)
                while (
                    qb.dead_letters.total > dl0
                    and stats["redelivered"] < self.max_redeliver
                ):
                    dl0 = qb.dead_letters.total
                    stats["redelivered"] += 1
                    out = qb.advance_window(view, delta)
                if out.degraded:
                    stats["degraded_slides"] += 1
                    behind = max(out.slides_behind.values(), default=0)
                    stats["max_behind"] = max(stats["max_behind"], behind)
                stats["retries"] += out.retries
                # drain: advance the fake clock past the backoff and retry
                # until the window is fresh again (bounded)
                drains = 0
                while out.degraded and drains < self.max_drain:
                    now[0] += self.backoff_cap
                    out = qb.advance_window(view, None)
                    stats["retries"] += out.retries
                    drains += 1
                stats["drain_rounds"] += drains
                rows.append(self._freeze(out))
                if self.on_slide is not None:
                    self.on_slide(i, view, qb)
                if mgr is not None and (i + 1) % self.ckpt_every == 0:
                    try:
                        tree, extra = qb.checkpoint_state(view)
                        mgr.save(i, tree, extra)
                        saved[i] = self._freeze(rows[-1])
                    except InjectedFault:
                        stats["torn_ckpts"] += 1
            if inj is not None:
                stats["faults_fired"] = inj.faults_fired
                stats["fired"] = list(inj.fired_log)
        stats["quarantined"] = qb.dead_letters.total
        stats["events"] = ev.counts()
        stats["cache_degraded"] = bool(qb.cache_info().degraded)
        if mgr is not None and saved:
            stats["ckpt_restore_ok"] = self._verify_restore(mgr, saved)
        return {"rows": rows, "stats": stats}

    def _verify_restore(self, mgr, saved: dict) -> bool:
        """Newest verifiable step restores rows bit-for-bit."""
        from repro.serving.scheduler import QueryBatcher

        arrays, manifest = mgr.load()
        step = int(manifest["step"])
        resumed, _ = QueryBatcher.resume(arrays, manifest["extra"])
        got: dict = {}
        for batch in {id(b): b for b in resumed._batches.values()}.values():
            got.update(resumed._capture_group(batch).materialize())
        want = saved[step]
        return set(got) == set(want) and all(
            np.array_equal(got[k], want[k]) for k in want
        )

    # ------------------------------------------------------------- driver
    def run(
        self,
        plan: Optional[FaultPlan] = None,
        *,
        seed: int = 0,
        n_faults: int = 2,
        sites=None,
    ) -> dict:
        """Run reference + faulted; return a convergence report.

        ``converged`` is True iff every served slide's post-drain results
        equal the fault-free reference bit-for-bit for every watcher.
        """
        if plan is None:
            plan = FaultPlan.seeded(
                seed,
                n_faults=n_faults,
                n_slides=len(self.serve_deltas),
                n_shards=self.n_shards,
                sites=sites,
            )
        # the fault-free reference depends only on the (fixed) stream:
        # compute it once per harness, reuse across seed sweeps
        if self._reference is None:
            self._reference = self._run(None)
        ref = self._reference
        fr = self._run(plan)
        mismatches = []
        for i, (a, b) in enumerate(zip(ref["rows"], fr["rows"])):
            for k in a:
                if k not in b or not np.array_equal(a[k], b[k]):
                    mismatches.append((i, k))
        return {
            **fr["stats"],
            "plan": plan,
            "slides": len(self.serve_deltas),
            "converged": not mismatches,
            "mismatches": mismatches,
        }

"""Checkpoint-restart supervisor with elastic rescale.

``TrainSupervisor.run`` drives a user step function under a failure model:

  * periodic async checkpointing (every ``ckpt_every`` steps);
  * on step exception (preemption, numerical blow-up, injected chaos), the
    state is restored from the last committed checkpoint and training
    resumes — re-executing at most ``ckpt_every - 1`` steps;
  * ``reshard_fn`` hook: when the caller detects a membership change
    (heartbeat monitor), it can hand back new shardings; restore then
    device_puts the checkpoint onto the surviving mesh (elastic rescale —
    exercised in tests by moving a checkpoint across device counts).

The loop is deliberately synchronous-per-step at the Python level; the jitted
step itself is where all the parallel work happens.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.ft.heartbeat import HeartbeatMonitor
from repro.obs.export import EventLog
from repro.obs.metrics import get_registry


@dataclasses.dataclass
class TrainSupervisor:
    manager: CheckpointManager
    ckpt_every: int = 50
    max_restarts: int = 10

    def run(
        self,
        state,
        step_fn: Callable,  # (state, step) -> state
        num_steps: int,
        *,
        start_step: int = 0,
        shardings=None,
        on_restore: Optional[Callable] = None,
    ):
        """Run ``num_steps`` with checkpoint/restart. Returns (state, stats)."""
        step = start_step
        restarts = 0
        completed = 0
        while step < num_steps:
            try:
                state = step_fn(state, step)
                completed += 1
                step += 1
                if step % self.ckpt_every == 0 or step == num_steps:
                    self.manager.save(step, state)
            except Exception:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                latest = self.manager.latest_step()
                if latest is None:
                    # nothing durable yet: restart from the initial state
                    step = start_step
                    if on_restore is not None:
                        state = on_restore(state, start_step)
                    continue
                state, manifest = self.manager.restore(
                    state, step=latest, shardings=shardings
                )
                step = int(manifest["step"])
                if on_restore is not None:
                    state = on_restore(state, step)
        return state, {"restarts": restarts, "steps_executed": completed}


@dataclasses.dataclass
class ServeSupervisor:
    """Checkpoint-restart driver for a streaming serving replica.

    The serving analogue of :class:`TrainSupervisor`: ``run`` drives a
    :class:`~repro.core.api.StreamingQuery` (or batch) over a delta stream,
    checkpointing its warm state (window + bound fixpoints + result rows,
    see ``repro.checkpoint.streamstate``) every ``ckpt_every`` slides.  When
    a slide raises (preemption, injected chaos), the replica is rebuilt from
    the latest committed checkpoint via
    :func:`~repro.checkpoint.streamstate.resume_streaming` — no cold solve —
    and *catches up by delta replay*: the slides since the checkpoint are
    re-served through the ordinary O(batch) incremental path, re-executing at
    most ``ckpt_every - 1`` of them.  Restore is elastic: ``n_shards``
    rebuilds the replica on a different shard count than it crashed on
    (``0`` = single host); values are shard-layout independent, so the
    re-served results stay bit-for-bit.

    ``heartbeat``: optional :class:`~repro.ft.heartbeat.HeartbeatMonitor` —
    a beat is posted per served slide and the worker is re-admitted after a
    restart, so a supervisor-of-supervisors can watch replica liveness.

    ``events``: optional :class:`~repro.obs.export.EventLog` — each restart
    emits a structured ``restart`` JSON-lines event carrying the failure
    cause, the slide restored to, and the catch-up depth (slides that will
    be re-served by delta replay); restarts are also counted in the
    ``serving_restarts_total`` registry counter and checkpoint save/restore
    wall times land in ``checkpoint_save_seconds``/
    ``checkpoint_restore_seconds`` histograms.

    ``reshard_policy``: optional
    :class:`~repro.serving.scheduler.ReshardPolicy` — checked after every
    served slide; when it fires (occupancy spread past threshold, capacity
    growth, or an ``n_shards`` target differing from the replica's current
    layout) the replica live-migrates via its ``reshard()`` (layout epochs,
    zero re-solves, bit-for-bit) instead of waiting for a crash-restore to
    pick the new layout.  Each migration emits a ``reshard`` event and
    counts into ``serving_reshards_total``.
    """

    manager: CheckpointManager
    ckpt_every: int = 8
    max_restarts: int = 10
    heartbeat: Optional[HeartbeatMonitor] = None
    worker: int = 0
    events: Optional[EventLog] = None
    reshard_policy: Optional[object] = None

    def _maybe_reshard(self, replica, reg, state: dict) -> None:
        """Post-slide policy check → live layout migration of ``replica``."""
        pol = self.reshard_policy
        if pol is None or not hasattr(replica, "reshard"):
            return
        from repro.serving.scheduler import plan_reshard

        log = replica.view.log
        if not hasattr(log, "occupancy_spread"):
            return
        state["slides"] = state.get("slides", 0) + 1
        cap = int(log.capacity)
        grew = cap > state.get("e_cap", cap)
        state["e_cap"] = cap
        assignment = plan_reshard(
            log, pol, capacity_grew=grew, slides_since=state["slides"]
        )
        if assignment is None:
            return
        state["slides"] = 0
        report = replica.reshard(assignment)
        reg.counter(
            "serving_reshards_total", "policy-triggered layout migrations"
        ).inc(worker=str(self.worker))
        if self.events is not None:
            self.events.emit(
                "reshard", worker=self.worker,
                epoch=int(report["epoch"]),
                n_shards=int(report["n_shards"]),
                bytes_moved=int(report["bytes_moved"]),
                seconds=float(report["seconds"]),
                occupancy_spread=float(report["occupancy_spread"]),
            )

    def run(
        self,
        replica,
        deltas,
        *,
        n_shards: Optional[int] = None,
        mesh=None,
        method: Optional[str] = None,
        on_restore: Optional[Callable] = None,
    ):
        """Serve ``deltas`` with checkpoint/restart.

        Returns ``(replica, served, stats)`` — ``served[i]`` is the result
        array after slide ``i`` (re-served slides overwrite their entry with
        bit-for-bit identical values), ``replica`` the final (possibly
        restarted) query object.
        """
        from repro.checkpoint.streamstate import resume_streaming, streaming_state

        reg = get_registry()
        deltas = list(deltas)
        replica.results  # prime: the cold solve happens before traffic
        with reg.timer("checkpoint_save_seconds",
                       "streaming-state serialize + manager.save wall time"):
            tree, extra = streaming_state(replica)
            self.manager.save(0, tree, extra=extra)
        served: dict[int, np.ndarray] = {}
        step = 0
        restarts = 0
        reshard_state: dict = {}
        while step < len(deltas):
            try:
                replica.advance(deltas[step])
                served[step] = np.asarray(replica.results).copy()
                step += 1
                self._maybe_reshard(replica, reg, reshard_state)
                if self.heartbeat is not None:
                    self.heartbeat.beat(self.worker)
                if step % self.ckpt_every == 0 or step == len(deltas):
                    with reg.timer(
                        "checkpoint_save_seconds",
                        "streaming-state serialize + manager.save wall time",
                    ):
                        tree, extra = streaming_state(replica)
                        self.manager.save(step, tree, extra=extra)
            except Exception as exc:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                failed_step = step
                with reg.timer(
                    "checkpoint_restore_seconds",
                    "manager.load + warm resume wall time",
                ):
                    arrays, manifest = self.manager.load()
                    replica = resume_streaming(
                        arrays, manifest["extra"],
                        n_shards=n_shards, mesh=mesh, method=method,
                    )
                step = int(manifest["step"])
                reg.counter(
                    "serving_restarts_total",
                    "replica crash → checkpoint-restore restarts",
                ).inc(worker=str(self.worker))
                if self.events is not None:
                    self.events.emit(
                        "restart", worker=self.worker, cause=repr(exc),
                        failed_slide=failed_step, restore_slide=step,
                        catchup_depth=failed_step - step,
                    )
                if self.heartbeat is not None:
                    self.heartbeat.readmit(self.worker)
                if on_restore is not None:
                    on_restore(replica, step)
        stats = {"restarts": restarts, "slides_served": len(served),
                 "final_step": step}
        return replica, [served[i] for i in range(len(deltas))], stats

"""Checkpoint-restart supervisor with elastic rescale.

``TrainSupervisor.run`` drives a user step function under a failure model:

  * periodic async checkpointing (every ``ckpt_every`` steps);
  * on step exception (preemption, numerical blow-up, injected chaos), the
    state is restored from the last committed checkpoint and training
    resumes — re-executing at most ``ckpt_every - 1`` steps;
  * ``reshard_fn`` hook: when the caller detects a membership change
    (heartbeat monitor), it can hand back new shardings; restore then
    device_puts the checkpoint onto the surviving mesh (elastic rescale —
    exercised in tests by moving a checkpoint across device counts).

The loop is deliberately synchronous-per-step at the Python level; the jitted
step itself is where all the parallel work happens.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class TrainSupervisor:
    manager: CheckpointManager
    ckpt_every: int = 50
    max_restarts: int = 10

    def run(
        self,
        state,
        step_fn: Callable,  # (state, step) -> state
        num_steps: int,
        *,
        start_step: int = 0,
        shardings=None,
        on_restore: Optional[Callable] = None,
    ):
        """Run ``num_steps`` with checkpoint/restart. Returns (state, stats)."""
        step = start_step
        restarts = 0
        completed = 0
        while step < num_steps:
            try:
                state = step_fn(state, step)
                completed += 1
                step += 1
                if step % self.ckpt_every == 0 or step == num_steps:
                    self.manager.save(step, state)
            except Exception:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                latest = self.manager.latest_step()
                if latest is None:
                    # nothing durable yet: restart from the initial state
                    step = start_step
                    if on_restore is not None:
                        state = on_restore(state, start_step)
                    continue
                state, manifest = self.manager.restore(
                    state, step=latest, shardings=shardings
                )
                step = int(manifest["step"])
                if on_restore is not None:
                    state = on_restore(state, step)
        return state, {"restarts": restarts, "steps_executed": completed}

"""Worker heartbeat monitor: liveness + failure detection.

Workers post monotonic timestamps; a worker is declared dead after
``timeout`` without a beat.  The supervisor (ft/recovery.py) polls
``dead_workers`` each step and triggers checkpoint-restart / elastic
rescale when membership changes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class HeartbeatMonitor:
    num_workers: int
    timeout: float = 60.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        now = self.clock()
        self.last_beat = {w: now for w in range(self.num_workers)}
        self.declared_dead: set[int] = set()

    def beat(self, worker: int, at: float | None = None):
        if worker in self.declared_dead:
            # a returning worker must rejoin via the supervisor (elastic path)
            return
        self.last_beat[worker] = self.clock() if at is None else at

    def dead_workers(self) -> set[int]:
        now = self.clock()
        for w, t in self.last_beat.items():
            if w not in self.declared_dead and now - t > self.timeout:
                self.declared_dead.add(w)
        return set(self.declared_dead)

    def alive_count(self) -> int:
        return self.num_workers - len(self.dead_workers())

    def readmit(self, worker: int):
        """Supervisor-controlled rejoin after recovery."""
        self.declared_dead.discard(worker)
        self.last_beat[worker] = self.clock()

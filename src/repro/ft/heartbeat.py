"""Worker heartbeat monitor: liveness + failure detection.

Workers post monotonic timestamps; a worker is declared dead after
``timeout`` without a beat.  The supervisor (ft/recovery.py) polls
``dead_workers`` each step and triggers checkpoint-restart / elastic
rescale when membership changes.

Missed-beat detections are no longer silent: each newly-declared death
emits a structured ``missed_beat`` JSON-lines event (worker id, beat age)
to the optional :class:`~repro.obs.export.EventLog`, and every worker's
last-beat age is exported as a lazy ``heartbeat_last_beat_age_seconds``
gauge — the closure reads the clock at scrape time, so the hot path
(``beat``) stays a dict write.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.obs.export import EventLog
from repro.obs.metrics import get_registry


@dataclasses.dataclass
class HeartbeatMonitor:
    num_workers: int
    timeout: float = 60.0
    clock: Callable[[], float] = time.monotonic
    events: Optional[EventLog] = None

    def __post_init__(self):
        now = self.clock()
        self.last_beat = {w: now for w in range(self.num_workers)}
        self.declared_dead: set[int] = set()
        reg = get_registry()
        gauge = reg.gauge(
            "heartbeat_last_beat_age_seconds",
            "seconds since each worker's last heartbeat (lazy: read at scrape)",
        )
        for w in range(self.num_workers):
            gauge.set(self._age_reader(w), worker=str(w))

    def _age_reader(self, worker: int) -> Callable[[], float]:
        def _age() -> float:
            return self.clock() - self.last_beat[worker]

        return _age

    def beat(self, worker: int, at: float | None = None):
        if worker in self.declared_dead:
            # a returning worker must rejoin via the supervisor (elastic path)
            return
        self.last_beat[worker] = self.clock() if at is None else at

    def dead_workers(self) -> set[int]:
        now = self.clock()
        for w, t in self.last_beat.items():
            if w not in self.declared_dead and now - t > self.timeout:
                self.declared_dead.add(w)
                get_registry().counter(
                    "heartbeat_missed_beats_total",
                    "workers declared dead by beat timeout",
                ).inc(worker=str(w))
                if self.events is not None:
                    self.events.emit(
                        "missed_beat", worker=w, age=now - t,
                        timeout=self.timeout,
                    )
        return set(self.declared_dead)

    def alive_count(self) -> int:
        return self.num_workers - len(self.dead_workers())

    def readmit(self, worker: int):
        """Supervisor-controlled rejoin after recovery."""
        self.declared_dead.discard(worker)
        self.last_beat[worker] = self.clock()

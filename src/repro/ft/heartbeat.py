"""Worker heartbeat monitor: liveness + failure detection.

Workers post monotonic timestamps; a worker is declared dead after
``timeout`` without a beat.  The supervisor (ft/recovery.py) polls
``dead_workers`` each step and triggers checkpoint-restart / elastic
rescale when membership changes.

Missed-beat detections are no longer silent: each newly-declared death
emits a structured ``missed_beat`` JSON-lines event (worker id, beat age)
to the optional :class:`~repro.obs.export.EventLog`, and every worker's
last-beat age is exported as a lazy ``heartbeat_last_beat_age_seconds``
gauge — the closure reads the clock at scrape time, so the hot path
(``beat``) stays a dict write.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.obs.export import EventLog
from repro.obs.metrics import get_registry


@dataclasses.dataclass
class HeartbeatMonitor:
    num_workers: int
    timeout: float = 60.0
    clock: Callable[[], float] = time.monotonic
    events: Optional[EventLog] = None
    # flapping-worker readmission backoff: a worker that died k times inside
    # ``flap_window`` waits min(readmit_base·2^(k-1), readmit_cap) seconds
    # before rejoining — a crash-looping replica can't churn the membership
    readmit_base: float = 1.0
    readmit_cap: float = 60.0
    flap_window: float = 300.0

    def __post_init__(self):
        now = self.clock()
        self.last_beat = {w: now for w in range(self.num_workers)}
        self.declared_dead: set[int] = set()
        self._deaths: dict[int, list[float]] = {}
        self._pending: dict[int, float] = {}  # worker → readmit-ready time
        reg = get_registry()
        gauge = reg.gauge(
            "heartbeat_last_beat_age_seconds",
            "seconds since each worker's last heartbeat (lazy: read at scrape)",
        )
        for w in range(self.num_workers):
            gauge.set(self._age_reader(w), worker=str(w))

    def _age_reader(self, worker: int) -> Callable[[], float]:
        def _age() -> float:
            return self.clock() - self.last_beat[worker]

        return _age

    def beat(self, worker: int, at: float | None = None):
        if worker in self.declared_dead:
            # a returning worker must rejoin via the supervisor (elastic path)
            return
        self.last_beat[worker] = self.clock() if at is None else at

    def dead_workers(self) -> set[int]:
        now = self.clock()
        for w, t in self.last_beat.items():
            if w not in self.declared_dead and now - t > self.timeout:
                self.declared_dead.add(w)
                self._deaths.setdefault(w, []).append(now)
                get_registry().counter(
                    "heartbeat_missed_beats_total",
                    "workers declared dead by beat timeout",
                ).inc(worker=str(w))
                if self.events is not None:
                    self.events.emit(
                        "missed_beat", worker=w, age=now - t,
                        timeout=self.timeout,
                    )
        # release parked readmissions whose backoff has elapsed
        for w, ready in list(self._pending.items()):
            if now >= ready:
                del self._pending[w]
                self._readmit_now(w, now)
        return set(self.declared_dead)

    def alive_count(self) -> int:
        return self.num_workers - len(self.dead_workers())

    def _readmit_now(self, worker: int, now: float) -> None:
        self.declared_dead.discard(worker)
        self.last_beat[worker] = now
        get_registry().gauge(
            "heartbeat_readmit_backoff_seconds",
            "remaining readmission backoff per worker (0 = admitted)",
        ).set(0.0, worker=str(worker))

    def readmit(self, worker: int) -> float:
        """Supervisor-controlled rejoin after recovery.

        A worker with a single recent death rejoins immediately.  A flapping
        worker — ``k`` deaths inside ``flap_window`` — is parked for
        ``min(readmit_base · 2^(k-1), readmit_cap)`` seconds: it stays in
        ``declared_dead`` (beats are ignored) and :meth:`dead_workers`
        admits it automatically once the backoff elapses.  Returns the wait
        in seconds (0.0 = admitted now).
        """
        now = self.clock()
        deaths = [
            t for t in self._deaths.get(worker, ())
            if now - t <= self.flap_window
        ]
        self._deaths[worker] = deaths
        k = len(deaths)
        wait = (
            0.0 if k <= 1
            else min(self.readmit_base * (2.0 ** (k - 1)), self.readmit_cap)
        )
        if wait > 0.0 and worker in self.declared_dead:
            self._pending[worker] = now + wait
            get_registry().gauge(
                "heartbeat_readmit_backoff_seconds",
                "remaining readmission backoff per worker (0 = admitted)",
            ).set(wait, worker=str(worker))
            if self.events is not None:
                self.events.emit(
                    "readmit_backoff", worker=worker, flaps=k, wait=wait,
                )
            return wait
        self._pending.pop(worker, None)
        self._readmit_now(worker, now)
        return 0.0

"""Unified observability: metrics registry, slide tracing, stability telemetry.

Layers (see the module docstrings for the contracts):

* :mod:`repro.obs.metrics` — lock-cheap counters/gauges/histograms with
  lazy (device-side) gauge values; :func:`get_registry` is the process
  default everything records to.
* :mod:`repro.obs.trace` — span API over every phase of a window slide,
  thread-shared so the pipelined worker is visible, with
  ``jax.profiler.TraceAnnotation`` for XLA-profile attribution.
* :mod:`repro.obs.stability` — the paper's study-table statistics (UVV
  fraction, QRS vertex/edge subgraph fractions, trims/re-relaxes, per-lane
  supersteps) as a live per-slide time series.
* :mod:`repro.obs.export` — JSON-lines snapshots, Prometheus text format,
  the structured :class:`~repro.obs.export.EventLog`, and a stdlib
  ``/metrics`` scrape server.
"""
from .export import (  # noqa: F401
    EventLog,
    serve_prometheus,
    snapshot,
    to_jsonl,
    to_prometheus,
    write_jsonl,
)
from .metrics import (  # noqa: F401
    MetricsRegistry,
    disabled,
    get_registry,
    use_registry,
)
from .stability import record_slide, window_union_edges  # noqa: F401
from .trace import (  # noqa: F401
    PHASES,
    Tracer,
    get_tracer,
    mark_ready,
    span,
    tracing,
)

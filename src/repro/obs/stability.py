"""Paper-grounded stability telemetry: the paper's table statistics, live.

The source paper's argument is quantitative — 53.2–99.8 % of vertices are
stable (UVV) across adjacent windows, and incremental analysis is confined
to <42 % of vertices on <32 % of edges (the QRS subgraph).  This module
turns those study-table numbers into per-slide gauges so a serving replica
exports them continuously:

* ``stream_uvv_fraction`` — fraction of (lane, vertex) pairs with
  ``val_cap == val_cup`` (Theorem 2's unchanged-value vertices).
* ``stream_qrs_vertex_fraction`` / ``stream_qrs_edge_fraction`` — the
  Algorithm-1 keep rule's vertex frontier and surviving-edge fraction of
  the window union graph (the "<42 % / <32 %" rows).
* ``stream_bounds_match_rate`` — fraction of the newest snapshot's values
  already pinned to the G∩ bound (how much the warm bootstrap explains).
* ``stream_trims_total`` / ``stream_rerelaxes_total`` — KickStarter-style
  maintenance moves per slide side.
* ``lane_slide_supersteps`` — per-lane convergence histogram (the QoS
  signal behind quarantine, as a distribution instead of a max).

:func:`record_slide` is called from ``StreamingQuery._publish_metrics`` at
the end of every ``advance_nowait``/``_prime`` — i.e. on BOTH the
synchronous and pipelined serving routes, which is what unifies the two
paths' accounting.  It must not add device syncs: everything recorded
eagerly is already host-resident (``stats`` fields, the folded QRS mask's
byte count, maintenance counters); anything needing device or O(V)/O(E)
work is recorded as a *lazy* gauge closure resolved only at export time.
Closures hold weak references so an evicted query's state can be freed.

On the sharded path every value here is derived from state the existing
convergence psum already folded (``frac_uvv``, lane tallies, the host-side
keep mask) — recording adds **zero** collectives to the HLO-pinned
schedule.
"""
from __future__ import annotations

import weakref
from typing import Optional

import numpy as np

from .metrics import MetricsRegistry, get_registry

LANE_BUCKETS = (0, 1, 2, 4, 8, 16, 32, 64, 128, 256, float("inf"))


def window_union_edges(view) -> int:
    """Edge count of the window union graph G∪ (denominator of the paper's
    edge-subgraph fraction).  Handles both the single-host ``WindowView``
    and the sharded view (per-shard masks summed host-side)."""
    shard_views = getattr(view, "views", None)
    if shard_views is not None:
        return int(sum(
            int(np.asarray(v.union_mask()[: v.log.num_edges]).sum())
            for v in shard_views
        ))
    return int(np.asarray(view.union_mask()[: view.log.num_edges]).sum())


def _query_labels(stats: dict) -> dict:
    source = stats.get("source")
    if source is None:
        srcs = stats.get("sources") or ("?",)
        source = srcs[0]
    return {"query": str(stats.get("query", "?")), "source": str(source)}


def _delta(sq, key: str, owner, current: float) -> float:
    """Monotone-counter delta against the value recorded last slide.

    The stash is keyed on the owning object's id so a serving rebuild
    (``_bounds = None`` → fresh maintainer with zeroed ledgers) restarts
    the baseline instead of producing a negative delta.
    """
    stash = sq.__dict__.setdefault("_obs_prev", {})
    prev_owner, prev = stash.get(key, (None, 0.0))
    if prev_owner != id(owner):
        prev = 0.0
    stash[key] = (id(owner), current)
    return current - prev


def record_slide(sq, registry: Optional[MetricsRegistry] = None) -> None:
    """Export one slide's stability/maintenance telemetry for ``sq``.

    ``sq`` is any primed :class:`~repro.core.api.StreamingQuery` (scalar,
    batched, or sharded) whose ``stats`` dict was just refreshed by
    ``_set_stats``.
    """
    reg = registry if registry is not None else get_registry()
    if not reg.enabled:
        return
    stats = sq.stats
    labels = _query_labels(stats)
    bounds, qrs = sq._bounds, sq._qrs

    # -- already-host values straight out of stats ---------------------------
    reg.gauge(
        "stream_uvv_fraction",
        "fraction of (lane, vertex) pairs with val_cap == val_cup",
    ).set(stats["frac_uvv"], **labels)
    reg.gauge(
        "stream_qrs_edges", "edges resident in the patched QRS"
    ).set(stats["qrs_edges"], **labels)
    reg.gauge(
        "stream_window_slides", "slides folded into this query's window"
    ).set(stats["slides"], **labels)
    if "seconds" in stats:
        reg.histogram(
            "advance_seconds", "wall time of one advance (all queued slides)"
        ).observe(stats["seconds"], **labels)
    if "advanced" in stats:
        reg.counter(
            "stream_slides_total", "window slides served"
        ).inc(stats["advanced"], **labels)
    for key in ("qrs_entered", "qrs_left", "qrs_touched"):
        if key in stats:
            reg.counter(
                f"stream_{key}_total", "QRS patch slot churn"
            ).inc(stats[key], **labels)

    # supersteps may be a device scalar on the pipelined (_defer_fetch)
    # route — record it lazily; export resolves it after the consumer's
    # materialize() has already forced the underlying computation
    if "supersteps" in stats:
        reg.gauge(
            "stream_slide_supersteps", "relaxation supersteps this advance"
        ).set(stats["supersteps"], **labels)

    # -- maintenance ledgers (bounds attrs, host ints) -----------------------
    if bounds is not None:
        reg.counter(
            "stream_trims_total",
            "KickStarter invalidation launches (deletion-driven trims)",
        ).inc(_delta(sq, "trims", bounds, bounds.trims), **labels)
        reg.counter(
            "stream_rerelaxes_total", "monotone re-relax launches"
        ).inc(_delta(sq, "rerelaxes", bounds, bounds.rerelaxes), **labels)
        launches = getattr(bounds, "launches", None)
        if launches is not None:
            reg.counter(
                "kernel_launches_total", "shard_map kernel launches"
            ).inc(_delta(sq, "launches", bounds, launches), **labels)
            reg.gauge(
                "stream_kernel_launches", "cumulative shard_map launches"
            ).set(launches, **labels)
        ls = getattr(bounds, "lane_supersteps", None)
        if ls is not None:
            sources = getattr(sq, "sources", None) or []
            live = np.asarray(ls[: len(sources)], np.int64)
            hist = reg.histogram(
                "lane_slide_supersteps",
                "per-lane maintenance supersteps per advance",
                buckets=LANE_BUCKETS,
            )
            # observe each lane's own per-advance delta (per-lane stash)
            stash = sq.__dict__.setdefault("_obs_lane_prev", {})
            prev_owner, prev_arr = stash.get("arr", (None, None))
            if prev_owner != id(bounds) or prev_arr is None \
                    or len(prev_arr) != len(live):
                prev_arr = np.zeros_like(live)
            for s, d in zip(sources, live - prev_arr):
                hist.observe(float(d), **dict(labels, lane=str(s)))
            stash["arr"] = (id(bounds), live.copy())

    # -- lazy gauges: O(V)/O(E)/device work deferred to export ---------------
    ref = weakref.ref(sq)

    def _qrs_vertex_fraction() -> float:
        q = ref()
        if q is None or q._qrs is None:
            return 0.0
        uvv = getattr(q._qrs, "uvv", None)  # folded keep-rule mask (host)
        if uvv is None:
            return 0.0
        return float(1.0 - np.asarray(uvv).mean())

    def _qrs_edge_fraction() -> float:
        q = ref()
        if q is None or q._qrs is None:
            return 0.0
        denom = window_union_edges(q.view)
        return q._qrs.num_edges / denom if denom else 0.0

    def _bounds_match_rate() -> float:
        q = ref()
        if q is None or q._bounds is None or not q._rows:
            return 0.0
        row = np.asarray(q._rows[-1])
        val_cap = np.asarray(q._bounds.val_cap)
        if hasattr(q._bounds, "to_global"):
            val_cap = q._bounds.to_global(val_cap)
        sources = getattr(q, "sources", None)
        if sources is not None and row.ndim == val_cap.ndim == 2:
            row, val_cap = row[: len(sources)], val_cap[: len(sources)]
        if row.shape != val_cap.shape:
            return 0.0
        return float((row == val_cap).mean())

    reg.gauge(
        "stream_qrs_vertex_fraction",
        "fraction of vertices in the QRS frontier (paper: <42%)",
    ).set(_qrs_vertex_fraction, **labels)
    reg.gauge(
        "stream_qrs_edge_fraction",
        "QRS edges / window union edges (paper: <32%)",
    ).set(_qrs_edge_fraction, **labels)
    reg.gauge(
        "stream_bounds_match_rate",
        "newest snapshot values already equal to the G∩ bound",
    ).set(_bounds_match_rate, **labels)

"""Export surfaces: JSON-lines snapshots, Prometheus text format, events.

This module is the *sync point* of the observability layer: lazy gauge
values (callables, device arrays) recorded on the hot path are resolved
here, when an operator scrapes or a bench writes a snapshot — never during
a slide.
"""
from __future__ import annotations

import json
import threading
import time
from typing import IO, Optional, Union

from .metrics import Counter, Gauge, Histogram, MetricsRegistry, get_registry


def _labels_str(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{k}="{str(v)}"' for k, v in sorted(labels.items())
    )
    return "{" + inner + "}"


def snapshot(registry: Optional[MetricsRegistry] = None) -> dict:
    """Resolve every instrument to plain JSON-serializable values.

    Shape: ``{"counters": {...}, "gauges": {...}, "histograms": {...}}``
    with flat ``name{label="v"}`` keys, matching the Prometheus exposition
    names so the two formats are cross-referenceable.
    """
    reg = registry if registry is not None else get_registry()
    out = {"counters": {}, "gauges": {}, "histograms": {}}
    for inst in reg.instruments():
        if isinstance(inst, Counter):
            for labels, v in inst.samples():
                out["counters"][inst.name + _labels_str(labels)] = v
        elif isinstance(inst, Gauge):
            for labels, v in inst.samples():
                out["gauges"][inst.name + _labels_str(labels)] = v
        elif isinstance(inst, Histogram):
            for labels, snap in inst.samples():
                out["histograms"][inst.name + _labels_str(labels)] = {
                    "le": [b if b != float("inf") else "+Inf"
                           for b in inst.buckets],
                    "buckets": snap["buckets"],
                    "sum": snap["sum"],
                    "count": snap["count"],
                }
    return out


def to_jsonl(registry: Optional[MetricsRegistry] = None, **extra) -> str:
    """One JSON line: a timestamped :func:`snapshot` plus ``extra`` keys."""
    rec = {"ts": time.time(), **extra, **snapshot(registry)}
    return json.dumps(rec, sort_keys=True)


def write_jsonl(path, registry: Optional[MetricsRegistry] = None, **extra) -> None:
    with open(path, "a") as f:
        f.write(to_jsonl(registry, **extra) + "\n")


def to_prometheus(registry: Optional[MetricsRegistry] = None) -> str:
    """Render the registry in the Prometheus text exposition format."""
    reg = registry if registry is not None else get_registry()
    lines: list[str] = []
    for inst in reg.instruments():
        if inst.help:
            lines.append(f"# HELP {inst.name} {inst.help}")
        if isinstance(inst, Counter):
            lines.append(f"# TYPE {inst.name} counter")
            for labels, v in inst.samples():
                lines.append(f"{inst.name}{_labels_str(labels)} {v}")
        elif isinstance(inst, Gauge):
            lines.append(f"# TYPE {inst.name} gauge")
            for labels, v in inst.samples():
                lines.append(f"{inst.name}{_labels_str(labels)} {v}")
        elif isinstance(inst, Histogram):
            lines.append(f"# TYPE {inst.name} histogram")
            for labels, snap in inst.samples():
                for b, c in zip(inst.buckets, snap["buckets"]):
                    le = "+Inf" if b == float("inf") else repr(b)
                    bl = dict(labels, le=le)
                    lines.append(f"{inst.name}_bucket{_labels_str(bl)} {c}")
                lines.append(
                    f"{inst.name}_sum{_labels_str(labels)} {snap['sum']}"
                )
                lines.append(
                    f"{inst.name}_count{_labels_str(labels)} {snap['count']}"
                )
    return "\n".join(lines) + "\n"


class EventLog:
    """Structured JSON-lines event sink (restarts, missed beats, evictions).

    Events are appended to an in-memory list (for tests and supervisors that
    inspect recent history) and, when a ``path`` or stream is given, written
    through as one JSON object per line.
    """

    def __init__(self, path: Optional[Union[str, IO]] = None):
        self.events: list[dict] = []
        self._lock = threading.Lock()
        self._stream: Optional[IO] = None
        self._path: Optional[str] = None
        if path is None:
            pass
        elif hasattr(path, "write"):
            self._stream = path
        else:
            self._path = str(path)

    def emit(self, kind: str, **fields) -> dict:
        rec = {"ts": time.time(), "event": kind, **fields}
        line = json.dumps(rec, sort_keys=True, default=str)
        with self._lock:
            self.events.append(rec)
            if self._stream is not None:
                self._stream.write(line + "\n")
                self._stream.flush()
            elif self._path is not None:
                with open(self._path, "a") as f:
                    f.write(line + "\n")
        return rec

    def of_kind(self, kind: str) -> list:
        with self._lock:
            return [e for e in self.events if e["event"] == kind]

    def counts(self) -> dict:
        """``{event kind: occurrences}`` over the in-memory history."""
        out: dict[str, int] = {}
        with self._lock:
            for e in self.events:
                out[e["event"]] = out.get(e["event"], 0) + 1
        return out


def serve_prometheus(
    port: int,
    registry: Optional[MetricsRegistry] = None,
    *,
    host: str = "127.0.0.1",
):
    """Start a daemon-thread HTTP server exposing ``/metrics`` for scraping.

    Stdlib-only (``http.server``); returns the server object — call
    ``.shutdown()`` to stop.  Port 0 picks a free port (``server_port``
    tells you which).
    """
    import http.server

    reg = registry if registry is not None else get_registry()

    class _Handler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 (http.server API)
            if self.path.rstrip("/") not in ("", "/metrics"):
                self.send_response(404)
                self.end_headers()
                return
            body = to_prometheus(reg).encode()
            self.send_response(200)
            self.send_header(
                "Content-Type", "text/plain; version=0.0.4; charset=utf-8"
            )
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):
            pass

    server = http.server.ThreadingHTTPServer((host, port), _Handler)
    thread = threading.Thread(
        target=server.serve_forever, name="prom-scrape", daemon=True
    )
    thread.start()
    return server

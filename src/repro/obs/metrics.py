"""Lock-cheap metrics registry: counters, gauges, histograms.

The serving stack's quantitative story — UVV rates, QRS subgraph fractions,
presence-scatter sizes, cache hit ratios, per-phase slide latencies — was
scattered across ad-hoc attributes (``cache_info()`` tuples, ``stats``
dicts, test-pinned counter lists).  This registry gives them one home with
two hard requirements:

* **Near-zero hot-path cost.**  Recording is a Python int/float update (no
  locks on the increment path — CPython's GIL makes the single ``+=`` safe
  enough for monitoring data, and torn reads cost a sample, not
  correctness).  When the registry is disabled every instrument is a single
  attribute check and an early return.
* **No device syncs.**  A gauge may hold a *lazy* value — a callable or a
  device array — which is resolved to a float only when a snapshot is
  collected (:func:`repro.obs.export.snapshot`).  The serving path records
  device-side scalars as-is and the fetch rides the existing
  ``_defer_fetch`` materialization points; export is the sync point, never
  the slide loop.

Instruments are identified by name; re-requesting a name returns the same
object (so modules can declare instruments at call sites without plumbing).
Per-instance accounting that tests pin exactly (``EllPresenceCache.touched``,
``QueryBatcher.cache_info()``) keeps its façade and *mirrors* into the
registry — the registry is the export surface, not the source of truth for
those invariants.
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Callable, Optional, Sequence

import numpy as np


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def resolve_value(v) -> float:
    """Resolve a recorded value to a float (the lazy-value sync point)."""
    if callable(v):
        v = v()
    return float(np.asarray(v))


class Counter:
    """Monotone counter (optionally labelled)."""

    kind = "counter"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str = ""):
        self._registry = registry
        self.name = name
        self.help = help
        self._values: dict[tuple, float] = {}

    def inc(self, amount: float = 1, **labels) -> None:
        if not self._registry._enabled:
            return
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0) + amount

    def value(self, **labels) -> float:
        return float(self._values.get(_label_key(labels), 0))

    def samples(self) -> list:
        return [(dict(k), float(v)) for k, v in self._values.items()]


class Gauge:
    """Point-in-time value; may hold a lazy (callable / device-array) value."""

    kind = "gauge"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str = ""):
        self._registry = registry
        self.name = name
        self.help = help
        self._values: dict[tuple, object] = {}

    def set(self, value, **labels) -> None:
        """Record ``value`` — a number, a device array, or a zero-arg
        callable; lazy values are resolved at snapshot time, never here."""
        if not self._registry._enabled:
            return
        self._values[_label_key(labels)] = value

    def value(self, **labels) -> Optional[float]:
        v = self._values.get(_label_key(labels))
        return None if v is None else resolve_value(v)

    def samples(self) -> list:
        return [(dict(k), resolve_value(v)) for k, v in self._values.items()]


DEFAULT_BUCKETS = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
    10.0, 25.0, 50.0, 100.0, float("inf"),
)


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics: le-upper-bounds)."""

    kind = "histogram"

    def __init__(
        self,
        registry: "MetricsRegistry",
        name: str,
        help: str = "",
        buckets: Sequence[float] = DEFAULT_BUCKETS,
    ):
        self._registry = registry
        self.name = name
        self.help = help
        bs = sorted(float(b) for b in buckets)
        if not bs or bs[-1] != float("inf"):
            bs.append(float("inf"))
        self.buckets = tuple(bs)
        self._series: dict[tuple, list] = {}  # key -> [counts..., sum, n]

    def _slot(self, labels: dict) -> list:
        key = _label_key(labels)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = [[0] * len(self.buckets), 0.0, 0]
        return s

    def observe(self, value: float, **labels) -> None:
        if not self._registry._enabled:
            return
        v = float(value)
        counts, _, _ = s = self._slot(labels)
        for i, b in enumerate(self.buckets):
            if v <= b:
                counts[i] += 1
                break
        s[1] += v
        s[2] += 1

    def snapshot(self, **labels) -> dict:
        """Cumulative bucket counts + sum/count for one label set."""
        s = self._series.get(_label_key(labels))
        if s is None:
            return {"buckets": [0] * len(self.buckets), "sum": 0.0, "count": 0}
        cum, total = [], 0
        for c in s[0]:
            total += c
            cum.append(total)
        return {"buckets": cum, "sum": float(s[1]), "count": int(s[2])}

    def samples(self) -> list:
        return [(dict(k), self.snapshot(**dict(k))) for k in self._series]


class MetricsRegistry:
    """Named instrument store; one per process by default (:func:`get_registry`)."""

    def __init__(self, *, enabled: bool = True):
        self._enabled = bool(enabled)
        self._instruments: dict[str, object] = {}
        self._lock = threading.Lock()  # instrument creation only, never inc

    # -- enablement ---------------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self) -> None:
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    # -- instrument factories ----------------------------------------------
    def _get(self, cls, name: str, help: str, **kw):
        inst = self._instruments.get(name)
        if inst is not None:
            if not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {inst.kind}"
                )
            return inst
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = cls(self, name, help, **kw)
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(
        self, name: str, help: str = "",
        buckets: Sequence[float] = DEFAULT_BUCKETS,
    ) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    @contextlib.contextmanager
    def timer(self, name: str, help: str = "", **labels):
        """Time a block into a (seconds) histogram; no-op when disabled."""
        if not self._enabled:
            yield
            return
        h = self.histogram(name, help)
        t0 = time.perf_counter()
        try:
            yield
        finally:
            h.observe(time.perf_counter() - t0, **labels)

    # -- introspection ------------------------------------------------------
    def instruments(self) -> list:
        return list(self._instruments.values())

    def reset(self) -> None:
        """Drop every instrument (tests / fresh serving epochs)."""
        with self._lock:
            self._instruments.clear()


_DEFAULT = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-default registry every instrumented module records to."""
    return _DEFAULT


@contextlib.contextmanager
def use_registry(registry: MetricsRegistry):
    """Temporarily swap the process-default registry (tests, benches).

    Instruments bound at construction time (e.g. a ``QueryBatcher``'s cache
    counters) stay bound to the registry that was active when their owner
    was constructed — build the owner inside this context to scope it.
    """
    global _DEFAULT
    prev = _DEFAULT
    _DEFAULT = registry
    try:
        yield registry
    finally:
        _DEFAULT = prev


@contextlib.contextmanager
def disabled():
    """Temporarily disable the default registry (the metrics-off baseline)."""
    reg = _DEFAULT
    prev = reg._enabled
    reg._enabled = False
    try:
        yield
    finally:
        reg._enabled = prev

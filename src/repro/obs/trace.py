"""Slide-lifecycle tracing: spans over every phase of a window advance.

A slide is a pipeline — delta routing, witness/bounds refresh, QRS patch,
ELL repack + presence scatter, per-group fixpoint, result fetch — and on
the pipelined serving path those phases run on *two* threads (the batcher's
worker packs slide k+1 while the caller materializes slide k).  A
contextvar-scoped tracer would lose the worker thread entirely, so the
active tracer is a deliberate module-level global shared across threads;
each thread keeps its own span *stack* (``threading.local``) so nesting is
per-thread while the recorded span list is shared.

Spans carry two end timestamps: ``end`` (the instrumented block returned —
on the async path that is when the future was *created*) and ``ready`` (the
result was actually materialized, stamped by :func:`mark_ready` from the
existing ``_defer_fetch`` sync points).  The gap between a span's ``end``
and its ``ready`` is the pipeline overlap the async path buys — measurable,
not assumed.

Inside jit boundaries wall-clock spans are meaningless, so :func:`span`
also enters :class:`jax.profiler.TraceAnnotation` (host-side annotation
visible in a captured XLA profile) and jitted code uses
``jax.named_scope`` at trace time; neither adds ops to the HLO.

When no tracer is installed, :func:`span` returns a shared no-op context
manager — one dict lookup and an ``is None`` test on the hot path.
"""
from __future__ import annotations

import contextlib
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

import jax

from .metrics import get_registry

# Canonical phase names, in slide order.  Keep in sync with the call sites
# in core/api.py and serving/scheduler.py; tests/test_observability.py pins
# that a pipelined slide's span tree covers all of these.
PHASES = (
    "delta_route",      # sweep/evict + append deltas + slide window to tip
    "bounds_refresh",   # witness diff -> StreamingBounds.apply_slide
    "qrs_patch",        # PatchableQRS.apply_slide
    "ell_pack",         # QRS ELL re-pack + presence scatter
    "fixpoint",         # per-group concurrent fixpoint launch
    "fetch",            # result materialization (np.asarray sync point)
)


@dataclass
class SpanRecord:
    name: str
    start: float
    end: Optional[float] = None
    ready: Optional[float] = None
    thread: str = ""
    depth: int = 0
    parent: Optional[str] = None
    meta: dict = field(default_factory=dict)

    @property
    def wall(self) -> Optional[float]:
        return None if self.end is None else self.end - self.start

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "start": self.start,
            "end": self.end,
            "ready": self.ready,
            "thread": self.thread,
            "depth": self.depth,
            "parent": self.parent,
            **({"meta": self.meta} if self.meta else {}),
        }


class Tracer:
    """Collects :class:`SpanRecord`\\ s from every thread that runs spans."""

    def __init__(self):
        self.spans: list[SpanRecord] = []
        self._lock = threading.Lock()
        self._stacks = threading.local()

    def _stack(self) -> list:
        st = getattr(self._stacks, "stack", None)
        if st is None:
            st = self._stacks.stack = []
        return st

    def begin(self, name: str, **meta) -> SpanRecord:
        stack = self._stack()
        rec = SpanRecord(
            name=name,
            start=time.perf_counter(),
            thread=threading.current_thread().name,
            depth=len(stack),
            parent=stack[-1].name if stack else None,
            meta=dict(meta),
        )
        stack.append(rec)
        with self._lock:
            self.spans.append(rec)
        return rec

    def end(self, rec: SpanRecord) -> None:
        rec.end = time.perf_counter()
        stack = self._stack()
        if stack and stack[-1] is rec:
            stack.pop()

    def mark_ready(self, name: str) -> None:
        """Stamp the most recent span named ``name`` whose result just
        became host-visible (called from materialization sync points)."""
        now = time.perf_counter()
        with self._lock:
            for rec in reversed(self.spans):
                if rec.name == name and rec.ready is None:
                    rec.ready = now
                    return

    # -- introspection -------------------------------------------------------
    def names(self) -> set:
        with self._lock:
            return {r.name for r in self.spans}

    def threads(self) -> set:
        with self._lock:
            return {r.thread for r in self.spans}

    def tree(self) -> list:
        """Spans as (depth, name, wall) rows in start order."""
        with self._lock:
            spans = sorted(self.spans, key=lambda r: r.start)
        return [(r.depth, r.name, r.wall) for r in spans]

    def as_dicts(self) -> list:
        with self._lock:
            return [r.as_dict() for r in self.spans]


_ACTIVE: Optional[Tracer] = None


def get_tracer() -> Optional[Tracer]:
    return _ACTIVE


@contextlib.contextmanager
def tracing(tracer: Optional[Tracer] = None):
    """Install ``tracer`` (or a fresh one) as the active tracer."""
    global _ACTIVE
    prev = _ACTIVE
    t = tracer if tracer is not None else Tracer()
    _ACTIVE = t
    try:
        yield t
    finally:
        _ACTIVE = prev


class _NullSpan:
    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _LiveSpan:
    __slots__ = ("name", "meta", "_rec", "_annot", "_t0")

    def __init__(self, name: str, meta: dict):
        self.name = name
        self.meta = meta

    def __enter__(self):
        tracer = _ACTIVE
        self._rec = tracer.begin(self.name, **self.meta) if tracer else None
        self._annot = jax.profiler.TraceAnnotation(f"repro/{self.name}")
        self._annot.__enter__()
        self._t0 = time.perf_counter()
        return self._rec

    def __exit__(self, *exc):
        dt = time.perf_counter() - self._t0
        self._annot.__exit__(*exc)
        if self._rec is not None:
            tracer = _ACTIVE
            if tracer is not None:
                tracer.end(self._rec)
        reg = get_registry()
        if reg.enabled:
            reg.histogram(
                "span_seconds", "wall time per slide phase"
            ).observe(dt, phase=self.name)
        return False


def span(name: str, **meta):
    """Context manager timing one phase of a slide.

    No-op (a shared null object) when neither a tracer nor the metrics
    registry is active; otherwise records a :class:`SpanRecord` and feeds
    the ``span_seconds{phase=...}`` histogram so per-phase timings are
    exported even outside an explicit tracing session.
    """
    if _ACTIVE is None and not get_registry().enabled:
        return _NULL_SPAN
    return _LiveSpan(name, meta)


def mark_ready(name: str) -> None:
    """Stamp result-readiness on the latest span named ``name`` (no-op
    without an active tracer)."""
    tracer = _ACTIVE
    if tracer is not None:
        tracer.mark_ready(name)

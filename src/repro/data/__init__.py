from repro.data.synthetic import TokenPipeline, synthetic_lm_batch
from repro.data.graphs import (
    random_graph_batch,
    molecule_batch,
    build_triplets,
    sampled_block_batch,
)
from repro.data.recsys import recsys_batch, retrieval_batch

__all__ = [
    "TokenPipeline",
    "synthetic_lm_batch",
    "random_graph_batch",
    "molecule_batch",
    "build_triplets",
    "sampled_block_batch",
    "recsys_batch",
    "retrieval_batch",
]

"""GNN batch builders: full-graph, batched molecules, sampled blocks.

Every builder returns a dict of static-shape arrays matching the model
forward contracts (see repro.models.gnn.*) — including host-precomputed
triplet index lists for DimeNet (capped at K per edge on non-molecular
graphs; the cap is logged, not silent — see DESIGN.md §8.7).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.generators import generate_rmat


def build_triplets(
    src: np.ndarray, dst: np.ndarray, *, cap_per_edge: int = 0, seed: int = 0
):
    """For each target edge (j→i), list incoming edges (k→j), k≠i.

    Returns ``(t_kj, t_ji, valid)`` — indices into the edge arrays, padded to
    a static size.  ``cap_per_edge>0`` uniformly samples at most K triplets
    per target edge (required for power-law graphs where Σ deg² explodes).
    """
    rng = np.random.default_rng(seed)
    e = len(src)
    in_edges: dict[int, list[int]] = {}
    for eid, d in enumerate(dst):
        in_edges.setdefault(int(d), []).append(eid)
    t_kj, t_ji = [], []
    for eid in range(e):
        j, i = int(src[eid]), int(dst[eid])
        incoming = in_edges.get(j, [])
        cands = [k for k in incoming if int(src[k]) != i]
        if cap_per_edge and len(cands) > cap_per_edge:
            cands = list(rng.choice(cands, cap_per_edge, replace=False))
        for k in cands:
            t_kj.append(k)
            t_ji.append(eid)
    n = max(1, len(t_kj))
    kj = np.zeros(n, np.int32)
    ji = np.zeros(n, np.int32)
    valid = np.zeros(n, bool)
    kj[: len(t_kj)] = t_kj
    ji[: len(t_ji)] = t_ji
    valid[: len(t_kj)] = True
    return jnp.asarray(kj), jnp.asarray(ji), jnp.asarray(valid)


def random_graph_batch(
    num_nodes: int,
    num_edges: int,
    d_feat: int,
    num_classes: int,
    *,
    d_edge_feat: int = 8,
    with_pos: bool = True,
    with_triplets: bool = False,
    triplet_cap: int = 8,
    seed: int = 0,
) -> dict:
    """Full-graph batch (citation/products style) with synthetic features."""
    rng = np.random.default_rng(seed)
    src, dst = generate_rmat(num_nodes, num_edges, seed=seed)
    batch = {
        "node_feat": jnp.asarray(rng.normal(size=(num_nodes, d_feat)).astype(np.float32)),
        "edge_feat": jnp.asarray(rng.normal(size=(len(src), d_edge_feat)).astype(np.float32)),
        "edge_src": jnp.asarray(src.astype(np.int32)),
        "edge_dst": jnp.asarray(dst.astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, num_classes, num_nodes).astype(np.int32)),
        "atom_type": jnp.asarray(rng.integers(0, 16, num_nodes).astype(np.int32)),
        "graph_id": jnp.zeros(num_nodes, jnp.int32),
    }
    if with_pos:
        batch["pos"] = jnp.asarray(rng.normal(size=(num_nodes, 3)).astype(np.float32) * 2.0)
    if with_triplets:
        kj, ji, tv = build_triplets(src, dst, cap_per_edge=triplet_cap, seed=seed)
        batch.update({"triplet_kj": kj, "triplet_ji": ji, "triplet_valid": tv})
    return batch


def molecule_batch(
    n_graphs: int,
    nodes_per_graph: int,
    edges_per_graph: int,
    *,
    num_atom_types: int = 16,
    seed: int = 0,
) -> dict:
    """Block-diagonal batch of small molecules (the DimeNet habitat)."""
    rng = np.random.default_rng(seed)
    n = n_graphs * nodes_per_graph
    srcs, dsts = [], []
    for g in range(n_graphs):
        # random geometric-ish connectivity within each molecule
        s = rng.integers(0, nodes_per_graph, edges_per_graph)
        d = (s + 1 + rng.integers(0, nodes_per_graph - 1, edges_per_graph)) % nodes_per_graph
        srcs.append(s + g * nodes_per_graph)
        dsts.append(d + g * nodes_per_graph)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    kj, ji, tv = build_triplets(src, dst, cap_per_edge=8, seed=seed)
    pos = rng.normal(size=(n, 3)).astype(np.float32) * 1.5
    return {
        "atom_type": jnp.asarray(rng.integers(0, num_atom_types, n).astype(np.int32)),
        "node_feat": jnp.asarray(rng.normal(size=(n, 16)).astype(np.float32)),
        "edge_feat": jnp.asarray(rng.normal(size=(len(src), 8)).astype(np.float32)),
        "pos": jnp.asarray(pos),
        "edge_src": jnp.asarray(src.astype(np.int32)),
        "edge_dst": jnp.asarray(dst.astype(np.int32)),
        "triplet_kj": kj,
        "triplet_ji": ji,
        "triplet_valid": tv,
        "graph_id": jnp.asarray(np.repeat(np.arange(n_graphs), nodes_per_graph).astype(np.int32)),
        "num_graphs": n_graphs,
        "energy": jnp.asarray(rng.normal(size=(n_graphs,)).astype(np.float32)),
        "labels": jnp.asarray(rng.integers(0, 8, n).astype(np.int32)),
    }


def sampled_block_batch(blocks, features: jax.Array, labels: jax.Array) -> dict:
    """Convert NeighborSampler blocks into a flat subgraph batch.

    Node 0..N0-1 are seeds; sampled edges point hop-(k+1) → hop-k nodes.
    Local node ids are offsets into the concatenated per-hop node lists.
    """
    offsets = [0]
    for nd in blocks.nodes:
        offsets.append(offsets[-1] + nd.shape[0])
    all_nodes = jnp.concatenate(blocks.nodes)
    srcs, dsts, valids = [], [], []
    for k in range(len(blocks.parents)):
        dsts.append(blocks.parents[k] + offsets[k])
        srcs.append(jnp.arange(blocks.neighbors[k].shape[0], dtype=jnp.int32) + offsets[k + 1])
        valids.append(blocks.valid[k])
    return {
        "node_ids": all_nodes,
        "node_feat": features[all_nodes],
        "edge_src": jnp.concatenate(srcs),
        "edge_dst": jnp.concatenate(dsts),
        "edge_valid": jnp.concatenate(valids),
        "labels": labels[all_nodes],
        "num_seeds": blocks.nodes[0].shape[0],
    }

"""Synthetic LM token pipeline.

A deterministic, seekable stream (Zipf-ish unigram mix + local n-gram
structure) standing in for a tokenized corpus: supports sharded reads
(each data-parallel host reads only its slice), step-addressed seeking for
checkpoint/restart, and prefetch double-buffering.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_lm_batch(
    rng: np.random.Generator, batch: int, seq: int, vocab: int
) -> dict:
    """One (tokens, targets) LM batch with mild sequential structure."""
    base = rng.zipf(1.3, size=(batch, seq + 1)) % vocab
    # local structure: with p=0.3 copy the previous token (n-gram-ish)
    copy = rng.random((batch, seq)) < 0.3
    toks = base.copy()
    toks[:, 1:][copy] = toks[:, :-1][copy]
    return {
        "tokens": jnp.asarray(toks[:, :-1].astype(np.int32)),
        "targets": jnp.asarray(toks[:, 1:].astype(np.int32)),
    }


@dataclasses.dataclass
class TokenPipeline:
    """Step-addressed sharded token stream (checkpoint-restartable)."""

    batch: int
    seq: int
    vocab: int
    shard_id: int = 0
    num_shards: int = 1
    seed: int = 0
    step: int = 0

    def __post_init__(self):
        if self.batch % self.num_shards:
            raise ValueError("global batch must divide across data shards")

    def next(self) -> dict:
        """The shard-local slice of the batch for the current step."""
        rng = np.random.default_rng(
            (self.seed, self.step, self.shard_id)
        )
        local = self.batch // self.num_shards
        out = synthetic_lm_batch(rng, local, self.seq, self.vocab)
        self.step += 1
        return out

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict):
        self.step = int(state["step"])
        self.seed = int(state["seed"])

"""Synthetic Criteo-like recsys stream (power-law categorical ids)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.models.dlrm import DLRMConfig


def recsys_batch(cfg: DLRMConfig, batch: int, *, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    dense = rng.lognormal(0.0, 1.0, size=(batch, cfg.n_dense)).astype(np.float32)
    sparse = np.zeros((batch, cfg.n_sparse), np.int64)
    for f, size in enumerate(cfg.table_sizes):
        # zipf-like skew clipped to each field's vocab
        sparse[:, f] = (rng.zipf(1.2, batch) - 1) % size
    labels = (rng.random(batch) < 0.25).astype(np.float32)
    return {
        "dense": jnp.asarray(np.log1p(dense)),
        "sparse": jnp.asarray(sparse.astype(np.int32)),
        "labels": jnp.asarray(labels),
    }


def retrieval_batch(cfg: DLRMConfig, n_candidates: int, *, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    dense = np.log1p(rng.lognormal(0.0, 1.0, size=(1, cfg.n_dense))).astype(np.float32)
    cand = rng.integers(0, cfg.total_rows, n_candidates, dtype=np.int64)
    return {"dense": jnp.asarray(dense), "cand_ids": jnp.asarray(cand.astype(np.int32))}

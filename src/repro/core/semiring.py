"""Path-semirings for the five monotonic vertex queries (paper Table 2).

A *path-based monotonic algorithm* is defined by:

* ``extend(val_u, w)`` — extend a path ending at ``u`` across edge ``(u,v,w)``
  (the paper's EdgeFunction body);
* ``improve(a, b)``    — keep the better value (the paper's CASMIN/CASMAX);
* ``identity``         — the "no path" value, absorbing under ``extend``;
* ``source``           — the initial value at the query source.

Monotonicity: repeated ``improve(old, extend(...))`` converges without
regressing, which is exactly what Theorem 1/2 and the snapshot-oblivious
frontier rely on.

+---------+-------------------------------+----------+--------+----------+
| name    | extend                        | improve  | ident  | source   |
+---------+-------------------------------+----------+--------+----------+
| bfs     | val_u + 1                     | min      | +inf   | 0        |
| sssp    | val_u + w                     | min      | +inf   | 0        |
| sswp    | min(val_u, w)                 | max      | 0      | +inf     |
| ssnp    | max(val_u, w)                 | min      | +inf   | -inf     |
| viterbi | val_u * w   (w in (0,1])      | max      | 0      | 1        |
+---------+-------------------------------+----------+--------+----------+
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Semiring:
    name: str
    identity: float
    source: float
    minimize: bool  # True → improve = min (CASMIN); False → max (CASMAX)
    extend: Callable  # (val_u, w) -> candidate value at v

    def improve(self, a, b):
        return jnp.minimum(a, b) if self.minimize else jnp.maximum(a, b)

    def segment_reduce(self, data, segment_ids, num_segments, **kw):
        import jax

        if self.minimize:
            return jax.ops.segment_min(data, segment_ids, num_segments, **kw)
        return jax.ops.segment_max(data, segment_ids, num_segments, **kw)

    def init_values(self, num_vertices: int, source: int):
        vals = jnp.full((num_vertices,), self.identity, jnp.float32)
        return vals.at[source].set(jnp.float32(self.source))

    def union_weight(self, weight_min, weight_max):
        """Safe G∪ weight for flip-flopping edges (paper §3 Step 1 rule)."""
        return weight_min if self.minimize else weight_max

    def intersection_weight(self, weight_min, weight_max):
        """Safe G∩ weight when an always-present edge changes weight."""
        return weight_max if self.minimize else weight_min

    def is_better(self, a, b):
        """True where ``a`` is strictly better than ``b``."""
        return a < b if self.minimize else a > b


SEMIRINGS: dict[str, Semiring] = {
    "bfs": Semiring("bfs", float("inf"), 0.0, True, lambda v, w: v + 1.0),
    "sssp": Semiring("sssp", float("inf"), 0.0, True, lambda v, w: v + w),
    "sswp": Semiring("sswp", 0.0, float("inf"), False, lambda v, w: jnp.minimum(v, w)),
    "ssnp": Semiring("ssnp", float("inf"), float("-inf"), True, lambda v, w: jnp.maximum(v, w)),
    "viterbi": Semiring("viterbi", 0.0, 1.0, False, lambda v, w: v * w),
}


def get_semiring(name: str) -> Semiring:
    try:
        return SEMIRINGS[name]
    except KeyError:
        raise KeyError(f"unknown semiring {name!r}; options: {sorted(SEMIRINGS)}")


def viterbi_weights(weight: jnp.ndarray) -> jnp.ndarray:
    """Map arbitrary positive weights into (0, 1] probabilities for Viterbi."""
    wmax = jnp.maximum(jnp.max(weight), 1e-30)
    return jnp.clip(weight / wmax, 1e-6, 1.0)

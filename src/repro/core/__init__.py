from repro.core.semiring import Semiring, SEMIRINGS, get_semiring
from repro.core.engine import compute_fixpoint, incremental_fixpoint, compute_parents
from repro.core.bounds import (
    compute_bounds,
    compute_bounds_batch,
    detect_uvv,
    BoundsResult,
    BatchBoundsResult,
)
from repro.core.qrs import build_qrs, build_qrs_shared, QRS, SharedQRS
from repro.core.concurrent import concurrent_fixpoint, concurrent_fixpoint_batch
from repro.core.api import EvolvingQuery, MultiQuery, evaluate_evolving_query

__all__ = [
    "Semiring",
    "SEMIRINGS",
    "get_semiring",
    "compute_fixpoint",
    "incremental_fixpoint",
    "compute_parents",
    "compute_bounds",
    "compute_bounds_batch",
    "detect_uvv",
    "BoundsResult",
    "BatchBoundsResult",
    "build_qrs",
    "build_qrs_shared",
    "QRS",
    "SharedQRS",
    "concurrent_fixpoint",
    "concurrent_fixpoint_batch",
    "EvolvingQuery",
    "MultiQuery",
    "evaluate_evolving_query",
]

# Semiring algebra — the five monotone path queries (paper Table 2).
from repro.core.semiring import Semiring, SEMIRINGS, get_semiring

# Fixpoint engine — dense relax supersteps + KickStarter-style parent trims.
from repro.core.engine import compute_fixpoint, incremental_fixpoint, compute_parents

# Intersection–union bounds (paper §3): fixed-window, batched, and streaming.
from repro.core.bounds import (
    compute_bounds,         # G∩/G∪ solve + UVV mask for one fixed window
    compute_bounds_batch,   # vmapped (Q, V) bounds for Q sources
    detect_uvv,             # Theorem-2 bound-equality test
    BoundsResult,
    BatchBoundsResult,
    StreamingBounds,        # sliding-window bounds maintained from slide diffs
)

# Q-Relevant Subgraph (paper §3 Step 3): per-query, shared-batch, and patched.
from repro.core.qrs import (
    build_qrs,              # compact the universe for one query's UVV mask
    build_qrs_shared,       # one compacted edge set for a Q-query batch
    QRS,
    SharedQRS,
    PatchableQRS,           # slot-maintained QRS grown/shrunk per window slide
)

# Concurrent all-snapshot evaluation (paper §4), single-query and batched.
from repro.core.concurrent import concurrent_fixpoint, concurrent_fixpoint_batch

# User-facing query APIs (paper §5 interface + serving extensions).
from repro.core.api import (
    EvolvingQuery,          # one (source, window) query, every baseline method
    MultiQuery,             # Q same-semiring sources through one shared pipeline
    StreamingQuery,         # warm sliding-window query: advance() per snapshot
    StreamingQueryBatch,    # Q sliding-window queries advanced in one launch
    evaluate_evolving_query,
)

__all__ = [
    "Semiring",
    "SEMIRINGS",
    "get_semiring",
    "compute_fixpoint",
    "incremental_fixpoint",
    "compute_parents",
    "compute_bounds",
    "compute_bounds_batch",
    "detect_uvv",
    "BoundsResult",
    "BatchBoundsResult",
    "StreamingBounds",
    "build_qrs",
    "build_qrs_shared",
    "QRS",
    "SharedQRS",
    "PatchableQRS",
    "concurrent_fixpoint",
    "concurrent_fixpoint_batch",
    "EvolvingQuery",
    "MultiQuery",
    "StreamingQuery",
    "StreamingQueryBatch",
    "evaluate_evolving_query",
]

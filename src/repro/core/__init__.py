from repro.core.semiring import Semiring, SEMIRINGS, get_semiring
from repro.core.engine import compute_fixpoint, incremental_fixpoint, compute_parents
from repro.core.bounds import compute_bounds, detect_uvv, BoundsResult
from repro.core.qrs import build_qrs, QRS
from repro.core.concurrent import concurrent_fixpoint
from repro.core.api import EvolvingQuery, evaluate_evolving_query

__all__ = [
    "Semiring",
    "SEMIRINGS",
    "get_semiring",
    "compute_fixpoint",
    "incremental_fixpoint",
    "compute_parents",
    "compute_bounds",
    "detect_uvv",
    "BoundsResult",
    "build_qrs",
    "QRS",
    "concurrent_fixpoint",
    "EvolvingQuery",
    "evaluate_evolving_query",
]

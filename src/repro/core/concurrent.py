"""Concurrent (all-snapshots-at-once) incremental evaluation — paper §4.

The paper's snapshot-oblivious frontier relaxes an active vertex's out-edges
for *every* snapshot, checking per-edge version bits.  On TPU we take that
design to its vectorized conclusion: the value state is a matrix ``(S, V)``
and one superstep relaxes every (edge × snapshot) pair —

    cand[s, e]  = extend(values[s, src[e]], w[e])       # rank-2 gather
    cand[s, e]  = identity  where snapshot s lacks e    # version-bit AND
    upd[s, v]   = segment_reduce over e: dst[e]=v
    values'     = improve(values, upd)

Monotonicity makes the extra (absent-edge) work harmless — the exact
correctness argument the paper gives for its oblivious frontier.  The
``Algorithm 2`` addition-batch seeding phase is subsumed: batch edges carry
their snapshot bits, so the first superstep performs exactly the paper's
lines 4–8.

This module is the paper-faithful, single-host engine; the pod-scale
``shard_map`` variant lives in ``repro.distributed.evolve``, and the Pallas
hot-path kernel in ``repro.kernels.vrelax``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.semiring import Semiring
from repro.graph.structures import unpack_presence


@functools.partial(
    jax.jit,
    static_argnames=("sr", "num_vertices", "num_snapshots", "max_iters",
                     "sorted_edges"),
)
def concurrent_fixpoint(
    bootstrap: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    weight: jax.Array,
    presence: jax.Array,
    valid: jax.Array,
    sr: Semiring,
    num_vertices: int,
    num_snapshots: int,
    max_iters: Optional[int] = None,
    sorted_edges: bool = True,
):
    """Relax all snapshots concurrently from the (S-broadcast) bootstrap.

    Args:
      bootstrap: ``(V,)`` — R∩ values (feasible for every snapshot).
      src/dst/weight/valid: compacted QRS edge arrays ``(E',)``.
      presence: ``(E', W) uint32`` snapshot bitmask.
      sorted_edges: edge arrays are dst-sorted (default); the streaming
        patched-QRS slot layout is unsorted and passes ``False``.
    Returns:
      ``(values (S, V), iters)``.
    """
    identity = jnp.float32(sr.identity)
    present = unpack_presence(presence, num_snapshots) & valid[None, :]  # (S, E)
    if bootstrap.ndim == 2:  # per-snapshot bootstrap (folded-QRS path)
        values0 = bootstrap
    else:
        values0 = jnp.broadcast_to(bootstrap[None, :], (num_snapshots, num_vertices))
    limit = num_vertices + 1 if max_iters is None else max_iters

    seg = functools.partial(
        sr.segment_reduce, segment_ids=dst, num_segments=num_vertices,
        indices_are_sorted=sorted_edges,
    )

    def relax(values):
        cand = sr.extend(values[:, src], weight[None, :])  # (S, E)
        cand = jnp.where(present, cand, identity)
        upd = jax.vmap(seg)(cand)  # (S, V)
        return sr.improve(values, upd)

    def cond(state):
        _, changed, it = state
        return changed & (it < limit)

    def body(state):
        values, _, it = state
        new = relax(values)
        return new, jnp.any(new != values), it + 1

    values, _, iters = jax.lax.while_loop(
        cond, body, (values0, jnp.bool_(True), jnp.int32(0))
    )
    return values, iters


@functools.partial(
    jax.jit,
    static_argnames=("sr", "num_vertices", "num_snapshots", "max_iters",
                     "sorted_edges"),
)
def concurrent_fixpoint_batch(
    bootstrap: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    weight: jax.Array,
    presence: jax.Array,
    valid: jax.Array,
    sr: Semiring,
    num_vertices: int,
    num_snapshots: int,
    max_iters: Optional[int] = None,
    sorted_edges: bool = True,
):
    """Batched multi-query relaxation: value state ``(Q, S, V)``.

    A vmap of :func:`concurrent_fixpoint` over the query axis: one superstep
    relaxes every (query × snapshot × edge) triple over a *shared* QRS edge
    set, with the per-snapshot presence bit-test unchanged (the graph-resident
    inputs are closed over, so the ``(S, E)`` mask is built once and broadcast
    across queries).  The lockstep ``while_loop`` runs until the slowest query
    converges — monotone relaxation makes the extra supersteps for
    already-converged queries no-ops — so ``iters`` is the max over the batch.

    Args:
      bootstrap: ``(Q, V)`` per-query R∩ values (broadcast over snapshots),
        or ``(Q, S, V)`` per-(query, snapshot) initial state.
      src/dst/weight/valid: shared compacted QRS edge arrays ``(E',)``.
      presence: ``(E', W) uint32`` snapshot bitmask.
      sorted_edges: edge arrays are dst-sorted (default); the streaming
        patched-QRS slot layout is unsorted and passes ``False``.
    Returns:
      ``(values (Q, S, V), iters)``.
    """
    values, iters = jax.vmap(
        lambda b: concurrent_fixpoint(
            b, src, dst, weight, presence, valid, sr, num_vertices,
            num_snapshots, max_iters, sorted_edges,
        )
    )(bootstrap)
    return values, iters.max()

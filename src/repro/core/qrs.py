"""Q-Relevant Subgraph construction (paper §3 Step 3 + Algorithm 1).

Given the UVV set, the QRS is the versioned universe minus every edge whose
*sink* is a UVV (``RemoveIncomingEdges`` + ``RemoveDeltaAdditionBatches`` in
Algorithm 1, fused into one mask).  Because the concurrent engine consumes the
paper's Fig.-7 *augmented* graph (QRS edges ∪ reduced addition batches, each
with its snapshot bitmask), we keep a single compacted edge array whose
presence bits distinguish always-present (all-ones) from snapshot-specific
edges.

Compaction happens **host-side, once per query** (the paper counts the
analogous "QRS generation" in query time; our benchmarks do too) and produces
small static-shape arrays — the compile-once / run-many fast path.
"""
from __future__ import annotations

import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.semiring import Semiring
from repro.graph.structures import EvolvingGraph, PAD_ALIGN
from repro.utils.padding import pad_to, pad_to_multiple, round_up
from repro.utils.pytree import register_static_dataclass

# process-wide ELL pack identities: every re-pack gets a fresh epoch so
# slot-position caches (presence planes) can never alias across packs
_ELL_EPOCH = itertools.count(1)


@register_static_dataclass(meta_fields=("num_vertices", "num_snapshots", "stats"))
@dataclasses.dataclass(frozen=True)
class QRS:
    """Compacted augmented subgraph + bootstrap state for incremental eval."""

    src: jax.Array  # (E',) int32, dst-sorted, padded
    dst: jax.Array  # (E',) int32
    weight: jax.Array  # (E',) float32
    presence: jax.Array  # (E', W) uint32 snapshot bitmask
    always: jax.Array  # (E',) bool — present in all snapshots (G∩ remnant)
    valid: jax.Array  # (E',) bool — real (non-padding) edge
    uvv: jax.Array  # (V,) bool
    bootstrap: jax.Array  # (V,) float32 — R∩ values (paper Fig. 5)
    num_vertices: int
    num_snapshots: int
    stats: tuple  # ((key, value), ...) hashable build statistics

    @property
    def stats_dict(self) -> dict:
        return dict(self.stats)

    def snapshot_valid(self, i: int) -> jax.Array:
        word, bit = divmod(int(i), 32)
        present = (self.presence[:, word] >> np.uint32(bit)) & np.uint32(1)
        return present.astype(bool) & self.valid


def build_qrs(
    eg: EvolvingGraph,
    uvv: jax.Array,
    bootstrap: jax.Array,
    sr: Semiring,
    *,
    align: int = PAD_ALIGN,
):
    """Compact the versioned universe down to the Q-Relevant Subgraph.

    Shared-QRS mode: passing a ``(Q, V)`` UVV mask and ``(Q, V)`` bootstrap
    (from :func:`~repro.core.bounds.compute_bounds_batch`) builds one
    :class:`SharedQRS` over the union of the per-query non-UVV frontiers, so
    Q queries reuse a single compacted edge set.
    """
    if np.ndim(uvv) == 2:
        return build_qrs_shared(eg, uvv, bootstrap, sr, align=align)
    uvv_np = np.asarray(uvv)
    src = np.asarray(eg.src)
    dst = np.asarray(eg.dst)
    presence = np.asarray(eg.presence)
    pop = np.asarray(eg.popcount())
    union_valid = pop > 0

    # Algorithm 1 lines 17–20: drop every edge sinking at a UVV vertex
    # (covers both G∩ incoming edges and delta-batch additions).
    keep = union_valid & ~uvv_np[dst]
    idx = np.flatnonzero(keep)

    w = np.asarray(sr.intersection_weight(eg.weight_min, eg.weight_max))
    k_src = src[idx]
    k_dst = dst[idx]
    k_w = w[idx]
    k_presence = presence[idx]
    k_always = pop[idx] == eg.num_snapshots
    k_valid = np.ones(idx.shape[0], bool)

    stats = (
        ("num_vertices", int(eg.num_vertices)),
        ("num_snapshots", int(eg.num_snapshots)),
        ("universe_edges", int(union_valid.sum())),
        ("intersection_edges", int((pop == eg.num_snapshots).sum())),
        ("qrs_edges", int(idx.shape[0])),
        ("num_uvv", int(uvv_np.sum())),
        ("frac_uvv", float(uvv_np.mean())),
        (
            "frac_edges_kept",
            float(idx.shape[0]) / max(1, int(union_valid.sum())),
        ),
    )

    return QRS(
        src=jnp.asarray(pad_to_multiple(k_src, align, 0)),
        dst=jnp.asarray(pad_to_multiple(k_dst, align, 0)),
        weight=jnp.asarray(pad_to_multiple(k_w, align, 0.0)),
        presence=jnp.asarray(pad_to_multiple(k_presence, align, 0, axis=0)),
        always=jnp.asarray(pad_to_multiple(k_always, align, False)),
        valid=jnp.asarray(pad_to_multiple(k_valid, align, False)),
        uvv=jnp.asarray(uvv_np),
        bootstrap=bootstrap,
        num_vertices=eg.num_vertices,
        num_snapshots=eg.num_snapshots,
        stats=stats,
    )


# ==========================================================================
# Shared QRS: one compacted edge set serving a batch of Q queries
# ==========================================================================
@register_static_dataclass(
    meta_fields=("num_vertices", "num_snapshots", "num_queries", "stats")
)
@dataclasses.dataclass(frozen=True)
class SharedQRS:
    """QRS over the union of Q queries' non-UVV frontiers.

    An edge is dropped only when its sink is UVV for *every* query in the
    batch, so each query's per-query QRS is a subset of this edge set.
    Theorem 2 stays intact per query: every non-UVV vertex of every query
    keeps all its union-graph in-edges, and the extra edges (sinking at a
    vertex that is UVV for query q but not for q') are harmless for q —
    monotone relaxation from q's feasible R∩ bootstrap can never push a UVV
    vertex past its exact (constant) value.
    """

    src: jax.Array  # (E',) int32, dst-sorted, padded
    dst: jax.Array  # (E',) int32
    weight: jax.Array  # (E',) float32
    presence: jax.Array  # (E', W) uint32 snapshot bitmask
    always: jax.Array  # (E',) bool — present in all snapshots
    valid: jax.Array  # (E',) bool — real (non-padding) edge
    uvv: jax.Array  # (Q, V) bool — per-query Theorem-2 masks
    bootstrap: jax.Array  # (Q, V) float32 — per-query R∩ values
    num_vertices: int
    num_snapshots: int
    num_queries: int
    stats: tuple

    @property
    def stats_dict(self) -> dict:
        return dict(self.stats)

    def snapshot_valid(self, i: int) -> jax.Array:
        word, bit = divmod(int(i), 32)
        present = (self.presence[:, word] >> np.uint32(bit)) & np.uint32(1)
        return present.astype(bool) & self.valid


def build_qrs_shared(
    eg: EvolvingGraph,
    uvv: jax.Array,  # (Q, V) bool
    bootstrap: jax.Array,  # (Q, V) float32
    sr: Semiring,
    *,
    align: int = PAD_ALIGN,
) -> SharedQRS:
    """One compacted augmented subgraph for a batch of Q queries.

    Same Algorithm-1 sink rule as :func:`build_qrs`, but an edge survives if
    its sink is non-UVV for *any* query (union of frontiers).  Compaction —
    the host-side gather/pad that dominates QRS generation time — happens
    once per batch instead of once per query.
    """
    uvv_q = np.asarray(uvv)
    if uvv_q.ndim != 2:
        raise ValueError(f"expected (Q, V) uvv mask, got shape {uvv_q.shape}")
    src = np.asarray(eg.src)
    dst = np.asarray(eg.dst)
    presence = np.asarray(eg.presence)
    pop = np.asarray(eg.popcount())
    union_valid = pop > 0

    all_uvv = uvv_q.all(axis=0)  # (V,) — UVV for every query in the batch
    keep = union_valid & ~all_uvv[dst]
    idx = np.flatnonzero(keep)

    w = np.asarray(sr.intersection_weight(eg.weight_min, eg.weight_max))
    k_always = pop[idx] == eg.num_snapshots
    k_valid = np.ones(idx.shape[0], bool)

    stats = (
        ("num_vertices", int(eg.num_vertices)),
        ("num_snapshots", int(eg.num_snapshots)),
        ("num_queries", int(uvv_q.shape[0])),
        ("universe_edges", int(union_valid.sum())),
        ("intersection_edges", int((pop == eg.num_snapshots).sum())),
        ("qrs_edges", int(idx.shape[0])),
        ("num_uvv_shared", int(all_uvv.sum())),
        ("frac_uvv_shared", float(all_uvv.mean())),
        ("frac_uvv_per_query", tuple(float(f) for f in uvv_q.mean(axis=1))),
        (
            "frac_edges_kept",
            float(idx.shape[0]) / max(1, int(union_valid.sum())),
        ),
    )

    return SharedQRS(
        src=jnp.asarray(pad_to_multiple(src[idx], align, 0)),
        dst=jnp.asarray(pad_to_multiple(dst[idx], align, 0)),
        weight=jnp.asarray(pad_to_multiple(w[idx], align, 0.0)),
        presence=jnp.asarray(pad_to_multiple(presence[idx], align, 0, axis=0)),
        always=jnp.asarray(pad_to_multiple(k_always, align, False)),
        valid=jnp.asarray(pad_to_multiple(k_valid, align, False)),
        uvv=jnp.asarray(uvv_q),
        bootstrap=jnp.asarray(bootstrap),
        num_vertices=eg.num_vertices,
        num_snapshots=eg.num_snapshots,
        num_queries=int(uvv_q.shape[0]),
        stats=stats,
    )


# ==========================================================================
# Streaming: slot-maintained QRS patched from UVV-mask diffs
# ==========================================================================
class PatchableQRS:
    """Compacted subgraph that grows/shrinks in place as the window slides.

    The batch :func:`build_qrs` recompacts the whole universe per query.  For
    a sliding window almost nothing changes between adjacent windows (the
    paper's 53–99 % stable-vertex observation), so this class keeps the
    compacted edge set in **slots**: fixed-capacity host arrays plus a
    universe-id → slot map.  ``apply_slide`` recomputes the Algorithm-1 keep
    rule (``in G∪ and sink not UVV``) only for edges *touched* by the slide —
    in-edges of vertices whose UVV bit flipped, plus edges whose G∪ membership
    or safe weight changed — and point-updates the slots.  Freed slots are
    recycled; capacity grows amortized-doubling so jitted consumers compile
    once per capacity class.

    Slot order is arbitrary (engine calls must pass ``sorted_edges=False``);
    the resident edge *set* is asserted identical to a fresh :func:`build_qrs`
    in the test suite.

    **Shared (batched) mode** — the streaming analogue of
    :class:`SharedQRS`: passing a ``(Q, V)`` UVV mask folds it to the union
    of the per-query non-UVV frontiers (an edge is dropped only when its
    sink is UVV for *every* query), so Q streaming queries patch and relax
    ONE compacted edge set.  The per-query safety argument is exactly
    :class:`SharedQRS`'s.  :meth:`refresh` re-evaluates residency from
    scratch when the query set itself changes (a serving batch gained or
    lost a lane).  Safe weights are the view's window-local extrema, and
    :meth:`ell_pack` exposes the slot arrays as a row-split ELL packing at
    sticky (amortized-doubling) row capacity so the Pallas kernel path
    compiles once per capacity class instead of once per slide.

    On the dst-range-sharded streaming path the same Algorithm-1 keep rule
    is evaluated as per-shard masks over slide-stable stacked shapes instead
    of compacted slots — see
    :class:`repro.distributed.stream_shard.ShardedQRSMask` (``uvv[dst]`` only
    reads shard-owned destinations, so patching stays shard-local).
    """

    @staticmethod
    def _fold(uvv) -> np.ndarray:
        """Fold a per-query ``(Q, V)`` UVV mask to the shared keep-rule mask."""
        uvv = np.asarray(uvv)
        return uvv.all(axis=0) if uvv.ndim == 2 else uvv

    def __init__(self, view, uvv, sr: Semiring, *, align: int = PAD_ALIGN,
                 min_capacity: int = 0, min_ell_rows: int = 0):
        self.view = view
        self.sr = sr
        self.align = int(align)
        log = view.log
        self.uvv = self._fold(uvv).copy()
        n = log.num_edges
        keep = view.union_mask().copy()
        keep[:n] &= ~self.uvv[log.dst[:n]]
        ids = np.flatnonzero(keep).astype(np.int32)

        # ``min_capacity``/``min_ell_rows`` let a checkpoint restore rebuild
        # this QRS at the capacity classes the interrupted replica had
        # already grown to, so the restored process re-enters the same
        # compiled kernel variants instead of re-walking the growth ladder.
        # When the saved class holds the current compaction, use it EXACTLY:
        # a live QRS only grows on patch overflow, so its sticky class can
        # sit below the fresh 2x-headroom rule — applying that rule here
        # would rebuild one class up and recompile on the serving path.
        need = 2 * len(ids)
        if min_capacity and len(ids) <= int(min_capacity):
            need = int(min_capacity)
        cap = round_up(max(1, need, int(min_capacity)), self.align)
        self.slot_edge = np.full(cap, -1, np.int32)  # slot → universe id
        self.slot_of = np.full(log.capacity, -1, np.int32)  # universe id → slot
        self.src = np.zeros(cap, np.int32)
        self.dst = np.zeros(cap, np.int32)
        self.weight = np.zeros(cap, np.float32)
        self.valid = np.zeros(cap, bool)
        k = len(ids)
        self.slot_edge[:k] = ids
        self.slot_of[ids] = np.arange(k, dtype=np.int32)
        self.src[:k] = log.src[ids]
        self.dst[:k] = log.dst[ids]
        self.weight[:k] = self._edge_weights(ids)
        self.valid[:k] = True
        self._free = list(range(cap - 1, k - 1, -1))  # pop() yields low slots first
        self._version = 0
        self._dev_version = -1
        self._dev: tuple = ()
        # sticky-shape ELL packing of the slot arrays (kernel engine path)
        from repro.graph.ell import StableEllPacker

        self._ell_packer = StableEllPacker(log.num_vertices)
        if min_ell_rows:
            self._ell_packer.num_rows = round_up(
                int(min_ell_rows), self._ell_packer.row_align
            )
        self._ell = None
        self._ell_version = -1
        self._ell_epoch = 0  # globally-unique pack identity (0 = no pack yet)

    # -- introspection --------------------------------------------------------
    @property
    def capacity(self) -> int:
        return len(self.slot_edge)

    @property
    def num_edges(self) -> int:
        return int(self.valid.sum())

    def edge_ids(self) -> np.ndarray:
        """Universe ids of resident edges (arbitrary order)."""
        return self.slot_edge[self.valid]

    def _edge_weights(self, ids: np.ndarray) -> np.ndarray:
        """G∩ safe weights for the given universe ids (gather, not full scan).

        Reads the view's window-local extrema — exact for the current
        window, narrowing back when a widening snapshot retires.
        """
        view = self.view
        view._sync_weights()
        return np.asarray(
            self.sr.intersection_weight(view.weight_min[ids], view.weight_max[ids])
        )

    # -- patching -------------------------------------------------------------
    def apply_slide(self, diff, uvv_new, union_mask=None) -> dict:
        """Patch the compacted edge set for one slide; returns patch stats.

        ``union_mask`` is the G∪ membership mask of the window *after this
        slide*; it defaults to the view's current mask, which is only correct
        when ``diff`` is the view's latest slide.  A consumer catching up on
        several queued slides must pass each intermediate window's mask
        (``WindowView.rolling_masks``), exactly as for
        :meth:`repro.core.bounds.StreamingBounds.apply_slide` — otherwise the
        intermediate QRS states mix slide-``k`` membership transitions with
        final-window residency.

        ``uvv_new`` may be ``(V,)`` or, in shared (batched) mode, ``(Q, V)``
        — folded to the union of the per-query non-UVV frontiers.
        """
        log = self.view.log
        uvv_new = self._fold(uvv_new)
        if union_mask is None:
            union_mask = self.view.union_mask()
        if len(self.slot_of) != log.capacity:
            self.slot_of = pad_to(self.slot_of, log.capacity, -1)

        flipped = np.flatnonzero(self.uvv != uvv_new).astype(np.int32)
        touched = [log.in_edges(flipped), diff.union_gained, diff.union_lost]
        touched = np.unique(np.concatenate(touched)).astype(np.int64)

        entered = left = 0
        if len(touched):
            new_keep = union_mask[touched] & ~uvv_new[log.dst[touched]]
            resident = self.slot_of[touched] >= 0
            left, entered = self._patch_slots(
                touched[resident & ~new_keep], touched[new_keep & ~resident]
            )

        # safe-weight refresh for resident edges whose window extrema moved
        reweighted = np.concatenate([
            diff.wmin_shrunk, diff.wmax_grown,
            diff.wmin_grown, diff.wmax_shrunk,
        ])
        if len(reweighted):
            slots = self.slot_of[reweighted]
            slots = slots[slots >= 0]
            if len(slots):
                self.weight[slots] = self._edge_weights(self.slot_edge[slots])
        if entered or left or len(reweighted):
            self._version += 1
        self.uvv = uvv_new.copy()
        return {
            "qrs_edges": self.num_edges,
            "qrs_entered": int(entered),
            "qrs_left": int(left),
            "qrs_touched": int(len(touched)),
        }

    def _patch_slots(self, leave_ids, enter_ids) -> tuple[int, int]:
        """Point-update slot residency; returns ``(left, entered)`` counts."""
        log = self.view.log
        left, entered = len(leave_ids), len(enter_ids)
        if left:
            slots = self.slot_of[leave_ids]
            self.valid[slots] = False
            self.slot_edge[slots] = -1
            self.slot_of[leave_ids] = -1
            # freed slots deliberately KEEP their stale src/dst/weight: the
            # ELL packing (ell_pack) packs the full slot arrays, and a freed
            # slot that keeps claiming its old vertex's row holds the packed
            # row histogram — and therefore the sticky row capacity — steady
            # across residency churn (zeroing them re-binned slots to vertex
            # 0 and made the row count jumpy enough to retrigger kernel
            # compiles; pinned by the ELL shape-stability test).  Stale
            # entries are inert everywhere: valid=False masks the flat path
            # and all-zero presence words mask the kernel path.
            self._free.extend(int(s) for s in slots)
        if entered:
            if entered > len(self._free):
                self._grow(self.capacity - len(self._free) + entered)
            slots = np.asarray(
                [self._free.pop() for _ in range(entered)], np.int32
            )
            self.slot_edge[slots] = enter_ids
            self.slot_of[enter_ids] = slots
            self.src[slots] = log.src[enter_ids]
            self.dst[slots] = log.dst[enter_ids]
            self.weight[slots] = self._edge_weights(enter_ids)
            self.valid[slots] = True
        return left, entered

    def refresh(self, uvv_new) -> dict:
        """Re-evaluate residency from scratch against a new UVV mask.

        For UVV changes *caused by a slide*, :meth:`apply_slide` touches only
        the affected in-edges.  When the **query set** sharing this QRS
        changes instead (a serving batch gained or lost a lane), the folded
        mask can flip anywhere, so the Algorithm-1 keep rule is re-evaluated
        over every universe edge; surviving edges keep their slots (warm
        device state stays valid where unchanged).  Same-window only.
        """
        log = self.view.log
        uvv_new = self._fold(uvv_new)
        if len(self.slot_of) != log.capacity:
            self.slot_of = pad_to(self.slot_of, log.capacity, -1)
        n = log.num_edges
        keep = self.view.union_mask().copy()
        keep[:n] &= ~uvv_new[log.dst[:n]]
        keep[n:] = False
        resident = self.slot_of[: log.capacity] >= 0
        left, entered = self._patch_slots(
            np.flatnonzero(resident & ~keep).astype(np.int64),
            np.flatnonzero(keep & ~resident).astype(np.int64),
        )
        if entered or left:
            self._version += 1
        self.uvv = uvv_new.copy()
        return {
            "qrs_edges": self.num_edges,
            "qrs_entered": int(entered),
            "qrs_left": int(left),
            "qrs_touched": int(entered + left),
        }

    def _grow(self, needed: int):
        old_cap = self.capacity
        new_cap = round_up(max(needed, 2 * old_cap), self.align)
        self.slot_edge = pad_to(self.slot_edge, new_cap, -1)
        self.src = pad_to(self.src, new_cap, 0)
        self.dst = pad_to(self.dst, new_cap, 0)
        self.weight = pad_to(self.weight, new_cap, 0.0)
        self.valid = pad_to(self.valid, new_cap, False)
        self._free.extend(range(new_cap - 1, old_cap - 1, -1))
        self._version += 1

    # -- engine-facing arrays -------------------------------------------------
    def device_arrays(self):
        """``(src, dst, weight)`` device arrays, re-uploaded only when patched."""
        if self._dev_version != self._version:
            self._dev = (
                jnp.asarray(self.src), jnp.asarray(self.dst),
                jnp.asarray(self.weight),
            )
            self._dev_version = self._version
        return self._dev

    def ell_pack(self):
        """Row-split ELL packing of the slot arrays at stable shapes.

        The FULL slot capacity is packed — invalid slots carry all-zero
        presence words, so the kernel masks them exactly like padding — and
        the row count is held at the packer's sticky amortized capacity, so
        the jitted kernel path compiles once per (slot, row) capacity class
        instead of once per slide.  Re-packed only when a slide actually
        patched the slots.
        """
        if self._ell is None or self._ell_version != self._version:
            self._ell = self._ell_packer.pack(self.src, self.dst, self.weight)
            self._ell_version = self._version
            self._ell_epoch = next(_ELL_EPOCH)
        return self._ell

    @property
    def ell_epoch(self) -> int:
        """Globally-unique id of the current :meth:`ell_pack` layout.

        Consumers keying cached slot-position state (e.g. the incremental
        presence plane,
        :class:`~repro.kernels.vrelax.ops.EllPresenceCache`) compare this to
        detect re-packs: any slot patch re-packs the ELL, which can move
        every slot's (row, col) position, so derived planes must be rebuilt
        — the presence-plane face of the freed-slot invariant documented in
        :meth:`_patch_slots`.
        """
        return self._ell_epoch

    def snapshot_mask(self, t: int) -> np.ndarray:
        """``(capacity,) bool``: resident edges present in log snapshot ``t``."""
        mask = np.zeros(self.capacity, bool)
        res = self.valid
        mask[res] = self.view.snapshot_mask(t)[self.slot_edge[res]]
        return mask


# ==========================================================================
# Beyond-paper: UVV source-folding + active-vertex compaction (§Perf A1)
# ==========================================================================
@register_static_dataclass(
    meta_fields=("num_vertices", "num_active", "num_snapshots", "stats")
)
@dataclasses.dataclass(frozen=True)
class FoldedQRS:
    """QRS with UVV *sources* folded out and active vertices renumbered.

    The paper's QRS removes edges whose SINK is a UVV.  We additionally
    observe that an edge whose SOURCE is a UVV contributes a CONSTANT
    relaxation (its source value never changes), so its effect can be
    applied once to a per-snapshot bootstrap and the edge dropped from the
    iteration entirely.  The remaining active↔active subgraph is renumbered
    densely, shrinking the value matrix — and, at pod scale, the
    per-superstep all-gather — from (S, V) to (S, V_active).
    """

    src: jax.Array  # (E'',) int32 — ACTIVE-vertex ids
    dst: jax.Array  # (E'',) int32
    weight: jax.Array
    presence: jax.Array  # (E'', W)
    valid: jax.Array
    bootstrap: jax.Array  # (S, V_active) — R∩ ⊕ folded UVV-source relaxations
    active_ids: jax.Array  # (V_active,) original vertex ids (padding → -1)
    uvv_values: jax.Array  # (V,) — R∩ (exact for UVV vertices)
    uvv: jax.Array  # (V,) bool
    num_vertices: int
    num_active: int
    num_snapshots: int
    stats: tuple

    @property
    def stats_dict(self) -> dict:
        return dict(self.stats)

    def expand(self, values_active: np.ndarray) -> np.ndarray:
        """(S, V_active) → (S, V): scatter active results over UVV constants."""
        s = values_active.shape[0]
        out = np.broadcast_to(np.asarray(self.uvv_values)[None, :],
                              (s, self.num_vertices)).copy()
        ids = np.asarray(self.active_ids)
        real = ids >= 0
        out[:, ids[real]] = np.asarray(values_active)[:, real]
        return out


def fold_qrs(qrs: QRS, sr: Semiring, *, align: int = PAD_ALIGN) -> FoldedQRS:
    """Fold UVV-source edges into a per-snapshot bootstrap; compact the rest."""
    from repro.graph.structures import pack_presence, unpack_presence

    uvv = np.asarray(qrs.uvv)
    boot = np.asarray(qrs.bootstrap)
    valid = np.asarray(qrs.valid)
    src = np.asarray(qrs.src)
    dst = np.asarray(qrs.dst)
    w = np.asarray(qrs.weight)
    pres = np.asarray(qrs.presence)
    s_count = qrs.num_snapshots

    active = ~uvv
    new_id = np.cumsum(active) - 1  # old → new (valid where active)
    v_active = int(active.sum())
    v_pad = max(align, ((v_active + align - 1) // align) * align)

    src_uvv = valid & uvv[src]  # foldable edges (dst is always active in QRS)
    keep = valid & ~uvv[src]

    # ---- fold constant relaxations into a per-snapshot bootstrap
    # (vectorized: one scatter-reduce over flattened (snapshot, dst) keys —
    #  §Perf A2; the per-snapshot python loop was 30× slower)
    boot2 = np.broadcast_to(boot[active][None, :], (s_count, v_active)).copy()
    fi = np.flatnonzero(src_uvv)
    if len(fi):
        cand = np.asarray(sr.extend(jnp.asarray(boot[src[fi]]), jnp.asarray(w[fi])))
        nd = new_id[dst[fi]]
        snaps = np.arange(s_count, dtype=np.uint32)
        words = pres[fi][:, (snaps // 32).astype(np.int64)]  # (Ef, S)
        dense = ((words >> (snaps % 32)[None, :]) & 1).astype(bool)  # (Ef, S)
        e_idx, s_idx = np.nonzero(dense)
        flat = boot2.reshape(-1)
        keys = s_idx * np.int64(v_active) + nd[e_idx]
        if sr.minimize:
            np.minimum.at(flat, keys, cand[e_idx])
        else:
            np.maximum.at(flat, keys, cand[e_idx])
        boot2 = flat.reshape(s_count, v_active)
    boot2 = pad_to_multiple(
        boot2.astype(np.float32), align, np.float32(sr.identity), axis=1
    )[:, :v_pad]

    ki = np.flatnonzero(keep)
    k_src = new_id[src[ki]].astype(np.int32)
    k_dst = new_id[dst[ki]].astype(np.int32)
    order = np.lexsort((k_src, k_dst))
    k_src, k_dst = k_src[order], k_dst[order]
    k_w = w[ki][order]
    k_pres = pres[ki][order]
    k_valid = np.ones(len(ki), bool)

    active_ids = np.full(v_pad, -1, np.int32)
    active_ids[:v_active] = np.flatnonzero(active)

    stats = qrs.stats + (
        ("num_active", v_active),
        ("folded_edges", int(len(fi))),
        ("active_edges", int(len(ki))),
        ("frac_active_vertices", v_active / max(1, qrs.num_vertices)),
        ("frac_active_edges", len(ki) / max(1, int(valid.sum()))),
    )
    return FoldedQRS(
        src=jnp.asarray(pad_to_multiple(k_src, align, 0)),
        dst=jnp.asarray(pad_to_multiple(k_dst, align, 0)),
        weight=jnp.asarray(pad_to_multiple(k_w, align, 0.0)),
        presence=jnp.asarray(pad_to_multiple(k_pres, align, 0, axis=0)),
        valid=jnp.asarray(pad_to_multiple(k_valid, align, False)),
        bootstrap=jnp.asarray(boot2),
        active_ids=jnp.asarray(active_ids),
        uvv_values=jnp.asarray(boot),
        uvv=qrs.uvv,
        num_vertices=qrs.num_vertices,
        num_active=v_pad,
        num_snapshots=s_count,
        stats=stats,
    )

"""User-facing evolving-graph query API (the paper §5 programming interface).

Vertex-centric usage::

    from repro.core import EvolvingQuery
    q = EvolvingQuery(evolving_graph, "sssp", source=0)
    results = q.evaluate(method="cqrs")        # (S, V) values
    q.stats                                     # UVV %, QRS size, timings

Users pick the query (one of the five registered monotone path algorithms, or
a custom :class:`~repro.core.semiring.Semiring`), the source, and the window
of snapshots of interest; the engine handles bounds → UVV → QRS → concurrent
incremental evaluation.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import numpy as np

from repro.core import baselines as _baselines
from repro.core.bounds import compute_bounds
from repro.core.qrs import build_qrs
from repro.core.semiring import Semiring, get_semiring
from repro.graph.structures import EvolvingGraph


class EvolvingQuery:
    """A vertex-specific monotone query over an evolving graph window."""

    def __init__(
        self,
        graph: EvolvingGraph,
        query: Union[str, Semiring],
        source: int,
        snapshots: Optional[Sequence[int]] = None,
    ):
        self.graph = graph
        self.semiring = get_semiring(query) if isinstance(query, str) else query
        self.source = int(source)
        if snapshots is not None:
            # snapshot scheduler: users may pick a sub-window of interest;
            # we narrow the graph's bitmask view accordingly.
            self.graph = _select_snapshots(graph, list(snapshots))
        self.stats: dict = {}
        self._bounds = None
        self._qrs = None

    # -- staged accessors ---------------------------------------------------
    @property
    def bounds(self):
        if self._bounds is None:
            self._bounds = compute_bounds(self.graph, self.semiring, self.source)
        return self._bounds

    @property
    def qrs(self):
        if self._qrs is None:
            b = self.bounds
            self._qrs = build_qrs(self.graph, b.uvv, b.val_cap, self.semiring)
        return self._qrs

    # -- evaluation ----------------------------------------------------------
    def evaluate(self, method: str = "cqrs") -> np.ndarray:
        """Evaluate on every snapshot. ``method`` ∈ full|kickstarter|
        commongraph|qrs|cqrs."""
        fn = _baselines.BASELINES.get(method)
        if fn is None:
            raise KeyError(f"unknown method {method!r}; options: {sorted(_baselines.BASELINES)}")
        results, stats = fn(self.graph, self.semiring, self.source)
        self.stats = stats
        return results


def evaluate_evolving_query(
    graph: EvolvingGraph,
    query: str,
    source: int,
    method: str = "cqrs",
    snapshots: Optional[Sequence[int]] = None,
):
    """One-shot functional wrapper. Returns ``(results (S,V), stats)``."""
    q = EvolvingQuery(graph, query, source, snapshots)
    res = q.evaluate(method)
    return res, q.stats


def _select_snapshots(eg: EvolvingGraph, snaps: list[int]) -> EvolvingGraph:
    """Narrow an evolving graph to a snapshot sub-window (bitmask re-pack)."""
    import jax.numpy as jnp

    from repro.graph.structures import pack_presence

    dense = np.asarray(eg.presence_dense())  # (S, E)
    sub = dense[np.asarray(snaps, int)]
    packed = pack_presence(sub)
    return EvolvingGraph(
        src=eg.src,
        dst=eg.dst,
        weight_min=eg.weight_min,
        weight_max=eg.weight_max,
        presence=jnp.asarray(packed),
        num_vertices=eg.num_vertices,
        num_snapshots=len(snaps),
    )

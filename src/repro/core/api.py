"""User-facing evolving-graph query API (the paper §5 programming interface).

Vertex-centric usage::

    from repro.core import EvolvingQuery
    q = EvolvingQuery(evolving_graph, "sssp", source=0)
    results = q.evaluate(method="cqrs")        # (S, V) values
    q.stats                                     # UVV %, QRS size, timings

Users pick the query (one of the five registered monotone path algorithms, or
a custom :class:`~repro.core.semiring.Semiring`), the source, and the window
of snapshots of interest; the engine handles bounds → UVV → QRS → concurrent
incremental evaluation.

Batched multi-source usage — real workloads issue many vertex-specific
queries over the same snapshot window, so the engine also exposes a Q×S×V
path that amortizes the graph-resident work (bounds launches, QRS
compaction, presence unpacking, gathers) across the whole batch::

    mq = MultiQuery(evolving_graph, "sssp", sources=[0, 7, 42])
    results = mq.evaluate(method="cqrs")       # (Q, S, V) values
    mq.result_for(7)                           # (S, V) slice for one source
    mq.stats                                    # shared-QRS size, per-query UVV %

    # or, from an existing single-source query object:
    q.evaluate_batch(sources=[0, 7, 42])       # (Q, S, V)

Batched results are bit-for-bit identical to Q independent ``evaluate``
calls; ``method="cqrs"`` runs the flat-XLA engine and ``method="cqrs_ell"``
the Pallas vrelax kernel with the query axis folded into the snapshot axis.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import numpy as np

from repro.core import baselines as _baselines
from repro.core.bounds import compute_bounds
from repro.core.qrs import build_qrs
from repro.core.semiring import Semiring, get_semiring
from repro.graph.structures import EvolvingGraph


class EvolvingQuery:
    """A vertex-specific monotone query over an evolving graph window."""

    def __init__(
        self,
        graph: EvolvingGraph,
        query: Union[str, Semiring],
        source: int,
        snapshots: Optional[Sequence[int]] = None,
    ):
        self.graph = graph
        self.semiring = get_semiring(query) if isinstance(query, str) else query
        self.source = int(source)
        if snapshots is not None:
            # snapshot scheduler: users may pick a sub-window of interest;
            # we narrow the graph's bitmask view accordingly.
            self.graph = _select_snapshots(graph, list(snapshots))
        self.stats: dict = {}
        self._bounds = None
        self._qrs = None

    # -- staged accessors ---------------------------------------------------
    @property
    def bounds(self):
        if self._bounds is None:
            self._bounds = compute_bounds(self.graph, self.semiring, self.source)
        return self._bounds

    @property
    def qrs(self):
        if self._qrs is None:
            b = self.bounds
            self._qrs = build_qrs(self.graph, b.uvv, b.val_cap, self.semiring)
        return self._qrs

    # -- evaluation ----------------------------------------------------------
    def evaluate(self, method: str = "cqrs") -> np.ndarray:
        """Evaluate on every snapshot. ``method`` ∈ full|kickstarter|
        commongraph|qrs|cqrs."""
        fn = _baselines.BASELINES.get(method)
        if fn is None:
            raise KeyError(f"unknown method {method!r}; options: {sorted(_baselines.BASELINES)}")
        results, stats = fn(self.graph, self.semiring, self.source)
        self.stats = stats
        return results

    def evaluate_batch(
        self, sources: Sequence[int], method: str = "cqrs"
    ) -> np.ndarray:
        """Evaluate this query from many sources in one batched launch.

        ``method="cqrs"`` / ``"cqrs_ell"`` run the shared-QRS Q×S×V fast
        path; any other registered baseline falls back to a per-source loop
        (useful as a reference).  Returns ``(Q, S, V)`` values; ``self.stats``
        holds the batched run's statistics.
        """
        res, stats = _evaluate_batch(self.graph, self.semiring, sources, method)
        self.stats = stats
        return res


class MultiQuery:
    """A batch of same-semiring queries from Q sources over one graph window.

    The batched façade over the Q×S×V CQRS engine: one vmapped bounds
    launch, one shared QRS, one concurrent fixpoint for the whole batch.
    """

    def __init__(
        self,
        graph: EvolvingGraph,
        query: Union[str, Semiring],
        sources: Sequence[int],
        snapshots: Optional[Sequence[int]] = None,
    ):
        self.graph = graph
        self.semiring = get_semiring(query) if isinstance(query, str) else query
        self.sources = [int(s) for s in sources]
        if not self.sources:
            raise ValueError("MultiQuery needs at least one source")
        if snapshots is not None:
            self.graph = _select_snapshots(graph, list(snapshots))
        self.stats: dict = {}
        self._results: Optional[np.ndarray] = None

    @property
    def num_queries(self) -> int:
        return len(self.sources)

    def evaluate(self, method: str = "cqrs") -> np.ndarray:
        """Evaluate every (source, snapshot) pair. Returns ``(Q, S, V)``."""
        res, stats = _evaluate_batch(self.graph, self.semiring, self.sources, method)
        self.stats = stats
        self._results = res
        return res

    def result_for(self, source: int) -> np.ndarray:
        """``(S, V)`` slice of the last ``evaluate`` for one source."""
        if self._results is None:
            raise RuntimeError("call evaluate() first")
        try:
            return self._results[self.sources.index(int(source))]
        except ValueError:
            raise KeyError(
                f"source {source} not in this batch; sources: {self.sources}"
            ) from None


def _evaluate_batch(graph, sr, sources, method):
    if method in ("cqrs", "cqrs_ell"):
        engine = "ell" if method == "cqrs_ell" else "xla"
        return _baselines.run_cqrs_batch(graph, sr, sources, engine=engine)
    fn = _baselines.BASELINES.get(method)
    if fn is None:
        raise KeyError(
            f"unknown method {method!r}; options: "
            f"{sorted(_baselines.BASELINES) + ['cqrs_ell']}"
        )
    outs, per_stats = [], []
    for s in sources:
        res, stats = fn(graph, sr, int(s))
        outs.append(res)
        per_stats.append(stats)
    stacked = np.stack(outs)
    stats = {
        "method": f"{method}[loop]",
        "sources": tuple(int(s) for s in sources),
        "seconds": float(sum(st.get("seconds", 0.0) for st in per_stats)),
        "supersteps": int(sum(st.get("supersteps", 0) for st in per_stats)),
    }
    return stacked, stats


def evaluate_evolving_query(
    graph: EvolvingGraph,
    query: str,
    source: int,
    method: str = "cqrs",
    snapshots: Optional[Sequence[int]] = None,
):
    """One-shot functional wrapper. Returns ``(results (S,V), stats)``."""
    q = EvolvingQuery(graph, query, source, snapshots)
    res = q.evaluate(method)
    return res, q.stats


def _select_snapshots(eg: EvolvingGraph, snaps: list[int]) -> EvolvingGraph:
    """Narrow an evolving graph to a snapshot sub-window (bitmask re-pack)."""
    import jax.numpy as jnp

    from repro.graph.structures import pack_presence

    dense = np.asarray(eg.presence_dense())  # (S, E)
    sub = dense[np.asarray(snaps, int)]
    packed = pack_presence(sub)
    return EvolvingGraph(
        src=eg.src,
        dst=eg.dst,
        weight_min=eg.weight_min,
        weight_max=eg.weight_max,
        presence=jnp.asarray(packed),
        num_vertices=eg.num_vertices,
        num_snapshots=len(snaps),
    )

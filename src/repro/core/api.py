"""User-facing evolving-graph query API (the paper §5 programming interface).

Vertex-centric usage::

    from repro.core import EvolvingQuery
    q = EvolvingQuery(evolving_graph, "sssp", source=0)
    results = q.evaluate(method="cqrs")        # (S, V) values
    q.stats                                     # UVV %, QRS size, timings

Users pick the query (one of the five registered monotone path algorithms, or
a custom :class:`~repro.core.semiring.Semiring`), the source, and the window
of snapshots of interest; the engine handles bounds → UVV → QRS → concurrent
incremental evaluation.

Batched multi-source usage — real workloads issue many vertex-specific
queries over the same snapshot window, so the engine also exposes a Q×S×V
path that amortizes the graph-resident work (bounds launches, QRS
compaction, presence unpacking, gathers) across the whole batch::

    mq = MultiQuery(evolving_graph, "sssp", sources=[0, 7, 42])
    results = mq.evaluate(method="cqrs")       # (Q, S, V) values
    mq.result_for(7)                           # (S, V) slice for one source
    mq.stats                                    # shared-QRS size, per-query UVV %

    # or, from an existing single-source query object:
    q.evaluate_batch(sources=[0, 7, 42])       # (Q, S, V)

Batched results are bit-for-bit identical to Q independent ``evaluate``
calls; ``method="cqrs"`` runs the flat-XLA engine and ``method="cqrs_ell"``
the Pallas vrelax kernel with the query axis folded into the snapshot axis.

Streaming usage — under continuous traffic the snapshot window *slides*
(new snapshots arrive, old ones retire), and recomputing bounds → UVV → QRS
from scratch per window throws away the paper's key observation that most
vertex values are stable across adjacent windows.  :class:`StreamingQuery`
keeps warm per-(window, query) state and folds each slide in incrementally::

    log = SnapshotLog.from_stream(base, deltas, num_vertices)
    view = WindowView(log, size=64)
    sq = StreamingQuery(view, "sssp", source=0)
    sq.results                                  # prime: full window solve
    results = sq.advance(next_delta)            # (S, V) for the slid window

``advance`` appends the delta, slides the window, refreshes the bounds from
the slide diff (monotone where the graphs grew, witness-tracked trims where
they shrank), patches the compacted QRS from the UVV-mask diff, evaluates
*only the appended snapshot* (rows for surviving snapshots are reused — they
are exact per-snapshot fixpoints, which are unique), and returns results
bit-for-bit identical to a fresh :class:`EvolvingQuery` on the slid window.

Batched streaming — a serving window is typically watched by MANY standing
queries, so :class:`StreamingQueryBatch` folds the query axis into the warm
state itself: ``(Q, V)`` bounds and witness parents, one shared patched QRS
over the union of per-query frontiers, and one batched launch per advance::

    sqb = StreamingQueryBatch(view, "sssp", sources=[0, 7, 42])
    sqb.advance(next_delta)                     # (Q, S, V), one launch
    sqb.result_for(7)                           # (S, V) slice

``QueryBatcher.watch``/``advance_window`` group same-(view, query, method)
watchers into these batches automatically.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.core import baselines as _baselines
from repro.core.bounds import StreamingBounds, compute_bounds
from repro.core.engine import incremental_fixpoint
from repro.core.qrs import PatchableQRS, build_qrs
from repro.core.semiring import Semiring, get_semiring
from repro.ft.faultinject import DeadLetterLog, InjectedFault, fault_point
from repro.graph.structures import EvolvingGraph
from repro.graph.stream import SnapshotLog, WindowView
from repro.obs.stability import record_slide
from repro.obs.trace import mark_ready, span

# Attributes staged by REFERENCE during a transactional advance: shared
# substrate (view/log), immutable config, and mesh handles are never part
# of a slide's mutation set, so copying them would only alias-break the
# sharing contracts (e.g. a QueryBatcher's common WindowView).  The
# observability sinks (events, dead letters) must survive a rollback —
# un-recording a quarantine would hide the fault the rollback answers.
_STAGE_SKIP = frozenset({
    "view", "log", "sr", "semiring", "mesh", "assign",
    "events", "dead_letters",
})


def _copy_leaf(v):
    """Rollback-safe copy of one attribute value.

    Host numpy arrays are the only state mutated in place by the warm
    layers; containers get a fresh spine (depth 1) so element rebinds roll
    back; everything else — ints, jax arrays (immutable), meshes — is safe
    by reference.
    """
    if isinstance(v, np.ndarray):
        return v.copy()
    if isinstance(v, list):
        return [x.copy() if isinstance(x, np.ndarray) else x for x in v]
    if isinstance(v, tuple):
        return tuple(x.copy() if isinstance(x, np.ndarray) else x for x in v)
    if isinstance(v, dict):
        return {
            k: (x.copy() if isinstance(x, np.ndarray) else x)
            for k, x in v.items()
        }
    if isinstance(v, (set, frozenset)):
        return set(v)
    return v


def _snapshot_state(obj, *, _depth: int = 0) -> dict:
    """Copy-snapshot ``obj.__dict__`` for transactional rollback.

    Engine sub-objects (``repro.*`` types: the warm bounds, the patchable
    QRS) are recursed ONE level so their own numpy state is captured;
    deeper derived caches are not snapshotted — rollback re-seeds them
    (:meth:`StreamingQuery._reset_eval_caches`), which is exactly the move
    live resharding already proved bit-for-bit safe.
    """
    snap = {}
    for name, v in obj.__dict__.items():
        if name in _STAGE_SKIP:
            snap[name] = ("ref", v)
        elif (
            _depth == 0
            and hasattr(v, "__dict__")
            and type(v).__module__.startswith("repro.")
        ):
            snap[name] = ("obj", v, _snapshot_state(v, _depth=1))
        else:
            snap[name] = ("val", _copy_leaf(v))
    return snap


def _restore_state(obj, snap: dict) -> None:
    """Put ``obj.__dict__`` back exactly as :func:`_snapshot_state` saw it."""
    for name in list(obj.__dict__):
        if name not in snap:
            del obj.__dict__[name]
    for name, entry in snap.items():
        if entry[0] == "obj":
            _restore_state(entry[1], entry[2])
            obj.__dict__[name] = entry[1]
        else:
            obj.__dict__[name] = entry[1]


class EvolvingQuery:
    """A vertex-specific monotone query over an evolving graph window."""

    def __init__(
        self,
        graph: EvolvingGraph,
        query: Union[str, Semiring],
        source: int,
        snapshots: Optional[Sequence[int]] = None,
    ):
        self.graph = graph
        self.semiring = get_semiring(query) if isinstance(query, str) else query
        self.source = int(source)
        if snapshots is not None:
            # snapshot scheduler: users may pick a sub-window of interest;
            # we narrow the graph's bitmask view accordingly.
            self.graph = _select_snapshots(graph, list(snapshots))
        self.stats: dict = {}
        self._bounds = None
        self._qrs = None

    # -- staged accessors ---------------------------------------------------
    @property
    def bounds(self):
        if self._bounds is None:
            self._bounds = compute_bounds(self.graph, self.semiring, self.source)
        return self._bounds

    @property
    def qrs(self):
        if self._qrs is None:
            b = self.bounds
            self._qrs = build_qrs(self.graph, b.uvv, b.val_cap, self.semiring)
        return self._qrs

    # -- evaluation ----------------------------------------------------------
    def evaluate(self, method: str = "cqrs") -> np.ndarray:
        """Evaluate on every snapshot. ``method`` ∈ full|kickstarter|
        commongraph|qrs|cqrs."""
        fn = _baselines.BASELINES.get(method)
        if fn is None:
            raise KeyError(f"unknown method {method!r}; options: {sorted(_baselines.BASELINES)}")
        results, stats = fn(self.graph, self.semiring, self.source)
        self.stats = stats
        return results

    def evaluate_batch(
        self, sources: Sequence[int], method: str = "cqrs"
    ) -> np.ndarray:
        """Evaluate this query from many sources in one batched launch.

        ``method="cqrs"`` / ``"cqrs_ell"`` run the shared-QRS Q×S×V fast
        path; any other registered baseline falls back to a per-source loop
        (useful as a reference).  Returns ``(Q, S, V)`` values; ``self.stats``
        holds the batched run's statistics.
        """
        res, stats = _evaluate_batch(self.graph, self.semiring, sources, method)
        self.stats = stats
        return res


class MultiQuery:
    """A batch of same-semiring queries from Q sources over one graph window.

    The batched façade over the Q×S×V CQRS engine: one vmapped bounds
    launch, one shared QRS, one concurrent fixpoint for the whole batch.
    """

    def __init__(
        self,
        graph: EvolvingGraph,
        query: Union[str, Semiring],
        sources: Sequence[int],
        snapshots: Optional[Sequence[int]] = None,
    ):
        self.graph = graph
        self.semiring = get_semiring(query) if isinstance(query, str) else query
        self.sources = [int(s) for s in sources]
        if not self.sources:
            raise ValueError("MultiQuery needs at least one source")
        if snapshots is not None:
            self.graph = _select_snapshots(graph, list(snapshots))
        self.stats: dict = {}
        self._results: Optional[np.ndarray] = None

    @property
    def num_queries(self) -> int:
        return len(self.sources)

    def evaluate(self, method: str = "cqrs") -> np.ndarray:
        """Evaluate every (source, snapshot) pair. Returns ``(Q, S, V)``."""
        res, stats = _evaluate_batch(self.graph, self.semiring, self.sources, method)
        self.stats = stats
        self._results = res
        return res

    def result_for(self, source: int) -> np.ndarray:
        """``(S, V)`` slice of the last ``evaluate`` for one source."""
        if self._results is None:
            raise RuntimeError("call evaluate() first")
        try:
            return self._results[self.sources.index(int(source))]
        except ValueError:
            raise KeyError(
                f"source {source} not in this batch; sources: {self.sources}"
            ) from None


def _evaluate_batch(graph, sr, sources, method):
    if method in ("cqrs", "cqrs_ell"):
        engine = "ell" if method == "cqrs_ell" else "xla"
        return _baselines.run_cqrs_batch(graph, sr, sources, engine=engine)
    fn = _baselines.BASELINES.get(method)
    if fn is None:
        raise KeyError(
            f"unknown method {method!r}; options: "
            f"{sorted(_baselines.BASELINES) + ['cqrs_ell']}"
        )
    outs, per_stats = [], []
    for s in sources:
        res, stats = fn(graph, sr, int(s))
        outs.append(res)
        per_stats.append(stats)
    stacked = np.stack(outs)
    stats = {
        "method": f"{method}[loop]",
        "sources": tuple(int(s) for s in sources),
        "seconds": float(sum(st.get("seconds", 0.0) for st in per_stats)),
        "supersteps": int(sum(st.get("supersteps", 0) for st in per_stats)),
    }
    return stacked, stats


class StreamingQuery:
    """A vertex-specific query whose snapshot window slides under it.

    Warm state kept across slides: the intersection/union bound fixpoints and
    their witness parents (:class:`~repro.core.bounds.StreamingBounds`), the
    slot-compacted QRS (:class:`~repro.core.qrs.PatchableQRS`), and the
    per-snapshot result rows of the current window.  Each ``advance()`` then
    costs one bounds refresh from the slide diff plus one single-snapshot
    incremental solve — instead of a full bounds → UVV → QRS → S-snapshot
    evaluation.

    ``method`` picks the appended-snapshot engine: ``"cqrs"`` (flat-XLA edge
    relaxation) or ``"cqrs_ell"`` (Pallas vrelax kernel on the row-split ELL
    layout).  Both are bit-for-bit equal to a fresh :class:`EvolvingQuery`
    on every slid window (monotone fixpoints are unique).

    Several ``StreamingQuery`` instances may share one
    :class:`~repro.graph.stream.WindowView`; each consumes the view's slide
    history at its own pace (see ``QueryBatcher.advance_window`` for the
    serving front-end).

    Passing a dst-range-sharded stream — a
    :class:`~repro.graph.shardlog.ShardedSnapshotLog` or
    :class:`~repro.graph.shardlog.ShardedWindowView` — constructs a
    :class:`~repro.distributed.stream_shard.ShardedStreamingQuery` instead:
    the same ``advance()`` contract (and bit-for-bit identical results),
    with bounds maintenance and per-snapshot evaluation dispatched through
    the ``shard_map`` SPMD path (one all-gather of per-vertex state per
    superstep; every scatter shard-local).
    """

    def __new__(cls, stream=None, *args, **kwargs):
        if cls is StreamingQuery:
            from repro.graph.shardlog import (
                ShardedSnapshotLog, ShardedWindowView,
            )

            if isinstance(stream, (ShardedSnapshotLog, ShardedWindowView)):
                # lazy: stream_shard imports this module
                from repro.distributed.stream_shard import ShardedStreamingQuery

                return super().__new__(ShardedStreamingQuery)
        return super().__new__(cls)

    def __init__(
        self,
        stream: Union[SnapshotLog, WindowView],
        query: Union[str, Semiring],
        source: int,
        *,
        window: Optional[int] = None,
        method: str = "cqrs",
    ):
        owns_view = isinstance(stream, SnapshotLog)
        if owns_view:
            stream = WindowView(stream, size=window)
        elif window is not None and window != stream.size:
            raise ValueError(
                f"window={window} conflicts with the shared view's size "
                f"{stream.size}"
            )
        if method not in ("cqrs", "cqrs_ell"):
            raise ValueError(f"unknown streaming method {method!r}; "
                             "options: cqrs, cqrs_ell")
        self.view = stream
        # a view built here is private to this query: its slide history can
        # be pruned as soon as it is consumed (shared views are pruned by
        # whoever coordinates their consumers, e.g. QueryBatcher)
        self._owns_view = owns_view
        self.semiring = get_semiring(query) if isinstance(query, str) else query
        self.source = int(source)
        self.method = method
        self.stats: dict = {}
        self._bounds: Optional[StreamingBounds] = None
        self._qrs: Optional[PatchableQRS] = None
        self._rows: list[np.ndarray] = []
        self._diff_pos = 0
        self._slides = 0
        self._presence: dict = {}  # num_queries → EllPresenceCache
        # pipelined serving (QueryBatcher) defers the device→host fetch of
        # eval results: advance_nowait() leaves rows as device arrays so the
        # caller's host thread can route/pack the next slide while devices
        # run this one; results/`_materialize_rows` is the sync point
        self._defer_fetch = False
        # poisoned delta batches rejected by log validation land here
        # instead of failing the slide; `events` (an obs EventLog) is set
        # by serving layers that want quarantine/rollback events
        self.dead_letters = DeadLetterLog()
        self.events = None

    # -- staged accessors -----------------------------------------------------
    @property
    def bounds(self):
        """Current window's :class:`~repro.core.bounds.BoundsResult`."""
        self._ensure_primed()
        return self._bounds.result

    @property
    def qrs(self) -> PatchableQRS:
        self._ensure_primed()
        return self._qrs

    @property
    def results(self) -> np.ndarray:
        """``(S, V)`` values for the current window."""
        self._ensure_primed()
        self._materialize_rows()
        return np.stack(self._rows)

    def _materialize_rows(self) -> None:
        """Fetch any deferred device rows to host (pipelined sync point)."""
        if all(isinstance(r, np.ndarray) for r in self._rows):
            return
        with span("fetch"):
            self._rows = [
                r if isinstance(r, np.ndarray) else np.asarray(r)
                for r in self._rows
            ]
        mark_ready("fixpoint")

    @property
    def diff_pos(self) -> int:
        """Absolute slide-history position this query has consumed up to."""
        return self._diff_pos

    def _ensure_primed(self):
        if self._bounds is None:
            self.view.slide_to_tip()
            self._prime()

    # -- evaluation -----------------------------------------------------------
    def advance(self, delta=None) -> np.ndarray:
        """Append ``delta`` (if given), slide to the log tip, return results.

        ``delta`` is a ``(add_src, add_dst, add_w, del_src, del_dst)`` batch
        as produced by :func:`repro.graph.generators.generate_evolving_stream`.
        With ``delta=None`` the query just catches up on slides already
        applied to a shared view/log.  Idempotent when there is nothing new.
        """
        self.advance_nowait(delta)
        return self.results

    def advance_nowait(self, delta=None) -> None:
        """:meth:`advance` without materializing results.

        The pipelined serving path (``QueryBatcher`` with ``pipelined=True``)
        calls this so the eval launches are dispatched but — with
        ``_defer_fetch`` set — not fetched; the device→host sync happens when
        a consumer reads :attr:`results`.  Identical state transitions to
        :meth:`advance` (which is exactly this plus a results fetch).
        """
        with span("delta_route"):
            if delta is not None:
                try:
                    self.view.log.append_snapshot(*delta)
                except (ValueError, KeyError) as exc:
                    # poisoned batch: validation rejected it BEFORE any log
                    # mutation, so quarantining it and sliding on is exact
                    self._quarantine_delta(delta, exc)
                except InjectedFault:
                    # torn cross-shard append: the sharded log self-heals
                    # (the batch is fully committed) before surfacing the
                    # fault, so the slide proceeds over durable state
                    self._note_ingest_fault()
            if self._bounds is None:
                self._ensure_primed()
                return
            t0 = time.perf_counter()
            view = self.view
            view.slide_to_tip()
            try:
                pending = view.diffs_since(self._diff_pos)
            except LookupError:
                # the shared view pruned slides this query never consumed —
                # incremental state can't catch up, rebuild from the window
                self._bounds = None
                self._ensure_primed()
                return
        if len(pending) > 1 and any(d.weights_changed() for d in pending):
            # the view's window extrema already reflect the whole queue, so
            # an intermediate slide cannot be folded in with the weights it
            # saw — its trims would run against post-change parents.  Weight
            # movement mid-queue is rare; rebuild from the final window.
            self._bounds = None
            self._ensure_primed()
            return
        steps = 0
        patch_stats: dict = {}
        weights_dirty = False
        staged = self._stage_slide() if pending else None
        try:
            if pending:
                fault_point("advance_delta_route")
            # each slide folds in against ITS window's masks, not the final
            # window's (rolling_masks reconstructs the intermediate states)
            for diff, (union, inter) in zip(
                pending, view.rolling_masks(pending)
            ):
                with span("bounds_refresh"):
                    fault_point("advance_bounds_refresh")
                    steps += self._bounds.apply_slide(diff, inter, union)
                with span("qrs_patch"):
                    fault_point("advance_qrs_patch")
                    ps = self._qrs.apply_slide(
                        diff, np.asarray(self._bounds.uvv), union_mask=union
                    )
                for key in ("qrs_entered", "qrs_left", "qrs_touched"):
                    patch_stats[key] = patch_stats.get(key, 0) + ps[key]
                patch_stats["qrs_edges"] = ps["qrs_edges"]
                # rows evaluate with the G∩ safe weight, so any movement of
                # that extremum — widening OR narrowing — stales cached rows
                weights_dirty |= any(
                    len(a) for a in
                    diff.cap_weight_transitions(self.semiring.minimize)
                )
                self._slides += 1
            if pending:
                fault_point("advance_eval")
                k = len(pending)
                if weights_dirty or k >= view.size:
                    survivors: list[np.ndarray] = []
                else:
                    survivors = self._rows[k:]
                self._rows = survivors
                start = view.stop - (view.size - len(survivors))
                for t in range(start, view.stop):
                    row, it = self._eval_snapshot(t)
                    steps += it
                    self._rows.append(row)
        except BaseException:
            # transactional slide: restore the pre-slide fixpoint state so
            # the query keeps serving (and can retry the fold) bit-for-bit;
            # _diff_pos is untouched, so a retry replays the same diffs via
            # rolling_masks.  Failures outside a staged fold (catch-up from
            # a cold prime) still poison → re-prime.
            if staged is not None:
                self._rollback_slide(staged)
            else:
                self._bounds = None
            raise
        self._diff_pos = view.history_end
        if self._owns_view:
            view.prune_history(self._diff_pos)
        self._set_stats(
            seconds=time.perf_counter() - t0, supersteps=steps,
            advanced=len(pending), **patch_stats,
        )
        self._publish_metrics()

    # -- transactional slide --------------------------------------------------
    def _stage_slide(self) -> dict:
        """Snapshot every mutable warm structure before folding a slide in.

        The copies cover the bounds arrays (fixpoints, parents, witness/lane
        accounting), the QRS slot tables and free list, the cached result
        rows, and the slide counters — everything ``apply_slide``/eval can
        touch.  Derived device caches (ELL packs, presence planes) are NOT
        staged; rollback re-seeds them instead.
        """
        return _snapshot_state(self)

    def _rollback_slide(self, staged: dict) -> None:
        """Restore the pre-slide fixpoint state captured by `_stage_slide`.

        After the restore the query serves the pre-slide window bit-for-bit
        and — because ``_diff_pos`` rolled back with it — a later advance
        retries the same diffs.  Derived eval caches are re-seeded at their
        sticky capacities so no compiled launch shapes change.
        """
        t0 = time.perf_counter()
        _restore_state(self, staged)
        self._reset_eval_caches()
        from repro.obs.metrics import get_registry

        reg = get_registry()
        reg.counter(
            "advance_rollbacks_total",
            "failed slide advances rolled back to the pre-slide fixpoint",
        ).inc()
        reg.histogram(
            "advance_rollback_seconds", "slide rollback wall time"
        ).observe(time.perf_counter() - t0)
        if self.events is not None:
            self.events.emit(
                "rollback", diff_pos=int(self._diff_pos),
                slides=int(self._slides),
            )

    def _reset_eval_caches(self) -> None:
        """Re-seed derived eval caches after a rollback (sticky shapes kept).

        The presence planes and the ELL pack key on pack epochs that moved
        with the failed fold; rebuilding them from the restored slot tables
        is bit-for-bit safe (row-split min/max reductions are order-exact)
        and is the same move live resharding performs on every migration.
        """
        self._presence = {}
        if self._qrs is None or not hasattr(self._qrs, "_ell_packer"):
            return  # sharded QRS masks keep their packers in _ell_cache
        from repro.graph.ell import StableEllPacker

        old = self._qrs._ell_packer
        fresh = StableEllPacker(
            old.num_vertices, slot_width=old.slot_width,
            row_align=old.row_align,
        )
        fresh.num_rows = old.num_rows  # sticky capacity: no recompiles
        fresh.class_history = list(old.class_history)
        self._qrs._ell_packer = fresh
        self._qrs._ell = None
        self._qrs._ell_version = -1

    def _quarantine_delta(self, delta, exc) -> None:
        """Dead-letter a poisoned delta batch and keep serving.

        Log validation rejects a bad batch BEFORE any mutation, so the tip
        is exactly as if the batch never arrived; the slide proceeds over
        the durable snapshots and a cleaned redelivery converges bit-for-bit.
        """
        snapshot = int(self.view.log.num_snapshots)
        self.dead_letters.record(delta, exc, {"snapshot": snapshot})
        from repro.obs.metrics import get_registry

        get_registry().counter(
            "delta_quarantined_total",
            "delta batches rejected by log validation and dead-lettered",
        ).inc()
        if self.events is not None:
            self.events.emit(
                "quarantine", error=str(exc), snapshot=snapshot,
            )

    def _note_ingest_fault(self) -> None:
        from repro.obs.metrics import get_registry

        get_registry().counter(
            "ingest_faults_total",
            "ingest faults absorbed by the serving path",
        ).inc()
        if self.events is not None:
            self.events.emit(
                "ingest_fault", snapshot=int(self.view.log.num_snapshots),
            )

    def _make_bounds(self):
        """Streaming bounds maintainer (overridden by the sharded subclass)."""
        return StreamingBounds(self.view, self.semiring, self.source)

    def _make_qrs(self):
        """Patchable QRS layer (overridden by the sharded subclass)."""
        return PatchableQRS(
            self.view, np.asarray(self._bounds.uvv), self.semiring
        )

    def _prime(self):
        """Cold start: full bounds + QRS build + one solve per window snapshot."""
        try:
            self._prime_inner()
        except BaseException:
            # a half-built cold start must not masquerade as warm state
            self._bounds = None
            raise

    def _prime_inner(self):
        t0 = time.perf_counter()
        self._bounds = self._make_bounds()
        self._qrs = self._make_qrs()
        steps = self._bounds.supersteps
        self._rows = []
        for t in self.view.snapshots():
            row, it = self._eval_snapshot(t)
            steps += it
            self._rows.append(row)
        self._diff_pos = self.view.history_end
        if self._owns_view:
            self.view.prune_history(self._diff_pos)
        self._set_stats(
            seconds=time.perf_counter() - t0, supersteps=steps, advanced=0,
            qrs_edges=self._qrs.num_edges,
        )
        self._publish_metrics()

    def _eval_snapshot(self, t: int, bounds=None) -> tuple[np.ndarray, int]:
        """Exact values for log snapshot ``t``: warm-start from R∩ over the QRS.

        ``bounds`` overrides the warm bounds supplying the R∩ bootstrap —
        the batched subclasses pass a single new lane's scalar bounds here
        to prime just that lane.
        """
        bounds = self._bounds if bounds is None else bounds
        sr = self.semiring
        v = self.view.log.num_vertices
        mask = self._qrs.snapshot_mask(t)
        if self.method == "cqrs":
            with span("ell_pack"):  # device-array refresh (no ELL re-pack)
                src, dst, w = self._qrs.device_arrays()
            with span("fixpoint"):
                vals, it = incremental_fixpoint(
                    bounds.val_cap, src, dst, w, jnp.asarray(mask), sr, v,
                    sorted_edges=False,
                )
        else:  # cqrs_ell — Pallas vrelax kernel over row-split ELL
            from repro.kernels.vrelax.ops import concurrent_fixpoint_ell

            # full slot capacity at sticky row count: shapes — and therefore
            # the jitted kernel path — are stable across slides; invalid
            # slots carry all-zero presence words and mask out in-kernel
            with span("ell_pack"):
                ell = self._qrs.ell_pack()
                presence_ell = self._presence_plane(ell, mask)
            with span("fixpoint"):
                vals, it = concurrent_fixpoint_ell(
                    bounds.val_cap, ell, presence_ell, sr, v, 1
                )
                vals = vals[0]
        if self._defer_fetch:
            return vals, it
        return np.asarray(vals), int(it)

    # -- warm-start checkpointing ---------------------------------------------
    def checkpoint_state(self) -> tuple[dict, dict]:
        """Serialize this query's serving state for a warm restart.

        Returns ``(tree, extra)`` ready for
        :meth:`repro.checkpoint.CheckpointManager.save`: the window's
        per-snapshot global edge lists plus the warm bound fixpoints and
        cached result rows (see :mod:`repro.checkpoint.streamstate`).
        Requires the window to be at the log tip (always true right after
        ``advance``).
        """
        from repro.checkpoint.streamstate import streaming_state

        return streaming_state(self)

    @staticmethod
    def resume(arrays: dict, extra: dict, **kwargs) -> "StreamingQuery":
        """Rebuild a query from a checkpoint instead of cold-solving.

        ``arrays``/``extra`` come from ``CheckpointManager.load()`` (pass
        ``manifest["extra"]`` as ``extra``).  The restored query's results
        are bit-for-bit equal to the uninterrupted stream's; catch-up is
        plain delta replay — feed the deltas recorded since the checkpoint
        through :meth:`advance`.  Keyword options: ``n_shards`` restores
        elastically onto a different shard count (``0`` = single host),
        ``mesh``/``assignment`` override the sharded layout, ``method``
        switches the appended-snapshot engine.
        """
        from repro.checkpoint.streamstate import resume_streaming

        return resume_streaming(arrays, extra, **kwargs)

    def _presence_plane(self, ell, mask, num_queries=None):
        """Incrementally-maintained presence word plane for ``mask``.

        One :class:`~repro.kernels.vrelax.ops.EllPresenceCache` per Q-fold
        width; the pack epoch keys invalidation — a QRS re-pack moves slots,
        so the plane is rebuilt whenever :meth:`PatchableQRS.ell_pack`
        re-packed (see the freed-slot invariant there).
        """
        from repro.kernels.vrelax.ops import EllPresenceCache

        cache = self._presence.get(num_queries)
        if cache is None:
            cache = self._presence[num_queries] = EllPresenceCache()
        return cache.update(
            self._qrs.ell_epoch, mask, np.asarray(ell.edge_id),
            num_queries=num_queries,
        )

    def _set_stats(self, **kw):
        self.stats = {
            "method": f"stream[{self.method}]",
            "query": self.semiring.name,
            "source": self.source,
            "window": (self.view.start, self.view.stop),
            "slides": self._slides,
            "frac_uvv": float(np.asarray(self._bounds.uvv).mean()),
            "qrs_edges": self._qrs.num_edges,
            **kw,
        }

    def _publish_metrics(self) -> None:
        """Export this advance's stability telemetry (both serving routes:
        ``advance``/``advance_nowait`` call this after ``_set_stats``, so
        the synchronous and pipelined paths share one accounting)."""
        record_slide(self)


class StreamingQueryBatch(StreamingQuery):
    """Q same-semiring sources over ONE sliding window, advanced together.

    The streaming counterpart of :class:`MultiQuery`: warm state carries a
    leading query axis — ``(Q, V)`` bound fixpoints with ``(Q, V)`` witness
    parents (:class:`~repro.core.bounds.StreamingBounds` in batched mode)
    and a SHARED patched QRS over the union of the per-query non-UVV
    frontiers (:class:`~repro.core.qrs.PatchableQRS` with a folded ``(Q,V)``
    mask) — so each ``advance()`` folds the slide into every watcher with
    ONE vmapped launch per maintenance pass and evaluates the appended
    snapshot for all Q queries in one
    :func:`~repro.core.concurrent.concurrent_fixpoint_batch` (``cqrs``) or
    one Pallas vrelax launch with Q folded into the kernel's snapshot axis
    (``cqrs_ell``).  Results are **bit-for-bit** identical to Q independent
    :class:`StreamingQuery` instances advanced in a loop: vmapped
    ``while_loop`` lanes freeze once their own convergence holds, and the
    extra supersteps the joint kernel loop runs for early-converged queries
    are idempotent monotone relaxations.

    ``add_source``/``remove_source`` change the query set between advances
    (the serving membership operations behind
    ``QueryBatcher.watch``/eviction): adding a lane primes only that lane;
    existing lanes keep their warm state.

    **Q-class compile amortization** — every jitted launch's shapes are
    keyed by the lane count, so serving membership churn would recompile
    per distinct Q.  The lane axis is therefore padded to a sticky
    power-of-two **capacity class** (the same amortized-capacity trick the
    substrate uses for edges and ELL rows): dead lanes duplicate lane 0 —
    idempotent monotone work, sliced off at the API boundary — and
    membership changes mutate lanes in place
    (:meth:`~repro.core.bounds.StreamingBounds.set_lane` /
    ``drop_lane_padded``), so under rotating traffic the engine compiles
    O(log Q_max) times instead of once per distinct Q.

    **Per-lane convergence accounting** — batched maintenance records each
    lane's own freeze step (the superstep at which the vmapped/joint
    ``while_loop`` stopped changing that lane) instead of only the lockstep
    max; :attr:`lane_supersteps` maps each source to its accumulated count
    so serving can spot pathological watchers
    (``QueryBatcher.cache_info().lane_supersteps``).

    Passing a dst-range-sharded stream constructs a
    :class:`~repro.distributed.stream_shard.ShardedStreamingQueryBatch`:
    the same Q-fold under ``shard_map``, with one all-gather of the
    ``(Q, V)`` vertex state per superstep.
    """

    def __new__(cls, stream=None, *args, **kwargs):
        if cls is StreamingQueryBatch:
            from repro.graph.shardlog import (
                ShardedSnapshotLog, ShardedWindowView,
            )

            if isinstance(stream, (ShardedSnapshotLog, ShardedWindowView)):
                from repro.distributed.stream_shard import (
                    ShardedStreamingQueryBatch,
                )

                return super().__new__(ShardedStreamingQueryBatch)
        return super().__new__(cls)

    def __init__(
        self,
        stream: Union[SnapshotLog, WindowView],
        query: Union[str, Semiring],
        sources: Sequence[int],
        *,
        window: Optional[int] = None,
        method: str = "cqrs",
    ):
        srcs = [int(s) for s in sources]
        if not srcs:
            raise ValueError("StreamingQueryBatch needs at least one source")
        if len(set(srcs)) != len(srcs):
            raise ValueError(f"duplicate sources in batch: {srcs}")
        self.sources = srcs
        self._q_cap = _q_class(len(srcs))  # sticky lane-capacity class
        super().__init__(stream, query, srcs[0], window=window, method=method)

    @property
    def num_queries(self) -> int:
        return len(self.sources)

    @property
    def lane_capacity(self) -> int:
        """Padded lane count every launch compiles for (sticky class)."""
        return self._q_cap

    def _lane_sources(self) -> list:
        """Real sources padded to the capacity class with lane-0 duplicates."""
        return self.sources + [self.sources[0]] * (
            self._q_cap - len(self.sources)
        )

    @property
    def lane_supersteps(self) -> dict:
        """Accumulated per-lane maintenance supersteps, ``{source: steps}``.

        Each lane reports its own freeze steps (the superstep at which a
        batched maintenance pass stopped changing it), so a watcher whose
        count runs far ahead of its peers is flagging pathological churn
        around its source — the serving signal
        ``QueryBatcher.cache_info()`` surfaces.
        """
        if self._bounds is None:  # unprimed: no maintenance has run
            return {s: 0 for s in self.sources}
        ls = self._bounds.lane_supersteps
        return {s: int(ls[i]) for i, s in enumerate(self.sources)}

    # -- batched substitutions ------------------------------------------------
    def _make_bounds(self):
        return StreamingBounds(self.view, self.semiring, self._lane_sources())

    def _lane_bounds(self, source: int):
        """Scalar bounds solve for one NEW lane (overridden by the sharded
        subclass); the cold cost a standalone watcher would pay anyway."""
        return StreamingBounds(self.view, self.semiring, source)

    def _eval_snapshot(self, t: int) -> tuple[np.ndarray, int]:
        """Exact ``(Q, V)`` values for log snapshot ``t`` in ONE launch."""
        sr = self.semiring
        v = self.view.log.num_vertices
        mask = self._qrs.snapshot_mask(t)
        if self.method == "cqrs":
            from repro.core.concurrent import concurrent_fixpoint_batch

            with span("ell_pack"):  # device-array refresh of the QRS edges
                src, dst, w = self._qrs.device_arrays()
                presence = jnp.asarray(mask.astype(np.uint32).reshape(-1, 1))
            with span("fixpoint"):
                vals, it = concurrent_fixpoint_batch(
                    self._bounds.val_cap, src, dst, w, presence,
                    jnp.asarray(mask), sr, v, 1, sorted_edges=False,
                )
                vals = vals[:, 0]
        else:  # cqrs_ell: Q folded into the kernel's snapshot axis
            from repro.kernels.vrelax.ops import concurrent_fixpoint_ell_batch

            with span("ell_pack"):
                ell = self._qrs.ell_pack()
                q = self._q_cap  # padded lane count (sticky compile class)
                presence_ell = self._presence_plane(ell, mask, num_queries=q)
            with span("fixpoint"):
                vals, it = concurrent_fixpoint_ell_batch(
                    self._bounds.val_cap, ell, presence_ell, sr, v, 1, q
                )
                vals = vals[:, 0]
        if self._defer_fetch:
            return vals, it
        return np.asarray(vals), int(it)

    # -- results --------------------------------------------------------------
    @property
    def results(self) -> np.ndarray:
        """``(Q, S, V)`` values for the current window (dead lanes sliced)."""
        self._ensure_primed()
        self._materialize_rows()
        return np.stack(self._rows, axis=1)[: len(self.sources)]

    def result_for(self, source: int) -> np.ndarray:
        """``(S, V)`` slice of the current window for one source."""
        try:
            i = self.sources.index(int(source))
        except ValueError:
            raise KeyError(
                f"source {source} not in this batch; sources: {self.sources}"
            ) from None
        return self.results[i]

    # -- serving membership ---------------------------------------------------
    def add_source(self, source: int) -> None:
        """Add one query lane; primes ONLY the new lane (warm lanes kept).

        The lane's bounds are solved on the current window (the same cold
        cost a standalone watcher would pay) and written into the first
        dead (padding) lane of the ``(Q_cap, V)`` state — shapes, and
        therefore compiled launches, are untouched while the batch stays
        within its capacity class; crossing the class doubles it (sticky).
        The shared QRS keep rule is refreshed — it can only loosen, so
        resident edges keep their slots.  Only the NEW lane's rows are
        evaluated; surviving lanes' cached rows are exact per-snapshot
        fixpoints independent of the keep superset and are reused as-is.
        """
        s = int(source)
        if s in self.sources:
            return
        if self._bounds is None:
            self.sources.append(s)
            self._q_cap = max(self._q_cap, _q_class(len(self.sources)))
            return
        self.advance()  # the lane joins at the log tip's window
        lane = self._lane_bounds(s)
        q = len(self.sources)
        if q == self._q_cap:  # class crossing: double the lane capacity
            self._q_cap *= 2
            self._bounds.pad_lanes(self._q_cap)
            self._rows = [
                np.concatenate(
                    [r, np.broadcast_to(r[0:1], (self._q_cap - q,)
                                        + r.shape[1:])]
                ) for r in self._rows
            ]
        self._bounds.set_lane(q, lane)
        self.sources.append(s)
        self._qrs.refresh(np.asarray(self._bounds.uvv))
        for i, t in enumerate(self.view.snapshots()):
            row, _ = self._eval_lane_snapshot(t, lane)
            r = self._rows[i]
            if not r.flags.writeable:  # np.asarray of a device array
                r = r.copy()
                self._rows[i] = r
            r[q] = row

    def remove_source(self, source: int) -> None:
        """Drop one query lane (no-op if absent; the last lane must stay).

        Pure state surgery at frozen shapes: real lanes after the dropped
        one shift down a slot and the freed tail slot re-duplicates lane 0
        (:meth:`~repro.core.bounds.StreamingBounds.drop_lane_padded`); the
        shared QRS keep rule is re-seated; no re-evaluation (the remaining
        lanes' rows are exact regardless of the keep superset).
        """
        s = int(source)
        if s not in self.sources:
            return
        if len(self.sources) == 1:
            raise ValueError("cannot remove the last source of a batch")
        i = self.sources.index(s)
        q = len(self.sources)
        self.sources.remove(s)
        if self._bounds is None:
            return
        self._bounds.drop_lane_padded(i, q)
        self._qrs.refresh(np.asarray(self._bounds.uvv))
        from repro.core.bounds import _drop_lane_order

        order = _drop_lane_order(i, q, self._q_cap)
        self._materialize_rows()
        self._rows = [row[order] for row in self._rows]

    def _eval_lane_snapshot(self, t: int, lane) -> tuple[np.ndarray, int]:
        """Scalar-engine eval of snapshot ``t`` for ONE new lane's bounds."""
        return StreamingQuery._eval_snapshot(self, t, bounds=lane)

    def _set_stats(self, **kw):
        self.stats = {
            "method": f"stream[{self.method}]",
            "query": self.semiring.name,
            "sources": tuple(self.sources),
            "num_queries": len(self.sources),
            "lane_capacity": self._q_cap,
            "window": (self.view.start, self.view.stop),
            "slides": self._slides,
            "frac_uvv": float(
                np.asarray(self._bounds.uvv)[: len(self.sources)].mean()
            ),
            "qrs_edges": self._qrs.num_edges,
            **kw,
        }


def _q_class(q: int) -> int:
    """Smallest power-of-two lane capacity ≥ ``q`` (sticky compile classes)."""
    cap = 1
    while cap < q:
        cap *= 2
    return cap


def evaluate_evolving_query(
    graph: EvolvingGraph,
    query: str,
    source: int,
    method: str = "cqrs",
    snapshots: Optional[Sequence[int]] = None,
):
    """One-shot functional wrapper. Returns ``(results (S,V), stats)``."""
    q = EvolvingQuery(graph, query, source, snapshots)
    res = q.evaluate(method)
    return res, q.stats


def _select_snapshots(eg: EvolvingGraph, snaps: list[int]) -> EvolvingGraph:
    """Narrow an evolving graph to a snapshot sub-window (bitmask re-pack)."""
    import jax.numpy as jnp

    from repro.graph.structures import pack_presence

    dense = np.asarray(eg.presence_dense())  # (S, E)
    sub = dense[np.asarray(snaps, int)]
    packed = pack_presence(sub)
    return EvolvingGraph(
        src=eg.src,
        dst=eg.dst,
        weight_min=eg.weight_min,
        weight_max=eg.weight_max,
        presence=jnp.asarray(packed),
        num_vertices=eg.num_vertices,
        num_snapshots=len(snaps),
    )

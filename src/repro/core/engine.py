"""Fixpoint relaxation engine for path-semiring vertex queries.

TPU-native formulation of the paper's pull/push traversal: each *superstep*
relaxes every (valid) edge at once —

    cand[e]  = extend(values[src[e]], w[e])        # gather + edge function
    upd[v]   = segment_reduce_{e: dst[e]=v} cand   # scatter-combine (CASMIN/…)
    values'  = improve(values, upd)

— iterated in a ``lax.while_loop`` until no value changes.  Dense supersteps
replace RisGraph's sparse frontiers (DESIGN.md §8.1); the QRS reduction (the
paper's contribution) is what keeps the edge set small enough for this to be
work-efficient.

All functions are jit-compiled with the semiring closed over statically, so
each (semiring, shape) pair compiles exactly once per process.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.semiring import Semiring

# compute_parents sentinel: the vertex holds a non-identity value but no
# acyclic achieving chain to the source was found — every trim must reset it
PARENT_FRAGILE = -2


@functools.partial(
    jax.jit, static_argnames=("sr", "num_vertices", "max_iters", "sorted_edges")
)
def compute_fixpoint(
    src: jax.Array,
    dst: jax.Array,
    weight: jax.Array,
    valid: jax.Array,
    sr: Semiring,
    source: jax.Array,
    num_vertices: int,
    max_iters: Optional[int] = None,
    sorted_edges: bool = True,
):
    """Solve the query from scratch.  Returns ``(values (V,), iters)``.

    ``sorted_edges`` asserts the edge arrays are dst-sorted (the canonical
    :class:`EvolvingGraph`/QRS layout); the streaming substrate keeps its
    universe in append order and passes ``False``.
    """
    values0 = jnp.full((num_vertices,), sr.identity, jnp.float32)
    values0 = values0.at[source].set(jnp.float32(sr.source))
    return _fixpoint(
        values0, src, dst, weight, valid, sr, num_vertices, max_iters, sorted_edges
    )


@functools.partial(
    jax.jit, static_argnames=("sr", "num_vertices", "max_iters", "sorted_edges")
)
def incremental_fixpoint(
    values0: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    weight: jax.Array,
    valid: jax.Array,
    sr: Semiring,
    num_vertices: int,
    max_iters: Optional[int] = None,
    sorted_edges: bool = True,
):
    """Monotone incremental relaxation from ``values0`` (addition-only).

    Correct whenever ``values0`` is *conservative* (no vertex is past its
    exact value, i.e. pointwise no better than the true fixpoint) with the
    source pinned — the CommonGraph/QRS/KickStarter bootstrap states and the
    streaming trim states all satisfy this.
    """
    return _fixpoint(
        values0, src, dst, weight, valid, sr, num_vertices, max_iters, sorted_edges
    )


def _fixpoint(values0, src, dst, weight, valid, sr, num_vertices, max_iters,
              sorted_edges=True):
    limit = num_vertices + 1 if max_iters is None else max_iters
    identity = jnp.float32(sr.identity)

    def relax(values):
        cand = sr.extend(values[src], weight)
        cand = jnp.where(valid, cand, identity)
        upd = sr.segment_reduce(
            cand, dst, num_vertices, indices_are_sorted=sorted_edges
        )
        return sr.improve(values, upd)

    def cond(state):
        _, changed, it = state
        return changed & (it < limit)

    def body(state):
        values, _, it = state
        new = relax(values)
        changed = jnp.any(new != values)
        return new, changed, it + 1

    values, _, iters = jax.lax.while_loop(
        cond, body, (values0, jnp.bool_(True), jnp.int32(0))
    )
    return values, iters


@functools.partial(jax.jit, static_argnames=("sr", "num_vertices", "sorted_edges"))
def compute_parents(
    values: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    weight: jax.Array,
    valid: jax.Array,
    sr: Semiring,
    source: jax.Array,
    num_vertices: int,
    sorted_edges: bool = True,
) -> jax.Array:
    """Per-vertex parent edge id achieving the converged value.

    Returns ``(V,) int32``: an edge id, ``-1`` for vertices with no
    dependence (the source and identity-valued vertices), or
    :data:`PARENT_FRAGILE` for vertices whose value has no acyclic witness.

    The parent edge is the dependence the KickStarter baseline (and the
    streaming bounds maintenance) trims on deletion: a vertex value is
    trusted only while its parent chain survives.  That argument is only
    sound if parent chains are acyclic, and with a non-strict ``extend``
    (sswp/ssnp clamp at the bottleneck, viterbi at w=1) an equal-value
    cycle can have *every* cycle edge achieving — picking an arbitrary
    achieving edge would let cycle vertices record each other as parents,
    so deleting their real support edge invalidates nothing and a stale
    value survives monotone re-relaxation.  Parents are therefore drawn
    from the shortest achieving-path forest: a BFS over achieving edges
    levels every vertex (source = 0) and only level-(L-1) → level-L edges
    qualify, so chains strictly descend in level and terminate at the
    source.  At a true fixpoint every non-identity vertex lies on an
    achieving path from the source (the optimal path is one), hence gets a
    finite level; any vertex the BFS cannot reach is defensively marked
    :data:`PARENT_FRAGILE` so :func:`invalidate_from_deletions` always
    resets it (conservative, and monotone re-relaxation recovers it).
    """
    num_edges = src.shape[0]
    cand = sr.extend(values[src], weight)
    achieving = valid & (cand == values[dst]) & (values[dst] != jnp.float32(sr.identity))

    unreached = jnp.int32(num_vertices + 1)
    level0 = jnp.full((num_vertices,), unreached, jnp.int32).at[source].set(0)

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        level, _ = state
        cand_lvl = jnp.where(
            achieving & (level[src] < unreached), level[src] + 1, unreached
        )
        upd = jax.ops.segment_min(
            cand_lvl, dst, num_vertices, indices_are_sorted=sorted_edges
        )
        new = jnp.minimum(level, upd)
        return new, jnp.any(new != level)

    level, _ = jax.lax.while_loop(cond, body, (level0, jnp.bool_(True)))

    on_forest = achieving & (level[src] + 1 == level[dst])
    eid = jnp.where(on_forest, jnp.arange(num_edges, dtype=jnp.int32), num_edges)
    parent = jax.ops.segment_min(
        eid, dst, num_vertices, indices_are_sorted=sorted_edges
    )
    # empty segments fill with INT32_MAX; the explicit sentinel is num_edges
    parent = jnp.where(parent >= num_edges, -1, parent)
    fragile = (values != jnp.float32(sr.identity)) & (level == unreached)
    parent = jnp.where(fragile, jnp.int32(PARENT_FRAGILE), parent)
    # the source never depends on an edge
    return parent.at[source].set(-1)


@functools.partial(jax.jit, static_argnames=("sr", "num_vertices"))
def invalidate_from_deletions(
    values: jax.Array,
    parent: jax.Array,
    deleted: jax.Array,
    src: jax.Array,
    sr: Semiring,
    source: jax.Array,
    num_vertices: int,
):
    """KickStarter-style trim: reset every vertex whose parent chain broke.

    ``deleted`` is an ``(E,) bool`` mask over the edge universe.  A vertex is
    invalid if its parent edge was deleted, if it was marked
    :data:`PARENT_FRAGILE` (no acyclic witness — trust nothing), or
    (transitively) if its parent edge's source became invalid.  Returns
    ``(values', invalid)``.
    """
    has_parent = parent >= 0
    pidx = jnp.maximum(parent, 0)
    invalid0 = (has_parent & deleted[pidx]) | (parent == PARENT_FRAGILE)
    parent_src = src[pidx]

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        invalid, _ = state
        nxt = invalid | (has_parent & invalid[parent_src])
        return nxt, jnp.any(nxt != invalid)

    invalid, _ = jax.lax.while_loop(cond, body, (invalid0, jnp.bool_(True)))
    new_values = jnp.where(invalid, jnp.float32(sr.identity), values)
    new_values = new_values.at[source].set(jnp.float32(sr.source))
    return new_values, invalid

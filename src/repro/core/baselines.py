"""The paper's comparison points, implemented in the same engine substrate.

* ``run_full``        — Fig. 2(a): from-scratch solve per snapshot.
* ``run_kickstarter`` — Fig. 2(b): incremental chain with deletion trimming
                        (KickStarter-style parent invalidation; DESIGN.md §8.2).
* ``run_commongraph`` — Fig. 2(c): solve on G∩ once, stream per-snapshot
                        additions (direct-hop).
* ``run_qrs``         — paper §3: bounds → UVV → QRS, sequential per-snapshot
                        incremental over the reduced graph.
* ``run_cqrs``        — paper §4: QRS + concurrent all-snapshot evaluation.

Every entry returns ``(results (S, V) np.ndarray, stats dict)``; agreement of
all five is the core correctness property tested in ``tests/``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bounds import compute_bounds
from repro.core.concurrent import concurrent_fixpoint
from repro.core.engine import (
    compute_fixpoint,
    compute_parents,
    incremental_fixpoint,
    invalidate_from_deletions,
)
from repro.core.qrs import build_qrs
from repro.core.semiring import Semiring
from repro.graph.structures import EvolvingGraph


def _weights_for(eg: EvolvingGraph, sr: Semiring) -> jax.Array:
    # Per-snapshot exact evaluation needs one weight per edge; the stream
    # generator keeps weights stable per (src,dst) so min==max.  (The bound
    # machinery still handles min!=max; see Semiring.*_weight.)
    return sr.intersection_weight(eg.weight_min, eg.weight_max)


def run_full(eg: EvolvingGraph, sr: Semiring, source: int):
    """Naive baseline: independent from-scratch solve per snapshot."""
    w = _weights_for(eg, sr)
    t0 = time.perf_counter()
    outs, iters = [], 0
    for i in range(eg.num_snapshots):
        vals, it = compute_fixpoint(
            eg.src, eg.dst, w, eg.snapshot_valid(i), sr, jnp.int32(source), eg.num_vertices
        )
        outs.append(vals)
        iters += int(it)
    res = np.stack([np.asarray(v) for v in outs])
    return res, {"method": "full", "seconds": time.perf_counter() - t0, "supersteps": iters}


def run_kickstarter(eg: EvolvingGraph, sr: Semiring, source: int):
    """Streaming incremental chain with KickStarter-style deletion trimming."""
    w = _weights_for(eg, sr)
    source_j = jnp.int32(source)
    t0 = time.perf_counter()

    valid = eg.snapshot_valid(0)
    values, iters0 = compute_fixpoint(
        eg.src, eg.dst, w, valid, sr, source_j, eg.num_vertices
    )
    parent = compute_parents(values, eg.src, eg.dst, w, valid, sr, source_j, eg.num_vertices)
    outs = [values]
    supersteps = int(iters0)
    for i in range(1, eg.num_snapshots):
        valid_new = eg.snapshot_valid(i)
        deleted = valid & ~valid_new
        # trim: reset every vertex whose dependence chain used a deleted edge
        values, _invalid = invalidate_from_deletions(
            values, parent, deleted, eg.src, sr, source_j, eg.num_vertices
        )
        # re-relax over the new snapshot (covers additions + re-derivations)
        values, it = incremental_fixpoint(
            values, eg.src, eg.dst, w, valid_new, sr, eg.num_vertices
        )
        parent = compute_parents(
            values, eg.src, eg.dst, w, valid_new, sr, source_j, eg.num_vertices
        )
        outs.append(values)
        supersteps += int(it)
        valid = valid_new
    res = np.stack([np.asarray(v) for v in outs])
    return res, {
        "method": "kickstarter",
        "seconds": time.perf_counter() - t0,
        "supersteps": supersteps,
    }


def run_commongraph(eg: EvolvingGraph, sr: Semiring, source: int):
    """CommonGraph direct-hop: solve G∩ once, stream additions per snapshot."""
    w = _weights_for(eg, sr)
    t0 = time.perf_counter()
    val_cap, it0 = compute_fixpoint(
        eg.src, eg.dst, w, eg.intersection_valid(), sr, jnp.int32(source), eg.num_vertices
    )
    outs, supersteps = [], int(it0)
    for i in range(eg.num_snapshots):
        vals, it = incremental_fixpoint(
            val_cap, eg.src, eg.dst, w, eg.snapshot_valid(i), sr, eg.num_vertices
        )
        outs.append(vals)
        supersteps += int(it)
    res = np.stack([np.asarray(v) for v in outs])
    return res, {
        "method": "commongraph",
        "seconds": time.perf_counter() - t0,
        "supersteps": supersteps,
    }


def _prepare_qrs(eg: EvolvingGraph, sr: Semiring, source: int):
    bounds = compute_bounds(eg, sr, source)
    jax.block_until_ready(bounds.uvv)
    qrs = build_qrs(eg, bounds.uvv, bounds.val_cap, sr)
    return bounds, qrs


def run_qrs(eg: EvolvingGraph, sr: Semiring, source: int):
    """Paper §3: QRS generation + sequential per-snapshot incremental."""
    t0 = time.perf_counter()
    bounds, qrs = _prepare_qrs(eg, sr, source)
    t_gen = time.perf_counter() - t0
    outs, supersteps = [], int(bounds.iters_cap) + int(bounds.iters_cup)
    for i in range(eg.num_snapshots):
        vals, it = incremental_fixpoint(
            qrs.bootstrap, qrs.src, qrs.dst, qrs.weight, qrs.snapshot_valid(i),
            sr, eg.num_vertices,
        )
        outs.append(vals)
        supersteps += int(it)
    res = np.stack([np.asarray(v) for v in outs])
    stats = {
        "method": "qrs",
        "seconds": time.perf_counter() - t0,
        "qrs_generation_seconds": t_gen,
        "supersteps": supersteps,
    }
    stats.update(qrs.stats_dict)
    return res, stats


def run_cqrs(eg: EvolvingGraph, sr: Semiring, source: int):
    """Paper §4: QRS + concurrent all-snapshot evaluation (the full system)."""
    t0 = time.perf_counter()
    bounds, qrs = _prepare_qrs(eg, sr, source)
    t_gen = time.perf_counter() - t0
    values, it = concurrent_fixpoint(
        qrs.bootstrap, qrs.src, qrs.dst, qrs.weight, qrs.presence, qrs.valid,
        sr, eg.num_vertices, eg.num_snapshots,
    )
    res = np.asarray(jax.block_until_ready(values))
    stats = {
        "method": "cqrs",
        "seconds": time.perf_counter() - t0,
        "qrs_generation_seconds": t_gen,
        "supersteps": int(bounds.iters_cap) + int(bounds.iters_cup) + int(it),
    }
    stats.update(qrs.stats_dict)
    return res, stats


def run_cqrs_folded(eg: EvolvingGraph, sr: Semiring, source: int):
    """Beyond-paper (§Perf A1): CQRS with UVV *source* folding — edges from
    UVV vertices contribute constants, applied once; the iteration runs on
    the compacted active↔active subgraph with a (S, V_active) state."""
    from repro.core.qrs import fold_qrs

    t0 = time.perf_counter()
    bounds, qrs = _prepare_qrs(eg, sr, source)
    folded = fold_qrs(qrs, sr)
    t_gen = time.perf_counter() - t0
    values, it = concurrent_fixpoint(
        folded.bootstrap, folded.src, folded.dst, folded.weight,
        folded.presence, folded.valid, sr, folded.num_active, eg.num_snapshots,
    )
    res = folded.expand(np.asarray(jax.block_until_ready(values)))
    stats = {
        "method": "cqrs_folded",
        "seconds": time.perf_counter() - t0,
        "qrs_generation_seconds": t_gen,
        "supersteps": int(bounds.iters_cap) + int(bounds.iters_cup) + int(it),
    }
    stats.update(folded.stats_dict)
    return res, stats


def run_cqrs_batch(eg: EvolvingGraph, sr: Semiring, sources, *, engine: str = "xla"):
    """Batched multi-source CQRS: Q queries through one shared pipeline.

    One vmapped bounds launch → one shared-QRS compaction → one (Q, S, V)
    concurrent fixpoint.  ``engine`` picks the hot path: ``"xla"`` (flat-edge
    ``concurrent_fixpoint_batch``) or ``"ell"`` (Pallas vrelax kernel with the
    query axis folded into the snapshot axis).  Returns
    ``(results (Q, S, V) np.ndarray, stats dict)``; results match Q
    independent single-source runs bit-for-bit.
    """
    from repro.core.bounds import compute_bounds_batch
    from repro.core.concurrent import concurrent_fixpoint_batch

    sources = [int(s) for s in sources]
    t0 = time.perf_counter()
    bounds = compute_bounds_batch(eg, sr, sources)
    jax.block_until_ready(bounds.uvv)
    sq = build_qrs(eg, bounds.uvv, bounds.val_cap, sr)
    t_gen = time.perf_counter() - t0

    if engine == "xla":
        values, it = concurrent_fixpoint_batch(
            sq.bootstrap, sq.src, sq.dst, sq.weight, sq.presence, sq.valid,
            sr, eg.num_vertices, eg.num_snapshots,
        )
    elif engine == "ell":
        from repro.graph.ell import pack_ell
        from repro.kernels.vrelax.ops import (
            build_presence_ell,
            concurrent_fixpoint_ell_batch,
            tile_presence_words,
        )

        vi = np.flatnonzero(np.asarray(sq.valid))
        ell = pack_ell(
            np.asarray(sq.src)[vi], np.asarray(sq.dst)[vi],
            np.asarray(sq.weight)[vi], eg.num_vertices,
        )
        tiled = tile_presence_words(
            np.asarray(sq.presence)[vi], eg.num_snapshots, len(sources)
        )
        presence_ell = build_presence_ell(tiled, ell)
        values, it = concurrent_fixpoint_ell_batch(
            sq.bootstrap, ell, presence_ell, sr, eg.num_vertices,
            eg.num_snapshots, len(sources),
        )
    else:
        raise ValueError(f"unknown engine {engine!r}; options: xla, ell")

    res = np.asarray(jax.block_until_ready(values))
    stats = {
        "method": f"cqrs_batch[{engine}]",
        "engine": engine,
        "sources": tuple(sources),
        "seconds": time.perf_counter() - t0,
        "qrs_generation_seconds": t_gen,
        "supersteps": int(bounds.iters_cap.max())
        + int(bounds.iters_cup.max())
        + int(it),
    }
    stats.update(sq.stats_dict)
    return res, stats


BASELINES = {
    "full": run_full,
    "kickstarter": run_kickstarter,
    "commongraph": run_commongraph,
    "qrs": run_qrs,
    "cqrs": run_cqrs,
    "cqrs_folded": run_cqrs_folded,
}

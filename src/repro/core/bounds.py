"""Intersection–union bound analysis and UVV detection (paper §3 Steps 1–2).

``compute_bounds`` solves the query on G∩ and G∪; per Theorem 1 this brackets
every snapshot's value.  Per the paper's own optimization (§6.2) the G∪ solve
is *incremental* from the G∩ result: going from G∩ to G∪ only adds edges, so
monotone relaxation from ``R∩`` converges to ``R∪`` without a second
from-scratch solve.

Bound direction is per-semiring (paper Table 1): CASMIN queries (BFS/SSSP/
SSNP) have ``R∪ ≤ Val_i ≤ R∩``; CASMAX queries (SSWP/Viterbi) the reverse.
Flip-flopping edges take their safe weight per direction (DESIGN.md §8.5).

Theorem 2 (UVV): where the two bounds agree — including at ``identity`` for
vertices unreachable in both — the value is constant across all snapshots.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (
    compute_fixpoint,
    compute_parents,
    incremental_fixpoint,
    invalidate_from_deletions,
)
from repro.core.semiring import Semiring
from repro.graph.structures import EvolvingGraph


@dataclasses.dataclass(frozen=True)
class BoundsResult:
    """Outputs of the intersection-union analysis."""

    val_cap: jax.Array  # R∩ — query result on the intersection graph (V,)
    val_cup: jax.Array  # R∪ — query result on the union graph (V,)
    lower: jax.Array  # per-vertex lower bound over all snapshots (V,)
    upper: jax.Array  # per-vertex upper bound over all snapshots (V,)
    uvv: jax.Array  # (V,) bool — bounds coincide (Theorem 2)
    iters_cap: jax.Array
    iters_cup: jax.Array


def compute_bounds(eg: EvolvingGraph, sr: Semiring, source: int) -> BoundsResult:
    valid_cap = eg.intersection_valid()
    valid_cup = eg.union_valid()
    w_cap = sr.intersection_weight(eg.weight_min, eg.weight_max)
    w_cup = sr.union_weight(eg.weight_min, eg.weight_max)
    source = jnp.int32(source)

    val_cap, iters_cap = compute_fixpoint(
        eg.src, eg.dst, w_cap, valid_cap, sr, source, eg.num_vertices
    )
    # Paper §6.2: derive R∪ incrementally from R∩ by streaming in the
    # union-only edges (strictly monotone, hence safe).
    val_cup, iters_cup = incremental_fixpoint(
        val_cap, eg.src, eg.dst, w_cup, valid_cup, sr, eg.num_vertices
    )

    if sr.minimize:
        lower, upper = val_cup, val_cap
    else:
        lower, upper = val_cap, val_cup
    uvv = detect_uvv(val_cap, val_cup)
    return BoundsResult(
        val_cap=val_cap,
        val_cup=val_cup,
        lower=lower,
        upper=upper,
        uvv=uvv,
        iters_cap=iters_cap,
        iters_cup=iters_cup,
    )


@jax.jit
def detect_uvv(val_cap: jax.Array, val_cup: jax.Array) -> jax.Array:
    """Theorem 2 test: exact bound equality (inf==inf counts — the paper
    explicitly notes the bound holds for unreachable vertices)."""
    return val_cap == val_cup


# ==========================================================================
# Batched multi-source bounds (Q×V) — the front of the Q×S×V CQRS pipeline
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class BatchBoundsResult:
    """Per-query intersection-union analysis for a batch of Q sources.

    Every array matches :class:`BoundsResult` with a leading query axis; the
    UVV mask is fused over the batch (computed in one vmapped launch, not Q
    separate ones).  ``iters_*`` are lockstep superstep counts: the vmapped
    ``while_loop`` runs until the *slowest* source converges, so every lane
    reports the same count (monotone relaxation makes the extra supersteps
    for already-converged lanes no-ops).
    """

    val_cap: jax.Array  # (Q, V) — R∩ per query
    val_cup: jax.Array  # (Q, V) — R∪ per query
    lower: jax.Array  # (Q, V)
    upper: jax.Array  # (Q, V)
    uvv: jax.Array  # (Q, V) bool — fused Theorem-2 mask
    iters_cap: jax.Array  # (Q,)
    iters_cup: jax.Array  # (Q,)

    @property
    def num_queries(self) -> int:
        return int(self.val_cap.shape[0])


def compute_bounds_batch(
    eg: EvolvingGraph, sr: Semiring, sources
) -> BatchBoundsResult:
    """Vmapped ``compute_bounds`` over Q sources → (Q, V) bound matrices.

    The graph-resident inputs (edge arrays, validity masks, safe weights) are
    computed once and closed over; only the source index is batched, so the
    whole G∩ solve + incremental G∪ lift for all Q queries is two vmapped
    ``while_loop`` launches instead of 2Q sequential ones.
    """
    sources = jnp.asarray(sources, jnp.int32)
    valid_cap = eg.intersection_valid()
    valid_cup = eg.union_valid()
    w_cap = sr.intersection_weight(eg.weight_min, eg.weight_max)
    w_cup = sr.union_weight(eg.weight_min, eg.weight_max)

    val_cap, iters_cap = jax.vmap(
        lambda s: compute_fixpoint(
            eg.src, eg.dst, w_cap, valid_cap, sr, s, eg.num_vertices
        )
    )(sources)
    val_cup, iters_cup = jax.vmap(
        lambda v0: incremental_fixpoint(
            v0, eg.src, eg.dst, w_cup, valid_cup, sr, eg.num_vertices
        )
    )(val_cap)

    if sr.minimize:
        lower, upper = val_cup, val_cap
    else:
        lower, upper = val_cap, val_cup
    uvv = detect_uvv(val_cap, val_cup)
    return BatchBoundsResult(
        val_cap=val_cap,
        val_cup=val_cup,
        lower=lower,
        upper=upper,
        uvv=uvv,
        iters_cap=iters_cap,
        iters_cup=iters_cup,
    )


# ==========================================================================
# Streaming bounds maintenance over a sliding snapshot window
# ==========================================================================
class StreamingBounds:
    """Incrementally-maintained intersection–union bounds for a sliding window.

    ``compute_bounds`` solves G∩ and G∪ from scratch for a fixed window.  A
    window *slide* changes both graphs in a structured way, tracked by the
    view's per-edge witness-count array
    (:class:`repro.graph.stream.WindowView.witness`):

    * the **appended** snapshot can only *shrink* G∩ (edges it lacks drop out
      of the intersection) and *grow* G∪;
    * the **retired** snapshot can only *grow* G∩ (edges it alone was missing
      join) and *shrink* G∪ (edges it alone witnessed — witness count hits
      zero — drop out).

    Growth is the monotone direction: relaxing the old fixpoint over the new
    edge set refines it without recomputation (the same §6.2 argument that
    lifts R∩ to R∪).  Shrinkage is handled KickStarter-style: only vertices
    whose bound was *witnessed* by a dropped edge — their
    :func:`~repro.core.engine.compute_parents` chain crosses an edge whose
    witness count made the fatal transition — are invalidated and re-relaxed
    (:func:`~repro.core.engine.invalidate_from_deletions`); everyone else's
    bound is provably unchanged-or-refinable in place.  Soundness of the trim
    rests on the parent forest being acyclic (``compute_parents`` levels the
    achieving subgraph by BFS depth so chains strictly descend to the source
    — an equal-value cycle under a non-strict ``extend`` cannot record its
    members as each other's parents and outlive its real support edge).  Lifetime weight-extrema
    widening is folded into the same machinery: the G∩ safe weight can only
    worsen (treated as a deletion of the old-weight edge), the G∪ safe weight
    can only improve (plain monotone re-relaxation).

    Because monotone fixpoints are unique, the maintained ``val_cap`` /
    ``val_cup`` are bit-for-bit identical to a fresh :func:`compute_bounds`
    on the slid window's materialized graph.

    Window-local weight extrema add two more transitions: a *narrowing*
    extremum (the snapshot carrying the extreme weight retired from the
    window) can only *improve* the safe weight on one side — a plain
    monotone re-relax — and only *worsen* it on the other, which is handled
    exactly like a deletion of the old-weight edge (trim + re-relax).

    ``source`` may be a single vertex or a **sequence of Q vertices**: in
    batched mode every state array carries a leading query axis —
    ``val_cap``/``val_cup``/``parent_cap``/``parent_cup`` are ``(Q, V)`` —
    and every maintenance pass (cold solves, monotone re-relaxes,
    KickStarter trims, parent rebuilds) runs as ONE vmapped launch for all
    Q queries.  ``jax.vmap`` of ``lax.while_loop`` freezes each lane's
    carry once its own convergence condition holds, so per-lane value
    arrays are bit-for-bit identical to Q sequential maintainers (the
    *reported* superstep count is the lockstep max over lanes; per-lane
    accounting is a ROADMAP item).

    This class is single-host;
    :class:`repro.distributed.stream_shard.ShardedStreamingBounds` runs the
    same maintenance algebra over a dst-range-sharded log under ``shard_map``
    (scatters and trims shard-local, one per-vertex all-gather per
    superstep) with bit-for-bit identical fixpoints.
    """

    def __init__(self, view, sr: Semiring, source):
        self.view = view
        self.sr = sr
        if np.ndim(source) == 0:
            self.sources = None  # scalar mode: (V,) state
            self.source = jnp.int32(int(source))
        else:
            self.sources = [int(s) for s in np.asarray(source).ravel()]
            if not self.sources:
                raise ValueError("StreamingBounds needs at least one source")
            self.source = jnp.asarray(self.sources, jnp.int32)
        self.supersteps = 0
        # KickStarter-style maintenance accounting: trims = invalidation
        # launches (deletion-driven), rerelaxes = monotone re-relax launches
        # (per slide side; exported as paper-grounded stability telemetry)
        self.trims = 0
        self.rerelaxes = 0
        # per-lane superstep accounting (batched mode): lane ``i`` accumulates
        # its own freeze steps — the superstep at which the vmapped while_loop
        # froze its carry — instead of the lockstep max, so serving can spot
        # pathological watchers (see StreamingQueryBatch.lane_supersteps)
        self.lane_supersteps = (
            None if self.sources is None
            else np.zeros(len(self.sources), np.int64)
        )
        self._weights_key = None
        self._w_cap = self._w_cup = None
        self._full_init()

    @property
    def batched(self) -> bool:
        return self.sources is not None

    def _tally(self, iters) -> int:
        """Fold a fixpoint's iteration count(s) into the per-lane ledger.

        Scalar mode passes a scalar through; batched mode accumulates the
        per-lane (Q,) counts and returns their max (the lockstep superstep
        count the aggregate ``supersteps`` stat always reported).
        """
        it = np.asarray(iters)
        if it.ndim == 0:
            return int(it)
        self.lane_supersteps[: len(it)] += it.astype(np.int64)
        return int(it.max()) if len(it) else 0

    # -- device-side universe arrays ------------------------------------------
    def _edges(self):
        return self.view.log.device_edges()

    def _weights(self):
        """Safe per-edge weights (w_cap, w_cup), re-uploaded only when stale.

        Weights are the VIEW's window-local extrema (exact for the current
        window), keyed on (generation, num_edges, weight_epoch): the host
        arrays are mutated in place by edge registration and extrema
        refreshes, and ``jnp.asarray`` copies.
        """
        view, log = self.view, self.view.log
        view._sync_weights()
        key = (log.generation, log.num_edges, view.weight_epoch)
        if self._weights_key != key:
            sr = self.sr
            self._w_cap = jnp.asarray(
                sr.intersection_weight(view.weight_min, view.weight_max)
            )
            self._w_cup = jnp.asarray(
                sr.union_weight(view.weight_min, view.weight_max)
            )
            self._weights_key = key
        return self._w_cap, self._w_cup

    # -- engine dispatch (scalar ↔ vmapped-Q launches) ------------------------
    def _cold(self, src, dst, w, mask):
        sr, v = self.sr, self.view.log.num_vertices
        if not self.batched:
            return compute_fixpoint(
                src, dst, w, mask, sr, self.source, v, sorted_edges=False
            )
        return jax.vmap(
            lambda s: compute_fixpoint(
                src, dst, w, mask, sr, s, v, sorted_edges=False
            )
        )(self.source)

    def _refix(self, values, src, dst, w, mask):
        sr, v = self.sr, self.view.log.num_vertices
        if not self.batched:
            return incremental_fixpoint(
                values, src, dst, w, mask, sr, v, sorted_edges=False
            )
        return jax.vmap(
            lambda v0: incremental_fixpoint(
                v0, src, dst, w, mask, sr, v, sorted_edges=False
            )
        )(values)

    def _parents(self, values, src, dst, w, mask):
        sr, v = self.sr, self.view.log.num_vertices
        if not self.batched:
            return compute_parents(
                values, src, dst, w, mask, sr, self.source, v,
                sorted_edges=False,
            )
        return jax.vmap(
            lambda v0, s: compute_parents(
                v0, src, dst, w, mask, sr, s, v, sorted_edges=False
            )
        )(values, self.source)

    def _invalidate(self, values, parent, dropped, src):
        sr, v = self.sr, self.view.log.num_vertices
        if not self.batched:
            vals, _ = invalidate_from_deletions(
                values, parent, dropped, src, sr, self.source, v
            )
            return vals
        vals, _ = jax.vmap(
            lambda v0, p, s: invalidate_from_deletions(
                v0, p, dropped, src, sr, s, v
            )
        )(values, parent, self.source)
        return vals

    # -- full solve (cold start) ----------------------------------------------
    def _full_init(self):
        src, dst = self._edges()
        w_cap, w_cup = self._weights()
        inter = jnp.asarray(self.view.intersection_mask())
        union = jnp.asarray(self.view.union_mask())
        if getattr(self, "_warm_vals", None) is not None:
            # warm start (from_state): the checkpointed value arrays ARE the
            # window's fixpoints (monotone fixpoints are unique), so skip
            # both solves; only the parent forests — trim metadata, not part
            # of the fixpoint — are recomputed, one relaxation-free launch
            # per side.
            self.val_cap, self.val_cup = self._warm_vals
            self._warm_vals = None
            self.parent_cap = self._parents(self.val_cap, src, dst, w_cap, inter)
            self.parent_cup = self._parents(self.val_cup, src, dst, w_cup, union)
            return
        self.val_cap, it_cap = self._cold(src, dst, w_cap, inter)
        self.val_cup, it_cup = self._refix(self.val_cap, src, dst, w_cup, union)
        self.parent_cap = self._parents(self.val_cap, src, dst, w_cap, inter)
        self.parent_cup = self._parents(self.val_cup, src, dst, w_cup, union)
        self.supersteps += self._tally(it_cap) + self._tally(it_cup)

    @classmethod
    def from_state(cls, view, sr: Semiring, source, val_cap, val_cup, *,
                   supersteps: int = 0, lane_supersteps=None, **kwargs):
        """Rebuild a maintainer from checkpointed value arrays (warm start).

        ``val_cap``/``val_cup`` must be the fixpoints of ``view``'s current
        window — restore replays the checkpointed window into a fresh log
        first, so uniqueness of monotone fixpoints makes the restored
        maintainer bit-for-bit equal to one that never stopped.  No cold
        solve runs; only the parent forests are rebuilt (one launch per
        side).  Extra ``kwargs`` pass through to the subclass constructor
        (e.g. ``mesh`` for the sharded maintainer).
        """
        self = cls.__new__(cls)
        self._warm_vals = (jnp.asarray(val_cap), jnp.asarray(val_cup))
        self.__init__(view, sr, source, **kwargs)
        self.supersteps = int(supersteps)
        if self.lane_supersteps is not None and lane_supersteps is not None:
            ls = np.asarray(lane_supersteps, np.int64)
            self.lane_supersteps[: len(ls)] = ls
        return self

    # -- batched-mode lane membership ----------------------------------------
    def append_lane(self, lane: "StreamingBounds") -> None:
        """Append one scalar maintainer's state as a new query lane.

        Owns the lane↔array bookkeeping so callers (the serving batch) never
        touch per-field internals; keeps the (Q, V) arrays and the source
        list index-aligned by construction.
        """
        if not self.batched or lane.batched:
            raise ValueError("append_lane needs a batched self + scalar lane")
        self.sources.append(int(lane.source))
        self.source = jnp.asarray(self.sources, jnp.int32)
        self.val_cap = jnp.concatenate([self.val_cap, lane.val_cap[None]], 0)
        self.val_cup = jnp.concatenate([self.val_cup, lane.val_cup[None]], 0)
        self.parent_cap = jnp.concatenate(
            [self.parent_cap, lane.parent_cap[None]], 0
        )
        self.parent_cup = jnp.concatenate(
            [self.parent_cup, lane.parent_cup[None]], 0
        )
        self.lane_supersteps = np.concatenate(
            [self.lane_supersteps, [lane.supersteps]]
        )
        self.supersteps += lane.supersteps

    def drop_lane(self, index: int) -> None:
        """Remove query lane ``index`` from the (Q, V) state."""
        if not self.batched:
            raise ValueError("drop_lane needs a batched maintainer")
        self.sources.pop(index)
        self.source = jnp.asarray(self.sources, jnp.int32)
        keep = np.asarray(
            [j for j in range(self.val_cap.shape[0]) if j != index], np.int32
        )
        self._permute_lanes(keep)

    def _permute_lanes(self, order: np.ndarray) -> None:
        """Re-index the lane axis of every (Q, V) array by ``order``."""
        self.val_cap = self.val_cap[order]
        self.val_cup = self.val_cup[order]
        self.parent_cap = self.parent_cap[order]
        self.parent_cup = self.parent_cup[order]
        self.lane_supersteps = self.lane_supersteps[order]

    # -- Q-class padding (sticky lane-capacity classes) -----------------------
    # The (Q, V) shapes key every jitted maintenance launch, so serving
    # membership churn (watch/evict) would recompile per distinct Q.
    # StreamingQueryBatch therefore pads the lane axis to a sticky capacity
    # class — dead lanes duplicate lane 0 (idempotent monotone work, sliced
    # off at the API boundary) — and mutates membership through these three
    # shape-preserving operations, the lane-axis analogue of the edge/ELL
    # amortized-capacity trick.
    def set_lane(self, index: int, lane: "StreamingBounds") -> None:
        """Overwrite lane ``index`` with a scalar maintainer's warm state."""
        if not self.batched or lane.batched:
            raise ValueError("set_lane needs a batched self + scalar lane")
        self.sources[index] = int(lane.source)
        self.source = jnp.asarray(self.sources, jnp.int32)
        self.val_cap = self.val_cap.at[index].set(lane.val_cap)
        self.val_cup = self.val_cup.at[index].set(lane.val_cup)
        self.parent_cap = self.parent_cap.at[index].set(lane.parent_cap)
        self.parent_cup = self.parent_cup.at[index].set(lane.parent_cup)
        self.lane_supersteps[index] = lane.supersteps
        self.supersteps += lane.supersteps

    def pad_lanes(self, cap: int) -> None:
        """Grow the lane axis to ``cap`` entries by duplicating lane 0."""
        if not self.batched:
            raise ValueError("pad_lanes needs a batched maintainer")
        reps = cap - len(self.sources)
        if reps <= 0:
            return
        order = np.concatenate([
            np.arange(len(self.sources)), np.zeros(reps, np.int64)
        ])
        self.sources.extend([self.sources[0]] * reps)
        self.source = jnp.asarray(self.sources, jnp.int32)
        self._permute_lanes(order)

    def drop_lane_padded(self, index: int, num_real: int) -> None:
        """Remove lane ``index`` WITHOUT changing the padded lane count.

        Real lanes after ``index`` shift down one slot; the freed tail slot
        (and everything past ``num_real``) re-duplicates the first
        SURVIVING lane — never the dropped one, whose state (and UVV mask,
        which the shared QRS keep rule folds over every lane) must stop
        influencing the batch.  Shapes, and therefore compiled launches,
        are untouched.
        """
        if not self.batched:
            raise ValueError("drop_lane_padded needs a batched maintainer")
        cap = len(self.sources)
        order = _drop_lane_order(index, num_real, cap)
        self.sources = [self.sources[j] for j in order]
        self.source = jnp.asarray(self.sources, jnp.int32)
        self._permute_lanes(order)

    # -- one slide ------------------------------------------------------------
    def apply_slide(self, diff, inter_mask=None, union_mask=None) -> int:
        """Fold one :class:`~repro.graph.stream.SlideDiff` into the bounds.

        ``inter_mask``/``union_mask`` are the G∩/G∪ membership masks of the
        window *after this slide*; they default to the view's current masks,
        which is only correct when ``diff`` is the view's latest slide.  A
        consumer catching up on several queued slides must pass each
        intermediate window's masks (``WindowView.rolling_masks``) — the trim
        argument is per-slide: parents recorded on window *k* justify
        invalidations against window *k+1*, not against a window several
        slides ahead.  Weights, however, are always the view's *current*
        window extrema: if any queued slide moved them, intermediate slides
        cannot be folded in consistently and the caller must rebuild
        instead (``StreamingQuery.advance`` does).

        Returns the number of relaxation supersteps spent (0 when the slide
        left both G∩ and G∪ untouched).
        """
        sr = self.sr
        cap_n = self.view.log.capacity
        if inter_mask is None:
            inter_mask = self.view.intersection_mask()
        if union_mask is None:
            union_mask = self.view.union_mask()
        src, dst = self._edges()
        w_cap, w_cup = self._weights()
        steps = 0

        # Window-extrema transitions map onto the two maintenance moves:
        # a WORSE safe weight behaves like a deletion of the old-weight edge
        # (trim + re-relax), a BETTER one is a plain monotone re-relax.
        # Widening worsens the G∩ side and improves the G∪ side; narrowing
        # (an extreme-weight snapshot retired from the window) the reverse.
        cap_weight_worse, cap_weight_better = diff.cap_weight_transitions(
            sr.minimize
        )
        cup_weight_worse, cup_weight_better = diff.cup_weight_transitions(
            sr.minimize
        )

        cap_dropped = _as_mask(cap_n, diff.inter_lost, cap_weight_worse)
        cap_changed = (
            cap_dropped is not None
            or len(diff.inter_gained)
            or len(cap_weight_better)
        )
        if cap_changed:
            inter = jnp.asarray(inter_mask)
            if cap_dropped is not None:
                self.val_cap = self._invalidate(
                    self.val_cap, self.parent_cap, jnp.asarray(cap_dropped), src
                )
                self.trims += 1
            self.val_cap, it = self._refix(self.val_cap, src, dst, w_cap, inter)
            self.parent_cap = self._parents(self.val_cap, src, dst, w_cap, inter)
            self.rerelaxes += 1
            steps += self._tally(it)

        cup_dropped = _as_mask(cap_n, diff.union_lost, cup_weight_worse)
        cup_changed = (
            cup_dropped is not None
            or len(diff.union_gained)
            or len(cup_weight_better)
        )
        if cup_changed:
            union = jnp.asarray(union_mask)
            if cup_dropped is not None:
                self.val_cup = self._invalidate(
                    self.val_cup, self.parent_cup, jnp.asarray(cup_dropped), src
                )
                self.trims += 1
            self.val_cup, it = self._refix(self.val_cup, src, dst, w_cup, union)
            self.parent_cup = self._parents(self.val_cup, src, dst, w_cup, union)
            self.rerelaxes += 1
            steps += self._tally(it)

        self.supersteps += steps
        return steps

    # -- results --------------------------------------------------------------
    @property
    def uvv(self) -> jax.Array:
        return detect_uvv(self.val_cap, self.val_cup)

    @property
    def result(self) -> BoundsResult:
        """Current window's bounds in the :func:`compute_bounds` shape."""
        if self.sr.minimize:
            lower, upper = self.val_cup, self.val_cap
        else:
            lower, upper = self.val_cap, self.val_cup
        total = jnp.int32(self.supersteps)
        return BoundsResult(
            val_cap=self.val_cap, val_cup=self.val_cup,
            lower=lower, upper=upper, uvv=self.uvv,
            iters_cap=total, iters_cup=jnp.int32(0),
        )


def _drop_lane_order(index: int, num_real: int, cap: int) -> np.ndarray:
    """Lane permutation dropping ``index``: survivors shift down, every
    freed/padding slot re-duplicates the first survivor.  Shared by the
    bounds arrays and the cached result rows so they cannot disagree."""
    survivors = [j for j in range(num_real) if j != index]
    return np.asarray(
        survivors + [survivors[0]] * (cap - num_real + 1), np.int64
    )


def _as_mask(n: int, *id_arrays) -> "np.ndarray | None":
    """Scatter universe-id arrays into an (n,) bool mask; None when all empty."""
    total = sum(len(a) for a in id_arrays)
    if total == 0:
        return None
    mask = np.zeros(n, bool)
    for a in id_arrays:
        mask[a] = True
    return mask

"""Intersection–union bound analysis and UVV detection (paper §3 Steps 1–2).

``compute_bounds`` solves the query on G∩ and G∪; per Theorem 1 this brackets
every snapshot's value.  Per the paper's own optimization (§6.2) the G∪ solve
is *incremental* from the G∩ result: going from G∩ to G∪ only adds edges, so
monotone relaxation from ``R∩`` converges to ``R∪`` without a second
from-scratch solve.

Bound direction is per-semiring (paper Table 1): CASMIN queries (BFS/SSSP/
SSNP) have ``R∪ ≤ Val_i ≤ R∩``; CASMAX queries (SSWP/Viterbi) the reverse.
Flip-flopping edges take their safe weight per direction (DESIGN.md §8.5).

Theorem 2 (UVV): where the two bounds agree — including at ``identity`` for
vertices unreachable in both — the value is constant across all snapshots.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.engine import compute_fixpoint, incremental_fixpoint
from repro.core.semiring import Semiring
from repro.graph.structures import EvolvingGraph


@dataclasses.dataclass(frozen=True)
class BoundsResult:
    """Outputs of the intersection-union analysis."""

    val_cap: jax.Array  # R∩ — query result on the intersection graph (V,)
    val_cup: jax.Array  # R∪ — query result on the union graph (V,)
    lower: jax.Array  # per-vertex lower bound over all snapshots (V,)
    upper: jax.Array  # per-vertex upper bound over all snapshots (V,)
    uvv: jax.Array  # (V,) bool — bounds coincide (Theorem 2)
    iters_cap: jax.Array
    iters_cup: jax.Array


def compute_bounds(eg: EvolvingGraph, sr: Semiring, source: int) -> BoundsResult:
    valid_cap = eg.intersection_valid()
    valid_cup = eg.union_valid()
    w_cap = sr.intersection_weight(eg.weight_min, eg.weight_max)
    w_cup = sr.union_weight(eg.weight_min, eg.weight_max)
    source = jnp.int32(source)

    val_cap, iters_cap = compute_fixpoint(
        eg.src, eg.dst, w_cap, valid_cap, sr, source, eg.num_vertices
    )
    # Paper §6.2: derive R∪ incrementally from R∩ by streaming in the
    # union-only edges (strictly monotone, hence safe).
    val_cup, iters_cup = incremental_fixpoint(
        val_cap, eg.src, eg.dst, w_cup, valid_cup, sr, eg.num_vertices
    )

    if sr.minimize:
        lower, upper = val_cup, val_cap
    else:
        lower, upper = val_cap, val_cup
    uvv = detect_uvv(val_cap, val_cup)
    return BoundsResult(
        val_cap=val_cap,
        val_cup=val_cup,
        lower=lower,
        upper=upper,
        uvv=uvv,
        iters_cap=iters_cap,
        iters_cup=iters_cup,
    )


@jax.jit
def detect_uvv(val_cap: jax.Array, val_cup: jax.Array) -> jax.Array:
    """Theorem 2 test: exact bound equality (inf==inf counts — the paper
    explicitly notes the bound holds for unreachable vertices)."""
    return val_cap == val_cup


# ==========================================================================
# Batched multi-source bounds (Q×V) — the front of the Q×S×V CQRS pipeline
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class BatchBoundsResult:
    """Per-query intersection-union analysis for a batch of Q sources.

    Every array matches :class:`BoundsResult` with a leading query axis; the
    UVV mask is fused over the batch (computed in one vmapped launch, not Q
    separate ones).  ``iters_*`` are lockstep superstep counts: the vmapped
    ``while_loop`` runs until the *slowest* source converges, so every lane
    reports the same count (monotone relaxation makes the extra supersteps
    for already-converged lanes no-ops).
    """

    val_cap: jax.Array  # (Q, V) — R∩ per query
    val_cup: jax.Array  # (Q, V) — R∪ per query
    lower: jax.Array  # (Q, V)
    upper: jax.Array  # (Q, V)
    uvv: jax.Array  # (Q, V) bool — fused Theorem-2 mask
    iters_cap: jax.Array  # (Q,)
    iters_cup: jax.Array  # (Q,)

    @property
    def num_queries(self) -> int:
        return int(self.val_cap.shape[0])


def compute_bounds_batch(
    eg: EvolvingGraph, sr: Semiring, sources
) -> BatchBoundsResult:
    """Vmapped ``compute_bounds`` over Q sources → (Q, V) bound matrices.

    The graph-resident inputs (edge arrays, validity masks, safe weights) are
    computed once and closed over; only the source index is batched, so the
    whole G∩ solve + incremental G∪ lift for all Q queries is two vmapped
    ``while_loop`` launches instead of 2Q sequential ones.
    """
    sources = jnp.asarray(sources, jnp.int32)
    valid_cap = eg.intersection_valid()
    valid_cup = eg.union_valid()
    w_cap = sr.intersection_weight(eg.weight_min, eg.weight_max)
    w_cup = sr.union_weight(eg.weight_min, eg.weight_max)

    val_cap, iters_cap = jax.vmap(
        lambda s: compute_fixpoint(
            eg.src, eg.dst, w_cap, valid_cap, sr, s, eg.num_vertices
        )
    )(sources)
    val_cup, iters_cup = jax.vmap(
        lambda v0: incremental_fixpoint(
            v0, eg.src, eg.dst, w_cup, valid_cup, sr, eg.num_vertices
        )
    )(val_cap)

    if sr.minimize:
        lower, upper = val_cup, val_cap
    else:
        lower, upper = val_cap, val_cup
    uvv = detect_uvv(val_cap, val_cup)
    return BatchBoundsResult(
        val_cap=val_cap,
        val_cup=val_cup,
        lower=lower,
        upper=upper,
        uvv=uvv,
        iters_cap=iters_cap,
        iters_cup=iters_cup,
    )

"""Three-term roofline from the compiled dry-run artifact.

    compute    = HLO_FLOPs_per_chip      / peak_FLOP/s          (197 TF bf16)
    memory     = HLO_bytes_per_chip      / HBM_bw               (819 GB/s)
    collective = collective_bytes_per_chip / link_bw            (~50 GB/s)

``compiled.cost_analysis()`` is per-device under SPMD (verified empirically:
flops == global/num_devices), so all terms are per-chip consistently.
Collective bytes are not in cost_analysis — we parse the optimized
(post-SPMD-partitioning) HLO text and sum operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

HW_V5E = {
    "peak_flops": 197e12,  # bf16 per chip
    "hbm_bw": 819e9,  # bytes/s per chip
    "ici_bw": 50e9,  # bytes/s per link
    "hbm_bytes": 16 * 1024**3,
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-chip collective bytes per type, from the optimized (post-SPMD) HLO.

    The optimized module prints per-device shapes.  We sum the RESULT bytes
    of each collective (operand refs are untyped in this dump):
      all-reduce / all-to-all / collective-permute: result == operand size;
      all-gather: result is the gathered buffer — (g−1)/g of it moves on the
        wire, ≈ result for realistic group sizes;
      reduce-scatter: result = operand/g, wire ≈ operand → scale by group
        size parsed from replica_groups=[g,r].
    ``*-start``/``*-done`` async pairs are counted once (on the start op).
    """
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    op_re = re.compile(
        r"=\s*(?P<result>[^=]*?)\s(?P<op>" +
        "|".join(_COLLECTIVES) + r")(?P<async>-start|-done)?\("
    )
    for line in hlo_text.splitlines():
        s = line.strip()
        m = op_re.search(s)
        if m is None:
            continue
        if m.group("async") == "-done":
            continue
        base = m.group("op")
        total = 0
        for dt, dims in _SHAPE_RE.findall(m.group("result")):
            if dt in _DTYPE_BYTES:
                total += _shape_bytes(dt, dims)
        if base == "reduce-scatter":
            g = _GROUPS_RE.search(s)
            if g:
                total *= int(g.group(1))
        out[base] += total
        count[base] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count
    return out


@dataclasses.dataclass
class RooflineReport:
    # raw per-chip numbers (XLA counts scan/while bodies ONCE — verified)
    raw_flops_per_chip: float
    raw_bytes_per_chip: float
    raw_collective_bytes_per_chip: float
    scan_factor: float
    # scan-corrected per-chip estimates (raw × scan_factor)
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    # the three roofline terms in seconds (corrected)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    # analytic (exact) model flops → MFU-at-bound = the perf score
    model_flops: Optional[float] = None
    model_compute_s: Optional[float] = None  # MODEL_FLOPS/chips/peak
    useful_ratio: Optional[float] = None  # MODEL_FLOPS / (HLO_FLOPs × chips)
    roofline_fraction: Optional[float] = None  # model_compute_s / bound_s
    collective_detail: Optional[dict] = None
    memory_stats: Optional[dict] = None

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline_report(
    cost: dict,
    hlo_text: str,
    *,
    num_chips: int,
    model_flops: Optional[float] = None,
    scan_factor: float = 1.0,
    coll_scan_factor: Optional[float] = None,
    analytic_bytes: Optional[float] = None,
    hw: dict = HW_V5E,
    memory_stats: Optional[dict] = None,
) -> RooflineReport:
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes_from_hlo(hlo_text)
    raw_coll = float(coll["total"])

    flops = raw_flops * scan_factor
    # LM cells supply an analytic HBM estimate (scan correction would
    # mis-scale the once-per-step optimizer/logits segments)
    byts = analytic_bytes if analytic_bytes is not None else raw_bytes * scan_factor
    csf = scan_factor if coll_scan_factor is None else coll_scan_factor
    coll_b = raw_coll * csf

    compute_s = flops / hw["peak_flops"]
    memory_s = byts / hw["hbm_bw"]
    collective_s = coll_b / hw["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())

    useful = model_compute_s = frac = None
    if model_flops:
        useful = model_flops / max(flops * num_chips, 1.0)
        model_compute_s = model_flops / num_chips / hw["peak_flops"]
        frac = model_compute_s / max(bound_s, 1e-30)
    return RooflineReport(
        raw_flops_per_chip=raw_flops,
        raw_bytes_per_chip=raw_bytes,
        raw_collective_bytes_per_chip=raw_coll,
        scan_factor=scan_factor,
        flops_per_chip=flops,
        bytes_per_chip=byts,
        collective_bytes_per_chip=coll_b,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        model_compute_s=model_compute_s,
        useful_ratio=useful,
        roofline_fraction=frac,
        collective_detail=coll,
        memory_stats=memory_stats,
    )

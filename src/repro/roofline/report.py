"""Render the §Dry-run / §Roofline markdown tables from experiments/dryrun/*.json.

    PYTHONPATH=src python -m repro.roofline.report [--mesh single_pod]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DRYRUN_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"
)


def load(mesh: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def roofline_table(mesh: str) -> str:
    rows = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL_FLOPS | useful | frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load(mesh):
        r = rec["roofline"]
        mf = r.get("model_flops")
        useful = r.get("useful_ratio")
        frac = r.get("roofline_fraction")
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | "
            f"{mf:.2e} | "
            f"{'-' if useful is None else format(useful, '.2f')} | "
            f"{'-' if frac is None else format(frac, '.3f')} |"
        )
    return "\n".join(rows)


def dryrun_table(mesh: str) -> str:
    rows = [
        "| arch | shape | compile | args/chip | raw flops/chip | raw bytes/chip |"
        " coll bytes/chip (corr) | collective counts |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for rec in load(mesh):
        r = rec["roofline"]
        mem = rec["memory"]
        counts = r["collective_detail"]["counts"]
        cshort = ",".join(f"{k.split('-')[-1][:4]}:{v}" for k, v in counts.items() if v)
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['compile_seconds']}s | "
            f"{mem.get('argument_bytes', 0)/2**30:.2f}GiB | "
            f"{r['raw_flops_per_chip']:.2e} | {r['raw_bytes_per_chip']:.2e} | "
            f"{r['collective_bytes_per_chip']:.2e} | {cshort} |"
        )
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single_pod")
    ap.add_argument("--table", choices=["roofline", "dryrun"], default="roofline")
    args = ap.parse_args()
    fn = roofline_table if args.table == "roofline" else dryrun_table
    print(fn(args.mesh))


if __name__ == "__main__":
    main()

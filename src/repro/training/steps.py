"""Train-step builders: loss → grads (optionally microbatched) → AdamW.

Each builder returns ``step(params, opt_state, batch) → (params, opt_state,
metrics)`` — the function the launcher jits with in/out shardings and the
dry-run lowers.  ``accum_steps > 1`` splits the global batch into
microbatches with ``lax.scan`` (gradient accumulation), which divides the
activation working set — required for the 236B config to fit 16 GiB chips.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, adamw_update


def _accumulate_grads(loss_fn, params, batch, accum_steps: int, accum_dtype=None):
    """Microbatched value_and_grad: mean over ``accum_steps`` slices.

    ``accum_dtype`` (e.g. bf16) halves the accumulator carry — the double-
    buffered scan carry is a full param-sized tensor, so this matters at
    the 236B scale.  The 1/accum rescale happens in fp32.
    """
    if accum_steps <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads
    acc_dt = jnp.float32 if accum_dtype is None else jnp.dtype(accum_dtype)

    def slice_batch(b, i):
        def f(x):
            mb = x.shape[0] // accum_steps
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

        return jax.tree_util.tree_map(f, b)

    def body(carry, i):
        loss_acc, grads_acc = carry
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, slice_batch(batch, i)
        )
        grads_acc = jax.tree_util.tree_map(
            lambda a, g: (a + g.astype(acc_dt) / accum_steps).astype(acc_dt),
            grads_acc, grads,
        )
        return (loss_acc + loss, grads_acc), metrics

    zero_grads = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, acc_dt), params
    )
    (loss_sum, grads), metrics = jax.lax.scan(
        body, (jnp.float32(0.0), zero_grads), jnp.arange(accum_steps)
    )
    last_metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
    return loss_sum / accum_steps, last_metrics, grads


def _make_step(loss_fn: Callable, opt_cfg: AdamWConfig, accum_steps: int = 1,
               accum_dtype=None):
    def step(params, opt_state, batch):
        loss, metrics, grads = _accumulate_grads(
            loss_fn, params, batch, accum_steps, accum_dtype
        )
        params, opt_state, om = adamw_update(grads, opt_state, params, opt_cfg)
        out = {"loss": loss, **metrics, **om}
        return params, opt_state, out

    return step


def build_lm_train_step(cfg, opt_cfg: AdamWConfig, accum_steps: int = 1,
                        accum_dtype=None, cast_params_once: bool = False):
    """``cast_params_once``: cast fp32 params to the compute dtype at step
    start (a sharded-local convert) so the FSDP all-gathers — the dominant
    training collective — move bf16 instead of fp32 (2× wire bytes), and the
    backward reduce-scatter likewise.  The optimizer still updates fp32
    master params (grads convert back locally). §Perf iteration B1."""
    from repro.models.transformer import lm_loss

    if not cast_params_once:
        return _make_step(
            lambda p, b: lm_loss(cfg, p, b), opt_cfg, accum_steps, accum_dtype
        )

    dt = cfg.compute_dtype

    def loss_fn(params, batch):
        params_c = jax.tree_util.tree_map(
            lambda p: p.astype(dt) if jnp.issubdtype(p.dtype, jnp.floating) else p,
            params,
        )
        return lm_loss(cfg, params_c, batch)

    return _make_step(loss_fn, opt_cfg, accum_steps, accum_dtype)


def build_gnn_train_step(cfg, opt_cfg: AdamWConfig, *, num_graphs: int = 1):
    """Node classification (pna/gatedgcn/equiformer) or energy MSE (dimenet)."""
    from repro.models.gnn.common import node_classification_loss
    from repro.models.gnn.dimenet import dimenet_forward
    from repro.models.gnn.equiformer_v2 import equiformer_forward
    from repro.models.gnn.gatedgcn import gatedgcn_forward
    from repro.models.gnn.pna import pna_forward

    def loss_fn(params, batch):
        if cfg.arch == "dimenet":
            e = dimenet_forward(cfg, params, batch, num_graphs=num_graphs)
            loss = jnp.mean((e - batch["energy"]) ** 2)
            return loss, {"mse": loss}
        fwd = {
            "pna": pna_forward,
            "gatedgcn": gatedgcn_forward,
            "equiformer_v2": equiformer_forward,
        }[cfg.arch]
        logits = fwd(cfg, params, batch)
        mask = batch.get("label_mask")
        loss = node_classification_loss(logits, batch["labels"], mask)
        return loss, {"nll": loss}

    return _make_step(loss_fn, opt_cfg)


def build_dlrm_train_step(cfg, opt_cfg: AdamWConfig, mesh=None, accum_steps: int = 1):
    from repro.models.dlrm import dlrm_loss

    return _make_step(
        lambda p, b: dlrm_loss(cfg, p, b, mesh), opt_cfg, accum_steps
    )

from repro.training.steps import (
    build_lm_train_step,
    build_gnn_train_step,
    build_dlrm_train_step,
)

__all__ = [
    "build_lm_train_step",
    "build_gnn_train_step",
    "build_dlrm_train_step",
]

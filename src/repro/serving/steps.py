"""Serve-step builders (the functions the decode/prefill dry-run cells lower)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def build_decode_step(cfg):
    from repro.models.transformer import decode_step

    def step(params, tokens, cache, cache_index):
        logits, new_cache = decode_step(cfg, params, tokens, cache, cache_index)
        # greedy head (sampling strategies plug in here)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, new_cache

    return step


def build_prefill_step(cfg):
    from repro.models.transformer import prefill_step

    def step(params, tokens):
        return prefill_step(cfg, params, tokens)

    return step

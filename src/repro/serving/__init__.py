from repro.serving.steps import build_decode_step, build_prefill_step
from repro.serving.scheduler import QueryBatcher, QueryRequest, RequestScheduler
from repro.serving.warmstart import (
    KernelGridSpec,
    aot_compile,
    enable_persistent_cache,
    enumerate_grid,
    grid_for,
    load_grid,
    save_grid,
    warm_from_manifest,
    warmup,
)

__all__ = [
    "build_decode_step",
    "build_prefill_step",
    "RequestScheduler",
    "QueryBatcher",
    "QueryRequest",
    "KernelGridSpec",
    "aot_compile",
    "enable_persistent_cache",
    "enumerate_grid",
    "grid_for",
    "load_grid",
    "save_grid",
    "warm_from_manifest",
    "warmup",
]

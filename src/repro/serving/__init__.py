from repro.serving.steps import build_decode_step, build_prefill_step
from repro.serving.scheduler import QueryBatcher, QueryRequest, RequestScheduler

__all__ = [
    "build_decode_step",
    "build_prefill_step",
    "RequestScheduler",
    "QueryBatcher",
    "QueryRequest",
]

"""AOT kernel-grid precompilation: warm-start serving off the compile path.

A streaming replica's jitted kernels are keyed by a small set of **compile
classes** — the log's edge capacity (amortized doubling, STREAM_ALIGN
quanta), the QRS slot capacity, the sticky ELL row count, the Q-lane
power-of-two class, the semiring, the method, and the shard count.  A cold
process pays an XLA compile the first time each (kernel, class) pair is hit
— on the serving path, between slides.  This module moves all of that
off-path:

* :class:`KernelGridSpec` names one point of the grid; :func:`grid_for`
  reads a live query's classes; :func:`enumerate_grid` expands a spec with
  its growth successors (the classes a capacity doubling would enter).
* :func:`aot_compile` traces the core engine kernels from
  ``jax.ShapeDtypeStruct``\\ s and compiles them ahead of time via
  ``fn.lower(...).compile()`` — no example data, no device transfers.
* :func:`warmup` drives a **synthetic replica** (an empty-but-capacity-
  matched log + query) through every serving-path entry point — cold solve,
  monotone re-relax, parent rebuild, KickStarter trim, per-snapshot eval —
  so the in-memory jit caches (including the vmapped and ``shard_map``
  dispatch paths AOT cannot reach) are populated at the exact serving
  shapes.  All-invalid masks make every fixpoint converge in one superstep,
  so the warmup *runs* in milliseconds; only the compiles cost anything.
* :func:`enable_persistent_cache` points JAX's persistent compilation cache
  at a directory and :func:`save_grid`/:func:`warm_from_manifest` persist
  the grid itself (``grid.json``), so a **restarted** replica replays the
  manifest, re-traces against the on-disk executables, and never compiles
  on the serving path — the crash-recovery half lives in
  :mod:`repro.checkpoint.streamstate`.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Iterable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

GRID_MANIFEST = "grid.json"
GRID_FORMAT = 1

_EMPTY = np.asarray([], np.int64)
_EMPTY_W = np.asarray([], np.float32)


@dataclasses.dataclass(frozen=True)
class KernelGridSpec:
    """One point of the reachable kernel grid (all fields are compile keys).

    ``q_cap == 0`` is the scalar (single-source) path; ``n_shards == 0`` the
    single-host engine.  ``qrs_capacity``/``ell_rows`` of 0 mean "whatever a
    tiny window naturally needs" (still warms the entry points, at the
    smallest class).
    """

    num_vertices: int
    log_capacity: int
    qrs_capacity: int = 0
    semiring: str = "sssp"
    method: str = "cqrs"
    q_cap: int = 0
    ell_rows: int = 0
    ell_slot_width: int = 128
    n_shards: int = 0

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "KernelGridSpec":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    def key(self) -> str:
        """Stable content key (manifest dedup + cache bookkeeping)."""
        blob = json.dumps(self.to_json(), sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:16]


def grid_for(sq) -> KernelGridSpec:
    """Read a live streaming query's compile classes into a spec."""
    sq._ensure_primed()
    log = sq.view.log
    sharded = hasattr(log, "shards")
    if sharded:
        cache = getattr(sq, "_ell_cache", None)
        ell_rows = int(getattr(cache, "_row_cap", 0) or 0)
        qrs_cap = 0  # mask-based QRS: the log capacity IS the eval class
    else:
        qrs_cap = int(sq._qrs.capacity)
        ell_rows = int(sq._qrs._ell_packer.num_rows)
    return KernelGridSpec(
        num_vertices=int(log.num_vertices),
        log_capacity=int(log.capacity),
        qrs_capacity=qrs_cap,
        semiring=sq.semiring.name,
        method=sq.method,
        q_cap=int(getattr(sq, "_q_cap", 0)),
        ell_rows=ell_rows,
        n_shards=int(log.n_shards) if sharded else 0,
    )


def observed_ell_ladder(sq) -> list[int]:
    """Distinct sticky ELL row classes this replica has actually entered.

    The ladder is data-dependent (repack growth follows the stream's degree
    skew), so :func:`enumerate_grid`'s doubling successors can miss the
    classes a real stream walks.  Reads the packer's recorded
    ``class_history`` (single-host: the QRS's packer; sharded: the per-shard
    packers run in lockstep, so shard 0's history is the group's).
    """
    qrs = getattr(sq, "_qrs", None)
    packer = getattr(qrs, "_ell_packer", None)
    if packer is None:
        cache = getattr(sq, "_ell_cache", None)
        packers = getattr(cache, "_packers", None)
        packer = packers[0] if packers else None
    if packer is None:
        return []
    out: list[int] = []
    for r in packer.class_history:
        if r and r not in out:
            out.append(int(r))
    return out


def ladder_specs(sq) -> list[KernelGridSpec]:
    """Current grid point plus one spec per observed ELL growth class.

    Checkpointing these into ``grid.json`` (``warmup(ladder_specs(sq),
    cache_dir=...)``) lets :func:`warm_from_manifest` pre-trace the exact
    repack ladder a previous run walked, so a first-boot replica of the
    same stream never compiles on a data-dependent ELL growth.
    """
    base = grid_for(sq)
    out = [base]
    for r in observed_ell_ladder(sq):
        if r != base.ell_rows:
            out.append(dataclasses.replace(base, ell_rows=r))
    return out


def enumerate_grid(
    specs: Union[KernelGridSpec, Iterable[KernelGridSpec]],
    *,
    growth_steps: int = 0,
) -> list[KernelGridSpec]:
    """Dedup spec(s) and append their capacity-growth successors.

    Each growth step doubles the three amortized capacities along their real
    growth ladders (log: STREAM_ALIGN quanta; QRS slots: PAD_ALIGN; ELL
    rows: the packer's row alignment), so a replica that repacks mid-stream
    still finds its post-growth kernels precompiled.
    """
    from repro.core.qrs import PAD_ALIGN
    from repro.graph.stream import STREAM_ALIGN
    from repro.utils.padding import round_up

    if isinstance(specs, KernelGridSpec):
        specs = [specs]
    out: list[KernelGridSpec] = []
    seen: set[str] = set()

    def add(s: KernelGridSpec):
        if s.key() not in seen:
            seen.add(s.key())
            out.append(s)

    for spec in specs:
        add(spec)
        s = spec
        for _ in range(growth_steps):
            s = dataclasses.replace(
                s,
                log_capacity=round_up(2 * s.log_capacity, STREAM_ALIGN),
                qrs_capacity=(
                    round_up(2 * s.qrs_capacity, PAD_ALIGN)
                    if s.qrs_capacity else 0
                ),
                ell_rows=round_up(2 * s.ell_rows, 8) if s.ell_rows else 0,
            )
            add(s)
    return out


# ==========================================================================
# Persistent executable cache + grid manifest
# ==========================================================================
def enable_persistent_cache(cache_dir: str) -> bool:
    """Point JAX's persistent compilation cache at ``cache_dir``.

    Every compile after this call is written to disk keyed by computation
    hash, and later processes load the executable instead of re-running XLA.
    Returns False (without raising) on JAX builds lacking the knobs.
    """
    os.makedirs(cache_dir, exist_ok=True)
    ok = True
    for name, value in (
        ("jax_compilation_cache_dir", str(cache_dir)),
        ("jax_persistent_cache_min_compile_time_secs", 0),
        ("jax_persistent_cache_min_entry_size_bytes", 0),
    ):
        try:
            jax.config.update(name, value)
        except Exception:
            ok = False
    return ok


def save_grid(specs: Iterable[KernelGridSpec], cache_dir: str) -> str:
    """Write the grid manifest next to the executable cache (atomic)."""
    os.makedirs(cache_dir, exist_ok=True)
    path = os.path.join(cache_dir, GRID_MANIFEST)
    payload = {
        "format": GRID_FORMAT,
        "specs": [s.to_json() for s in specs],
    }
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, path)
    return path


def load_grid(cache_dir: str) -> list[KernelGridSpec]:
    path = os.path.join(cache_dir, GRID_MANIFEST)
    with open(path) as f:
        payload = json.load(f)
    if int(payload.get("format", 0)) != GRID_FORMAT:
        raise ValueError(f"unsupported grid manifest format in {path}")
    return [KernelGridSpec.from_json(d) for d in payload["specs"]]


# ==========================================================================
# AOT: trace from ShapeDtypeStructs, compile via lower().compile()
# ==========================================================================
def aot_compile(spec: KernelGridSpec) -> dict:
    """Ahead-of-time compile the core engine kernels for one grid point.

    Traces each jitted entry point from ``ShapeDtypeStruct``\\ s (no data,
    no transfers) and runs the XLA compile now — with the persistent cache
    enabled the executables land on disk.  Returns ``{kernel: "ok" | error
    string}``.  The vmapped/``shard_map`` dispatch variants are not
    AOT-traceable through the module-level entry points; :func:`warmup`
    covers those by dummy invocation.
    """
    from repro.core.bounds import detect_uvv
    from repro.core.concurrent import concurrent_fixpoint_batch
    from repro.core.engine import (
        compute_fixpoint,
        compute_parents,
        incremental_fixpoint,
        invalidate_from_deletions,
    )
    from repro.core.semiring import get_semiring

    sr = get_semiring(spec.semiring)
    v, e = spec.num_vertices, spec.log_capacity

    def f32(*s):
        return jax.ShapeDtypeStruct(s, jnp.float32)

    def i32(*s):
        return jax.ShapeDtypeStruct(s, jnp.int32)

    def b1(*s):
        return jax.ShapeDtypeStruct(s, jnp.bool_)

    def u32(*s):
        return jax.ShapeDtypeStruct(s, jnp.uint32)

    report: dict = {}

    def compile_(name, fn, *args, **statics):
        try:
            fn.lower(*args, **statics).compile()
            report[name] = "ok"
        except Exception as exc:  # record, never fail the warm path
            report[name] = f"{type(exc).__name__}: {exc}"

    edge = (i32(e), i32(e), f32(e), b1(e))
    compile_(
        "compute_fixpoint", compute_fixpoint, *edge,
        sr=sr, source=i32(), num_vertices=v, sorted_edges=False,
    )
    compile_(
        "incremental_fixpoint", incremental_fixpoint, f32(v), *edge,
        sr=sr, num_vertices=v, sorted_edges=False,
    )
    compile_(
        "compute_parents", compute_parents, f32(v), *edge,
        sr=sr, source=i32(), num_vertices=v, sorted_edges=False,
    )
    compile_(
        "invalidate_from_deletions", invalidate_from_deletions,
        f32(v), i32(v), b1(e), i32(e),
        sr=sr, source=i32(), num_vertices=v,
    )
    compile_("detect_uvv", detect_uvv, f32(v), f32(v))
    eq = spec.qrs_capacity
    if eq:
        compile_(
            "incremental_fixpoint@qrs", incremental_fixpoint,
            f32(v), i32(eq), i32(eq), f32(eq), b1(eq),
            sr=sr, num_vertices=v, sorted_edges=False,
        )
        if spec.q_cap:
            compile_(
                "concurrent_fixpoint_batch@qrs", concurrent_fixpoint_batch,
                f32(spec.q_cap, v), i32(eq), i32(eq), f32(eq),
                u32(eq, 1), b1(eq),
                sr=sr, num_vertices=v, num_snapshots=1, sorted_edges=False,
            )
    return report


# ==========================================================================
# Warmup: drive a synthetic replica through every serving entry point
# ==========================================================================
def _dummy_query(spec: KernelGridSpec):
    """Capacity-matched synthetic replica: one edge, window of one snapshot.

    Constructing the query through the public front door guarantees every
    dummy launch has exactly the shapes/dtypes the real serving path will
    use — the compile classes are injected the same way checkpoint restore
    does it (``min_capacity``/``min_ell_rows``/``_q_cap``).
    """
    from repro.core.api import StreamingQuery, StreamingQueryBatch
    from repro.core.qrs import PatchableQRS
    from repro.core.semiring import get_semiring
    from repro.graph.stream import SnapshotLog

    sr = get_semiring(spec.semiring)
    v = spec.num_vertices
    if spec.n_shards:
        from repro.graph.shardlog import ShardedSnapshotLog

        log = ShardedSnapshotLog(v, spec.n_shards, capacity=spec.log_capacity)
    else:
        log = SnapshotLog(v, capacity=spec.log_capacity)
    log.append_snapshot(
        np.asarray([0], np.int64), np.asarray([min(1, v - 1)], np.int64),
        np.asarray([1.0], np.float32), _EMPTY, _EMPTY,
    )
    if spec.q_cap:
        sq = StreamingQueryBatch(log, sr, [0], window=1, method=spec.method)
        sq._q_cap = max(sq._q_cap, int(spec.q_cap))
    else:
        sq = StreamingQuery(log, sr, 0, window=1, method=spec.method)
    sq._ensure_primed()
    # re-enter the spec's eval-path capacity classes (prime used the tiny
    # window's natural ones), exactly as checkpoint restore does
    if spec.n_shards:
        if spec.ell_rows and spec.method == "cqrs_ell":
            sq._ell_cache = sq._make_ell_cache(row_cap=spec.ell_rows)
    elif spec.qrs_capacity or spec.ell_rows:
        sq._qrs = PatchableQRS(
            sq.view, np.asarray(sq._bounds.uvv), sr,
            min_capacity=spec.qrs_capacity, min_ell_rows=spec.ell_rows,
        )
        sq._presence = {}
    return sq


def _warm_one(spec: KernelGridSpec) -> list[str]:
    """Invoke every serving-path kernel for one grid point; returns labels."""
    sq = _dummy_query(spec)  # prime: cold solve + refix + parents (+ eval)
    hit = ["prime"]
    b = sq._bounds
    # eval at the spec's QRS/ELL class (snapshot t = the window's only one)
    t = sq.view.stop - 1
    sq._eval_snapshot(t)
    hit.append("eval")
    # the trim kernel only fires on deletion slides; invoke it directly with
    # an all-False drop mask (converges immediately, same compiled shape)
    if spec.n_shards:
        dev, k = b._device(), b._kernels()
        dropped = jnp.asarray(
            np.zeros(sq.view.log.n_shards * sq.view.log.capacity, bool)
        )
        k["invalidate"](
            b.val_cap, b.parent_cap, dropped, dev["src"], b.source
        )
    else:
        src, _ = b._edges()
        dropped = jnp.asarray(np.zeros(sq.view.log.capacity, bool))
        b._invalidate(b.val_cap, b.parent_cap, dropped, src)
    hit.append("invalidate")
    # maintenance re-relax at the final masks (the per-slide hot pair)
    if spec.n_shards:
        dev, k = b._device(), b._kernels()
        inter = b._stack(sq.view.intersection_masks())
        b._fixpoint(k, b.val_cap, dev, dev["w_cap"], inter, tally=False)
    else:
        src, dst = b._edges()
        w_cap, _ = b._weights()
        b._refix(
            b.val_cap, src, dst, w_cap,
            jnp.asarray(sq.view.intersection_mask()),
        )
    hit.append("refix")
    return hit


def warmup(
    specs: Union[KernelGridSpec, Iterable[KernelGridSpec]],
    *,
    cache_dir: Optional[str] = None,
    growth_steps: int = 0,
    aot: bool = True,
) -> dict:
    """Precompile the kernel grid for ``specs`` (plus growth successors).

    With ``cache_dir`` the persistent executable cache is enabled first and
    the grid manifest is written there, so a restarted process can call
    :func:`warm_from_manifest` and reload every executable from disk.
    Sharded grid points are skipped (and reported) when the process has
    fewer devices than shards.  Returns a report dict.
    """
    if cache_dir is not None:
        enable_persistent_cache(cache_dir)
    grid = enumerate_grid(specs, growth_steps=growth_steps)
    t0 = time.perf_counter()
    report: dict = {"specs": [], "skipped": [], "aot": {}}
    for spec in grid:
        if spec.n_shards and len(jax.devices()) < spec.n_shards:
            report["skipped"].append(
                {"key": spec.key(),
                 "reason": f"{spec.n_shards} shards > "
                           f"{len(jax.devices())} devices"}
            )
            continue
        if aot and not spec.n_shards:
            report["aot"][spec.key()] = aot_compile(spec)
        hit = _warm_one(spec)
        report["specs"].append({"key": spec.key(), "warmed": hit})
    if cache_dir is not None:
        save_grid(grid, cache_dir)
        report["manifest"] = os.path.join(cache_dir, GRID_MANIFEST)
    report["seconds"] = time.perf_counter() - t0
    return report


def warm_from_manifest(cache_dir: str, **kwargs) -> dict:
    """Replay a saved grid manifest: the restarted-replica warm path.

    Re-traces every grid point against the persistent executable cache in
    ``cache_dir`` — the expensive XLA compiles are disk hits — and seeds the
    in-memory jit caches so the serving path never lowers or compiles.
    """
    return warmup(load_grid(cache_dir), cache_dir=cache_dir, **kwargs)

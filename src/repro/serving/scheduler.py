"""Batched request schedulers: LM decode slots + evolving-graph query serving.

Three front-ends share this module:

* ``RequestScheduler`` — LM decoding.  Maintains a fixed pool of B decode
  slots over one shared KV cache; incoming requests claim free slots,
  finished sequences (EOS or length cap) release them.  The jitted decode
  step always runs the full (B,) batch with a slot mask — static shapes, no
  recompilation — the standard TPU serving pattern (orbit/vLLM-style without
  paging).
* ``QueryBatcher.submit``/``flush`` — one-shot vertex queries.  Requests that
  share a graph window and semiring are grouped and launched as one Q×S×V
  CQRS batch (``repro.core.baselines.run_cqrs_batch``), amortizing bounds,
  shared-QRS compaction, and the concurrent fixpoint across the group.
* ``QueryBatcher.watch``/``advance_window`` — standing queries over a
  *sliding* window.  Each watched (query, source) keeps a warm
  :class:`~repro.core.api.StreamingQuery` (bounds + witness parents +
  patched QRS + cached rows) on a shared
  :class:`~repro.graph.stream.WindowView`; ``advance_window`` appends a
  snapshot delta, slides the shared view once, and advances every watcher
  incrementally instead of re-evaluating their windows from scratch.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Callable, Optional, Sequence

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list
    max_new_tokens: int = 32
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class RequestScheduler:
    def __init__(self, batch_size: int, eos_id: int = 0, max_len: int = 2048):
        self.batch_size = batch_size
        self.eos_id = eos_id
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * batch_size
        self.positions = np.zeros(batch_size, np.int32)

    def submit(self, req: Request):
        self.queue.append(req)

    def _fill_slots(self):
        for i in range(self.batch_size):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                self.positions[i] = 0

    def active(self) -> int:
        return sum(s is not None for s in self.slots) + len(self.queue)

    def run(self, decode_token_fn: Callable, max_steps: int = 256) -> list:
        """Drive decode until all requests finish.

        ``decode_token_fn(tokens (B,), positions (B,), mask (B,)) → next (B,)``
        wraps the jitted per-slot decode (prompt feeding + generation unified
        as token-at-a-time for simplicity; prefill fast-path is separate).
        """
        finished = []
        for _ in range(max_steps):
            self._fill_slots()
            if not any(self.slots):
                break
            tokens = np.zeros(self.batch_size, np.int32)
            mask = np.zeros(self.batch_size, bool)
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                pos = self.positions[i]
                if pos < len(req.prompt):
                    tokens[i] = req.prompt[pos]
                elif req.generated:
                    tokens[i] = req.generated[-1]
                mask[i] = True
            nxt = np.asarray(
                decode_token_fn(
                    jnp.asarray(tokens), jnp.asarray(self.positions), jnp.asarray(mask)
                )
            )
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                self.positions[i] += 1
                if self.positions[i] >= len(req.prompt):
                    tok = int(nxt[i])
                    req.generated.append(tok)
                    n_new = len(req.generated)
                    if (
                        tok == self.eos_id
                        or n_new >= req.max_new_tokens
                        or self.positions[i] >= self.max_len - 1
                    ):
                        req.done = True
                        finished.append(req)
                        self.slots[i] = None
        return finished


# ==========================================================================
# Evolving-graph query batching (Q×S×V CQRS serving front-end)
# ==========================================================================
@dataclasses.dataclass
class QueryRequest:
    """One vertex-specific query awaiting a batched launch."""

    uid: int
    graph: object  # EvolvingGraph
    query: str  # semiring name
    source: int
    snapshots: Optional[tuple] = None  # sub-window, None = full window
    result: Optional[np.ndarray] = None  # (S, V) once done
    stats: dict = dataclasses.field(default_factory=dict)
    done: bool = False

    def batch_key(self):
        # id(graph): requests share a launch only when they literally share
        # the graph object (same arrays ⇒ same compiled shapes).
        return (id(self.graph), self.query, self.snapshots)


class QueryBatcher:
    """Coalesce vertex queries sharing a graph window into batched launches.

    ``submit`` enqueues; ``flush`` groups the queue by (graph, semiring,
    snapshot window), runs each group — up to ``max_batch`` sources at a
    time — through one batched CQRS evaluation, and scatters the per-source
    ``(S, V)`` slices back onto the finished requests.  Duplicate sources
    within a group are deduplicated for the launch and fan back out.
    """

    def __init__(self, max_batch: int = 32, method: str = "cqrs"):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = max_batch
        self.method = method
        self.queue: deque[QueryRequest] = deque()
        self._uid = itertools.count()
        self._streams: dict[tuple, object] = {}  # warm StreamingQuery state

    def submit(
        self,
        graph,
        query: str,
        source: int,
        snapshots: Optional[Sequence[int]] = None,
    ) -> QueryRequest:
        req = QueryRequest(
            uid=next(self._uid),
            graph=graph,
            query=str(query),
            source=int(source),
            snapshots=None if snapshots is None else tuple(int(s) for s in snapshots),
        )
        self.queue.append(req)
        return req

    def pending(self) -> int:
        return len(self.queue)

    def flush(self) -> list:
        """Run every queued request; returns them in submission order.

        Requests are grouped by batch key, each group's *unique* sources are
        chunked into ``max_batch``-sized launches, and results fan back out
        to every request (duplicates share one launch slot).  If a launch
        raises, every not-yet-finished request is re-queued before the
        exception propagates — nothing is silently dropped.
        """
        from repro.core.api import MultiQuery

        by_key: dict = {}
        submitted = list(self.queue)
        self.queue.clear()
        for req in submitted:
            by_key.setdefault(req.batch_key(), []).append(req)

        try:
            for reqs in by_key.values():
                by_source: dict = {}
                for r in reqs:
                    by_source.setdefault(r.source, []).append(r)
                uniq = sorted(by_source)
                for chunk_start in range(0, len(uniq), self.max_batch):
                    sources = uniq[chunk_start : chunk_start + self.max_batch]
                    mq = MultiQuery(
                        reqs[0].graph, reqs[0].query, sources,
                        snapshots=reqs[0].snapshots,
                    )
                    mq.evaluate(self.method)
                    stats = dict(mq.stats, batched_queries=len(sources))
                    for s in sources:
                        # copy: don't pin the whole (Q, S, V) batch array to
                        # the lifetime of one request's (S, V) slice
                        res = mq.result_for(s).copy()
                        for r in by_source[s]:
                            r.result = res
                            r.stats = stats
                            r.done = True
        except BaseException:
            self.queue.extend(r for r in submitted if not r.done)
            raise
        return submitted

    # -- sliding-window serving (warm per-(window, query) state) ------------
    def watch(self, view, query: str, source: int, *, method: Optional[str] = None):
        """Register a standing query on a shared sliding window.

        Returns the warm :class:`~repro.core.api.StreamingQuery` (idempotent:
        watching the same (view, query, source, method) again returns the
        existing instance with its state intact).  ``method`` defaults to the
        batcher's method when it is a streaming engine, else ``"cqrs"``.
        """
        from repro.core.api import StreamingQuery

        method = method or (
            self.method if self.method in ("cqrs", "cqrs_ell") else "cqrs"
        )
        key = (id(view), str(query), int(source), method)
        sq = self._streams.get(key)
        if sq is None:
            sq = StreamingQuery(view, str(query), int(source), method=method)
            sq.results  # prime eagerly: pay the cold solve before traffic
            self._streams[key] = sq
        return sq

    def watching(self, view=None) -> list:
        """Warm streaming queries (optionally restricted to one view)."""
        return [sq for sq in self._streams.values()
                if view is None or sq.view is view]

    def advance_window(self, view, delta=None) -> dict:
        """Append ``delta`` to the view's log, slide, advance every watcher.

        The shared view slides exactly once per appended snapshot; each
        watcher folds the slide diff into its warm bounds/QRS state and
        evaluates only the appended snapshot.  Returns
        ``{(query, source): (S, V) results}`` for the watchers on ``view``.
        (A (query, source) watched under both engine methods yields one
        entry — both engines are bit-for-bit identical by contract.)

        Slide history consumed by every watcher is pruned from the shared
        view afterwards, so long-running serving loops stay bounded.
        """
        if delta is not None:
            view.log.append_snapshot(*delta)
        view.slide_to_tip()
        watchers = self.watching(view)
        out = {
            (sq.semiring.name, sq.source): sq.advance() for sq in watchers
        }
        if watchers:
            view.prune_history(min(sq.diff_pos for sq in watchers))
        return out

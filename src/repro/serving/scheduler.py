"""Batched request schedulers: LM decode slots + evolving-graph query serving.

Three front-ends share this module:

* ``RequestScheduler`` — LM decoding.  Maintains a fixed pool of B decode
  slots over one shared KV cache; incoming requests claim free slots,
  finished sequences (EOS or length cap) release them.  The jitted decode
  step always runs the full (B,) batch with a slot mask — static shapes, no
  recompilation — the standard TPU serving pattern (orbit/vLLM-style without
  paging).
* ``QueryBatcher.submit``/``flush`` — one-shot vertex queries.  Requests that
  share a graph window and semiring are grouped and launched as one Q×S×V
  CQRS batch (``repro.core.baselines.run_cqrs_batch``), amortizing bounds,
  shared-QRS compaction, and the concurrent fixpoint across the group.
* ``QueryBatcher.watch``/``advance_window`` — standing queries over a
  *sliding* window.  Watchers sharing a (view, query, method) are grouped
  into ONE warm :class:`~repro.core.api.StreamingQueryBatch` (``(Q, V)``
  bounds + witness parents + one shared patched QRS + cached ``(Q, V)``
  rows) on a shared :class:`~repro.graph.stream.WindowView` — or, for SPMD
  serving, a
  :class:`~repro.distributed.stream_shard.ShardedStreamingQueryBatch` on a
  :class:`~repro.graph.shardlog.ShardedWindowView`; ``advance_window``
  appends a snapshot delta, slides the shared view once, and folds the
  slide into every watcher group with one batched advance per group — NOT Q
  sequential per-watcher advances — bit-for-bit equal to the sequential
  loop.  Warm state is bounded (LRU capacity + watch-stamped TTL +
  evict-on-divergence, see ``cache_info``; evicting a watcher drops its
  lane from the group) so serving memory stays bounded under rotating
  traffic.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import OrderedDict, deque, namedtuple
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.ft.faultinject import DeadLetterLog, stall_point
from repro.obs.metrics import get_registry
from repro.obs.trace import mark_ready, span

StreamCacheInfo = namedtuple(
    "StreamCacheInfo",
    # trailing degraded-serving fields keep positional unpacking of the
    # original six stable
    ["hits", "misses", "evictions", "currsize", "maxsize", "lane_supersteps",
     "degraded", "slides_behind"],
)


class AdvanceRetryExhausted(RuntimeError):
    """A group's advance kept failing past the batcher's retry budget.

    Raised out of the serving path ONLY after ``retry_budget`` consecutive
    failed advances of one watcher group — the escalation signal
    :class:`~repro.ft.recovery.ServeSupervisor` answers with a checkpoint
    restore.  Until then failures degrade to last-good results.
    """


class WindowResults(dict):
    """``{(query, source): (S, V) rows}`` plus staleness metadata.

    A plain dict (existing consumers index it unchanged) carrying the
    degraded-mode contract: ``degraded`` is True when any served group
    returned last-good rows instead of folding the newest slide in, and
    ``slides_behind`` maps every watcher to how many window slides its rows
    lag the log tip (0 = fresh).  ``retries`` totals the failed advance
    attempts currently outstanding across the window's groups.
    """

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.degraded: bool = False
        self.slides_behind: dict = {}
        self.retries: int = 0


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list
    max_new_tokens: int = 32
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class RequestScheduler:
    def __init__(self, batch_size: int, eos_id: int = 0, max_len: int = 2048):
        self.batch_size = batch_size
        self.eos_id = eos_id
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * batch_size
        self.positions = np.zeros(batch_size, np.int32)

    def submit(self, req: Request):
        self.queue.append(req)

    def _fill_slots(self):
        for i in range(self.batch_size):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                self.positions[i] = 0

    def active(self) -> int:
        return sum(s is not None for s in self.slots) + len(self.queue)

    def run(self, decode_token_fn: Callable, max_steps: int = 256) -> list:
        """Drive decode until all requests finish.

        ``decode_token_fn(tokens (B,), positions (B,), mask (B,)) → next (B,)``
        wraps the jitted per-slot decode (prompt feeding + generation unified
        as token-at-a-time for simplicity; prefill fast-path is separate).
        """
        finished = []
        for _ in range(max_steps):
            self._fill_slots()
            if not any(self.slots):
                break
            tokens = np.zeros(self.batch_size, np.int32)
            mask = np.zeros(self.batch_size, bool)
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                pos = self.positions[i]
                if pos < len(req.prompt):
                    tokens[i] = req.prompt[pos]
                elif req.generated:
                    tokens[i] = req.generated[-1]
                mask[i] = True
            nxt = np.asarray(
                decode_token_fn(
                    jnp.asarray(tokens), jnp.asarray(self.positions), jnp.asarray(mask)
                )
            )
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                self.positions[i] += 1
                if self.positions[i] >= len(req.prompt):
                    tok = int(nxt[i])
                    req.generated.append(tok)
                    n_new = len(req.generated)
                    if (
                        tok == self.eos_id
                        or n_new >= req.max_new_tokens
                        or self.positions[i] >= self.max_len - 1
                    ):
                        req.done = True
                        finished.append(req)
                        self.slots[i] = None
        return finished


# ==========================================================================
# Online resharding policy (layout epochs, serving-path trigger)
# ==========================================================================
@dataclasses.dataclass
class ReshardPolicy:
    """When and how the serving path migrates a sharded log's layout.

    Checked once per served slide (``QueryBatcher.advance_window`` /
    ``ServeSupervisor.run``).  A migration is triggered when any of:

    * ``n_shards`` is set and differs from the log's current shard count
      (elastic resize — replica scale-out/in);
    * the live universe's ``occupancy_spread()`` (max/mean per-shard edges)
      exceeds ``spread_threshold`` (drifting hubs unbalanced the layout);
    * ``on_capacity_growth`` and a shard's edge capacity class grew since
      the last check (growth epochs are natural migration points — the
      kernels recompile for the new capacity class anyway).

    ``min_slides`` rate-limits migrations.  The derived layout is a
    degree-balanced assignment over the live universe
    (:meth:`~repro.graph.shardlog.ShardAssignment.rebalance`); a derived
    layout identical to the current one is skipped, so a balanced stream
    never migrates.
    """

    spread_threshold: float = 1.5
    on_capacity_growth: bool = True
    n_shards: Optional[int] = None
    min_slides: int = 8


def plan_reshard(log, policy: ReshardPolicy, *, capacity_grew: bool = False,
                 slides_since: Optional[int] = None):
    """Evaluate ``policy`` against a sharded log's live occupancy.

    Returns the new :class:`~repro.graph.shardlog.ShardAssignment` to
    migrate to, or ``None`` to keep the current layout.
    """
    if slides_since is not None and slides_since < policy.min_slides:
        return None
    cur = log.assignment
    want = policy.n_shards
    resize = want is not None and int(want) != cur.n_shards
    trigger = (
        resize
        or log.occupancy_spread() > policy.spread_threshold
        or (policy.on_capacity_growth and capacity_grew)
    )
    if not trigger:
        return None
    hist = log.live_degree_histogram()
    if resize:
        return cur.resize(int(want), hist)
    new = cur.rebalance(hist)
    if np.array_equal(new.positions, cur.positions):
        return None  # same layout would be installed: skip the no-op epoch
    return new


# ==========================================================================
# Evolving-graph query batching (Q×S×V CQRS serving front-end)
# ==========================================================================
@dataclasses.dataclass
class QueryRequest:
    """One vertex-specific query awaiting a batched launch."""

    uid: int
    graph: object  # EvolvingGraph
    query: str  # semiring name
    source: int
    snapshots: Optional[tuple] = None  # sub-window, None = full window
    result: Optional[np.ndarray] = None  # (S, V) once done
    stats: dict = dataclasses.field(default_factory=dict)
    done: bool = False

    def batch_key(self):
        # id(graph): requests share a launch only when they literally share
        # the graph object (same arrays ⇒ same compiled shapes).
        return (id(self.graph), self.query, self.snapshots)


class QueryBatcher:
    """Coalesce vertex queries sharing a graph window into batched launches.

    ``submit`` enqueues; ``flush`` groups the queue by (graph, semiring,
    snapshot window), runs each group — up to ``max_batch`` sources at a
    time — through one batched CQRS evaluation, and scatters the per-source
    ``(S, V)`` slices back onto the finished requests.  Duplicate sources
    within a group are deduplicated for the launch and fan back out.
    """

    def __init__(
        self,
        max_batch: int = 32,
        method: str = "cqrs",
        *,
        stream_capacity: int = 64,
        stream_ttl: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
        pipelined: bool = False,
        quarantine_factor: Optional[float] = None,
        reshard_policy: Optional[ReshardPolicy] = None,
        retry_budget: int = 8,
        backoff_base: float = 0.05,
        backoff_cap: float = 2.0,
        advance_timeout: Optional[float] = None,
        events=None,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if stream_capacity < 1:
            raise ValueError("stream_capacity must be >= 1")
        if retry_budget < 0:
            raise ValueError("retry_budget must be >= 0")
        self.max_batch = max_batch
        self.method = method
        self.stream_capacity = stream_capacity
        self.stream_ttl = stream_ttl
        # pipelined serving: ingest + per-group advances run on a single
        # worker owned by this batcher, eval fetches are deferred to the
        # consumer's .result() — see advance_window_async
        self.pipelined = bool(pipelined)
        # lane-aware QoS: a lane whose accumulated maintenance supersteps
        # exceed factor × its group's median is quarantined into its own
        # single-lane batch group (and preferred for TTL eviction) so one
        # pathological watcher stops holding its group's lockstep
        # while_loops hostage.  None disables quarantining.
        self.quarantine_factor = quarantine_factor
        # online resharding: after each served slide the policy is checked
        # against the view's log and, when it fires, every group on the
        # view live-migrates to the derived layout as part of the same
        # (pipelined-executor) window job — serving lanes keep draining
        self.reshard_policy = reshard_policy
        self._reshard_state: dict = {}  # id(view) → {"slides", "e_cap"}
        # degraded-mode serving: a group whose advance fails is rolled back
        # (transactional slide) and served from its last-good fixpoint with
        # staleness metadata; the advance is retried with capped exponential
        # backoff and escalates (AdvanceRetryExhausted) only once
        # `retry_budget` consecutive attempts failed.  `advance_timeout`
        # flags slow-but-successful advances (metrics only, never degraded).
        self.retry_budget = int(retry_budget)
        self.backoff_base = float(backoff_base)
        self.backoff_cap = float(backoff_cap)
        self.advance_timeout = advance_timeout
        self.events = events
        self._degraded: dict = {}  # gkey → {"failures", "next_retry"}
        # poisoned delta batches rejected by log validation (ingest path)
        self.dead_letters = DeadLetterLog()
        self._clock = clock
        self._executor: Optional[ThreadPoolExecutor] = None
        self.queue: deque[QueryRequest] = deque()
        self._uid = itertools.count()
        # warm watcher handles, LRU-ordered (oldest first); each value is a
        # _StreamEntry so eviction can reason about idleness/divergence.
        # The actual warm state lives in _batches: one StreamingQueryBatch
        # per (view, query, method) group, shared by its watchers' lanes
        # (quarantined watchers get a dedicated per-source group key).
        self._streams: "OrderedDict[tuple, _StreamEntry]" = OrderedDict()
        self._batches: dict = {}
        # per-instance counters stay the cache_info() façade (tests pin
        # them); every bump is mirrored into the metrics registry bound at
        # construction (use_registry() scopes a batcher to a test registry)
        self._stream_hits = 0
        self._stream_misses = 0
        self._stream_evictions = 0
        self._stream_quarantines = 0
        self._obs = get_registry()

    def _obs_inc(self, name: str, help: str, n: int = 1, **labels) -> None:
        self._obs.counter(name, help).inc(n, **labels)
        self._obs.gauge(
            "serving_stream_watchers", "warm watcher handles resident"
        ).set(len(self._streams))

    def submit(
        self,
        graph,
        query: str,
        source: int,
        snapshots: Optional[Sequence[int]] = None,
    ) -> QueryRequest:
        req = QueryRequest(
            uid=next(self._uid),
            graph=graph,
            query=str(query),
            source=int(source),
            snapshots=None if snapshots is None else tuple(int(s) for s in snapshots),
        )
        self.queue.append(req)
        return req

    def pending(self) -> int:
        return len(self.queue)

    def flush(self) -> list:
        """Run every queued request; returns them in submission order.

        Requests are grouped by batch key, each group's *unique* sources are
        chunked into ``max_batch``-sized launches, and results fan back out
        to every request (duplicates share one launch slot).  If a launch
        raises, every not-yet-finished request is re-queued before the
        exception propagates — nothing is silently dropped.
        """
        from repro.core.api import MultiQuery

        by_key: dict = {}
        submitted = list(self.queue)
        self.queue.clear()
        for req in submitted:
            by_key.setdefault(req.batch_key(), []).append(req)

        try:
            for reqs in by_key.values():
                by_source: dict = {}
                for r in reqs:
                    by_source.setdefault(r.source, []).append(r)
                uniq = sorted(by_source)
                for chunk_start in range(0, len(uniq), self.max_batch):
                    sources = uniq[chunk_start : chunk_start + self.max_batch]
                    mq = MultiQuery(
                        reqs[0].graph, reqs[0].query, sources,
                        snapshots=reqs[0].snapshots,
                    )
                    mq.evaluate(self.method)
                    stats = dict(mq.stats, batched_queries=len(sources))
                    for s in sources:
                        # copy: don't pin the whole (Q, S, V) batch array to
                        # the lifetime of one request's (S, V) slice
                        res = mq.result_for(s).copy()
                        for r in by_source[s]:
                            r.result = res
                            r.stats = stats
                            r.done = True
        except BaseException:
            self.queue.extend(r for r in submitted if not r.done)
            raise
        return submitted

    # -- sliding-window serving (warm per-(window, query) state) ------------
    def watch(self, view, query: str, source: int, *, method: Optional[str] = None):
        """Register a standing query on a shared sliding window.

        Returns a warm watcher handle (idempotent: watching the same (view,
        query, source, method) again returns the existing handle with its
        state intact).  ``method`` defaults to the batcher's method when it
        is a streaming engine, else ``"cqrs"``.

        Watchers sharing a (view, query, method) are folded into ONE
        :class:`~repro.core.api.StreamingQueryBatch` — the handle is a lane
        of that group: registration primes only the new lane, and
        ``advance_window`` serves the whole group with one batched advance.

        Warm state is bounded: at most ``stream_capacity`` watchers are
        kept, least-recently-*watched* evicted first, and watchers are also
        dropped when idle past ``stream_ttl`` seconds or *divergent* — their
        view's log has slid at least a full window past them, or the shared
        view pruned slide history they never consumed — since such state
        would be rebuilt from scratch on its next advance anyway.  Evicting
        a watcher drops its lane from the group (the group itself is dropped
        with its last lane).  Recency/idleness is stamped by ``watch()``
        calls only, never by ``advance_window`` — being served says nothing
        about whether a client still reads the result, so abandoned watchers
        expire even on a view that advances every slide.  :meth:`cache_info`
        exposes the counters.
        """
        from repro.core.api import StreamingQueryBatch

        self._drain()  # admission mutates group state: no in-flight advances
        if method is None:
            method = (self.method if self.method in ("cqrs", "cqrs_ell")
                      else "cqrs")
        key = (id(view), str(query), int(source), method)
        entry = self._streams.get(key)
        if entry is not None:
            # touch BEFORE housekeeping: a re-watch is exactly the liveness
            # signal TTL measures, so the warm state must survive it
            self._stream_hits += 1
            self._obs_inc("serving_stream_hits_total", "warm-cache watch hits")
            entry.last_used = self._clock()
            self._streams.move_to_end(key)
        self._evict_stale(exempt_view=view)
        if entry is None:
            self._stream_misses += 1
            self._obs_inc(
                "serving_stream_misses_total", "warm-cache watch misses"
            )
            gkey = (id(view), str(query), method)
            batch = self._batches.get(gkey)
            if batch is None:
                batch = StreamingQueryBatch(
                    view, str(query), [int(source)], method=method
                )
                batch._defer_fetch = self.pipelined
                batch.events = self.events
                batch.results  # prime eagerly: pay the cold solve pre-traffic
                self._batches[gkey] = batch
            else:
                batch.add_source(int(source))  # primes only the new lane
            entry = _StreamEntry(
                sq=_BatchWatcher(batch=batch, source=int(source)),
                last_used=self._clock(),
                gkey=gkey,
            )
            self._streams[key] = entry
            while len(self._streams) > self.stream_capacity:
                # quarantined lanes are the preferred victims: their warm
                # state is the most expensive to keep and the least shared
                old_key = next(
                    (k for k, e in self._streams.items() if e.quarantined),
                    next(iter(self._streams)),  # else plain LRU (oldest)
                )
                old_entry = self._streams.pop(old_key)
                self._drop_lane(old_key, old_entry)
                self._stream_evictions += 1
                self._obs_inc(
                    "serving_stream_evictions_total",
                    "warm watcher evictions by cause",
                    reason="capacity",
                )
        return entry.sq

    def _drop_lane(self, key: tuple, entry) -> None:
        """Remove an evicted watcher's lane from its batch group."""
        gkey = entry.gkey
        batch = self._batches.get(gkey)
        if batch is None or batch is not entry.sq.batch:
            return
        if any(e.gkey == gkey for e in self._streams.values()):
            batch.remove_source(entry.sq.source)
        else:
            del self._batches[gkey]  # last lane: drop the whole group
            self._degraded.pop(gkey, None)

    def watching(self, view=None) -> list:
        """Warm streaming queries (optionally restricted to one view)."""
        return [e.sq for e in self._streams.values()
                if view is None or e.sq.view is view]

    def cache_info(self) -> StreamCacheInfo:
        """LRU/TTL/divergence statistics for the warm streaming-query cache.

        ``lane_supersteps`` maps ``(query, source)`` to accumulated per-lane
        maintenance supersteps (each lane's own freeze steps, not the
        lockstep max) — a watcher whose count runs far ahead of its group is
        flagging pathological churn around its source and is a candidate
        for eviction or a dedicated batch.  The same ``(query, source)``
        watched on several views (or under both engine methods) collapses
        to ONE entry carrying the max over its groups — the hottest
        instance; per-group introspection goes through the watcher handle's
        ``batch.lane_supersteps``.
        """
        lanes: dict = {}
        for batch in self._batches.values():
            for s, steps in batch.lane_supersteps.items():
                key = (batch.semiring.name, s)
                lanes[key] = max(lanes.get(key, 0), steps)
        behind: dict = {}
        for gkey in self._degraded:
            batch = self._batches.get(gkey)
            if batch is None:
                continue
            lag = max(0, batch.view.history_end - batch.diff_pos)
            for e in self._streams.values():
                if e.gkey == gkey:
                    behind[(e.sq.semiring.name, e.sq.source)] = lag
        return StreamCacheInfo(
            hits=self._stream_hits,
            misses=self._stream_misses,
            evictions=self._stream_evictions,
            currsize=len(self._streams),
            maxsize=self.stream_capacity,
            lane_supersteps=lanes,
            degraded=bool(self._degraded),
            slides_behind=behind,
        )

    def _is_divergent(self, sq) -> bool:
        """True when ``sq``'s warm state cannot help its next advance.

        Either the view's log has slid ≥ one full window past the view (every
        cached row would be rebuilt), or the shared view pruned slide history
        the query never consumed (it must re-prime).
        """
        view = sq.view
        if view.log.num_snapshots - view.stop >= view.size:
            return True
        return sq.diff_pos < view.history_end - len(view.history)

    def sweep(self, exempt_view=None) -> int:
        """Run TTL/divergence expiry now; returns the evicted entry count.

        The serving path runs this itself — at the top of every
        :meth:`advance_window` and on every :meth:`watch` admission — so a
        caller that only ever advances still observes eviction; ``sweep`` is
        the explicit entry point for callers that want housekeeping between
        slides (e.g. an idle loop).  Recency semantics are unchanged:
        serving never refreshes idleness, only ``watch()`` stamps it.
        """
        self._drain()
        return self._evict_stale(exempt_view=exempt_view)

    def _evict_stale(self, exempt_view=None) -> int:
        """Drop TTL-expired and divergent entries.

        ``exempt_view`` guards only the *divergence* test (the view about to
        be served may legitimately lag its log until ``slide_to_tip``); TTL
        expiry applies to every entry, so abandoned watchers expire even on
        a view that is advanced every slide.  Quarantined lanes expire at
        HALF the TTL — they are the preferred victims (their warm state is
        per-lane, the most expensive to keep per watcher).
        """
        now = self._clock()
        dead = []
        for key, e in self._streams.items():
            ttl = self.stream_ttl
            if ttl is not None and e.quarantined:
                ttl = ttl / 2
            expired = ttl is not None and now - e.last_used > ttl
            divergent = e.sq.view is not exempt_view and self._is_divergent(e.sq)
            if expired or divergent:
                dead.append((key, "ttl" if expired else "divergent"))
        for key, reason in dead:
            entry = self._streams.pop(key)
            self._drop_lane(key, entry)
            self._stream_evictions += 1
            self._obs_inc(
                "serving_stream_evictions_total",
                "warm watcher evictions by cause",
                reason=reason,
            )
        return len(dead)

    def advance_window(self, view, delta=None) -> dict:
        """Append ``delta`` to the view's log, slide, advance every watcher.

        The shared view slides exactly once per appended snapshot; each
        (query, method) GROUP of watchers then folds the slide diff into its
        warm ``(Q, V)`` bounds/QRS state and evaluates the appended snapshot
        for all its lanes with ONE batched advance
        (:meth:`~repro.core.api.StreamingQueryBatch.advance`) — not Q
        sequential per-watcher advances; results are bit-for-bit equal to
        the sequential loop.  Returns ``{(query, source): (S, V) results}``
        for the watchers on ``view``.  (A (query, source) watched under both
        engine methods yields one entry — both engines are bit-for-bit
        identical by contract.)

        Slide history consumed by every group is pruned from the shared
        view afterwards (which also retires unreachable log history), so
        long-running serving loops stay bounded; stale warm state is evicted
        on the way (see :meth:`watch` and :meth:`sweep`).  Note that with
        ``stream_ttl`` set, being served does NOT refresh a watcher's
        idleness — a client must re-``watch`` within the TTL or its
        (query, source) expires and drops out of subsequent results.

        With ``pipelined=True`` this is exactly
        ``advance_window_async(view, delta).result()`` — same state
        transitions on the batcher's worker thread, bit-for-bit identical
        results.
        """
        if self.pipelined:
            return self.advance_window_async(view, delta).result()
        with span("delta_route"):
            self._evict_stale(exempt_view=view)
            self._ingest(view, delta)
            view.slide_to_tip()
        out = WindowResults()
        served = []
        for gkey, batch in list(self._batches.items()):
            if batch.view is not view:
                continue
            # one launch for the whole (query, method) group; a failed
            # advance rolls back and serves last-good rows (degraded mode)
            g = self._serve_group(gkey, batch)
            served.append(batch)
            self._fold_group(out, g)
            # deliberately NOT a recency touch: serving a watcher says nothing
            # about whether any client still reads it — idleness (TTL) and
            # LRU order are stamped only by client-side watch() calls, so an
            # abandoned (query, source) does eventually expire even on a view
            # that is advanced every slide
        self._quarantine_pathological(view)
        self._maybe_reshard(view)
        if served:
            # min over ALL groups, degraded included: a lagging group's
            # unconsumed diffs must stay replayable for its retries
            view.prune_history(min(b.diff_pos for b in served))
        return out

    @staticmethod
    def _fold_group(out: WindowResults, g: "_GroupResult") -> None:
        """Merge one group's (possibly degraded) serve into the window dict."""
        out.update(g.materialize())
        out.degraded |= g.degraded
        out.retries += g.retries
        for qs in g.watchers:
            out.slides_behind[qs] = g.slides_behind

    # -- degraded-mode serving ------------------------------------------------
    def _ingest(self, view, delta) -> None:
        """Append a delta batch, absorbing poisoned/torn-append faults.

        The log's validate-before-mutate contract (and the sharded log's
        torn-append self-heal) makes every append all-or-nothing, so the
        serving path can always proceed over durable state: a rejected
        batch is quarantined to the dead-letter log (clean redelivery
        converges bit-for-bit), any other ingest fault is recorded and the
        slide serves whatever committed.  No exception escapes.
        """
        if delta is None:
            return
        try:
            view.log.append_snapshot(*delta)
        except (ValueError, KeyError) as exc:
            snapshot = int(view.log.num_snapshots)
            self.dead_letters.record(delta, exc, {"snapshot": snapshot})
            self._obs.counter(
                "delta_quarantined_total",
                "delta batches rejected by log validation and dead-lettered",
            ).inc()
            if self.events is not None:
                self.events.emit(
                    "quarantine", error=str(exc), snapshot=snapshot,
                )
        except Exception as exc:
            self._obs.counter(
                "ingest_faults_total",
                "ingest faults absorbed by the serving path",
            ).inc()
            if self.events is not None:
                self.events.emit("ingest_fault", error=str(exc))

    def _serve_group(self, gkey: tuple, batch) -> "_GroupResult":
        """Advance one group; never raises within the retry budget.

        On success the freshly folded rows are captured; on failure the
        transactional advance has already rolled the group back to its
        pre-slide fixpoint, so last-good rows are simply the group's CURRENT
        rows, tagged with how many slides they lag (``diff_pos`` rolled back
        with them, so the lag is exact and the next call retries the same
        diffs).  Consecutive failures back off exponentially (capped) and
        raise :class:`AdvanceRetryExhausted` past ``retry_budget``.
        """
        st = self._degraded.get(gkey)
        now = self._clock()
        if st is not None and now < st["next_retry"]:
            # still backing off: don't hammer a failing fold every slide
            return self._stale_result(gkey, batch, st)
        try:
            stall_point("executor_stall")
            t0 = time.perf_counter()
            if self.pipelined:  # dispatch only; the fetch is the consumer's
                batch.advance_nowait()
            else:
                batch.advance()
            elapsed = time.perf_counter() - t0
        except Exception as exc:
            return self._note_advance_failure(gkey, batch, exc)
        if self.advance_timeout is not None and elapsed > self.advance_timeout:
            # slow but successful: flag it, never degrade fresh results
            self._obs.counter(
                "serving_slow_advances_total",
                "group advances exceeding the advance timeout",
            ).inc()
            if self.events is not None:
                self.events.emit(
                    "slow_advance", gkey=str(gkey), seconds=elapsed,
                )
        if st is not None:
            self._degraded.pop(gkey, None)
            self._obs.counter(
                "serving_recoveries_total",
                "degraded groups recovered within the retry budget",
            ).inc()
            if self.events is not None:
                self.events.emit(
                    "recovered", gkey=str(gkey), retries=st["failures"],
                )
        return self._capture_group(batch)

    def _note_advance_failure(self, gkey: tuple, batch, exc) -> "_GroupResult":
        st = self._degraded.get(gkey)
        failures = (st["failures"] if st else 0) + 1
        if failures > self.retry_budget:
            self._obs.counter(
                "serving_retry_exhausted_total",
                "groups escalated after exhausting the retry budget",
            ).inc()
            if self.events is not None:
                self.events.emit(
                    "retry_exhausted", gkey=str(gkey), retries=failures - 1,
                    error=str(exc),
                )
            raise AdvanceRetryExhausted(
                f"group {gkey} failed {failures - 1} retries "
                f"(budget {self.retry_budget}): {exc}"
            ) from exc
        wait = min(self.backoff_base * (2 ** (failures - 1)), self.backoff_cap)
        st = {"failures": failures, "next_retry": self._clock() + wait}
        self._degraded[gkey] = st
        if failures > 1:
            self._obs.counter(
                "serving_retries_total", "failed advance retry attempts"
            ).inc()
        if self.events is not None:
            self.events.emit(
                "degraded", gkey=str(gkey), failures=failures,
                backoff=wait, error=str(exc),
            )
        return self._stale_result(gkey, batch, st)

    def _stale_result(self, gkey: tuple, batch, st: dict) -> "_GroupResult":
        self._obs.counter(
            "serving_degraded_slides_total",
            "group serves answered with last-good (stale) rows",
        ).inc()
        return self._capture_group(
            batch, degraded=True, retries=st["failures"],
        )

    def _capture_group(self, batch, *, degraded: bool = False,
                       retries: int = 0) -> "_GroupResult":
        watchers = [
            (e.sq.semiring.name, e.sq.source)
            for e in self._streams.values() if e.sq.batch is batch
        ]
        return _GroupResult(
            rows=list(batch._rows),
            sources=list(batch.sources),
            watchers=watchers,
            degraded=degraded,
            slides_behind=max(
                0, batch.view.history_end - batch.diff_pos
            ) if degraded else 0,
            retries=retries,
        )

    def _maybe_reshard(self, view) -> Optional[dict]:
        """Check the reshard policy for one served view; migrate if it fires.

        Runs after the window's group advances (every group is caught up to
        the tip, the migration precondition) and inside the same executor
        job on the pipelined path.  Returns the last group's migration
        report, or ``None`` when nothing fired.
        """
        pol = self.reshard_policy
        if pol is None:
            return None
        log = getattr(view, "log", None)
        if log is None or not hasattr(log, "occupancy_spread"):
            return None  # single-host view: nothing to migrate
        groups = [b for b in self._batches.values() if b.view is view]
        if not groups:
            return None
        st = self._reshard_state.setdefault(
            id(view), {"slides": 0, "e_cap": int(log.capacity)}
        )
        st["slides"] += 1
        cap = int(log.capacity)
        grew = cap > st["e_cap"]
        st["e_cap"] = cap
        assignment = plan_reshard(
            log, pol, capacity_grew=grew, slides_since=st["slides"]
        )
        if assignment is None:
            return None
        st["slides"] = 0
        report = None
        for b in groups:  # first call migrates the log; the rest are
            report = b.reshard(assignment)  # view-idempotent lane migrations
        self._obs_inc(
            "serving_reshards_total", "policy-triggered layout migrations"
        )
        return report

    # -- pipelined serving ---------------------------------------------------
    def _ensure_executor(self) -> ThreadPoolExecutor:
        if self._executor is None:
            # ONE worker: group state mutation stays serialized; overlap
            # comes from jax async dispatch (host routing/packing for slide
            # k+1 proceeds while devices execute slide k's fixpoint, whose
            # fetch is deferred to the consumer's .result())
            self._executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="query-batcher"
            )
        return self._executor

    def _drain(self) -> None:
        """Wait for in-flight pipelined work (admission/sweep barrier)."""
        if self._executor is not None:
            self._executor.submit(lambda: None).result()

    def close(self) -> None:
        """Shut down the pipelined worker (no-op when never used)."""
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None

    def advance_window_async(self, view, delta=None) -> "PendingWindow":
        """Pipelined :meth:`advance_window`: returns a handle, not results.

        Ingest (sweep + append + slide) and every per-group advance are
        submitted to the batcher's single worker; the returned
        :class:`PendingWindow` resolves to the same ``{(query, source):
        (S, V)}`` dict — bit-for-bit equal to the synchronous path — when
        ``.result()`` is called.  Group advances only *dispatch* their
        device work (fetches are deferred to ``.result()``), so a caller can
        submit the next window's delta before this one is fetched —
        host-side routing and ELL packing for slide k+1 then overlap the
        devices' slide-k fixpoints.  Back-to-back submissions are safe:
        windows are processed strictly in order on the worker.

        The caller must not mutate the view/log directly while windows are
        in flight (``watch``/``sweep`` are safe: they drain first).
        """
        ex = self._ensure_executor()
        return PendingWindow(ex.submit(self._pre_advance, view, delta))

    def _pre_advance(self, view, delta):
        """Worker-side window job: sweep, append, slide, advance each group.

        Everything for one window runs inside THIS job (the per-group
        futures are fulfilled inline, not re-submitted) so a later window's
        ingest can never overtake an earlier window's group advances on the
        FIFO worker queue.
        """
        with span("delta_route"):
            self._evict_stale(exempt_view=view)
            self._ingest(view, delta)
            view.slide_to_tip()
        items = [(k, b) for k, b in self._batches.items() if b.view is view]
        futs = []
        for gkey, b in items:
            f: Future = Future()
            futs.append(f)
            try:
                f.set_result(self._advance_group(gkey, b))
            except BaseException as exc:  # surfaced at the group's .result()
                f.set_exception(exc)
        post: Future = Future()
        try:
            post.set_result(
                self._post_advance(view, [b for _, b in items])
            )
        except BaseException as exc:
            post.set_exception(exc)
        return futs, post

    def _advance_group(self, gkey, batch):
        """Advance one group; capture its rows WITHOUT fetching them.

        Rides the same transactional/degraded machinery as the synchronous
        path (:meth:`_serve_group`); rows are captured by reference (device
        arrays are immutable, host rows are only ever written at lanes past
        the captured count), so the snapshot stays exact even if the group
        advances again before the consumer materializes it.
        """
        if not any(b is batch for b in self._batches.values()):
            return None  # evicted after submission (sweep won the race)
        return self._serve_group(gkey, batch)

    def _post_advance(self, view, groups) -> None:
        """Worker-side epilogue: QoS quarantine + resharding + pruning."""
        self._quarantine_pathological(view)
        self._maybe_reshard(view)
        served = [
            b for b in groups
            if any(b is bb for bb in self._batches.values())
        ]
        if served:
            view.prune_history(min(b.diff_pos for b in served))

    def _quarantine_pathological(self, view) -> None:
        """Move lanes whose supersteps dwarf their group's median into
        dedicated single-lane groups (see ``quarantine_factor``)."""
        if self.quarantine_factor is None:
            return
        from repro.core.api import StreamingQueryBatch

        for batch in list(self._batches.values()):
            if batch.view is not view or len(batch.sources) < 2:
                continue
            steps = batch.lane_supersteps
            med = sorted(steps.values())[len(steps) // 2]
            threshold = self.quarantine_factor * max(med, 1)
            for s, st in steps.items():
                if st <= threshold or len(batch.sources) < 2:
                    continue
                key = (id(view), batch.semiring.name, int(s), batch.method)
                entry = self._streams.get(key)
                if entry is None or entry.quarantined:
                    continue
                batch.remove_source(s)
                solo = StreamingQueryBatch(
                    view, batch.semiring.name, [int(s)], method=batch.method
                )
                solo._defer_fetch = self.pipelined
                solo.events = self.events
                solo.results  # prime the dedicated group eagerly
                gkey = (id(view), batch.semiring.name, batch.method, "q", s)
                self._batches[gkey] = solo
                entry.sq.batch = solo
                entry.gkey = gkey
                entry.quarantined = True
                self._stream_quarantines += 1
                self._obs_inc(
                    "serving_quarantines_total",
                    "lanes moved to dedicated QoS groups",
                )

    def quarantined(self) -> list:
        """``(query, source)`` pairs currently serving from quarantine."""
        return [
            (e.sq.semiring.name, e.sq.source)
            for e in self._streams.values() if e.quarantined
        ]

    # -- warm-state checkpoints ---------------------------------------------
    def checkpoint_state(self, view) -> tuple[dict, dict]:
        """Serialize the warm serving state attached to ``view``.

        One shared window payload plus every batch group's query payload
        (``group/<i>/`` prefixes) and the watcher registry (query, source,
        method, group, quarantine flag).  Returns ``(tree, extra)`` for
        :meth:`~repro.checkpoint.manager.CheckpointManager.save`; restore
        with :meth:`resume`.  Checkpoints are taken between windows — the
        batcher drains in-flight pipelined work first.
        """
        self._drain()
        return self._checkpoint_state_sync(view)

    def checkpoint_state_async(self, view) -> Future:
        """:meth:`checkpoint_state` as a pipelined-executor job.

        Serialization rides the batcher's single FIFO worker — it runs
        after any in-flight window jobs (so the captured state is a
        consistent between-windows snapshot) and the serving thread never
        blocks on it: the call returns a :class:`~concurrent.futures.Future`
        immediately and the caller hands its eventual ``(tree, extra)`` to
        the checkpoint manager whenever convenient.  Later windows may be
        submitted while the snapshot job is still queued — FIFO order keeps
        the capture point well-defined (after every previously submitted
        window, before every later one).
        """
        return self._ensure_executor().submit(
            self._checkpoint_state_sync, view
        )

    def _checkpoint_state_sync(self, view) -> tuple[dict, dict]:
        from repro.checkpoint.manager import array_checksums
        from repro.checkpoint.streamstate import (
            STATE_FORMAT, query_payload, window_payload,
        )

        tree, wmeta = window_payload(view, prefix="window/")
        groups = [b for b in self._batches.values() if b.view is view]
        gmetas = []
        for i, b in enumerate(groups):
            qtree, qmeta = query_payload(b, prefix=f"group/{i}/")
            tree.update(qtree)
            gmetas.append(qmeta)
        watchers = []
        for key, e in self._streams.items():
            if e.sq.view is not view:
                continue
            gi = next(i for i, b in enumerate(groups) if b is e.sq.batch)
            watchers.append({
                "query": key[1], "source": int(key[2]), "method": key[3],
                "group": gi, "quarantined": bool(e.quarantined),
            })
        extra = {
            "format": STATE_FORMAT,
            "state": "query-batcher",
            "window_meta": wmeta,
            "groups": gmetas,
            "watchers": watchers,
            "checksums": array_checksums(tree),
        }
        return tree, extra

    @classmethod
    def resume(cls, arrays: dict, extra: dict, *,
               n_shards: Optional[int] = None, mesh=None, **kwargs):
        """Rebuild a batcher and its warm watcher groups from a checkpoint.

        ``arrays``/``extra`` are what ``CheckpointManager.load`` returns
        (pass ``manifest["extra"]``); ``kwargs`` forward to the constructor.
        Returns ``(batcher, view)`` — the replayed view is a NEW object, so
        every group/watcher key is re-built against its identity, and
        watcher TTLs are re-stamped at resume time (a restart is a liveness
        signal, not idleness).  ``n_shards`` restores elastically onto a
        different shard count; each group's bound fixpoints are injected
        warm (no cold solve) and catch-up is plain
        :meth:`advance_window` replay of the deltas recorded since the
        checkpoint.
        """
        from repro.checkpoint.streamstate import (
            STATE_FORMAT, rebuild_query, rebuild_view,
        )

        if int(extra.get("format", 0)) != STATE_FORMAT:
            raise ValueError(
                f"unsupported checkpoint format: {extra.get('format')}"
            )
        if extra.get("state") != "query-batcher":
            raise ValueError(f"not a batcher checkpoint: {extra.get('state')}")
        sums = extra.get("checksums")
        if sums:
            from repro.checkpoint.manager import verify_checksums

            verify_checksums(arrays, sums, where="batcher state")
        self = cls(**kwargs)
        view = rebuild_view(
            arrays, extra["window_meta"], prefix="window/", n_shards=n_shards
        )
        groups = []
        for i, qmeta in enumerate(extra["groups"]):
            b = rebuild_query(
                view, arrays, qmeta, prefix=f"group/{i}/", mesh=mesh
            )
            # the batcher prunes shared-view history itself (min over groups)
            b._owns_view = False
            b._defer_fetch = self.pipelined
            b.events = self.events
            groups.append(b)
        now = self._clock()
        for w in extra["watchers"]:
            b = groups[int(w["group"])]
            if w.get("quarantined"):
                gkey = (id(view), w["query"], w["method"], "q", int(w["source"]))
            else:
                gkey = (id(view), w["query"], w["method"])
            self._batches[gkey] = b
            key = (id(view), w["query"], int(w["source"]), w["method"])
            self._streams[key] = _StreamEntry(
                sq=_BatchWatcher(batch=b, source=int(w["source"])),
                last_used=now, gkey=gkey,
                quarantined=bool(w.get("quarantined")),
            )
        return self, view


@dataclasses.dataclass
class _StreamEntry:
    """One warm watcher handle + its recency stamp (LRU/TTL bookkeeping).

    ``gkey`` is the key of the batch group this watcher's lane lives in —
    the shared ``(view, query, method)`` group, or a dedicated per-source
    key once ``quarantined`` (lane-aware QoS, see
    ``QueryBatcher._quarantine_pathological``).
    """

    sq: object
    last_used: float
    gkey: tuple = ()
    quarantined: bool = False


@dataclasses.dataclass
class _GroupResult:
    """One group's advance captured lazily (rows possibly still on device).

    ``materialize()`` is the pipelined path's device→host sync point: it
    stacks the captured row references and slices out each watcher's lane.
    Runs on the CONSUMER's thread, so the batcher's worker is already free
    to ingest the next slide while devices finish this one.
    """

    rows: list
    sources: list
    watchers: list  # (query_name, source) pairs served from this group
    degraded: bool = False  # rows are last-good, not this slide's fold
    slides_behind: int = 0  # window slides these rows lag the log tip
    retries: int = 0  # outstanding failed advance attempts for the group

    def materialize(self) -> dict:
        if not self.rows:  # degraded before ever priming: nothing to serve
            return {}
        with span("fetch"):
            stacked = np.stack(
                [np.asarray(r) for r in self.rows], axis=1
            )[: len(self.sources)]
        mark_ready("fixpoint")
        lanes = {s: i for i, s in enumerate(self.sources)}
        return {
            (q, s): stacked[lanes[s]] for (q, s) in self.watchers
        }


class PendingWindow:
    """Handle for one in-flight pipelined ``advance_window``.

    ``result()`` blocks until every group served this window and returns
    the same ``{(query, source): (S, V)}`` dict the synchronous path
    returns — bit-for-bit.  ``group_futures()`` exposes the per-group
    futures (each resolving to a :class:`_GroupResult`) so consumers can
    overlap their own work with later groups' convergence loops.
    """

    def __init__(self, pre: Future):
        self._pre = pre
        self._out: Optional[dict] = None

    def group_futures(self) -> list:
        """Per-group futures, available once ingest has run."""
        futs, _ = self._pre.result()
        return futs

    def done(self) -> bool:
        if not self._pre.done():
            return False
        futs, post = self._pre.result()
        return post.done() and all(f.done() for f in futs)

    def result(self) -> dict:
        if self._out is None:
            futs, post = self._pre.result()
            out = WindowResults()
            first_exc: Optional[BaseException] = None
            # consume EVERY sibling future before surfacing any error: one
            # group's failure must not strand the others' results (they
            # advanced on the worker regardless) or wedge later windows
            for f in futs:
                try:
                    g = f.result()
                except BaseException as exc:
                    if first_exc is None:
                        first_exc = exc
                    continue
                if g is not None:  # None: group evicted mid-flight
                    QueryBatcher._fold_group(out, g)
            try:
                post.result()  # surface epilogue errors (quarantine/prune)
            except BaseException as exc:
                if first_exc is None:
                    first_exc = exc
            if first_exc is not None:
                raise first_exc  # original traceback, siblings materialized
            self._out = out
        return self._out


@dataclasses.dataclass
class _BatchWatcher:
    """One standing (query, source) watcher — a lane of a shared batch.

    The identity-stable handle :meth:`QueryBatcher.watch` returns: repeated
    watches of the same (view, query, source, method) are cache hits on the
    same object, while the warm state lives in the underlying
    :class:`~repro.core.api.StreamingQueryBatch` shared by every
    same-(view, query, method) watcher.
    """

    batch: object  # StreamingQueryBatch
    source: int

    @property
    def view(self):
        return self.batch.view

    @property
    def semiring(self):
        return self.batch.semiring

    @property
    def method(self) -> str:
        return self.batch.method

    @property
    def stats(self) -> dict:
        return self.batch.stats

    @property
    def diff_pos(self) -> int:
        return self.batch.diff_pos

    @property
    def results(self):
        """``(S, V)`` values of this watcher's lane for the current window."""
        return self.batch.result_for(self.source)

    def advance(self, delta=None):
        """Advance the whole group; returns this lane's ``(S, V)`` results."""
        self.batch.advance(delta)
        return self.batch.result_for(self.source)

"""Batched request scheduler for decode serving (continuous batching lite).

Maintains a fixed pool of B decode slots over one shared KV cache; incoming
requests claim free slots, finished sequences (EOS or length cap) release
them.  The jitted decode step always runs the full (B,) batch with a slot
mask — static shapes, no recompilation — which is the standard TPU serving
pattern (orbit/vLLM-style without paging).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list
    max_new_tokens: int = 32
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class RequestScheduler:
    def __init__(self, batch_size: int, eos_id: int = 0, max_len: int = 2048):
        self.batch_size = batch_size
        self.eos_id = eos_id
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * batch_size
        self.positions = np.zeros(batch_size, np.int32)

    def submit(self, req: Request):
        self.queue.append(req)

    def _fill_slots(self):
        for i in range(self.batch_size):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                self.positions[i] = 0

    def active(self) -> int:
        return sum(s is not None for s in self.slots) + len(self.queue)

    def run(self, decode_token_fn: Callable, max_steps: int = 256) -> list:
        """Drive decode until all requests finish.

        ``decode_token_fn(tokens (B,), positions (B,), mask (B,)) → next (B,)``
        wraps the jitted per-slot decode (prompt feeding + generation unified
        as token-at-a-time for simplicity; prefill fast-path is separate).
        """
        finished = []
        for _ in range(max_steps):
            self._fill_slots()
            if not any(self.slots):
                break
            tokens = np.zeros(self.batch_size, np.int32)
            mask = np.zeros(self.batch_size, bool)
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                pos = self.positions[i]
                if pos < len(req.prompt):
                    tokens[i] = req.prompt[pos]
                elif req.generated:
                    tokens[i] = req.generated[-1]
                mask[i] = True
            nxt = np.asarray(
                decode_token_fn(
                    jnp.asarray(tokens), jnp.asarray(self.positions), jnp.asarray(mask)
                )
            )
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                self.positions[i] += 1
                if self.positions[i] >= len(req.prompt):
                    tok = int(nxt[i])
                    req.generated.append(tok)
                    n_new = len(req.generated)
                    if (
                        tok == self.eos_id
                        or n_new >= req.max_new_tokens
                        or self.positions[i] >= self.max_len - 1
                    ):
                        req.done = True
                        finished.append(req)
                        self.slots[i] = None
        return finished

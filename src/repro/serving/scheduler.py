"""Batched request schedulers: LM decode slots + evolving-graph query serving.

Three front-ends share this module:

* ``RequestScheduler`` — LM decoding.  Maintains a fixed pool of B decode
  slots over one shared KV cache; incoming requests claim free slots,
  finished sequences (EOS or length cap) release them.  The jitted decode
  step always runs the full (B,) batch with a slot mask — static shapes, no
  recompilation — the standard TPU serving pattern (orbit/vLLM-style without
  paging).
* ``QueryBatcher.submit``/``flush`` — one-shot vertex queries.  Requests that
  share a graph window and semiring are grouped and launched as one Q×S×V
  CQRS batch (``repro.core.baselines.run_cqrs_batch``), amortizing bounds,
  shared-QRS compaction, and the concurrent fixpoint across the group.
* ``QueryBatcher.watch``/``advance_window`` — standing queries over a
  *sliding* window.  Each watched (query, source) keeps a warm
  :class:`~repro.core.api.StreamingQuery` (bounds + witness parents +
  patched QRS + cached rows) on a shared
  :class:`~repro.graph.stream.WindowView` — or, for SPMD serving, a
  :class:`~repro.distributed.stream_shard.ShardedStreamingQuery` on a
  :class:`~repro.graph.shardlog.ShardedWindowView`; ``advance_window``
  appends a snapshot delta, slides the shared view once, and advances every
  watcher incrementally instead of re-evaluating their windows from scratch.
  Warm state is bounded (LRU capacity + watch-stamped TTL +
  evict-on-divergence, see ``cache_info``) so serving memory stays bounded
  under rotating traffic.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import OrderedDict, deque, namedtuple
from typing import Callable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

StreamCacheInfo = namedtuple(
    "StreamCacheInfo", ["hits", "misses", "evictions", "currsize", "maxsize"]
)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list
    max_new_tokens: int = 32
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class RequestScheduler:
    def __init__(self, batch_size: int, eos_id: int = 0, max_len: int = 2048):
        self.batch_size = batch_size
        self.eos_id = eos_id
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * batch_size
        self.positions = np.zeros(batch_size, np.int32)

    def submit(self, req: Request):
        self.queue.append(req)

    def _fill_slots(self):
        for i in range(self.batch_size):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                self.positions[i] = 0

    def active(self) -> int:
        return sum(s is not None for s in self.slots) + len(self.queue)

    def run(self, decode_token_fn: Callable, max_steps: int = 256) -> list:
        """Drive decode until all requests finish.

        ``decode_token_fn(tokens (B,), positions (B,), mask (B,)) → next (B,)``
        wraps the jitted per-slot decode (prompt feeding + generation unified
        as token-at-a-time for simplicity; prefill fast-path is separate).
        """
        finished = []
        for _ in range(max_steps):
            self._fill_slots()
            if not any(self.slots):
                break
            tokens = np.zeros(self.batch_size, np.int32)
            mask = np.zeros(self.batch_size, bool)
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                pos = self.positions[i]
                if pos < len(req.prompt):
                    tokens[i] = req.prompt[pos]
                elif req.generated:
                    tokens[i] = req.generated[-1]
                mask[i] = True
            nxt = np.asarray(
                decode_token_fn(
                    jnp.asarray(tokens), jnp.asarray(self.positions), jnp.asarray(mask)
                )
            )
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                self.positions[i] += 1
                if self.positions[i] >= len(req.prompt):
                    tok = int(nxt[i])
                    req.generated.append(tok)
                    n_new = len(req.generated)
                    if (
                        tok == self.eos_id
                        or n_new >= req.max_new_tokens
                        or self.positions[i] >= self.max_len - 1
                    ):
                        req.done = True
                        finished.append(req)
                        self.slots[i] = None
        return finished


# ==========================================================================
# Evolving-graph query batching (Q×S×V CQRS serving front-end)
# ==========================================================================
@dataclasses.dataclass
class QueryRequest:
    """One vertex-specific query awaiting a batched launch."""

    uid: int
    graph: object  # EvolvingGraph
    query: str  # semiring name
    source: int
    snapshots: Optional[tuple] = None  # sub-window, None = full window
    result: Optional[np.ndarray] = None  # (S, V) once done
    stats: dict = dataclasses.field(default_factory=dict)
    done: bool = False

    def batch_key(self):
        # id(graph): requests share a launch only when they literally share
        # the graph object (same arrays ⇒ same compiled shapes).
        return (id(self.graph), self.query, self.snapshots)


class QueryBatcher:
    """Coalesce vertex queries sharing a graph window into batched launches.

    ``submit`` enqueues; ``flush`` groups the queue by (graph, semiring,
    snapshot window), runs each group — up to ``max_batch`` sources at a
    time — through one batched CQRS evaluation, and scatters the per-source
    ``(S, V)`` slices back onto the finished requests.  Duplicate sources
    within a group are deduplicated for the launch and fan back out.
    """

    def __init__(
        self,
        max_batch: int = 32,
        method: str = "cqrs",
        *,
        stream_capacity: int = 64,
        stream_ttl: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if stream_capacity < 1:
            raise ValueError("stream_capacity must be >= 1")
        self.max_batch = max_batch
        self.method = method
        self.stream_capacity = stream_capacity
        self.stream_ttl = stream_ttl
        self._clock = clock
        self.queue: deque[QueryRequest] = deque()
        self._uid = itertools.count()
        # warm StreamingQuery state, LRU-ordered (oldest first); each value
        # is a _StreamEntry so eviction can reason about idleness/divergence
        self._streams: "OrderedDict[tuple, _StreamEntry]" = OrderedDict()
        self._stream_hits = 0
        self._stream_misses = 0
        self._stream_evictions = 0

    def submit(
        self,
        graph,
        query: str,
        source: int,
        snapshots: Optional[Sequence[int]] = None,
    ) -> QueryRequest:
        req = QueryRequest(
            uid=next(self._uid),
            graph=graph,
            query=str(query),
            source=int(source),
            snapshots=None if snapshots is None else tuple(int(s) for s in snapshots),
        )
        self.queue.append(req)
        return req

    def pending(self) -> int:
        return len(self.queue)

    def flush(self) -> list:
        """Run every queued request; returns them in submission order.

        Requests are grouped by batch key, each group's *unique* sources are
        chunked into ``max_batch``-sized launches, and results fan back out
        to every request (duplicates share one launch slot).  If a launch
        raises, every not-yet-finished request is re-queued before the
        exception propagates — nothing is silently dropped.
        """
        from repro.core.api import MultiQuery

        by_key: dict = {}
        submitted = list(self.queue)
        self.queue.clear()
        for req in submitted:
            by_key.setdefault(req.batch_key(), []).append(req)

        try:
            for reqs in by_key.values():
                by_source: dict = {}
                for r in reqs:
                    by_source.setdefault(r.source, []).append(r)
                uniq = sorted(by_source)
                for chunk_start in range(0, len(uniq), self.max_batch):
                    sources = uniq[chunk_start : chunk_start + self.max_batch]
                    mq = MultiQuery(
                        reqs[0].graph, reqs[0].query, sources,
                        snapshots=reqs[0].snapshots,
                    )
                    mq.evaluate(self.method)
                    stats = dict(mq.stats, batched_queries=len(sources))
                    for s in sources:
                        # copy: don't pin the whole (Q, S, V) batch array to
                        # the lifetime of one request's (S, V) slice
                        res = mq.result_for(s).copy()
                        for r in by_source[s]:
                            r.result = res
                            r.stats = stats
                            r.done = True
        except BaseException:
            self.queue.extend(r for r in submitted if not r.done)
            raise
        return submitted

    # -- sliding-window serving (warm per-(window, query) state) ------------
    def watch(self, view, query: str, source: int, *, method: Optional[str] = None):
        """Register a standing query on a shared sliding window.

        Returns the warm :class:`~repro.core.api.StreamingQuery` (idempotent:
        watching the same (view, query, source, method) again returns the
        existing instance with its state intact).  ``method`` defaults to the
        batcher's method when it is a streaming engine, else ``"cqrs"``.

        Warm state is bounded: at most ``stream_capacity`` entries are kept,
        least-recently-*watched* evicted first, and entries are also dropped
        when idle past ``stream_ttl`` seconds or *divergent* — their view's
        log has slid at least a full window past them, or the shared view
        pruned slide history they never consumed — since such state would be
        rebuilt from scratch on its next advance anyway.  Recency/idleness is
        stamped by ``watch()`` calls only, never by ``advance_window`` —
        being served says nothing about whether a client still reads the
        result, so abandoned watchers expire even on a view that advances
        every slide.  :meth:`cache_info` exposes the counters.
        """
        from repro.core.api import StreamingQuery

        if method is None:
            method = (self.method if self.method in ("cqrs", "cqrs_ell")
                      else "cqrs")
            from repro.graph.shardlog import ShardedWindowView

            if method == "cqrs_ell" and isinstance(view, ShardedWindowView):
                # the sharded engine has no ELL path yet (ROADMAP): fall back
                # rather than reject the view — explicit method still raises
                method = "cqrs"
        key = (id(view), str(query), int(source), method)
        entry = self._streams.get(key)
        if entry is not None:
            # touch BEFORE housekeeping: a re-watch is exactly the liveness
            # signal TTL measures, so the warm state must survive it
            self._stream_hits += 1
            entry.last_used = self._clock()
            self._streams.move_to_end(key)
        self._evict_stale(exempt_view=view)
        if entry is None:
            self._stream_misses += 1
            sq = StreamingQuery(view, str(query), int(source), method=method)
            sq.results  # prime eagerly: pay the cold solve before traffic
            entry = _StreamEntry(sq=sq, last_used=self._clock())
            self._streams[key] = entry
            while len(self._streams) > self.stream_capacity:
                self._streams.popitem(last=False)  # LRU out
                self._stream_evictions += 1
        return entry.sq

    def watching(self, view=None) -> list:
        """Warm streaming queries (optionally restricted to one view)."""
        return [e.sq for e in self._streams.values()
                if view is None or e.sq.view is view]

    def cache_info(self) -> StreamCacheInfo:
        """LRU/TTL/divergence statistics for the warm streaming-query cache."""
        return StreamCacheInfo(
            hits=self._stream_hits,
            misses=self._stream_misses,
            evictions=self._stream_evictions,
            currsize=len(self._streams),
            maxsize=self.stream_capacity,
        )

    def _is_divergent(self, sq) -> bool:
        """True when ``sq``'s warm state cannot help its next advance.

        Either the view's log has slid ≥ one full window past the view (every
        cached row would be rebuilt), or the shared view pruned slide history
        the query never consumed (it must re-prime).
        """
        view = sq.view
        if view.log.num_snapshots - view.stop >= view.size:
            return True
        return sq.diff_pos < view.history_end - len(view.history)

    def _evict_stale(self, exempt_view=None) -> int:
        """Drop TTL-expired and divergent entries.

        ``exempt_view`` guards only the *divergence* test (the view about to
        be served may legitimately lag its log until ``slide_to_tip``); TTL
        expiry applies to every entry, so abandoned watchers expire even on
        a view that is advanced every slide.
        """
        now = self._clock()
        dead = []
        for key, e in self._streams.items():
            expired = (self.stream_ttl is not None
                       and now - e.last_used > self.stream_ttl)
            divergent = e.sq.view is not exempt_view and self._is_divergent(e.sq)
            if expired or divergent:
                dead.append(key)
        for key in dead:
            del self._streams[key]
            self._stream_evictions += 1
        return len(dead)

    def advance_window(self, view, delta=None) -> dict:
        """Append ``delta`` to the view's log, slide, advance every watcher.

        The shared view slides exactly once per appended snapshot; each
        watcher folds the slide diff into its warm bounds/QRS state and
        evaluates only the appended snapshot.  Returns
        ``{(query, source): (S, V) results}`` for the watchers on ``view``.
        (A (query, source) watched under both engine methods yields one
        entry — both engines are bit-for-bit identical by contract.)

        Slide history consumed by every watcher is pruned from the shared
        view afterwards (which also retires unreachable log history), so
        long-running serving loops stay bounded; stale warm state is evicted
        on the way (see :meth:`watch`).  Note that with ``stream_ttl`` set,
        being served does NOT refresh a watcher's idleness — a client must
        re-``watch`` within the TTL or its (query, source) expires and drops
        out of subsequent results.
        """
        self._evict_stale(exempt_view=view)
        if delta is not None:
            view.log.append_snapshot(*delta)
        view.slide_to_tip()
        out = {}
        for e in list(self._streams.values()):
            if e.sq.view is not view:
                continue
            out[(e.sq.semiring.name, e.sq.source)] = e.sq.advance()
            # deliberately NOT a recency touch: serving a watcher says nothing
            # about whether any client still reads it — idleness (TTL) and
            # LRU order are stamped only by client-side watch() calls, so an
            # abandoned (query, source) does eventually expire even on a view
            # that is advanced every slide
        watchers = self.watching(view)
        if watchers:
            view.prune_history(min(sq.diff_pos for sq in watchers))
        return out


@dataclasses.dataclass
class _StreamEntry:
    """One warm streaming query + its recency stamp (LRU/TTL bookkeeping)."""

    sq: object
    last_used: float

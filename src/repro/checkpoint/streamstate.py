"""Streaming serving-state checkpoints: window replay + warm fixpoint restore.

The hybrid persist-then-replay design (Khurana & Deshpande's snapshot
retrieval; Koloniari et al.'s graph deltas — see PAPERS.md) applied to the
paper's streaming engine: a checkpoint captures the *window* — per-snapshot
global edge lists with their weights-in-effect — plus the warm per-query
state that is expensive to recover (bound fixpoints, cached result rows).
Restore replays the window into a fresh log, rebuilds a view over it, and
injects the checkpointed fixpoints instead of cold-solving:

* **Values are bit-for-bit.**  Monotone fixpoints are unique, so the
  checkpointed ``val_cap``/``val_cup`` *are* the fixpoints of the replayed
  window — no solve runs on restore, only one parent-forest launch per bound
  side (trim metadata, not part of the fixpoint).  Min/max segment reductions
  are order-exact, so results are independent of the replayed log's edge-id
  permutation, of QRS slot order, and of the shard count.
* **Elastic by construction.**  The payload is in *global* vertex/edge terms
  (sharded maintainers fold through
  :meth:`~repro.distributed.stream_shard.ShardedStreamingBounds.to_global`),
  so a checkpoint written single-host restores onto any shard count and vice
  versa — the shard axis is a layout choice, not state.
* **Capacity classes survive.**  The replayed log, the rebuilt
  :class:`~repro.core.qrs.PatchableQRS`, and the sticky ELL row capacity are
  re-seeded at the checkpointed capacities, so a restored replica re-enters
  the same compiled kernel variants (see ``repro.serving.warmstart``) instead
  of re-walking the growth ladder.

What is deliberately NOT checkpointed: parent forests (recomputed — their
edge-id tie-breaks differ in the replayed id space, which may change *trim
sets and superstep counts* but never values), QRS slot tables (rebuilt from
the keep rule at the saved capacity), and presence planes (rebuilt under the
new pack epoch; see :meth:`EllPresenceCache.export_state` for the counters).

Catch-up after restore is plain delta replay: the resumed query object owns
its replayed view, so feeding it the deltas recorded since the checkpoint
through ``advance()`` is exactly the O(batch) incremental path —
``ServeSupervisor.run`` drives this.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.graph.stream import STREAM_ALIGN, SnapshotLog, WindowView

STATE_FORMAT = 1


def _is_sharded_view(view) -> bool:
    from repro.graph.shardlog import ShardedWindowView

    return isinstance(view, ShardedWindowView)


def _snapshot_arrays(log: SnapshotLog, t: int):
    """Global ``(src, dst, weight-in-effect)`` of edges present at snapshot t."""
    ids = log.snapshot_edges(t)
    w = log.weight_tip[ids].astype(np.float32).copy()
    if log.has_weight_events:
        # weight_tip is the weight in effect NOW; patch the rare edges whose
        # assignment history differs at snapshot t
        multi = np.intersect1d(ids, log.multi_weight_ids())
        if len(multi):
            pos = np.searchsorted(ids, multi)  # ids are sorted
            for p, j in zip(pos.tolist(), multi.tolist()):
                w[p] = log.weight_at(int(j), t)
    return (
        log.src[ids].astype(np.int32),
        log.dst[ids].astype(np.int32),
        w,
    )


# ==========================================================================
# Checkpoint payload (flat array tree + JSON-able meta)
# ==========================================================================
def window_payload(view, *, prefix: str = "",
                   encoding: str = "delta") -> tuple[dict, dict]:
    """Serialize a window view's snapshot contents in global terms.

    ``encoding="delta"`` (the default) stores the FIRST window snapshot as
    a full ``snap/0/{src,dst,w}`` triple and every later one as its own
    add/del batch (``delta/<i>/...`` — :meth:`SnapshotLog.delta_batch`,
    the log's retirement-surviving O(batch) record), so the payload is
    O(window·batch) instead of O(window·E).  ``encoding="full"`` keeps the
    legacy one-triple-per-snapshot layout.  Sharded views concatenate
    their shards — per-shard logs store global vertex ids — and include
    the assignment's owner/local maps so a same-shard-count restore
    reproduces the exact layout.  Requires the view to be at the log tip
    (checkpoints are taken between advances).
    """
    log = view.log
    if view.stop != log.num_snapshots:
        raise ValueError(
            f"checkpoint requires the window at the log tip "
            f"(window ends at {view.stop}, log has {log.num_snapshots})"
        )
    if encoding not in ("delta", "full"):
        raise ValueError(f"unknown window encoding: {encoding!r}")
    sharded = _is_sharded_view(view)
    tree: dict = {}

    def full_snap(t):
        if sharded:
            parts = [_snapshot_arrays(sh, t) for sh in log.shards]
            return tuple(np.concatenate([p[k] for p in parts])
                         for k in range(3))
        return _snapshot_arrays(log, t)

    ts = list(range(view.start, view.stop))
    for i, t in enumerate(ts):
        if encoding == "delta" and i > 0:
            if sharded:
                parts = [sh.delta_batch(t) for sh in log.shards]
                batch = tuple(np.concatenate([p[k] for p in parts])
                              for k in range(5))
            else:
                batch = log.delta_batch(t)
            asrc, adst, aw, dsrc, ddst = batch
            tree[f"{prefix}delta/{i}/asrc"] = np.asarray(asrc, np.int32)
            tree[f"{prefix}delta/{i}/adst"] = np.asarray(adst, np.int32)
            tree[f"{prefix}delta/{i}/aw"] = np.asarray(aw, np.float32)
            tree[f"{prefix}delta/{i}/dsrc"] = np.asarray(dsrc, np.int32)
            tree[f"{prefix}delta/{i}/ddst"] = np.asarray(ddst, np.int32)
            continue
        src, dst, w = full_snap(t)
        tree[f"{prefix}snap/{i}/src"] = src
        tree[f"{prefix}snap/{i}/dst"] = dst
        tree[f"{prefix}snap/{i}/w"] = w
    meta = {
        "num_vertices": int(log.num_vertices),
        "window": int(view.size),
        "log_capacity": int(log.capacity),
        "encoding": encoding,
        "sharded": bool(sharded),
        "n_shards": int(log.n_shards) if sharded else 0,
    }
    if sharded:
        a = log.assignment
        meta["assignment_mode"] = a.mode
        meta["assignment_v_cap"] = int(a.v_cap)
        tree[f"{prefix}assign/owner"] = a.owner.copy()
        tree[f"{prefix}assign/local"] = a.local.copy()
    return tree, meta


def query_payload(sq, *, prefix: str = "") -> tuple[dict, dict]:
    """Serialize one streaming query's warm state (window payload excluded).

    Bounds value arrays are stored in GLOBAL vertex space — the sharded
    maintainer's position-space layout is a function of the (possibly
    different) restore-time assignment, not state.
    """
    sq._ensure_primed()
    sq._materialize_rows()
    bounds = sq._bounds
    sharded = _is_sharded_view(sq.view)
    if sharded:
        val_cap = bounds.to_global(bounds.val_cap)
        val_cup = bounds.to_global(bounds.val_cup)
    else:
        val_cap = np.asarray(bounds.val_cap)
        val_cup = np.asarray(bounds.val_cup)
    tree = {
        f"{prefix}bounds/val_cap": np.asarray(val_cap),
        f"{prefix}bounds/val_cup": np.asarray(val_cup),
    }
    for i, row in enumerate(sq._rows):
        tree[f"{prefix}rows/{i}"] = np.asarray(row)
    if bounds.lane_supersteps is not None:
        # np.array, not np.asarray: the live counter is mutated in place on
        # every advance, and an aliased capture would drift after the fact
        tree[f"{prefix}lane_supersteps"] = np.array(
            bounds.lane_supersteps, np.int64
        )
    batched = bounds.batched
    meta = {
        "kind": "batch" if batched else "scalar",
        "query": sq.semiring.name,
        "method": sq.method,
        "slides": int(sq._slides),
        "supersteps": int(bounds.supersteps),
    }
    if batched:
        meta["sources"] = [int(s) for s in sq.sources]
        meta["q_cap"] = int(sq._q_cap)
    else:
        meta["source"] = int(sq.source)
    qrs = sq._qrs
    if hasattr(qrs, "capacity"):  # single-host PatchableQRS slot tables
        meta["qrs_capacity"] = int(qrs.capacity)
        meta["ell_rows"] = int(qrs._ell_packer.num_rows)
    else:  # sharded mask-based QRS: only the sticky ELL row cap matters
        meta["qrs_capacity"] = 0
        cache = getattr(sq, "_ell_cache", None)
        meta["ell_rows"] = int(getattr(cache, "_row_cap", 0) or 0)
    # presence-plane counters (stats continuity; planes rebuild on restore)
    presence = {
        str(q): cache.export_state()
        for q, cache in getattr(sq, "_presence", {}).items()
    }
    if presence:
        meta["presence"] = presence
    return tree, meta


def streaming_state(sq) -> tuple[dict, dict]:
    """Full checkpoint of one ``StreamingQuery``/``StreamingQueryBatch``.

    Returns ``(tree, extra)`` for
    :meth:`repro.checkpoint.manager.CheckpointManager.save`.  Every payload
    section carries a CRC32 in ``extra["checksums"]`` so
    :func:`resume_streaming` can reject a corrupt step before replaying it
    (the manager's manifest-level checksums cover the same bytes, but the
    extra travels with the state even through out-of-band transports).
    """
    from repro.checkpoint.manager import array_checksums

    wtree, wmeta = window_payload(sq.view)
    qtree, qmeta = query_payload(sq)
    tree = {**wtree, **qtree}
    return tree, {
        "format": STATE_FORMAT,
        "state": "streaming-query",
        "window_meta": wmeta,
        "query_meta": qmeta,
        "checksums": array_checksums(tree),
    }


# ==========================================================================
# Restore: replay the window, inject the fixpoints
# ==========================================================================
def _fresh_log(num_vertices: int, *, capacity: Optional[int] = None,
               n_shards: int = 0, assignment="range", v_cap: int = 0,
               owner=None, local=None, mode: str = "range"):
    """Empty (sharded) log under the checkpointed capacity + layout spec."""
    cap = int(capacity or STREAM_ALIGN)
    if n_shards:
        from repro.graph.shardlog import ShardAssignment, ShardedSnapshotLog

        if owner is not None and local is not None and v_cap:
            assignment = ShardAssignment._build(
                mode, num_vertices, n_shards,
                np.asarray(owner, np.int64), np.asarray(local, np.int64),
                int(v_cap),
            )
        return ShardedSnapshotLog(
            num_vertices, n_shards, capacity=cap, assignment=assignment
        )
    return SnapshotLog(num_vertices, capacity=cap)


def replay_delta_log(base, deltas, num_vertices: int, **kwargs):
    """Replay a delta-encoded window into a fresh log — O(window·batch).

    ``base`` is the first snapshot's full ``(src, dst, w)`` membership;
    ``deltas`` the later snapshots' ``(add_src, add_dst, add_w, del_src,
    del_dst)`` batches (:meth:`SnapshotLog.delta_batch` records).  Each
    batch is exactly what the original log committed (weight re-assignments
    included), so the replayed log reproduces membership, weight events,
    and window extrema without any host-side diffing.
    """
    log = _fresh_log(num_vertices, **kwargs)
    src, dst, w = base
    log.append_snapshot(src, dst, w)
    for add_src, add_dst, add_w, del_src, del_dst in deltas:
        log.append_snapshot(add_src, add_dst, add_w, del_src, del_dst)
    return log


def replay_log(snaps, num_vertices: int, **kwargs):
    """Replay global per-snapshot edge lists into a fresh log.

    ``snaps`` is a list of ``(src, dst, w)`` triples (full membership per
    snapshot).  Consecutive snapshots are diffed host-side: membership
    changes become add/del batches and an in-place weight change becomes a
    re-add with the new weight (a weight *event* in the log — exactly how
    the original stream recorded it).  Iteration order is the array order of
    each snapshot, so edge-id assignment is deterministic (though generally
    a permutation of the original log's — harmless, results are order-exact).
    """
    log = _fresh_log(num_vertices, **kwargs)
    # Vectorized host-side diff: each snapshot's edges become int64 keys
    # ``s * V + d`` and consecutive snapshots are compared through sorted
    # key arrays (searchsorted), not Python dicts — restore cost is a few
    # numpy passes per snapshot instead of per-edge interpreter work.
    nv = int(num_vertices)
    prev_keys = np.empty(0, np.int64)  # snapshot order (del emission order)
    prev_skeys = np.empty(0, np.int64)  # sorted (lookup order)
    prev_sw = np.empty(0, np.float32)
    for src, dst, w in snaps:
        src = np.asarray(src, np.int64).ravel()
        dst = np.asarray(dst, np.int64).ravel()
        w = np.asarray(w, np.float32).ravel()
        keys = src * nv + dst
        order = np.argsort(keys, kind="stable")
        skeys, sw = keys[order], w[order]
        if skeys.size and np.any(skeys[1:] == skeys[:-1]):
            # duplicate edges within one snapshot: collapse to dict
            # semantics (first position wins the slot, last weight wins)
            d: dict = {}
            for k, x in zip(keys.tolist(), w.tolist()):
                d[k] = x
            keys = np.fromiter(d.keys(), np.int64, len(d))
            w = np.asarray(list(d.values()), np.float32)
            src, dst = keys // nv, keys % nv
            order = np.argsort(keys, kind="stable")
            skeys, sw = keys[order], w[order]
        if prev_skeys.size:
            pos = np.minimum(
                np.searchsorted(prev_skeys, keys), prev_skeys.size - 1
            )
            in_prev = prev_skeys[pos] == keys
            # membership adds OR in-place weight events (re-add, new weight)
            add = ~in_prev | (in_prev & (prev_sw[pos] != w))
            if skeys.size:
                dpos = np.minimum(
                    np.searchsorted(skeys, prev_keys), skeys.size - 1
                )
                dele = skeys[dpos] != prev_keys
            else:
                dele = np.ones(prev_keys.size, bool)
        else:
            add = np.ones(keys.size, bool)
            dele = np.zeros(prev_keys.size, bool)
        dk = prev_keys[dele]
        log.append_snapshot(
            src[add], dst[add], w[add], dk // nv, dk % nv,
        )
        prev_keys, prev_skeys, prev_sw = keys, skeys, sw
    return log


def rebuild_view(arrays: dict, meta: dict, *, prefix: str = "",
                 n_shards: Optional[int] = None, assignment=None):
    """Replay a :func:`window_payload` into a fresh log + tip view.

    ``n_shards`` overrides the checkpointed shard count (elastic restore):
    ``0`` forces single-host, ``k`` restores onto ``k`` shards.  The saved
    assignment layout is reused only when the shard count matches (and no
    explicit ``assignment`` is given); otherwise a fresh ``"range"``/given
    spec is built — values are shard-layout independent.
    """
    size = int(meta["window"])
    want = int(meta.get("n_shards", 0)) if n_shards is None else int(n_shards)
    kwargs: dict = {}
    if want and assignment is not None:
        kwargs["assignment"] = assignment
    elif want and want == int(meta.get("n_shards", 0)):
        kwargs.update(
            v_cap=int(meta.get("assignment_v_cap", 0)),
            owner=arrays.get(f"{prefix}assign/owner"),
            local=arrays.get(f"{prefix}assign/local"),
            mode=str(meta.get("assignment_mode", "range")),
        )
    kwargs.update(
        capacity=int(meta.get("log_capacity", 0)) or None, n_shards=want,
    )
    if str(meta.get("encoding", "full")) == "delta":
        base = (
            arrays[f"{prefix}snap/0/src"],
            arrays[f"{prefix}snap/0/dst"],
            arrays[f"{prefix}snap/0/w"],
        )
        deltas = [
            (
                arrays[f"{prefix}delta/{i}/asrc"],
                arrays[f"{prefix}delta/{i}/adst"],
                arrays[f"{prefix}delta/{i}/aw"],
                arrays[f"{prefix}delta/{i}/dsrc"],
                arrays[f"{prefix}delta/{i}/ddst"],
            )
            for i in range(1, size)
        ]
        log = replay_delta_log(
            base, deltas, int(meta["num_vertices"]), **kwargs
        )
    else:
        snaps = [
            (
                arrays[f"{prefix}snap/{i}/src"],
                arrays[f"{prefix}snap/{i}/dst"],
                arrays[f"{prefix}snap/{i}/w"],
            )
            for i in range(size)
        ]
        log = replay_log(snaps, int(meta["num_vertices"]), **kwargs)
    if want:
        from repro.graph.shardlog import ShardedWindowView

        return ShardedWindowView(log, size=size)
    return WindowView(log, size=size)


def rebuild_query(view, arrays: dict, meta: dict, *, prefix: str = "",
                  mesh=None, query=None):
    """Attach a resumed streaming query to a replayed ``view``.

    The query object is constructed normally (priming is lazy, so this is
    cheap), then the checkpointed state is injected: warm bound fixpoints via
    :meth:`StreamingBounds.from_state` (parents recomputed, no solve), the
    QRS rebuilt at its saved capacity classes, and result rows verbatim.
    """
    from repro.core.api import StreamingQuery, StreamingQueryBatch
    from repro.core.bounds import StreamingBounds, detect_uvv
    from repro.core.qrs import PatchableQRS
    from repro.core.semiring import get_semiring

    sr = query if query is not None else get_semiring(meta["query"])
    method = meta["method"]
    sharded = _is_sharded_view(view)
    kwargs: dict = {}
    if sharded and mesh is not None:
        kwargs["mesh"] = mesh
    if meta["kind"] == "batch":
        sq = StreamingQueryBatch(
            view, sr, meta["sources"], method=method, **kwargs
        )
        # re-enter the saved lane-capacity class (it never shrinks live, so
        # a restore below the class boundary must not shrink it either)
        sq._q_cap = max(sq._q_cap, int(meta["q_cap"]))
        src_spec = sq._lane_sources()
    else:
        sq = StreamingQuery(view, sr, meta["source"], method=method, **kwargs)
        src_spec = meta["source"]
    # the resumed query owns its replayed view: prune consumed history
    sq._owns_view = True

    val_cap = arrays[f"{prefix}bounds/val_cap"]
    val_cup = arrays[f"{prefix}bounds/val_cup"]
    lane_steps = arrays.get(f"{prefix}lane_supersteps")
    bkwargs: dict = {}
    if sharded:
        from repro.distributed.stream_shard import ShardedStreamingBounds

        bounds_cls = ShardedStreamingBounds
        bkwargs["mesh"] = getattr(sq, "mesh", None)
        assign = view.log.assignment
        val_cap = _to_positions(assign, val_cap, sr)
        val_cup = _to_positions(assign, val_cup, sr)
    else:
        bounds_cls = StreamingBounds
    sq._bounds = bounds_cls.from_state(
        view, sr, src_spec, val_cap, val_cup,
        supersteps=int(meta.get("supersteps", 0)),
        lane_supersteps=lane_steps, **bkwargs,
    )
    if sharded:
        sq._qrs = sq._make_qrs()
        rows_cap = int(meta.get("ell_rows", 0))
        if rows_cap and sq.method == "cqrs_ell":
            sq._ell_cache = sq._make_ell_cache(row_cap=rows_cap)
    else:
        uvv = np.asarray(
            detect_uvv(jnp.asarray(val_cap), jnp.asarray(val_cup))
        )
        sq._qrs = PatchableQRS(
            view, uvv, sr,
            min_capacity=int(meta.get("qrs_capacity", 0)),
            min_ell_rows=int(meta.get("ell_rows", 0)),
        )
    for q_str, state in meta.get("presence", {}).items():
        from repro.kernels.vrelax.ops import EllPresenceCache

        q = None if q_str == "None" else int(q_str)
        cache = sq._presence[q] = EllPresenceCache()
        cache.import_state(state)
    size = int(view.size)
    sq._rows = [np.asarray(arrays[f"{prefix}rows/{i}"]) for i in range(size)]
    sq._diff_pos = view.history_end
    sq._slides = int(meta.get("slides", 0))
    sq._set_stats(seconds=0.0, supersteps=0, advanced=0, resumed=True)
    return sq


def resume_streaming(arrays: dict, extra: dict, *,
                     n_shards: Optional[int] = None, mesh=None,
                     assignment=None, query=None, method: Optional[str] = None):
    """Rebuild a streaming query from a :func:`streaming_state` checkpoint.

    ``arrays``/``extra`` are what
    :meth:`~repro.checkpoint.manager.CheckpointManager.load` returns (pass
    ``manifest["extra"]``).  ``n_shards`` restores elastically onto a
    different shard count (``0`` = single host); ``method`` optionally
    switches the appended-snapshot engine.
    """
    if int(extra.get("format", 0)) != STATE_FORMAT:
        raise ValueError(f"unsupported checkpoint format: {extra.get('format')}")
    sums = extra.get("checksums")
    if sums:
        from repro.checkpoint.manager import verify_checksums

        verify_checksums(arrays, sums, where="streaming state")
    qmeta = dict(extra["query_meta"])
    if method is not None:
        qmeta["method"] = method
    view = rebuild_view(
        arrays, extra["window_meta"], n_shards=n_shards, assignment=assignment
    )
    return rebuild_query(view, arrays, qmeta, mesh=mesh, query=query)


def _to_positions(assign, vals: np.ndarray, sr) -> np.ndarray:
    """Scatter global ``(..., V)`` values into flat position space.

    Padding positions (no global vertex maps there) take the semiring
    identity — inert under relaxation, exactly like a live maintainer's
    padding lanes.
    """
    vals = np.asarray(vals, np.float32)
    out = np.full(
        vals.shape[:-1] + (int(assign.state_len),), sr.identity, np.float32
    )
    out[..., assign.positions] = vals
    return out

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.streamstate import (
    replay_log,
    rebuild_query,
    rebuild_view,
    resume_streaming,
    streaming_state,
    window_payload,
    query_payload,
)

__all__ = [
    "CheckpointManager",
    "replay_log",
    "rebuild_query",
    "rebuild_view",
    "resume_streaming",
    "streaming_state",
    "window_payload",
    "query_payload",
]

from repro.checkpoint.manager import (
    CheckpointCorruptError,
    CheckpointManager,
    array_checksums,
    verify_checksums,
)
from repro.checkpoint.streamstate import (
    replay_log,
    rebuild_query,
    rebuild_view,
    resume_streaming,
    streaming_state,
    window_payload,
    query_payload,
)

__all__ = [
    "CheckpointCorruptError",
    "CheckpointManager",
    "array_checksums",
    "verify_checksums",
    "replay_log",
    "rebuild_query",
    "rebuild_view",
    "resume_streaming",
    "streaming_state",
    "window_payload",
    "query_payload",
]

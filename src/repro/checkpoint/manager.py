"""Checkpoint/restart with atomic commits and elastic resharding.

Layout per step::

    <dir>/step_000123.tmp/   → arrays.npz + manifest.json   (write)
    <dir>/step_000123/                                      (atomic rename)

* arrays are addressed by flattened pytree key paths;
* ``restore(..., shardings=...)`` device_puts onto ANY target sharding —
  loading a 256-chip checkpoint onto a 512-chip (or 8-chip) mesh is just a
  different sharding tree (elastic rescale);
* ``keep`` bounds retained checkpoints; partial/crashed writes never become
  visible (tmp suffix), so restart always finds a consistent latest step.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        # steps a concurrent restore()/load() resolved; _gc must not delete
        # them out from under the reader even when newer saves land mid-read
        self._protected: set[int] = set()
        os.makedirs(directory, exist_ok=True)
        self._sweep_orphans()

    def _sweep_orphans(self) -> list[str]:
        """Remove ``step_*.tmp`` directories left by a crash mid-write.

        A crash between array write and the atomic rename leaves a ``.tmp``
        directory that would otherwise shadow the next save of the same step
        (``save`` rmtrees it) but still waste disk and confuse inspection;
        committed steps are never suffixed, so sweeping is always safe.
        """
        swept = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, d))
                swept.append(d)
        return swept

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, extra: dict | None = None) -> str:
        from repro.obs.metrics import get_registry

        with get_registry().timer(
            "checkpoint_write_seconds", "manager.save disk commit wall time"
        ):
            return self._save(step, tree, extra)

    def _save(self, step: int, tree, extra: dict | None = None) -> str:
        name = f"step_{step:09d}"
        tmp = os.path.join(self.directory, name + ".tmp")
        final = os.path.join(self.directory, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat, _ = _flatten_with_paths(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {
            "step": int(step),
            "keys": sorted(host.keys()),
            "extra": extra or {},
            "format": 1,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()
        return final

    # ------------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def _resolve(self, step: int | None) -> int:
        """Resolve + protect a step so a concurrent save's gc can't prune it."""
        step = self.latest_step() if step is None else int(step)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        self._protected.add(step)
        return step

    def load(self, step: int | None = None):
        """Self-describing read: ``(dict key → np.ndarray, manifest)``.

        Unlike :meth:`restore` no target tree is needed — the checkpoint's
        own key set is returned as a flat dict.  The resolved step is pinned
        against ``keep``-pruning for the manager's lifetime.
        """
        from repro.obs.metrics import get_registry

        step = self._resolve(step)
        path = os.path.join(self.directory, f"step_{step:09d}")
        with get_registry().timer(
            "checkpoint_read_seconds", "manager.load disk read wall time"
        ):
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            with np.load(os.path.join(path, "arrays.npz")) as data:
                arrays = {k: data[k] for k in data.files}
        return arrays, manifest

    def restore(self, target_tree, step: int | None = None, shardings=None):
        """Rebuild ``target_tree``'s structure from disk.

        ``shardings``: optional matching tree of NamedShardings — arrays are
        device_put onto them, which reshards transparently across mesh-size
        changes (elastic restart).  Returns ``(tree, manifest)``.
        """
        step = self._resolve(step)
        path = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat_target, treedef = _flatten_with_paths(target_tree)
        flat_shard = None
        if shardings is not None:
            flat_shard, _ = _flatten_with_paths(shardings)
        leaves = []
        for key in flat_target:
            if key not in data:
                raise KeyError(f"checkpoint {path} missing key {key}")
            arr = data[key]
            want = flat_target[key]
            if tuple(arr.shape) != tuple(want.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs target {want.shape}"
                )
            if flat_shard is not None:
                arr = jax.device_put(arr, flat_shard[key])
            else:
                arr = jax.device_put(arr)
            leaves.append((key, arr))
        # rebuild in treedef order
        order = {k: i for i, (k, _) in enumerate(leaves)}
        vals = [v for _, v in sorted(leaves, key=lambda kv: order[kv[0]])]
        # tree_unflatten wants leaves in flatten order, which matches
        # _flatten_with_paths iteration order of flat_target.
        vals = [dict(leaves)[k] for k in flat_target]
        return jax.tree_util.tree_unflatten(treedef, vals), manifest

    # ------------------------------------------------------------- gc
    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            if s in self._protected:
                continue  # a restore()/load() resolved this step — keep it
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"))

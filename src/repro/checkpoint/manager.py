"""Checkpoint/restart with atomic commits and elastic resharding.

Layout per step::

    <dir>/step_000123.tmp/   → arrays.npz + manifest.json   (write)
    <dir>/step_000123/                                      (atomic rename)

* arrays are addressed by flattened pytree key paths;
* ``restore(..., shardings=...)`` device_puts onto ANY target sharding —
  loading a 256-chip checkpoint onto a 512-chip (or 8-chip) mesh is just a
  different sharding tree (elastic rescale);
* ``keep`` bounds retained checkpoints; partial/crashed writes never become
  visible (tmp suffix), so restart always finds a consistent latest step.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib

import jax
import numpy as np

from repro.ft.faultinject import fault_file_point, fault_point


class CheckpointCorruptError(RuntimeError):
    """A checkpoint step failed integrity verification (checksum mismatch,
    truncated archive, or missing payload keys)."""


def array_checksums(host: dict) -> dict:
    """Per-key CRC32 of a flat ``{key: array}`` payload (JSON-able ints)."""
    return {
        k: int(zlib.crc32(np.ascontiguousarray(np.asarray(v)).tobytes()))
        for k, v in host.items()
    }


def verify_checksums(arrays: dict, sums: dict, *, where: str = "") -> None:
    """Raise :class:`CheckpointCorruptError` on any missing/mismatched key."""
    bad = []
    for key, want in sums.items():
        arr = arrays.get(key)
        if arr is None:
            bad.append(f"{key} (missing)")
            continue
        got = int(zlib.crc32(np.ascontiguousarray(np.asarray(arr)).tobytes()))
        if got != int(want):
            bad.append(f"{key} (crc {got} != {int(want)})")
    if bad:
        raise CheckpointCorruptError(
            f"checkpoint payload corrupt{' in ' + where if where else ''}: "
            + ", ".join(bad[:4])
            + (f" … +{len(bad) - 4} more" if len(bad) > 4 else "")
        )


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        # steps a concurrent restore()/load() resolved; _gc must not delete
        # them out from under the reader even when newer saves land mid-read
        self._protected: set[int] = set()
        os.makedirs(directory, exist_ok=True)
        self._sweep_orphans()

    def _sweep_orphans(self) -> list[str]:
        """Remove ``step_*.tmp`` directories left by a crash mid-write.

        A crash between array write and the atomic rename leaves a ``.tmp``
        directory that would otherwise shadow the next save of the same step
        (``save`` rmtrees it) but still waste disk and confuse inspection;
        committed steps are never suffixed, so sweeping is always safe.
        """
        swept = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, d))
                swept.append(d)
        return swept

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, extra: dict | None = None) -> str:
        from repro.obs.metrics import get_registry

        with get_registry().timer(
            "checkpoint_write_seconds", "manager.save disk commit wall time"
        ):
            return self._save(step, tree, extra)

    def _save(self, step: int, tree, extra: dict | None = None) -> str:
        name = f"step_{step:09d}"
        tmp = os.path.join(self.directory, name + ".tmp")
        final = os.path.join(self.directory, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat, _ = _flatten_with_paths(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        # torn-write site: a fault here simulates a crash after the payload
        # hits disk but before the manifest commit — the .tmp dir never
        # becomes visible and _sweep_orphans reclaims it
        fault_point("ckpt_torn")
        manifest = {
            "step": int(step),
            "keys": sorted(host.keys()),
            "checksums": array_checksums(host),
            "extra": extra or {},
            "format": 1,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()
        # silent-corruption site: bit-flip/truncate a COMMITTED payload —
        # only load-time checksum verification can catch this one
        fault_file_point("ckpt_payload", os.path.join(final, "arrays.npz"))
        return final

    # ------------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def _resolve(self, step: int | None) -> int:
        """Resolve + protect a step so a concurrent save's gc can't prune it."""
        step = self.latest_step() if step is None else int(step)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        self._protected.add(step)
        return step

    def load(self, step: int | None = None):
        """Self-describing read: ``(dict key → np.ndarray, manifest)``.

        Unlike :meth:`restore` no target tree is needed — the checkpoint's
        own key set is returned as a flat dict.  The resolved step is pinned
        against ``keep``-pruning for the manager's lifetime.

        Every read verifies the manifest's per-key CRC32 checksums (written
        by :meth:`save`).  An explicit ``step`` that fails verification
        raises :class:`CheckpointCorruptError`; with ``step=None`` a corrupt
        or truncated step is *skipped* — a ``checkpoint_corrupt_steps_total``
        counter and ``ckpt_corrupt`` event record it — and the newest older
        step that verifies is returned instead.
        """
        from repro.obs.metrics import get_registry

        reg = get_registry()
        if step is not None:
            s = self._resolve(step)
            with reg.timer(
                "checkpoint_read_seconds", "manager.load disk read wall time"
            ):
                return self._read_step(s)
        candidates = sorted(self.steps(), reverse=True)
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        last_exc: Exception | None = None
        with reg.timer(
            "checkpoint_read_seconds", "manager.load disk read wall time"
        ):
            for s in candidates:
                try:
                    out = self._read_step(s)
                except Exception as exc:  # corrupt/truncated: fall back
                    last_exc = exc
                    reg.counter(
                        "checkpoint_corrupt_steps_total",
                        "checkpoint steps skipped at load (failed verification)",
                    ).inc()
                    continue
                self._protected.add(s)
                return out
        raise CheckpointCorruptError(
            f"no verifiable checkpoint in {self.directory} "
            f"(tried {len(candidates)} steps)"
        ) from last_exc

    def _read_step(self, step: int):
        """Read + verify one committed step; raises on any corruption."""
        path = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        try:
            with np.load(os.path.join(path, "arrays.npz")) as data:
                arrays = {k: data[k] for k in data.files}
        except CheckpointCorruptError:
            raise
        except Exception as exc:  # zip CRC failure, truncation, bad magic …
            raise CheckpointCorruptError(
                f"step {step} payload unreadable: {exc}"
            ) from exc
        missing = [k for k in manifest.get("keys", []) if k not in arrays]
        if missing:
            raise CheckpointCorruptError(
                f"step {step} payload missing keys: {missing[:4]}"
            )
        sums = manifest.get("checksums")
        if sums:
            verify_checksums(arrays, sums, where=f"step {step}")
        return arrays, manifest

    def restore(self, target_tree, step: int | None = None, shardings=None):
        """Rebuild ``target_tree``'s structure from disk.

        ``shardings``: optional matching tree of NamedShardings — arrays are
        device_put onto them, which reshards transparently across mesh-size
        changes (elastic restart).  Returns ``(tree, manifest)``.
        """
        step = self._resolve(step)
        path = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat_target, treedef = _flatten_with_paths(target_tree)
        flat_shard = None
        if shardings is not None:
            flat_shard, _ = _flatten_with_paths(shardings)
        leaves = []
        for key in flat_target:
            if key not in data:
                raise KeyError(f"checkpoint {path} missing key {key}")
            arr = data[key]
            want = flat_target[key]
            if tuple(arr.shape) != tuple(want.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs target {want.shape}"
                )
            if flat_shard is not None:
                arr = jax.device_put(arr, flat_shard[key])
            else:
                arr = jax.device_put(arr)
            leaves.append((key, arr))
        # rebuild in treedef order
        order = {k: i for i, (k, _) in enumerate(leaves)}
        vals = [v for _, v in sorted(leaves, key=lambda kv: order[kv[0]])]
        # tree_unflatten wants leaves in flatten order, which matches
        # _flatten_with_paths iteration order of flat_target.
        vals = [dict(leaves)[k] for k in flat_target]
        return jax.tree_util.tree_unflatten(treedef, vals), manifest

    # ------------------------------------------------------------- gc
    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            if s in self._protected:
                continue  # a restore()/load() resolved this step — keep it
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"))

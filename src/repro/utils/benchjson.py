"""Structured benchmark emission — the ``BENCH_*.json`` CI artifact format.

``benchmarks/run.py`` prints (and ``--out`` persists) a flat CSV; the
``--json`` flag additionally writes one machine-readable payload per run so
downstream tooling (dashboards, regression diffing) does not have to parse
the free-form ``derived`` column.  The payload carries:

* every CSV row verbatim (``name``, ``us_per_call``, ``derived``),
* per-mode latency records for the pipelined-serving bench (per-slide
  milliseconds, p50/p99 slide-to-result, presence touched-slot counts, and
  shard occupancy spread),
* schema v2: an optional ``metrics`` block — a resolved registry snapshot
  (``counters``/``gauges`` name→number maps, see
  :func:`repro.obs.export.snapshot`) plus optional ``per_slide`` dicts and
  an ``overhead`` measurement from the latency bench,
* a ``meta`` dict (fast/full, argv, device count) for provenance.

:func:`validate_bench_json` is the schema contract: CI's well-formedness
test round-trips an emitted payload through it, so a malformed artifact
fails tier-1 rather than silently breaking a dashboard.
"""
from __future__ import annotations

from typing import Optional, Sequence

SCHEMA_VERSION = 2

# every latency record carries exactly these keys (see LATENCY_RECORD_KEYS
# usage in validate_bench_json); per_slide_ms and touched_slots are
# per-slide sequences, the rest are scalars
LATENCY_RECORD_KEYS = frozenset(
    {
        "mode",  # "synchronous" | "pipelined"
        "query",  # semiring name
        "window",  # window size (snapshots)
        "q",  # watcher count
        "per_slide_ms",  # list[float], slide-to-result per slide
        "p50_ms",  # float, median of per_slide_ms
        "p99_ms",  # float, 99th percentile of per_slide_ms
        "touched_slots",  # list[int], presence scatter sizes (may be empty)
        "occupancy_spread",  # float, max/mean shard occupancy after the run
    }
)


def make_payload(
    rows: Sequence[tuple],
    *,
    mode: str,
    meta: Optional[dict] = None,
    latency: Optional[Sequence[dict]] = None,
    metrics: Optional[dict] = None,
) -> dict:
    """Build the ``BENCH_*.json`` payload from emitted CSV rows.

    ``rows`` is the ``(name, us_per_call, derived)`` list ``emit()``
    accumulates; ``mode`` is ``"fast"`` or ``"full"``; ``latency`` is the
    per-mode record list the latency bench produces (omitted when the bench
    did not run); ``metrics`` is a resolved registry snapshot (schema v2 —
    ``counters``/``gauges`` maps plus optional ``per_slide``/``overhead``).
    The result always passes :func:`validate_bench_json`.
    """
    payload = {
        "schema_version": SCHEMA_VERSION,
        "mode": str(mode),
        "rows": [
            {"name": str(n), "us_per_call": float(us), "derived": str(d)}
            for n, us, d in rows
        ],
        "meta": dict(meta or {}),
    }
    if latency is not None:
        payload["latency"] = [dict(r) for r in latency]
    if metrics is not None:
        payload["metrics"] = dict(metrics)
    return payload


def _check_number_map(obj, what: str) -> None:
    if not isinstance(obj, dict):
        raise ValueError(f"{what} must be a dict")
    for k, v in obj.items():
        if not isinstance(k, str):
            raise ValueError(f"{what} keys must be strings")
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            raise ValueError(f"{what}[{k!r}] must be a number")


def validate_bench_json(payload: dict) -> dict:
    """Check a payload against the schema; returns it, raises ``ValueError``.

    Deliberately strict about *shape* (key sets, scalar vs sequence, value
    types) and silent about *values* — a regression dashboard compares
    numbers across runs, the schema only promises they are numbers.
    """
    if not isinstance(payload, dict):
        raise ValueError("payload must be a dict")
    if payload.get("schema_version") != SCHEMA_VERSION:
        raise ValueError(
            f"schema_version must be {SCHEMA_VERSION}, "
            f"got {payload.get('schema_version')!r}"
        )
    if payload.get("mode") not in ("fast", "full"):
        raise ValueError(f"mode must be 'fast' or 'full', got {payload.get('mode')!r}")
    rows = payload.get("rows")
    if not isinstance(rows, list):
        raise ValueError("rows must be a list")
    for i, row in enumerate(rows):
        if not isinstance(row, dict) or set(row) != {"name", "us_per_call", "derived"}:
            raise ValueError(f"rows[{i}] must have exactly name/us_per_call/derived")
        if not isinstance(row["name"], str) or not isinstance(row["derived"], str):
            raise ValueError(f"rows[{i}] name/derived must be strings")
        if not isinstance(row["us_per_call"], (int, float)) or isinstance(
            row["us_per_call"], bool
        ):
            raise ValueError(f"rows[{i}] us_per_call must be a number")
    if not isinstance(payload.get("meta"), dict):
        raise ValueError("meta must be a dict")
    if "latency" in payload:
        lat = payload["latency"]
        if not isinstance(lat, list):
            raise ValueError("latency must be a list")
        for i, rec in enumerate(lat):
            if not isinstance(rec, dict) or set(rec) != LATENCY_RECORD_KEYS:
                missing = LATENCY_RECORD_KEYS - set(rec or ())
                extra = set(rec or ()) - LATENCY_RECORD_KEYS
                raise ValueError(
                    f"latency[{i}] key mismatch (missing={sorted(missing)}, "
                    f"extra={sorted(extra)})"
                )
            if rec["mode"] not in ("synchronous", "pipelined"):
                raise ValueError(f"latency[{i}] mode must be synchronous|pipelined")
            if not isinstance(rec["query"], str):
                raise ValueError(f"latency[{i}] query must be a string")
            for k in ("window", "q"):
                if not isinstance(rec[k], int) or isinstance(rec[k], bool):
                    raise ValueError(f"latency[{i}] {k} must be an int")
            for k in ("p50_ms", "p99_ms", "occupancy_spread"):
                if not isinstance(rec[k], (int, float)) or isinstance(rec[k], bool):
                    raise ValueError(f"latency[{i}] {k} must be a number")
            if not isinstance(rec["per_slide_ms"], list) or not all(
                isinstance(x, (int, float)) and not isinstance(x, bool)
                for x in rec["per_slide_ms"]
            ):
                raise ValueError(f"latency[{i}] per_slide_ms must be a number list")
            if not isinstance(rec["touched_slots"], list) or not all(
                isinstance(x, int) and not isinstance(x, bool)
                for x in rec["touched_slots"]
            ):
                raise ValueError(f"latency[{i}] touched_slots must be an int list")
    if "metrics" in payload:
        m = payload["metrics"]
        if not isinstance(m, dict):
            raise ValueError("metrics must be a dict")
        for req in ("counters", "gauges"):
            if req not in m:
                raise ValueError(f"metrics must carry a {req!r} map")
            _check_number_map(m[req], f"metrics.{req}")
        if "per_slide" in m:
            ps = m["per_slide"]
            if not isinstance(ps, list) or not all(
                isinstance(r, dict) for r in ps
            ):
                raise ValueError("metrics.per_slide must be a list of dicts")
        if "overhead" in m:
            _check_number_map(m["overhead"], "metrics.overhead")
    return payload

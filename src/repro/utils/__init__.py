from repro.utils.padding import pad_to, pad_to_multiple, round_up
from repro.utils.pytree import register_static_dataclass

__all__ = [
    "pad_to",
    "pad_to_multiple",
    "round_up",
    "register_static_dataclass",
]

"""Memory-bounded chunked scatter-sum with recompute backward.

``agg = Σ_chunks scatter_add(dst_c, msg_fn(diff, ints_c, floats_c))`` is
LINEAR in the messages, so reverse-mode does not need the per-step carry
checkpoints ``lax.scan`` would store (O(n_chunks × |agg|) — terabytes on the
61M-edge graphs).  This custom_vjp:

  forward:  scan accumulate, storing only the (small) chunk inputs;
  backward: given cotangent ``g``, re-run each chunk's ``msg_fn`` under
            ``jax.vjp`` with cotangent ``g[dst_c]``, accumulating the
            differentiable-tree cotangent; per-chunk float cotangents are
            re-stacked by the scan.

Peak memory: one chunk's intermediates + two agg-sized buffers, independent
of the number of chunks.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def chunked_scatter_sum(
    msg_fn: Callable,  # (diff_tree, ints_c: tuple, floats_c: tuple) -> (ck, ...)
    out_shape: tuple,
    out_dtype,
    diff_tree,  # differentiable pytree (params, node features, ...)
    dst: jax.Array,  # (nc, ck) int32 scatter destinations
    int_chunks: tuple,  # tuple of (nc, ck, ...) integer arrays (no cotangent)
    float_chunks: tuple,  # tuple of (nc, ck, ...) float arrays (cotangent via vjp)
):
    @jax.custom_vjp
    def run(diff_tree, dst, int_chunks, float_chunks):
        def body(agg, inp):
            d_c, ic, fc = inp
            return agg.at[d_c].add(msg_fn(diff_tree, ic, fc)), None

        agg0 = jnp.zeros(out_shape, out_dtype)
        agg, _ = jax.lax.scan(body, agg0, (dst, int_chunks, float_chunks))
        return agg

    def fwd(diff_tree, dst, int_chunks, float_chunks):
        return run(diff_tree, dst, int_chunks, float_chunks), (
            diff_tree, dst, int_chunks, float_chunks,
        )

    def bwd(res, g):
        diff_tree, dst, int_chunks, float_chunks = res

        def body(diff_cot, inp):
            d_c, ic, fc = inp
            _, vjp_fn = jax.vjp(lambda d, f: msg_fn(d, ic, f), diff_tree, fc)
            d_cot, f_cot = vjp_fn(g[d_c])
            diff_cot = jax.tree_util.tree_map(jnp.add, diff_cot, d_cot)
            return diff_cot, f_cot

        zeros = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, x.dtype), diff_tree
        )
        diff_cot, f_cots = jax.lax.scan(body, zeros, (dst, int_chunks, float_chunks))
        f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)
        return (
            diff_cot,
            f0(dst),
            jax.tree_util.tree_map(f0, int_chunks),
            f_cots,
        )

    run.defvjp(fwd, bwd)
    return run(diff_tree, dst, int_chunks, float_chunks)


def chunked_map(
    fn: Callable,  # (diff_tree, ints_c, floats_c) -> (ck, ...) outputs
    diff_tree,
    int_chunks: tuple,  # (nc, ck, ...) int arrays
    float_chunks: tuple,  # (nc, ck, ...) float arrays
):
    """Per-chunk map with recompute backward. Returns stacked (nc, ck, ...).

    Like ``chunked_scatter_sum`` but the outputs are independent per chunk
    (no reduction): backward re-runs each chunk's vjp with its own cotangent
    slice, so no per-chunk forward residuals survive the scan.
    """

    @jax.custom_vjp
    def run(diff_tree, int_chunks, float_chunks):
        def body(_, inp):
            ic, fc = inp
            return None, fn(diff_tree, ic, fc)

        _, out = jax.lax.scan(body, None, (int_chunks, float_chunks))
        return out

    def fwd(diff_tree, int_chunks, float_chunks):
        return run(diff_tree, int_chunks, float_chunks), (
            diff_tree, int_chunks, float_chunks,
        )

    def bwd(res, g):
        diff_tree, int_chunks, float_chunks = res

        def body(diff_cot, inp):
            ic, fc, g_c = inp
            _, vjp_fn = jax.vjp(lambda d, f: fn(d, ic, f), diff_tree, fc)
            d_cot, f_cot = vjp_fn(g_c)
            diff_cot = jax.tree_util.tree_map(jnp.add, diff_cot, d_cot)
            return diff_cot, f_cot

        zeros = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, x.dtype), diff_tree
        )
        diff_cot, f_cots = jax.lax.scan(body, zeros, (int_chunks, float_chunks, g))
        f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)
        return diff_cot, jax.tree_util.tree_map(f0, int_chunks), f_cots

    run.defvjp(fwd, bwd)
    return run(diff_tree, int_chunks, float_chunks)

"""Pytree registration helpers for dataclasses with static (hashable) fields."""
from __future__ import annotations

import dataclasses

import jax


def register_static_dataclass(cls=None, *, meta_fields: tuple = ()):
    """Register a dataclass as a pytree; ``meta_fields`` are static aux data.

    Usage::

        @register_static_dataclass(meta_fields=("num_vertices",))
        @dataclasses.dataclass(frozen=True)
        class EdgeList: ...
    """

    def wrap(c):
        data_fields = tuple(
            f.name for f in dataclasses.fields(c) if f.name not in meta_fields
        )
        return jax.tree_util.register_dataclass(
            c, data_fields=data_fields, meta_fields=tuple(meta_fields)
        )

    if cls is None:
        return wrap
    return wrap(cls)

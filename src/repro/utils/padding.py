"""Static-shape padding helpers.

TPU kernels and pjit'd programs want shapes that are (a) static and (b)
aligned to hardware tile sizes (multiples of 8 sublanes / 128 lanes).  All
host-side graph compaction in this repo pads through these helpers so the
jitted fast path compiles once per padded size class.
"""
from __future__ import annotations

import numpy as np


def round_up(n: int, multiple: int) -> int:
    """Round ``n`` up to the next multiple of ``multiple`` (min ``multiple``)."""
    if multiple <= 0:
        raise ValueError(f"multiple must be positive, got {multiple}")
    return max(multiple, ((n + multiple - 1) // multiple) * multiple)


def pad_to(arr: np.ndarray, size: int, fill, axis: int = 0) -> np.ndarray:
    """Pad ``arr`` along ``axis`` with ``fill`` up to length ``size``."""
    cur = arr.shape[axis]
    if cur > size:
        raise ValueError(f"array length {cur} exceeds pad target {size}")
    if cur == size:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, size - cur)
    return np.pad(arr, widths, mode="constant", constant_values=fill)


def pad_to_multiple(arr: np.ndarray, multiple: int, fill, axis: int = 0) -> np.ndarray:
    """Pad ``arr`` along ``axis`` with ``fill`` to a multiple of ``multiple``."""
    return pad_to(arr, round_up(arr.shape[axis], multiple), fill, axis=axis)

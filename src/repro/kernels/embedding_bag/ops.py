"""Public EmbeddingBag op (gather + fused bag reduce)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.embedding_bag.kernel import B_BLOCK, F_BLOCK, bag_reduce_pallas
from repro.utils.padding import round_up


def embedding_bag(
    table: jax.Array,  # (N, F)
    indices: jax.Array,  # (B, L) int32
    weights: Optional[jax.Array] = None,  # (B, L)
    valid: Optional[jax.Array] = None,  # (B, L) bool
    mode: str = "sum",
    *,
    use_kernel: bool = True,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """torch.nn.EmbeddingBag equivalent: per-bag weighted sum/mean.

    ``use_kernel=False`` falls back to the pure-XLA path (used for sharded
    tables inside ``shard_map``, where the kernel runs per shard).
    """
    interpret = default_interpret() if interpret is None else interpret
    b, l = indices.shape
    if weights is None:
        weights = jnp.ones((b, l), table.dtype)
    if valid is None:
        valid = jnp.ones((b, l), bool)
    w = jnp.where(valid, weights, 0.0).astype(table.dtype)

    rows = table[indices]  # (B, L, F) — XLA gather
    if not use_kernel:
        out = jnp.sum(rows * w[:, :, None], axis=1)
    else:
        f = table.shape[1]
        b_pad, f_pad = round_up(b, B_BLOCK), round_up(f, F_BLOCK)
        rows_p = jnp.pad(rows, ((0, b_pad - b), (0, 0), (0, f_pad - f)))
        w_p = jnp.pad(w, ((0, b_pad - b), (0, 0)))
        out = bag_reduce_pallas(rows_p, w_p, interpret=interpret)[:b, :f]

    if mode == "mean":
        denom = jnp.maximum(valid.sum(axis=1, keepdims=True), 1).astype(table.dtype)
        out = out / denom
    elif mode != "sum":
        raise ValueError(f"unknown mode {mode!r}")
    return out

"""Fused weighted-bag reduction for embedding lookups (DLRM hot path).

JAX has no native ``nn.EmbeddingBag``; the framework implements it as
``jnp.take`` (XLA gather — efficient on TPU) followed by this kernel, which
fuses {per-sample weighting, validity masking, bag reduction} so the gathered
``(B, L, F)`` rows are read from HBM once and only the ``(B, F)`` bag outputs
are written (unfused XLA materializes the weighted intermediate).

Tiling: F blocks of 128 lanes; B blocks of 8 sublanes; the full multi-hot
length L rides the reduce axis inside a tile → VMEM per step is
``8·L·128·4 B`` (L=64 → 256 KB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

B_BLOCK = 8
F_BLOCK = 128


def _bag_kernel(rows_ref, w_ref, out_ref):
    rows = rows_ref[...]  # (B_blk, L, F_blk)
    w = w_ref[...]  # (B_blk, L)
    out_ref[...] = jnp.sum(rows * w[:, :, None], axis=1)


@functools.partial(jax.jit, static_argnames=("interpret", "b_block", "f_block"))
def bag_reduce_pallas(
    rows: jax.Array,  # (B, L, F) gathered embedding rows
    weights: jax.Array,  # (B, L) per-sample weights (0 for invalid slots)
    *,
    interpret: bool = True,
    b_block: int = B_BLOCK,
    f_block: int = F_BLOCK,
) -> jax.Array:
    b, l, f = rows.shape
    if b % b_block or f % f_block:
        raise ValueError(f"B={b} must be {b_block}-aligned, F={f} {f_block}-aligned")
    grid = (b // b_block, f // f_block)
    return pl.pallas_call(
        _bag_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_block, l, f_block), lambda i, j: (i, 0, j)),
            pl.BlockSpec((b_block, l), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b_block, f_block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, f), rows.dtype),
        interpret=interpret,
    )(rows, weights)

"""Pure-jnp EmbeddingBag oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(
    table: jax.Array,  # (N, F)
    indices: jax.Array,  # (B, L) int32
    weights: jax.Array,  # (B, L)
    valid: jax.Array,  # (B, L) bool
    mode: str = "sum",
) -> jax.Array:
    rows = table[indices]  # (B, L, F)
    w = jnp.where(valid, weights, 0.0).astype(rows.dtype)
    out = jnp.sum(rows * w[:, :, None], axis=1)
    if mode == "mean":
        denom = jnp.maximum(valid.sum(axis=1, keepdims=True), 1).astype(rows.dtype)
        out = out / denom
    elif mode != "sum":
        raise ValueError(f"unknown mode {mode!r}")
    return out

from repro.kernels.ell_agg.ops import ell_multi_aggregate

__all__ = ["ell_multi_aggregate"]

"""Public fused neighbor-statistics op with mean/std epilogue."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.ell_agg.kernel import (
    F_BLOCK,
    R_BLOCK,
    ell_multi_aggregate_pallas,
)
from repro.kernels.ell_agg.ref import ell_multi_aggregate_ref
from repro.utils.padding import round_up


def ell_multi_aggregate(
    feats: jax.Array,  # (R, D, F) gathered neighbor messages
    valid: jax.Array,  # (R, D) bool
    *,
    use_kernel: bool = True,
    interpret: Optional[bool] = None,
    eps: float = 1e-5,
):
    """Returns ``(mean, std, maxv, minv)`` each ``(R, F)``; empty rows → 0."""
    interpret = default_interpret() if interpret is None else interpret
    r, d, f = feats.shape
    if use_kernel:
        r_pad, f_pad = round_up(r, R_BLOCK), round_up(f, F_BLOCK)
        fp = jnp.pad(feats, ((0, r_pad - r), (0, 0), (0, f_pad - f)))
        vp = jnp.pad(valid, ((0, r_pad - r), (0, 0)))
        s, sq, mx, mn = ell_multi_aggregate_pallas(fp, vp, interpret=interpret)
        s, sq, mx, mn = s[:r, :f], sq[:r, :f], mx[:r, :f], mn[:r, :f]
    else:
        s, sq, mx, mn = ell_multi_aggregate_ref(feats, valid)

    cnt = valid.sum(axis=1, keepdims=True).astype(feats.dtype)  # (R, 1)
    denom = jnp.maximum(cnt, 1.0)
    mean = s / denom
    var = jnp.maximum(sq / denom - mean * mean, 0.0)
    std = jnp.sqrt(var + eps)
    empty = cnt == 0
    mean = jnp.where(empty, 0.0, mean)
    std = jnp.where(empty, 0.0, std)
    mx = jnp.where(empty, 0.0, mx)
    mn = jnp.where(empty, 0.0, mn)
    return mean, std, mx, mn

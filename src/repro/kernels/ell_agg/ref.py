"""Pure-jnp oracle for fused multi-statistic aggregation."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ell_agg.kernel import NEG, POS


def ell_multi_aggregate_ref(feats, valid):
    v = valid[:, :, None]
    xz = jnp.where(v, feats, 0.0)
    return (
        jnp.sum(xz, axis=1),
        jnp.sum(xz * xz, axis=1),
        jnp.max(jnp.where(v, feats, NEG), axis=1),
        jnp.min(jnp.where(v, feats, POS), axis=1),
    )

"""Fused multi-statistic neighbor aggregation (PNA/GatedGCN hot path).

PNA needs {mean, max, min, std} of neighbor messages; naively that is four
passes over the gathered ``(R, D, F)`` message tensor.  This kernel computes
{sum, sum-of-squares, max, min} in ONE pass through VMEM (mean/std are cheap
epilogues on the (R, F) outputs), cutting HBM reads of the message tensor 4×.

Tiling mirrors vrelax: R rows of split-ELL neighbors × D=degree-slot axis
(reduce) × F feature lanes.  Block = (R_blk, D, F_blk) with F_blk=128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

R_BLOCK = 8
F_BLOCK = 128
NEG = -3.0e38
POS = 3.0e38


def _agg_kernel(feat_ref, valid_ref, sum_ref, sq_ref, max_ref, min_ref):
    x = feat_ref[...]  # (R_blk, D, F_blk)
    v = valid_ref[...][:, :, None]  # (R_blk, D, 1)
    xz = jnp.where(v, x, 0.0)
    sum_ref[...] = jnp.sum(xz, axis=1)
    sq_ref[...] = jnp.sum(xz * xz, axis=1)
    max_ref[...] = jnp.max(jnp.where(v, x, NEG), axis=1)
    min_ref[...] = jnp.min(jnp.where(v, x, POS), axis=1)


@functools.partial(jax.jit, static_argnames=("interpret", "r_block", "f_block"))
def ell_multi_aggregate_pallas(
    feats: jax.Array,  # (R, D, F) gathered neighbor messages
    valid: jax.Array,  # (R, D) bool
    *,
    interpret: bool = True,
    r_block: int = R_BLOCK,
    f_block: int = F_BLOCK,
):
    r, d, f = feats.shape
    if r % r_block or f % f_block:
        raise ValueError(f"R={r} must be {r_block}-aligned, F={f} {f_block}-aligned")
    grid = (r // r_block, f // f_block)
    out = jax.ShapeDtypeStruct((r, f), feats.dtype)
    return pl.pallas_call(
        _agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r_block, d, f_block), lambda i, j: (i, 0, j)),
            pl.BlockSpec((r_block, d), lambda i, j: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((r_block, f_block), lambda i, j: (i, j))] * 4,
        out_shape=[out, out, out, out],
        interpret=interpret,
    )(feats, valid)

"""Versioned multi-snapshot edge relaxation — the paper's hot loop, on TPU.

One CQRS superstep evaluates, for every (snapshot s, packed ELL row r,
slot d):  ``extend(values[s, src[r,d]], w[r,d])`` masked by the snapshot
presence bit, then reduces over the slot axis.  Unfused XLA materializes the
``(S, R, D)`` candidate + mask intermediates in HBM three times; this kernel
streams each gathered tile through VMEM exactly once and writes only the
``(S, R)`` per-row reductions — the op is bandwidth-bound, so that ~3×
traffic cut is the win (see EXPERIMENTS.md §Perf for the measured term).

TPU mapping:
  * slot axis D = 128 → one VPU lane row per (s, r); the reduce over D is an
    in-register lane reduction.
  * S_BLOCK = 8 sublanes; an (8, R_BLOCK, 128) f32 tile is 8·R_BLOCK·512 B —
    R_BLOCK = 8 keeps {values tile, weight tile, word tile, out tile} well
    under VMEM (~290 KB total).
  * version bits: 8 consecutive snapshots always share one packed uint32
    word (S_BLOCK | 32), so the word plane for a grid step is a single
    ``(R_BLOCK, D)`` uint32 tile selected by the BlockSpec index map — the
    bit-test is two VPU ops, the paper's per-edge "ownership check".
  * the value gather ``values[:, src]`` stays in XLA (TPU gathers are
    efficient there; fusing it into Pallas would force an HBM-resident
    values ref with per-slot dynamic addressing — slower than XLA's gather
    on current TPUs).  See DESIGN.md §4.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import EXTEND_OPS

S_BLOCK = 8
R_BLOCK = 8


def _vrelax_kernel(vals_ref, w_ref, words_ref, out_ref, *, semiring: str, s_block: int):
    extend, minimize, identity = EXTEND_OPS[semiring]
    s_idx = pl.program_id(0)

    vals = vals_ref[...]  # (S_blk, R_blk, D) f32 — gathered source values
    w = w_ref[...]  # (R_blk, D) f32
    words = words_ref[...][:, :, 0]  # (R_blk, D) uint32 — presence word plane

    # snapshot bit positions within the shared word
    bit0 = (s_idx * s_block) % 32
    bits = (
        jax.lax.broadcasted_iota(jnp.uint32, (s_block, 1, 1), 0)
        + jnp.uint32(bit0)
    )
    present = ((words[None, :, :] >> bits) & jnp.uint32(1)).astype(jnp.bool_)

    cand = extend(vals, w[None, :, :])
    cand = jnp.where(present, cand, jnp.float32(identity))
    red = jnp.min(cand, axis=-1) if minimize else jnp.max(cand, axis=-1)
    out_ref[...] = red  # (S_blk, R_blk)


@functools.partial(
    jax.jit, static_argnames=("semiring", "interpret", "s_block", "r_block")
)
def vrelax_partial_pallas(
    gathered: jax.Array,  # (S, R, D) f32 — values[:, ell.src]
    weights: jax.Array,  # (R, D) f32
    words: jax.Array,  # (R, D, W) uint32 presence words (slot-aligned)
    *,
    semiring: str,
    interpret: bool = True,
    s_block: int = S_BLOCK,
    r_block: int = R_BLOCK,
) -> jax.Array:
    """Per-(snapshot, packed-row) reduction ``(S, R)`` of the masked relax."""
    s, r, d = gathered.shape
    if s % s_block or r % r_block:
        raise ValueError(f"S={s} must be {s_block}-aligned and R={r} {r_block}-aligned")
    if 32 % s_block:
        raise ValueError("s_block must divide 32 (shared presence word)")
    grid = (s // s_block, r // r_block)

    kernel = functools.partial(_vrelax_kernel, semiring=semiring, s_block=s_block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s_block, r_block, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((r_block, d), lambda i, j: (j, 0)),
            pl.BlockSpec(
                (r_block, d, 1), lambda i, j, _sb=s_block: (j, 0, (i * _sb) // 32)
            ),
        ],
        out_specs=pl.BlockSpec((s_block, r_block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, r), jnp.float32),
        interpret=interpret,
    )(gathered, weights, words)

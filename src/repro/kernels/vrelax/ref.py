"""Pure-jnp oracle for the vrelax kernel (and the full superstep)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import EXTEND_OPS


def vrelax_partial_ref(
    gathered: jax.Array,  # (S, R, D)
    weights: jax.Array,  # (R, D)
    words: jax.Array,  # (R, D, W)
    *,
    semiring: str,
) -> jax.Array:
    """Reference per-row reduction, identical math to the kernel."""
    extend, minimize, identity = EXTEND_OPS[semiring]
    s = gathered.shape[0]
    snaps = jnp.arange(s, dtype=jnp.uint32)
    word_idx = (snaps // 32).astype(jnp.int32)
    bit_idx = snaps % 32
    sel = jnp.moveaxis(words, -1, 0)[word_idx]  # (S, R, D)
    present = ((sel >> bit_idx[:, None, None]) & jnp.uint32(1)).astype(bool)
    cand = extend(gathered, weights[None, :, :])
    cand = jnp.where(present, cand, jnp.float32(identity))
    return jnp.min(cand, axis=-1) if minimize else jnp.max(cand, axis=-1)

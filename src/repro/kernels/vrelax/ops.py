"""Public vrelax ops: kernel-backed CQRS superstep + fixpoint driver.

``concurrent_fixpoint_ell`` is the kernel-backed twin of
``repro.core.concurrent.concurrent_fixpoint`` (flat-edge XLA path); tests
assert they agree bit-for-bit with each other and with per-snapshot full
recompute.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.semiring import Semiring
from repro.graph.ell import EllPack
from repro.kernels.common import default_interpret
from repro.kernels.vrelax.kernel import S_BLOCK, vrelax_partial_pallas
from repro.utils.padding import round_up


def build_presence_ell(
    presence: jax.Array, ell: EllPack, *, as_numpy: bool = False
):
    """Scatter per-edge presence words ``(E, W)`` into ELL slots ``(R, D, W)``.

    Empty slots (edge_id == -1) get all-zero words → masked in-kernel.
    ``as_numpy`` skips the device upload — callers assembling several packs'
    word planes into one array (the per-shard SPMD ELL path stacks
    ``n_shards`` of them) concatenate host-side and upload once.
    """
    eid = np.asarray(ell.edge_id)
    pres = np.asarray(presence)
    w = pres.shape[1]
    out = np.zeros((eid.shape[0], eid.shape[1], w), np.uint32)
    valid = eid >= 0
    out[valid] = pres[eid[valid]]
    return out if as_numpy else jnp.asarray(out)


def tile_presence_words(
    presence: np.ndarray, num_snapshots: int, num_queries: int
) -> np.ndarray:
    """Repack per-edge presence words for a flattened Q·S snapshot axis.

    The batched ELL path folds Q queries into the kernel's snapshot axis
    (combined index ``t = q * S + s``); bit ``t`` of the repacked words must
    equal bit ``s`` of the originals.  Host-side, once per batch — the kernel
    and its word-sharing BlockSpec stay unchanged.
    """
    from repro.graph.structures import pack_presence

    pres = np.asarray(presence)
    snaps = np.arange(num_snapshots, dtype=np.uint32)
    words = pres[:, (snaps // 32).astype(np.int64)]  # (E, S)
    dense = ((words >> (snaps % 32)[None, :]) & 1).astype(bool).T  # (S, E)
    return pack_presence(np.tile(dense, (num_queries, 1)))  # (E, ceil(QS/32))


def presence_word_pattern(num_queries: Optional[int] = None) -> np.ndarray:
    """Presence words ``(W,) uint32`` of one *present* edge for a Q-fold eval.

    The streaming serving path evaluates one snapshot at a time, so a present
    edge's words carry bit ``q * 1 + 0`` for every query lane ``q`` — i.e.
    bits ``0..Q-1`` set (``num_queries=None`` means the scalar path: one word,
    bit 0).  This is exactly what :func:`tile_presence_words` produces for a
    single-snapshot all-ones column, computed in O(W) instead of O(E·Q).
    """
    q = 1 if num_queries is None else int(num_queries)
    w = (q + 31) // 32
    out = np.zeros(w, np.uint32)
    for k in range(w):
        n = min(32, q - 32 * k)
        out[k] = np.uint32(0xFFFFFFFF) if n >= 32 else np.uint32((1 << n) - 1)
    return out


def _scatter_bucket(n: int) -> int:
    """Power-of-two bucket for scatter index padding (bounds jit cache)."""
    b = 8
    while b < n:
        b *= 2
    return b


class EllPresenceCache:
    """Persistent device-resident ELL presence-word plane, updated by
    scattering only the slots whose presence flipped.

    The synchronous serving path rebuilt the full ``(R, D, W)`` word plane
    from scratch on every slide — O(capacity · Q) host work plus a full
    host→device upload — even though a slide flips only the edges named by
    its ``SlideDiff``.  This cache keeps the plane resident on device and
    folds each new presence mask in as a scatter of just the flipped slots
    (``jnp`` functional update, so the *previous* plane stays alive for any
    in-flight kernels — the double-buffering the pipelined path relies on).

    Invalidation rule (the presence-plane twin of the PatchableQRS freed-slot
    invariant): slot→(row, col) positions are only meaningful for one packed
    layout, so whenever the ELL pack changes — capacity-class growth, weight
    epoch bump, QRS re-pack — the caller passes a new ``key`` and the plane
    is rebuilt from scratch.  Between repacks the maintained plane is
    bit-for-bit identical to a full rebuild: slot validity cannot change
    without a repack, and absent edges write all-zero words either way.

    ``touched`` records the per-update scatter size (flipped slots, before
    power-of-two padding); tests pin it against the ``SlideDiff`` size the
    same way collective counts are HLO-pinned.
    """

    def __init__(self):
        self._key = None  # opaque pack identity (layout epoch)
        self._q = None  # query-fold width the plane was built for
        self._plane = None  # jax (R, D, W) uint32
        self._mask = None  # np bool (n_slots,) mask the plane encodes
        self._rows = None  # np (n_slots,) packed row per slot id (-1: none)
        self._cols = None  # np (n_slots,) packed col per slot id
        self._pattern = None  # np (W,) uint32 present-edge words
        self.touched: list = []  # scatter sizes per incremental update
        self.rebuilds = 0  # full plane rebuilds (invalidation events)
        self.incremental = True  # False: legacy rebuild-every-call path

    def invalidate(self) -> None:
        self._key = None
        self._plane = None
        self._mask = None

    def export_state(self) -> dict:
        """JSON-able counters + last mask for a warm-start checkpoint.

        The device plane itself is NOT exported: slot positions are only
        meaningful for one packed layout, and a restored process packs under
        a fresh epoch, so the restore path rebuilds the plane on first use
        (one rebuild, correct by construction).  What survives is the
        accounting a serving supervisor tracks across restarts.
        """
        return {
            "touched": [int(t) for t in self.touched],
            "rebuilds": int(self.rebuilds),
            "incremental": bool(self.incremental),
            "mask": (
                None if self._mask is None
                else [int(i) for i in np.flatnonzero(self._mask)]
            ),
            "mask_len": 0 if self._mask is None else int(len(self._mask)),
        }

    def import_state(self, state: dict) -> None:
        """Restore :meth:`export_state` counters into a fresh cache.

        The plane stays unset — the next :meth:`update` sees a new pack key
        and rebuilds it (counted as one more rebuild, matching what the
        uninterrupted process would do on its next repack).
        """
        self.touched = [int(t) for t in state.get("touched", [])]
        self.rebuilds = int(state.get("rebuilds", 0))
        self.incremental = bool(state.get("incremental", True))
        if state.get("mask") is not None and state.get("mask_len"):
            mask = np.zeros(int(state["mask_len"]), bool)
            mask[np.asarray(state["mask"], np.int64)] = True
            self._mask = mask

    def _set_layout(self, key, edge_id: np.ndarray, num_queries) -> None:
        eid = np.asarray(edge_id)
        n_slots = int(eid.max()) + 1 if eid.size else 0
        r, c = np.nonzero(eid >= 0)
        ids = eid[r, c]
        self._rows = np.full(n_slots, -1, np.int64)
        self._cols = np.zeros(n_slots, np.int64)
        self._rows[ids] = r
        self._cols[ids] = c
        self._pattern = presence_word_pattern(num_queries)
        self._key = key
        self._q = num_queries

    def update(
        self,
        key,
        mask: np.ndarray,
        edge_id: np.ndarray,
        *,
        num_queries: Optional[int] = None,
    ) -> jax.Array:
        """Return the word plane for ``mask``, maintained incrementally.

        ``key`` identifies the packed layout ``edge_id`` (any hashable —
        callers use their pack cache key); a key or Q-fold change rebuilds
        the plane from scratch.  ``mask`` is the per-slot presence over the
        edge universe ``edge_id`` indexes into.
        """
        mask = np.asarray(mask, bool)
        fresh = (
            self._plane is None
            or key != self._key
            or num_queries != self._q
            or not self.incremental
        )
        if fresh:
            if key != self._key or num_queries != self._q:
                self._set_layout(key, edge_id, num_queries)
            eid = np.asarray(edge_id)
            words = np.where(
                mask[:, None], self._pattern[None, :], np.uint32(0)
            ).astype(np.uint32)
            plane = np.zeros(eid.shape + (len(self._pattern),), np.uint32)
            valid = eid >= 0
            plane[valid] = words[eid[valid]]
            self._plane = jnp.asarray(plane)
            self._mask = mask.copy()
            self.rebuilds += 1
            _obs_presence(rebuild=True)
            return self._plane
        (diff,) = np.nonzero(mask != self._mask)
        diff = diff[self._rows[diff] >= 0]  # slot-less ids cannot scatter
        self._mask = mask.copy()
        self.touched.append(int(len(diff)))
        _obs_presence(touched=len(diff))
        if len(diff) == 0:
            return self._plane
        rows = self._rows[diff]
        cols = self._cols[diff]
        vals = np.where(
            mask[diff][:, None], self._pattern[None, :], np.uint32(0)
        ).astype(np.uint32)
        pad = _scatter_bucket(len(diff)) - len(diff)
        if pad:  # pad to a power-of-two bucket with idempotent repeat writes
            rows = np.concatenate([rows, np.repeat(rows[:1], pad)])
            cols = np.concatenate([cols, np.repeat(cols[:1], pad)])
            vals = np.concatenate([vals, np.repeat(vals[:1], pad, axis=0)])
        self._plane = self._plane.at[
            jnp.asarray(rows), jnp.asarray(cols)
        ].set(jnp.asarray(vals))
        return self._plane


def _obs_presence(*, rebuild: bool = False, touched: int = 0) -> None:
    """Mirror presence-plane maintenance into the metrics registry.

    The per-cache ``touched``/``rebuilds`` attributes stay the pinned
    source of truth (tests and ``presence_stats`` read them); the registry
    aggregates across every cache instance on BOTH serving routes — the
    unified accounting the pipelined path previously lacked.
    """
    from repro.obs.metrics import get_registry

    reg = get_registry()
    if not reg.enabled:
        return
    if rebuild:
        reg.counter(
            "presence_rebuilds_total", "full presence-plane rebuilds"
        ).inc()
    else:
        reg.counter(
            "presence_updates_total", "incremental presence scatters"
        ).inc()
        reg.counter(
            "presence_touched_slots_total", "slots flipped by presence scatters"
        ).inc(touched)


def vrelax_partial(
    values: jax.Array,  # (S, V)
    ell: EllPack,
    presence_ell: jax.Array,  # (R, D, W)
    semiring: str,
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Gather + kernel: per-(snapshot, row) masked reduction ``(S, R)``."""
    interpret = default_interpret() if interpret is None else interpret
    s = values.shape[0]
    s_pad = round_up(s, S_BLOCK)
    if s_pad != s:
        values = jnp.pad(values, ((0, s_pad - s), (0, 0)))
    gathered = values[:, ell.src]  # (S_pad, R, D) — XLA gather (see kernel.py)
    partial = vrelax_partial_pallas(
        gathered, ell.weight, presence_ell, semiring=semiring, interpret=interpret
    )
    return partial[:s]


@functools.partial(
    jax.jit,
    static_argnames=("sr", "num_vertices", "num_snapshots", "max_iters", "interpret"),
)
def concurrent_fixpoint_ell(
    bootstrap: jax.Array,  # (V,) or (S, V)
    ell: EllPack,
    presence_ell: jax.Array,  # (R, D, W)
    sr: Semiring,
    num_vertices: int,
    num_snapshots: int,
    max_iters: Optional[int] = None,
    interpret: bool = True,
):
    """Kernel-backed concurrent evaluation of all snapshots. → ((S,V), iters).

    ``bootstrap`` may be ``(V,)`` (broadcast over snapshots) or ``(S, V)``
    (per-snapshot initial state — the folded-QRS and Q·S-flattened batched
    paths).
    """
    if bootstrap.ndim == 2:
        values0 = bootstrap
    else:
        values0 = jnp.broadcast_to(bootstrap[None, :], (num_snapshots, num_vertices))
    limit = num_vertices + 1 if max_iters is None else max_iters
    row2vertex = ell.row2vertex

    def relax(values):
        partial = vrelax_partial(
            values, ell, presence_ell, sr.name, interpret=interpret
        )  # (S, R)
        # combine split rows → vertices (tiny XLA segment reduce)
        seg = functools.partial(
            sr.segment_reduce,
            segment_ids=row2vertex,
            num_segments=num_vertices,
            indices_are_sorted=True,
        )
        upd = jax.vmap(seg)(partial)
        return sr.improve(values, upd)

    def cond(state):
        _, changed, it = state
        return changed & (it < limit)

    def body(state):
        values, _, it = state
        new = relax(values)
        return new, jnp.any(new != values), it + 1

    values, _, iters = jax.lax.while_loop(
        cond, body, (values0, jnp.bool_(True), jnp.int32(0))
    )
    return values, iters


def concurrent_fixpoint_ell_batch(
    bootstrap: jax.Array,  # (Q, V) per-query R∩ values
    ell: EllPack,
    presence_ell_qs: jax.Array,  # (R, D, W') words repacked for the Q·S axis
    sr: Semiring,
    num_vertices: int,
    num_snapshots: int,
    num_queries: int,
    max_iters: Optional[int] = None,
    interpret: bool = True,
):
    """Kernel-backed batched evaluation: (Q, S, V) state through one kernel.

    Folds the query axis into the kernel's snapshot axis (combined index
    ``q * S + s``): the value state becomes ``(Q·S, V)`` and the presence
    words — repacked once host-side by :func:`tile_presence_words` — carry
    the same per-snapshot bit for every query.  One superstep then relaxes
    every (query × snapshot × edge) triple with the per-snapshot presence
    bit-test unchanged, and the ELL gather/reduce is amortized across the
    whole batch.  → ``(values (Q, S, V), iters)``.
    """
    values0 = jnp.repeat(bootstrap, num_snapshots, axis=0)  # (Q·S, V)
    values, iters = concurrent_fixpoint_ell(
        values0, ell, presence_ell_qs, sr, num_vertices,
        num_queries * num_snapshots, max_iters, interpret,
    )
    return values.reshape(num_queries, num_snapshots, num_vertices), iters

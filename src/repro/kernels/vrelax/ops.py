"""Public vrelax ops: kernel-backed CQRS superstep + fixpoint driver.

``concurrent_fixpoint_ell`` is the kernel-backed twin of
``repro.core.concurrent.concurrent_fixpoint`` (flat-edge XLA path); tests
assert they agree bit-for-bit with each other and with per-snapshot full
recompute.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.semiring import Semiring
from repro.graph.ell import EllPack
from repro.kernels.common import default_interpret
from repro.kernels.vrelax.kernel import S_BLOCK, vrelax_partial_pallas
from repro.utils.padding import round_up


def build_presence_ell(presence: jax.Array, ell: EllPack) -> jax.Array:
    """Scatter per-edge presence words ``(E, W)`` into ELL slots ``(R, D, W)``.

    Empty slots (edge_id == -1) get all-zero words → masked in-kernel.
    """
    eid = np.asarray(ell.edge_id)
    pres = np.asarray(presence)
    w = pres.shape[1]
    out = np.zeros((eid.shape[0], eid.shape[1], w), np.uint32)
    valid = eid >= 0
    out[valid] = pres[eid[valid]]
    return jnp.asarray(out)


def vrelax_partial(
    values: jax.Array,  # (S, V)
    ell: EllPack,
    presence_ell: jax.Array,  # (R, D, W)
    semiring: str,
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Gather + kernel: per-(snapshot, row) masked reduction ``(S, R)``."""
    interpret = default_interpret() if interpret is None else interpret
    s = values.shape[0]
    s_pad = round_up(s, S_BLOCK)
    if s_pad != s:
        values = jnp.pad(values, ((0, s_pad - s), (0, 0)))
    gathered = values[:, ell.src]  # (S_pad, R, D) — XLA gather (see kernel.py)
    partial = vrelax_partial_pallas(
        gathered, ell.weight, presence_ell, semiring=semiring, interpret=interpret
    )
    return partial[:s]


@functools.partial(
    jax.jit,
    static_argnames=("sr", "num_vertices", "num_snapshots", "max_iters", "interpret"),
)
def concurrent_fixpoint_ell(
    bootstrap: jax.Array,  # (V,)
    ell: EllPack,
    presence_ell: jax.Array,  # (R, D, W)
    sr: Semiring,
    num_vertices: int,
    num_snapshots: int,
    max_iters: Optional[int] = None,
    interpret: bool = True,
):
    """Kernel-backed concurrent evaluation of all snapshots. → ((S,V), iters)."""
    values0 = jnp.broadcast_to(bootstrap[None, :], (num_snapshots, num_vertices))
    limit = num_vertices + 1 if max_iters is None else max_iters
    row2vertex = ell.row2vertex

    def relax(values):
        partial = vrelax_partial(
            values, ell, presence_ell, sr.name, interpret=interpret
        )  # (S, R)
        # combine split rows → vertices (tiny XLA segment reduce)
        seg = functools.partial(
            sr.segment_reduce,
            segment_ids=row2vertex,
            num_segments=num_vertices,
            indices_are_sorted=True,
        )
        upd = jax.vmap(seg)(partial)
        return sr.improve(values, upd)

    def cond(state):
        _, changed, it = state
        return changed & (it < limit)

    def body(state):
        values, _, it = state
        new = relax(values)
        return new, jnp.any(new != values), it + 1

    values, _, iters = jax.lax.while_loop(
        cond, body, (values0, jnp.bool_(True), jnp.int32(0))
    )
    return values, iters

"""Public vrelax ops: kernel-backed CQRS superstep + fixpoint driver.

``concurrent_fixpoint_ell`` is the kernel-backed twin of
``repro.core.concurrent.concurrent_fixpoint`` (flat-edge XLA path); tests
assert they agree bit-for-bit with each other and with per-snapshot full
recompute.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.semiring import Semiring
from repro.graph.ell import EllPack
from repro.kernels.common import default_interpret
from repro.kernels.vrelax.kernel import S_BLOCK, vrelax_partial_pallas
from repro.utils.padding import round_up


def build_presence_ell(
    presence: jax.Array, ell: EllPack, *, as_numpy: bool = False
):
    """Scatter per-edge presence words ``(E, W)`` into ELL slots ``(R, D, W)``.

    Empty slots (edge_id == -1) get all-zero words → masked in-kernel.
    ``as_numpy`` skips the device upload — callers assembling several packs'
    word planes into one array (the per-shard SPMD ELL path stacks
    ``n_shards`` of them) concatenate host-side and upload once.
    """
    eid = np.asarray(ell.edge_id)
    pres = np.asarray(presence)
    w = pres.shape[1]
    out = np.zeros((eid.shape[0], eid.shape[1], w), np.uint32)
    valid = eid >= 0
    out[valid] = pres[eid[valid]]
    return out if as_numpy else jnp.asarray(out)


def tile_presence_words(
    presence: np.ndarray, num_snapshots: int, num_queries: int
) -> np.ndarray:
    """Repack per-edge presence words for a flattened Q·S snapshot axis.

    The batched ELL path folds Q queries into the kernel's snapshot axis
    (combined index ``t = q * S + s``); bit ``t`` of the repacked words must
    equal bit ``s`` of the originals.  Host-side, once per batch — the kernel
    and its word-sharing BlockSpec stay unchanged.
    """
    from repro.graph.structures import pack_presence

    pres = np.asarray(presence)
    snaps = np.arange(num_snapshots, dtype=np.uint32)
    words = pres[:, (snaps // 32).astype(np.int64)]  # (E, S)
    dense = ((words >> (snaps % 32)[None, :]) & 1).astype(bool).T  # (S, E)
    return pack_presence(np.tile(dense, (num_queries, 1)))  # (E, ceil(QS/32))


def vrelax_partial(
    values: jax.Array,  # (S, V)
    ell: EllPack,
    presence_ell: jax.Array,  # (R, D, W)
    semiring: str,
    *,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Gather + kernel: per-(snapshot, row) masked reduction ``(S, R)``."""
    interpret = default_interpret() if interpret is None else interpret
    s = values.shape[0]
    s_pad = round_up(s, S_BLOCK)
    if s_pad != s:
        values = jnp.pad(values, ((0, s_pad - s), (0, 0)))
    gathered = values[:, ell.src]  # (S_pad, R, D) — XLA gather (see kernel.py)
    partial = vrelax_partial_pallas(
        gathered, ell.weight, presence_ell, semiring=semiring, interpret=interpret
    )
    return partial[:s]


@functools.partial(
    jax.jit,
    static_argnames=("sr", "num_vertices", "num_snapshots", "max_iters", "interpret"),
)
def concurrent_fixpoint_ell(
    bootstrap: jax.Array,  # (V,) or (S, V)
    ell: EllPack,
    presence_ell: jax.Array,  # (R, D, W)
    sr: Semiring,
    num_vertices: int,
    num_snapshots: int,
    max_iters: Optional[int] = None,
    interpret: bool = True,
):
    """Kernel-backed concurrent evaluation of all snapshots. → ((S,V), iters).

    ``bootstrap`` may be ``(V,)`` (broadcast over snapshots) or ``(S, V)``
    (per-snapshot initial state — the folded-QRS and Q·S-flattened batched
    paths).
    """
    if bootstrap.ndim == 2:
        values0 = bootstrap
    else:
        values0 = jnp.broadcast_to(bootstrap[None, :], (num_snapshots, num_vertices))
    limit = num_vertices + 1 if max_iters is None else max_iters
    row2vertex = ell.row2vertex

    def relax(values):
        partial = vrelax_partial(
            values, ell, presence_ell, sr.name, interpret=interpret
        )  # (S, R)
        # combine split rows → vertices (tiny XLA segment reduce)
        seg = functools.partial(
            sr.segment_reduce,
            segment_ids=row2vertex,
            num_segments=num_vertices,
            indices_are_sorted=True,
        )
        upd = jax.vmap(seg)(partial)
        return sr.improve(values, upd)

    def cond(state):
        _, changed, it = state
        return changed & (it < limit)

    def body(state):
        values, _, it = state
        new = relax(values)
        return new, jnp.any(new != values), it + 1

    values, _, iters = jax.lax.while_loop(
        cond, body, (values0, jnp.bool_(True), jnp.int32(0))
    )
    return values, iters


def concurrent_fixpoint_ell_batch(
    bootstrap: jax.Array,  # (Q, V) per-query R∩ values
    ell: EllPack,
    presence_ell_qs: jax.Array,  # (R, D, W') words repacked for the Q·S axis
    sr: Semiring,
    num_vertices: int,
    num_snapshots: int,
    num_queries: int,
    max_iters: Optional[int] = None,
    interpret: bool = True,
):
    """Kernel-backed batched evaluation: (Q, S, V) state through one kernel.

    Folds the query axis into the kernel's snapshot axis (combined index
    ``q * S + s``): the value state becomes ``(Q·S, V)`` and the presence
    words — repacked once host-side by :func:`tile_presence_words` — carry
    the same per-snapshot bit for every query.  One superstep then relaxes
    every (query × snapshot × edge) triple with the per-snapshot presence
    bit-test unchanged, and the ELL gather/reduce is amortized across the
    whole batch.  → ``(values (Q, S, V), iters)``.
    """
    values0 = jnp.repeat(bootstrap, num_snapshots, axis=0)  # (Q·S, V)
    values, iters = concurrent_fixpoint_ell(
        values0, ell, presence_ell_qs, sr, num_vertices,
        num_queries * num_snapshots, max_iters, interpret,
    )
    return values.reshape(num_queries, num_snapshots, num_vertices), iters

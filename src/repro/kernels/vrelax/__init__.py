from repro.kernels.vrelax.ops import (
    vrelax_partial,
    concurrent_fixpoint_ell,
    build_presence_ell,
)

__all__ = [
    "vrelax_partial",
    "concurrent_fixpoint_ell",
    "build_presence_ell",
]

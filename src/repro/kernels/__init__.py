"""Pallas TPU kernels for the compute hot-spots.

Each kernel subpackage ships three layers:
  kernel.py — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (chooses kernel vs XLA path, host plumbing)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

On this CPU container kernels are validated with ``interpret=True``; on TPU
the same ``pallas_call`` lowers natively.  ``repro.kernels.common.default_interpret``
picks the mode from the backend.
"""
from repro.kernels.common import default_interpret

__all__ = ["default_interpret"]

"""Blocked online-softmax attention (FlashAttention-style) for the LM cells.

Grid = (batch·heads, q-blocks, kv-blocks); the kv axis is the innermost
(sequential) dimension, accumulating into VMEM scratch {m, l, acc} with the
standard online-softmax rescaling.  MXU work is the two (q_blk × d)·(d ×
kv_blk) / (q_blk × kv_blk)·(kv_blk × d) matmuls per step; block sizes default
to 128 so both matmuls are MXU-native 128×128 tiles and the score tile is one
(128, 128) VMEM buffer.

Causal masking is positional (global indices derived from the grid step), so
fully-masked kv blocks cost one masked matmul rather than a branch — on TPU
the sequential kv grid cannot skip steps without scalar prefetch, and the
masked-matmul cost is what the roofline counts anyway.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Q_BLOCK = 128
KV_BLOCK = 128
NEG_INF = -1.0e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, causal, kv_blocks
):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # (q_blk, d)
    k = k_ref[0]  # (kv_blk, d)
    v = v_ref[0]  # (kv_blk, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (q_blk, kv_blk)

    if causal:
        q_idx = pl.program_id(1)
        q_pos = q_idx * q.shape[0] + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 0
        )
        k_pos = kv_idx * k.shape[0] + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1
        )
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[...]  # (q_blk, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)  # (q_blk, kv_blk)
    alpha = jnp.exp(m_prev - m_new)  # (q_blk, 1)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(kv_idx == kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "interpret", "q_block", "kv_block")
)
def flash_attention_pallas(
    q: jax.Array,  # (BH, Tq, d)
    k: jax.Array,  # (BH, Tk, d)
    v: jax.Array,  # (BH, Tk, d)
    *,
    causal: bool = True,
    interpret: bool = True,
    q_block: int = Q_BLOCK,
    kv_block: int = KV_BLOCK,
) -> jax.Array:
    bh, tq, d = q.shape
    tk = k.shape[1]
    if tq % q_block or tk % kv_block:
        raise ValueError(f"Tq={tq} needs {q_block}-align, Tk={tk} needs {kv_block}-align")
    grid = (bh, tq // q_block, tk // kv_block)
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, kv_blocks=tk // kv_block
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, kv_block, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, kv_block, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

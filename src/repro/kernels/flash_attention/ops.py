"""Public flash-attention wrapper over (B, H, T, d) layouts."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(
    q: jax.Array,  # (B, H, Tq, d)
    k: jax.Array,  # (B, H, Tk, d)
    v: jax.Array,  # (B, H, Tk, d)
    *,
    causal: bool = True,
    use_kernel: bool = True,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interpret = default_interpret() if interpret is None else interpret
    b, h, tq, d = q.shape
    qf = q.reshape(b * h, tq, d)
    kf = k.reshape(b * h, k.shape[2], d)
    vf = v.reshape(b * h, v.shape[2], d)
    if use_kernel:
        out = flash_attention_pallas(qf, kf, vf, causal=causal, interpret=interpret)
    else:
        out = attention_ref(qf, kf, vf, causal=causal)
    return out.reshape(b, h, tq, d)

"""Pure-jnp attention oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True):
    """(BH, Tq, d) x (BH, Tk, d) → (BH, Tq, d), fp32 softmax."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (d ** 0.5)
    if causal:
        tq, tk = s.shape[-2], s.shape[-1]
        mask = jnp.arange(tq)[:, None] >= jnp.arange(tk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)

"""Shared kernel helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Static map from semiring name → (extend-op, minimize, identity); kernels are
# specialized per entry (hashable static args → one compile per semiring).
EXTEND_OPS = {
    "bfs": (lambda v, w: v + 1.0, True, float("inf")),
    "sssp": (lambda v, w: v + w, True, float("inf")),
    "sswp": (lambda v, w: jnp.minimum(v, w), False, 0.0),
    "ssnp": (lambda v, w: jnp.maximum(v, w), True, float("inf")),
    "viterbi": (lambda v, w: v * w, False, 0.0),
}


def default_interpret() -> bool:
    """Pallas interpret mode unless running on a real TPU backend."""
    return jax.default_backend() != "tpu"

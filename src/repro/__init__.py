"""repro: Stable Vertex Values (UVV) evolving-graph query framework in JAX.

Implements "Analysis of Stable Vertex Values: Fast Query Evaluation Over An
Evolving Graph" as a production-grade, multi-pod JAX framework: the paper's
intersection-union bound analysis / QRS / concurrent versioned evaluation as
first-class features, plus the model zoo, distribution, checkpointing, and
fault-tolerance substrate needed to run at pod scale.
"""

__version__ = "0.1.0"

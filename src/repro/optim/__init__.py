from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, opt_state_defs
from repro.optim.schedules import warmup_cosine

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "opt_state_defs",
    "warmup_cosine",
]

"""AdamW with global-norm clipping; optimizer state mirrors param sharding.

State layout: ``{"m": tree, "v": tree, "count": scalar}`` where m/v inherit
each parameter's ParamDef logical axes — under the FSDP rules (``embed`` →
``data``; heads/mlp/vocab/expert → ``model``) both the fp32 master moments
and the params are fully sharded across the 256/512-chip mesh (ZeRO-style),
which is what makes the 236B config fit 16 GiB chips (see EXPERIMENTS.md
§Dry-run memory table).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # memory levers for 100B+ on 16 GiB chips (Adafactor heritage):
    factored: bool = False  # rank-1 second moment for ndim≥2 params
    momentum_dtype: str = "float32"  # bf16 halves the m buffer


def _factored_shapes(shape):
    """(row_shape, col_shape) for the rank-1 second-moment factorization."""
    return shape[:-1], shape[:-2] + shape[-1:]


def adamw_init(params, cfg: "AdamWConfig | None" = None):
    cfg = cfg or AdamWConfig()
    mdt = jnp.dtype(cfg.momentum_dtype)

    def v_init(p):
        if cfg.factored and p.ndim >= 2:
            r, c = _factored_shapes(p.shape)
            return {
                "row": jnp.zeros(r, jnp.float32),
                "col": jnp.zeros(c, jnp.float32),
            }
        return jnp.zeros_like(p, dtype=jnp.float32)

    return {
        "m": jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=mdt), params),
        "v": jax.tree_util.tree_map(v_init, params),
        "count": jnp.zeros((), jnp.int32),
    }


def opt_state_defs(param_defs, cfg: "AdamWConfig | None" = None):
    """ParamDef tree for the optimizer state (dry-run abstract init)."""
    cfg = cfg or AdamWConfig()
    mdt = jnp.dtype(cfg.momentum_dtype)
    isdef = lambda x: isinstance(x, ParamDef)

    def m_def(d):
        return ParamDef(d.shape, mdt, d.logical_axes, "zeros")

    def v_def(d):
        if cfg.factored and len(d.shape) >= 2:
            r, c = _factored_shapes(d.shape)
            return {
                "row": ParamDef(r, jnp.float32, d.logical_axes[:-1], "zeros"),
                "col": ParamDef(
                    c, jnp.float32, d.logical_axes[:-2] + d.logical_axes[-1:], "zeros"
                ),
            }
        return ParamDef(d.shape, jnp.float32, d.logical_axes, "zeros")

    return {
        "m": jax.tree_util.tree_map(m_def, param_defs, is_leaf=isdef),
        "v": jax.tree_util.tree_map(v_def, param_defs, is_leaf=isdef),
        "count": ParamDef((), jnp.int32, (), "zeros"),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(grads, state, params, cfg: AdamWConfig,
                 lr_fn: Optional[Callable] = None):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    from repro.optim.schedules import warmup_cosine

    count = state["count"] + 1
    if lr_fn is None:
        lr = warmup_cosine(
            count, peak_lr=cfg.peak_lr, warmup_steps=cfg.warmup_steps,
            total_steps=cfg.total_steps,
        )
    else:
        lr = lr_fn(count)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = (b1 * m.astype(jnp.float32) + (1 - b1) * g)
        mhat = m_new / bc1
        if isinstance(v, dict):  # factored second moment (Adafactor-style)
            g2 = g * g
            row = b2 * v["row"] + (1 - b2) * g2.mean(axis=-1)
            col = b2 * v["col"] + (1 - b2) * g2.mean(axis=-2)
            r_mean = row.mean(axis=-1, keepdims=True)
            vhat = (
                row[..., :, None] * col[..., None, :]
                / jnp.maximum(r_mean[..., None], 1e-30)
            ) / bc2
            v_new = {"row": row, "col": col}
        else:
            v_full = b2 * v + (1 - b2) * g * g
            vhat = v_full / bc2
            v_new = v_full
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, m_new.astype(m.dtype), v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

"""DLRM (MLPerf config) [arXiv:1906.00091] — Criteo-1TB recommendation.

Huge sparse embedding tables (26 categorical fields, the canonical MLPerf
row counts, ~187M rows × 128) → dot-product feature interaction → small MLPs.
JAX has no native EmbeddingBag or CSR: the lookup is built from ``jnp.take``
+ the fused bag-reduce kernel (single-host) or a ``shard_map`` masked-local
lookup + psum (row-sharded tables over the ``model`` axis — the EP-style
pattern used by the pod-scale configs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.params import ParamDef

# MLPerf DLRM Criteo-1TB per-field row counts.
CRITEO_TABLE_SIZES = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-mlperf"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 128
    bot_mlp: tuple = (13, 512, 256, 128)
    top_mlp: tuple = (1024, 1024, 512, 256, 1)
    table_sizes: tuple = CRITEO_TABLE_SIZES
    dtype: str = "float32"

    @property
    def cdt(self):
        return jnp.dtype(self.dtype)

    @property
    def total_rows(self) -> int:
        return int(sum(self.table_sizes))

    @property
    def padded_rows(self) -> int:
        """Row count padded so any mesh axis (≤4096-way) divides the table."""
        n = self.total_rows
        return ((n + 4095) // 4096) * 4096

    @property
    def field_offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.table_sizes)[:-1]]).astype(np.int64)

    @property
    def n_interactions(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2


def _mlp_defs(dims: Sequence[int], dtype):
    defs = {}
    for i in range(len(dims) - 1):
        defs[f"w{i}"] = ParamDef((dims[i], dims[i + 1]), dtype, ("embed", "mlp"))
        defs[f"b{i}"] = ParamDef((dims[i + 1],), dtype, (None,), "zeros")
    return defs


def _mlp_fwd(p, x, final_act=False):
    n = len([k for k in p if k.startswith("w")])
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def dlrm_defs(cfg: DLRMConfig):
    top_in = cfg.n_interactions + cfg.embed_dim
    return {
        # single concatenated table, row-sharded over `model` at pod scale
        "table": ParamDef(
            (cfg.padded_rows, cfg.embed_dim), cfg.cdt, ("table_rows", None), "embed"
        ),
        "bot": _mlp_defs(cfg.bot_mlp, cfg.cdt),
        "top": _mlp_defs((top_in,) + cfg.top_mlp, cfg.cdt),
    }


# ---------------------------------------------------------------- lookup
def embedding_lookup(
    table: jax.Array,
    flat_idx: jax.Array,
    mesh: Optional[Mesh] = None,
    axes: tuple = ("pod", "data", "model"),
) -> jax.Array:
    """Row lookup. With a mesh: shard_map masked-local gather + psum so the
    row-sharded table never materializes (the all-reduce carries only the
    (B·F, dim) results — the classic model-parallel embedding exchange).
    The table is row-sharded over every available mesh axis in ``axes``."""
    if mesh is not None:
        axes = tuple(a for a in axes if a in mesh.axis_names)
    if mesh is None or not axes:
        return jnp.take(table, flat_idx, axis=0)

    from jax.experimental.shard_map import shard_map

    n_shards = 1
    for a in axes:
        n_shards *= int(mesh.shape[a])
    rows_local = table.shape[0] // n_shards

    def local_lookup(tbl, idx):
        shard = jnp.int32(0)
        for a in axes:
            shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
        local = idx - shard * rows_local
        ok = (local >= 0) & (local < rows_local)
        rows = jnp.take(tbl, jnp.clip(local, 0, rows_local - 1), axis=0)
        rows = jnp.where(ok[:, None], rows, 0.0)
        return jax.lax.psum(rows, axes)

    in_specs = (P(axes, None), P())
    return shard_map(
        local_lookup, mesh=mesh, in_specs=in_specs, out_specs=P(),
        check_rep=False,
    )(table, flat_idx)


# ---------------------------------------------------------------- forward
def dlrm_forward(cfg: DLRMConfig, params, batch, mesh: Optional[Mesh] = None):
    """batch: dense (B, 13) float, sparse (B, 26) int32 per-field ids
    → logits (B,)."""
    b = batch["dense"].shape[0]
    bot = _mlp_fwd(params["bot"], batch["dense"].astype(cfg.cdt), final_act=True)

    offsets = jnp.asarray(cfg.field_offsets, jnp.int32)
    flat_idx = (batch["sparse"] + offsets[None, :]).reshape(-1)  # (B*26,)
    emb = embedding_lookup(params["table"], flat_idx, mesh).reshape(
        b, cfg.n_sparse, cfg.embed_dim
    )

    # dot interaction over the 27 feature vectors (bottom output + fields)
    z = jnp.concatenate([bot[:, None, :], emb], axis=1)  # (B, 27, D)
    zz = jnp.einsum("bfd,bgd->bfg", z, z)  # (B, 27, 27)
    f = cfg.n_sparse + 1
    iu, ju = np.triu_indices(f, k=1)
    inter = zz[:, iu, ju]  # (B, 351)

    top_in = jnp.concatenate([bot, inter], axis=-1)
    return _mlp_fwd(params["top"], top_in)[:, 0]


def dlrm_loss(cfg: DLRMConfig, params, batch, mesh: Optional[Mesh] = None):
    """Binary cross-entropy CTR loss. batch adds labels (B,) float."""
    logits = dlrm_forward(cfg, params, batch, mesh)
    y = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    return loss, {"bce": loss}


def dlrm_retrieval_scores(
    cfg: DLRMConfig, params, batch, mesh: Optional[Mesh] = None, top_k: int = 100
):
    """Retrieval cell: one query vs n_candidates items.

    batch: dense (1, 13), cand_ids (N_c,) int32 (global rows into the table).
    Scores every candidate with a batched dot against the query tower output
    (no per-candidate loop), returns (top-k scores, top-k ids).
    """
    q = _mlp_fwd(params["bot"], batch["dense"].astype(cfg.cdt), final_act=True)  # (1, D)
    cand = embedding_lookup(params["table"], batch["cand_ids"], mesh)  # (N_c, D)
    scores = (cand @ q[0]).astype(jnp.float32)  # (N_c,)
    return jax.lax.top_k(scores, top_k)

"""Model zoo: LM transformers (dense/MoE/GQA/MQA/MLA), GNNs, DLRM."""

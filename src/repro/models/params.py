"""Parameter-definition system: metadata first, arrays on demand.

Models describe their parameters as a pytree of :class:`ParamDef` (shape,
dtype, logical axes, initializer).  From that single source of truth we
derive (a) real initialized params, (b) allocation-free abstract params for
the dry-run (``jax.ShapeDtypeStruct``), and (c) per-leaf NamedShardings via
the logical-axis rules.  No flax dependency — plain dict pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.partitioning import sharding_for


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    dtype: jnp.dtype
    logical_axes: tuple  # one logical name (or None) per dim
    init: str = "normal"  # normal | zeros | ones | embed | uniform_scaled

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (self.shape, self.logical_axes)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _fan_in(shape: Sequence[int]) -> int:
    return int(np.prod(shape[:-1])) if len(shape) > 1 else int(shape[0])


def init_param(d: ParamDef, key: jax.Array) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "embed":
        return (jax.random.normal(key, d.shape) * 0.02).astype(d.dtype)
    # truncated-normal fan-in scaling (the MaxText/t5x default)
    scale = 1.0 / np.sqrt(max(1, _fan_in(d.shape)))
    return (jax.random.truncated_normal(key, -2.0, 2.0, d.shape) * scale).astype(d.dtype)


def init_params(defs, rng: jax.Array):
    """Materialize a ParamDef tree into arrays (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(rng, len(leaves))
    vals = [init_param(d, k) for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(defs):
    """ShapeDtypeStruct tree — the dry-run stand-in (no allocation)."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=_is_def
    )


def param_shardings(defs, mesh, rules=None):
    """NamedSharding tree matching the ParamDef tree (divisibility-aware)."""
    return jax.tree_util.tree_map(
        lambda d: sharding_for(d.logical_axes, mesh, rules, d.shape),
        defs,
        is_leaf=_is_def,
    )


def param_count(defs) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=_is_def)
    return int(sum(np.prod(d.shape) for d in leaves))


def param_bytes(defs) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=_is_def)
    return int(sum(np.prod(d.shape) * jnp.dtype(d.dtype).itemsize for d in leaves))

"""Decoder-only LM: composable param defs, train forward, prefill, decode.

Layers are stacked on a leading ``L`` axis and executed with ``lax.scan``
(+ optional per-layer remat) so the 60-layer DeepSeek HLO stays compact and
activation memory is one layer boundary per microbatch.  Dense-first layers
(DeepSeek's ``first_k_dense``) form a second, smaller scan group.

Three entry points (all pjit-able; shardings via logical axes):
  ``lm_loss``       — training loss (tokens, targets) → scalar
  ``prefill_step``  — (B, T) prompt → last-token logits + KV cache
  ``decode_step``   — (B,) token + cache @ index → logits + cache
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import (
    TransformerConfig,
    ffn_defs,
    ffn_fwd,
    gqa_decode_fwd,
    gqa_defs,
    gqa_fwd,
    mla_decode_fwd,
    mla_defs,
    mla_fwd,
    moe_defs,
    moe_fwd,
    rmsnorm_defs,
    rmsnorm_fwd,
)
from repro.distributed.partitioning import constrain
from repro.models.params import ParamDef


# --------------------------------------------------------------------------
# parameter tree
# --------------------------------------------------------------------------
def _stack_defs(defs, n: int):
    """Add a leading scanned-layer axis to every ParamDef in the tree."""
    return jax.tree_util.tree_map(
        lambda d: ParamDef((n,) + d.shape, d.dtype, ("layers",) + d.logical_axes, d.init),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def _block_defs(cfg: TransformerConfig, moe: bool):
    attn = mla_defs(cfg) if cfg.attention_type == "mla" else gqa_defs(cfg)
    blk = {
        "attn_norm": rmsnorm_defs(cfg),
        "attn": attn,
        "ffn_norm": rmsnorm_defs(cfg),
    }
    if moe:
        blk["moe"] = moe_defs(cfg)
    else:
        blk["ffn"] = ffn_defs(cfg)
    return blk


def transformer_defs(cfg: TransformerConfig):
    n_dense = cfg.first_k_dense if cfg.moe else cfg.num_layers
    n_moe = cfg.num_layers - n_dense if cfg.moe else 0
    defs = {
        "embed": ParamDef(
            (cfg.vocab_size, cfg.d_model), cfg.pdtype, ("vocab", "embed"), "embed"
        ),
        "final_norm": rmsnorm_defs(cfg),
    }
    if n_dense:
        defs["dense_blocks"] = _stack_defs(_block_defs(cfg, moe=False), n_dense)
    if n_moe:
        defs["moe_blocks"] = _stack_defs(_block_defs(cfg, moe=True), n_moe)
    if not cfg.tie_embeddings:
        defs["out"] = ParamDef(
            (cfg.d_model, cfg.vocab_size), cfg.pdtype, ("embed", "vocab")
        )
    return defs


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------
def _attn_fwd(cfg, p, x, positions):
    if cfg.attention_type == "mla":
        return mla_fwd(cfg, p, x, positions)
    return gqa_fwd(cfg, p, x, positions)


def _block_fwd(cfg: TransformerConfig, p, x, positions, moe: bool):
    x = constrain(x, ("batch", "seq", "embed"))
    h = _attn_fwd(cfg, p["attn"], rmsnorm_fwd(p["attn_norm"], x), positions)
    x = x + h
    y_in = rmsnorm_fwd(p["ffn_norm"], x)
    if moe:
        y, aux = moe_fwd(cfg, p["moe"], y_in)
    else:
        y, aux = ffn_fwd(cfg, p["ffn"], y_in), jnp.float32(0.0)
    return constrain(x + y, ("batch", "seq", "embed")), aux


def _scan_blocks(cfg, stacked, x, positions, moe: bool):
    def blk(lp, xx):
        return _block_fwd(cfg, lp, xx, positions, moe)

    if cfg.remat:
        policy = None
        if cfg.remat_policy == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        blk = jax.checkpoint(blk, prevent_cse=False, policy=policy)

    def body(carry, lp):
        xx, aux = carry
        xx, a = blk(lp, xx)
        return (xx, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), stacked)
    return x, aux


def transformer_forward(cfg: TransformerConfig, params, tokens: jax.Array):
    """tokens (B, T) → (logits (B, T, V) fp32, aux loss)."""
    dt = cfg.compute_dtype
    x = params["embed"][tokens].astype(dt)
    if getattr(cfg, "scale_embeddings", False):
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
    aux = jnp.float32(0.0)
    if "dense_blocks" in params:
        x, a = _scan_blocks(cfg, params["dense_blocks"], x, positions, moe=False)
        aux += a
    if "moe_blocks" in params:
        x, a = _scan_blocks(cfg, params["moe_blocks"], x, positions, moe=True)
        aux += a
    x = rmsnorm_fwd(params["final_norm"], x)
    out_w = params["out"] if "out" in params else params["embed"].T
    logits = jnp.einsum("btd,dv->btv", x, out_w.astype(dt))
    logits = constrain(logits, ("batch", "seq", "vocab"))
    return logits.astype(jnp.float32), aux


def lm_loss(cfg: TransformerConfig, params, batch):
    """Cross-entropy (+ MoE aux + z-loss). batch: tokens/targets (B, T)."""
    logits, aux = transformer_forward(cfg, params, batch["tokens"])
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, batch["targets"][..., None], axis=-1)[..., 0]
    nll = logz - tgt
    mask = batch.get("mask")
    if mask is None:
        loss = nll.mean()
    else:
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    zloss = 1e-4 * jnp.mean(logz * logz)
    return loss + aux + zloss, {"nll": loss, "aux": aux, "zloss": zloss}


# --------------------------------------------------------------------------
# serving: cache init / prefill / decode
# --------------------------------------------------------------------------
def cache_defs(cfg: TransformerConfig, batch: int, max_len: int, *, big_seq=False):
    """ParamDef tree for the KV cache (lets the dry-run build abstract caches).

    ``big_seq=True`` shards the cache length over (data×model) — the 500k
    single-sequence regime where batch parallelism is unavailable.
    """
    seq_ax = "cache_seq_mp" if big_seq else "cache_seq"
    bt_ax = None if big_seq else "batch"
    cdt = jnp.dtype(cfg.dtype)

    def one(n_layers):
        if cfg.attention_type == "mla":
            return {
                "ckv": ParamDef(
                    (n_layers, batch, max_len, cfg.kv_lora_rank), cdt,
                    ("layers", bt_ax, seq_ax, None), "zeros",
                ),
                "krope": ParamDef(
                    (n_layers, batch, max_len, cfg.qk_rope_dim), cdt,
                    ("layers", bt_ax, seq_ax, None), "zeros",
                ),
            }
        return {
            "k": ParamDef(
                (n_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim), cdt,
                ("layers", bt_ax, seq_ax, "kv_heads", None), "zeros",
            ),
            "v": ParamDef(
                (n_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim), cdt,
                ("layers", bt_ax, seq_ax, "kv_heads", None), "zeros",
            ),
        }

    n_dense = cfg.first_k_dense if cfg.moe else cfg.num_layers
    n_moe = cfg.num_layers - n_dense if cfg.moe else 0
    out = {}
    if n_dense:
        out["dense"] = one(n_dense)
    if n_moe:
        out["moe"] = one(n_moe)
    return out


def _attn_decode(cfg, p, x, cache, idx):
    if cfg.attention_type == "mla":
        return mla_decode_fwd(cfg, p["attn"], x, cache, idx)
    return gqa_decode_fwd(cfg, p["attn"], x, cache, idx)


def _block_decode(cfg, p, x, cache, idx, moe: bool):
    h, new_cache = _attn_decode(cfg, p, rmsnorm_fwd(p["attn_norm"], x), cache, idx)
    x = x + h
    y_in = rmsnorm_fwd(p["ffn_norm"], x)
    if moe:
        y, _ = moe_fwd(cfg, p["moe"], y_in)
    else:
        y = ffn_fwd(cfg, p["ffn"], y_in)
    return x + y, new_cache


def _scan_decode(cfg, stacked, cache, x, idx, moe: bool):
    def body(xx, inputs):
        lp, lc = inputs
        xx, nc = _block_decode(cfg, lp, xx, lc, idx, moe)
        return xx, nc

    x, new_cache = jax.lax.scan(body, x, (stacked, cache))
    return x, new_cache


def decode_step(cfg: TransformerConfig, params, tokens, cache, cache_index):
    """One decode step. tokens (B,) int32 → (logits (B, V), new cache)."""
    dt = cfg.compute_dtype
    x = params["embed"][tokens][:, None, :].astype(dt)  # (B, 1, D)
    if getattr(cfg, "scale_embeddings", False):
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    new_cache = {}
    if "dense_blocks" in params:
        x, new_cache["dense"] = _scan_decode(
            cfg, params["dense_blocks"], cache["dense"], x, cache_index, moe=False
        )
    if "moe_blocks" in params:
        x, new_cache["moe"] = _scan_decode(
            cfg, params["moe_blocks"], cache["moe"], x, cache_index, moe=True
        )
    x = rmsnorm_fwd(params["final_norm"], x)
    out_w = params["out"] if "out" in params else params["embed"].T
    logits = jnp.einsum("btd,dv->btv", x, out_w.astype(dt))[:, 0]
    return logits.astype(jnp.float32), new_cache


def prefill_step(cfg: TransformerConfig, params, tokens: jax.Array):
    """Prompt prefill: (B, T) → last-token logits (B, V).

    (Cache population is a straightforward extension — the dry-run cells
    lower the compute-dominant pass below; decode_step covers cache reads.)
    """
    logits, _ = transformer_forward(cfg, params, tokens)
    return logits[:, -1]

"""DimeNet [arXiv:2003.03123] — directional message passing over triplets.

Messages live on *edges*; interaction blocks gather, for each target edge
(j→i), the incoming edges (k→j) (the triplet regime — not expressible as
SpMM) and modulate them by a 2-D basis of (distance d_kj, angle ∠kji) through
an 8-component bilinear tensor layer.

TPU adaptations (DESIGN.md §8.7):
  * triplet index lists are *inputs* (host-precomputed / sampled, capped at
    K per edge for non-molecular graphs) so shapes stay static;
  * the angular basis uses sin-radial × Legendre-polynomial angular factors
    (n_radial × n_spherical), a same-rank stand-in for the spherical-Bessel
    basis (numerically different basis functions, same tensor shapes and
    sparsity pattern — the systems behaviour under study).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn.common import GNNConfig, mlp_defs, mlp_fwd
from repro.models.params import ParamDef


# ---------------------------------------------------------------- bases
def envelope(d, cutoff, p=6):
    """Smooth polynomial cutoff (DimeNet eq. 8 family)."""
    x = jnp.clip(d / cutoff, 0.0, 1.0)
    return (
        1.0
        - (p + 1) * (p + 2) / 2 * x**p
        + p * (p + 2) * x ** (p + 1)
        - p * (p + 1) / 2 * x ** (p + 2)
    )


def radial_basis(d, n_radial, cutoff):
    """sin(nπ d/c)/d with smooth envelope. d: (E,) → (E, n_radial)."""
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    dd = jnp.maximum(d[:, None], 1e-6)
    rb = jnp.sin(n * np.pi * dd / cutoff) / dd
    return rb * envelope(d, cutoff)[:, None]


def _legendre(cos_t, l_max):
    """P_0..P_{l_max-1}(cosθ) via the Bonnet recurrence. → (T, l_max)."""
    outs = [jnp.ones_like(cos_t)]
    if l_max > 1:
        outs.append(cos_t)
    for l in range(2, l_max):
        outs.append(((2 * l - 1) * cos_t * outs[-1] - (l - 1) * outs[-2]) / l)
    return jnp.stack(outs, axis=-1)


def angular_basis(d_kj, cos_angle, n_radial, n_spherical, cutoff):
    """(T,) × (T,) → (T, n_spherical * n_radial) joint distance-angle basis."""
    rb = radial_basis(d_kj, n_radial, cutoff)  # (T, R)
    pl = _legendre(cos_angle, n_spherical)  # (T, L)
    return (rb[:, None, :] * pl[:, :, None]).reshape(d_kj.shape[0], -1)


# ---------------------------------------------------------------- model
def dimenet_defs(cfg: GNNConfig):
    d = cfg.d_hidden
    nrb = cfg.n_radial
    nsbf = cfg.n_spherical * cfg.n_radial
    blocks = {}
    for i in range(cfg.num_layers):
        blocks[f"block{i}"] = {
            "w_rbf": ParamDef((nrb, d), cfg.cdt, (None, "mlp")),
            "w_sbf": ParamDef((nsbf, cfg.n_bilinear), cfg.cdt, (None, None)),
            "w_bil": ParamDef((cfg.n_bilinear, d, d), cfg.cdt, (None, "embed", "mlp")),
            "dense_ji": mlp_defs((d, d), cfg.cdt),
            "dense_kj": mlp_defs((d, d), cfg.cdt),
            "post": mlp_defs((d, d, d), cfg.cdt),
            "out_rbf": ParamDef((nrb, d), cfg.cdt, (None, "mlp")),
            "out": mlp_defs((d, d, 1), cfg.cdt),
        }
    return {
        "atom_embed": ParamDef((cfg.num_atom_types, d), cfg.cdt, (None, "embed"), "embed"),
        "edge_embed": mlp_defs((2 * d + cfg.n_radial, d, d), cfg.cdt),
        "blocks": blocks,
    }


def dimenet_forward(cfg: GNNConfig, params, batch, num_graphs: int = 1):
    """batch: atom_type (N,), pos (N,3), edge_src/dst (E,), triplet_kj/ji (T,),
    graph_id (N,) → per-graph energy (num_graphs,).  ``num_graphs`` is static.

    Triplet t pairs edge ``triplet_kj[t]`` = (k→j) with target edge
    ``triplet_ji[t]`` = (j→i); invalid/padded triplets carry index 0 with
    ``triplet_valid`` False.
    """
    src, dst = batch["edge_src"], batch["edge_dst"]
    pos = batch["pos"].astype(cfg.cdt)
    t_kj, t_ji = batch["triplet_kj"], batch["triplet_ji"]
    t_valid = batch.get("triplet_valid")
    e_valid = batch.get("edge_valid")
    n_edges = src.shape[0]

    vec = pos[dst] - pos[src]  # j→i direction per edge (E, 3)
    d_e = jnp.sqrt(jnp.maximum((vec * vec).sum(-1), 1e-12))
    rbf = radial_basis(d_e, cfg.n_radial, cfg.cutoff)  # (E, R)

    # triplet angle at j between (k→j) and (j→i)
    v_ji = vec[t_ji]
    v_kj = -vec[t_kj]  # pointing k→j reversed to j→k for the angle at j
    cos_a = (v_ji * v_kj).sum(-1) / jnp.maximum(
        jnp.linalg.norm(v_ji, axis=-1) * jnp.linalg.norm(v_kj, axis=-1), 1e-9
    )
    sbf = angular_basis(d_e[t_kj], jnp.clip(cos_a, -1.0, 1.0), cfg.n_radial,
                        cfg.n_spherical, cfg.cutoff)  # (T, S*R)

    h = params["atom_embed"][batch["atom_type"]]
    m = mlp_fwd(
        params["edge_embed"],
        jnp.concatenate([h[src], h[dst], rbf], axis=-1),
        final_act=True,
    )  # (E, d) directional edge messages

    t_total = t_kj.shape[0]
    tv = t_valid if t_valid is not None else jnp.ones(t_total, bool)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def block_fn(p, m):
        x_ji = jax.nn.silu(mlp_fwd(p["dense_ji"], m))
        x_kj_edges = jax.nn.silu(mlp_fwd(p["dense_kj"], m))  # (E, d)
        sb = sbf @ p["w_sbf"]  # (T, B)

        def t_messages(kj_c, ji_c, sb_c, tv_c, agg):
            # bilinear: combine angular basis with source-edge message
            t_msg = jnp.einsum("tb,td,bdh->th", sb_c, x_kj_edges[kj_c], p["w_bil"])
            t_msg = jnp.where(tv_c[:, None], t_msg, 0.0)
            return agg.at[ji_c].add(t_msg)

        ck = cfg.triplet_chunk
        agg0 = jnp.zeros((n_edges, x_ji.shape[1]), cfg.cdt)
        if ck and t_total > ck and t_total % ck == 0:
            from repro.utils.chunked import chunked_scatter_sum

            nc = t_total // ck

            # linear triplet aggregation with recompute backward
            def chunk_msg(diff, ints_c, floats_c):
                w_bil, x_kj_e = diff
                (kj_c,) = ints_c
                sb_c, tv_c = floats_c
                t_msg = jnp.einsum("tb,td,bdh->th", sb_c, x_kj_e[kj_c], w_bil)
                return t_msg * tv_c[:, None]  # tv is 0/1 float here

            agg = chunked_scatter_sum(
                chunk_msg, agg0.shape, cfg.cdt,
                (p["w_bil"], x_kj_edges),
                t_ji.reshape(nc, ck),
                (t_kj.reshape(nc, ck),),
                (sb.reshape(nc, ck, -1), tv.reshape(nc, ck).astype(cfg.cdt)),
            )
        else:
            agg = t_messages(t_kj, t_ji, sb, tv, agg0)
        m_new = x_ji * (rbf @ p["w_rbf"]) + agg
        m = m + mlp_fwd(p["post"], m_new, final_act=True)

        # per-block output head: edge → node → graph energy
        per_edge = m * (rbf @ p["out_rbf"])
        if e_valid is not None:
            per_edge = jnp.where(e_valid[:, None], per_edge, 0.0)
        per_node = jax.ops.segment_sum(per_edge, dst, h.shape[0])
        node_e = mlp_fwd(p["out"], per_node)[:, 0]
        e_blk = jax.ops.segment_sum(node_e, batch["graph_id"], num_graphs)
        return m, e_blk

    energy = jnp.zeros((num_graphs,), cfg.cdt)
    for i in range(cfg.num_layers):
        m, e_blk = block_fn(params["blocks"][f"block{i}"], m)
        energy = energy + e_blk
    return energy

"""Real-spherical-harmonic rotation (Wigner-D) machinery for eSCN layers.

Strategy (e3nn-style, TPU-friendly):
  * rotations about **z** in the real-SH basis have a closed form — ±m pairs
    mix with cos/sin(mθ) (two VPU ops per edge);
  * the constant change-of-basis ``J_l = D_l(R_x(π/2))`` is precomputed once
    per ``l`` on the host by least-squares over a point grid of real SH
    evaluations (exact to fp64 round-off; no scipy needed);
  * any rotation then factors as  D(R_z(α)R_y(β)) = Dz(α) · Jᵀ · Dz(β) · J.

Conventions: basis order within ``l`` is m = −l..l, with
Y_{l,m>0} ∝ P_l^m cos(mφ), Y_{l,−m} ∝ P_l^m sin(mφ); all matrices are
orthogonal, so rotate-back is a transpose.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------- numpy SH
def _assoc_legendre_np(l_max: int, x: np.ndarray) -> dict:
    """P_l^m(x) for 0 <= m <= l <= l_max (no Condon-Shortley)."""
    out = {}
    somx2 = np.sqrt(np.maximum(1.0 - x * x, 0.0))
    pmm = np.ones_like(x)
    for m in range(l_max + 1):
        out[(m, m)] = pmm.copy()
        if m < l_max:
            out[(m + 1, m)] = x * (2 * m + 1) * pmm
        for l in range(m + 2, l_max + 1):
            out[(l, m)] = (
                (2 * l - 1) * x * out[(l - 1, m)] - (l + m - 1) * out[(l - 2, m)]
            ) / (l - m)
        pmm = pmm * -(2 * m + 1) * somx2  # CS phase folded; consistent either way
    return out


def real_sph_harm_np(l_max: int, pts: np.ndarray) -> np.ndarray:
    """Real SH values Y_{l,m}(p) for unit vectors pts (n,3) → (n, (L+1)^2)."""
    x, y, z = pts[:, 0], pts[:, 1], pts[:, 2]
    phi = np.arctan2(y, x)
    ct = np.clip(z, -1.0, 1.0)
    P = _assoc_legendre_np(l_max, ct)
    cols = []
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            am = abs(m)
            norm = np.sqrt(
                (2 * l + 1)
                / (4 * np.pi)
                * float(math.factorial(l - am))
                / float(math.factorial(l + am))
            )
            if m == 0:
                cols.append(norm * P[(l, 0)])
            elif m > 0:
                cols.append(np.sqrt(2.0) * norm * P[(l, m)] * np.cos(m * phi))
            else:
                cols.append(np.sqrt(2.0) * norm * P[(l, am)] * np.sin(am * phi))
    return np.stack(cols, axis=1)


@functools.lru_cache(maxsize=None)
def j_matrices(l_max: int) -> tuple:
    """Constant ``J_l = D_l(R_x(π/2))`` per l, solved on a host point grid."""
    rng = np.random.default_rng(12345)
    pts = rng.normal(size=(max(512, 8 * (l_max + 1) ** 2), 3))
    pts /= np.linalg.norm(pts, axis=1, keepdims=True)
    # R_a = rotation about x by +π/2:  (x, y, z) → (x, −z, y);  R_a ŷ = ẑ.
    ra = np.array([[1.0, 0.0, 0.0], [0.0, 0.0, -1.0], [0.0, 1.0, 0.0]])
    # (D(R) Y)(p) = Y(R⁻¹ p)  ⇒  solve  Y(R_a⁻¹ p) = J · Y(p).
    y_p = real_sph_harm_np(l_max, pts)
    y_rp = real_sph_harm_np(l_max, pts @ ra)  # rows: Y(R_a⁻¹ p) = Y(p Rᵀ... )
    js = []
    off = 0
    for l in range(l_max + 1):
        k = 2 * l + 1
        a = y_p[:, off : off + k]
        b = y_rp[:, off : off + k]
        j, *_ = np.linalg.lstsq(a, b, rcond=None)
        j = j.T  # b_rows = a_rows @ j.T  ⇒  Y(R⁻¹p) = J Y(p)
        # orthogonality check / cleanup
        u, _, vt = np.linalg.svd(j)
        js.append((u @ vt).astype(np.float32))
        off += k
    # NOTE: cache NUMPY constants — caching jnp arrays created inside a
    # trace (e.g. under jax.checkpoint) leaks tracers across traces.
    return tuple(js)


# ------------------------------------------------------------- jax rotations
def dz_matrix(l: int, theta: jax.Array) -> jax.Array:
    """Closed-form rotation about z in the real-SH l-block. θ: (E,) → (E,k,k)."""
    k = 2 * l + 1
    m = jnp.arange(-l, l + 1)
    c = jnp.cos(jnp.abs(m)[None, :] * theta[:, None])  # (E, k)
    s = jnp.sin(jnp.abs(m)[None, :] * theta[:, None]) * jnp.sign(m)[None, :]
    eye = jnp.eye(k)
    flip = jnp.fliplr(eye)
    return c[:, :, None] * eye[None] + s[:, :, None] * flip[None]


def edge_wigner(l_max: int, edge_vec: jax.Array) -> list:
    """Per-edge coefficient-rotation matrices into the edge-aligned frame.

    R_e maps the edge direction n̂ (azimuth α, polar β) onto ẑ.  The
    coefficient matrix — validated against the pointwise-SH delta property
    (C·Y(n̂) = Y(ẑ)) in tests — factors as  C = J · Dz(β) · Jᵀ · Dz(α)
    per l, with J the constant x-axis-π/2 change of basis.
    ``rotate_blocks(C, x)`` aligns features; ``transpose=True`` rotates back.
    """
    n = edge_vec / jnp.maximum(
        jnp.linalg.norm(edge_vec, axis=-1, keepdims=True), 1e-9
    )
    alpha = jnp.arctan2(n[:, 1], n[:, 0])
    beta = jnp.arccos(jnp.clip(n[:, 2], -1.0, 1.0))
    js = j_matrices(l_max)
    out = []
    for l in range(l_max + 1):
        dz_a = dz_matrix(l, alpha)
        dz_b = dz_matrix(l, beta)
        j = js[l]
        d = jnp.einsum("ij,ejk,kl,elm->eim", j, dz_b, j.T, dz_a)
        out.append(d)
    return out


def rotate_blocks(d_list: list, x: jax.Array, transpose: bool = False) -> jax.Array:
    """Apply block-diagonal per-edge rotation to (E, (L+1)^2, C) features."""
    outs = []
    off = 0
    for l, d in enumerate(d_list):
        k = 2 * l + 1
        blk = x[:, off : off + k, :]
        eq = "eji,ejc->eic" if transpose else "eij,ejc->eic"
        outs.append(jnp.einsum(eq, d, blk))
        off += k
    return jnp.concatenate(outs, axis=1)

"""Principal Neighbourhood Aggregation (PNA) [arXiv:2004.05718].

4 aggregators {mean, std, max, min} × 3 degree scalers {identity,
amplification, attenuation} → 12 aggregated views concatenated with the
self feature, projected back to d_hidden.  The 4-statistic reduction is the
fused `ell_agg` kernel's target shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.gnn.common import (
    GNNConfig,
    layernorm_defs,
    layernorm_fwd,
    mlp_defs,
    mlp_fwd,
    multi_aggregate,
)
from repro.models.params import ParamDef


def pna_defs(cfg: GNNConfig):
    d = cfg.d_hidden
    layers = {}
    for i in range(cfg.num_layers):
        layers[f"layer{i}"] = {
            "msg": mlp_defs((2 * d, d, d), cfg.cdt),
            "upd": mlp_defs((12 * d + d, d, d), cfg.cdt),
            "norm": layernorm_defs(d, cfg.cdt),
        }
    return {
        "encode": mlp_defs((cfg.d_feat, d), cfg.cdt),
        "layers": layers,
        "decode": mlp_defs((d, d, cfg.num_classes), cfg.cdt),
    }


def pna_forward(cfg: GNNConfig, params, batch):
    """batch: node_feat (N,F), edge_src/dst (E,), edge_valid (E,) → logits."""
    from repro.distributed.partitioning import constrain

    ep = cfg.edge_parallel
    repl = (None, None)  # replicated node state (edge-parallel regime)
    shard = ("vertices", None)

    h = mlp_fwd(params["encode"], batch["node_feat"].astype(cfg.cdt))
    h = constrain(h, repl if ep else shard)
    src, dst = batch["edge_src"], batch["edge_dst"]
    valid = batch.get("edge_valid")
    n = h.shape[0]
    delta = cfg.avg_log_degree

    for i in range(cfg.num_layers):
        p = params["layers"][f"layer{i}"]
        msgs = mlp_fwd(p["msg"], jnp.concatenate([h[src], h[dst]], axis=-1))
        mean, std, mmax, mmin, cnt = multi_aggregate(msgs, dst, n, valid)
        aggs = jnp.concatenate([mean, std, mmax, mmin], axis=-1)  # (N, 4d)
        if ep:  # node-update phase runs vertex-sharded
            aggs = constrain(aggs, shard)
            cnt = constrain(cnt, shard)
            h = constrain(h, shard)
        logd = jnp.log1p(cnt)  # (N, 1)
        amp = logd / delta
        att = delta / jnp.maximum(logd, 1e-5)
        scaled = jnp.concatenate([aggs, aggs * amp, aggs * att], axis=-1)  # (N, 12d)
        out = mlp_fwd(p["upd"], jnp.concatenate([h, scaled], axis=-1))
        h = layernorm_fwd(p["norm"], h + out)
        h = constrain(h, repl if ep else shard)  # re-broadcast for next gather
    return mlp_fwd(params["decode"], constrain(h, shard))

"""Shared GNN machinery: segment message passing, degree scalers, losses.

JAX sparse is BCOO-only, so message passing here IS the substrate: edge-index
scatter via ``jax.ops.segment_*`` (sum/max/min), with the fused multi-stat
Pallas kernel (`repro.kernels.ell_agg`) as the TPU hot-path alternative for
the PNA-style multi-aggregator reduction.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str  # pna | gatedgcn | dimenet | equiformer_v2
    num_layers: int
    d_hidden: int
    d_feat: int
    num_classes: int = 40
    # pna
    avg_log_degree: float = 3.0
    # gatedgcn
    d_edge_feat: int = 8
    # dimenet
    n_radial: int = 6
    n_spherical: int = 7
    n_bilinear: int = 8
    cutoff: float = 5.0
    num_atom_types: int = 32
    # equiformer
    l_max: int = 6
    m_max: int = 2
    num_heads: int = 8
    edge_chunk: int = 0  # >0: scan edge blocks of this size (memory bound)
    triplet_chunk: int = 0  # dimenet: scan triplet blocks of this size
    # §Perf C2 (edge-parallel hybrid): node state REPLICATED across the mesh
    # (so per-edge gathers are chip-local) while the node-update phase is
    # vertex-sharded (so node compute stays distributed).  Per layer this
    # costs one partial-sum all-reduce of the aggregate + one all-gather of
    # the new node state — instead of per-edge cross-chip gather traffic.
    edge_parallel: bool = False
    dtype: str = "float32"

    @property
    def cdt(self):
        return jnp.dtype(self.dtype)


def segment_softmax(logits: jax.Array, segment_ids: jax.Array, num_segments: int):
    """Numerically-stable softmax over variable-size segments (edge→dst)."""
    seg_max = jax.ops.segment_max(logits, segment_ids, num_segments)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    ex = jnp.exp(logits - seg_max[segment_ids])
    denom = jax.ops.segment_sum(ex, segment_ids, num_segments)
    return ex / jnp.maximum(denom[segment_ids], 1e-16)


def multi_aggregate(msgs: jax.Array, dst: jax.Array, num_nodes: int, valid=None):
    """{mean, std, max, min} per destination — flat-edge XLA twin of the
    fused `ell_agg` kernel (same outputs, so the kernel is a drop-in)."""
    if valid is not None:
        msgs = jnp.where(valid[:, None], msgs, 0.0)
        ones = valid.astype(msgs.dtype)
    else:
        ones = jnp.ones(msgs.shape[0], msgs.dtype)
    cnt = jax.ops.segment_sum(ones, dst, num_nodes)[:, None]
    s = jax.ops.segment_sum(msgs, dst, num_nodes)
    sq = jax.ops.segment_sum(msgs * msgs, dst, num_nodes)
    big = jnp.asarray(3e38, msgs.dtype)
    mmax = jax.ops.segment_max(
        jnp.where((valid[:, None] if valid is not None else True), msgs, -big), dst, num_nodes
    )
    mmin = jax.ops.segment_min(
        jnp.where((valid[:, None] if valid is not None else True), msgs, big), dst, num_nodes
    )
    denom = jnp.maximum(cnt, 1.0)
    mean = s / denom
    std = jnp.sqrt(jnp.maximum(sq / denom - mean * mean, 0.0) + 1e-5)
    empty = cnt == 0
    return (
        jnp.where(empty, 0.0, mean),
        jnp.where(empty, 0.0, std),
        jnp.where(empty, 0.0, mmax),
        jnp.where(empty, 0.0, mmin),
        cnt,
    )


def mlp_defs(dims: tuple, dtype, prefix_axes=("embed", "mlp")):
    """Simple MLP ParamDefs: dims = (in, h1, ..., out)."""
    defs = {}
    for i in range(len(dims) - 1):
        defs[f"w{i}"] = ParamDef((dims[i], dims[i + 1]), dtype, prefix_axes)
        defs[f"b{i}"] = ParamDef((dims[i + 1],), dtype, (None,), "zeros")
    return defs


def mlp_fwd(p, x, act=jax.nn.silu, final_act=False):
    n = len([k for k in p if k.startswith("w")])
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


def layernorm_defs(dim, dtype):
    return {
        "scale": ParamDef((dim,), dtype, (None,), "ones"),
        "bias": ParamDef((dim,), dtype, (None,), "zeros"),
    }


def layernorm_fwd(p, x, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]


def node_classification_loss(logits, labels, mask=None):
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = logz - tgt
    if mask is None:
        return nll.mean()
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)

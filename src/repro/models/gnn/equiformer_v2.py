"""EquiformerV2 [arXiv:2306.12059] — equivariant graph attention via eSCN.

The O(L⁶) Clebsch-Gordan tensor product is replaced by eSCN's SO(2) trick
[arXiv:2302.03655]: rotate source irreps into the edge-aligned frame (our
Wigner machinery, `wigner.py`), where the convolution preserves azimuthal
order m; truncate to |m| ≤ m_max and apply per-m linear maps mixing degrees
and channels (O(L³)); rotate back and aggregate with attention.

Structure per layer (faithful-in-spirit, simplifications in DESIGN.md §8.7):
  * GAT-style attention logits from scalar (l=0) features + radial basis —
    computed BEFORE the expensive message pass so the giant-graph edge-chunked
    path can do softmax globally and messages chunk-wise;
  * eSCN SO(2) convolution messages, radially modulated per degree l;
  * gate activation (scalars gate higher degrees), equivariant RMS layer norm.

Memory: per-edge irrep tensors are (E, (L+1)², C); for the 61M/114M-edge
shapes `cfg.edge_chunk` scans fixed-size edge blocks, accumulating the (N,
(L+1)², C) aggregate — bounded working set, identical math.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn.common import GNNConfig, mlp_defs, mlp_fwd, segment_softmax
from repro.models.gnn.dimenet import radial_basis
from repro.models.gnn.wigner import edge_wigner, rotate_blocks
from repro.models.params import ParamDef


def _m_layout(l_max: int, m_max: int):
    """Index bookkeeping: for each m ∈ 0..m_max, the (row, l) pairs carrying
    that order, as flat indices into the (L+1)² irrep axis."""
    cos_idx, sin_idx, m0_idx = {}, {}, []
    for m in range(m_max + 1):
        cos_idx[m], sin_idx[m] = [], []
        for l in range(m, l_max + 1):
            base = l * l
            if m == 0:
                m0_idx.append(base + l)
            else:
                cos_idx[m].append(base + l + m)
                sin_idx[m].append(base + l - m)
    return m0_idx, cos_idx, sin_idx


def so2_conv_defs(cfg: GNNConfig):
    """Per-m linear maps: (n_l(m)·C) → (n_l(m)·C), real+imag for m>0."""
    c = cfg.d_hidden
    defs = {}
    for m in range(cfg.m_max + 1):
        n_l = cfg.l_max + 1 - m
        dim = n_l * c
        defs[f"w{m}_r"] = ParamDef((dim, dim), cfg.cdt, ("embed", "mlp"))
        if m > 0:
            defs[f"w{m}_i"] = ParamDef((dim, dim), cfg.cdt, ("embed", "mlp"))
    return defs


def so2_conv_fwd(cfg: GNNConfig, p, x_rot: jax.Array, layout):
    """x_rot: (E, (L+1)², C) edge-frame features → same shape, m>m_max zeroed."""
    e, _, c = x_rot.shape
    m0_idx, cos_idx, sin_idx = layout
    out = jnp.zeros_like(x_rot)

    # m = 0: plain linear over (l, channel)
    x0 = x_rot[:, jnp.asarray(m0_idx), :].reshape(e, -1)
    y0 = x0 @ p["w0_r"]
    out = out.at[:, jnp.asarray(m0_idx), :].set(y0.reshape(e, -1, c))

    # m > 0: complex-style 2x2 mixing of (cos, sin) components
    for m in range(1, cfg.m_max + 1):
        ci = jnp.asarray(cos_idx[m])
        si = jnp.asarray(sin_idx[m])
        xc = x_rot[:, ci, :].reshape(e, -1)
        xs = x_rot[:, si, :].reshape(e, -1)
        wr, wi = p[f"w{m}_r"], p[f"w{m}_i"]
        yc = xc @ wr - xs @ wi
        ys = xs @ wr + xc @ wi
        out = out.at[:, ci, :].set(yc.reshape(e, -1, c))
        out = out.at[:, si, :].set(ys.reshape(e, -1, c))
    return out


def equiformer_defs(cfg: GNNConfig):
    c = cfg.d_hidden
    layers = {}
    for i in range(cfg.num_layers):
        layers[f"layer{i}"] = {
            "so2": so2_conv_defs(cfg),
            "radial": mlp_defs((cfg.n_radial, c, cfg.l_max + 1), cfg.cdt),
            "alpha": mlp_defs((2 * c + cfg.n_radial, c, cfg.num_heads), cfg.cdt),
            "gate": mlp_defs((c, c, cfg.l_max), cfg.cdt),
            "scalar_mlp": mlp_defs((c, 2 * c, c), cfg.cdt),
            "ln_scale": ParamDef((cfg.l_max + 1, c), cfg.cdt, (None, None), "ones"),
        }
    return {
        "embed": mlp_defs((cfg.d_feat, c), cfg.cdt),
        "layers": layers,
        "decode": mlp_defs((c, c, cfg.num_classes), cfg.cdt),
    }


def _equi_layernorm(p, x, l_max):
    """Per-degree RMS norm: scalars get standard centering-free LN; each
    l-block is scaled by its mean vector norm (equivariant)."""
    outs = []
    for l in range(l_max + 1):
        blk = x[:, l * l : (l + 1) ** 2, :]  # (N, 2l+1, C)
        rms = jnp.sqrt(jnp.mean(jnp.sum(blk * blk, axis=1), axis=-1) + 1e-6)
        outs.append(blk / rms[:, None, None] * p["ln_scale"][l][None, None, :])
    return jnp.concatenate(outs, axis=1)


def equiformer_forward(cfg: GNNConfig, params, batch):
    """batch: node_feat (N,F), pos (N,3), edge_src/dst (E,) → node outputs.

    Returns logits (N, num_classes) from the invariant (l=0) channel.
    """
    n = batch["node_feat"].shape[0]
    m_sq = (cfg.l_max + 1) ** 2
    c = cfg.d_hidden
    layout = _m_layout(cfg.l_max, cfg.m_max)

    # nodes start as scalars; higher degrees are created by the edge geometry
    x = jnp.zeros((n, m_sq, c), cfg.cdt)
    x = x.at[:, 0, :].set(mlp_fwd(params["embed"], batch["node_feat"].astype(cfg.cdt)))

    src, dst = batch["edge_src"], batch["edge_dst"]
    pos = batch["pos"].astype(cfg.cdt)
    vec = pos[dst] - pos[src]
    dist = jnp.sqrt(jnp.maximum((vec * vec).sum(-1), 1e-12))
    rbf = radial_basis(dist, cfg.n_radial, cfg.cutoff)  # (E, R)
    e_valid = batch.get("edge_valid")

    deg_l = jnp.asarray(
        np.repeat(np.arange(cfg.l_max + 1), 2 * np.arange(cfg.l_max + 1) + 1)
    )

    e_total = src.shape[0]
    use_chunks = bool(
        cfg.edge_chunk and e_total > cfg.edge_chunk
        and e_total % cfg.edge_chunk == 0
    )

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def layer_fn(p, x):
        # ---- attention logits from invariant (l=0) features.  Chunked on
        # giant graphs: the MLP hidden is (E, C) — 32 GB at 61M edges —
        # so only the (E, H) logits ever materialize.
        if use_chunks:
            from repro.utils.chunked import chunked_map

            nc, ck = e_total // cfg.edge_chunk, cfg.edge_chunk

            def logit_chunk(diff, ints_c, floats_c):
                p_a, x0 = diff
                src_c, dst_c = ints_c
                (rbf_c,) = floats_c
                a_in = jnp.concatenate([x0[dst_c], x0[src_c], rbf_c], axis=-1)
                return mlp_fwd(p_a, a_in)

            logits = chunked_map(
                logit_chunk, (p["alpha"], x[:, 0, :]),
                (src.reshape(nc, ck), dst.reshape(nc, ck)),
                (rbf.reshape(nc, ck, -1),),
            ).reshape(e_total, -1)
        else:
            a_in = jnp.concatenate([x[dst, 0, :], x[src, 0, :], rbf], axis=-1)
            logits = mlp_fwd(p["alpha"], a_in)  # (E, H)
        if e_valid is not None:
            logits = jnp.where(e_valid[:, None], logits, -1e30)
        alpha = segment_softmax(logits, dst, n)  # (E, H)

        # ---- eSCN message pass (chunkable)
        def message_block(src_c, vec_c, rbf_c, alpha_c):
            xs = x[src_c]  # (e, M, C)
            # Wigner matrices are (re)built per block: (E, Σ(2l+1)²) floats
            # would dominate memory on 61M-edge graphs if precomputed.
            w_blk = edge_wigner(cfg.l_max, vec_c)
            x_rot = rotate_blocks(w_blk, xs)
            y = so2_conv_fwd(cfg, p["so2"], x_rot, layout)
            radial_w = mlp_fwd(p["radial"], rbf_c)  # (e, L+1)
            y = y * radial_w[:, deg_l, None]
            y = rotate_blocks(w_blk, y, transpose=True)
            h = cfg.num_heads
            y = y.reshape(y.shape[0], m_sq, h, c // h) * alpha_c[:, None, :, None]
            return y.reshape(y.shape[0], m_sq, c)

        if use_chunks:
            from repro.utils.chunked import chunked_scatter_sum

            nc, ck = e_total // cfg.edge_chunk, cfg.edge_chunk

            # linear aggregation with recompute backward: memory stays at one
            # chunk's working set regardless of the number of chunks
            def chunk_msg(diff, ints_c, floats_c):
                p_c, x_c = diff
                (src_c,) = ints_c
                vec_c, rbf_c, alpha_c = floats_c
                xs = x_c[src_c]
                w_blk = edge_wigner(cfg.l_max, vec_c)
                x_rot = rotate_blocks(w_blk, xs)
                y = so2_conv_fwd(cfg, p_c["so2"], x_rot, layout)
                radial_w = mlp_fwd(p_c["radial"], rbf_c)
                y = y * radial_w[:, deg_l, None]
                y = rotate_blocks(w_blk, y, transpose=True)
                h = cfg.num_heads
                y = y.reshape(y.shape[0], m_sq, h, c // h) * alpha_c[:, None, :, None]
                return y.reshape(y.shape[0], m_sq, c)

            agg = chunked_scatter_sum(
                chunk_msg, (n, m_sq, c), cfg.cdt,
                ({"so2": p["so2"], "radial": p["radial"]}, x),
                dst.reshape(nc, ck),
                (src.reshape(nc, ck),),
                (vec.reshape(nc, ck, 3), rbf.reshape(nc, ck, -1),
                 alpha.reshape(nc, ck, -1)),
            )
        else:
            msg = message_block(src, vec, rbf, alpha)
            agg = jax.ops.segment_sum(msg, dst, n)

        # ---- node update: gate activation + scalar MLP + equivariant LN
        x = x + agg
        scal = x[:, 0, :]
        gates = jax.nn.sigmoid(mlp_fwd(p["gate"], scal))  # (N, L)
        gate_full = jnp.concatenate(
            [jnp.ones((n, 1), cfg.cdt), gates], axis=-1
        )  # l=0 ungated
        x = x * gate_full[:, deg_l, None]
        x = x.at[:, 0, :].add(mlp_fwd(p["scalar_mlp"], scal))
        return _equi_layernorm(p, x, cfg.l_max)

    for i in range(cfg.num_layers):
        x = layer_fn(params["layers"][f"layer{i}"], x)

    return mlp_fwd(params["decode"], x[:, 0, :])

"""GNN zoo: PNA, GatedGCN (SpMM/segment regime), DimeNet (triplet regime),
EquiformerV2 (eSCN irrep regime)."""

"""GatedGCN [arXiv:1711.07553, benchmarking config arXiv:2003.00982].

Anisotropic message passing with explicit edge states:
    e'_ij = A h_i + B h_j + C e_ij ;  η_ij = σ(e'_ij)
    h'_i  = U h_i + ( Σ_j η_ij ⊙ V h_j ) / ( Σ_j η_ij + ε )
residual + norm on both node and edge streams (16 layers, d=70).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.gnn.common import (
    GNNConfig,
    layernorm_defs,
    layernorm_fwd,
    mlp_defs,
    mlp_fwd,
)
from repro.models.params import ParamDef


def _lin(d_in, d_out, dtype):
    return {
        "w": ParamDef((d_in, d_out), dtype, ("embed", "mlp")),
        "b": ParamDef((d_out,), dtype, (None,), "zeros"),
    }


def _lin_fwd(p, x):
    return x @ p["w"] + p["b"]


def gatedgcn_defs(cfg: GNNConfig):
    d = cfg.d_hidden
    layers = {}
    for i in range(cfg.num_layers):
        layers[f"layer{i}"] = {
            "A": _lin(d, d, cfg.cdt),
            "B": _lin(d, d, cfg.cdt),
            "C": _lin(d, d, cfg.cdt),
            "U": _lin(d, d, cfg.cdt),
            "V": _lin(d, d, cfg.cdt),
            "norm_h": layernorm_defs(d, cfg.cdt),
            "norm_e": layernorm_defs(d, cfg.cdt),
        }
    return {
        "encode_h": mlp_defs((cfg.d_feat, d), cfg.cdt),
        "encode_e": mlp_defs((cfg.d_edge_feat, d), cfg.cdt),
        "layers": layers,
        "decode": mlp_defs((d, d, cfg.num_classes), cfg.cdt),
    }


def gatedgcn_forward(cfg: GNNConfig, params, batch):
    """batch: node_feat (N,F), edge_feat (E,Fe), edge_src/dst → node logits."""
    h = mlp_fwd(params["encode_h"], batch["node_feat"].astype(cfg.cdt))
    e = mlp_fwd(params["encode_e"], batch["edge_feat"].astype(cfg.cdt))
    src, dst = batch["edge_src"], batch["edge_dst"]
    valid = batch.get("edge_valid")
    n = h.shape[0]

    for i in range(cfg.num_layers):
        p = params["layers"][f"layer{i}"]
        e_new = _lin_fwd(p["A"], h[dst]) + _lin_fwd(p["B"], h[src]) + _lin_fwd(p["C"], e)
        eta = jax.nn.sigmoid(e_new)
        if valid is not None:
            eta = jnp.where(valid[:, None], eta, 0.0)
        vh = _lin_fwd(p["V"], h)[src]
        num = jax.ops.segment_sum(eta * vh, dst, n)
        den = jax.ops.segment_sum(eta, dst, n) + 1e-6
        h_new = _lin_fwd(p["U"], h) + num / den
        h = layernorm_fwd(p["norm_h"], h + jax.nn.relu(h_new))
        e = layernorm_fwd(p["norm_e"], e + jax.nn.relu(e_new))
    return mlp_fwd(params["decode"], h)

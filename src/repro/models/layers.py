"""Transformer building blocks: norms, RoPE, attention (GQA/MQA/MLA), FFN, MoE.

Pure-function style: each block has a ``*_defs(cfg)`` returning a ParamDef
tree and a ``*_fwd(cfg, params, ...)`` forward.  Sharding is expressed with
logical-axis constraints (see repro.distributed.partitioning); compute dtype
is bf16 with fp32 params/softmax/reductions (MaxText convention).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.partitioning import constrain
from repro.models.params import ParamDef


# --------------------------------------------------------------------------
# config
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    ffn_type: str = "swiglu"  # swiglu | geglu | gelu
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    scale_embeddings: bool = False  # gemma: x *= sqrt(d_model)
    # attention
    attention_type: str = "gqa"  # gqa | mla
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # MoE
    moe: bool = False
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # dispatch groups (GShard 2D dispatch): sort/capacity are evaluated
    # per group so a data-sharded group axis keeps the dispatch local and
    # the only cross-chip movement is the token⇄expert all-to-all.
    # 1 = single global group (the paper-faithful/simple baseline).
    moe_groups: int = 1
    # numerics / execution
    dtype: str = "bfloat16"  # compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    # remat policy: "full" recomputes everything (min memory);  "dots"
    # saves matmul outputs (jax dots_with_no_batch_dims_saveable) — §Perf B4
    remat_policy: str = "full"
    attn_q_block: int = 1024
    attn_kv_block: int = 1024
    max_cache_len: int = 32_768  # decode KV-cache capacity

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def qk_head_dim(self) -> int:
        if self.attention_type == "mla":
            return self.qk_nope_dim + self.qk_rope_dim
        return self.head_dim

    def param_count(self) -> int:
        from repro.models.params import param_count
        from repro.models.transformer import transformer_defs

        return param_count(transformer_defs(self))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        total = self.param_count()
        if not self.moe:
            return total
        per_expert = 3 * self.d_model * self.moe_d_ff
        moe_layers = self.num_layers - self.first_k_dense
        inactive = moe_layers * per_expert * (self.num_experts - self.top_k)
        return total - inactive


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rmsnorm_defs(cfg, dim: Optional[int] = None):
    return {"scale": ParamDef((dim or cfg.d_model,), cfg.pdtype, ("embed",), "ones")}


def rmsnorm_fwd(p, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, n, dim) rotated pairwise; positions: (..., T)."""
    dim = x.shape[-1]
    freqs = rope_freqs(dim, theta)  # (dim/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, dim/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    sin = sin[..., None, :]  # broadcast over heads
    cos = cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# blockwise (flash-style, pure-XLA) attention
# --------------------------------------------------------------------------
def blockwise_attention(
    q: jax.Array,  # (B, H, Tq, d)
    k: jax.Array,  # (B, H, Tk, d)
    v: jax.Array,  # (B, H, Tk, dv)
    *,
    causal: bool,
    scale: float,
    q_block: int = 1024,
    kv_block: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Online-softmax attention with O(q_block·kv_block) score memory.

    Same math as kernels/flash_attention but in composable XLA (scan over kv
    blocks, map over q blocks) — this is what the pjit'd models use so that
    32k-prefill activations stay bounded; the Pallas kernel is the TPU
    drop-in.  ``q_offset`` shifts query positions (decode/chunked prefill).
    """
    b, h, tq, d = q.shape
    tk = k.shape[2]
    q_block = min(q_block, tq)
    kv_block = min(kv_block, tk)
    nq = (tq + q_block - 1) // q_block
    nk = (tk + kv_block - 1) // kv_block
    pad_q = nq * q_block - tq
    pad_k = nk * kv_block - tk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    kb = k.reshape(b, h, nk, kv_block, d)
    vb = v.reshape(b, h, nk, kv_block, v.shape[-1])

    kv_valid = (jnp.arange(nk * kv_block) < tk).reshape(nk, kv_block)

    def one_q_block(qi):
        qblk = jax.lax.dynamic_slice_in_dim(q, qi * q_block, q_block, axis=2)
        q_pos = q_offset + qi * q_block + jnp.arange(q_block)

        def kv_step(carry, inputs):
            m, l, acc = carry
            kblk, vblk, kvi, valid = inputs
            s = jnp.einsum(
                "bhqd,bhkd->bhqk", qblk, kblk, preferred_element_type=jnp.float32
            ) * scale
            k_pos = kvi * kv_block + jnp.arange(kv_block)
            mask = valid[None, None, None, :]
            if causal:
                mask = mask & (q_pos[:, None] >= k_pos[None, :])[None, None]
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_block), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, q_block), jnp.float32)
        a0 = jnp.zeros((b, h, q_block, v.shape[-1]), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (
                jnp.moveaxis(kb, 2, 0),
                jnp.moveaxis(vb, 2, 0),
                jnp.arange(nk),
                kv_valid,
            ),
        )
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    outs = jax.lax.map(one_q_block, jnp.arange(nq))  # (nq, B, H, q_block, dv)
    out = jnp.moveaxis(outs, 0, 2).reshape(b, h, nq * q_block, v.shape[-1])
    return out[:, :, :tq]


# --------------------------------------------------------------------------
# GQA / MQA / MHA attention
# --------------------------------------------------------------------------
def gqa_defs(cfg: TransformerConfig):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": ParamDef((d, h, hd), cfg.pdtype, ("embed", "heads", None)),
        "wk": ParamDef((d, kv, hd), cfg.pdtype, ("embed", "kv_heads", None)),
        "wv": ParamDef((d, kv, hd), cfg.pdtype, ("embed", "kv_heads", None)),
        "wo": ParamDef((h, hd, d), cfg.pdtype, ("heads", None, "embed")),
    }


def gqa_project_qkv(cfg, p, x, positions):
    dt = cfg.compute_dtype
    q = constrain(jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt)),
                  ("batch", "seq", "heads", None))
    k = constrain(jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(dt)),
                  ("batch", "seq", "kv_heads", None))
    v = constrain(jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(dt)),
                  ("batch", "seq", "kv_heads", None))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, KV, T, d) → (B, KV*groups, T, d) by head repetition."""
    if groups == 1:
        return k
    b, kv, t, d = k.shape
    return jnp.broadcast_to(k[:, :, None], (b, kv, groups, t, d)).reshape(
        b, kv * groups, t, d
    )


def gqa_fwd(cfg: TransformerConfig, p, x, positions):
    """Training/prefill self-attention. x: (B, T, D)."""
    q, k, v = gqa_project_qkv(cfg, p, x, positions)
    q = jnp.moveaxis(q, 1, 2)  # (B, H, T, hd)
    k = jnp.moveaxis(k, 1, 2)
    v = jnp.moveaxis(v, 1, 2)
    groups = cfg.num_heads // cfg.num_kv_heads
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    out = blockwise_attention(
        q, k, v, causal=True, scale=1.0 / np.sqrt(cfg.head_dim),
        q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
    )
    out = jnp.moveaxis(out, 1, 2)  # (B, T, H, hd)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(cfg.compute_dtype))


def gqa_decode_fwd(cfg: TransformerConfig, p, x, cache, cache_index):
    """Single-token decode with KV cache.

    x: (B, 1, D); cache: dict(k=(B, S, KV, hd), v=...); cache_index: scalar.
    Returns (out (B,1,D), new_cache).
    """
    positions = jnp.full((x.shape[0], 1), cache_index, jnp.int32)
    q, k_new, v_new = gqa_project_qkv(cfg, p, x, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), cache_index, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), cache_index, axis=1)

    dt = cfg.compute_dtype
    groups = cfg.num_heads // cfg.num_kv_heads
    # scores over the whole cache, masked beyond cache_index
    qh = jnp.moveaxis(q, 1, 2)  # (B, H, 1, hd)
    kh = _repeat_kv(jnp.moveaxis(k_cache.astype(dt), 1, 2), groups)  # (B,H,S,hd)
    vh = _repeat_kv(jnp.moveaxis(v_cache.astype(dt), 1, 2), groups)
    s = jnp.einsum("bhqd,bhkd->bhqk", qh, kh, preferred_element_type=jnp.float32)
    s = s / np.sqrt(cfg.head_dim)
    valid = jnp.arange(kh.shape[2]) <= cache_index
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1).astype(dt)
    out = jnp.einsum("bhqk,bhkd->bhqd", pr, vh)
    out = jnp.moveaxis(out, 1, 2)
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(dt))
    return y, {"k": k_cache, "v": v_cache}


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# --------------------------------------------------------------------------
def mla_defs(cfg: TransformerConfig):
    d, h = cfg.d_model, cfg.num_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    defs = {
        "wdkv": ParamDef((d, kvr), cfg.pdtype, ("embed", None)),
        "kv_norm": ParamDef((kvr,), cfg.pdtype, (None,), "ones"),
        "wuk": ParamDef((kvr, h, nope), cfg.pdtype, (None, "heads", None)),
        "wuv": ParamDef((kvr, h, vd), cfg.pdtype, (None, "heads", None)),
        "wkr": ParamDef((d, rope_d), cfg.pdtype, ("embed", None)),
        "wo": ParamDef((h, vd, d), cfg.pdtype, ("heads", None, "embed")),
    }
    if qr:
        defs.update(
            {
                "wdq": ParamDef((d, qr), cfg.pdtype, ("embed", None)),
                "q_norm": ParamDef((qr,), cfg.pdtype, (None,), "ones"),
                "wuq": ParamDef((qr, h, nope + rope_d), cfg.pdtype, (None, "heads", None)),
            }
        )
    else:
        defs["wq"] = ParamDef((d, h, nope + rope_d), cfg.pdtype, ("embed", "heads", None))
    return defs


def _mla_q(cfg, p, x, positions):
    dt = cfg.compute_dtype
    if cfg.q_lora_rank:
        cq = jnp.einsum("btd,dr->btr", x, p["wdq"].astype(dt))
        cq = rmsnorm_fwd({"scale": p["q_norm"]}, cq)
        q = jnp.einsum("btr,rhk->bthk", cq, p["wuq"].astype(dt))
    else:
        q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    q = constrain(q, ("batch", "seq", "heads", None))
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_dim :], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latents(cfg, p, x, positions):
    dt = cfg.compute_dtype
    c_kv = jnp.einsum("btd,dr->btr", x, p["wdkv"].astype(dt))
    c_kv = rmsnorm_fwd({"scale": p["kv_norm"]}, c_kv)
    k_rope = jnp.einsum("btd,dr->btr", x, p["wkr"].astype(dt))
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_fwd(cfg: TransformerConfig, p, x, positions):
    """Training/prefill MLA (expanded form). x: (B, T, D)."""
    dt = cfg.compute_dtype
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    c_kv, k_rope = _mla_latents(cfg, p, x, positions)
    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, p["wuk"].astype(dt))
    v = jnp.einsum("btr,rhk->bthk", c_kv, p["wuv"].astype(dt))
    h = cfg.num_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], k_rope.shape[:2] + (h, cfg.qk_rope_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    out = blockwise_attention(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        causal=True, scale=1.0 / np.sqrt(cfg.qk_head_dim),
        q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
    )
    out = jnp.moveaxis(out, 1, 2)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(dt))


def mla_decode_fwd(cfg: TransformerConfig, p, x, cache, cache_index):
    """Absorbed-matrix MLA decode: cache holds latents only (B, S, kvr+rope).

    score_h = (W_uk^T q_nope_h)·c_kv + q_rope_h·k_rope ;
    out_h   = (softmax · c_kv) W_uv_h       — O(S·kv_lora) memory/chip.
    """
    dt = cfg.compute_dtype
    positions = jnp.full((x.shape[0], 1), cache_index, jnp.int32)
    q_nope, q_rope = _mla_q(cfg, p, x, positions)  # (B,1,H,·)
    c_kv_new, k_rope_new = _mla_latents(cfg, p, x, positions)  # (B,1,kvr),(B,1,rope)
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], c_kv_new.astype(cache["ckv"].dtype), cache_index, axis=1
    )
    krope_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["krope"], k_rope_new.astype(cache["krope"].dtype), cache_index, axis=1
    )
    # absorb W_uk into the query
    q_lat = jnp.einsum("bthk,rhk->bthr", q_nope, p["wuk"].astype(dt))  # (B,1,H,kvr)
    s = jnp.einsum("bthr,bsr->bhts", q_lat, ckv_cache.astype(dt), preferred_element_type=jnp.float32)
    s += jnp.einsum("bthk,bsk->bhts", q_rope, krope_cache.astype(dt), preferred_element_type=jnp.float32)
    s = s / np.sqrt(cfg.qk_head_dim)
    valid = jnp.arange(ckv_cache.shape[1]) <= cache_index
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1).astype(dt)
    o_lat = jnp.einsum("bhts,bsr->bthr", pr, ckv_cache.astype(dt))  # (B,1,H,kvr)
    out = jnp.einsum("bthr,rhk->bthk", o_lat, p["wuv"].astype(dt))  # (B,1,H,vd)
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(dt))
    return y, {"ckv": ckv_cache, "krope": krope_cache}


# --------------------------------------------------------------------------
# dense FFN
# --------------------------------------------------------------------------
def ffn_defs(cfg: TransformerConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    gated = cfg.ffn_type in ("swiglu", "geglu")
    defs = {
        "wi": ParamDef((d, f), cfg.pdtype, ("embed", "mlp")),
        "wo": ParamDef((f, d), cfg.pdtype, ("mlp", "embed")),
    }
    if gated:
        defs["wg"] = ParamDef((d, f), cfg.pdtype, ("embed", "mlp"))
    return defs


def _act(cfg, x):
    if cfg.ffn_type == "swiglu":
        return jax.nn.silu(x)
    if cfg.ffn_type == "geglu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.gelu(x, approximate=True)


def ffn_fwd(cfg: TransformerConfig, p, x):
    dt = cfg.compute_dtype
    h = jnp.einsum("btd,df->btf", x, p["wi"].astype(dt))
    if "wg" in p:
        g = jnp.einsum("btd,df->btf", x, p["wg"].astype(dt))
        h = _act(cfg, g) * h
    else:
        h = _act(cfg, h)
    h = constrain(h, ("batch", "seq", "mlp"))
    return jnp.einsum("btf,fd->btd", h, p["wo"].astype(dt))


# --------------------------------------------------------------------------
# MoE (GShard-style capacity dispatch + shared experts)
# --------------------------------------------------------------------------
def moe_defs(cfg: TransformerConfig):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    defs = {
        "router": ParamDef((d, e), cfg.pdtype, ("embed", None)),
        "wi": ParamDef((e, d, f), cfg.pdtype, ("expert", "embed", "mlp")),
        "wg": ParamDef((e, d, f), cfg.pdtype, ("expert", "embed", "mlp")),
        "wo": ParamDef((e, f, d), cfg.pdtype, ("expert", "mlp", "embed")),
    }
    if cfg.num_shared_experts:
        defs["shared"] = ffn_defs(cfg, cfg.num_shared_experts * cfg.moe_d_ff)
    return defs


def moe_fwd(cfg: TransformerConfig, p, x):
    """Top-k capacity-factor MoE. x: (B, T, D) → (y, aux_loss).

    Dispatch is sort-based (argsort by expert id → positional capacity
    check → gather into (G, E, C, d) buffers), which keeps peak memory at
    O(T·k·d + E·C·d) instead of the O(T·E) one-hot cumsum — the difference
    between fitting and OOM for 160-expert DeepSeek at 1M tokens.

    With ``moe_groups > 1`` the sort/capacity run independently per group
    (vmapped), so under SPMD with the group axis data-sharded the dispatch
    is shard-local and the expert einsum's (G→E) exchange is the only
    collective — §Perf iteration B2 (36× collective-bytes reduction on
    qwen2-moe train_4k vs the global-sort baseline).
    """
    dt = cfg.compute_dtype
    b, t, d = x.shape
    n = b * t
    e, k = cfg.num_experts, cfg.top_k
    g = max(1, cfg.moe_groups)
    if n % g:
        g = 1
    m = n // g  # tokens per group
    xg = x.reshape(g, m, d)

    logits = jnp.einsum(
        "gmd,de->gme", xg.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (g, m, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- load-balancing auxiliary loss (Switch/GShard form)
    me = probs.mean(axis=(0, 1))  # (e,)
    ce = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (n * k)
    aux = cfg.router_aux_coef * e * jnp.sum(me * ce)

    # ---- group-local sort-based dispatch with capacity
    cap = int(np.ceil(m * k / e * cfg.capacity_factor))
    flat_expert = expert_ids.reshape(g, m * k)
    flat_token = jnp.broadcast_to(
        jnp.repeat(jnp.arange(m, dtype=jnp.int32), k)[None], (g, m * k)
    )
    flat_gate = gate_vals.reshape(g, m * k)
    order = jnp.argsort(flat_expert, axis=-1)
    s_exp = jnp.take_along_axis(flat_expert, order, axis=-1)
    s_tok = jnp.take_along_axis(flat_token, order, axis=-1)
    s_gate = jnp.take_along_axis(flat_gate, order, axis=-1)
    # position within each expert's run, per group
    starts = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e)))(s_exp)
    pos = jnp.arange(m * k)[None, :] - jnp.take_along_axis(starts, s_exp, axis=-1)
    keep = pos < cap
    slot = jnp.where(keep, s_exp * cap + pos, e * cap)  # overflow → dropped row

    gathered = jnp.take_along_axis(xg.astype(dt), s_tok[..., None], axis=1)
    buf = jnp.zeros((g, e * cap + 1, d), dt)
    buf = jax.vmap(lambda bb, sl, xx: bb.at[sl].set(xx, mode="drop"))(
        buf, slot, gathered
    )
    buf = buf[:, :-1].reshape(g, e, cap, d)
    buf = constrain(buf, ("batch", "expert", None, None))  # G→data, E→model

    # ---- expert FFN (EP: expert axis sharded; (G→E) exchange happens here)
    hg = jnp.einsum("gecd,edf->gecf", buf, p["wg"].astype(dt))
    hi = jnp.einsum("gecd,edf->gecf", buf, p["wi"].astype(dt))
    h = jax.nn.silu(hg) * hi
    eo = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dt))

    # ---- combine (gather back + gate-weight + scatter-add over k)
    eo_flat = jnp.concatenate(
        [eo.reshape(g, e * cap, d), jnp.zeros((g, 1, d), dt)], axis=1
    )
    taken = jnp.take_along_axis(eo_flat, slot[..., None], axis=1)
    contrib = taken * jnp.where(keep, s_gate, 0.0)[..., None].astype(dt)
    yf = jax.vmap(lambda acc, tk, cc: acc.at[tk].add(cc))(
        jnp.zeros((g, m, d), dt), s_tok, contrib
    )

    y = yf.reshape(b, t, d)
    if cfg.num_shared_experts:
        y = y + ffn_fwd(cfg, p["shared"], x)
    return y, aux

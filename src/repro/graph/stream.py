"""Streaming snapshot substrate: append-only delta log + sliding window views.

The batch structures in :mod:`repro.graph.structures` freeze a *fixed* window
of snapshots into one :class:`EvolvingGraph`.  A serving system sees the
opposite regime — snapshots arrive continuously and old ones retire — so this
module provides the streaming counterpart:

* :class:`SnapshotLog` — an append-only log of snapshot deltas over a growing
  *edge universe*.  Universe ids are assigned in **append order and never
  change** (no re-sorting on growth), so every downstream consumer — witness
  counts, bound-parent arrays, QRS slot maps — can hold edge ids across window
  slides.  Arrays are kept at an amortized-doubling capacity so jitted
  consumers compile once per capacity class, not once per slide.
* :class:`WindowView` — a sliding ``[start, start+size)`` window over a log.
  Sliding never copies the edge arrays: the view maintains a per-edge
  **witness-count array** (how many window snapshots contain each edge; the
  paper's per-edge version bits, folded to a count) and updates only the
  entries touched by the entering/retiring snapshots.  ``witness == size``
  is the G∩ membership test, ``witness > 0`` the G∪ test.  Each slide emits a
  :class:`SlideDiff` that the incremental bounds/QRS layers consume
  (:class:`repro.core.bounds.StreamingBounds`,
  :class:`repro.core.qrs.PatchableQRS`).

``WindowView.materialize()`` produces a canonical (dst-sorted, bit-packed)
:class:`EvolvingGraph` for the current window — the reference substrate the
streaming engine must match bit-for-bit.  Weight extrema are **window-local
and exact**: the log records every per-edge weight *assignment* (a re-add
whose weight differs from the one in effect), and each view maintains the
min/max of the weights in effect over the snapshots of *its* window where
the edge is present — so a weight-widening snapshot retiring from the window
narrows the extrema back, matching a from-deltas
:func:`repro.graph.structures.build_evolving_graph` of the same window.
Narrowing/widening transitions are emitted per slide
(:class:`SlideDiff` ``wmin_*``/``wmax_*`` fields) for the incremental bounds
layers.  For edges with one lifetime weight (the regime of the paper's
update streams and of
:func:`repro.graph.generators.generate_evolving_stream`) the extrema are the
degenerate ``(w, w)`` and never change.
"""
from __future__ import annotations

import bisect
import dataclasses
import operator
import weakref
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.ft.faultinject import corrupt_point
from repro.graph.structures import EvolvingGraph, PAD_ALIGN, pack_presence
from repro.utils.padding import pad_to, round_up

STREAM_ALIGN = 1024  # universe-capacity growth quantum (compile stability)

_EMPTY = np.empty(0, np.int32)

# Weight events are ``(snapshot, weight)`` tuples kept sorted by snapshot;
# bisect by the time component (binary search replaces the former linear scan).
_EV_TIME = operator.itemgetter(0)


@dataclasses.dataclass(frozen=True)
class SlideDiff:
    """Universe-edge membership changes produced by one window slide.

    All fields are arrays of universe edge ids (append-order, stable).  The
    ``union_*`` / ``inter_*`` transitions are derived from the witness-count
    array.  The ``wmin_*`` / ``wmax_*`` fields track the view's
    **window-local** weight extrema: ``wmin_shrunk`` / ``wmax_grown`` list
    edges whose extrema *widened* this slide (a new weight entered the
    window), ``wmin_grown`` / ``wmax_shrunk`` edges whose extrema *narrowed*
    (the snapshot carrying an extreme weight retired from the window).
    """

    appended: int  # log index of the snapshot that entered the window
    retired: int  # log index of the snapshot that left the window
    union_gained: np.ndarray  # witness 0 → >0
    union_lost: np.ndarray  # witness >0 → 0
    inter_gained: np.ndarray  # witness <size → ==size
    inter_lost: np.ndarray  # witness ==size → <size
    wmin_shrunk: np.ndarray  # window weight_min decreased (widened)
    wmax_grown: np.ndarray  # window weight_max increased (widened)
    wmin_grown: np.ndarray = _EMPTY  # window weight_min increased (narrowed)
    wmax_shrunk: np.ndarray = _EMPTY  # window weight_max decreased (narrowed)

    def is_empty(self) -> bool:
        return not (
            len(self.union_gained) or len(self.union_lost)
            or len(self.inter_gained) or len(self.inter_lost)
            or len(self.wmin_shrunk) or len(self.wmax_grown)
            or len(self.wmin_grown) or len(self.wmax_shrunk)
        )

    def weights_changed(self) -> bool:
        """True when any window weight extremum moved this slide."""
        return bool(
            len(self.wmin_shrunk) or len(self.wmax_grown)
            or len(self.wmin_grown) or len(self.wmax_shrunk)
        )

    # The single source of truth for which extremum transition worsens or
    # improves which bound side, per semiring direction: w_cap is wmax for
    # CASMIN (minimize) queries and wmin for CASMAX, w_cup the reverse.
    # Every consumer (both bounds maintainers, row staleness in advance())
    # goes through these two accessors so the mapping cannot diverge.
    def cap_weight_transitions(self, minimize: bool):
        """``(worse, better)`` edge ids for the G∩ safe weight this slide."""
        return ((self.wmax_grown, self.wmax_shrunk) if minimize
                else (self.wmin_shrunk, self.wmin_grown))

    def cup_weight_transitions(self, minimize: bool):
        """``(worse, better)`` edge ids for the G∪ safe weight this slide."""
        return ((self.wmin_grown, self.wmin_shrunk) if minimize
                else (self.wmax_shrunk, self.wmax_grown))


class SnapshotLog:
    """Append-only snapshot delta log over a growing edge universe.

    Each appended snapshot is a delta ``(add_src, add_dst, add_w, del_src,
    del_dst)`` applied to the previous snapshot (deletions first, matching
    :func:`repro.graph.structures.build_evolving_graph` replay order); the
    first append is the base snapshot.  The universe table assigns every
    ``(src, dst)`` pair a stable id on first sight and tracks lifetime weight
    extrema; per-snapshot presence is recorded as an id array, so the log is
    O(present edges) per snapshot and never rewrites history.
    """

    def __init__(self, num_vertices: int, *, capacity: int = STREAM_ALIGN):
        self.num_vertices = int(num_vertices)
        self._capacity = round_up(int(capacity), STREAM_ALIGN)
        self.src = np.zeros(self._capacity, np.int32)
        self.dst = np.zeros(self._capacity, np.int32)
        self.weight_min = np.zeros(self._capacity, np.float32)
        self.weight_max = np.zeros(self._capacity, np.float32)
        self.weight_tip = np.zeros(self._capacity, np.float32)  # in effect now
        # per-edge weight assignment history, ONLY for edges whose weight ever
        # changed: id → [(snapshot, w), ...] ascending, seeded with (-1, w0)
        # so weight_at() resolves any snapshot ≥ the edge's first appearance
        self._wevents: dict[int, list] = {}
        self._index: dict[int, int] = {}  # (src * V + dst) key → universe id
        self._n_edges = 0
        self._generation = 0  # bumped on capacity growth
        self._tip = np.zeros(self._capacity, bool)  # presence at latest snapshot
        # per-snapshot present ids; retired entries are None (see retire_history)
        self._snapshots: list[Optional[np.ndarray]] = []
        # per-snapshot membership delta vs the previous snapshot — O(batch)
        # storage that outlives retirement of the O(present) id arrays
        self._deltas: list[tuple[np.ndarray, np.ndarray]] = []
        self._retired_upto = 0
        self._views: "weakref.WeakSet" = weakref.WeakSet()  # for retire watermark
        self._weight_changes: list[tuple[np.ndarray, np.ndarray]] = []
        self._weight_version = 0  # bumped when any edge's extrema widen
        # device-side mirrors of the universe arrays; keyed on (generation,
        # n_edges) because registration mutates the host arrays in place
        # (jnp.asarray copies — a stale upload silently drops edges)
        self._dev_key = None
        self._dev: tuple = ()
        # in-edge CSR cache (indptr, edge ids grouped by dst), keyed on n_edges
        self._csr_n = -1
        self._csr: tuple = ()

    # -- sizes ----------------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self._capacity

    @property
    def num_edges(self) -> int:
        """Registered universe edges (the rest of the capacity is padding)."""
        return self._n_edges

    @property
    def num_snapshots(self) -> int:
        return len(self._snapshots)

    @property
    def generation(self) -> int:
        """Bumped whenever the capacity (array shapes) changes."""
        return self._generation

    @property
    def weight_version(self) -> int:
        """Bumped whenever any edge's weight assignment changes."""
        return self._weight_version

    # -- append ---------------------------------------------------------------
    @staticmethod
    def _normalize_delta(add_src, add_dst, add_w, del_src, del_dst):
        return (
            np.asarray(add_src, np.int64).ravel(),
            np.asarray(add_dst, np.int64).ravel(),
            np.asarray(add_w, np.float32).ravel(),
            np.asarray(del_src, np.int64).ravel(),
            np.asarray(del_dst, np.int64).ravel(),
        )

    def _validate_delta(self, add_src, add_dst, add_w, del_src, del_dst):
        """Raise on a bad delta *without mutating*; returns deletion ids.

        Every id is validated up front: out-of-range ids would corrupt the
        src*V+dst key encoding (aliasing distinct edges), and raising after
        any mutation would leave the tip/extrema half-updated with no
        snapshot recorded.
        """
        v = np.int64(self.num_vertices)
        for kind, ids in (("add", add_src), ("add", add_dst),
                          ("del", del_src), ("del", del_dst)):
            if len(ids) and (ids.min() < 0 or ids.max() >= v):
                raise ValueError(
                    f"{kind} edge vertex id outside [0, {self.num_vertices}) "
                    f"at snapshot {len(self._snapshots)}"
                )
        if len(add_src) != len(add_dst) or len(add_src) != len(add_w):
            raise ValueError(
                f"add arrays disagree in length at snapshot "
                f"{len(self._snapshots)}"
            )
        if len(del_src) != len(del_dst):
            raise ValueError(
                f"del arrays disagree in length at snapshot "
                f"{len(self._snapshots)}"
            )
        del_ids: list[int] = []
        seen: set[int] = set()
        for k in (del_src * v + del_dst).tolist():
            j = self._index.get(int(k))
            if j is None or not self._tip[j] or j in seen:
                raise KeyError(
                    f"deletion of absent edge ({k // v}, {k % v}) "
                    f"at snapshot {len(self._snapshots)}"
                )
            seen.add(j)
            del_ids.append(j)
        return del_ids

    def prepare_delta(
        self,
        add_src: Sequence[int],
        add_dst: Sequence[int],
        add_w: Sequence[float],
        del_src: Sequence[int] = (),
        del_dst: Sequence[int] = (),
    ) -> tuple:
        """Normalize + validate a delta against the current tip, WITHOUT
        applying it; returns an opaque token for :meth:`commit_delta`.

        Committing a prepared delta cannot fail (additions only register or
        widen extrema) — :class:`~repro.graph.shardlog.ShardedSnapshotLog`
        relies on this to keep multi-shard appends atomic: prepare every
        shard's sub-delta, then commit every shard.  The token is only valid
        while no other mutation intervenes.
        """
        arrays = self._normalize_delta(add_src, add_dst, add_w, del_src, del_dst)
        return arrays, self._validate_delta(*arrays)

    def append_snapshot(
        self,
        add_src: Sequence[int],
        add_dst: Sequence[int],
        add_w: Sequence[float],
        del_src: Sequence[int] = (),
        del_dst: Sequence[int] = (),
    ) -> int:
        """Apply one delta batch to the tip; returns the new snapshot's index.

        Validates the whole batch before touching the tip, so a bad delta
        cannot leave the log half-mutated with no snapshot recorded.
        """
        add_src, add_dst, add_w, del_src, del_dst = corrupt_point(
            "ingest",
            (add_src, add_dst, add_w, del_src, del_dst),
            num_vertices=self.num_vertices,
        )
        return self.commit_delta(
            self.prepare_delta(add_src, add_dst, add_w, del_src, del_dst)
        )

    def commit_delta(self, prepared: tuple) -> int:
        """Apply a delta previously validated by :meth:`prepare_delta`."""
        (add_src, add_dst, add_w, del_src, del_dst), del_ids = prepared
        v = np.int64(self.num_vertices)
        # deletions first (build_evolving_graph replay order)
        if del_ids:
            self._tip[del_ids] = False

        t_new = len(self._snapshots)
        wmin_shrunk: list[int] = []
        wmax_grown: list[int] = []
        weights_changed = False
        for k, w in zip((add_src * v + add_dst).tolist(), add_w.tolist()):
            j = self._index.get(int(k))
            if j is None:
                j = self._register(int(k), np.float32(w))
            else:
                if w < self.weight_min[j]:
                    self.weight_min[j] = w
                    wmin_shrunk.append(j)
                if w > self.weight_max[j]:
                    self.weight_max[j] = w
                    wmax_grown.append(j)
                if w != self.weight_tip[j]:
                    # a re-add re-assigned the edge's weight: record the
                    # event so views can resolve weight-in-effect per
                    # snapshot (window-local extrema)
                    ev = self._wevents.setdefault(
                        j, [(-1, np.float32(self.weight_tip[j]))]
                    )
                    ev.append((t_new, np.float32(w)))
                    self.weight_tip[j] = w
                    weights_changed = True
            self._tip[j] = True

        ids = np.flatnonzero(self._tip).astype(np.int32)
        prev = self._snapshots[-1] if self._snapshots else _EMPTY
        # the membership delta is O(batch) and survives retirement of the
        # O(present) id array (see retire_history)
        self._deltas.append((
            np.setdiff1d(ids, prev, assume_unique=True),
            np.setdiff1d(prev, ids, assume_unique=True),
        ))
        self._snapshots.append(ids)
        self._weight_changes.append(
            (np.asarray(wmin_shrunk, np.int32), np.asarray(wmax_grown, np.int32))
        )
        if weights_changed:
            self._weight_version += 1
        return len(self._snapshots) - 1

    def _register(self, key: int, w: np.float32) -> int:
        j = self._n_edges
        if j == self._capacity:
            self._grow(j + 1)
        self.src[j] = key // self.num_vertices
        self.dst[j] = key % self.num_vertices
        self.weight_min[j] = w
        self.weight_max[j] = w
        self.weight_tip[j] = w
        self._index[key] = j
        self._n_edges = j + 1
        return j

    def _grow(self, needed: int):
        new_cap = round_up(max(needed, 2 * self._capacity), STREAM_ALIGN)
        self.src = pad_to(self.src, new_cap, 0)
        self.dst = pad_to(self.dst, new_cap, 0)
        self.weight_min = pad_to(self.weight_min, new_cap, 0.0)
        self.weight_max = pad_to(self.weight_max, new_cap, 0.0)
        self.weight_tip = pad_to(self.weight_tip, new_cap, 0.0)
        self._tip = pad_to(self._tip, new_cap, False)
        self._capacity = new_cap
        self._generation += 1

    @classmethod
    def from_stream(cls, base, deltas, num_vertices: int, *,
                    capacity: int = STREAM_ALIGN) -> "SnapshotLog":
        """Build a log from ``generate_evolving_stream`` output."""
        log = cls(num_vertices, capacity=capacity)
        bs, bd, bw = base
        log.append_snapshot(bs, bd, bw)
        for add_src, add_dst, add_w, del_src, del_dst in deltas:
            log.append_snapshot(add_src, add_dst, add_w, del_src, del_dst)
        return log

    # -- lookups --------------------------------------------------------------
    def snapshot_edges(self, t: int) -> np.ndarray:
        """Universe ids present in snapshot ``t`` (sorted, stable)."""
        ids = self._snapshots[t]
        if ids is None:
            raise LookupError(
                f"snapshot {t} was retired to delta storage (ids before "
                f"{self._retired_upto} are compacted; see retire_history)"
            )
        return ids

    def snapshot_mask(self, t: int) -> np.ndarray:
        """``(capacity,) bool`` presence mask for snapshot ``t``."""
        mask = np.zeros(self._capacity, bool)
        mask[self.snapshot_edges(t)] = True
        return mask

    def snapshot_delta(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """``(entered ids, left ids)`` of snapshot ``t`` vs its predecessor.

        Unlike :meth:`snapshot_edges` this survives retirement — it is the
        bounded per-snapshot record history compaction keeps.
        """
        return self._deltas[t]

    def delta_batch(
        self, t: int
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Snapshot ``t`` as a replayable ``(add_src, add_dst, add_w,
        del_src, del_dst)`` batch in **global vertex ids**.

        Appending this batch onto a log positioned at snapshot ``t - 1``
        reproduces snapshot ``t`` exactly: membership comes from the
        retirement-surviving :meth:`snapshot_delta` record, entering edges
        carry their weight **in effect** at ``t`` (:meth:`weight_at`), and
        weight re-assignments of edges already present (re-adds that changed
        the weight — the events :meth:`append_snapshot` records) are emitted
        as re-adds so the replayed log records the same events.  This is the
        O(batch) encoding the delta checkpoints and the live reshard replay
        share; re-adds of a present edge at an *unchanged* weight are not
        reproduced (they alter no observable state).
        """
        entered, left = self._deltas[t]
        ent = np.asarray(entered, np.int64)
        add_w = np.asarray(
            [self.weight_at(j, t) for j in ent], np.float32
        )
        if self._wevents:
            ent_set = set(ent.tolist())
            re_ids, re_w = [], []
            for j, ev in self._wevents.items():
                if j in ent_set:
                    continue
                # rightmost event at exactly t — duplicate adds in one
                # batch record several events with the same stamp and the
                # last one is the weight in effect (weight_at semantics)
                idx = bisect.bisect_right(ev, t, key=_EV_TIME) - 1
                if idx >= 0 and ev[idx][0] == t:
                    re_ids.append(j)
                    re_w.append(ev[idx][1])
            if re_ids:
                ent = np.concatenate([ent, np.asarray(re_ids, np.int64)])
                add_w = np.concatenate(
                    [add_w, np.asarray(re_w, np.float32)]
                )
        left = np.asarray(left, np.int64)
        return (self.src[ent].astype(np.int64), self.dst[ent].astype(np.int64),
                add_w, self.src[left].astype(np.int64),
                self.dst[left].astype(np.int64))

    # -- history compaction ---------------------------------------------------
    @property
    def retired_upto(self) -> int:
        """Snapshots below this index hold only their membership delta."""
        return self._retired_upto

    def register_view(self, view) -> None:
        """Track a window view (weakly) for the retirement watermark."""
        self._views.add(view)

    def retire_history(self) -> int:
        """Retire snapshot id arrays no registered view can reach.

        A :class:`WindowView` can reach snapshot ``t`` if ``t >= start`` (its
        window and future slides) or if one of its *retained* history diffs
        replays ``t`` (``rolling_masks`` touches ``d.retired``, which for the
        oldest retained diff is ``start - len(history)``) — so the watermark
        is ``min over live views of (start - len(history))``.  Retired
        snapshots keep their O(batch) membership delta
        (:meth:`snapshot_delta`) but drop the O(present-edges) id array, so
        the *dominant* per-snapshot term stops growing with log lifetime
        (per-append storage is still O(batch) — the retained delta records).
        With no registered views nothing is retired (a future view may still
        want the full history).  Returns the number of snapshots retired.

        Called by :meth:`WindowView.prune_history`; long-running consumers
        (``StreamingQuery`` on a private view, ``QueryBatcher.advance_window``
        on a shared one) therefore compact the log as a side effect of
        pruning their slide history.
        """
        views = list(self._views)
        if not views:
            return 0
        watermark = min(v.start - len(v.history) for v in views)
        upto = min(max(watermark, self._retired_upto), self.num_snapshots)
        retired = 0
        for t in range(self._retired_upto, upto):
            if self._snapshots[t] is not None:
                self._snapshots[t] = None
                retired += 1
        if retired and self._wevents:
            # weight-event compaction: assignments at snapshots no live view
            # can reach (time < upto; every live window starts ≥ the
            # watermark) fold into the seed entry, so event lists stay
            # O(reachable changes) instead of growing with log lifetime.
            # An edge whose events ALL folded is constant again — restore
            # the lifetime extrema to that constant so new views seed
            # exactly, and drop its entry.
            for j, ev in list(self._wevents.items()):
                cut = bisect.bisect_left(ev, upto, key=_EV_TIME)
                if cut == len(ev):
                    self.weight_min[j] = self.weight_max[j] = self.weight_tip[j]
                    del self._wevents[j]
                elif cut > 1:
                    self._wevents[j] = [(-1, ev[cut - 1][1])] + ev[cut:]
        self._retired_upto = max(self._retired_upto, upto)
        return retired

    def weight_changes(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """(wmin_shrunk ids, wmax_grown ids) of the LIFETIME extrema at ``t``.

        Kept for introspection; window consumers use the per-view
        window-local extrema (see :class:`WindowView`) instead.
        """
        return self._weight_changes[t]

    def weight_at(self, j: int, t: int) -> np.float32:
        """Weight of universe edge ``j`` in effect at snapshot ``t``.

        The weight in effect is the latest assignment (registration or
        differing re-add) at a snapshot ≤ ``t``; it survives retirement of
        the snapshot id arrays because assignments are recorded as events.
        Events are sorted by snapshot (seeded at ``-1``), so the lookup is a
        binary search — O(log events) instead of the former linear scan.
        """
        ev = self._wevents.get(int(j))
        if ev is None:
            return self.weight_tip[j]
        # Rightmost event with time ≤ t; index 0 (the -1 seed) always
        # qualifies for any t ≥ 0.
        idx = bisect.bisect_right(ev, t, key=_EV_TIME)
        return ev[max(idx - 1, 0)][1]

    @property
    def has_weight_events(self) -> bool:
        """True when any edge ever changed weight (the rare case)."""
        return bool(self._wevents)

    def multi_weight_ids(self) -> np.ndarray:
        """Universe ids of edges with more than one recorded weight (rare)."""
        return np.fromiter(self._wevents, np.int64, len(self._wevents))

    def device_edges(self):
        """``(src, dst)`` as device arrays, re-uploaded when edges register."""
        key = (self._generation, self._n_edges)
        if self._dev_key != key:
            self._dev = (jnp.asarray(self.src), jnp.asarray(self.dst))
            self._dev_key = key
        return self._dev

    def in_edge_csr(self) -> tuple[np.ndarray, np.ndarray]:
        """``(indptr (V+1,), ids)``: universe ids grouped by destination."""
        if self._csr_n != self._n_edges:
            n = self._n_edges
            d = self.dst[:n]
            ids = np.argsort(d, kind="stable").astype(np.int32)
            counts = np.bincount(d, minlength=self.num_vertices)
            indptr = np.zeros(self.num_vertices + 1, np.int64)
            np.cumsum(counts, out=indptr[1:])
            self._csr = (indptr, ids)
            self._csr_n = n
        return self._csr

    def in_edges(self, vertices: np.ndarray) -> np.ndarray:
        """Universe ids of all edges sinking at any of ``vertices``.

        One fancy-index over the CSR ranges (no per-vertex Python loop) —
        this is the :class:`~repro.core.qrs.PatchableQRS` hot path on slides
        with many UVV flips.
        """
        if len(vertices) == 0:
            return _EMPTY
        indptr, ids = self.in_edge_csr()
        v = np.asarray(vertices, np.int64).ravel()
        starts = indptr[v]
        counts = indptr[v + 1] - starts
        total = int(counts.sum())
        if total == 0:
            return _EMPTY
        cum = np.cumsum(counts)
        take = np.repeat(starts - (cum - counts), counts) + np.arange(total)
        return ids[take].astype(np.int32)


class WindowView:
    """A sliding snapshot window over a :class:`SnapshotLog`.

    The view shares the log's edge arrays (sliding copies nothing) and owns
    the per-edge witness-count array for its window.  ``slide()`` advances by
    one snapshot, updates only the touched witness entries, and records a
    :class:`SlideDiff` in ``history`` so multiple consumers (e.g. several
    :class:`~repro.core.api.StreamingQuery` instances sharing one view) can
    each catch up at their own pace.
    """

    def __init__(self, log: SnapshotLog, size: Optional[int] = None,
                 start: Optional[int] = None):
        if log.num_snapshots == 0:
            raise ValueError("log has no snapshots yet")
        self.log = log
        # default to the earliest still-materializable snapshot: history
        # compaction may have retired a prefix of the log's id arrays, and a
        # consumer that doesn't ask for a specific start (StreamingQuery
        # slides to the tip before priming anyway) must stay constructible
        self.start = int(start) if start is not None else log.retired_upto
        self.size = int(size) if size is not None else log.num_snapshots - self.start
        if self.size < 1 or self.start < 0 or self.stop > log.num_snapshots:
            raise ValueError(
                f"window [{self.start}, {self.stop}) out of range for "
                f"{log.num_snapshots} snapshots"
            )
        self.witness = np.zeros(log.capacity, np.int32)
        for t in range(self.start, self.stop):
            self.witness[log.snapshot_edges(t)] += 1
        # window-local weight extrema: exact min/max of the weights in effect
        # over the window snapshots where each edge is present.  Seeded from
        # the lifetime extrema (exact for single-weight edges — the common
        # case) and corrected for the rare multi-weight edges.
        self.weight_min = log.weight_min[: log.capacity].copy()
        self.weight_max = log.weight_max[: log.capacity].copy()
        self._weights_synced_n = log.num_edges
        self._weight_epoch = 0
        multi = log.multi_weight_ids()
        if len(multi):
            self._refresh_window_extrema(multi[self.witness[multi] > 0])
        self.history: list[SlideDiff] = []
        self._history_offset = 0  # absolute index of history[0]
        log.register_view(self)  # pins [start - len(history), ∞) against retirement

    @property
    def stop(self) -> int:
        return self.start + self.size

    @property
    def history_end(self) -> int:
        """Absolute index one past the latest recorded slide."""
        return self._history_offset + len(self.history)

    def diffs_since(self, pos: int) -> list[SlideDiff]:
        """Slides recorded at absolute positions ``[pos, history_end)``.

        Raises if ``pos`` predates the pruned prefix — the consumer missed
        diffs it can never recover and must rebuild from scratch.
        """
        if pos < self._history_offset:
            raise LookupError(
                f"slide history before position {self._history_offset} was "
                f"pruned; consumer at {pos} must re-prime"
            )
        return self.history[pos - self._history_offset:]

    def prune_history(self, upto: int) -> None:
        """Drop recorded slides before absolute position ``upto``.

        Long-running consumers (e.g. ``QueryBatcher.advance_window``) call
        this with the minimum consumer watermark so history stays bounded.
        Pruning also retires pre-window snapshot id arrays from the log
        (:meth:`SnapshotLog.retire_history`) once no registered view can
        reach them, so the *log* stays bounded too.
        """
        drop = min(upto, self.history_end) - self._history_offset
        if drop > 0:
            del self.history[:drop]
            self._history_offset += drop
        self.log.retire_history()

    def snapshots(self) -> range:
        return range(self.start, self.stop)

    @property
    def weight_epoch(self) -> int:
        """Bumped whenever any window-local weight extremum changes."""
        return self._weight_epoch

    def _sync_capacity(self):
        if len(self.witness) != self.log.capacity:
            self.witness = pad_to(self.witness, self.log.capacity, 0)
        self._sync_weights()

    def _sync_weights(self):
        """Adopt extrema for edges registered since the last sync.

        A freshly registered edge has a single lifetime weight, so the
        log's lifetime extrema are its exact window extrema; if it was
        already re-weighted before entering this view's window, the slide
        that brings it in refreshes it (it is in that slide's ``new_ids``
        and in the log's multi-weight set).
        """
        cap = self.log.capacity
        if len(self.weight_min) != cap:
            self.weight_min = pad_to(self.weight_min, cap, 0.0)
            self.weight_max = pad_to(self.weight_max, cap, 0.0)
        n0, n1 = self._weights_synced_n, self.log.num_edges
        if n1 > n0:
            self.weight_min[n0:n1] = self.log.weight_min[n0:n1]
            self.weight_max[n0:n1] = self.log.weight_max[n0:n1]
            self._weights_synced_n = n1

    def _refresh_window_extrema(self, ids) -> tuple:
        """Recompute window extrema for universe edges ``ids`` (in place).

        Returns ``(wmin_shrunk, wmax_grown, wmin_grown, wmax_shrunk)`` id
        arrays classifying each change (widened vs narrowed).  Edges present
        nowhere in the current window are left untouched — their extrema are
        masked out by G∪ everywhere downstream and refreshed on re-entry.
        """
        log = self.log
        ids = np.asarray(ids, np.int64).ravel()
        if len(ids) == 0:
            return (_EMPTY,) * 4
        vals: dict[int, list] = {int(j): [] for j in ids}
        for t in range(self.start, self.stop):
            snap = log.snapshot_edges(t)
            pos = np.searchsorted(snap, ids)
            ok = pos < len(snap)
            ok[ok] = snap[pos[ok]] == ids[ok]
            for j in ids[ok]:
                vals[int(j)].append(log.weight_at(int(j), t))
        out: tuple = ([], [], [], [])
        for j, ws in vals.items():
            if not ws:
                continue
            lo, hi = min(ws), max(ws)
            if lo < self.weight_min[j]:
                out[0].append(j)  # wmin widened
            elif lo > self.weight_min[j]:
                out[2].append(j)  # wmin narrowed
            if hi > self.weight_max[j]:
                out[1].append(j)  # wmax widened
            elif hi < self.weight_max[j]:
                out[3].append(j)  # wmax narrowed
            self.weight_min[j] = lo
            self.weight_max[j] = hi
        if any(out):
            self._weight_epoch += 1
        return tuple(np.asarray(o, np.int32) for o in out)

    # -- sliding --------------------------------------------------------------
    def slide(self) -> SlideDiff:
        """Advance the window one snapshot: append log[stop], retire log[start]."""
        if self.stop >= self.log.num_snapshots:
            raise IndexError(
                f"cannot slide: window ends at {self.stop} and the log has "
                f"{self.log.num_snapshots} snapshots (append first)"
            )
        self._sync_capacity()
        t_new, t_old = self.stop, self.start
        new_ids = self.log.snapshot_edges(t_new)
        old_ids = self.log.snapshot_edges(t_old)
        touched = np.union1d(new_ids, old_ids).astype(np.int32)
        before = self.witness[touched].copy()
        self.witness[new_ids] += 1
        self.witness[old_ids] -= 1
        after = self.witness[touched]
        s = self.size
        self.start += 1
        # window-local extrema can move only for multi-weight edges touched
        # by the entering/retiring snapshots; recompute those over the NEW
        # window and classify each change as widened or narrowed.  With no
        # weight events anywhere (the paper's stable-weight regime) this
        # whole branch is a single bool check per slide.
        if self.log.has_weight_events:
            wmin_shrunk, wmax_grown, wmin_grown, wmax_shrunk = (
                self._refresh_window_extrema(
                    np.intersect1d(touched, self.log.multi_weight_ids())
                )
            )
        else:
            wmin_shrunk = wmax_grown = wmin_grown = wmax_shrunk = _EMPTY
        diff = SlideDiff(
            appended=t_new,
            retired=t_old,
            union_gained=touched[(before == 0) & (after > 0)],
            union_lost=touched[(before > 0) & (after == 0)],
            inter_gained=touched[(before < s) & (after == s)],
            inter_lost=touched[(before == s) & (after < s)],
            wmin_shrunk=wmin_shrunk,
            wmax_grown=wmax_grown,
            wmin_grown=wmin_grown,
            wmax_shrunk=wmax_shrunk,
        )
        self.history.append(diff)
        return diff

    def slide_to_tip(self) -> list[SlideDiff]:
        """Slide until the window ends at the log tip; returns the new diffs."""
        out = []
        while self.stop < self.log.num_snapshots:
            out.append(self.slide())
        return out

    # -- masks (append-order universe ids, capacity-shaped) -------------------
    def union_mask(self) -> np.ndarray:
        """G∪ membership: edges present in ≥1 window snapshot."""
        self._sync_capacity()
        return self.witness > 0

    def intersection_mask(self) -> np.ndarray:
        """G∩ membership: edges present in every window snapshot."""
        self._sync_capacity()
        return self.witness == self.size

    def snapshot_mask(self, t: int) -> np.ndarray:
        """Presence mask for log snapshot ``t`` (must lie in the window)."""
        if not (self.start <= t < self.stop):
            raise IndexError(f"snapshot {t} outside window [{self.start}, {self.stop})")
        return self.log.snapshot_mask(t)

    def rolling_masks(self, diffs: Sequence[SlideDiff]):
        """Yield each slide's post-slide ``(union, intersection)`` masks.

        ``diffs`` must be the view's most recent consecutive slides (ending
        in its current state) — exactly what a consumer catching up on
        several queued slides holds.  Each intermediate slide must be folded
        in against *its* window's graphs, not the final window's (the
        current ``witness`` array describes only the latter); this
        reconstructs the intermediate witness counts by undoing the recorded
        slides and rolling forward, touching only each slide's snapshots
        instead of rescanning the whole window per step.
        """
        self._sync_capacity()
        log = self.log
        w = self.witness.copy()
        for d in reversed(diffs):
            w[log.snapshot_edges(d.appended)] -= 1
            w[log.snapshot_edges(d.retired)] += 1
        for d in diffs:
            w[log.snapshot_edges(d.appended)] += 1
            w[log.snapshot_edges(d.retired)] -= 1
            yield w > 0, w == self.size

    # -- canonical reference graph -------------------------------------------
    def materialize(self, *, pad_to_capacity: bool = True) -> EvolvingGraph:
        """Canonical (dst-sorted, bit-packed) :class:`EvolvingGraph` of the window.

        This is the reference substrate: a fresh
        :class:`~repro.core.api.EvolvingQuery` on the materialized graph is
        what the streaming engine must match bit-for-bit.  Weight extrema
        are the view's exact window-local extrema (what a from-deltas
        :func:`~repro.graph.structures.build_evolving_graph` of the same
        window yields).  With ``pad_to_capacity`` (default) the edge arrays
        are padded to the log capacity so the reference path compiles once
        per capacity class too.
        """
        self._sync_capacity()
        log = self.log
        n = log.num_edges
        order = np.lexsort((log.src[:n], log.dst[:n]))
        dense = np.zeros((self.size, n), bool)
        for i, t in enumerate(self.snapshots()):
            dense[i, log.snapshot_edges(t)] = True
        packed = pack_presence(dense[:, order])
        cap = log.capacity if pad_to_capacity else round_up(n, PAD_ALIGN)
        return EvolvingGraph(
            src=jnp.asarray(pad_to(log.src[:n][order], cap, 0)),
            dst=jnp.asarray(pad_to(log.dst[:n][order], cap, 0)),
            weight_min=jnp.asarray(pad_to(self.weight_min[:n][order], cap, 0.0)),
            weight_max=jnp.asarray(pad_to(self.weight_max[:n][order], cap, 0.0)),
            presence=jnp.asarray(pad_to(packed, cap, 0, axis=0)),
            num_vertices=log.num_vertices,
            num_snapshots=self.size,
        )

"""Synthetic graph + evolving-stream generators (host side, numpy).

The paper evaluates on LiveJournal/Orkut/Wikipedia/Twitter/Friendster with
100K–150K edge updates per snapshot (50% additions / 50% deletions).  We
reproduce that regime at laptop scale with RMAT power-law graphs: same
degree-skew family as the social graphs, parameterized (a,b,c,d) as in the
Graph500 reference.
"""
from __future__ import annotations

import numpy as np


def generate_rmat(
    num_vertices: int,
    num_edges: int,
    *,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    dedup: bool = True,
) -> tuple[np.ndarray, np.ndarray]:
    """Generate a power-law directed graph via recursive-matrix sampling.

    Returns ``(src, dst)`` int64 arrays (deduplicated, self-loop-free).
    """
    rng = np.random.default_rng(seed)
    scale = max(1, int(np.ceil(np.log2(max(2, num_vertices)))))
    n_target = num_edges
    srcs, dsts = [], []
    got = 0
    while got < n_target:
        n = int((n_target - got) * 1.3) + 1024
        src = np.zeros(n, np.int64)
        dst = np.zeros(n, np.int64)
        for _ in range(scale):
            # quadrant probs: a=(0,0), b=(0,1), c=(1,0), d=(1,1)
            q = rng.random(n)
            src_bit = (q >= a + b).astype(np.int64)
            dst_bit = (((q >= a) & (q < a + b)) | (q >= a + b + c)).astype(np.int64)
            src = (src << 1) | src_bit
            dst = (dst << 1) | dst_bit
        src %= num_vertices
        dst %= num_vertices
        keep = src != dst
        src, dst = src[keep], dst[keep]
        if dedup:
            k = src * np.int64(num_vertices) + dst
            _, idx = np.unique(k, return_index=True)
            src, dst = src[idx], dst[idx]
        srcs.append(src)
        dsts.append(dst)
        got = sum(len(s) for s in srcs)
        if dedup:
            cat_s = np.concatenate(srcs)
            cat_d = np.concatenate(dsts)
            k = cat_s * np.int64(num_vertices) + cat_d
            _, idx = np.unique(k, return_index=True)
            srcs, dsts = [cat_s[idx]], [cat_d[idx]]
            got = len(idx)
    src = np.concatenate(srcs)[:n_target]
    dst = np.concatenate(dsts)[:n_target]
    return src, dst


def generate_uniform_weights(
    n: int, *, seed: int = 0, low: float = 1.0, high: float = 64.0, grid: int = 0
) -> np.ndarray:
    """Positive float32 weights; if ``grid>0`` snap to 1/grid multiples.

    Grid-snapped weights keep path sums exactly representable, which makes
    the bound-equality test in UVV detection exact (a nicety, not a
    requirement — see DESIGN.md §8).
    """
    rng = np.random.default_rng(seed)
    w = rng.uniform(low, high, n)
    if grid:
        w = np.round(w * grid) / grid
    return w.astype(np.float32)


def generate_evolving_stream(
    src: np.ndarray,
    dst: np.ndarray,
    weight: np.ndarray,
    num_vertices: int,
    *,
    num_snapshots: int,
    batch_size: int,
    frac_deletions: float = 0.5,
    readd_prob: float = 0.25,
    seed: int = 0,
):
    """Produce the paper's update stream: per-snapshot batches of edge updates.

    Each delta batch contains ``batch_size`` updates, ``frac_deletions`` of
    which delete currently-present edges and the rest add edges.  With
    probability ``readd_prob`` an addition re-adds a previously deleted edge
    (possibly with a new weight) — this creates the "flip-flopping" edges the
    paper's safe-weight rule exists for.

    Returns ``(base, deltas)`` where ``base=(src,dst,w)`` numpy arrays and
    ``deltas`` is a list of ``(add_src, add_dst, add_w, del_src, del_dst)``.
    """
    rng = np.random.default_rng(seed)
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    weight = np.asarray(weight, np.float32)

    present = {}
    weight_of = {}  # weight is stable per (src,dst) pair across the stream
    for s, d, w in zip(src.tolist(), dst.tolist(), weight.tolist()):
        present[(s, d)] = w
        weight_of[(s, d)] = w
    deleted_pool: list[tuple[int, int]] = []

    deltas = []
    n_del = int(batch_size * frac_deletions)
    n_add = batch_size - n_del
    for _ in range(num_snapshots - 1):
        # deletions: sample without replacement from present edges
        keys = list(present.keys())
        del_idx = rng.choice(len(keys), size=min(n_del, len(keys)), replace=False)
        del_edges = [keys[i] for i in del_idx]
        for e in del_edges:
            del present[e]
        deleted_pool.extend(del_edges)

        # additions: mix of re-adds and fresh random edges
        add_edges = []
        add_ws = []
        while len(add_edges) < n_add:
            if deleted_pool and rng.random() < readd_prob:
                i = rng.integers(len(deleted_pool))
                e = deleted_pool.pop(int(i))
                if e in present:
                    continue
            else:
                e = (int(rng.integers(num_vertices)), int(rng.integers(num_vertices)))
                if e[0] == e[1] or e in present:
                    continue
            w = weight_of.get(e)
            if w is None:
                w = float(np.round(rng.uniform(1.0, 64.0) * 16) / 16)
                weight_of[e] = w
            present[e] = w
            add_edges.append(e)
            add_ws.append(w)
        deltas.append(
            (
                np.array([e[0] for e in add_edges], np.int64),
                np.array([e[1] for e in add_edges], np.int64),
                np.array(add_ws, np.float32),
                np.array([e[0] for e in del_edges], np.int64),
                np.array([e[1] for e in del_edges], np.int64),
            )
        )
    return (src, dst, weight), deltas

"""GraphSAGE-style fixed-fanout neighbor sampler (jit-able).

``minibatch_lg`` (232K nodes / 114M edges, batch 1024, fanout 15-10) needs a
real sampler: given seed nodes, sample ``fanout[k]`` neighbors per node per
hop (uniform with replacement — the standard trick that keeps shapes static),
returning the padded block adjacency used by the GNN layers.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.graph.structures import CSR


@dataclasses.dataclass(frozen=True)
class SampledBlocks:
    """Per-hop sampled adjacency, innermost hop first.

    nodes:   list of ``(N_k,) int32`` node ids per layer (layer 0 = seeds).
    parents: list of ``(N_k * fanout_k,) int32`` indices into ``nodes[k]``
             (the "dst" of each sampled edge).
    neighbors: list of ``(N_k * fanout_k,) int32`` global neighbor ids
             (the "src" of each sampled edge) — also ``nodes[k+1]``.
    valid:   list of ``(N_k * fanout_k,) bool`` (False where degree 0).
    """

    nodes: list
    parents: list
    neighbors: list
    valid: list


class NeighborSampler:
    """Uniform-with-replacement fanout sampler over a CSR adjacency."""

    def __init__(self, csr: CSR, fanouts: Sequence[int]):
        self.csr = csr
        self.fanouts = tuple(int(f) for f in fanouts)

    def sample_hop(self, rng: jax.Array, nodes: jax.Array, fanout: int):
        """Sample ``fanout`` neighbors per node. Returns (parents, nbrs, valid)."""
        start = self.csr.indptr[nodes]  # (N,)
        deg = self.csr.indptr[nodes + 1] - start  # (N,)
        u = jax.random.uniform(rng, (nodes.shape[0], fanout))
        offs = jnp.floor(u * jnp.maximum(deg, 1)[:, None]).astype(jnp.int32)
        idx = start[:, None] + jnp.minimum(offs, jnp.maximum(deg - 1, 0)[:, None])
        nbrs = self.csr.indices[idx]  # (N, fanout)
        valid = (deg > 0)[:, None] & jnp.ones((1, fanout), bool)
        parents = jnp.broadcast_to(
            jnp.arange(nodes.shape[0], dtype=jnp.int32)[:, None], idx.shape
        )
        return parents.reshape(-1), nbrs.reshape(-1), valid.reshape(-1)

    def sample(self, rng: jax.Array, seeds: jax.Array) -> SampledBlocks:
        nodes = [seeds]
        parents, neighbors, valid = [], [], []
        cur = seeds
        for k, f in enumerate(self.fanouts):
            rng, sub = jax.random.split(rng)
            p, n, v = self.sample_hop(sub, cur, f)
            parents.append(p)
            neighbors.append(n)
            valid.append(v)
            cur = n
            nodes.append(cur)
        return SampledBlocks(nodes=nodes, parents=parents, neighbors=neighbors, valid=valid)

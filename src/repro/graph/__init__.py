from repro.graph.structures import EdgeList, EvolvingGraph, CSR
from repro.graph.generators import (
    generate_rmat,
    generate_evolving_stream,
    generate_uniform_weights,
)
from repro.graph.ell import EllPack, pack_ell
from repro.graph.sampler import NeighborSampler

__all__ = [
    "EdgeList",
    "EvolvingGraph",
    "CSR",
    "generate_rmat",
    "generate_evolving_stream",
    "generate_uniform_weights",
    "EllPack",
    "pack_ell",
    "NeighborSampler",
]

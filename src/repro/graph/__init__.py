from repro.graph.structures import EdgeList, EvolvingGraph, CSR, build_evolving_graph
from repro.graph.stream import SnapshotLog, WindowView, SlideDiff
from repro.graph.shardlog import (
    ShardAssignment,
    ShardedSnapshotLog,
    ShardedWindowView,
    ShardSlideDiff,
    degree_histogram,
    make_assignment,
)
from repro.graph.generators import (
    generate_rmat,
    generate_evolving_stream,
    generate_uniform_weights,
)
from repro.graph.ell import EllPack, StableEllPacker, pack_ell
from repro.graph.sampler import NeighborSampler

__all__ = [
    "EdgeList",
    "EvolvingGraph",
    "CSR",
    "build_evolving_graph",
    "SnapshotLog",
    "WindowView",
    "SlideDiff",
    "ShardAssignment",
    "ShardedSnapshotLog",
    "ShardedWindowView",
    "ShardSlideDiff",
    "degree_histogram",
    "make_assignment",
    "generate_rmat",
    "generate_evolving_stream",
    "generate_uniform_weights",
    "EllPack",
    "StableEllPacker",
    "pack_ell",
    "NeighborSampler",
]

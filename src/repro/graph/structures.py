"""Graph data structures: static-shape edge lists, CSR, evolving graphs.

Design notes (TPU adaptation of the paper's RisGraph adjacency structures):

* Everything is a fixed-shape array so the relax/aggregate fast paths compile
  once.  Invalid/padded edges are encoded with ``valid=False`` (engine treats
  them as absorbing-identity contributions).
* An :class:`EvolvingGraph` stores the *edge universe* (the union of every
  edge that ever exists across the snapshot window) plus a packed ``uint32``
  presence bitmask per edge — the paper's Figure-7 version words, generalized
  beyond 64 snapshots via ``ceil(S/32)`` words.
* Edges are kept **sorted by destination**.  That makes the per-superstep
  scatter (segment-reduce by dst) contiguous, and under a dst-range sharding
  of the vertex space the scatter is shard-local (only the source-value
  gather communicates).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.pytree import register_static_dataclass
from repro.utils.padding import pad_to_multiple

PAD_ALIGN = 128  # lane alignment for padded edge arrays


@register_static_dataclass(meta_fields=("num_vertices",))
@dataclasses.dataclass(frozen=True)
class EdgeList:
    """A padded, dst-sorted directed edge list.

    Attributes:
      src, dst: ``(E,) int32`` endpoints (padding rows hold 0).
      weight:   ``(E,) float32`` edge weight (padding rows hold 0).
      valid:    ``(E,) bool`` True for real edges.
      num_vertices: static vertex count.
    """

    src: jax.Array
    dst: jax.Array
    weight: jax.Array
    valid: jax.Array
    num_vertices: int

    @property
    def num_edges_padded(self) -> int:
        return int(self.src.shape[0])

    def num_edges(self) -> int:
        return int(np.asarray(self.valid).sum())

    @staticmethod
    def from_numpy(
        src: np.ndarray,
        dst: np.ndarray,
        weight: np.ndarray,
        num_vertices: int,
        *,
        align: int = PAD_ALIGN,
        sort_by_dst: bool = True,
    ) -> "EdgeList":
        src = np.asarray(src, np.int32)
        dst = np.asarray(dst, np.int32)
        weight = np.asarray(weight, np.float32)
        if sort_by_dst:
            order = np.lexsort((src, dst))
            src, dst, weight = src[order], dst[order], weight[order]
        valid = np.ones(src.shape[0], bool)
        return EdgeList(
            src=jnp.asarray(pad_to_multiple(src, align, 0)),
            dst=jnp.asarray(pad_to_multiple(dst, align, 0)),
            weight=jnp.asarray(pad_to_multiple(weight, align, 0.0)),
            valid=jnp.asarray(pad_to_multiple(valid, align, False)),
            num_vertices=int(num_vertices),
        )


@register_static_dataclass(meta_fields=("num_vertices", "num_snapshots"))
@dataclasses.dataclass(frozen=True)
class EvolvingGraph:
    """Edge universe + per-edge snapshot-presence bitmask (+ weight bounds).

    Attributes:
      src, dst: ``(E,) int32`` universe endpoints, dst-sorted, padded.
      weight_min, weight_max: per-edge weight extrema across the snapshots in
        which the edge occurs (the paper's safe-weight rule for edges that are
        added/deleted repeatedly, generalized to both bound directions).
      presence: ``(E, W) uint32`` with ``W = ceil(S/32)``; bit ``s`` of the
        packed words is 1 iff the edge is present in snapshot ``s``.  Padding
        rows are all-zero (present in no snapshot).
      num_vertices, num_snapshots: static sizes.
    """

    src: jax.Array
    dst: jax.Array
    weight_min: jax.Array
    weight_max: jax.Array
    presence: jax.Array
    num_vertices: int
    num_snapshots: int

    @property
    def num_edges_padded(self) -> int:
        return int(self.src.shape[0])

    @property
    def num_words(self) -> int:
        return int(self.presence.shape[1])

    def presence_dense(self) -> jax.Array:
        """Unpack presence bits to a ``(S, E) bool`` matrix."""
        return unpack_presence(self.presence, self.num_snapshots)

    def popcount(self) -> jax.Array:
        """Per-edge count of snapshots containing the edge, ``(E,) int32``."""
        bits = self.presence
        # Kernighan-free vectorized popcount on uint32 words.
        x = bits - ((bits >> 1) & np.uint32(0x55555555))
        x = (x & np.uint32(0x33333333)) + ((x >> 2) & np.uint32(0x33333333))
        x = (x + (x >> 4)) & np.uint32(0x0F0F0F0F)
        counts = (x * np.uint32(0x01010101)) >> 24
        return counts.astype(jnp.int32).sum(axis=1)

    def intersection_valid(self) -> jax.Array:
        """``(E,) bool`` — edges present in *all* snapshots (the G∩ mask)."""
        return self.popcount() == self.num_snapshots

    def union_valid(self) -> jax.Array:
        """``(E,) bool`` — edges present in *any* snapshot (the G∪ mask)."""
        return self.popcount() > 0

    def snapshot_valid(self, i: int) -> jax.Array:
        """``(E,) bool`` — edges present in snapshot ``i``."""
        word, bit = divmod(int(i), 32)
        return ((self.presence[:, word] >> np.uint32(bit)) & np.uint32(1)).astype(bool)


def pack_presence(dense: np.ndarray) -> np.ndarray:
    """Pack a ``(S, E) bool`` presence matrix into ``(E, ceil(S/32)) uint32``."""
    dense = np.asarray(dense, bool)
    s, e = dense.shape
    w = (s + 31) // 32
    out = np.zeros((e, w), np.uint32)
    for snap in range(s):
        word, bit = divmod(snap, 32)
        out[:, word] |= dense[snap].astype(np.uint32) << np.uint32(bit)
    return out


def unpack_presence(packed: jax.Array, num_snapshots: int) -> jax.Array:
    """Unpack ``(E, W) uint32`` words into ``(S, E) bool``."""
    snaps = jnp.arange(num_snapshots, dtype=jnp.uint32)
    word_idx = (snaps // 32).astype(jnp.int32)  # (S,)
    bit_idx = snaps % 32  # (S,)
    words = packed.T[word_idx]  # (S, E) uint32
    return ((words >> bit_idx[:, None]) & np.uint32(1)).astype(bool)


@register_static_dataclass(meta_fields=("num_vertices",))
@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed sparse row adjacency (out-edges), for sampling/traversal.

    Attributes:
      indptr:  ``(V+1,) int32``.
      indices: ``(E,) int32`` neighbor ids.
      weights: ``(E,) float32``.
    """

    indptr: jax.Array
    indices: jax.Array
    weights: jax.Array
    num_vertices: int

    @staticmethod
    def from_edges(
        src: np.ndarray, dst: np.ndarray, weight: np.ndarray, num_vertices: int
    ) -> "CSR":
        src = np.asarray(src, np.int64)
        order = np.argsort(src, kind="stable")
        s, d, w = src[order], np.asarray(dst)[order], np.asarray(weight)[order]
        counts = np.bincount(s, minlength=num_vertices)
        indptr = np.zeros(num_vertices + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSR(
            indptr=jnp.asarray(indptr.astype(np.int32)),
            indices=jnp.asarray(d.astype(np.int32)),
            weights=jnp.asarray(w.astype(np.float32)),
            num_vertices=int(num_vertices),
        )


def build_evolving_graph(
    base_src: np.ndarray,
    base_dst: np.ndarray,
    base_weight: np.ndarray,
    deltas,
    num_vertices: int,
    *,
    align: int = PAD_ALIGN,
) -> EvolvingGraph:
    """Construct an :class:`EvolvingGraph` from a base snapshot + delta batches.

    Args:
      base_*: snapshot ``G_0`` edges (numpy, host side).
      deltas: sequence of ``(add_src, add_dst, add_w, del_src, del_dst)``
        batches; applying batch ``i`` to snapshot ``i`` yields snapshot
        ``i+1``.  ``len(deltas) + 1`` snapshots total.
      num_vertices: vertex-count (all vertices present in all snapshots, per
        the paper's setting).
    """
    num_snapshots = len(deltas) + 1

    def key(s, d):
        return s.astype(np.int64) * np.int64(num_vertices) + d.astype(np.int64)

    # --- build the universe -------------------------------------------------
    all_src = [np.asarray(base_src, np.int64)]
    all_dst = [np.asarray(base_dst, np.int64)]
    all_w = [np.asarray(base_weight, np.float64)]
    for add_src, add_dst, add_w, _ds, _dd in deltas:
        all_src.append(np.asarray(add_src, np.int64))
        all_dst.append(np.asarray(add_dst, np.int64))
        all_w.append(np.asarray(add_w, np.float64))
    cat_src = np.concatenate(all_src)
    cat_dst = np.concatenate(all_dst)
    cat_w = np.concatenate(all_w)
    cat_key = key(cat_src, cat_dst)
    uniq_key, inv = np.unique(cat_key, return_inverse=True)
    n_uniq = uniq_key.shape[0]
    # weight extrema across every occurrence of the edge (safe-weight rule)
    w_min = np.full(n_uniq, np.inf)
    w_max = np.full(n_uniq, -np.inf)
    np.minimum.at(w_min, inv, cat_w)
    np.maximum.at(w_max, inv, cat_w)
    u_src = (uniq_key // num_vertices).astype(np.int32)
    u_dst = (uniq_key % num_vertices).astype(np.int32)

    # --- replay deltas to get per-snapshot presence -------------------------
    lookup = {k: i for i, k in enumerate(uniq_key.tolist())}
    present = np.zeros(n_uniq, bool)
    base_idx = np.searchsorted(uniq_key, key(np.asarray(base_src, np.int64), np.asarray(base_dst, np.int64)))
    present[base_idx] = True
    dense = np.zeros((num_snapshots, n_uniq), bool)
    dense[0] = present
    for i, (add_src, add_dst, _aw, del_src, del_dst) in enumerate(deltas):
        if len(del_src):
            di = np.searchsorted(uniq_key, key(np.asarray(del_src, np.int64), np.asarray(del_dst, np.int64)))
            present[di] = False
        if len(add_src):
            ai = np.searchsorted(uniq_key, key(np.asarray(add_src, np.int64), np.asarray(add_dst, np.int64)))
            present[ai] = True
        dense[i + 1] = present
    del lookup

    # --- dst-sort + pad ------------------------------------------------------
    order = np.lexsort((u_src, u_dst))
    u_src, u_dst = u_src[order], u_dst[order]
    w_min, w_max = w_min[order], w_max[order]
    dense = dense[:, order]
    packed = pack_presence(dense)

    return EvolvingGraph(
        src=jnp.asarray(pad_to_multiple(u_src, align, 0)),
        dst=jnp.asarray(pad_to_multiple(u_dst, align, 0)),
        weight_min=jnp.asarray(pad_to_multiple(w_min.astype(np.float32), align, 0.0)),
        weight_max=jnp.asarray(pad_to_multiple(w_max.astype(np.float32), align, 0.0)),
        presence=jnp.asarray(pad_to_multiple(packed, align, 0, axis=0)),
        num_vertices=int(num_vertices),
        num_snapshots=num_snapshots,
    )

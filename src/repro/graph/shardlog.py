"""Dst-range-sharded streaming substrate: per-shard delta logs + window views.

:class:`~repro.graph.stream.SnapshotLog` keeps the whole edge universe on one
host.  The pod deployment partitions the vertex space by **dst range** —
shard ``s`` owns vertices ``[s * v_local, (s+1) * v_local)`` and every edge
*sinking* in that range (the layout
:func:`repro.distributed.evolve.shard_evolving_arrays` lowers for the static
batch engine).  This module applies the same partitioning to the streaming
substrate:

* :class:`ShardedSnapshotLog` — ``n_shards`` independent
  :class:`~repro.graph.stream.SnapshotLog` instances.  ``append_snapshot``
  routes each delta edge to the shard owning its destination, so universe-id
  assignment, weight-extrema tracking, and per-snapshot presence recording
  are **shard-local by construction**: no shard ever sees (or stores) another
  shard's edges, matching the delta-partitioning of historical-graph stores
  (Koloniari et al.; Khurana & Deshpande).
* :class:`ShardedWindowView` — ``n_shards`` independent
  :class:`~repro.graph.stream.WindowView` instances sliding in lockstep.
  ``slide()`` emits a :class:`ShardSlideDiff` of per-shard
  :class:`~repro.graph.stream.SlideDiff`\\ s; witness-count maintenance —
  like appends — touches only shard-owned arrays.

Because every consumer downstream of the slide diff scatters **into edge
destinations**, all of the expensive maintenance (witness counts, QRS keep
rules, bound trims, segment reductions) stays shard-local; only the
source-value gather crosses shards, and that is exactly the one all-gather
per superstep :func:`repro.distributed.stream_shard.ShardedStreamingBounds`
issues.  The host-side structures here are mesh-free (plain numpy); the
device-side SPMD engine lives in :mod:`repro.distributed.stream_shard`.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.graph.stream import STREAM_ALIGN, SlideDiff, SnapshotLog, WindowView
from repro.graph.structures import EvolvingGraph, PAD_ALIGN, pack_presence
from repro.utils.padding import pad_to, round_up

_EMPTY = np.empty(0, np.int64)


@dataclasses.dataclass(frozen=True)
class ShardSlideDiff:
    """One window slide, as ``n_shards`` independent per-shard diffs.

    ``shards[s]`` is shard ``s``'s :class:`~repro.graph.stream.SlideDiff`
    with **shard-local** universe ids (indices into shard ``s``'s arrays).
    The aggregate accessors below concatenate those shard-local ids and are
    meaningful only for emptiness/length tests (``StreamingQuery.advance``
    uses them to detect weight widening); per-shard consumers must read
    ``shards[s]`` directly.
    """

    shards: tuple
    appended: int  # log index of the snapshot that entered the window
    retired: int  # log index of the snapshot that left the window

    def _concat(self, field: str) -> np.ndarray:
        return np.concatenate([getattr(d, field) for d in self.shards])

    @property
    def wmin_shrunk(self) -> np.ndarray:  # shard-local ids; lengths only
        return self._concat("wmin_shrunk")

    @property
    def wmax_grown(self) -> np.ndarray:  # shard-local ids; lengths only
        return self._concat("wmax_grown")

    @property
    def wmin_grown(self) -> np.ndarray:  # shard-local ids; lengths only
        return self._concat("wmin_grown")

    @property
    def wmax_shrunk(self) -> np.ndarray:  # shard-local ids; lengths only
        return self._concat("wmax_shrunk")

    def weights_changed(self) -> bool:
        """True when any shard's window weight extremum moved this slide."""
        return any(d.weights_changed() for d in self.shards)

    # same worse/better mapping as SlideDiff, over the concatenated ids
    # (lengths only — see class docstring); reused, not re-encoded
    cap_weight_transitions = SlideDiff.cap_weight_transitions
    cup_weight_transitions = SlideDiff.cup_weight_transitions

    def is_empty(self) -> bool:
        return all(d.is_empty() for d in self.shards)


class ShardedSnapshotLog:
    """A :class:`~repro.graph.stream.SnapshotLog` partitioned by dst range.

    Shard ``s`` owns every edge whose destination lies in
    ``[s * v_local, (s+1) * v_local)`` (``v_local = num_vertices //
    n_shards``, the :func:`~repro.distributed.evolve.shard_evolving_arrays`
    layout).  Each shard is a full independent :class:`SnapshotLog` over the
    *global* vertex-id space — sources are arbitrary vertices — so all of its
    machinery (stable append-order ids, amortized capacity, weight extrema,
    per-snapshot presence, history compaction) is reused unchanged.

    Appends are **atomic across shards**: every shard's sub-delta is
    validated against its tip (:meth:`SnapshotLog.prepare_delta`) before any
    shard commits, so a bad delta leaves no shard half-advanced.
    """

    def __init__(self, num_vertices: int, n_shards: int, *,
                 capacity: int = STREAM_ALIGN):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if num_vertices % n_shards:
            raise ValueError(
                f"num_vertices {num_vertices} must be divisible by "
                f"n_shards {n_shards}"
            )
        self.num_vertices = int(num_vertices)
        self.n_shards = int(n_shards)
        self.v_local = self.num_vertices // self.n_shards
        self.shards = [
            SnapshotLog(num_vertices, capacity=capacity)
            for _ in range(self.n_shards)
        ]
        # host-side stacked-array cache (see stacked_arrays)
        self._stack_key = None
        self._stack: dict = {}

    # -- sizes ----------------------------------------------------------------
    @property
    def num_snapshots(self) -> int:
        return self.shards[0].num_snapshots

    @property
    def num_edges(self) -> int:
        """Registered universe edges summed over shards."""
        return sum(sh.num_edges for sh in self.shards)

    @property
    def capacity(self) -> int:
        """Uniform per-shard slot count (max over shard capacities).

        Shards grow independently; stacked device arrays pad every shard to
        this, so jitted consumers compile once per max-capacity class.
        """
        return max(sh.capacity for sh in self.shards)

    def state_key(self) -> tuple:
        """Hashable fingerprint of universe/extrema state (cache key)."""
        return tuple(
            (sh.generation, sh.num_edges, sh.weight_version) for sh in self.shards
        )

    # -- append ---------------------------------------------------------------
    def shard_of(self, dst) -> np.ndarray:
        """Owning shard per destination id."""
        return np.asarray(dst, np.int64) // self.v_local

    def _route(self, src, dst, *payloads):
        """Split ``(src, dst, *payloads)`` into per-shard tuples by dst."""
        src = np.asarray(src, np.int64).ravel()
        dst = np.asarray(dst, np.int64).ravel()
        if len(dst) == 0:
            empties = (_EMPTY,) * (2 + len(payloads))
            return [empties] * self.n_shards
        if dst.min() < 0 or dst.max() >= self.num_vertices:
            raise ValueError(
                f"dst vertex id outside [0, {self.num_vertices}) "
                f"at snapshot {self.num_snapshots}"
            )
        shard = dst // self.v_local
        out = []
        for s in range(self.n_shards):
            sel = shard == s
            out.append((src[sel], dst[sel])
                       + tuple(np.asarray(p).ravel()[sel] for p in payloads))
        return out

    def append_snapshot(
        self,
        add_src: Sequence[int],
        add_dst: Sequence[int],
        add_w: Sequence[float],
        del_src: Sequence[int] = (),
        del_dst: Sequence[int] = (),
    ) -> int:
        """Route one delta batch to its owning shards; returns snapshot index.

        Shards receiving no edges still append an (empty) snapshot so
        per-shard snapshot indices stay aligned with the global log.
        """
        n_add = len(np.asarray(add_src).ravel())
        if (n_add != len(np.asarray(add_dst).ravel())
                or n_add != len(np.asarray(add_w).ravel())):
            raise ValueError(
                f"add arrays disagree in length at snapshot {self.num_snapshots}"
            )
        if len(np.asarray(del_src).ravel()) != len(np.asarray(del_dst).ravel()):
            raise ValueError(
                f"del arrays disagree in length at snapshot {self.num_snapshots}"
            )
        adds = self._route(add_src, add_dst, add_w)
        dels = self._route(del_src, del_dst)
        # validate every shard's sub-delta before any shard mutates: a bad
        # delta must not leave some shards one snapshot ahead of others
        prepared = [
            self.shards[s].prepare_delta(
                adds[s][0], adds[s][1], adds[s][2], dels[s][0], dels[s][1]
            )
            for s in range(self.n_shards)
        ]
        t = -1
        for s, p in enumerate(prepared):
            t = self.shards[s].commit_delta(p)
        return t

    @classmethod
    def from_stream(cls, base, deltas, num_vertices: int, n_shards: int, *,
                    capacity: int = STREAM_ALIGN) -> "ShardedSnapshotLog":
        """Build a sharded log from ``generate_evolving_stream`` output."""
        log = cls(num_vertices, n_shards, capacity=capacity)
        bs, bd, bw = base
        log.append_snapshot(bs, bd, bw)
        for add_src, add_dst, add_w, del_src, del_dst in deltas:
            log.append_snapshot(add_src, add_dst, add_w, del_src, del_dst)
        return log

    # -- stacked host arrays (the shard_map feed) -----------------------------
    def stacked_arrays(self) -> dict:
        """Per-shard edge arrays stacked to ``(n_shards * capacity,)`` numpy.

        ``src`` keeps global vertex ids (the gather side spans shards);
        ``dst_local`` is rebased to ``[0, v_local)`` (the scatter side is
        shard-local).  ``valid`` marks registered slots.  Re-stacked only
        when :meth:`state_key` changes.
        """
        key = (self.state_key(), self.capacity)
        if self._stack_key != key:
            cap = self.capacity
            n = self.n_shards
            src = np.zeros((n, cap), np.int32)
            dstl = np.zeros((n, cap), np.int32)
            wmin = np.zeros((n, cap), np.float32)
            wmax = np.zeros((n, cap), np.float32)
            valid = np.zeros((n, cap), bool)
            for s, sh in enumerate(self.shards):
                k = sh.num_edges
                src[s, :k] = sh.src[:k]
                dstl[s, :k] = sh.dst[:k] - s * self.v_local
                wmin[s, :k] = sh.weight_min[:k]
                wmax[s, :k] = sh.weight_max[:k]
                valid[s, :k] = True
            self._stack = {
                "src": src.reshape(-1),
                "dst_local": dstl.reshape(-1),
                "weight_min": wmin.reshape(-1),
                "weight_max": wmax.reshape(-1),
                "valid": valid.reshape(-1),
                "e_cap": cap,
            }
            self._stack_key = key
        return self._stack

    def stack_masks(self, masks: Sequence[np.ndarray]) -> np.ndarray:
        """Stack per-shard ``(shard capacity,)`` masks to one flat array.

        Each shard's mask is padded with ``False`` to the uniform
        :attr:`capacity`, matching the :meth:`stacked_arrays` layout.
        """
        cap = self.capacity
        return np.stack(
            [pad_to(np.asarray(m), cap, False) for m in masks]
        ).reshape(-1)

    def stack_ids(self, per_shard_ids: Sequence[np.ndarray]) -> np.ndarray:
        """Scatter per-shard local-id arrays into one flat stacked bool mask."""
        cap = self.capacity
        mask = np.zeros(self.n_shards * cap, bool)
        for s, ids in enumerate(per_shard_ids):
            if len(ids):
                mask[s * cap + np.asarray(ids, np.int64)] = True
        return mask


class ShardedWindowView:
    """Lockstep sliding windows over a :class:`ShardedSnapshotLog`.

    Mirrors the :class:`~repro.graph.stream.WindowView` API so
    :class:`~repro.core.api.StreamingQuery` front-ends can drive either;
    mask accessors return **per-shard lists** (shard-local, capacity-shaped)
    and ``slide()`` returns a :class:`ShardSlideDiff`.
    """

    def __init__(self, log: ShardedSnapshotLog, size: Optional[int] = None,
                 start: Optional[int] = None):
        self.log = log
        if start is None:
            # lockstep views must agree on the window even if one shard's
            # history happens to be retired further than another's
            start = max(sh.retired_upto for sh in log.shards)
        self.views = [WindowView(sh, size=size, start=start) for sh in log.shards]
        self.history: list[ShardSlideDiff] = []
        self._history_offset = 0

    # -- window geometry (all shards identical) -------------------------------
    @property
    def start(self) -> int:
        return self.views[0].start

    @property
    def size(self) -> int:
        return self.views[0].size

    @property
    def stop(self) -> int:
        return self.views[0].stop

    def snapshots(self) -> range:
        return range(self.start, self.stop)

    # -- slide history --------------------------------------------------------
    @property
    def history_end(self) -> int:
        return self._history_offset + len(self.history)

    def diffs_since(self, pos: int) -> list[ShardSlideDiff]:
        if pos < self._history_offset:
            raise LookupError(
                f"slide history before position {self._history_offset} was "
                f"pruned; consumer at {pos} must re-prime"
            )
        return self.history[pos - self._history_offset:]

    def prune_history(self, upto: int) -> None:
        drop = min(upto, self.history_end) - self._history_offset
        if drop > 0:
            del self.history[:drop]
            self._history_offset += drop
        for v in self.views:
            v.prune_history(upto)  # also retires per-shard log history

    # -- sliding --------------------------------------------------------------
    def slide(self) -> ShardSlideDiff:
        diffs = tuple(v.slide() for v in self.views)
        d = ShardSlideDiff(
            shards=diffs, appended=diffs[0].appended, retired=diffs[0].retired
        )
        self.history.append(d)
        return d

    def slide_to_tip(self) -> list[ShardSlideDiff]:
        out = []
        while self.stop < self.log.num_snapshots:
            out.append(self.slide())
        return out

    # -- per-shard masks / weights --------------------------------------------
    @property
    def weight_epoch(self) -> int:
        """Bumped whenever any shard's window weight extrema change."""
        return sum(v.weight_epoch for v in self.views)

    def stacked_weight_extrema(self) -> tuple[np.ndarray, np.ndarray]:
        """Per-shard window-local ``(weight_min, weight_max)`` stacked flat.

        Matches the :meth:`ShardedSnapshotLog.stacked_arrays` layout
        (``(n_shards * capacity,)``, each shard padded to the uniform
        capacity) so the SPMD bounds kernels can consume exact window
        extrema instead of the log's lifetime ones.
        """
        cap = self.log.capacity
        for v in self.views:
            v._sync_capacity()
        wmin = np.stack(
            [pad_to(v.weight_min[: cap], cap, 0.0) for v in self.views]
        ).reshape(-1)
        wmax = np.stack(
            [pad_to(v.weight_max[: cap], cap, 0.0) for v in self.views]
        ).reshape(-1)
        return wmin, wmax

    def union_masks(self) -> list[np.ndarray]:
        return [v.union_mask() for v in self.views]

    def intersection_masks(self) -> list[np.ndarray]:
        return [v.intersection_mask() for v in self.views]

    def snapshot_masks(self, t: int) -> list[np.ndarray]:
        return [v.snapshot_mask(t) for v in self.views]

    def rolling_masks(
        self, diffs: Sequence[ShardSlideDiff]
    ) -> Iterator[tuple[list[np.ndarray], list[np.ndarray]]]:
        """Yield per-slide ``(union masks, intersection masks)`` lists.

        The per-shard :meth:`WindowView.rolling_masks` generators run in
        lockstep, so each yield is one intermediate window's state — exactly
        what a multi-slide catch-up needs (see the single-host docstring).
        """
        gens = [
            v.rolling_masks([d.shards[s] for d in diffs])
            for s, v in enumerate(self.views)
        ]
        for _ in range(len(diffs)):
            step = [next(g) for g in gens]
            yield [u for u, _ in step], [i for _, i in step]

    # -- canonical reference graph -------------------------------------------
    def materialize(self, *, pad_to_capacity: bool = True) -> EvolvingGraph:
        """Canonical (dst-sorted, bit-packed) global graph of the window.

        Concatenates the shard universes back into one edge list and applies
        the same canonical layout as :meth:`WindowView.materialize` — the
        reference substrate the sharded streaming engine must match
        bit-for-bit.
        """
        log = self.log
        for v in self.views:
            v._sync_capacity()
        counts = [sh.num_edges for sh in log.shards]
        src = np.concatenate([sh.src[:k] for sh, k in zip(log.shards, counts)])
        dst = np.concatenate([sh.dst[:k] for sh, k in zip(log.shards, counts)])
        wmin = np.concatenate(
            [v.weight_min[:k] for v, k in zip(self.views, counts)]
        )
        wmax = np.concatenate(
            [v.weight_max[:k] for v, k in zip(self.views, counts)]
        )
        offsets = np.cumsum([0] + counts[:-1])
        n = int(sum(counts))
        order = np.lexsort((src, dst))
        dense = np.zeros((self.size, n), bool)
        for i, t in enumerate(self.snapshots()):
            for s, (sh, off) in enumerate(zip(log.shards, offsets)):
                dense[i, off + sh.snapshot_edges(t)] = True
        packed = pack_presence(dense[:, order])
        cap = (log.capacity * log.n_shards if pad_to_capacity
               else round_up(max(n, 1), PAD_ALIGN))
        return EvolvingGraph(
            src=jnp.asarray(pad_to(src[order].astype(np.int32), cap, 0)),
            dst=jnp.asarray(pad_to(dst[order].astype(np.int32), cap, 0)),
            weight_min=jnp.asarray(pad_to(wmin[order], cap, 0.0)),
            weight_max=jnp.asarray(pad_to(wmax[order], cap, 0.0)),
            presence=jnp.asarray(pad_to(packed, cap, 0, axis=0)),
            num_vertices=log.num_vertices,
            num_snapshots=self.size,
        )

"""Dst-sharded streaming substrate: per-shard delta logs + window views.

:class:`~repro.graph.stream.SnapshotLog` keeps the whole edge universe on one
host.  The pod deployment partitions the vertex space by **destination** —
a shard owns a set of vertices and every edge *sinking* there.  Which
vertices a shard owns is decided by a :class:`ShardAssignment`: equal dst
ranges (the historical
:func:`repro.distributed.evolve.shard_evolving_arrays` layout), degree-
histogram-**balanced** range boundaries, or **hash**-of-dst with a
per-shard local-id map — the latter two evening out the per-shard edge
mass that naive ranges inherit from the graph's degree skew.  This module
applies the chosen partitioning to the streaming substrate:

* :class:`ShardedSnapshotLog` — ``n_shards`` independent
  :class:`~repro.graph.stream.SnapshotLog` instances.  ``append_snapshot``
  routes each delta edge to the shard owning its destination, so universe-id
  assignment, weight-extrema tracking, and per-snapshot presence recording
  are **shard-local by construction**: no shard ever sees (or stores) another
  shard's edges, matching the delta-partitioning of historical-graph stores
  (Koloniari et al.; Khurana & Deshpande).
* :class:`ShardedWindowView` — ``n_shards`` independent
  :class:`~repro.graph.stream.WindowView` instances sliding in lockstep.
  ``slide()`` emits a :class:`ShardSlideDiff` of per-shard
  :class:`~repro.graph.stream.SlideDiff`\\ s; witness-count maintenance —
  like appends — touches only shard-owned arrays.

Because every consumer downstream of the slide diff scatters **into edge
destinations**, all of the expensive maintenance (witness counts, QRS keep
rules, bound trims, segment reductions) stays shard-local; only the
source-value gather crosses shards, and that is exactly the one all-gather
per superstep :func:`repro.distributed.stream_shard.ShardedStreamingBounds`
issues.  The host-side structures here are mesh-free (plain numpy); the
device-side SPMD engine lives in :mod:`repro.distributed.stream_shard`.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.ft.faultinject import InjectedFault, corrupt_point, fault_point
from repro.graph.stream import STREAM_ALIGN, SlideDiff, SnapshotLog, WindowView
from repro.graph.structures import EvolvingGraph, PAD_ALIGN, pack_presence
from repro.utils.padding import pad_to, round_up

_EMPTY = np.empty(0, np.int64)


@dataclasses.dataclass(frozen=True)
class ShardAssignment:
    """Vertex → shard assignment with a per-shard local-id map.

    Dst-range sharding inherits the graph's degree skew (a hub-heavy range
    owns most of the edges, so its shard's capacity, ELL rows, and superstep
    work dominate every launch).  This abstraction decouples *which shard
    owns a vertex* from the contiguous-range default so the assignment can be
    rebalanced:

    * ``range``    — shard ``s`` owns ``[s·v_local, (s+1)·v_local)`` (the
      historical layout; zero-overhead identity position map).
    * ``balanced`` — still contiguous ranges, but the boundaries are chosen
      from a **degree histogram** so per-shard edge mass evens out
      (:meth:`balanced`).
    * ``hash``     — vertices hashed to shards (:meth:`hashed`), the
      skew-oblivious assignment; local ids come from the per-shard map.

    Every shard's local-id space is padded to the uniform width
    :attr:`v_cap`, so the device-side per-vertex state is the flat
    **position space** ``(n_shards · v_cap,)`` with vertex ``v`` at
    ``positions[v] = owner[v] · v_cap + local[v]`` — the ``shard_map``
    kernels (:mod:`repro.distributed.stream_shard`) run *unchanged* on that
    space (padding positions hold the semiring identity and own no edges),
    and for ``range`` mode it degenerates to the identity layout.
    """

    mode: str
    n_shards: int
    num_vertices: int
    owner: np.ndarray  # (V,) int32 — owning shard per vertex
    local: np.ndarray  # (V,) int32 — local id within the owner, < v_cap
    v_cap: int  # uniform per-shard local width (padded)
    global_ids: np.ndarray  # (n_shards, v_cap) int32 — local → global, -1 pad
    positions: np.ndarray  # (V,) int64 — owner·v_cap + local
    epoch: int = 0  # layout epoch; bumped by rebalance/resize/reshard

    @property
    def state_len(self) -> int:
        """Length of the flat position-space per-vertex state."""
        return self.n_shards * self.v_cap

    @classmethod
    def _build(cls, mode: str, num_vertices: int, n_shards: int,
               owner: np.ndarray, local: np.ndarray, v_cap: int,
               epoch: int = 0):
        gid = np.full((n_shards, v_cap), -1, np.int32)
        gid[owner, local] = np.arange(num_vertices, dtype=np.int32)
        positions = owner.astype(np.int64) * v_cap + local
        return cls(mode, int(n_shards), int(num_vertices),
                   owner.astype(np.int32), local.astype(np.int32),
                   int(v_cap), gid, positions, int(epoch))

    # -- layout-epoch derivations ---------------------------------------------
    def rebalance(self, degree_hist) -> "ShardAssignment":
        """Next-epoch balanced layout at the same shard count.

        Re-derives degree-histogram-balanced range boundaries from a *fresh*
        histogram (typically :meth:`ShardedSnapshotLog.live_degree_histogram`
        so drifting hubs re-even the per-shard edge mass) and stamps the
        successor epoch — the input to a live :meth:`ShardedSnapshotLog.reshard`.
        """
        new = ShardAssignment.balanced(
            self.num_vertices, self.n_shards, degree_hist
        )
        return dataclasses.replace(new, epoch=self.epoch + 1)

    def resize(self, n_shards: int, degree_hist=None) -> "ShardAssignment":
        """Next-epoch balanced layout at a *different* shard count.

        With no histogram each vertex carries uniform mass, so the ranges
        split evenly regardless of divisibility (unlike :meth:`ranged`).
        """
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if degree_hist is None:
            degree_hist = np.ones(self.num_vertices)
        new = ShardAssignment.balanced(
            self.num_vertices, int(n_shards), degree_hist
        )
        return dataclasses.replace(new, epoch=self.epoch + 1)

    @classmethod
    def ranged(cls, num_vertices: int, n_shards: int) -> "ShardAssignment":
        """Contiguous equal-width dst ranges (the historical layout)."""
        if num_vertices % n_shards:
            raise ValueError(
                f"num_vertices {num_vertices} must be divisible by "
                f"n_shards {n_shards} for range sharding (use 'balanced' or "
                f"'hash' otherwise)"
            )
        v_local = num_vertices // n_shards
        ids = np.arange(num_vertices, dtype=np.int64)
        return cls._build("range", num_vertices, n_shards,
                          ids // v_local, ids % v_local, v_local)

    @classmethod
    def balanced(cls, num_vertices: int, n_shards: int,
                 degree_hist) -> "ShardAssignment":
        """Contiguous ranges with degree-histogram-driven boundaries.

        Boundary ``s`` is placed where the cumulative in-degree mass crosses
        ``s/n`` of the total, so each shard owns ≈ the same number of edges
        (dst-sharding puts an edge on its destination's shard) instead of the
        same number of vertices.  Each vertex also carries a small uniform
        mass so zero-degree spans still split instead of piling onto one
        shard.  Per-shard widths differ; the local-id space is padded to the
        widest range.
        """
        deg = np.asarray(degree_hist, np.float64).ravel()
        if len(deg) != num_vertices:
            raise ValueError(
                f"degree_hist has {len(deg)} entries for {num_vertices} "
                f"vertices"
            )
        mass = deg + max(float(deg.sum()), 1.0) / num_vertices * 1e-3
        cum = np.cumsum(mass)
        targets = cum[-1] * np.arange(1, n_shards) / n_shards
        cuts = np.searchsorted(cum, targets, side="left") + 1
        bounds = np.concatenate([[0], np.minimum(cuts, num_vertices),
                                 [num_vertices]]).astype(np.int64)
        bounds = np.maximum.accumulate(bounds)
        widths = np.diff(bounds)
        v_cap = int(widths.max())
        ids = np.arange(num_vertices, dtype=np.int64)
        owner = np.repeat(np.arange(n_shards, dtype=np.int64), widths)
        local = ids - bounds[owner]
        return cls._build("balanced", num_vertices, n_shards,
                          owner, local, v_cap)

    @classmethod
    def hashed(cls, num_vertices: int, n_shards: int, *,
               seed: int = 0) -> "ShardAssignment":
        """Hash-of-dst sharding with a per-shard local-id map.

        A splitmix64-style mix of the vertex id picks the owner, so hub
        vertices spread across shards regardless of id locality; within a
        shard, local ids follow hash order — a nontrivial position map even
        at ``n_shards=1``, which is what lets tier-1 exercise the map on a
        single device.
        """
        h = np.arange(num_vertices, dtype=np.uint64)
        h = (h + np.uint64(seed) + np.uint64(0x9E3779B97F4A7C15))
        h = (h ^ (h >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        h = (h ^ (h >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        h = h ^ (h >> np.uint64(31))
        owner = (h % np.uint64(n_shards)).astype(np.int64)
        order = np.lexsort((np.arange(num_vertices), h, owner))
        local = np.empty(num_vertices, np.int64)
        counts = np.bincount(owner, minlength=n_shards)
        local[order] = (np.arange(num_vertices)
                        - np.repeat(np.cumsum(counts) - counts, counts))
        return cls._build("hash", num_vertices, n_shards,
                          owner, local, int(max(counts.max(), 1)))


@dataclasses.dataclass(frozen=True)
class MigrationPlan:
    """Old → new flat-position-space map between two layout epochs.

    ``new_to_old[p]`` is the old position holding the vertex that position
    ``p`` owns under the new layout (``-1`` at padding positions, which own
    no vertex).  :meth:`permute` routes any ``(..., old.state_len)``
    per-vertex state array through the global vertex space in one gather —
    the whole live state migration, because position-space values *are*
    global values at permuted indices (identity at padding).  ``moved``
    counts vertices whose flat position changed (the migration's real
    traffic; unchanged positions are copies a device could elide).
    """

    old: ShardAssignment
    new: ShardAssignment
    new_to_old: np.ndarray  # (new.state_len,) int64, -1 at padding
    moved: int

    def permute(self, vals, fill) -> np.ndarray:
        """Map an old-position-space array onto the new position space."""
        vals = np.asarray(vals)
        out = np.full(vals.shape[:-1] + (self.new.state_len,), fill,
                      vals.dtype)
        live = self.new_to_old >= 0
        out[..., live] = vals[..., self.new_to_old[live]]
        return out

    def bytes_moved(self, *state_arrays) -> int:
        """Bytes of per-vertex state the migration rerouted (obs accounting)."""
        total = 0
        for a in state_arrays:
            a = np.asarray(a)
            per_pos = a.size // max(a.shape[-1], 1) * a.dtype.itemsize
            total += per_pos * self.moved
        return int(total)


def migration_plan(old: ShardAssignment,
                   new: ShardAssignment) -> MigrationPlan:
    """Build the old→new position-space map for a layout transition."""
    if old.num_vertices != new.num_vertices:
        raise ValueError(
            f"cannot migrate between vertex spaces ({old.num_vertices} -> "
            f"{new.num_vertices})"
        )
    new_to_old = np.full(new.state_len, -1, np.int64)
    new_to_old[new.positions] = old.positions
    if old.state_len == new.state_len:
        moved = int((old.positions != new.positions).sum())
    else:
        moved = old.num_vertices
    return MigrationPlan(old, new, new_to_old, moved)


def make_assignment(
    assignment: Union[str, ShardAssignment], num_vertices: int,
    n_shards: int, *, degree_hist=None, seed: int = 0,
) -> ShardAssignment:
    """Resolve an assignment spec (mode name or prebuilt) for a log."""
    if isinstance(assignment, ShardAssignment):
        if (assignment.num_vertices != num_vertices
                or assignment.n_shards != n_shards):
            raise ValueError(
                f"assignment is for {assignment.num_vertices} vertices / "
                f"{assignment.n_shards} shards, log has {num_vertices} / "
                f"{n_shards}"
            )
        return assignment
    if assignment == "range":
        return ShardAssignment.ranged(num_vertices, n_shards)
    if assignment == "balanced":
        if degree_hist is None:
            raise ValueError(
                "assignment='balanced' needs a degree_hist (per-vertex "
                "in-degree histogram; see degree_histogram())"
            )
        return ShardAssignment.balanced(num_vertices, n_shards, degree_hist)
    if assignment == "hash":
        return ShardAssignment.hashed(num_vertices, n_shards, seed=seed)
    raise ValueError(
        f"unknown assignment {assignment!r}; options: range, balanced, hash"
    )


def degree_histogram(base, deltas, num_vertices: int) -> np.ndarray:
    """Per-vertex in-degree mass of a ``generate_evolving_stream`` stream.

    Counts every *addition*'s destination (base + deltas): the quantity
    dst-sharding distributes is edge-slot mass, and re-adds keep an edge's
    universe slot live, so addition counts track per-shard occupancy well.
    """
    hist = np.bincount(np.asarray(base[1], np.int64), minlength=num_vertices)
    for _, add_dst, _, _, _ in deltas:
        if len(np.asarray(add_dst).ravel()):
            hist = hist + np.bincount(
                np.asarray(add_dst, np.int64).ravel(), minlength=num_vertices
            )
    return hist


@dataclasses.dataclass(frozen=True)
class ShardSlideDiff:
    """One window slide, as ``n_shards`` independent per-shard diffs.

    ``shards[s]`` is shard ``s``'s :class:`~repro.graph.stream.SlideDiff`
    with **shard-local** universe ids (indices into shard ``s``'s arrays).
    The aggregate accessors below concatenate those shard-local ids and are
    meaningful only for emptiness/length tests (``StreamingQuery.advance``
    uses them to detect weight widening); per-shard consumers must read
    ``shards[s]`` directly.
    """

    shards: tuple
    appended: int  # log index of the snapshot that entered the window
    retired: int  # log index of the snapshot that left the window

    def _concat(self, field: str) -> np.ndarray:
        return np.concatenate([getattr(d, field) for d in self.shards])

    @property
    def wmin_shrunk(self) -> np.ndarray:  # shard-local ids; lengths only
        return self._concat("wmin_shrunk")

    @property
    def wmax_grown(self) -> np.ndarray:  # shard-local ids; lengths only
        return self._concat("wmax_grown")

    @property
    def wmin_grown(self) -> np.ndarray:  # shard-local ids; lengths only
        return self._concat("wmin_grown")

    @property
    def wmax_shrunk(self) -> np.ndarray:  # shard-local ids; lengths only
        return self._concat("wmax_shrunk")

    def weights_changed(self) -> bool:
        """True when any shard's window weight extremum moved this slide."""
        return any(d.weights_changed() for d in self.shards)

    # same worse/better mapping as SlideDiff, over the concatenated ids
    # (lengths only — see class docstring); reused, not re-encoded
    cap_weight_transitions = SlideDiff.cap_weight_transitions
    cup_weight_transitions = SlideDiff.cup_weight_transitions

    def is_empty(self) -> bool:
        return all(d.is_empty() for d in self.shards)


class ShardedSnapshotLog:
    """A :class:`~repro.graph.stream.SnapshotLog` partitioned by dst range.

    Shard ``s`` owns every edge whose destination lies in
    ``[s * v_local, (s+1) * v_local)`` (``v_local = num_vertices //
    n_shards``, the :func:`~repro.distributed.evolve.shard_evolving_arrays`
    layout).  Each shard is a full independent :class:`SnapshotLog` over the
    *global* vertex-id space — sources are arbitrary vertices — so all of its
    machinery (stable append-order ids, amortized capacity, weight extrema,
    per-snapshot presence, history compaction) is reused unchanged.

    Appends are **atomic across shards**: every shard's sub-delta is
    validated against its tip (:meth:`SnapshotLog.prepare_delta`) before any
    shard commits, so a bad delta leaves no shard half-advanced.

    ``assignment`` picks the vertex → shard map (:class:`ShardAssignment`):
    ``"range"`` (default, the historical equal-width dst ranges),
    ``"balanced"`` (degree-histogram-driven range boundaries; pass
    ``degree_hist``), ``"hash"`` (hash-of-dst with a per-shard local-id
    map), or a prebuilt :class:`ShardAssignment`.  Every mode preserves the
    shard-local-by-construction property (a shard owns all edges sinking at
    its vertices) and therefore the engine's bit-for-bit guarantees — only
    *which* shard owns a vertex changes.
    """

    def __init__(self, num_vertices: int, n_shards: int, *,
                 capacity: int = STREAM_ALIGN,
                 assignment: Union[str, ShardAssignment] = "range",
                 degree_hist=None, seed: int = 0):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.num_vertices = int(num_vertices)
        self.n_shards = int(n_shards)
        self.assignment = make_assignment(
            assignment, self.num_vertices, self.n_shards,
            degree_hist=degree_hist, seed=seed,
        )
        # uniform per-shard local width; == num_vertices // n_shards for the
        # historical range mode (several tests/examples rely on that)
        self.v_local = self.assignment.v_cap
        self.shards = [
            SnapshotLog(num_vertices, capacity=capacity)
            for _ in range(self.n_shards)
        ]
        # host-side stacked-array cache (see stacked_arrays)
        self._stack_key = None
        self._stack: dict = {}

    # -- sizes ----------------------------------------------------------------
    @property
    def num_snapshots(self) -> int:
        return self.shards[0].num_snapshots

    @property
    def num_edges(self) -> int:
        """Registered universe edges summed over shards."""
        return sum(sh.num_edges for sh in self.shards)

    @property
    def capacity(self) -> int:
        """Uniform per-shard slot count (max over shard capacities).

        Shards grow independently; stacked device arrays pad every shard to
        this, so jitted consumers compile once per max-capacity class.
        """
        return max(sh.capacity for sh in self.shards)

    def state_key(self) -> tuple:
        """Hashable fingerprint of universe/extrema state (cache key).

        Includes the layout epoch: a live :meth:`reshard` swaps in fresh
        per-shard logs whose (generation, edges, weight-version) tuples could
        coincide with the old layout's, and every stacked-array / device /
        ELL-pack cache keyed on this fingerprint must miss across epochs.
        """
        return (self.assignment.epoch,) + tuple(
            (sh.generation, sh.num_edges, sh.weight_version) for sh in self.shards
        )

    # -- append ---------------------------------------------------------------
    @property
    def state_len(self) -> int:
        """Flat position-space state length (``n_shards * v_cap``)."""
        return self.assignment.state_len

    def shard_of(self, dst) -> np.ndarray:
        """Owning shard per destination id."""
        return self.assignment.owner[np.asarray(dst, np.int64)].astype(np.int64)

    def _route(self, src, dst, *payloads):
        """Split ``(src, dst, *payloads)`` into per-shard tuples by dst."""
        src = np.asarray(src, np.int64).ravel()
        dst = np.asarray(dst, np.int64).ravel()
        if len(dst) == 0:
            empties = (_EMPTY,) * (2 + len(payloads))
            return [empties] * self.n_shards
        if dst.min() < 0 or dst.max() >= self.num_vertices:
            raise ValueError(
                f"dst vertex id outside [0, {self.num_vertices}) "
                f"at snapshot {self.num_snapshots}"
            )
        shard = self.assignment.owner[dst]
        out = []
        for s in range(self.n_shards):
            sel = shard == s
            out.append((src[sel], dst[sel])
                       + tuple(np.asarray(p).ravel()[sel] for p in payloads))
        return out

    def append_snapshot(
        self,
        add_src: Sequence[int],
        add_dst: Sequence[int],
        add_w: Sequence[float],
        del_src: Sequence[int] = (),
        del_dst: Sequence[int] = (),
    ) -> int:
        """Route one delta batch to its owning shards; returns snapshot index.

        Shards receiving no edges still append an (empty) snapshot so
        per-shard snapshot indices stay aligned with the global log.
        """
        add_src, add_dst, add_w, del_src, del_dst = corrupt_point(
            "ingest",
            (add_src, add_dst, add_w, del_src, del_dst),
            num_vertices=self.num_vertices,
        )
        n_add = len(np.asarray(add_src).ravel())
        if (n_add != len(np.asarray(add_dst).ravel())
                or n_add != len(np.asarray(add_w).ravel())):
            raise ValueError(
                f"add arrays disagree in length at snapshot {self.num_snapshots}"
            )
        if len(np.asarray(del_src).ravel()) != len(np.asarray(del_dst).ravel()):
            raise ValueError(
                f"del arrays disagree in length at snapshot {self.num_snapshots}"
            )
        adds = self._route(add_src, add_dst, add_w)
        dels = self._route(del_src, del_dst)
        # validate every shard's sub-delta before any shard mutates: a bad
        # delta must not leave some shards one snapshot ahead of others
        prepared = [
            self.shards[s].prepare_delta(
                adds[s][0], adds[s][1], adds[s][2], dels[s][0], dels[s][1]
            )
            for s in range(self.n_shards)
        ]
        t = -1
        s = 0
        try:
            for s, p in enumerate(prepared):
                fault_point("ingest_shard", shard=s)
                t = self.shards[s].commit_delta(p)
        except InjectedFault:
            # torn cross-shard append: the prepared tokens stay valid (the
            # per-shard logs are independent and nothing else intervened),
            # so finish committing the remaining shards before surfacing
            # the fault — the log is all-or-nothing either way, never torn.
            for s2 in range(s, self.n_shards):
                t = self.shards[s2].commit_delta(prepared[s2])
            raise
        return t

    @classmethod
    def from_stream(cls, base, deltas, num_vertices: int, n_shards: int, *,
                    capacity: int = STREAM_ALIGN,
                    assignment: Union[str, ShardAssignment] = "range",
                    degree_hist=None, seed: int = 0) -> "ShardedSnapshotLog":
        """Build a sharded log from ``generate_evolving_stream`` output.

        With ``assignment="balanced"`` and no explicit ``degree_hist``, the
        histogram is derived from the stream itself
        (:func:`degree_histogram`) — the construction-time rebalance.
        """
        if assignment == "balanced" and degree_hist is None:
            degree_hist = degree_histogram(base, deltas, num_vertices)
        log = cls(num_vertices, n_shards, capacity=capacity,
                  assignment=assignment, degree_hist=degree_hist, seed=seed)
        bs, bd, bw = base
        log.append_snapshot(bs, bd, bw)
        for add_src, add_dst, add_w, del_src, del_dst in deltas:
            log.append_snapshot(add_src, add_dst, add_w, del_src, del_dst)
        return log

    def occupancy_spread(self) -> float:
        """Max/mean per-shard universe occupancy (1.0 = perfectly even)."""
        occ = np.asarray([sh.num_edges for sh in self.shards], np.float64)
        mean = occ.mean()
        return float(occ.max() / mean) if mean > 0 else 1.0

    def live_degree_histogram(self) -> np.ndarray:
        """Per-vertex in-degree mass of the *registered universe*.

        Unlike :func:`degree_histogram` (which needs the original stream)
        this reads the live per-shard universes — one universe slot per
        destination, the exact mass :meth:`occupancy_spread` measures — so a
        reshard policy can derive a fresh balanced assignment mid-stream.
        """
        hist = np.zeros(self.num_vertices, np.int64)
        for sh in self.shards:
            n = sh.num_edges
            if n:
                hist += np.bincount(
                    sh.dst[:n].astype(np.int64), minlength=self.num_vertices
                )
        return hist

    def reshard(self, assignment: ShardAssignment) -> ShardAssignment:
        """Re-route the log onto a new layout epoch, **in place**.

        Rebuilds the per-shard :class:`SnapshotLog`\\ s under ``assignment``
        by replaying the log against itself from the retirement watermark:
        the full membership in effect there seeds the new shards (weights in
        effect via :meth:`SnapshotLog.weight_at`), then every retained
        snapshot re-applies its own O(batch) :meth:`SnapshotLog.delta_batch`
        — membership, weight extrema, *and* weight events reproduce exactly,
        just routed to the new owners.  Snapshot indices are preserved
        (pre-watermark entries are empty and pre-retired), so registered
        views keep their absolute window coordinates.  ``n_shards`` may
        change.  Universe slots dead before the watermark (edges that left
        and never returned) are dropped — a compaction; they own no presence
        in any reachable window, so results are unaffected.

        The swap is atomic: the new shards are fully built (and validated by
        the ordinary append path) before ``self`` mutates.  Returns the
        installed assignment (epoch force-bumped past the current one if the
        caller's wasn't).
        """
        if not isinstance(assignment, ShardAssignment):
            raise TypeError(
                "reshard needs a prebuilt ShardAssignment (see "
                "ShardAssignment.rebalance/resize)"
            )
        if assignment.num_vertices != self.num_vertices:
            raise ValueError(
                f"assignment is for {assignment.num_vertices} vertices, "
                f"log has {self.num_vertices}"
            )
        if assignment.epoch <= self.assignment.epoch:
            assignment = dataclasses.replace(
                assignment, epoch=self.assignment.epoch + 1
            )
        old_shards = self.shards
        watermark = max(sh.retired_upto for sh in old_shards)
        num_snaps = self.num_snapshots
        tmp = ShardedSnapshotLog(
            self.num_vertices, assignment.n_shards,
            capacity=self.capacity, assignment=assignment,
        )
        for _ in range(min(watermark, num_snaps)):
            tmp.append_snapshot((), (), ())
        if num_snaps > watermark:
            bs, bd, bw = [], [], []
            for sh in old_shards:
                ids = sh.snapshot_edges(watermark)
                bs.append(sh.src[ids].astype(np.int64))
                bd.append(sh.dst[ids].astype(np.int64))
                bw.append(np.asarray(
                    [sh.weight_at(j, watermark) for j in ids], np.float32
                ))
            tmp.append_snapshot(
                np.concatenate(bs), np.concatenate(bd), np.concatenate(bw)
            )
            for t in range(watermark + 1, num_snaps):
                parts = [sh.delta_batch(t) for sh in old_shards]
                tmp.append_snapshot(*(
                    np.concatenate([p[i] for p in parts]) for i in range(5)
                ))
        for sh in tmp.shards:
            # pre-watermark snapshots were empty placeholders for index
            # alignment; mark them retired so reads fail loudly, like the
            # originals
            for t in range(min(watermark, num_snaps)):
                sh._snapshots[t] = None
            sh._retired_upto = watermark
        self.assignment = assignment
        self.n_shards = assignment.n_shards
        self.v_local = assignment.v_cap
        self.shards = tmp.shards
        self._stack_key = None
        self._stack = {}
        return assignment

    # -- stacked host arrays (the shard_map feed) -----------------------------
    def stacked_arrays(self) -> dict:
        """Per-shard edge arrays stacked to ``(n_shards * capacity,)`` numpy.

        ``src`` keeps global vertex ids (host-side consumers); ``src_pos``
        maps sources into the flat position space (the gather side of the
        SPMD kernels spans shards); ``dst_local`` is the assignment's local
        id in ``[0, v_cap)`` (the scatter side is shard-local).  ``valid``
        marks registered slots.  Re-stacked only when :meth:`state_key`
        changes.
        """
        key = (self.state_key(), self.capacity)
        if self._stack_key != key:
            cap = self.capacity
            n = self.n_shards
            a = self.assignment
            src = np.zeros((n, cap), np.int32)
            srcp = np.zeros((n, cap), np.int32)
            dstl = np.zeros((n, cap), np.int32)
            wmin = np.zeros((n, cap), np.float32)
            wmax = np.zeros((n, cap), np.float32)
            valid = np.zeros((n, cap), bool)
            for s, sh in enumerate(self.shards):
                k = sh.num_edges
                src[s, :k] = sh.src[:k]
                srcp[s, :k] = a.positions[sh.src[:k]]
                dstl[s, :k] = a.local[sh.dst[:k]]
                wmin[s, :k] = sh.weight_min[:k]
                wmax[s, :k] = sh.weight_max[:k]
                valid[s, :k] = True
            self._stack = {
                "src": src.reshape(-1),
                "src_pos": srcp.reshape(-1),
                "dst_local": dstl.reshape(-1),
                "weight_min": wmin.reshape(-1),
                "weight_max": wmax.reshape(-1),
                "valid": valid.reshape(-1),
                "e_cap": cap,
            }
            self._stack_key = key
        return self._stack

    def stack_masks(self, masks: Sequence[np.ndarray]) -> np.ndarray:
        """Stack per-shard ``(shard capacity,)`` masks to one flat array.

        Each shard's mask is padded with ``False`` to the uniform
        :attr:`capacity`, matching the :meth:`stacked_arrays` layout.
        """
        cap = self.capacity
        return np.stack(
            [pad_to(np.asarray(m), cap, False) for m in masks]
        ).reshape(-1)

    def stack_ids(self, per_shard_ids: Sequence[np.ndarray]) -> np.ndarray:
        """Scatter per-shard local-id arrays into one flat stacked bool mask."""
        cap = self.capacity
        mask = np.zeros(self.n_shards * cap, bool)
        for s, ids in enumerate(per_shard_ids):
            if len(ids):
                mask[s * cap + np.asarray(ids, np.int64)] = True
        return mask


class ShardedWindowView:
    """Lockstep sliding windows over a :class:`ShardedSnapshotLog`.

    Mirrors the :class:`~repro.graph.stream.WindowView` API so
    :class:`~repro.core.api.StreamingQuery` front-ends can drive either;
    mask accessors return **per-shard lists** (shard-local, capacity-shaped)
    and ``slide()`` returns a :class:`ShardSlideDiff`.
    """

    def __init__(self, log: ShardedSnapshotLog, size: Optional[int] = None,
                 start: Optional[int] = None):
        self.log = log
        if start is None:
            # lockstep views must agree on the window even if one shard's
            # history happens to be retired further than another's
            start = max(sh.retired_upto for sh in log.shards)
        self.views = [WindowView(sh, size=size, start=start) for sh in log.shards]
        self.history: list[ShardSlideDiff] = []
        self._history_offset = 0

    # -- window geometry (all shards identical) -------------------------------
    @property
    def start(self) -> int:
        return self.views[0].start

    @property
    def size(self) -> int:
        return self.views[0].size

    @property
    def stop(self) -> int:
        return self.views[0].stop

    def snapshots(self) -> range:
        return range(self.start, self.stop)

    # -- slide history --------------------------------------------------------
    @property
    def history_end(self) -> int:
        return self._history_offset + len(self.history)

    def diffs_since(self, pos: int) -> list[ShardSlideDiff]:
        if pos < self._history_offset:
            raise LookupError(
                f"slide history before position {self._history_offset} was "
                f"pruned; consumer at {pos} must re-prime"
            )
        return self.history[pos - self._history_offset:]

    def prune_history(self, upto: int) -> None:
        drop = min(upto, self.history_end) - self._history_offset
        if drop > 0:
            del self.history[:drop]
            self._history_offset += drop
        for v in self.views:
            v.prune_history(upto)  # also retires per-shard log history

    # -- online resharding ----------------------------------------------------
    def reshard(self, assignment: Optional[ShardAssignment] = None, *,
                degree_hist=None) -> ShardAssignment:
        """Migrate the log *and* this view onto a new layout epoch, live.

        With no ``assignment`` a balanced one is derived from the live
        universe histogram (:meth:`ShardedSnapshotLog.live_degree_histogram`,
        or ``degree_hist``).  The per-shard views are rebuilt at the same
        ``(start, size)`` on the re-routed shards — witness counts recompute
        from the new shard-local presence, which is the old presence
        re-routed.  Slide history is cut at the current position (the
        re-routed shards speak new shard-local ids): callers must be caught
        up — a consumer behind ``history_end`` gets the ordinary pruned-
        history ``LookupError`` and re-primes.  Idempotent when the log is
        already on ``assignment`` (so several queries sharing one view can
        each call this with the same target).
        """
        log = self.log
        if assignment is not None and assignment is log.assignment:
            return assignment  # a sibling query already migrated this view
        if assignment is None:
            assignment = log.assignment.rebalance(
                log.live_degree_histogram() if degree_hist is None
                else degree_hist
            )
        size, start = self.size, self.start
        installed = log.reshard(assignment)
        self.views = [
            WindowView(sh, size=size, start=start) for sh in log.shards
        ]
        self._history_offset = self.history_end
        self.history = []
        # the rebuilt per-shard views must stay on the same absolute slide
        # axis as this view: prune_history forwards absolute positions, and
        # a shard view restarting at 0 would over-prune by the cut amount —
        # retiring snapshot ids a post-reshard rollback still replays
        for v in self.views:
            v._history_offset = self._history_offset
        return installed

    # -- sliding --------------------------------------------------------------
    def slide(self) -> ShardSlideDiff:
        diffs = tuple(v.slide() for v in self.views)
        d = ShardSlideDiff(
            shards=diffs, appended=diffs[0].appended, retired=diffs[0].retired
        )
        self.history.append(d)
        return d

    def slide_to_tip(self) -> list[ShardSlideDiff]:
        out = []
        while self.stop < self.log.num_snapshots:
            out.append(self.slide())
        return out

    # -- per-shard masks / weights --------------------------------------------
    @property
    def weight_epoch(self) -> int:
        """Bumped whenever any shard's window weight extrema change."""
        return sum(v.weight_epoch for v in self.views)

    def stacked_weight_extrema(self) -> tuple[np.ndarray, np.ndarray]:
        """Per-shard window-local ``(weight_min, weight_max)`` stacked flat.

        Matches the :meth:`ShardedSnapshotLog.stacked_arrays` layout
        (``(n_shards * capacity,)``, each shard padded to the uniform
        capacity) so the SPMD bounds kernels can consume exact window
        extrema instead of the log's lifetime ones.
        """
        cap = self.log.capacity
        for v in self.views:
            v._sync_capacity()
        wmin = np.stack(
            [pad_to(v.weight_min[: cap], cap, 0.0) for v in self.views]
        ).reshape(-1)
        wmax = np.stack(
            [pad_to(v.weight_max[: cap], cap, 0.0) for v in self.views]
        ).reshape(-1)
        return wmin, wmax

    def union_masks(self) -> list[np.ndarray]:
        return [v.union_mask() for v in self.views]

    def intersection_masks(self) -> list[np.ndarray]:
        return [v.intersection_mask() for v in self.views]

    def snapshot_masks(self, t: int) -> list[np.ndarray]:
        return [v.snapshot_mask(t) for v in self.views]

    def rolling_masks(
        self, diffs: Sequence[ShardSlideDiff]
    ) -> Iterator[tuple[list[np.ndarray], list[np.ndarray]]]:
        """Yield per-slide ``(union masks, intersection masks)`` lists.

        The per-shard :meth:`WindowView.rolling_masks` generators run in
        lockstep, so each yield is one intermediate window's state — exactly
        what a multi-slide catch-up needs (see the single-host docstring).
        """
        gens = [
            v.rolling_masks([d.shards[s] for d in diffs])
            for s, v in enumerate(self.views)
        ]
        for _ in range(len(diffs)):
            step = [next(g) for g in gens]
            yield [u for u, _ in step], [i for _, i in step]

    # -- canonical reference graph -------------------------------------------
    def materialize(self, *, pad_to_capacity: bool = True) -> EvolvingGraph:
        """Canonical (dst-sorted, bit-packed) global graph of the window.

        Concatenates the shard universes back into one edge list and applies
        the same canonical layout as :meth:`WindowView.materialize` — the
        reference substrate the sharded streaming engine must match
        bit-for-bit.
        """
        log = self.log
        for v in self.views:
            v._sync_capacity()
        counts = [sh.num_edges for sh in log.shards]
        src = np.concatenate([sh.src[:k] for sh, k in zip(log.shards, counts)])
        dst = np.concatenate([sh.dst[:k] for sh, k in zip(log.shards, counts)])
        wmin = np.concatenate(
            [v.weight_min[:k] for v, k in zip(self.views, counts)]
        )
        wmax = np.concatenate(
            [v.weight_max[:k] for v, k in zip(self.views, counts)]
        )
        offsets = np.cumsum([0] + counts[:-1])
        n = int(sum(counts))
        order = np.lexsort((src, dst))
        dense = np.zeros((self.size, n), bool)
        for i, t in enumerate(self.snapshots()):
            for s, (sh, off) in enumerate(zip(log.shards, offsets)):
                dense[i, off + sh.snapshot_edges(t)] = True
        packed = pack_presence(dense[:, order])
        cap = (log.capacity * log.n_shards if pad_to_capacity
               else round_up(max(n, 1), PAD_ALIGN))
        return EvolvingGraph(
            src=jnp.asarray(pad_to(src[order].astype(np.int32), cap, 0)),
            dst=jnp.asarray(pad_to(dst[order].astype(np.int32), cap, 0)),
            weight_min=jnp.asarray(pad_to(wmin[order], cap, 0.0)),
            weight_max=jnp.asarray(pad_to(wmax[order], cap, 0.0)),
            presence=jnp.asarray(pad_to(packed, cap, 0, axis=0)),
            num_vertices=log.num_vertices,
            num_snapshots=self.size,
        )

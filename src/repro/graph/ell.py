"""ELL packing with row splitting — the dense layout behind the TPU kernels.

Power-law graphs have wildly skewed in-degrees; a plain ELL layout (one row of
``max_degree`` slots per vertex) would waste nearly all slots.  We use
*row-split ELL*: each vertex's incoming edges are split into rows of at most
``slot_width`` slots; ``row2vertex`` maps packed rows back to their vertex so
a final (cheap, XLA-side) segment-reduce combines split rows.  ``slot_width``
is chosen as a lane multiple (128) so a packed row is one VPU vector row.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.pytree import register_static_dataclass
from repro.utils.padding import round_up


@register_static_dataclass(meta_fields=("num_vertices", "slot_width"))
@dataclasses.dataclass(frozen=True)
class EllPack:
    """Row-split ELL packing of incoming edges.

    Attributes:
      src:    ``(R, D) int32`` source vertex per slot (0 for empty slots).
      weight: ``(R, D) float32`` edge weight per slot.
      slot_valid: ``(R, D) bool``.
      edge_id: ``(R, D) int32`` index into the original edge array (-1 empty);
        lets callers fetch per-edge side data (e.g. presence bitmasks).
      row2vertex: ``(R,) int32`` destination vertex per packed row (padding
        rows point at vertex 0 with all-empty slots).
      num_vertices, slot_width: static.
    """

    src: jax.Array
    weight: jax.Array
    slot_valid: jax.Array
    edge_id: jax.Array
    row2vertex: jax.Array
    num_vertices: int
    slot_width: int

    @property
    def num_rows(self) -> int:
        return int(self.src.shape[0])


def pack_ell(
    src: np.ndarray,
    dst: np.ndarray,
    weight: np.ndarray,
    num_vertices: int,
    *,
    slot_width: int = 128,
    row_align: int = 8,
    min_rows: int = 0,
) -> EllPack:
    """Pack (src→dst, w) incoming edges into row-split ELL (host side).

    ``min_rows`` pads the packed row count up to a caller-chosen floor, so a
    consumer re-packing a churning edge set can hold its array shapes stable
    (see :class:`StableEllPacker`).
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    weight = np.asarray(weight, np.float32)
    e = src.shape[0]
    order = np.argsort(dst, kind="stable")
    s, d, w = src[order], dst[order], weight[order]
    eid = np.arange(e, dtype=np.int64)[order]

    deg = np.bincount(d, minlength=num_vertices)
    rows_per_vertex = np.maximum(1, (deg + slot_width - 1) // slot_width)
    # vertices with zero degree get no row at all
    rows_per_vertex = np.where(deg == 0, 0, rows_per_vertex)
    n_rows = int(rows_per_vertex.sum())
    n_rows_pad = round_up(max(n_rows, min_rows, 1), row_align)

    row2vertex = np.zeros(n_rows_pad, np.int32)
    out_src = np.zeros((n_rows_pad, slot_width), np.int32)
    out_w = np.zeros((n_rows_pad, slot_width), np.float32)
    out_valid = np.zeros((n_rows_pad, slot_width), bool)
    out_eid = np.full((n_rows_pad, slot_width), -1, np.int64)

    # positions of each edge within its destination's run
    starts = np.zeros(num_vertices + 1, np.int64)
    np.cumsum(deg, out=starts[1:])
    pos_in_run = np.arange(e) - starts[d]
    row_base = np.zeros(num_vertices + 1, np.int64)
    np.cumsum(rows_per_vertex, out=row_base[1:])
    row_idx = row_base[d] + pos_in_run // slot_width
    col_idx = pos_in_run % slot_width

    out_src[row_idx, col_idx] = s.astype(np.int32)
    out_w[row_idx, col_idx] = w
    out_valid[row_idx, col_idx] = True
    out_eid[row_idx, col_idx] = eid
    # fill row2vertex for real rows
    v_ids = np.repeat(np.arange(num_vertices, dtype=np.int32), rows_per_vertex)
    row2vertex[: len(v_ids)] = v_ids

    return EllPack(
        src=jnp.asarray(out_src),
        weight=jnp.asarray(out_w),
        slot_valid=jnp.asarray(out_valid),
        edge_id=jnp.asarray(out_eid.astype(np.int32)),
        row2vertex=jnp.asarray(row2vertex),
        num_vertices=int(num_vertices),
        slot_width=int(slot_width),
    )


class StableEllPacker:
    """Re-pack a churning edge set into ELL at sticky row capacity.

    Per-slide ``pack_ell`` calls on a streaming edge set can change the
    packed row count every slide, retriggering XLA compilation of every
    consumer whose shapes include it.  This helper keeps the row count at an
    **amortized-doubling capacity** (the same policy the streaming substrate
    uses for flat edge arrays): packs reuse the previous row capacity while
    the edges fit, and growth jumps past the immediate need so at most
    O(log rows) distinct shapes — hence compilations — occur over a stream's
    lifetime.

    Pack identity doubles as a cache epoch for derived device state: any
    repack (same capacity or grown) may permute slot→edge assignments, so
    consumers holding per-slot planes — e.g. the incremental presence words
    of ``repro.kernels.vrelax.ops.EllPresenceCache`` — must key on the pack
    (``ell_epoch`` / the sharded pack key) and rebuild rather than scatter
    when it changes.
    """

    def __init__(self, num_vertices: int, *, slot_width: int = 128,
                 row_align: int = 8):
        self.num_vertices = int(num_vertices)
        self.slot_width = int(slot_width)
        self.row_align = int(row_align)
        self.num_rows = 0  # current sticky row capacity (0 = unset)
        # every sticky capacity class this packer has entered, in order —
        # the data-dependent growth ladder enumerate_grid cannot predict;
        # checkpointed into grid.json so a first-boot replica pre-traces
        # the classes a prior run actually walked (see serving.warmstart)
        self.class_history: list[int] = []

    def _natural_rows(self, dst) -> int:
        """Row count the edge set needs, from the dst degree histogram
        alone (much cheaper than a probe pack)."""
        deg = np.bincount(
            np.asarray(dst, np.int64), minlength=self.num_vertices
        )
        rows = np.maximum(1, (deg + self.slot_width - 1) // self.slot_width)
        return int(np.where(deg == 0, 0, rows).sum())

    def pack(self, src, dst, weight, *, min_rows: int = 0) -> EllPack:
        """``pack_ell`` at the sticky row capacity, growing it if needed.

        ``min_rows`` raises the capacity floor for this and all later packs
        — a group of packers that must agree on shapes (e.g. the per-shard
        ELL planes stacked under ``shard_map`` in
        :class:`repro.distributed.stream_shard._ShardedEllCache`) passes the
        group-wide capacity here so every member packs identical row counts.
        """
        from repro.obs.metrics import get_registry

        reg = get_registry()
        need = max(self._natural_rows(dst), int(min_rows))
        if need > self.num_rows:
            # growth: double past the immediate need, then pack exactly once
            floor = max(need, 2 * self.num_rows) if self.num_rows else need
            self.num_rows = round_up(floor, self.row_align)
            # a capacity-class transition recompiles every ELL consumer —
            # the signal the AOT grid / warm-start work keys on
            reg.counter(
                "ell_class_transitions_total",
                "sticky ELL row-capacity growth events (recompile class)",
            ).inc()
            reg.gauge(
                "ell_row_capacity", "current sticky ELL row capacity"
            ).set(self.num_rows)
        reg.counter(
            "ell_repacks_total", "StableEllPacker pack_ell invocations"
        ).inc()
        ell = pack_ell(
            src, dst, weight, self.num_vertices,
            slot_width=self.slot_width, row_align=self.row_align,
            min_rows=self.num_rows,
        )
        self.num_rows = ell.num_rows
        if not self.class_history or self.class_history[-1] != self.num_rows:
            self.class_history.append(self.num_rows)
        return ell

"""Per-(arch × shape × mesh) cell builders for the dry-run and launchers.

``build_cell`` returns the jit-able step function, abstract (ShapeDtypeStruct)
inputs, matching in_shardings, and analytic MODEL_FLOPS — everything
``launch/dryrun.py`` needs to ``lower().compile()`` a cell without touching
device memory, and everything ``roofline`` needs to score it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ArchSpec
from repro.distributed.partitioning import sharding_for
from repro.models.params import abstract_params, param_shardings
from repro.optim.adamw import AdamWConfig, opt_state_defs
from repro.utils.padding import round_up

# per-arch gradient-accumulation (activation-memory control at full scale)
ACCUM_STEPS = {
    "deepseek-v2-236b": 32,
    "llama3-8b": 8,
    "qwen2-moe-a2.7b": 4,
    "stablelm-1.6b": 4,
    "gemma-2b": 4,
}

OPT = AdamWConfig()
# 236B on 16 GiB chips: factored second moment + bf16 momentum + bf16
# gradient accumulator (see EXPERIMENTS.md §Dry-run memory notes).
ARCH_OPT = {
    "deepseek-v2-236b": AdamWConfig(factored=True, momentum_dtype="bfloat16"),
}
ACCUM_DTYPE = {"deepseek-v2-236b": "bfloat16"}
# §Perf iteration B1: bf16 weight gathers (see training/steps.py).  Off by
# default so the paper-faithful fp32-gather baseline stays reproducible;
# REPRO_BF16_GATHER=1 enables it for the hillclimb measurement.
import os as _os
BF16_GATHER = bool(int(_os.environ.get("REPRO_BF16_GATHER", "0")))
# §Perf iteration B2: group-local MoE dispatch (default ON — beyond-paper
# optimized path; REPRO_MOE_GROUPED=0 restores the global-sort baseline).
MOE_GROUPED = bool(int(_os.environ.get("REPRO_MOE_GROUPED", "1")))
# §Perf iteration B4: remat policy "dots" saves matmul outputs (less bwd
# recompute, more activation memory). Off by default pending memory check.
REMAT_POLICY = _os.environ.get("REPRO_REMAT_POLICY", "full")
# §Perf iteration C1: edge-parallel GNN regime — replicate the node state,
# shard only edges.  Gathers h[src] become chip-local; the per-layer
# aggregate costs ONE (N, d) all-reduce instead of per-edge cross-chip
# traffic.  Applied to pna/gatedgcn full-graph cells where the replicated
# node state fits (N × d_hidden × 4B < 1.5 GiB/chip).
GNN_EDGE_PARALLEL = bool(int(_os.environ.get("REPRO_GNN_EDGE_PARALLEL", "0")))
# §Perf iteration C3: bf16 node/message state for big graphs — halves the
# all-gather/all-reduce wire bytes that dominate full-graph GNN training.
GNN_BF16 = bool(int(_os.environ.get("REPRO_GNN_BF16", "0")))
# §Perf iteration A: folded-CQRS evolving cells (active-subgraph sizes)
EVOLVE_FOLDED = bool(int(_os.environ.get("REPRO_EVOLVE_FOLDED", "0")))


def opt_for(arch_id: str) -> AdamWConfig:
    return ARCH_OPT.get(arch_id, OPT)


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    fn: Callable
    args: tuple
    in_shardings: tuple
    model_flops: Optional[float] = None
    description: str = ""
    # product of enclosing scan trip counts for the dominant compute body —
    # XLA cost_analysis counts while/scan bodies ONCE (verified; see
    # EXPERIMENTS.md §Roofline methodology), so raw numbers are multiplied
    # by this to estimate whole-step costs.
    scan_factor: float = 1.0
    # collectives often sit at a different loop level than the compute body
    # (XLA hoists FSDP all-gathers out of the layer scan, so they run once
    # per MICROBATCH, not per layer) — separate correction factor.
    coll_scan_factor: Optional[float] = None
    # analytic per-chip HBM traffic estimate (bytes); set where the scan
    # correction would mis-scale once-per-step segments (LM optimizer etc.)
    analytic_bytes: Optional[float] = None
    static_kwargs: dict = dataclasses.field(default_factory=dict)


def _sds(shape, dtype, mesh, logical):
    return jax.ShapeDtypeStruct(shape, dtype), sharding_for(logical, mesh, shape=shape)


def _abstract_and_shard(defs, mesh):
    return abstract_params(defs), param_shardings(defs, mesh)


# ===========================================================================
# LM cells
# ===========================================================================
def _lm_model_flops(cfg, tokens: int, *, train: bool) -> float:
    n_active = cfg.active_param_count()
    mult = 6.0 if train else 2.0
    return mult * n_active * tokens


def _batch_shards(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in ("pod", "data") if a in mesh.axis_names]))


def _lm_train_bytes(cfg, defs, opt_defs, mesh, b, s, accum) -> float:
    """Per-chip HBM bytes per train step (documented in EXPERIMENTS.md):
    params re-read per microbatch (fwd+bwd) + optimizer read/write +
    activations ~12 passes per layer per microbatch token + logits."""
    from repro.models.params import param_bytes

    chips = mesh.devices.size
    pb = param_bytes(defs) / chips
    ob = param_bytes(opt_defs) / chips
    tokens_chip = b * s / _batch_shards(mesh)
    acts = tokens_chip * cfg.d_model * 4 * cfg.num_layers * 12
    model_size = mesh.shape.get("model", 1)
    logits = accum * (tokens_chip / accum) * cfg.vocab_size * 4 / model_size * 3
    return 2 * accum * pb + pb + 2 * ob + acts + logits


def _lm_infer_bytes(cfg, defs, mesh, tokens_chip, cache_bytes_chip=0.0) -> float:
    from repro.models.params import param_bytes

    chips = mesh.devices.size
    pb = param_bytes(defs) / chips
    acts = tokens_chip * cfg.d_model * 4 * cfg.num_layers * 8
    return pb + acts + 2 * cache_bytes_chip


def _lm_train_cell(spec, shape, mesh, cfg) -> Cell:
    from repro.models.transformer import transformer_defs
    from repro.training.steps import build_lm_train_step

    opt_cfg = opt_for(spec.arch_id)
    if cfg.moe and MOE_GROUPED:
        # §Perf B2: group-local MoE dispatch — one group per data shard
        cfg = dataclasses.replace(cfg, moe_groups=_batch_shards(mesh))
    if REMAT_POLICY != "full":
        cfg = dataclasses.replace(cfg, remat_policy=REMAT_POLICY)
    defs = transformer_defs(cfg)
    params, pshard = _abstract_and_shard(defs, mesh)
    opt, oshard = _abstract_and_shard(opt_state_defs(defs, opt_cfg), mesh)
    b, s = shape["batch"], shape["seq"]
    tok, tok_sh = _sds((b, s), jnp.int32, mesh, ("batch", "seq"))
    batch = {"tokens": tok, "targets": tok}
    bshard = {"tokens": tok_sh, "targets": tok_sh}
    accum = ACCUM_STEPS.get(spec.arch_id, 1)
    fn = build_lm_train_step(
        cfg, opt_cfg, accum_steps=accum,
        accum_dtype=ACCUM_DTYPE.get(spec.arch_id),
        cast_params_once=BF16_GATHER,
    )
    odefs = opt_state_defs(defs, opt_cfg)
    return Cell(
        arch_id=spec.arch_id, shape_name="", fn=fn,
        args=(params, opt, batch), in_shardings=(pshard, oshard, bshard),
        model_flops=_lm_model_flops(cfg, b * s, train=True),
        description=f"train_step accum={accum}",
        scan_factor=float(cfg.num_layers * accum),
        coll_scan_factor=float(accum),  # FSDP gathers hoisted per microbatch
        analytic_bytes=_lm_train_bytes(cfg, defs, odefs, mesh, b, s, accum),
    )


def _lm_prefill_cell(spec, shape, mesh, cfg) -> Cell:
    from repro.models.transformer import transformer_defs
    from repro.serving.steps import build_prefill_step

    defs = transformer_defs(cfg)
    params, pshard = _abstract_and_shard(defs, mesh)
    b, s = shape["batch"], shape["seq"]
    tok, tok_sh = _sds((b, s), jnp.int32, mesh, ("batch", "seq"))
    fn = build_prefill_step(cfg)
    return Cell(
        arch_id=spec.arch_id, shape_name="", fn=fn,
        args=(params, tok), in_shardings=(pshard, tok_sh),
        model_flops=_lm_model_flops(cfg, b * s, train=False),
        description="prefill_step",
        scan_factor=float(cfg.num_layers),
        coll_scan_factor=1.0,  # weight gathers hoisted out of the layer scan
        analytic_bytes=_lm_infer_bytes(cfg, defs, mesh, b * s / _batch_shards(mesh)),
    )


def _lm_decode_cell(spec, shape, mesh, cfg) -> Cell:
    from repro.models.transformer import cache_defs, transformer_defs
    from repro.serving.steps import build_decode_step

    defs = transformer_defs(cfg)
    params, pshard = _abstract_and_shard(defs, mesh)
    b, cache_len = shape["batch"], shape["cache_len"]
    big = shape.get("big_seq", False)
    cdefs = cache_defs(cfg, b, cache_len, big_seq=big)
    cache, cshard = _abstract_and_shard(cdefs, mesh)
    tok, tok_sh = _sds((b,), jnp.int32, mesh, ("batch",))
    idx = jax.ShapeDtypeStruct((), jnp.int32)
    idx_sh = NamedSharding(mesh, P())
    fn = build_decode_step(cfg)
    from repro.models.params import param_bytes as _pb

    cache_chip = _pb(cdefs) / mesh.devices.size
    return Cell(
        arch_id=spec.arch_id, shape_name="", fn=fn,
        args=(params, tok, cache, idx),
        in_shardings=(pshard, tok_sh, cshard, idx_sh),
        model_flops=_lm_model_flops(cfg, b, train=False),
        description=f"decode_step cache={cache_len}",
        scan_factor=float(cfg.num_layers),
        coll_scan_factor=1.0,
        analytic_bytes=_lm_infer_bytes(cfg, defs, mesh, float(b), cache_chip),
    )


# ===========================================================================
# GNN cells
# ===========================================================================
def _gnn_batch_specs(cfg, mesh, n, e, d_feat, *, with_triplets, triplet_cap,
                     edge_chunk, replicate_nodes=False):
    # vertex/edge spaces take the whole mesh — pad so every axis divides
    n = round_up(n, 512)
    e_pad = round_up(e, max(512, edge_chunk or 512))
    batch, bshard = {}, {}
    vax = None if replicate_nodes else "vertices"

    def add(name, shape, dtype, logical):
        logical = tuple(vax if a == "vertices" else a for a in logical)
        batch[name], bshard[name] = _sds(shape, dtype, mesh, logical)

    add("node_feat", (n, d_feat), jnp.float32, ("vertices", None))
    add("edge_src", (e_pad,), jnp.int32, ("edges",))
    add("edge_dst", (e_pad,), jnp.int32, ("edges",))
    add("edge_valid", (e_pad,), jnp.bool_, ("edges",))
    add("labels", (n,), jnp.int32, ("vertices",))
    add("label_mask", (n,), jnp.float32, ("vertices",))
    if cfg.arch == "gatedgcn":
        add("edge_feat", (e_pad, cfg.d_edge_feat), jnp.float32, ("edges", None))
    if cfg.arch in ("dimenet", "equiformer_v2"):
        add("pos", (n, 3), jnp.float32, ("vertices", None))
    if cfg.arch == "dimenet":
        add("atom_type", (n,), jnp.int32, ("vertices",))
        add("graph_id", (n,), jnp.int32, ("vertices",))
        add("energy", (1,), jnp.float32, (None,))
        t = round_up(e_pad * triplet_cap, max(512, cfg.triplet_chunk or 512))
        add("triplet_kj", (t,), jnp.int32, ("edges",))
        add("triplet_ji", (t,), jnp.int32, ("edges",))
        add("triplet_valid", (t,), jnp.bool_, ("edges",))
    return batch, bshard, e_pad


def _gnn_full_cell(spec, shape, mesh, cfg) -> Cell:
    from repro.models.gnn.dimenet import dimenet_defs
    from repro.models.gnn.equiformer_v2 import equiformer_defs
    from repro.models.gnn.gatedgcn import gatedgcn_defs
    from repro.models.gnn.pna import pna_defs
    from repro.training.steps import build_gnn_train_step

    n, e = shape["n_nodes"], shape["n_edges"]
    # big-graph memory control: chunk eSCN edges / DimeNet triplets
    edge_chunk = 0
    triplet_chunk = 0
    triplet_cap = 4
    if cfg.arch == "equiformer_v2" and e > 10_000_000:
        edge_chunk = 131_072
    if e > 10_000_000:
        triplet_cap = 2
        triplet_chunk = 1_048_576
    cfg = dataclasses.replace(
        cfg, d_feat=shape["d_feat"], num_classes=shape["num_classes"],
        edge_chunk=edge_chunk, triplet_chunk=triplet_chunk,
        dtype="bfloat16" if (GNN_BF16 and e > 10_000_000) else cfg.dtype,
    )
    defs = {
        "pna": pna_defs, "gatedgcn": gatedgcn_defs, "dimenet": dimenet_defs,
        "equiformer_v2": equiformer_defs,
    }[cfg.arch](cfg)
    params, pshard = _abstract_and_shard(defs, mesh)
    opt, oshard = _abstract_and_shard(opt_state_defs(defs), mesh)
    replicate_nodes = (
        GNN_EDGE_PARALLEL
        and cfg.arch in ("pna", "gatedgcn")
        and n * cfg.d_hidden * 4 < 1.5 * 2**30
    )
    if replicate_nodes:
        cfg = dataclasses.replace(cfg, edge_parallel=True)
    batch, bshard, e_pad = _gnn_batch_specs(
        cfg, mesh, n, e, shape["d_feat"],
        with_triplets=cfg.arch == "dimenet", triplet_cap=triplet_cap,
        edge_chunk=edge_chunk, replicate_nodes=replicate_nodes,
    )
    fn = build_gnn_train_step(cfg, OPT, num_graphs=1)
    # message-passing "model flops": edges × per-edge MACs (arch-dependent)
    per_edge = {
        "pna": 2 * 2 * cfg.d_hidden * cfg.d_hidden + 13 * cfg.d_hidden * cfg.d_hidden * 2,
        "gatedgcn": 2 * 5 * cfg.d_hidden * cfg.d_hidden,
        "dimenet": 2 * (3 * cfg.d_hidden**2) + triplet_cap * 2 * cfg.n_bilinear * cfg.d_hidden**2,
        "equiformer_v2": 2 * (cfg.m_max * 2 + 1) * ((cfg.l_max + 1) * cfg.d_hidden) ** 2,
    }[cfg.arch]
    mf = 3.0 * cfg.num_layers * e * per_edge  # fwd+bwd
    sf = 1.0
    if cfg.arch == "equiformer_v2" and edge_chunk:
        sf = float(e_pad // edge_chunk)
    elif cfg.arch == "dimenet" and triplet_chunk:
        t_pad = round_up(e_pad * triplet_cap, max(512, triplet_chunk))
        sf = float(t_pad // triplet_chunk)
    return Cell(
        arch_id=spec.arch_id, shape_name="", fn=fn,
        args=(params, opt, batch), in_shardings=(pshard, oshard, bshard),
        model_flops=mf,
        description=(f"gnn_train n={n} e={e_pad} chunk={edge_chunk}"
                     f"{' edge-parallel' if replicate_nodes else ''}"),
        scan_factor=sf,
    )


def _gnn_minibatch_cell(spec, shape, mesh, cfg) -> Cell:
    """Sampled-training cell. pna/gatedgcn/equiformer run the in-jit
    fixed-fanout sampler from CSR inputs; dimenet (triplet lists are host
    built) takes pre-sampled block arrays."""
    from repro.training.steps import build_gnn_train_step

    n_seed = shape["batch_nodes"]
    fanout = shape["fanout"]
    n_all, e_all = shape["n_nodes"], shape["n_edges"]
    d_feat, n_cls = shape["d_feat"], shape["num_classes"]
    # sampled-subgraph sizes (fixed fanout ⇒ static)
    n_sub, e_sub, cur = n_seed, 0, n_seed
    for f in fanout:
        e_sub += cur * f
        cur *= f
        n_sub += cur
    cfg = dataclasses.replace(cfg, d_feat=d_feat, num_classes=n_cls)

    if cfg.arch == "dimenet":
        shape2 = dict(shape, kind="gnn_full", n_nodes=n_sub, n_edges=e_sub)
        cell = _gnn_full_cell(spec, shape2, mesh, cfg)
        cell.description = f"gnn_minibatch(presampled) n={n_sub} e={e_sub}"
        return cell

    from repro.models.gnn.equiformer_v2 import equiformer_defs
    from repro.models.gnn.gatedgcn import gatedgcn_defs
    from repro.models.gnn.pna import pna_defs

    defs = {
        "pna": pna_defs, "gatedgcn": gatedgcn_defs,
        "equiformer_v2": equiformer_defs,
    }[cfg.arch](cfg)
    params, pshard = _abstract_and_shard(defs, mesh)
    opt, oshard = _abstract_and_shard(opt_state_defs(defs), mesh)

    inputs, ishard = {}, {}

    def add(name, shp, dtype, logical):
        inputs[name], ishard[name] = _sds(shp, dtype, mesh, logical)

    n_all_pad = round_up(n_all, 512)
    e_all_pad = round_up(e_all, 512)
    add("indptr", (n_all + 1,), jnp.int32, (None,))
    add("indices", (e_all_pad,), jnp.int32, ("edges",))
    add("features", (n_all_pad, d_feat), jnp.float32, ("vertices", None))
    add("labels_all", (n_all_pad,), jnp.int32, ("vertices",))
    add("seeds", (n_seed,), jnp.int32, (None,))
    add("seed", (), jnp.int32, ())
    if cfg.arch == "equiformer_v2":
        add("pos_all", (n_all_pad, 3), jnp.float32, ("vertices", None))

    base_step = build_gnn_train_step(cfg, OPT)
    arch = cfg.arch

    def step(params, opt_state, inputs):
        from repro.data.graphs import sampled_block_batch
        from repro.graph.sampler import NeighborSampler
        from repro.graph.structures import CSR

        csr = CSR(
            indptr=inputs["indptr"], indices=inputs["indices"],
            weights=jnp.ones_like(inputs["indices"], jnp.float32),
            num_vertices=n_all,
        )
        sampler = NeighborSampler(csr, fanout)
        rng = jax.random.PRNGKey(inputs["seed"])
        blocks = sampler.sample(rng, inputs["seeds"])
        batch = sampled_block_batch(blocks, inputs["features"], inputs["labels_all"])
        batch["label_mask"] = (
            jnp.arange(batch["node_feat"].shape[0]) < batch.pop("num_seeds")
        ).astype(jnp.float32)
        if arch == "equiformer_v2":
            batch["pos"] = inputs["pos_all"][batch["node_ids"]]
        if arch == "gatedgcn":
            batch["edge_feat"] = jnp.ones(
                (batch["edge_src"].shape[0], cfg.d_edge_feat), jnp.float32
            )
        batch.pop("node_ids")
        return base_step(params, opt_state, batch)

    per_edge = 2 * 5 * cfg.d_hidden * cfg.d_hidden
    return Cell(
        arch_id=spec.arch_id, shape_name="", fn=step,
        args=(params, opt, inputs), in_shardings=(pshard, oshard, ishard),
        model_flops=3.0 * cfg.num_layers * e_sub * per_edge,
        description=f"gnn_minibatch sampler fanout={fanout} n_sub={n_sub}",
    )


def _gnn_molecule_cell(spec, shape, mesh, cfg) -> Cell:
    n = shape["batch"] * shape["n_nodes"]
    e = shape["batch"] * shape["n_edges"]
    shape2 = dict(shape, kind="gnn_full", n_nodes=n, n_edges=e,
                  d_feat=shape["d_feat"], num_classes=shape["num_classes"])
    cfg2 = dataclasses.replace(cfg, d_feat=shape["d_feat"],
                               num_classes=shape["num_classes"])
    from repro.training.steps import build_gnn_train_step

    cell = _gnn_full_cell(spec, shape2, mesh, cfg2)
    if cfg.arch == "dimenet":
        # per-graph energies for the batched molecules
        from repro.models.gnn.dimenet import dimenet_defs

        b = shape["batch"]
        cell.args[2]["energy"] = jax.ShapeDtypeStruct((b,), jnp.float32)
        cell.in_shardings[2]["energy"] = sharding_for(("batch",), mesh, shape=(b,))
        cell.fn = build_gnn_train_step(cfg2, OPT, num_graphs=b)
    cell.description = f"gnn_molecule batch={shape['batch']}"
    return cell


# ===========================================================================
# recsys cells
# ===========================================================================
def _dlrm_flops(cfg, batch: int, *, train: bool) -> float:
    mlp = 0
    dims = cfg.bot_mlp
    for i in range(len(dims) - 1):
        mlp += 2 * dims[i] * dims[i + 1]
    tdims = (cfg.n_interactions + cfg.embed_dim,) + cfg.top_mlp
    for i in range(len(tdims) - 1):
        mlp += 2 * tdims[i] * tdims[i + 1]
    inter = 2 * (cfg.n_sparse + 1) ** 2 * cfg.embed_dim
    per_ex = mlp + inter
    return batch * per_ex * (3.0 if train else 1.0)


def _recsys_cell(spec, shape, mesh, cfg) -> Cell:
    from repro.models.dlrm import dlrm_defs, dlrm_forward, dlrm_retrieval_scores
    from repro.training.steps import build_dlrm_train_step

    defs = dlrm_defs(cfg)
    params, pshard = _abstract_and_shard(defs, mesh)
    kind = shape["kind"]

    if kind == "recsys_train":
        opt, oshard = _abstract_and_shard(opt_state_defs(defs), mesh)
        b = shape["batch"]
        batch, bshard = {}, {}
        for name, shp, dt, lg in (
            ("dense", (b, cfg.n_dense), jnp.float32, ("batch", None)),
            ("sparse", (b, cfg.n_sparse), jnp.int32, ("batch", None)),
            ("labels", (b,), jnp.float32, ("batch",)),
        ):
            batch[name], bshard[name] = _sds(shp, dt, mesh, lg)
        fn = build_dlrm_train_step(cfg, OPT, mesh)
        return Cell(
            arch_id=spec.arch_id, shape_name="", fn=fn,
            args=(params, opt, batch), in_shardings=(pshard, oshard, bshard),
            model_flops=_dlrm_flops(cfg, b, train=True),
            description=f"dlrm_train b={b}",
        )

    if kind == "recsys_serve":
        b = shape["batch"]
        batch, bshard = {}, {}
        for name, shp, dt, lg in (
            ("dense", (b, cfg.n_dense), jnp.float32, ("batch", None)),
            ("sparse", (b, cfg.n_sparse), jnp.int32, ("batch", None)),
        ):
            batch[name], bshard[name] = _sds(shp, dt, mesh, lg)
        fn = lambda p, bb: dlrm_forward(cfg, p, bb, mesh)
        return Cell(
            arch_id=spec.arch_id, shape_name="", fn=fn,
            args=(params, batch), in_shardings=(pshard, bshard),
            model_flops=_dlrm_flops(cfg, b, train=False),
            description=f"dlrm_serve b={b}",
        )

    # retrieval: 1 query vs n_candidates
    nc = shape["n_candidates"]
    batch, bshard = {}, {}
    batch["dense"], bshard["dense"] = _sds((1, cfg.n_dense), jnp.float32, mesh, (None, None))
    batch["cand_ids"], bshard["cand_ids"] = _sds((nc,), jnp.int32, mesh, ("edges",))
    fn = lambda p, bb: dlrm_retrieval_scores(cfg, p, bb, mesh, top_k=100)
    return Cell(
        arch_id=spec.arch_id, shape_name="", fn=fn,
        args=(params, batch), in_shardings=(pshard, bshard),
        model_flops=2.0 * nc * cfg.embed_dim,
        description=f"dlrm_retrieval nc={nc}",
    )


# ===========================================================================
# evolving-graph cells (the paper's workload)
# ===========================================================================
def _evolving_cell(spec, shape, mesh, cfg) -> Cell:
    from repro.core.semiring import get_semiring
    from repro.distributed.evolve import distributed_concurrent_fixpoint

    sr = get_semiring(cfg.query)
    v, e, s = shape["n_vertices"], shape["n_edges"], shape["n_snapshots"]
    if EVOLVE_FOLDED:
        # §Perf A1/A3: UVV source-folding — iterate only the active↔active
        # subgraph.  Sizes use the paper's own worst-case reductions (42% of
        # vertices / 32% of edges, Fig. 9); our measured CPU-scale stats are
        # smaller still (21.5% / 18.9%).
        v = round_up(int(v * 0.42), 512 * 16)
        e = int(e * 0.32)
    model_shards = int(mesh.shape["model"])
    snap_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    e_local = round_up(-(-e // model_shards), 128)
    e_total = e_local * model_shards
    w = (s + 31) // 32
    fixed_iters = 8  # dry-run superstep count (cost scales linearly)

    def fn(bootstrap, src, dst_local, weight, presence, valid):
        sharded = {
            "src": src, "dst_local": dst_local, "weight": weight,
            "presence": presence, "valid": valid,
            "v_local": v // model_shards, "e_local": e_local,
        }
        return distributed_concurrent_fixpoint(
            bootstrap, sharded, sr, v, s, mesh,
            fixed_iters=fixed_iters, snap_axes=snap_axes,
        )

    def sd(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    ns = NamedSharding
    args = (
        sd((v,), jnp.float32), sd((e_total,), jnp.int32), sd((e_total,), jnp.int32),
        sd((e_total,), jnp.float32), sd((e_total, w), jnp.uint32), sd((e_total,), jnp.bool_),
    )
    shardings = (
        ns(mesh, P("model")), ns(mesh, P("model")), ns(mesh, P("model")),
        ns(mesh, P("model")), ns(mesh, P("model", None)), ns(mesh, P("model")),
    )
    # model "flops": S × E edge relaxations × ~4 flop-equivalents × iters
    mf = float(fixed_iters) * s * e * 4.0
    return Cell(
        arch_id=spec.arch_id, shape_name="", fn=fn,
        args=args, in_shardings=shardings, model_flops=mf,
        description=f"cqrs_superstep x{fixed_iters} V={v} E={e} S={s}",
        scan_factor=float(fixed_iters),
    )


# ===========================================================================
# dispatcher
# ===========================================================================
def build_cell(spec: ArchSpec, shape_name: str, mesh: Mesh, *, smoke=False) -> Cell:
    shape = spec.shapes[shape_name]
    cfg = spec.smoke_config if smoke else spec.config
    kind = shape["kind"]
    builders = {
        "train": _lm_train_cell,
        "prefill": _lm_prefill_cell,
        "decode": _lm_decode_cell,
        "gnn_full": _gnn_full_cell,
        "gnn_minibatch": _gnn_minibatch_cell,
        "gnn_molecule": _gnn_molecule_cell,
        "recsys_train": _recsys_cell,
        "recsys_serve": _recsys_cell,
        "recsys_retrieval": _recsys_cell,
        "evolving": _evolving_cell,
    }
    cell = builders[kind](spec, shape, mesh, cfg)
    cell.shape_name = shape_name
    return cell

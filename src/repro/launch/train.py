"""Production train launcher: ``--arch`` selects any registered architecture.

On this CPU container it runs the *smoke* config end-to-end (real data
pipeline, optimizer, checkpoint/restart); on a TPU pod the same launcher
binds the full config to the production mesh via launch.specs.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch, list_archs
from repro.ft.recovery import TrainSupervisor
from repro.models.params import init_params
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.data.synthetic import TokenPipeline


def _lm_runner(spec, args):
    from repro.models.transformer import transformer_defs
    from repro.training.steps import build_lm_train_step

    cfg = spec.smoke_config
    defs = transformer_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=10, total_steps=args.steps)
    opt = adamw_init(params)
    step = jax.jit(build_lm_train_step(cfg, opt_cfg))
    pipe = TokenPipeline(batch=args.batch, seq=args.seq, vocab=cfg.vocab_size)

    def one(state, i):
        p, o, ps = state
        pipe.restore(ps)
        p, o, m = step(p, o, pipe.next())
        if i % 10 == 0:
            print(f"step {i} loss {float(m['loss']):.4f}")
        return (p, o, pipe.state())

    return (params, opt, pipe.state()), one


def _gnn_runner(spec, args):
    import dataclasses

    from repro.data.graphs import molecule_batch, random_graph_batch
    from repro.models.gnn.dimenet import dimenet_defs
    from repro.models.gnn.equiformer_v2 import equiformer_defs
    from repro.models.gnn.gatedgcn import gatedgcn_defs
    from repro.models.gnn.pna import pna_defs
    from repro.training.steps import build_gnn_train_step

    cfg = spec.smoke_config
    if cfg.arch == "dimenet":
        batch = molecule_batch(4, 8, 16, seed=0)
        batch.pop("num_graphs")
        ng = 4
    else:
        batch = random_graph_batch(128, 512, cfg.d_feat, cfg.num_classes, seed=0)
        ng = 1
    defs = {"pna": pna_defs, "gatedgcn": gatedgcn_defs, "dimenet": dimenet_defs,
            "equiformer_v2": equiformer_defs}[cfg.arch](cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=5, total_steps=args.steps)
    opt = adamw_init(params)
    step = jax.jit(build_gnn_train_step(cfg, opt_cfg, num_graphs=ng))

    def one(state, i):
        p, o = state
        p, o, m = step(p, o, batch)
        if i % 10 == 0:
            print(f"step {i} loss {float(m['loss']):.4f}")
        return (p, o)

    return (params, opt), one


def _recsys_runner(spec, args):
    from repro.data.recsys import recsys_batch
    from repro.models.dlrm import dlrm_defs
    from repro.training.steps import build_dlrm_train_step

    cfg = spec.smoke_config
    defs = dlrm_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=5, total_steps=args.steps)
    opt = adamw_init(params)
    step = jax.jit(build_dlrm_train_step(cfg, opt_cfg))

    def one(state, i):
        p, o = state
        batch = recsys_batch(cfg, args.batch, seed=i)
        p, o, m = step(p, o, batch)
        if i % 10 == 0:
            print(f"step {i} loss {float(m['loss']):.4f}")
        return (p, o)

    return (params, opt), one


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    runner = {
        "lm": _lm_runner, "gnn": _gnn_runner, "recsys": _recsys_runner,
    }.get(spec.family)
    if runner is None:
        raise SystemExit(
            f"{args.arch} ({spec.family}) is driven by launch.evolve, not train"
        )
    state, one = runner(spec, args)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    sup = TrainSupervisor(mgr, ckpt_every=max(10, args.steps // 3))
    t0 = time.time()
    state, stats = sup.run(state, one, args.steps)
    print(f"trained {args.arch} smoke config: {args.steps} steps "
          f"in {time.time()-t0:.1f}s, {stats}")


if __name__ == "__main__":
    main()

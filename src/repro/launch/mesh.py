"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets the 512-device XLA flag before
any jax initialization, and tests build small meshes of their own.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_debug_mesh(*, multi_pod: bool = False):
    """Shrunk mesh (8 host devices) with the same axis names, for CI."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )

"""Serving launcher: LM decode smoke OR a live streaming-graph replica.

    # batched decode with the request scheduler (smoke config)
    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --requests 8

    # streaming-graph serving replica with live telemetry:
    # watch Q sources over a sliding window of an RMAT delta stream, serve
    # every slide through the pipelined QueryBatcher, and expose the metrics
    # registry on a Prometheus /metrics scrape endpoint
    PYTHONPATH=src python -m repro.launch.serve --mode stream \
        --watchers 8 --slides 16 --prom-port 9464 --metrics-jsonl slides.jsonl

Imports are gated per mode so the stream replica never pulls the LM stack
(and vice versa).
"""
from __future__ import annotations

import argparse


def run_decode(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.models.params import init_params
    from repro.models.transformer import cache_defs, decode_step, transformer_defs
    from repro.serving.scheduler import Request, RequestScheduler

    cfg = get_arch(args.arch).smoke_config
    defs = transformer_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    cache = init_params(cache_defs(cfg, args.batch, args.max_len), jax.random.PRNGKey(1))
    state = {"cache": cache}

    @jax.jit
    def decode_at(params, cache, tokens, position):
        logits, new_cache = decode_step(cfg, params, tokens, cache, position)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache

    def decode_token(tokens, positions, mask):
        nxt, state["cache"] = decode_at(params, state["cache"], tokens, positions[0])
        return nxt

    sched = RequestScheduler(batch_size=args.batch, eos_id=0, max_len=args.max_len)
    for uid in range(args.requests):
        prompt = [1 + (uid * 3 + k) % (cfg.vocab_size - 1) for k in range(4)]
        sched.submit(Request(uid=uid, prompt=prompt, max_new_tokens=6))
    done = sched.run(decode_token, max_steps=300)
    for r in sorted(done, key=lambda r: r.uid)[:4]:
        print(f"req {r.uid}: {r.prompt} → {r.generated}")
    print(f"served {len(done)}/{args.requests} requests with {args.arch} smoke config")


def run_stream(args) -> None:
    """Streaming-graph serving replica with the full telemetry stack on.

    One pipelined :class:`~repro.serving.scheduler.QueryBatcher` serves
    ``--watchers`` sources over a size-``--window`` sliding window; each
    slide's stability gauges (UVV fraction, QRS fractions, bounds-match
    rate) and phase spans land in the process registry, scrapeable live at
    ``--prom-port`` and appended per slide to ``--metrics-jsonl``.
    """
    import numpy as np

    from repro.graph.generators import (
        generate_evolving_stream, generate_rmat, generate_uniform_weights,
    )
    from repro.graph.stream import SnapshotLog, WindowView
    from repro.obs.export import serve_prometheus, to_prometheus
    from repro.obs.trace import Tracer, tracing
    from repro.serving.scheduler import QueryBatcher

    v, e, s = args.vertices, args.vertices * 8, args.window
    src, dst = generate_rmat(v, e, seed=7)
    w = generate_uniform_weights(len(src), seed=8, grid=16)
    base, deltas = generate_evolving_stream(
        src, dst, w, v, num_snapshots=s + args.slides + 1,
        batch_size=args.delta_batch, seed=9,
    )
    log = SnapshotLog(v, capacity=e + (s + args.slides + 1) * args.delta_batch)
    log.append_snapshot(*base)
    for d in deltas[: s - 1]:
        log.append_snapshot(*d)
    view = WindowView(log, size=s)

    server = None
    if args.prom_port is not None:
        server = serve_prometheus(args.prom_port)
        print(f"prometheus: http://127.0.0.1:{server.server_port}/metrics")

    rng = np.random.default_rng(13)
    sources = sorted(int(x) for x in rng.choice(v, size=args.watchers, replace=False))
    qb = QueryBatcher(method="cqrs_ell", pipelined=True)
    tracer = Tracer()
    with tracing(tracer):
        for x in sources:
            qb.watch(view, args.query, x, method="cqrs_ell")
        pending = None
        for k, d in enumerate(deltas[s - 1 : s - 1 + args.slides]):
            nxt = qb.advance_window_async(view, d)
            if pending is not None:
                pending.result()
                _report_slide(k - 1, args)
            pending = nxt
        pending.result()
        _report_slide(args.slides - 1, args)
    qb.close()

    phases = sorted(tracer.names())
    print(f"served {args.slides} slides x {args.watchers} watchers "
          f"({args.query}, window={s}); traced phases: {', '.join(phases)}")
    if server is not None:
        n = len(to_prometheus().splitlines())
        print(f"final scrape: {n} exposition lines "
              f"(http://127.0.0.1:{server.server_port}/metrics)")
        if args.linger:
            import time
            print(f"lingering {args.linger}s for scrapes...")
            time.sleep(args.linger)
        server.shutdown()


def _report_slide(k: int, args) -> None:
    from repro.obs.export import write_jsonl
    from repro.obs.metrics import get_registry

    if args.metrics_jsonl:
        write_jsonl(args.metrics_jsonl, slide=k)
    line = f"slide {k}: served"
    for name, fmt in (("stream_uvv_fraction", "uvv={:.3f}"),
                      ("stream_qrs_edge_fraction", "qrs_edges={:.3f}"),
                      ("stream_bounds_match_rate", "match={:.3f}")):
        samples = get_registry().gauge(name).samples()  # resolves lazies
        if samples:
            vals = [v for _, v in samples]
            line += "  " + fmt.format(sum(vals) / len(vals))
    print(line, flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="decode", choices=["decode", "stream"],
                    help="decode: LM request-scheduler smoke; stream: live "
                         "streaming-graph replica with telemetry")
    # decode-mode knobs
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    # stream-mode knobs
    ap.add_argument("--query", default="sssp")
    ap.add_argument("--watchers", type=int, default=8)
    ap.add_argument("--vertices", type=int, default=512)
    ap.add_argument("--window", type=int, default=8)
    ap.add_argument("--slides", type=int, default=8)
    ap.add_argument("--delta-batch", type=int, default=64,
                    help="edge insertions/deletions per stream delta")
    ap.add_argument("--prom-port", type=int, default=None, metavar="PORT",
                    help="expose the registry at /metrics on PORT (0 = any "
                         "free port); stream mode only")
    ap.add_argument("--metrics-jsonl", default=None, metavar="PATH",
                    help="append one registry snapshot per served slide")
    ap.add_argument("--linger", type=float, default=0.0,
                    help="keep the /metrics endpoint up this many seconds "
                         "after the last slide")
    args = ap.parse_args()
    if args.mode == "stream":
        run_stream(args)
    else:
        from repro.configs import get_arch, list_archs
        lm = [a for a in list_archs() if get_arch(a).family == "lm"]
        if args.arch not in lm:
            raise SystemExit(f"--arch must be one of {lm}")
        run_decode(args)


if __name__ == "__main__":
    main()

"""Serving launcher: batched decode with the request scheduler (smoke config).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --requests 8
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_archs
from repro.models.params import init_params
from repro.models.transformer import cache_defs, decode_step, transformer_defs
from repro.serving.scheduler import Request, RequestScheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b",
                    choices=[a for a in list_archs() if get_arch(a).family == "lm"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_arch(args.arch).smoke_config
    defs = transformer_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    cache = init_params(cache_defs(cfg, args.batch, args.max_len), jax.random.PRNGKey(1))
    state = {"cache": cache}

    @jax.jit
    def decode_at(params, cache, tokens, position):
        logits, new_cache = decode_step(cfg, params, tokens, cache, position)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache

    def decode_token(tokens, positions, mask):
        nxt, state["cache"] = decode_at(params, state["cache"], tokens, positions[0])
        return nxt

    sched = RequestScheduler(batch_size=args.batch, eos_id=0, max_len=args.max_len)
    for uid in range(args.requests):
        prompt = [1 + (uid * 3 + k) % (cfg.vocab_size - 1) for k in range(4)]
        sched.submit(Request(uid=uid, prompt=prompt, max_new_tokens=6))
    done = sched.run(decode_token, max_steps=300)
    for r in sorted(done, key=lambda r: r.uid)[:4]:
        print(f"req {r.uid}: {r.prompt} → {r.generated}")
    print(f"served {len(done)}/{args.requests} requests with {args.arch} smoke config")


if __name__ == "__main__":
    main()

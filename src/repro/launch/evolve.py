"""Evolving-graph query launcher (the paper's system CLI).

    PYTHONPATH=src python -m repro.launch.evolve \
        --query sssp --method cqrs --vertices 8192 --snapshots 16
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.api import evaluate_evolving_query
from repro.core.baselines import BASELINES
from repro.core.semiring import SEMIRINGS
from repro.graph.generators import (
    generate_evolving_stream, generate_rmat, generate_uniform_weights,
)
from repro.graph.structures import build_evolving_graph


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--query", choices=sorted(SEMIRINGS), default="sssp")
    ap.add_argument("--method", choices=sorted(BASELINES), default="cqrs")
    ap.add_argument("--vertices", type=int, default=8192)
    ap.add_argument("--edges", type=int, default=65536)
    ap.add_argument("--snapshots", type=int, default=16)
    ap.add_argument("--batch", type=int, default=600)
    ap.add_argument("--source", type=int, default=0)
    ap.add_argument("--verify", action="store_true")
    args = ap.parse_args()

    src, dst = generate_rmat(args.vertices, args.edges, seed=0)
    w = generate_uniform_weights(len(src), seed=1, grid=16)
    base, deltas = generate_evolving_stream(
        src, dst, w, args.vertices, num_snapshots=args.snapshots,
        batch_size=args.batch, seed=2,
    )
    eg = build_evolving_graph(*base, deltas, args.vertices)

    res, stats = evaluate_evolving_query(eg, args.query, args.source, args.method)
    reach = np.isfinite(res).mean() if SEMIRINGS[args.query].minimize else (res != 0).mean()
    print(f"{args.method} on {args.query}: results {res.shape}, "
          f"{reach:.1%} vertices reached")
    for k, v in stats.items():
        print(f"  {k}: {v}")
    if args.verify and args.method != "full":
        ref, _ = evaluate_evolving_query(eg, args.query, args.source, "full")
        assert np.allclose(res, ref)
        print("verified against full recompute ✓")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# CI-scale override must also land before jax initializes:
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={os.environ['REPRO_DRYRUN_DEVICES']}"
    )

# Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.
#
# For each cell: build abstract inputs (ShapeDtypeStruct, zero allocation),
# ``jax.jit(fn, in_shardings=...).lower(...).compile()``, print/record
# ``memory_analysis()`` (fits-per-chip proof) and ``cost_analysis()`` +
# collective bytes (→ §Roofline).  Results land as JSON under
# ``experiments/dryrun/`` for benchmarks and EXPERIMENTS.md.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
#   REPRO_DRYRUN_DEVICES=8 ... --debug-mesh   (CI-scale smoke of the machinery)

import argparse
import json
import time
import traceback

import jax

from repro.configs import get_arch, list_archs
from repro.distributed.partitioning import active_mesh
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.specs import build_cell
from repro.roofline.analysis import HW_V5E, roofline_report

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _mem_stats(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
        return {
            "argument_bytes": int(m.argument_size_in_bytes),
            "output_bytes": int(m.output_size_in_bytes),
            "temp_bytes": int(m.temp_size_in_bytes),
            "alias_bytes": int(m.alias_size_in_bytes),
            "peak_estimate_bytes": int(
                m.argument_size_in_bytes + m.output_size_in_bytes
                + m.temp_size_in_bytes - m.alias_size_in_bytes
            ),
        }
    except Exception as e:  # backend without memory stats
        return {"error": str(e)}


def run_cell(arch_id: str, shape_name: str, mesh, mesh_name: str, *, out_dir: str):
    spec = get_arch(arch_id)
    t0 = time.time()
    cell = build_cell(spec, shape_name, mesh)
    with mesh, active_mesh(mesh):
        lowered = jax.jit(cell.fn, in_shardings=cell.in_shardings).lower(*cell.args)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = _mem_stats(compiled)
    try:
        cost = compiled.cost_analysis()
        cost = cost if isinstance(cost, dict) else cost[0]
    except Exception as e:
        cost = {"error": str(e)}
    hlo = compiled.as_text()
    n_chips = mesh.devices.size
    report = roofline_report(
        {k: v for k, v in cost.items() if isinstance(v, (int, float))},
        hlo,
        num_chips=n_chips,
        model_flops=cell.model_flops,
        scan_factor=cell.scan_factor,
        coll_scan_factor=cell.coll_scan_factor,
        analytic_bytes=cell.analytic_bytes,
        memory_stats=mem,
    )

    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": mesh_name,
        "num_chips": int(n_chips),
        "description": cell.description,
        "compile_seconds": round(t_compile, 2),
        "memory": mem,
        "cost": {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))},
        "roofline": report.to_dict(),
        "fits_hbm": (
            mem.get("peak_estimate_bytes", 0) < HW_V5E["hbm_bytes"]
            if "peak_estimate_bytes" in mem else None
        ),
    }
    os.makedirs(out_dir, exist_ok=True)
    fn = os.path.join(out_dir, f"{arch_id}__{shape_name}__{mesh_name}.json")
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)

    frac = report.roofline_fraction
    print(
        f"[OK] {arch_id:18s} {shape_name:14s} {mesh_name:9s} "
        f"compile={t_compile:6.1f}s "
        f"args/chip={mem.get('argument_bytes', 0)/2**30:6.2f}GiB "
        f"flops/chip={report.flops_per_chip:.3e} "
        f"coll/chip={report.collective_bytes_per_chip:.3e}B "
        f"dom={report.dominant:10s} "
        f"frac={frac if frac is None else round(frac, 3)}",
        flush=True,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--debug-mesh", action="store_true",
                    help="use the small 8-device mesh (with REPRO_DRYRUN_DEVICES=8)")
    ap.add_argument("--include-evolving", action="store_true", default=True)
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    make = make_debug_mesh if args.debug_mesh else make_production_mesh
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod", make(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod", make(multi_pod=True)))

    if args.all:
        targets = [
            (a, s)
            for a in list_archs(include_extra=args.include_evolving)
            for s in get_arch(a).shapes
        ]
    else:
        archs = [args.arch] if args.arch else list_archs()
        targets = [
            (a, s)
            for a in archs
            for s in ([args.shape] if args.shape else get_arch(a).shapes)
        ]

    failures = []
    for mesh_name, mesh in meshes:
        for arch_id, shape_name in targets:
            try:
                run_cell(arch_id, shape_name, mesh, mesh_name, out_dir=args.out)
            except Exception as e:
                failures.append((arch_id, shape_name, mesh_name, repr(e)))
                print(f"[FAIL] {arch_id} {shape_name} {mesh_name}: {e!r}", flush=True)
                traceback.print_exc()
    print(f"\ndone: {len(targets) * len(meshes) - len(failures)} ok, "
          f"{len(failures)} failed")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""dimenet [arXiv:2003.03123]
6 interaction blocks, d_hidden=128, n_bilinear=8, n_spherical=7, n_radial=6.
Triplet regime: host-precomputed (and capped) triplet index lists."""
from repro.configs import ArchSpec, GNN_SHAPES
from repro.models.gnn.common import GNNConfig

FULL = GNNConfig(
    name="dimenet",
    arch="dimenet",
    num_layers=6,
    d_hidden=128,
    d_feat=16,
    num_classes=1,
    n_radial=6,
    n_spherical=7,
    n_bilinear=8,
    cutoff=5.0,
    num_atom_types=95,
)

SMOKE = GNNConfig(
    name="dimenet-smoke",
    arch="dimenet",
    num_layers=2,
    d_hidden=32,
    d_feat=16,
    num_classes=1,
    n_radial=6,
    n_spherical=7,
    n_bilinear=8,
    num_atom_types=16,
)

SPEC = ArchSpec(
    arch_id="dimenet",
    family="gnn",
    config=FULL,
    smoke_config=SMOKE,
    shapes=dict(GNN_SHAPES),
    notes=(
        "Molecular model; on citation/product graphs positions are synthetic "
        "inputs and triplets are sampled (cap K/edge) — DESIGN.md §8.7."
    ),
)

"""llama3-8b [arXiv:2407.21783]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256, rope theta 500k."""
from repro.configs import ArchSpec, LM_SHAPES
from repro.models.layers import TransformerConfig

FULL = TransformerConfig(
    name="llama3-8b",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    ffn_type="swiglu",
    rope_theta=500_000.0,
    remat=True,
)

SMOKE = TransformerConfig(
    name="llama3-smoke",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=224,
    vocab_size=128,
    ffn_type="swiglu",
    remat=True,
)

SPEC = ArchSpec(
    arch_id="llama3-8b",
    family="lm",
    config=FULL,
    smoke_config=SMOKE,
    shapes=dict(LM_SHAPES),
)

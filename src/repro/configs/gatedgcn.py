"""gatedgcn [arXiv:2003.00982]
16 layers, d_hidden=70, gated edge aggregation."""
from repro.configs import ArchSpec, GNN_SHAPES
from repro.models.gnn.common import GNNConfig

FULL = GNNConfig(
    name="gatedgcn",
    arch="gatedgcn",
    num_layers=16,
    d_hidden=70,
    d_feat=1433,
    num_classes=7,
    d_edge_feat=8,
)

SMOKE = GNNConfig(
    name="gatedgcn-smoke",
    arch="gatedgcn",
    num_layers=3,
    d_hidden=20,
    d_feat=16,
    num_classes=5,
    d_edge_feat=8,
)

SPEC = ArchSpec(
    arch_id="gatedgcn",
    family="gnn",
    config=FULL,
    smoke_config=SMOKE,
    shapes=dict(GNN_SHAPES),
)

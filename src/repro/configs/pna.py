"""pna [arXiv:2004.05718]
4 layers, d_hidden=75, aggregators mean-max-min-std, scalers id-amp-atten."""
from repro.configs import ArchSpec, GNN_SHAPES
from repro.models.gnn.common import GNNConfig

FULL = GNNConfig(
    name="pna",
    arch="pna",
    num_layers=4,
    d_hidden=75,
    d_feat=1433,  # per-shape override via launch/specs
    num_classes=7,
)

SMOKE = GNNConfig(
    name="pna-smoke",
    arch="pna",
    num_layers=2,
    d_hidden=24,
    d_feat=16,
    num_classes=5,
)

SPEC = ArchSpec(
    arch_id="pna",
    family="gnn",
    config=FULL,
    smoke_config=SMOKE,
    shapes=dict(GNN_SHAPES),
    notes="12 aggregator x scaler views; fused 4-stat kernel = kernels/ell_agg.",
)

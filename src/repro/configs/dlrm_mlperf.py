"""dlrm-mlperf [arXiv:1906.00091] — MLPerf Criteo-1TB benchmark config.
13 dense + 26 sparse features, embed_dim=128, bot 13-512-256-128,
top 1024-1024-512-256-1, dot interaction."""
from repro.configs import ArchSpec, RECSYS_SHAPES
from repro.models.dlrm import CRITEO_TABLE_SIZES, DLRMConfig

FULL = DLRMConfig(
    name="dlrm-mlperf",
    n_dense=13,
    n_sparse=26,
    embed_dim=128,
    bot_mlp=(13, 512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1),
    table_sizes=CRITEO_TABLE_SIZES,
)

SMOKE = DLRMConfig(
    name="dlrm-smoke",
    embed_dim=32,
    bot_mlp=(13, 64, 32),
    top_mlp=(64, 32, 1),
    table_sizes=tuple([40, 17, 100, 3, 20, 9, 50, 11, 5, 30, 60, 8, 4, 12, 7,
                       25, 13, 6, 19, 33, 21, 14, 10, 16, 22, 18]),
)

SPEC = ArchSpec(
    arch_id="dlrm-mlperf",
    family="recsys",
    config=FULL,
    smoke_config=SMOKE,
    shapes=dict(RECSYS_SHAPES),
    notes="Tables row-sharded over `model` via shard_map lookup + psum.",
)

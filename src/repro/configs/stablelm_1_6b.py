"""stablelm-1.6b [hf:stabilityai/stablelm-2-1_6b]
24L d_model=2048 32H (MHA kv=32) d_ff=5632 vocab=100352, head_dim=64."""
from repro.configs import ArchSpec, LM_SHAPES
from repro.models.layers import TransformerConfig

FULL = TransformerConfig(
    name="stablelm-1.6b",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100352,
    ffn_type="swiglu",
    rope_theta=10_000.0,
    remat=True,
)

SMOKE = TransformerConfig(
    name="stablelm-smoke",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=8,
    head_dim=8,
    d_ff=176,
    vocab_size=128,
    ffn_type="swiglu",
    remat=True,
)

SPEC = ArchSpec(
    arch_id="stablelm-1.6b",
    family="lm",
    config=FULL,
    smoke_config=SMOKE,
    shapes=dict(LM_SHAPES),
)

"""gemma-2b [arXiv:2403.08295]
18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000, GeGLU, head_dim=256,
tied + scaled embeddings."""
from repro.configs import ArchSpec, LM_SHAPES
from repro.models.layers import TransformerConfig

FULL = TransformerConfig(
    name="gemma-2b",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    ffn_type="geglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    scale_embeddings=True,
    remat=True,
)

SMOKE = TransformerConfig(
    name="gemma-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab_size=128,
    ffn_type="geglu",
    tie_embeddings=True,
    scale_embeddings=True,
    remat=True,
)

SPEC = ArchSpec(
    arch_id="gemma-2b",
    family="lm",
    config=FULL,
    smoke_config=SMOKE,
    shapes=dict(LM_SHAPES),
)

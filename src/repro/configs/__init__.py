"""Architecture registry: ``--arch <id>`` → ArchSpec (config + shapes).

Every assigned architecture (plus the paper's own evolving-graph workload)
registers the EXACT full config from the assignment, a reduced smoke config
(CPU-runnable), and its shape set.  ``get_arch`` / ``list_archs`` are the
single lookup point used by launch/, benchmarks/ and tests/.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict

ARCH_MODULES = {
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "gemma-2b": "repro.configs.gemma_2b",
    "llama3-8b": "repro.configs.llama3_8b",
    "dimenet": "repro.configs.dimenet",
    "equiformer-v2": "repro.configs.equiformer_v2",
    "pna": "repro.configs.pna",
    "gatedgcn": "repro.configs.gatedgcn",
    "dlrm-mlperf": "repro.configs.dlrm_mlperf",
    # the paper's own workload (not part of the assigned 40 cells)
    "evolving-rmat": "repro.configs.evolving_rmat",
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # lm | gnn | recsys | evolving
    config: Any
    smoke_config: Any
    shapes: Dict[str, dict]
    notes: str = ""


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; options: {sorted(ARCH_MODULES)}")
    mod = importlib.import_module(ARCH_MODULES[arch_id])
    return mod.SPEC


def list_archs(include_extra: bool = True) -> list:
    ids = list(ARCH_MODULES)
    if not include_extra:
        ids.remove("evolving-rmat")
    return ids


# canonical shape sets (assignment tables)
LM_SHAPES = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "cache_len": 32768, "batch": 128},
    # decode over a 500k cache is linear in cache length (not quadratic
    # prefill) — run, not skipped; see DESIGN.md §6.
    "long_500k": {"kind": "decode", "cache_len": 524288, "batch": 1, "big_seq": True},
}

GNN_SHAPES = {
    "full_graph_sm": {
        "kind": "gnn_full", "n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
        "num_classes": 7,
    },
    "minibatch_lg": {
        "kind": "gnn_minibatch", "n_nodes": 232965, "n_edges": 114615892,
        "batch_nodes": 1024, "fanout": (15, 10), "d_feat": 602, "num_classes": 41,
    },
    "ogb_products": {
        "kind": "gnn_full", "n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100,
        "num_classes": 47,
    },
    "molecule": {
        "kind": "gnn_molecule", "n_nodes": 30, "n_edges": 64, "batch": 128,
        "d_feat": 16, "num_classes": 1,
    },
}

RECSYS_SHAPES = {
    "train_batch": {"kind": "recsys_train", "batch": 65536},
    "serve_p99": {"kind": "recsys_serve", "batch": 512},
    "serve_bulk": {"kind": "recsys_serve", "batch": 262144},
    "retrieval_cand": {"kind": "recsys_retrieval", "batch": 1, "n_candidates": 1_000_000},
}

EVOLVING_SHAPES = {
    # paper Table 3 scale points (universe ≈ |E| + updates), 64 snapshots
    "lj_64snap": {
        "kind": "evolving", "n_vertices": 4_800_512, "n_edges": 72_000_000,
        "n_snapshots": 64,
    },
    "twitter_64snap": {
        "kind": "evolving", "n_vertices": 41_652_224, "n_edges": 1_470_000_000,
        "n_snapshots": 64,
    },
}

"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]
24L d_model=2048 16H (MHA kv=16) vocab=151936; MoE: 60 routed top-4 experts
of d_ff=1408 + 4 shared experts (shared intermediate 4×1408=5632)."""
from repro.configs import ArchSpec, LM_SHAPES
from repro.models.layers import TransformerConfig

FULL = TransformerConfig(
    name="qwen2-moe-a2.7b",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=5632,  # shared-expert intermediate (dense path unused: all-MoE)
    vocab_size=151936,
    ffn_type="swiglu",
    rope_theta=1_000_000.0,
    moe=True,
    num_experts=60,
    num_shared_experts=4,
    top_k=4,
    moe_d_ff=1408,
    first_k_dense=0,
    remat=True,
)

SMOKE = TransformerConfig(
    name="qwen2-moe-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=176,
    vocab_size=128,
    ffn_type="swiglu",
    moe=True,
    num_experts=8,
    num_shared_experts=2,
    top_k=4,
    moe_d_ff=44,
    first_k_dense=0,
    remat=True,
)

SPEC = ArchSpec(
    arch_id="qwen2-moe-a2.7b",
    family="lm",
    config=FULL,
    smoke_config=SMOKE,
    shapes=dict(LM_SHAPES),
    notes="4 shared experts modeled as one fused shared FFN of 4x1408.",
)

"""The paper's own workload: evolving-graph queries over RMAT social graphs.

Full shapes mirror Table 3/4 scale points (LiveJournal, Twitter) with 64
snapshots and 150K-edge update batches; the smoke config is the CPU-runnable
regime every correctness test and benchmark uses.
"""
import dataclasses

from repro.configs import ArchSpec, EVOLVING_SHAPES


@dataclasses.dataclass(frozen=True)
class EvolvingConfig:
    name: str
    query: str = "sssp"  # bfs | sssp | sswp | ssnp | viterbi
    n_vertices: int = 4_800_512
    n_edges: int = 72_000_000
    n_snapshots: int = 64
    batch_updates: int = 150_000
    source: int = 0


FULL = EvolvingConfig(name="evolving-lj")

SMOKE = EvolvingConfig(
    name="evolving-smoke",
    n_vertices=256,
    n_edges=1024,
    n_snapshots=8,
    batch_updates=32,
)

SPEC = ArchSpec(
    arch_id="evolving-rmat",
    family="evolving",
    config=FULL,
    smoke_config=SMOKE,
    shapes=dict(EVOLVING_SHAPES),
    notes="The paper's technique itself (UVV/QRS/CQRS) at pod scale.",
)

"""equiformer-v2 [arXiv:2306.12059]
12 layers, d_hidden=128, l_max=6, m_max=2, 8 heads, SO(2)-eSCN convolutions."""
from repro.configs import ArchSpec, GNN_SHAPES
from repro.models.gnn.common import GNNConfig

FULL = GNNConfig(
    name="equiformer-v2",
    arch="equiformer_v2",
    num_layers=12,
    d_hidden=128,
    d_feat=16,
    num_classes=1,
    l_max=6,
    m_max=2,
    num_heads=8,
    n_radial=6,
    cutoff=5.0,
    edge_chunk=0,  # per-shape override for the 61M/114M-edge graphs
)

SMOKE = GNNConfig(
    name="equiformer-v2-smoke",
    arch="equiformer_v2",
    num_layers=2,
    d_hidden=16,
    d_feat=12,
    num_classes=4,
    l_max=3,
    m_max=2,
    num_heads=4,
)

SPEC = ArchSpec(
    arch_id="equiformer-v2",
    family="gnn",
    config=FULL,
    smoke_config=SMOKE,
    shapes=dict(GNN_SHAPES),
    notes="eSCN SO(2) trick via wigner.py; gate activation in lieu of S2 grids.",
)

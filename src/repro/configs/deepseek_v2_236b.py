"""deepseek-v2-236b [arXiv:2405.04434]
60L d_model=5120 128H; MLA kv_lora=512 (q_lora=1536, nope=128, rope=64,
v=128); MoE: 160 routed top-6 (d_ff=1536) + 2 shared; first layer dense
(d_ff=12288); vocab=102400."""
from repro.configs import ArchSpec, LM_SHAPES
from repro.models.layers import TransformerConfig

FULL = TransformerConfig(
    name="deepseek-v2-236b",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=12288,  # the dense first layer
    vocab_size=102400,
    ffn_type="swiglu",
    rope_theta=10_000.0,
    attention_type="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    moe=True,
    num_experts=160,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    first_k_dense=1,
    capacity_factor=1.25,
    remat=True,
)

SMOKE = TransformerConfig(
    name="deepseek-v2-smoke",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    ffn_type="swiglu",
    attention_type="mla",
    kv_lora_rank=32,
    q_lora_rank=24,
    qk_nope_dim=16,
    qk_rope_dim=8,
    v_head_dim=16,
    moe=True,
    num_experts=8,
    num_shared_experts=2,
    top_k=2,
    moe_d_ff=32,
    first_k_dense=1,
    remat=True,
)

SPEC = ArchSpec(
    arch_id="deepseek-v2-236b",
    family="lm",
    config=FULL,
    smoke_config=SMOKE,
    shapes=dict(LM_SHAPES),
    notes="MLA decode uses the absorbed-matrix latent-cache formulation.",
)

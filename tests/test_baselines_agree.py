"""All five evaluation strategies must produce identical (S, V) results."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.baselines import BASELINES, run_full
from repro.core.semiring import SEMIRINGS
from conftest import make_evolving


@pytest.mark.parametrize("name", sorted(SEMIRINGS))
@pytest.mark.parametrize("method", [m for m in BASELINES if m != "full"])
def test_methods_agree_with_full(name, method):
    eg = make_evolving(num_vertices=56, num_edges=220, num_snapshots=6, batch_size=24)
    sr = SEMIRINGS[name]
    ref, _ = run_full(eg, sr, 0)
    got, stats = BASELINES[method](eg, sr, 0)
    np.testing.assert_allclose(got, ref, rtol=1e-6, err_msg=f"{method} != full for {name}")
    assert stats["method"] == method


@pytest.mark.parametrize("seed", [11, 42, 99])
def test_methods_agree_various_churn(seed):
    eg = make_evolving(
        num_vertices=72, num_edges=300, num_snapshots=7, batch_size=40,
        seed=seed, readd_prob=0.5,
    )
    sr = SEMIRINGS["sssp"]
    ref, _ = run_full(eg, sr, seed % 72)
    for method in ("kickstarter", "commongraph", "qrs", "cqrs"):
        got, _ = BASELINES[method](eg, sr, seed % 72)
        np.testing.assert_allclose(got, ref, rtol=1e-6, err_msg=method)


def test_kickstarter_trims_equal_value_cycle():
    """Regression: a sswp cycle 1↔2 whose sole support 0→1 is deleted must
    lose its value — arbitrary achieving-edge parents would let the cycle
    vertices justify each other and keep the stale 5.0."""
    from repro.core.baselines import run_kickstarter
    from repro.graph.structures import build_evolving_graph

    eg = build_evolving_graph(
        [1, 2, 0], [2, 1, 1], [9.0, 9.0, 5.0],
        [([], [], [], [0], [1])], 5,
    )
    sr = SEMIRINGS["sswp"]
    ref, _ = run_full(eg, sr, 0)
    got, _ = run_kickstarter(eg, sr, 0)
    np.testing.assert_array_equal(got, ref)
    assert got[1, 1] == sr.identity and got[1, 2] == sr.identity


@pytest.mark.parametrize("name", ["ssnp", "sswp", "viterbi"])
def test_non_strict_extend_agreement_under_churn(name):
    """Regression for the example-scale "commongraph disagrees" failure.

    The failure was a mis-attribution: under a non-strict ``extend`` (ssnp's
    max, sswp's min, viterbi at w=1) *kickstarter* — the example's reference
    — kept stale too-good values when an equal-value plateau survived its
    support edge's deletion, and the next method compared (commongraph, whose
    direct-hop bootstrap is provably conservative: G∩ ⊆ every snapshot) got
    blamed by the assert.  This fixture (the make_evolving defaults, seed 0)
    reproduces the divergence on the pre-acyclic-parent-forest trim at tier-1
    size — tier-1's smaller 56-vertex fixture never tripped it.
    """
    eg = make_evolving(num_vertices=64, num_edges=256, num_snapshots=6,
                       batch_size=24, seed=0, readd_prob=0.3)
    sr = SEMIRINGS[name]
    ref, _ = run_full(eg, sr, 0)
    for method in ("kickstarter", "commongraph", "qrs", "cqrs"):
        got, _ = BASELINES[method](eg, sr, 0)
        np.testing.assert_allclose(
            got, ref, rtol=1e-6, err_msg=f"{method} != full for {name}"
        )


def test_qrs_reduces_edges():
    """Fig. 9 analog: QRS keeps a small fraction of edges under light churn."""
    eg = make_evolving(num_vertices=256, num_edges=1500, num_snapshots=8, batch_size=30)
    sr = SEMIRINGS["sssp"]
    _, stats = BASELINES["qrs"](eg, sr, 0)
    assert stats["qrs_edges"] < stats["universe_edges"]
    assert 0.0 <= stats["frac_edges_kept"] <= 1.0
    assert stats["frac_uvv"] > 0.3

"""Docs smoke tests: markdown links resolve, paper→code map names real symbols.

Run by the CI ``docs`` job (and as part of tier-1).  The
``docs/ARCHITECTURE.md`` paper→code table is parsed row by row and every
named module/symbol is imported — so the map cannot silently rot as the code
moves.
"""
from __future__ import annotations

import importlib
import re
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_ROW_RE = re.compile(r"^\|[^|]+\|\s*`(repro/[\w/]+\.py)`\s*\|(.+)\|\s*$")
_SYM_RE = re.compile(r"`([A-Za-z_]\w*)`")


def _md_files():
    files = sorted(REPO.glob("*.md")) + sorted((REPO / "docs").glob("*.md"))
    assert files, "no markdown files found"
    return files


def test_readme_and_architecture_exist():
    assert (REPO / "README.md").exists()
    assert (REPO / "docs" / "ARCHITECTURE.md").exists()


def test_markdown_links_resolve():
    """Every relative markdown link in *.md points at an existing file."""
    missing = []
    for md in _md_files():
        text = _FENCE_RE.sub("", md.read_text())  # ignore code blocks
        for target in _LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "#", "mailto:")):
                continue
            path = target.split("#")[0]
            if path and not (md.parent / path).exists():
                missing.append(f"{md.relative_to(REPO)} -> {target}")
    assert not missing, f"dangling markdown links: {missing}"


def test_architecture_map_names_real_symbols():
    """Each paper→code row's module imports and exposes the named symbols."""
    text = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    rows = [m for line in text.splitlines() if (m := _ROW_RE.match(line))]
    assert len(rows) >= 15, "paper→code table went missing or lost its rows"
    for m in rows:
        path, symbol_col = m.group(1), m.group(2)
        assert (REPO / "src" / path).exists(), f"{path} does not exist"
        module = importlib.import_module(path[:-3].replace("/", "."))
        symbols = _SYM_RE.findall(symbol_col)
        assert symbols, f"row for {path} names no symbols"
        for sym in symbols:
            assert hasattr(module, sym), f"{path} has no symbol {sym!r}"


def test_architecture_covers_streaming_layer():
    """The new streaming entry points are on the map."""
    text = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    for sym in ("SnapshotLog", "WindowView", "StreamingBounds", "PatchableQRS",
                "StreamingQuery", "advance_window"):
        assert sym in text, f"ARCHITECTURE.md does not mention {sym}"


def test_architecture_covers_sharded_streaming_layer():
    """The sharded-streaming section and its entry points are on the map."""
    text = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    assert "## Sharded streaming" in text
    for sym in ("ShardedSnapshotLog", "ShardedWindowView", "ShardSlideDiff",
                "ShardedStreamingBounds", "ShardedStreamingQuery",
                "retire_history", "cache_info", "host_mesh"):
        assert sym in text, f"ARCHITECTURE.md does not mention {sym}"


def test_architecture_covers_batched_streaming_serving():
    """The batched-serving section and its entry points are on the map."""
    text = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    assert "## Batched streaming serving" in text
    for sym in ("StreamingQueryBatch", "ShardedStreamingQueryBatch",
                "StableEllPacker", "add_source", "remove_source",
                "advance_window", "tile_presence_words"):
        assert sym in text, f"ARCHITECTURE.md does not mention {sym}"


def test_architecture_covers_spmd_ell_and_rebalancing():
    """The SPMD ELL / shard-rebalancing section and entry points are mapped."""
    text = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    assert "## SPMD ELL & shard rebalancing" in text
    for sym in ("ShardAssignment", "degree_histogram", "_ell_kernels",
                "_ShardedEllCache", "lane_supersteps", "set_lane",
                "drop_lane_padded", "occupancy"):
        assert sym in text, f"ARCHITECTURE.md does not mention {sym}"


def test_architecture_covers_pipelined_serving():
    """The pipelined-serving section and its entry points are on the map."""
    text = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    assert "## Pipelined serving" in text
    for sym in ("EllPresenceCache", "presence_word_pattern",
                "advance_window_async", "PendingWindow", "group_futures",
                "to_global_lazy", "ell_epoch", "quarantine_factor",
                "quarantined", "sweep", "validate_bench_json"):
        assert sym in text, f"ARCHITECTURE.md does not mention {sym}"


def test_architecture_covers_observability():
    """The observability section and its entry points are on the map."""
    text = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    assert "## Observability" in text
    for sym in ("MetricsRegistry", "get_registry", "use_registry", "span",
                "mark_ready", "PHASES", "record_slide", "window_union_edges",
                "stream_uvv_fraction", "stream_qrs_edge_fraction",
                "stream_bounds_match_rate", "to_prometheus",
                "serve_prometheus", "write_jsonl", "EventLog"):
        assert sym in text, f"ARCHITECTURE.md does not mention {sym}"


def test_architecture_covers_online_resharding():
    """The online-resharding section and its entry points are on the map."""
    text = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    assert "## Online resharding" in text
    for sym in ("MigrationPlan", "migration_plan", "rebalance", "resize",
                "ReshardPolicy", "plan_reshard", "occupancy_spread",
                "reshard", "window_payload", "replay_delta_log",
                "observed_ell_ladder", "ladder_specs"):
        assert sym in text, f"ARCHITECTURE.md does not mention {sym}"


def test_architecture_covers_failure_model():
    """The failure-model section and its entry points are on the map."""
    text = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    assert "## Failure model & degraded serving" in text
    for sym in ("FaultPlan", "FaultSpec", "inject", "fault_point",
                "corrupt_point", "DeadLetterLog", "ChaosHarness",
                "AdvanceRetryExhausted", "slides_behind", "retry_budget",
                "CheckpointCorruptError", "array_checksums",
                "verify_checksums", "readmit", "flap_window"):
        assert sym in text, f"ARCHITECTURE.md does not mention {sym}"


def test_architecture_covers_warm_start_and_recovery():
    """The warm-start/recovery section and its entry points are on the map."""
    text = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    assert "## Warm start & recovery" in text
    for sym in ("CheckpointManager", "streaming_state", "resume_streaming",
                "replay_log", "from_state", "KernelGridSpec", "grid_for",
                "aot_compile", "warmup", "warm_from_manifest", "grid.json",
                "ServeSupervisor", "HeartbeatMonitor", "ckpt_every"):
        assert sym in text, f"ARCHITECTURE.md does not mention {sym}"

"""Compile-stability pinning: AOT warm-up keeps the serving path trace-free.

Satellite of the warm-start tentpole (``repro.serving.warmstart``).  Three
independent guarantees are pinned:

* **restore re-enters the exact compile classes** — a replica resumed from a
  checkpoint mid-stream serves the remaining slides with ZERO new jit cache
  entries (the classes were compiled by the pre-crash replica in the same
  process, and restore injects the same capacity classes);
* **AOT warm-up covers the probed grid** — ``jax.clear_caches()`` then
  ``warmup(specs)`` for the specs probed off a live replica, then a fresh
  replica primes and serves K slides with frozen cache-miss counters;
* **a restarted process never compiles on the serving path** — subprocess
  pair sharing a persistent executable cache directory: the second process
  replays ``grid.json`` via ``warm_from_manifest`` and its serve loop adds
  zero files to the cache dir and zero in-memory cache entries (covers the
  vmapped dispatch paths the counters cannot see).
"""
from __future__ import annotations

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.checkpoint import resume_streaming, streaming_state
from repro.core.api import StreamingQuery, StreamingQueryBatch
from repro.graph.generators import (
    generate_evolving_stream,
    generate_rmat,
    generate_uniform_weights,
)
from repro.graph.stream import SnapshotLog, WindowView
from repro.serving.warmstart import (
    KernelGridSpec,
    aot_compile,
    enumerate_grid,
    grid_for,
    load_grid,
    save_grid,
    warmup,
)

V = 48
WINDOW = 3
SOURCES = [0, 7, 13, 21]


def make_log(seed: int, *, capacity: int = 512):
    src, dst = generate_rmat(V, 192, seed=seed)
    w = generate_uniform_weights(len(src), seed=seed + 1, grid=16)
    base, deltas = generate_evolving_stream(
        src, dst, w, V, num_snapshots=WINDOW + 4, batch_size=20,
        readd_prob=0.4, seed=seed + 2,
    )
    log = SnapshotLog(V, capacity=capacity)
    log.append_snapshot(*base)
    for d in deltas[: WINDOW - 1]:
        log.append_snapshot(*d)
    return log, deltas[WINDOW - 1:]


def _counters():
    from repro.core.concurrent import concurrent_fixpoint_batch
    from repro.core.engine import (
        compute_fixpoint,
        compute_parents,
        incremental_fixpoint,
        invalidate_from_deletions,
    )
    from repro.kernels.vrelax.ops import (
        concurrent_fixpoint_ell,
        concurrent_fixpoint_ell_batch,
    )

    return [
        fn for fn in (
            compute_fixpoint, incremental_fixpoint, compute_parents,
            invalidate_from_deletions, concurrent_fixpoint_batch,
            concurrent_fixpoint_ell, concurrent_fixpoint_ell_batch,
        )
        if hasattr(fn, "_cache_size")
    ]


# ==================================================================== restore
@pytest.mark.parametrize("method", ["cqrs", "cqrs_ell"])
def test_restored_replica_compiles_nothing(method):
    """Resume mid-stream and serve the tail with FROZEN jit caches: restore
    must re-enter the pre-crash replica's exact compile classes (log
    capacity, QRS slots, ELL rows, Q class) rather than re-deriving its own."""
    log, pending = make_log(seed=0)
    view = WindowView(log, size=WINDOW)
    sq = StreamingQueryBatch(view, "sssp", SOURCES, method=method)
    sq.results
    ref = []
    tree = extra = None
    for j, d in enumerate(pending):
        sq.advance(d)
        ref.append(np.asarray(sq.results).copy())
        if j == 1:
            tree, extra = streaming_state(sq)
    fns = _counters()
    assert fns, "no countable jitted entry points found"
    misses = [fn._cache_size() for fn in fns]
    restored = resume_streaming(tree, extra)
    np.testing.assert_array_equal(np.asarray(restored.results), ref[1])
    for j, d in enumerate(pending[2:], start=2):
        restored.advance(d)
        np.testing.assert_array_equal(np.asarray(restored.results), ref[j])
    assert [fn._cache_size() for fn in fns] == misses, \
        "restore + catch-up traced new kernel variants"


# ===================================================================== warmup
def test_aot_warmup_covers_probed_grid():
    """Probe the grid off a live replica, clear every jit cache, warm the
    probed specs, then serve a FRESH replica: zero cache growth across the
    served slides (vmapped dispatch counters stay frozen too)."""
    log, pending = make_log(seed=1)
    probe_sq = StreamingQueryBatch(
        WindowView(log, size=WINDOW), "sssp", SOURCES, method="cqrs_ell"
    )
    probe_sq.results
    specs, seen = [], set()
    for step in range(len(pending) + 1):
        if step:
            probe_sq.advance(pending[step - 1])
        s = grid_for(probe_sq)
        if s.key() not in seen:
            seen.add(s.key())
            specs.append(s)
    jax.clear_caches()
    report = warmup(specs)
    assert len(report["specs"]) == len(specs)

    log2, pending2 = make_log(seed=1)
    sq = StreamingQueryBatch(
        WindowView(log2, size=WINDOW), "sssp", SOURCES, method="cqrs_ell"
    )
    sq.results
    fns = _counters()
    misses = [fn._cache_size() for fn in fns]
    for d in pending2:
        sq.advance(d)
    after = [fn._cache_size() for fn in fns]
    assert after == misses, (
        "serving missed the warmed grid: "
        + str([(fn.__name__, b, a)
               for fn, b, a in zip(fns, misses, after) if a != b])
    )


def test_aot_compile_report_all_ok():
    """Every AOT-traceable engine kernel lowers and compiles from
    ShapeDtypeStructs alone for a representative grid point."""
    spec = KernelGridSpec(
        num_vertices=V, log_capacity=1024, qrs_capacity=384,
        semiring="sswp", method="cqrs", q_cap=4,
    )
    report = aot_compile(spec)
    bad = {k: v for k, v in report.items() if v != "ok"}
    assert not bad, f"AOT compile failures: {bad}"
    assert {"compute_fixpoint", "incremental_fixpoint", "compute_parents",
            "invalidate_from_deletions", "detect_uvv",
            "incremental_fixpoint@qrs",
            "concurrent_fixpoint_batch@qrs"} <= set(report)


def test_grid_manifest_roundtrip(tmp_path):
    """grid.json survives save/load; enumerate_grid dedups by content key
    and appends growth successors along the real capacity ladders."""
    base = KernelGridSpec(num_vertices=V, log_capacity=1024,
                          qrs_capacity=128, ell_rows=16, q_cap=4)
    grid = enumerate_grid([base, base], growth_steps=2)
    assert len(grid) == 3  # duplicate collapsed; two growth successors
    assert grid[1].log_capacity == 2048 and grid[2].log_capacity == 4096
    assert grid[1].qrs_capacity == 256 and grid[1].ell_rows == 32
    path = save_grid(grid, str(tmp_path))
    assert os.path.basename(path) == "grid.json"
    loaded = load_grid(str(tmp_path))
    assert [s.key() for s in loaded] == [s.key() for s in grid]
    assert loaded[0] == grid[0]


# ================================================================= subprocess
def _run_subproc(phase, cache_dir):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + "tests"
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, os.path.join("tests", "_warmstart_subproc.py"),
         phase, cache_dir],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=600,
    )


def test_restarted_process_zero_compiles_on_serving_path(tmp_path):
    """The full warm-start story across a REAL process boundary: process A
    probes + warms a persistent executable cache; process B replays the
    manifest and serves — the cache dir gains zero files and the jit caches
    zero entries during B's serve loop."""
    cache_dir = str(tmp_path / "xla-cache")
    warm = _run_subproc("warm", cache_dir)
    assert warm.returncode == 0, warm.stdout + warm.stderr
    if "SKIP" in warm.stdout:
        pytest.skip("persistent compilation cache unsupported in this build")
    assert "WARM_OK" in warm.stdout, warm.stdout + warm.stderr
    serve = _run_subproc("serve", cache_dir)
    assert serve.returncode == 0, serve.stdout + serve.stderr
    assert "CHECK_OK" in serve.stdout, serve.stdout + serve.stderr

"""Sharded streaming subsystem: dst-range delta log + SPMD window serving.

Two layers of coverage:

* host-side structure tests (single device, run in-process): delta routing,
  multi-shard append atomicity, materialize equivalence, slide-diff lockstep,
  and the 1-shard SPMD query (a real ``shard_map`` on the one local device,
  so tier-1 exercises the sharded code path without a forced host mesh);
* 8-device mesh checks (subprocess, because
  ``xla_force_host_platform_device_count`` must be set before jax
  initializes): bit-for-bit advance equivalence across semirings × slides,
  capacity growth under a live query, SPMD serving via ``QueryBatcher``,
  shard-locality of appends, the one-collective-per-superstep HLO
  invariant, and a fault-during-reshard chaos schedule — see
  ``tests/_stream_shard_checks.py``.
"""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.api import EvolvingQuery, StreamingQuery
from repro.graph.generators import (
    generate_evolving_stream,
    generate_rmat,
    generate_uniform_weights,
)
from repro.graph.shardlog import ShardedSnapshotLog, ShardedWindowView
from repro.graph.stream import SnapshotLog, WindowView
from _prop import given, settings, st

V = 48
WINDOW = 3
SCRIPT = os.path.join(os.path.dirname(__file__), "_stream_shard_checks.py")


def make_stream(seed: int, *, num_snapshots: int = 8, batch_size: int = 20):
    src, dst = generate_rmat(V, 192, seed=seed)
    w = generate_uniform_weights(len(src), seed=seed + 1, grid=16)
    return generate_evolving_stream(
        src, dst, w, V, num_snapshots=num_snapshots, batch_size=batch_size,
        readd_prob=0.4, seed=seed + 2,
    )


def paired_logs(seed: int, n_shards: int, *, n_prime: int = WINDOW):
    base, deltas = make_stream(seed)
    log = SnapshotLog(V, capacity=512)
    slog = ShardedSnapshotLog(V, n_shards, capacity=64)
    log.append_snapshot(*base)
    slog.append_snapshot(*base)
    for d in deltas[: n_prime - 1]:
        log.append_snapshot(*d)
        slog.append_snapshot(*d)
    return log, slog, deltas[n_prime - 1:]


# ----------------------------------------------------------- host structures
def test_append_routes_edges_to_dst_owners():
    log, slog, pending = paired_logs(seed=0, n_shards=4)
    for d in pending:
        log.append_snapshot(*d)
        slog.append_snapshot(*d)
    assert slog.num_snapshots == log.num_snapshots
    assert slog.num_edges == log.num_edges
    v_local = slog.v_local
    for s, sh in enumerate(slog.shards):
        n = sh.num_edges
        if n:
            assert ((sh.dst[:n] // v_local) == s).all()
    # the union of shard universes is the single-host universe
    pairs = set()
    for sh in slog.shards:
        n = sh.num_edges
        pairs |= set(zip(sh.src[:n].tolist(), sh.dst[:n].tolist()))
    n = log.num_edges
    assert pairs == set(zip(log.src[:n].tolist(), log.dst[:n].tolist()))


def test_sharded_append_is_atomic_across_shards():
    slog = ShardedSnapshotLog(V, 4, capacity=64)
    # edges on two different shards
    slog.append_snapshot([0, 1], [1, V - 1], [1.0, 2.0])
    # second deletion is absent (dst V-2 on the last shard): the whole delta
    # must be rejected with NO shard advanced — not just the failing one
    with pytest.raises(KeyError):
        slog.append_snapshot([], [], [], [0, 1], [1, V - 2])
    assert all(sh.num_snapshots == 1 for sh in slog.shards)
    with pytest.raises(ValueError):
        slog.append_snapshot([0], [V + 3], [1.0])
    with pytest.raises(ValueError):
        slog.append_snapshot([0, 1], [2], [1.0, 1.0])
    with pytest.raises(ValueError):
        slog.append_snapshot([], [], [], [0], [1, 2])
    assert all(sh.num_snapshots == 1 for sh in slog.shards)
    t = slog.append_snapshot([], [], [])
    assert t == 1


def test_sharded_log_shape_validation():
    with pytest.raises(ValueError):
        ShardedSnapshotLog(V, 5)  # 48 % 5 != 0
    with pytest.raises(ValueError):
        ShardedSnapshotLog(V, 0)


def test_sharded_from_stream_roundtrip():
    base, deltas = make_stream(seed=5)
    log = SnapshotLog.from_stream(base, deltas, V)
    slog = ShardedSnapshotLog.from_stream(base, deltas, V, n_shards=4)
    assert slog.num_snapshots == log.num_snapshots
    assert slog.num_edges == log.num_edges
    ref = EvolvingQuery(
        WindowView(log).materialize(pad_to_capacity=False), "sssp", 0
    ).evaluate("cqrs")
    got = EvolvingQuery(
        ShardedWindowView(slog).materialize(pad_to_capacity=False), "sssp", 0
    ).evaluate("cqrs")
    np.testing.assert_array_equal(got, ref)


def test_sharded_materialize_matches_single_host():
    log, slog, pending = paired_logs(seed=1, n_shards=4)
    view = WindowView(log, size=WINDOW)
    sview = ShardedWindowView(slog, size=WINDOW)
    for d in pending:
        log.append_snapshot(*d)
        slog.append_snapshot(*d)
        view.slide()
        sview.slide()
        ref = EvolvingQuery(view.materialize(), "sssp", 0).evaluate("cqrs")
        got = EvolvingQuery(sview.materialize(), "sssp", 0).evaluate("cqrs")
        np.testing.assert_array_equal(got, ref)


def test_shard_slide_diffs_partition_the_global_diff():
    """Per-shard diffs, mapped back to (src, dst) pairs, must exactly tile
    the single-host diff — no transition lost or duplicated across shards."""
    log, slog, pending = paired_logs(seed=2, n_shards=4)
    view = WindowView(log, size=WINDOW)
    sview = ShardedWindowView(slog, size=WINDOW)

    def pairs_of(sh, ids):
        return set(zip(sh.src[ids].tolist(), sh.dst[ids].tolist()))

    for d in pending:
        log.append_snapshot(*d)
        slog.append_snapshot(*d)
        gd = view.slide()
        sd = sview.slide()
        assert (sd.appended, sd.retired) == (gd.appended, gd.retired)
        for field in ("union_gained", "union_lost", "inter_gained",
                      "inter_lost", "wmin_shrunk", "wmax_grown",
                      "wmin_grown", "wmax_shrunk"):
            want = set(zip(log.src[getattr(gd, field)].tolist(),
                           log.dst[getattr(gd, field)].tolist()))
            got = set()
            for sh, part in zip(slog.shards, sd.shards):
                ids = getattr(part, field)
                local = pairs_of(sh, ids)
                assert not (got & local)  # shard-disjoint
                got |= local
            assert got == want, field
        assert sd.is_empty() == gd.is_empty()


def test_one_shard_spmd_query_in_process():
    """n_shards=1 runs the full shard_map path on the lone CPU device, so
    tier-1 covers the sharded engine without a forced host mesh."""
    from repro.distributed.stream_shard import ShardedStreamingQuery

    log, slog, pending = paired_logs(seed=3, n_shards=1)
    view = WindowView(log, size=WINDOW)
    sview = ShardedWindowView(slog, size=WINDOW)
    sq = StreamingQuery(view, "sssp", 0)
    ssq = StreamingQuery(sview, "sssp", 0)
    assert isinstance(ssq, ShardedStreamingQuery)  # __new__ dispatch
    np.testing.assert_array_equal(sq.results, ssq.results)
    for d in pending:
        np.testing.assert_array_equal(sq.advance(d), ssq.advance(d))
    assert ssq.stats["method"] == "stream[cqrs]"
    assert ssq.stats["qrs_edges"] == sq.stats["qrs_edges"]
    assert ssq.stats["kernel_launches"] > 0


def test_one_shard_spmd_ell_query_in_process():
    """n_shards=1 cqrs_ell runs the per-shard Pallas path (vrelax inside
    shard_map over the shard's own ELL tiles) on the lone CPU device —
    tier-1 covers the SPMD ELL kernel without a forced host mesh."""
    log, slog, pending = paired_logs(seed=7, n_shards=1)
    view = WindowView(log, size=WINDOW)
    sview = ShardedWindowView(slog, size=WINDOW)
    sq = StreamingQuery(view, "sssp", 0, method="cqrs_ell")
    ssq = StreamingQuery(sview, "sssp", 0, method="cqrs_ell")
    np.testing.assert_array_equal(sq.results, ssq.results)
    shapes = []
    for d in pending:
        np.testing.assert_array_equal(sq.advance(d), ssq.advance(d))
        _, dev = ssq._ell_cache.pack()
        shapes.append(tuple(dev["src"].shape))
    # sticky per-shard row capacity: the stacked planes (and therefore the
    # compiled shard_map kernel) keep one shape across steady-state slides
    assert len(set(shapes)) == 1, shapes


# ----------------------------------------------------- skew-aware assignments
def test_balanced_assignment_evens_out_rmat_skew():
    """Degree-histogram range rebalance: the same RMAT stream that skews
    naive dst ranges ~N× lands within 2× max/mean under 'balanced'."""
    from repro.graph.shardlog import degree_histogram

    base, deltas = make_stream(seed=0)
    hist = degree_histogram(base, deltas, V)
    naive = ShardedSnapshotLog.from_stream(base, deltas, V, 4, capacity=64)
    bal = ShardedSnapshotLog.from_stream(
        base, deltas, V, 4, capacity=64, assignment="balanced",
        degree_hist=hist,
    )
    assert bal.num_edges == naive.num_edges
    assert bal.occupancy_spread() < naive.occupancy_spread()
    assert bal.occupancy_spread() <= 2.0, bal.occupancy_spread()


@pytest.mark.parametrize("mode", ["balanced", "hash"])
def test_assignment_modes_materialize_like_single_host(mode):
    """Rebalanced routing preserves the window: a 4-shard balanced/hash log
    materializes the same canonical graph (and query results) as the
    single-host log on every slide."""
    from repro.graph.shardlog import degree_histogram

    base, deltas = make_stream(seed=2)
    hist = degree_histogram(base, deltas, V)
    log = SnapshotLog(V, capacity=512)
    slog = ShardedSnapshotLog(V, 4, capacity=64, assignment=mode,
                              degree_hist=hist)
    log.append_snapshot(*base)
    slog.append_snapshot(*base)
    for d in deltas[: WINDOW - 1]:
        log.append_snapshot(*d)
        slog.append_snapshot(*d)
    view = WindowView(log, size=WINDOW)
    sview = ShardedWindowView(slog, size=WINDOW)
    for d in deltas[WINDOW - 1:]:
        log.append_snapshot(*d)
        slog.append_snapshot(*d)
        view.slide()
        sview.slide()
        ref = EvolvingQuery(view.materialize(), "sssp", 0).evaluate("cqrs")
        got = EvolvingQuery(sview.materialize(), "sssp", 0).evaluate("cqrs")
        np.testing.assert_array_equal(got, ref)
    # every edge landed on the shard its assignment names
    owner = slog.assignment.owner
    for s, sh in enumerate(slog.shards):
        n = sh.num_edges
        assert n == 0 or (owner[sh.dst[:n]] == s).all()


@settings(max_examples=6)
@given(
    seed=st.integers(0, 10_000),
    query=st.sampled_from(["sssp", "sswp", "bfs"]),
    method=st.sampled_from(["cqrs", "cqrs_ell"]),
    mode=st.sampled_from(["hash", "balanced"]),
)
def test_assignment_property_bit_for_bit(seed, query, method, mode):
    """Seed-swept: rebalanced-range and hash-of-dst sharded streams match
    the single-host StreamingQuery bit-for-bit across semirings × engines.
    n_shards=1 runs real shard_map on the lone device; the hash mode's
    local-id map is a nontrivial vertex permutation even there, so the
    position-space machinery is exercised in-process (the 8-shard variant
    lives in _stream_shard_checks.py::check_rebalance)."""
    from repro.graph.shardlog import degree_histogram

    base, deltas = make_stream(seed=seed)
    hist = degree_histogram(base, deltas, V)
    log = SnapshotLog(V, capacity=512)
    slog = ShardedSnapshotLog(V, 1, capacity=64, assignment=mode,
                              degree_hist=hist, seed=seed)
    log.append_snapshot(*base)
    slog.append_snapshot(*base)
    for d in deltas[: WINDOW - 1]:
        log.append_snapshot(*d)
        slog.append_snapshot(*d)
    view = WindowView(log, size=WINDOW)
    sview = ShardedWindowView(slog, size=WINDOW)
    sq = StreamingQuery(view, query, 0, method=method)
    ssq = StreamingQuery(sview, query, 0, method=method)
    np.testing.assert_array_equal(sq.results, ssq.results)
    for d in deltas[WINDOW - 1: WINDOW + 1]:
        np.testing.assert_array_equal(sq.advance(d), ssq.advance(d))


def test_ell_batcher_serves_sharded_view():
    """A cqrs_ell QueryBatcher serves sharded views through the sharded ELL
    path (sticky-shape ELL over the stacked shard universes) — no silent
    fallback to cqrs, and bit-for-bit equal to the single-host watcher."""
    from repro.serving.scheduler import QueryBatcher

    log, slog, pending = paired_logs(seed=6, n_shards=1)
    sview = ShardedWindowView(slog, size=WINDOW)
    qb = QueryBatcher(method="cqrs_ell")
    sq = qb.watch(sview, "sssp", 0)
    assert sq.method == "cqrs_ell"
    view = WindowView(log, size=WINDOW)
    ref = qb.watch(view, "sssp", 0)
    assert ref.method == "cqrs_ell"  # single-host default unchanged
    got = qb.advance_window(sview, pending[0])
    want = qb.advance_window(view, pending[0])
    np.testing.assert_array_equal(got[("sssp", 0)], want[("sssp", 0)])


def test_sharded_query_validation():
    _, slog, _ = paired_logs(seed=4, n_shards=1)
    sview = ShardedWindowView(slog, size=WINDOW)
    with pytest.raises(ValueError):
        StreamingQuery(sview, "sssp", 0, method="kickstarter")
    with pytest.raises(ValueError):
        StreamingQuery(sview, "sssp", 0, window=WINDOW + 1)
    with pytest.raises(RuntimeError):
        # more shards than visible devices → actionable host-mesh error
        from repro.distributed.stream_shard import host_mesh

        host_mesh(1024)


# ------------------------------------------------------- 8-device mesh checks
def _run(check: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep
        + os.path.dirname(__file__)
    )
    out = subprocess.run(
        [sys.executable, SCRIPT, check],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert out.returncode == 0, f"{check} failed:\n{out.stdout}\n{out.stderr}"
    assert "CHECK_OK" in out.stdout


@pytest.mark.parametrize(
    "check",
    ["equivalence", "growth", "serving", "shard_local", "qbatch",
     "collectives", "ell", "rebalance", "warmstart", "reshard", "chaos"],
)
def test_stream_shard_mesh(check):
    _run(check)

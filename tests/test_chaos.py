"""Chaos-hardening suite: seeded fault schedules, degraded serving, DLQ,
checkpoint integrity, heartbeat flap backoff, async error propagation.

The seed sweep drives ≥20 deterministic :class:`FaultPlan` schedules across
ingest / advance-phase / checkpoint / executor sites, three semirings, both
streaming engines, and sync / pipelined / sharded serving — every schedule
must recover bit-for-bit against the fault-free reference (monotone
fixpoints are unique; the transactional slide makes retries re-fold the
same diffs).
"""
from __future__ import annotations

import tempfile

import numpy as np
import pytest

from repro.ft.chaos import ChaosHarness
from repro.ft.faultinject import (
    ADVANCE_SITES,
    EXECUTOR_SITES,
    INGEST_SITES,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    active_injector,
    corrupt_point,
    fault_point,
    inject,
)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

STREAM = dict(num_snapshots=8)  # 5 served slides per run


# =========================================================================
# Seed sweep: ≥20 schedules × engines × semirings × serving modes
# =========================================================================
SWEEP_CONFIGS = {
    "sync-cqrs-sssp": (dict(method="cqrs"), dict()),
    "sync-cqrs_ell-sssp": (dict(method="cqrs_ell"), dict()),
    "pipelined-cqrs-sssp": (
        dict(method="cqrs", pipelined=True),
        dict(sites=INGEST_SITES[:1] + ADVANCE_SITES + EXECUTOR_SITES),
    ),
    "sharded1-cqrs-sssp": (
        dict(method="cqrs", n_shards=1),
        dict(sites=INGEST_SITES + ADVANCE_SITES),
    ),
    "sync-cqrs-sswp": (
        dict(method="cqrs", watchers=(("sswp", 0), ("sswp", 7))), dict(),
    ),
    "sync-cqrs-ssnp": (
        dict(method="cqrs", watchers=(("ssnp", 0), ("ssnp", 7))), dict(),
    ),
    "sync-two-groups": (
        dict(method="cqrs", watchers=(("sssp", 0), ("sswp", 7))),
        dict(n_faults=3),
    ),
}
SWEEP_CASES = [
    (cfg, seed)
    for cfg, seeds in [
        ("sync-cqrs-sssp", (0, 1, 2, 3)),
        ("sync-cqrs_ell-sssp", (4, 5)),
        ("pipelined-cqrs-sssp", (6, 7)),
        ("sharded1-cqrs-sssp", (8, 9)),
        ("sync-cqrs-sswp", (10, 11)),
        ("sync-cqrs-ssnp", (12, 13)),
        ("sync-two-groups", (14, 15)),
    ]
    for seed in seeds
]

_HARNESSES: dict = {}


def _harness(cfg: str) -> ChaosHarness:
    if cfg not in _HARNESSES:
        kwargs, _ = SWEEP_CONFIGS[cfg]
        _HARNESSES[cfg] = ChaosHarness(**STREAM, **kwargs)
    return _HARNESSES[cfg]


@pytest.mark.parametrize("cfg,seed", SWEEP_CASES)
def test_seeded_schedule_recovers_bit_for_bit(cfg, seed):
    h = _harness(cfg)
    _, run_kwargs = SWEEP_CONFIGS[cfg]
    report = h.run(seed=seed, **run_kwargs)
    assert report["converged"], (cfg, seed, report["mismatches"], report["fired"])
    assert report["faults_fired"] >= 1, (cfg, seed, report)
    assert not report["cache_degraded"]


def test_checkpoint_site_schedules_recover():
    """Torn writes + committed-payload corruption during a chaotic run."""
    with tempfile.TemporaryDirectory() as d:
        h = ChaosHarness(num_snapshots=10, ckpt_every=2, ckpt_dir=d)
        plan = FaultPlan(specs=(
            FaultSpec(site="ckpt_torn", slide=1),
            FaultSpec(site="ckpt_payload", slide=2, mode="bitflip"),
            FaultSpec(site="advance_eval", slide=3),
        ))
        report = h.run(plan)
        assert report["converged"], report["mismatches"]
        assert report["torn_ckpts"] == 1
        assert report["ckpt_restore_ok"]
    with tempfile.TemporaryDirectory() as d:
        h = ChaosHarness(num_snapshots=10, ckpt_every=2, ckpt_dir=d)
        plan = FaultPlan(specs=(
            FaultSpec(site="ckpt_payload", slide=0, mode="truncate"),
            FaultSpec(site="ingest", slide=2, mode="duplicate"),
        ))
        report = h.run(plan)
        assert report["converged"], report["mismatches"]
        assert report["ckpt_restore_ok"]


def test_executor_stall_schedule_converges():
    h = ChaosHarness(**STREAM, pipelined=True)
    plan = FaultPlan(specs=(
        FaultSpec(site="executor_stall", slide=1, payload=0.02, times=2),
    ))
    report = h.run(plan)
    assert report["converged"]
    assert report["faults_fired"] >= 1


def test_torn_cross_shard_append_self_heals():
    h = ChaosHarness(**STREAM, n_shards=1)
    plan = FaultPlan(specs=(FaultSpec(site="ingest_shard", slide=2, shard=0),))
    report = h.run(plan)
    assert report["converged"], report["mismatches"]
    assert report["faults_fired"] == 1
    # the torn append self-healed: nothing was quarantined, nothing degraded
    assert report["quarantined"] == 0


# =========================================================================
# Degraded-mode serving contract
# =========================================================================
def _serving_fixture(**qb_kwargs):
    from repro.graph.stream import SnapshotLog, WindowView
    from repro.obs.export import EventLog
    from repro.serving.scheduler import QueryBatcher

    h = ChaosHarness(**STREAM)
    log = SnapshotLog(h.num_vertices, capacity=512)
    log.append_snapshot(*h.base)
    for d in h.prime_deltas:
        log.append_snapshot(*d)
    view = WindowView(log, size=h.window)
    now = [0.0]
    ev = EventLog()
    qb = QueryBatcher(
        clock=lambda: now[0], events=ev, backoff_base=0.25, backoff_cap=1.0,
        **qb_kwargs,
    )
    qb.watch(view, "sssp", 0)
    qb.watch(view, "sssp", 7)
    return h, view, qb, now, ev


def _clean_rows():
    """Fault-free per-slide rows for the default stream/watcher config."""
    h = _harness("sync-cqrs-sssp")
    if h._reference is None:
        h._reference = h._run(None)
    return h._reference["rows"]


def test_persistent_fault_serves_last_good_with_accurate_lag():
    """Advance keeps failing → stale rows with exact slides_behind; recovery
    clears degraded within the budget; no exception escapes."""
    h, view, qb, now, ev = _serving_fixture(retry_budget=16)
    clean = _clean_rows()

    plan = FaultPlan(specs=(
        FaultSpec(site="advance_bounds_refresh", slide=-1, times=3),
    ))
    with inject(plan, events=ev) as inj:
        out0 = qb.advance_window(view, h.serve_deltas[0])   # fail 1
        assert out0.degraded
        assert set(out0.slides_behind.values()) == {1}
        assert qb.cache_info().degraded
        assert qb.cache_info().slides_behind[("sssp", 0)] == 1

        # next slide arrives while still degraded (backoff passed): the lag
        # grows and the served rows are still the pre-fault fixpoint
        now[0] += 10.0
        out1 = qb.advance_window(view, h.serve_deltas[1])   # fail 2
        assert out1.degraded
        assert max(out1.slides_behind.values()) == 2

        now[0] += 10.0
        out2 = qb.advance_window(view, h.serve_deltas[2])   # fail 3 (last)
        assert out2.degraded
        assert max(out2.slides_behind.values()) == 3

        # fault exhausted: the retry catches up all pending diffs at once
        now[0] += 10.0
        out3 = qb.advance_window(view, h.serve_deltas[3])
        assert not out3.degraded
        assert not qb.cache_info().degraded
        assert inj.faults_fired == 3
    for k, v in out3.items():
        assert np.array_equal(v, clean[3][k]), k
    kinds = ev.counts()
    assert kinds.get("rollback", 0) >= 3
    assert kinds.get("degraded", 0) == 3
    assert kinds.get("recovered") == 1


def test_retry_exhausted_escalates_after_budget():
    h, view, qb, now, ev = _serving_fixture(retry_budget=2)
    from repro.serving.scheduler import AdvanceRetryExhausted

    plan = FaultPlan(specs=(
        FaultSpec(site="advance_qrs_patch", slide=-1, times=-1),
    ))
    with inject(plan, events=ev):
        out = qb.advance_window(view, h.serve_deltas[0])    # failure 1
        assert out.degraded
        now[0] += 10.0
        out = qb.advance_window(view, None)                 # failure 2
        assert out.degraded
        now[0] += 10.0
        with pytest.raises(AdvanceRetryExhausted) as ei:    # budget exhausted
            qb.advance_window(view, None)
    assert isinstance(ei.value.__cause__, InjectedFault)
    assert ev.counts().get("retry_exhausted") == 1


def test_poisoned_delta_quarantined_then_redelivered():
    h, view, qb, now, ev = _serving_fixture()
    clean = _clean_rows()
    plan = FaultPlan(specs=(FaultSpec(site="ingest", slide=0, mode="range"),))
    with inject(plan, events=ev) as inj:
        out = qb.advance_window(view, h.serve_deltas[0])
        assert inj.faults_fired == 1
        assert qb.dead_letters.total == 1
        assert not out.degraded  # the slide proceeded over durable state
        entry = qb.dead_letters.entries[0]
        assert "outside [0," in entry.error
        # clean redelivery of the SAME batch converges bit-for-bit
        out = qb.advance_window(view, h.serve_deltas[0])
    for k, v in out.items():
        assert np.array_equal(v, clean[0][k]), k
    assert ev.counts().get("quarantine") == 1


# =========================================================================
# Pipelined async error propagation
# =========================================================================
def test_pending_window_propagates_group_failure_without_wedging():
    """One group's terminal failure fails that window's result with the
    original cause; the executor survives and the next window is clean."""
    from repro.serving.scheduler import AdvanceRetryExhausted

    h, view, qb, now, ev = _serving_fixture(retry_budget=0, pipelined=True)
    qb.watch(view, "sswp", 3)  # sibling group on the same view

    plan = FaultPlan(specs=(FaultSpec(site="advance_eval", slide=0),))
    with inject(plan, events=ev):
        pw = qb.advance_window_async(view, h.serve_deltas[0])
        with pytest.raises(AdvanceRetryExhausted) as ei:
            pw.result()
    assert isinstance(ei.value.__cause__, InjectedFault)

    # not wedged: the failed group was rolled back, so the NEXT window
    # advances everything (the failed group re-folds both pending slides)
    out = qb.advance_window_async(view, h.serve_deltas[1]).result()
    assert not out.degraded
    assert ("sssp", 0) in out and ("sswp", 3) in out
    assert all(np.isfinite(np.asarray(v)).any() for v in out.values())


# =========================================================================
# Checkpoint integrity
# =========================================================================
def test_checkpoint_bitflip_falls_back_to_verifiable_step(tmp_path):
    from repro.checkpoint import (
        CheckpointCorruptError, CheckpointManager, resume_streaming,
        streaming_state,
    )
    from repro.core.api import StreamingQuery
    from repro.graph.stream import SnapshotLog, WindowView

    h = ChaosHarness(**STREAM)
    log = SnapshotLog(h.num_vertices, capacity=512)
    log.append_snapshot(*h.base)
    for d in h.prime_deltas:
        log.append_snapshot(*d)
    view = WindowView(log, size=h.window)
    sq = StreamingQuery(view, "sssp", 0, method="cqrs")
    ref = np.asarray(sq.results).copy()

    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, *streaming_state(sq))
    log.append_snapshot(*h.serve_deltas[0])
    sq.advance()
    # step 2's committed payload is bit-flipped mid-file after the rename
    plan = FaultPlan(specs=(
        FaultSpec(site="ckpt_payload", slide=0, mode="bitflip"),
    ))
    with inject(plan):
        mgr.save(2, *streaming_state(sq))

    arrays, manifest = mgr.load()        # falls back past the corrupt step
    assert manifest["step"] == 1
    resumed = resume_streaming(arrays, manifest["extra"])
    assert np.array_equal(ref, np.asarray(resumed.results))
    with pytest.raises(CheckpointCorruptError):
        mgr.load(2)                      # explicit step: surfaced, not hidden
    # tampering with a section after load is caught by the extra's checksums
    bad = dict(arrays)
    bad["rows/0"] = np.asarray(bad["rows/0"]).copy()
    bad["rows/0"][0] += 1
    with pytest.raises(CheckpointCorruptError):
        resume_streaming(bad, manifest["extra"])


def test_supervisor_restores_through_corrupt_checkpoint(tmp_path):
    """Regression: bit-flip the newest checkpoint mid-payload, crash the
    replica — ServeSupervisor still restores (from the older verifiable
    step) and the re-served slides stay bit-for-bit."""
    from repro.core.api import StreamingQuery
    from repro.checkpoint import CheckpointManager
    from repro.ft.recovery import ServeSupervisor
    from repro.graph.stream import SnapshotLog, WindowView

    h = ChaosHarness(num_snapshots=10)

    def build():
        log = SnapshotLog(h.num_vertices, capacity=512)
        log.append_snapshot(*h.base)
        for d in h.prime_deltas:
            log.append_snapshot(*d)
        view = WindowView(log, size=h.window)
        return StreamingQuery(view, "sssp", 0, method="cqrs")

    ref_replica = build()
    expect = []
    for d in h.serve_deltas:
        ref_replica.advance(d)
        expect.append(np.asarray(ref_replica.results).copy())

    sup = ServeSupervisor(
        manager=CheckpointManager(str(tmp_path), keep=0), ckpt_every=2,
    )
    # ckpt saves happen at slides 2, 4, 6 (and the final); flip the slide-4
    # payload (occurrence 2 of ckpt_payload counting the step-0 prime save),
    # then crash the replica at slide 5 → restore must skip back to slide 2
    plan = FaultPlan(specs=(
        FaultSpec(site="ckpt_payload", slide=2, mode="bitflip"),
        FaultSpec(site="advance_eval", slide=4),
    ))
    with inject(plan) as inj:
        _, served, stats = sup.run(build(), h.serve_deltas)
    assert inj.faults_fired == 2
    assert stats["restarts"] == 1
    for i, (got, want) in enumerate(zip(served, expect)):
        assert np.array_equal(got, want), f"slide {i} diverged after restore"


# =========================================================================
# Heartbeat flap backoff
# =========================================================================
def test_heartbeat_flapping_worker_backs_off():
    from repro.ft.heartbeat import HeartbeatMonitor
    from repro.obs.export import EventLog

    t = [0.0]
    ev = EventLog()
    hb = HeartbeatMonitor(
        2, timeout=10.0, clock=lambda: t[0], events=ev,
        readmit_base=1.0, readmit_cap=8.0, flap_window=1000.0,
    )

    def die_and_readmit(wait_prev):
        if wait_prev:  # release the parked readmission first
            t[0] += wait_prev
            assert 0 not in hb.dead_workers()
        t[0] += 11.0
        assert 0 in hb.dead_workers()
        return hb.readmit(0)

    waits = []
    w = 0.0
    for _ in range(6):
        w = die_and_readmit(w)
        waits.append(w)
    # k deaths in the window → base·2^(k-1), capped
    assert waits == [0.0, 2.0, 4.0, 8.0, 8.0, 8.0]
    # while parked the worker stays dead and beats are ignored
    assert 0 in hb.declared_dead
    hb.beat(0)
    assert 0 in hb.declared_dead
    # flap-window expiry resets the penalty
    t[0] += 5000.0
    hb.dead_workers()
    t[0] += 11.0
    hb.dead_workers()
    assert hb.readmit(0) == 0.0
    flaps = [e["flaps"] for e in ev.of_kind("readmit_backoff")]
    assert flaps == [2, 3, 4, 5, 6]


# =========================================================================
# Injection is inert when disarmed
# =========================================================================
def test_injection_points_are_noops_when_disarmed():
    assert active_injector() is None
    fault_point("advance_eval")          # no raise
    delta = (np.array([0]), np.array([1]), np.array([1.0]))
    assert corrupt_point("ingest", delta, num_vertices=4) is delta
    with inject(FaultPlan()) as inj:
        with pytest.raises(RuntimeError):
            with inject(FaultPlan()):    # nested arming is ambiguous
                pass
        assert inj.faults_fired == 0
    assert active_injector() is None

"""Batched streaming serving: StreamingQueryBatch ≡ per-watcher loop.

The core contract of the serving Q-fold: ``StreamingQueryBatch.advance()``
— one vmapped bounds refresh + one shared-QRS patch + one batched appended-
snapshot launch for all Q queries — is **bit-for-bit** equal to Q
independent ``StreamingQuery`` instances advanced in a sequential loop, for
≥3 semirings × both engines (``cqrs``/``cqrs_ell``) × single-host/sharded.

Also covered: the window-local weight-extrema regression (a widening
snapshot retiring from the window must NARROW the extrema — the pre-PR
lifetime extrema stayed loose), stable ELL shapes across slides (jit
cache-miss counter), serving-batch membership (add/remove lanes), and
``QueryBatcher.advance_window`` issuing one batched advance per watcher
group instead of Q sequential per-watcher advances.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.api import (
    EvolvingQuery,
    StreamingQuery,
    StreamingQueryBatch,
)
from repro.core.bounds import compute_bounds
from repro.core.semiring import SEMIRINGS
from repro.graph.generators import (
    generate_evolving_stream,
    generate_rmat,
    generate_uniform_weights,
)
from repro.graph.shardlog import ShardedSnapshotLog, ShardedWindowView
from repro.graph.stream import SnapshotLog, WindowView
from repro.graph.structures import build_evolving_graph
from repro.serving.scheduler import QueryBatcher
from _prop import given, settings, st

V = 48
WINDOW = 3
SOURCES = [0, 7, 13, 21]
NO_DELTA = ((), (), (), (), ())


def make_stream(seed: int, *, num_snapshots: int = WINDOW + 3, batch_size: int = 20):
    src, dst = generate_rmat(V, 192, seed=seed)
    w = generate_uniform_weights(len(src), seed=seed + 1, grid=16)
    return generate_evolving_stream(
        src, dst, w, V, num_snapshots=num_snapshots, batch_size=batch_size,
        readd_prob=0.4, seed=seed + 2,
    )


def make_log(seed: int, *, capacity: int = 512):
    base, deltas = make_stream(seed)
    log = SnapshotLog(V, capacity=capacity)
    log.append_snapshot(*base)
    for d in deltas[: WINDOW - 1]:
        log.append_snapshot(*d)
    return log, deltas[WINDOW - 1:]


def fresh_eval(view, query: str, source: int) -> np.ndarray:
    return EvolvingQuery(view.materialize(), query, source).evaluate("cqrs")


# ------------------------------------------------------- batch ≡ loop (host)
@pytest.mark.parametrize("query", ["sssp", "sswp", "ssnp"])
@pytest.mark.parametrize("method", ["cqrs", "cqrs_ell"])
def test_batch_equals_sequential_loop(query, method):
    """K batched advances ≡ K advances of Q sequential watchers, bit-for-bit."""
    log, pending = make_log(seed=0)
    view = WindowView(log, size=WINDOW)
    loop_view = WindowView(log, size=WINDOW)
    sqb = StreamingQueryBatch(view, query, SOURCES, method=method)
    seqs = [StreamingQuery(loop_view, query, s, method=method) for s in SOURCES]
    got = sqb.results
    for i, sq in enumerate(seqs):
        np.testing.assert_array_equal(got[i], sq.results)
        np.testing.assert_array_equal(got[i], fresh_eval(view, query, SOURCES[i]))
    for k, delta in enumerate(pending):
        got = sqb.advance(delta)
        for i, sq in enumerate(seqs):
            np.testing.assert_array_equal(
                got[i], sq.advance(),
                err_msg=f"{query}/{method} slide {k} lane {i}",
            )
    assert sqb.stats["slides"] == len(pending)
    assert sqb.stats["num_queries"] == len(SOURCES)
    np.testing.assert_array_equal(
        sqb.result_for(SOURCES[1]), sqb.results[1]
    )


@settings(max_examples=6)
@given(
    seed=st.integers(0, 10_000),
    query=st.sampled_from(["bfs", "sssp", "viterbi"]),
    s0=st.integers(0, V - 1),
)
def test_batch_advance_property(seed, query, s0):
    """Seed-swept: batched advance ≡ per-watcher loop on random streams."""
    log, pending = make_log(seed=seed)
    view = WindowView(log, size=WINDOW)
    loop_view = WindowView(log, size=WINDOW)
    sources = sorted({s0, (s0 + 11) % V, (s0 + 29) % V})
    sqb = StreamingQueryBatch(view, query, sources)
    seqs = [StreamingQuery(loop_view, query, s) for s in sources]
    for i, sq in enumerate(seqs):
        np.testing.assert_array_equal(sqb.results[i], sq.results)
    for delta in pending[:2]:
        got = sqb.advance(delta)
        for i, sq in enumerate(seqs):
            np.testing.assert_array_equal(got[i], sq.advance())


# --------------------------------------------------------- batch ≡ loop (SPMD)
@pytest.mark.parametrize("method", ["cqrs", "cqrs_ell"])
def test_sharded_batch_equals_loop_one_shard(method):
    """n_shards=1 runs the full Q-batched shard_map path on the lone CPU
    device, so tier-1 covers the sharded serving Q-fold without a forced
    host mesh (the 8-device variant lives in _stream_shard_checks.py)."""
    from repro.distributed.stream_shard import ShardedStreamingQueryBatch

    base, deltas = make_stream(seed=3)
    log = SnapshotLog(V, capacity=512)
    slog = ShardedSnapshotLog(V, 1, capacity=64)
    log.append_snapshot(*base)
    slog.append_snapshot(*base)
    for d in deltas[: WINDOW - 1]:
        log.append_snapshot(*d)
        slog.append_snapshot(*d)
    view = WindowView(log, size=WINDOW)
    sview = ShardedWindowView(slog, size=WINDOW)
    sqb = StreamingQueryBatch(sview, "sssp", SOURCES, method=method)
    assert isinstance(sqb, ShardedStreamingQueryBatch)  # __new__ dispatch
    seqs = [StreamingQuery(view, "sssp", s) for s in SOURCES]
    for i, sq in enumerate(seqs):
        np.testing.assert_array_equal(sqb.results[i], sq.results)
    host = StreamingQueryBatch(
        WindowView(log, size=WINDOW), "sssp", SOURCES, method=method
    )
    host.results
    for k, d in enumerate(deltas[WINDOW - 1:]):
        log.append_snapshot(*d)
        got = sqb.advance(d)
        host.advance()
        for i, sq in enumerate(seqs):
            np.testing.assert_array_equal(
                got[i], sq.advance(), err_msg=f"{method} slide {k} lane {i}"
            )
    # per-lane freeze-step ledgers are comparable ACROSS deployments: the
    # sharded joint loop's accounting is defined exactly like the vmapped
    # single-host one (last change step + the confirming pass)
    assert sqb.lane_supersteps == host.lane_supersteps


# --------------------------------------------- window-local extrema narrowing
@pytest.mark.parametrize("query,worse,better,cap_before,cap_after", [
    # sssp: wmax widens to 9 then narrows to 2; val_cap[1] = min(direct, 0→2→1=9)
    ("sssp", 9.0, 2.0, 9.0, 2.0),
    # sswp: wmin widens to 0.5 then narrows to 8; val_cap[1] = max(direct, 0→2→1=4)
    ("sswp", 0.5, 8.0, 4.0, 8.0),
])
def test_weight_narrowing_when_widening_snapshot_retires(
    query, worse, better, cap_before, cap_after
):
    """Regression: the snapshot that widened an edge's weight extrema
    retires from the window — the window-local extrema must NARROW, changing
    a bound the old lifetime extrema left loose.

    Pre-PR behavior (lifetime extrema never narrow): after the slide the
    G∩ safe weight of 0→1 stayed ``worse`` and val_cap[1] stayed at the
    loose value, disagreeing with a from-deltas build of the same window.
    """
    sr = SEMIRINGS[query]
    log = SnapshotLog(4, capacity=64)
    log.append_snapshot([0, 0, 2], [1, 2, 1], [worse, 5.0, 4.0])  # t0
    log.append_snapshot([0], [1], [better])  # t1: re-assign 0→1
    view = WindowView(log, size=2)
    sq = StreamingQuery(view, query, 0)
    sq.results
    # window [0,2): both weights in effect → extrema = {better, worse}
    assert float(np.asarray(sq.bounds.val_cap)[1]) == cap_before

    got = sq.advance(NO_DELTA)  # t2: window [1,3) — only `better` in effect
    assert float(np.asarray(sq.bounds.val_cap)[1]) == cap_after, \
        "window extrema did not narrow when the widening snapshot retired"
    # exactness vs a from-deltas build of the same window
    ref_graph = build_evolving_graph(
        [0, 0, 2], [1, 2, 1], [better, 5.0, 4.0], [NO_DELTA], 4
    )
    ref = compute_bounds(ref_graph, sr, 0)
    np.testing.assert_array_equal(
        np.asarray(sq.bounds.val_cap), np.asarray(ref.val_cap)
    )
    np.testing.assert_array_equal(
        np.asarray(sq.bounds.val_cup), np.asarray(ref.val_cup)
    )
    # and the streamed rows still match fresh evaluation of the window
    np.testing.assert_array_equal(got, fresh_eval(view, query, 0))


def test_narrowing_mid_catch_up_rebuilds():
    """Queued slides where one narrows extrema must rebuild, not fold stale."""
    log = SnapshotLog(4, capacity=64)
    log.append_snapshot([0, 0, 2], [1, 2, 1], [9.0, 5.0, 4.0])
    log.append_snapshot([0], [1], [2.0])
    view = WindowView(log, size=2)
    sq = StreamingQuery(view, "sssp", 0)
    sq.results
    log.append_snapshot([], [], [])          # queued slide 1: t1 retires t0
    log.append_snapshot([1], [3], [1.0], [], [])  # queued slide 2
    got = sq.advance()  # one catch-up over both queued slides
    np.testing.assert_array_equal(got, fresh_eval(view, "sssp", 0))
    assert float(np.asarray(sq.bounds.val_cap)[1]) == 2.0


def test_window_extrema_match_from_deltas_build_under_churn():
    """Seeded stream with per-edge weight CHANGES on re-add: the view's
    materialize() must equal streaming results on every slide (both use the
    exact window extrema, unlike the pre-PR lifetime extrema)."""
    rng = np.random.default_rng(7)
    log = SnapshotLog(8, capacity=64)
    edges = [(0, 1), (0, 2), (1, 3), (2, 3), (3, 4), (1, 5), (5, 6)]
    w0 = {e: float(1 + rng.integers(1, 16)) / 4 for e in edges}
    log.append_snapshot(
        [s for s, _ in edges], [d for _, d in edges], [w0[e] for e in edges]
    )
    log.append_snapshot([], [], [])
    view = WindowView(log, size=2)
    sq = StreamingQuery(view, "sssp", 0)
    sq.results
    present = set(edges)
    for k in range(6):
        adds, dels = [], []
        for e in edges:
            r = rng.random()
            if e in present and r < 0.25:
                dels.append(e)
                present.discard(e)
            elif r < 0.6:
                # (re-)add, sometimes with a different weight
                wmod = float(1 + rng.integers(1, 16)) / 4
                adds.append((e, wmod))
                present.add(e)
        delta = (
            [s for (s, _), _ in adds], [d for (_, d), _ in adds],
            [w for _, w in adds],
            [s for s, _ in dels], [d for _, d in dels],
        )
        got = sq.advance(delta)
        np.testing.assert_array_equal(
            got, fresh_eval(view, "sssp", 0), err_msg=f"slide {k}"
        )


# ----------------------------------------------------- stable ELL kernel path
def test_ell_shapes_and_compile_count_stable_across_slides():
    """Per-slide ELL packs keep identical shapes (sticky amortized rows), so
    the jitted kernel path does not recompile per slide."""
    from repro.kernels.vrelax.ops import concurrent_fixpoint_ell

    base, deltas = make_stream(seed=5, num_snapshots=WINDOW + 9)
    log = SnapshotLog(V, capacity=512)
    log.append_snapshot(*base)
    for d in deltas[: WINDOW - 1]:
        log.append_snapshot(*d)
    pending = deltas[WINDOW - 1:]
    view = WindowView(log, size=WINDOW)
    sq = StreamingQuery(view, "sssp", 0, method="cqrs_ell")
    sq.results
    warm, check = pending[:4], pending[4:]
    for delta in warm:  # amortized row growth settles during warmup
        sq.advance(delta)
    ell0 = sq._qrs.ell_pack()
    shape0 = (ell0.src.shape, ell0.weight.shape, ell0.row2vertex.shape)
    can_count = hasattr(concurrent_fixpoint_ell, "_cache_size")
    misses0 = concurrent_fixpoint_ell._cache_size() if can_count else None
    assert len(check) >= 4
    for k, delta in enumerate(check):
        got = sq.advance(delta)
        ell = sq._qrs.ell_pack()
        assert (ell.src.shape, ell.weight.shape, ell.row2vertex.shape) == \
            shape0, f"ELL shapes changed on slide {k}"
        np.testing.assert_array_equal(got, fresh_eval(view, "sssp", 0))
    if can_count:
        assert concurrent_fixpoint_ell._cache_size() == misses0, \
            "kernel fixpoint recompiled during steady-state slides"


# ------------------------------------------------------- serving batch groups
def test_advance_window_issues_one_batched_advance(monkeypatch):
    """Q=8 watchers on one (view, query): advance_window must run ONE
    batched advance for the group, never Q sequential scalar advances."""
    import repro.core.api as api_mod

    log, pending = make_log(seed=4)
    view = WindowView(log, size=WINDOW)
    loop_view = WindowView(log, size=WINDOW)
    sources = [0, 3, 7, 11, 19, 23, 31, 40]
    qb = QueryBatcher()
    watchers = [qb.watch(view, "sssp", s) for s in sources]
    assert len({id(w.batch) for w in watchers}) == 1  # one group
    assert watchers[0].batch.num_queries == len(sources)
    seqs = [StreamingQuery(loop_view, "sssp", s) for s in sources]
    [sq.results for sq in seqs]

    calls = []
    real_advance = api_mod.StreamingQuery.advance

    def counting_advance(self, delta=None):
        calls.append(type(self).__name__)
        return real_advance(self, delta)

    monkeypatch.setattr(api_mod.StreamingQuery, "advance", counting_advance)
    for delta in pending:
        calls.clear()
        out = qb.advance_window(view, delta)
        # one batched advance for the whole group — not Q scalar ones
        assert calls == ["StreamingQueryBatch"], calls
        assert set(out) == {("sssp", s) for s in sources}
        for s, sq in zip(sources, seqs):
            np.testing.assert_array_equal(
                out[("sssp", s)], real_advance(sq), err_msg=f"source {s}"
            )


def test_watch_groups_by_query_and_method():
    log, pending = make_log(seed=6)
    view = WindowView(log, size=WINDOW)
    qb = QueryBatcher()
    a = qb.watch(view, "sssp", 0)
    b = qb.watch(view, "sssp", 7)          # same group, new lane
    c = qb.watch(view, "bfs", 7)           # different semiring → new group
    d = qb.watch(view, "sssp", 0, method="cqrs_ell")  # different engine
    assert a.batch is b.batch
    assert c.batch is not a.batch and d.batch is not a.batch
    assert qb.watch(view, "sssp", 0) is a  # idempotent handle
    out = qb.advance_window(view, pending[0])
    # (sssp, 0) appears once even though watched under both engines
    assert set(out) == {("sssp", 0), ("sssp", 7), ("bfs", 7)}
    for (qname, s), res in out.items():
        np.testing.assert_array_equal(res, fresh_eval(view, qname, s))


def test_lane_eviction_keeps_group_serving():
    """TTL-evicting one lane must drop only that lane; the group keeps
    serving the remaining watchers correctly."""
    log, pending = make_log(seed=8)
    view = WindowView(log, size=WINDOW)
    now = [0.0]
    qb = QueryBatcher(stream_ttl=10.0, clock=lambda: now[0])
    a = qb.watch(view, "sssp", 0)
    qb.watch(view, "sssp", 7)   # abandoned lane
    assert a.batch.num_queries == 2
    out = qb.advance_window(view, pending[0])
    assert set(out) == {("sssp", 0), ("sssp", 7)}
    now[0] = 16.0
    qb.watch(view, "sssp", 0)   # touch 0; lane 7 idles past the TTL
    out = qb.advance_window(view, pending[1])
    assert set(out) == {("sssp", 0)}
    assert a.batch.num_queries == 1
    assert qb.cache_info().evictions == 1
    np.testing.assert_array_equal(out[("sssp", 0)], fresh_eval(view, "sssp", 0))


def test_last_lane_eviction_drops_group():
    log, _ = make_log(seed=9)
    view = WindowView(log, size=WINDOW)
    now = [0.0]
    qb = QueryBatcher(stream_ttl=5.0, clock=lambda: now[0])
    qb.watch(view, "sssp", 0)
    assert len(qb._batches) == 1
    now[0] = 11.0
    qb.watch(view, "bfs", 1)  # housekeeping evicts the idle sssp lane
    assert len(qb.watching(view)) == 1
    assert len(qb._batches) == 1  # only the bfs group remains
    assert next(iter(qb._batches.values())).semiring.name == "bfs"


# ---------------------------------------------- Q-class compile amortization
def test_q_class_padding_stops_recompiles_under_churn():
    """Membership churn inside a lane-capacity class must not recompile:
    the (Q, V) launch shapes are padded to the sticky power-of-two class
    (dead lanes duplicate lane 0), so watch/evict traffic re-uses the same
    compiled maintenance kernels — pinned by the jit cache-miss counters."""
    from repro.core.concurrent import concurrent_fixpoint_batch
    from repro.core.engine import compute_fixpoint, incremental_fixpoint

    log, pending = make_log(seed=13)
    view = WindowView(log, size=WINDOW)
    sqb = StreamingQueryBatch(view, "sssp", [0, 7, 13])
    assert sqb.lane_capacity == 4  # 3 real lanes padded to the class of 4
    sqb.results
    sqb.advance(pending[0])
    sqb.add_source(21)  # fills the dead lane (first scalar prime compiles)
    sqb.advance(pending[1])
    assert sqb.lane_capacity == 4
    counters = [
        fn for fn in (compute_fixpoint, incremental_fixpoint,
                      concurrent_fixpoint_batch)
        if hasattr(fn, "_cache_size")
    ]
    before = [fn._cache_size() for fn in counters]
    sqb.remove_source(7)   # padded drop: shapes frozen
    sqb.add_source(33)     # re-fills the freed lane: shapes frozen
    sqb.advance(pending[2])
    # read the counters BEFORE the reference evaluations below, which
    # compile their own (materialized-graph) shapes
    after = [fn._cache_size() for fn in counters]
    assert sqb.lane_capacity == 4
    assert sqb.sources == [0, 13, 21, 33]
    for s in sqb.sources:
        np.testing.assert_array_equal(
            sqb.result_for(s), fresh_eval(view, "sssp", s)
        )
    assert after == before, (
        f"maintenance kernels recompiled under same-class churn: "
        f"{[(fn.__name__ if hasattr(fn, '__name__') else fn, a - b) for fn, a, b in zip(counters, after, before)]}"
    )


def test_remove_first_lane_stops_influencing_keep_rule():
    """Regression: dropping lane 0 must re-duplicate a SURVIVING lane into
    the padding slots — if the removed lane's state lingered there, its UVV
    mask would keep loosening the shared QRS keep rule (folded over every
    padded lane) and the batch would keep solving an evicted query."""
    log, pending = make_log(seed=17)
    view = WindowView(log, size=WINDOW)
    sqb = StreamingQueryBatch(view, "sssp", [0, 7, 13])  # cap 4, 1 dead lane
    sqb.results
    sqb.remove_source(0)  # drop lane 0 — padding must re-seat onto lane 7
    assert sqb.sources == [7, 13]
    lane_srcs = sqb._lane_sources()
    assert set(lane_srcs) == {7, 13}, lane_srcs  # no trace of source 0
    assert all(int(s) in (7, 13) for s in sqb._bounds.sources)
    # keep rule now folds survivors only: identical to a fresh 2-lane batch
    fresh = StreamingQueryBatch(WindowView(log, size=WINDOW), "sssp", [7, 13])
    fresh.results
    assert sqb._qrs.num_edges == fresh._qrs.num_edges
    got = sqb.advance(pending[0])
    for i, s in enumerate(sqb.sources):
        np.testing.assert_array_equal(got[i], fresh_eval(view, "sssp", s))


def test_q_class_is_sticky_across_growth():
    log, pending = make_log(seed=15)
    view = WindowView(log, size=WINDOW)
    sqb = StreamingQueryBatch(view, "sssp", [0])
    assert sqb.lane_capacity == 1
    sqb.results
    sqb.add_source(7)   # 1 → 2 (class crossing)
    sqb.add_source(13)  # 2 → 4
    assert sqb.lane_capacity == 4
    sqb.remove_source(7)
    sqb.remove_source(13)
    assert sqb.lane_capacity == 4  # sticky: never shrinks
    got = sqb.advance(pending[0])
    assert got.shape[0] == 1
    np.testing.assert_array_equal(got[0], fresh_eval(view, "sssp", 0))


# ---------------------------------------------- per-lane convergence accounts
def test_per_lane_convergence_accounting():
    """Batched maintenance reports each lane's own freeze step, not just the
    lockstep max — and the counts surface through QueryBatcher.cache_info()
    so serving can spot pathological watchers."""
    log, pending = make_log(seed=14)
    view = WindowView(log, size=WINDOW)
    sqb = StreamingQueryBatch(view, "sssp", SOURCES)
    sqb.results
    ls = sqb.lane_supersteps
    assert set(ls) == set(SOURCES)
    assert all(v > 0 for v in ls.values())  # every lane ran a cold solve
    # dead padding lanes are excluded from the report
    assert len(ls) == len(SOURCES) < sqb.lane_capacity + 1
    sqb.advance(pending[0])
    ls2 = sqb.lane_supersteps
    assert all(ls2[s] >= ls[s] for s in SOURCES)  # monotone accumulation
    # the aggregate stat stays the lockstep count ≥ any single lane's share
    assert sqb.stats["lane_capacity"] == sqb.lane_capacity

    qb = QueryBatcher()
    for s in SOURCES:
        qb.watch(view, "sssp", s)
    qb.advance_window(view, pending[1])
    info = qb.cache_info()
    assert set(info.lane_supersteps) == {("sssp", s) for s in SOURCES}
    assert all(v > 0 for v in info.lane_supersteps.values())


# ------------------------------------------------------------- batch plumbing
def test_batch_membership_add_remove():
    log, pending = make_log(seed=10)
    view = WindowView(log, size=WINDOW)
    sqb = StreamingQueryBatch(view, "sssp", [0, 7])
    sqb.results
    sqb.advance(pending[0])
    sqb.add_source(13)
    assert sqb.sources == [0, 7, 13]
    for s in sqb.sources:
        np.testing.assert_array_equal(sqb.result_for(s), fresh_eval(view, "sssp", s))
    sqb.remove_source(7)
    got = sqb.advance(pending[1])
    assert got.shape[0] == 2
    for s in sqb.sources:
        np.testing.assert_array_equal(sqb.result_for(s), fresh_eval(view, "sssp", s))
    sqb.add_source(0)  # idempotent
    assert sqb.sources == [0, 13]


def test_membership_changes_do_not_reevaluate_surviving_lanes(monkeypatch):
    """add_source primes ONLY the new lane (scalar evals over the window);
    remove_source is pure state surgery — neither re-runs the batched
    window evaluation for lanes whose rows are already exact."""
    import repro.core.api as api_mod

    log, pending = make_log(seed=12)
    view = WindowView(log, size=WINDOW)
    sqb = StreamingQueryBatch(view, "sssp", [0, 7])
    sqb.results
    sqb.advance(pending[0])

    batched_evals, lane_evals = [], []
    real_batched = api_mod.StreamingQueryBatch._eval_snapshot
    real_lane = api_mod.StreamingQueryBatch._eval_lane_snapshot
    monkeypatch.setattr(
        api_mod.StreamingQueryBatch, "_eval_snapshot",
        lambda self, t: batched_evals.append(t) or real_batched(self, t),
    )
    monkeypatch.setattr(
        api_mod.StreamingQueryBatch, "_eval_lane_snapshot",
        lambda self, t, lane: (
            lane_evals.append(t) or real_lane(self, t, lane)
        ),
    )
    sqb.add_source(13)
    assert batched_evals == []  # surviving lanes untouched
    assert len(lane_evals) == WINDOW  # only the new lane, once per snapshot
    lane_evals.clear()
    sqb.remove_source(7)
    assert batched_evals == [] and lane_evals == []  # pure surgery
    for s in sqb.sources:
        np.testing.assert_array_equal(
            sqb.result_for(s), fresh_eval(view, "sssp", s)
        )
    # and the warm state stays coherent through the next slide
    got = sqb.advance(pending[1])
    for i, s in enumerate(sqb.sources):
        np.testing.assert_array_equal(got[i], fresh_eval(view, "sssp", s))


def test_weight_events_compact_with_history_retirement():
    """Assignment events no live view can replay fold into the seed; an
    edge whose events all folded becomes single-weight again (entry dropped,
    lifetime extrema restored to the constant) — event storage is bounded
    by the reachable history, not the log lifetime."""
    log = SnapshotLog(4, capacity=64)
    log.append_snapshot([0, 0, 2], [1, 2, 1], [9.0, 5.0, 4.0])
    log.append_snapshot([0], [1], [2.0])   # event at t1
    sq = StreamingQuery(log, "sssp", 0, window=2)  # private view: prunes
    sq.results
    assert log.has_weight_events
    for _ in range(3):  # slide until t0/t1 retire from reachable history
        sq.advance(NO_DELTA)
        np.testing.assert_array_equal(
            sq.results, fresh_eval(sq.view, "sssp", 0)
        )
    assert log.retired_upto >= 2
    assert not log.has_weight_events  # folded to a constant and dropped
    j = 0  # universe id of 0→1 (first registered)
    assert log.weight_min[j] == log.weight_max[j] == np.float32(2.0)
    # a NEW view on the compacted log seeds exact (narrow) extrema
    sq2 = StreamingQuery(log, "sssp", 0, window=2)
    np.testing.assert_array_equal(sq2.results, sq.results)
    assert float(np.asarray(sq2.bounds.val_cap)[1]) == 2.0


def test_batch_validation():
    log, _ = make_log(seed=11)
    view = WindowView(log, size=WINDOW)
    with pytest.raises(ValueError):
        StreamingQueryBatch(view, "sssp", [])
    with pytest.raises(ValueError):
        StreamingQueryBatch(view, "sssp", [1, 1])
    with pytest.raises(ValueError):
        StreamingQueryBatch(view, "sssp", [0], method="kickstarter")
    sqb = StreamingQueryBatch(view, "sssp", [0])
    with pytest.raises(KeyError):
        sqb.result_for(42)
    with pytest.raises(ValueError):
        sqb.remove_source(0)  # the last lane must stay

"""Shared-QRS safety properties (Theorem 2 is never violated by sharing).

For random evolving graphs and source batches, every non-UVV vertex of every
query in the batch must keep *all* its union-graph in-edges in the shared
QRS — the edge set each per-query QRS would have kept is a subset of the
shared one, so sharing can only add (harmless) work, never drop a required
dependence.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.baselines import BASELINES, run_cqrs_batch
from repro.core.bounds import compute_bounds, compute_bounds_batch
from repro.core.qrs import build_qrs, build_qrs_shared
from repro.core.semiring import SEMIRINGS
from conftest import make_evolving
from _prop import given, settings, st


def _edge_key(src, dst, num_vertices):
    return src.astype(np.int64) * np.int64(num_vertices) + dst.astype(np.int64)


def _sources_for(eg, seed, q=4):
    rng = np.random.default_rng(seed)
    return sorted(int(s) for s in rng.choice(eg.num_vertices, size=q, replace=False))


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    snaps=st.integers(2, 8),
    name=st.sampled_from(sorted(SEMIRINGS)),
)
def test_shared_qrs_keeps_every_nonuvv_inedge(seed, snaps, name):
    eg = make_evolving(num_vertices=48, num_edges=200, num_snapshots=snaps,
                       batch_size=20, seed=seed, readd_prob=0.4)
    sr = SEMIRINGS[name]
    sources = _sources_for(eg, seed)
    bb = compute_bounds_batch(eg, sr, sources)
    sq = build_qrs(eg, bb.uvv, bb.val_cap, sr)  # dispatches to shared mode

    src = np.asarray(eg.src)
    dst = np.asarray(eg.dst)
    union_valid = np.asarray(eg.popcount()) > 0
    uvv_q = np.asarray(bb.uvv)  # (Q, V)

    kept = set(
        _edge_key(
            np.asarray(sq.src)[np.asarray(sq.valid)],
            np.asarray(sq.dst)[np.asarray(sq.valid)],
            eg.num_vertices,
        ).tolist()
    )
    # Theorem 2 safety: an in-edge may be dropped only when its sink is UVV
    # for EVERY query in the batch.
    required = union_valid & (~uvv_q).any(axis=0)[dst]
    req_keys = _edge_key(src[required], dst[required], eg.num_vertices)
    missing = [k for k in req_keys.tolist() if k not in kept]
    assert not missing, f"shared QRS dropped {len(missing)} required in-edges"

    # and each per-query QRS is a subset of the shared edge set
    for qi, s in enumerate(sources):
        b = compute_bounds(eg, sr, s)
        per = build_qrs(eg, b.uvv, b.val_cap, sr)
        per_keys = _edge_key(
            np.asarray(per.src)[np.asarray(per.valid)],
            np.asarray(per.dst)[np.asarray(per.valid)],
            eg.num_vertices,
        )
        assert set(per_keys.tolist()) <= kept, f"per-query QRS ⊄ shared (q={qi})"


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    snaps=st.integers(2, 6),
    name=st.sampled_from(sorted(SEMIRINGS)),
)
def test_shared_qrs_batch_matches_full_fuzz(seed, snaps, name):
    eg = make_evolving(num_vertices=40, num_edges=160, num_snapshots=snaps,
                       batch_size=16, seed=seed, readd_prob=0.4)
    sr = SEMIRINGS[name]
    sources = _sources_for(eg, seed, q=3)
    got, _ = run_cqrs_batch(eg, sr, sources)
    ref = np.stack([BASELINES["full"](eg, sr, s)[0] for s in sources])
    np.testing.assert_allclose(got, ref, rtol=1e-6)


def test_build_qrs_shared_rejects_1d_mask():
    eg = make_evolving(num_vertices=32, num_edges=100, num_snapshots=3,
                       batch_size=10)
    sr = SEMIRINGS["sssp"]
    with pytest.raises(ValueError):
        build_qrs_shared(eg, np.zeros(eg.num_vertices, bool),
                         np.zeros(eg.num_vertices, np.float32), sr)
